#!/usr/bin/env bash
# Bench bit-rot gate: compile every benches/bench_*.rs, then execute
# each with a tiny iteration count (BENCH_SMOKE=1 → 1 warmup, 2 samples,
# shrunken workloads, perf assertions skipped).
#
# Usage: bash scripts/bench_smoke.sh
#
# Benches that exercise the platform need the AOT artifacts
# (rust/artifacts/manifest.json, built via `make artifacts`); when they
# are absent we still build everything — catching signature/API rot —
# and skip only the execution phase.
set -euo pipefail

cd "$(dirname "$0")/../rust"

echo "== cargo build --benches =="
cargo build --release --benches

if [ ! -f artifacts/manifest.json ]; then
  echo "artifacts not built (rust/artifacts/manifest.json missing):"
  echo "benches compiled OK; skipping the execution phase"
  exit 0
fi

status=0
for bench in ../benches/bench_*.rs; do
  name="$(basename "$bench" .rs)"
  echo "== BENCH_SMOKE=1 cargo bench --bench $name =="
  if ! BENCH_SMOKE=1 cargo bench --bench "$name"; then
    echo "FAILED: $name"
    status=1
  fi
done
exit $status
