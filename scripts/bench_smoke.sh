#!/usr/bin/env bash
# Bench bit-rot gate: compile every benches/bench_*.rs, then execute
# each with a tiny iteration count (BENCH_SMOKE=1 → 1 warmup, 2 samples,
# shrunken workloads, perf assertions skipped).
#
# Usage: bash scripts/bench_smoke.sh
#
# Benches that exercise the platform need the AOT artifacts
# (rust/artifacts/manifest.json, built via `make artifacts`); when they
# are absent we still build everything — catching signature/API rot —
# and skip only the execution phase.
#
# Each executed bench writes a machine-readable
# target/bench-results/BENCH_<suite>.json (ops/sec, p50/p99, gate
# verdicts); CI uploads those as artifacts so the perf trajectory is
# recorded across PRs.
set -euo pipefail

cd "$(dirname "$0")/../rust"

# Build each bench target *by name*: a benches/bench_*.rs that fails to
# compile — or was never registered in Cargo.toml — fails this phase
# loudly instead of being silently skipped by a bulk --benches build.
build_status=0
for bench in ../benches/bench_*.rs; do
  name="$(basename "$bench" .rs)"
  echo "== cargo build --release --bench $name =="
  if ! cargo build --release --bench "$name"; then
    echo "BUILD FAILED: $name ($bench did not compile or is not a registered bench target)"
    build_status=1
  fi
done
if [ "$build_status" -ne 0 ]; then
  exit "$build_status"
fi

if [ ! -f artifacts/manifest.json ]; then
  echo "artifacts not built (rust/artifacts/manifest.json missing):"
  echo "benches compiled OK; skipping the execution phase"
  exit 0
fi

rm -rf target/bench-results
status=0
for bench in ../benches/bench_*.rs; do
  name="$(basename "$bench" .rs)"
  echo "== BENCH_SMOKE=1 cargo bench --bench $name =="
  if ! BENCH_SMOKE=1 cargo bench --bench "$name"; then
    echo "FAILED: $name"
    status=1
  fi
done

echo "== collected bench results =="
ls -l target/bench-results/BENCH_*.json 2>/dev/null \
  || echo "no BENCH_*.json results written (benches exited before finish())"
exit $status
