#!/usr/bin/env bash
# Tier-1 verification: build, test, example-smoke, and format-check
# the Rust platform.
#
# Usage: bash scripts/verify.sh
#
# Runs from rust/ so cargo picks up the crate there; artifacts must be
# built first (`make artifacts`) for the platform-level tests and the
# quickstart example smoke to run — without them those tests skip, the
# example step is skipped, and only the pure-logic tests gate.
set -euo pipefail

cd "$(dirname "$0")/../rust"

echo "== cargo build --release =="
cargo build --release

echo "== cargo build --release --examples =="
cargo build --release --examples

echo "== cargo test -q =="
cargo test -q

echo "== example smoke: cargo run --release --example quickstart =="
if [ -f artifacts/manifest.json ]; then
    cargo run --release --example quickstart
else
    echo "artifacts not built (rust/artifacts/manifest.json missing); skipping example smoke"
fi

echo "== recovery smoke: cargo test --release --test durability =="
if [ -f artifacts/manifest.json ]; then
    # Optimized re-run of the crash-recovery suite: debug-mode training
    # under `cargo test -q` above is slow enough that these stay shallow;
    # release mode exercises the full crash/replay/GC scenarios.
    cargo test --release --test durability
else
    echo "artifacts not built (rust/artifacts/manifest.json missing); skipping recovery smoke"
fi

echo "== serve smoke: nsml serve on an ephemeral port =="
if [ -f artifacts/manifest.json ] && [ -x target/release/nsml ]; then
    tmp="$(mktemp -d)"
    trap 'rm -rf "$tmp"' EXIT
    # Seed the state dir with a trained session and promote its best
    # checkpoint to a serving endpoint before the daemon starts.
    sid="$(target/release/nsml run main.py -d mnist -u kim --steps 16 --quiet \
        --state "$tmp/state" | sed -n 's/^session: \([^ ]*\).*/\1/p')"
    [ -n "$sid" ] || { echo "nsml run printed no session id"; exit 1; }
    target/release/nsml promote prod "$sid" --state "$tmp/state"
    # --for-ms bounds the daemon: the service exits 0 on its own after
    # the deadline (a clean, state-saving shutdown — no kill needed).
    target/release/nsml serve --port 0 --for-ms 6000 \
        --state "$tmp/state" > "$tmp/serve.log" 2>&1 &
    serve_pid=$!
    port=""
    for _ in $(seq 1 50); do
        port="$(sed -n 's#.*http://127\.0\.0\.1:\([0-9]*\)/.*#\1#p' "$tmp/serve.log" | head -n1)"
        [ -n "$port" ] && break
        sleep 0.1
    done
    [ -n "$port" ] || { echo "serve never printed its URL"; cat "$tmp/serve.log"; exit 1; }
    curl -sf "http://127.0.0.1:$port/api/v1/sessions" | grep -q '"kind":"sessions"'
    # The SSE route streams forever; grab just the headers and confirm
    # the content type (curl exits 28 on the read timeout — expected).
    curl -s -i -m 2 "http://127.0.0.1:$port/api/v1/events/stream" \
        > "$tmp/sse.out" 2>/dev/null || true
    grep -q "text/event-stream" "$tmp/sse.out"
    # Serving smoke: the promoted endpoint is listed and answers one
    # micro-batched inference through the daemon.
    curl -sf "http://127.0.0.1:$port/api/v1/endpoints" | grep -q '"kind":"endpoints"'
    x="$(seq 144 | awk '{printf "%s0.5", (NR>1?",":"")}')"
    curl -sf -X POST "http://127.0.0.1:$port/api/v1/endpoints/prod/infer" \
        -H "X-Trace-Id: verify-smoke-1" \
        -d "{\"user\":\"kim\",\"x\":[$x]}" | grep -q '"kind":"served"'
    # Observability smoke: the Prometheus exposition covers the HTTP
    # layer, and the inference above left a retrievable span chain.
    curl -sf "http://127.0.0.1:$port/metrics" | grep -q nsml_http_requests_total
    curl -sf "http://127.0.0.1:$port/api/v1/trace/verify-smoke-1" | grep -q '"kind":"trace"'
    wait "$serve_pid"
    echo "serve smoke OK (port $port)"
else
    echo "artifacts or release binary missing; skipping serve smoke"
fi

echo "== cargo clippy --all-targets -- -D warnings =="
if cargo clippy --version >/dev/null 2>&1; then
    cargo clippy --all-targets -- -D warnings
else
    echo "clippy component not installed; skipping (CI runs it as its own job)"
fi

echo "== cargo fmt --check =="
cargo fmt --check

echo "verify OK"
