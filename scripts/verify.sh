#!/usr/bin/env bash
# Tier-1 verification: build, test, example-smoke, and format-check
# the Rust platform.
#
# Usage: bash scripts/verify.sh
#
# Runs from rust/ so cargo picks up the crate there; artifacts must be
# built first (`make artifacts`) for the platform-level tests and the
# quickstart example smoke to run — without them those tests skip, the
# example step is skipped, and only the pure-logic tests gate.
set -euo pipefail

cd "$(dirname "$0")/../rust"

echo "== cargo build --release =="
cargo build --release

echo "== cargo build --release --examples =="
cargo build --release --examples

echo "== cargo test -q =="
cargo test -q

echo "== example smoke: cargo run --release --example quickstart =="
if [ -f artifacts/manifest.json ]; then
    cargo run --release --example quickstart
else
    echo "artifacts not built (rust/artifacts/manifest.json missing); skipping example smoke"
fi

echo "== recovery smoke: cargo test --release --test durability =="
if [ -f artifacts/manifest.json ]; then
    # Optimized re-run of the crash-recovery suite: debug-mode training
    # under `cargo test -q` above is slow enough that these stay shallow;
    # release mode exercises the full crash/replay/GC scenarios.
    cargo test --release --test durability
else
    echo "artifacts not built (rust/artifacts/manifest.json missing); skipping recovery smoke"
fi

echo "== cargo clippy --all-targets -- -D warnings =="
if cargo clippy --version >/dev/null 2>&1; then
    cargo clippy --all-targets -- -D warnings
else
    echo "clippy component not installed; skipping (CI runs it as its own job)"
fi

echo "== cargo fmt --check =="
cargo fmt --check

echo "verify OK"
