"""Layer-2 JAX models: the paper's four alpha-test tasks (§4.1).

1. ``mnist_mlp``     — MNIST-style digit classification (Fig. 2/4 demo)
2. ``emotion_cnn``   — CNN facial-emotion recognition
3. ``movie_rnn``     — BiLSTM movie-rating prediction
4. ``face_gan``      — GAN face generation

Every model is a pure function over a *flat list* of f32 parameter
arrays, which is exactly the calling convention the Rust runtime uses
when it executes the AOT artifacts:

* ``init(seed:i32)                        -> (*params)``
* ``train_step(*params, x, y, lr)         -> (*params', loss)``
* ``train_scan(*params, xs, ys, lr)       -> (*params', mean_loss)``  (K fused steps)
* ``evaluate(*params, x, y)               -> (loss, metric)``
* ``infer(*params, x)                     -> output``

Hot-spot compute (every matmul contraction, the conv contraction via
explicit im2col, the LSTM gate matmuls, the classifier losses) routes
through the Layer-1 Pallas kernels in :mod:`compile.kernels`.
"""

from dataclasses import dataclass, field
from typing import Callable, List, Tuple

import jax
import jax.numpy as jnp

from .kernels.fused_linear import fused_linear
from .kernels.softmax_xent import softmax_xent

# Fused steps per train_scan call (the L2 perf lever: amortizes runtime
# dispatch over K steps; ablated in bench_session).
SCAN_K = 8


# --------------------------------------------------------------------------
# Shared building blocks
# --------------------------------------------------------------------------

def _glorot(key, shape):
    fan_in = shape[0] if len(shape) > 1 else shape[0]
    fan_out = shape[-1]
    scale = jnp.sqrt(2.0 / (fan_in + fan_out))
    return (jax.random.normal(key, shape) * scale).astype(jnp.float32)


def _init_params(seed, shapes):
    key = jax.random.PRNGKey(seed.astype(jnp.uint32))
    keys = jax.random.split(key, len(shapes))
    out = []
    for k, shape in zip(keys, shapes):
        if len(shape) == 1:
            out.append(jnp.zeros(shape, jnp.float32))
        else:
            out.append(_glorot(k, shape))
    return tuple(out)


def _sgd(params, grads, lr):
    return [p - lr * g for p, g in zip(params, grads)]


def _bce_logits(logits, targets):
    """Numerically stable binary cross-entropy on logits."""
    return jnp.mean(jax.nn.softplus(logits) - targets * logits)


def _im2col(x, kh, kw):
    """Extract 'same' 3x3-style patches: [B,H,W,C] -> [B*H*W, kh*kw*C].

    Explicit slicing keeps the feature ordering identical to reshaping an
    HWIO kernel, so the contraction is a plain (Pallas) matmul.
    """
    b, h, w, c = x.shape
    ph, pw = kh // 2, kw // 2
    xp = jnp.pad(x, ((0, 0), (ph, ph), (pw, pw), (0, 0)))
    cols = [xp[:, i : i + h, j : j + w, :] for i in range(kh) for j in range(kw)]
    patches = jnp.stack(cols, axis=3)  # [B,H,W,kh*kw,C]
    return patches.reshape(b * h * w, kh * kw * c)


def conv2d(x, k, b):
    """'same' conv through the Pallas matmul. x:[B,H,W,C], k:[KH,KW,C,O]."""
    bsz, h, w, _ = x.shape
    kh, kw, cin, cout = k.shape
    cols = _im2col(x, kh, kw)  # [B*H*W, KH*KW*C]
    kmat = k.reshape(kh * kw * cin, cout)
    out = fused_linear(cols, kmat, b, "none")
    return out.reshape(bsz, h, w, cout)


def maxpool2(x):
    """2x2 max pool, NHWC."""
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


# --------------------------------------------------------------------------
# Model definition container
# --------------------------------------------------------------------------

@dataclass
class ModelDef:
    name: str
    param_shapes: List[Tuple[int, ...]]
    batch: int
    x_shape: Tuple[int, ...]          # train/eval input (incl. batch dim)
    x_dtype: str                      # "f32" | "i32"
    y_shape: Tuple[int, ...]
    y_dtype: str
    infer_x_shape: Tuple[int, ...]    # infer input (may differ, e.g. GAN latents)
    loss_fn: Callable                 # (params, x, y) -> scalar loss
    eval_fn: Callable                 # (params, x, y) -> (loss, metric)
    infer_fn: Callable                # (params, x) -> output
    metric_name: str = "loss"
    lower_is_better: bool = True
    scan_k: int = SCAN_K
    description: str = ""
    hparam_defaults: dict = field(default_factory=lambda: {"lr": 0.1})
    # Optional custom optimizer step (params, x, y, lr) -> (params', loss);
    # the GAN uses this for its alternating D/G updates.
    step_fn: Callable = None

    # ---- derived entry points (the AOT surface) ----

    def init(self, seed):
        return _init_params(seed, self.param_shapes)

    def _step(self, params, x, y, lr):
        if self.step_fn is not None:
            return self.step_fn(params, x, y, lr)
        loss, grads = jax.value_and_grad(self.loss_fn)(params, x, y)
        return _sgd(params, grads, lr), loss

    def train_step(self, *args):
        *params, x, y, lr = args
        new_params, loss = self._step(list(params), x, y, lr)
        return (*new_params, loss)

    def train_scan(self, *args):
        *params, xs, ys, lr = args

        def body(carry, xy):
            x, y = xy
            new_params, loss = self._step(list(carry), x, y, lr)
            return tuple(new_params), loss

        final, losses = jax.lax.scan(body, tuple(params), (xs, ys))
        return (*final, jnp.mean(losses))

    def evaluate(self, *args):
        *params, x, y = args
        return self.eval_fn(list(params), x, y)

    def infer(self, *args):
        *params, x = args
        return self.infer_fn(list(params), x)


# --------------------------------------------------------------------------
# 1. MNIST MLP (12x12 procedural digits, 10 classes)
# --------------------------------------------------------------------------

MNIST_D = 144  # 12x12
MNIST_C = 10
MNIST_B = 64


def _mnist_logits(params, x):
    w1, b1, w2, b2, w3, b3 = params
    h = fused_linear(x, w1, b1, "relu")
    h = fused_linear(h, w2, b2, "relu")
    return fused_linear(h, w3, b3, "none")


def _mnist_loss(params, x, y):
    return softmax_xent(_mnist_logits(params, x), y)


def _mnist_eval(params, x, y):
    logits = _mnist_logits(params, x)
    acc = jnp.mean((jnp.argmax(logits, axis=1) == y).astype(jnp.float32))
    return softmax_xent(logits, y), acc


def _mnist_infer(params, x):
    return jax.nn.softmax(_mnist_logits(params, x), axis=1)


MNIST_MLP = ModelDef(
    name="mnist_mlp",
    param_shapes=[(MNIST_D, 256), (256,), (256, 128), (128,), (128, MNIST_C), (MNIST_C,)],
    batch=MNIST_B,
    x_shape=(MNIST_B, MNIST_D),
    x_dtype="f32",
    y_shape=(MNIST_B,),
    y_dtype="i32",
    infer_x_shape=(MNIST_B, MNIST_D),
    loss_fn=_mnist_loss,
    eval_fn=_mnist_eval,
    infer_fn=_mnist_infer,
    metric_name="accuracy",
    lower_is_better=False,
    description="MNIST-style digit classification (Fig. 2/4 demo task)",
)


# --------------------------------------------------------------------------
# 2. Emotion CNN (16x16 face sketches, 4 emotions)
# --------------------------------------------------------------------------

EMO_HW = 16
EMO_D = EMO_HW * EMO_HW
EMO_C = 4
EMO_B = 32


def _emotion_logits(params, x):
    k1, c1, k2, c2, w1, b1, w2, b2 = params
    img = x.reshape(-1, EMO_HW, EMO_HW, 1)
    h = jnp.maximum(conv2d(img, k1, c1), 0.0)
    h = maxpool2(h)  # 8x8x8
    h = jnp.maximum(conv2d(h, k2, c2), 0.0)
    h = maxpool2(h)  # 4x4x16
    flat = h.reshape(h.shape[0], -1)  # 256
    h = fused_linear(flat, w1, b1, "relu")
    return fused_linear(h, w2, b2, "none")


def _emotion_loss(params, x, y):
    return softmax_xent(_emotion_logits(params, x), y)


def _emotion_eval(params, x, y):
    logits = _emotion_logits(params, x)
    acc = jnp.mean((jnp.argmax(logits, axis=1) == y).astype(jnp.float32))
    return softmax_xent(logits, y), acc


def _emotion_infer(params, x):
    return jax.nn.softmax(_emotion_logits(params, x), axis=1)


EMOTION_CNN = ModelDef(
    name="emotion_cnn",
    param_shapes=[
        (3, 3, 1, 8), (8,),
        (3, 3, 8, 16), (16,),
        (256, 64), (64,),
        (64, EMO_C), (EMO_C,),
    ],
    batch=EMO_B,
    x_shape=(EMO_B, EMO_D),
    x_dtype="f32",
    y_shape=(EMO_B,),
    y_dtype="i32",
    infer_x_shape=(EMO_B, EMO_D),
    loss_fn=_emotion_loss,
    eval_fn=_emotion_eval,
    infer_fn=_emotion_infer,
    metric_name="accuracy",
    lower_is_better=False,
    description="CNN facial-emotion recognition (alpha-test task 4)",
)


# --------------------------------------------------------------------------
# 3. Movie-rating BiLSTM (token sequences -> rating regression)
# --------------------------------------------------------------------------

MOVIE_T = 24
MOVIE_V = 64  # vocab
MOVIE_E = 32  # embedding dim
MOVIE_H = 64  # lstm hidden
MOVIE_B = 32


def _lstm_scan(x_emb, wg, bg, reverse=False):
    """Single-direction LSTM over [B,T,E]; returns final hidden [B,H]."""
    bsz = x_emb.shape[0]
    h0 = jnp.zeros((bsz, MOVIE_H), jnp.float32)
    c0 = jnp.zeros((bsz, MOVIE_H), jnp.float32)

    def cell(carry, xt):
        h, c = carry
        gates = fused_linear(jnp.concatenate([h, xt], axis=1), wg, bg, "none")
        i, f, g, o = jnp.split(gates, 4, axis=1)
        c = jax.nn.sigmoid(f + 1.0) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
        h = jax.nn.sigmoid(o) * jnp.tanh(c)
        return (h, c), None

    seq = jnp.swapaxes(x_emb, 0, 1)  # [T,B,E]
    (h, _), _ = jax.lax.scan(cell, (h0, c0), seq, reverse=reverse)
    return h


def _movie_pred(params, x):
    emb, wg_f, bg_f, wg_b, bg_b, wh, bh = params
    x_emb = jnp.take(emb, x, axis=0)  # [B,T,E]
    hf = _lstm_scan(x_emb, wg_f, bg_f, reverse=False)
    hb = _lstm_scan(x_emb, wg_b, bg_b, reverse=True)
    h = jnp.concatenate([hf, hb], axis=1)
    # Rating in [0, 10].
    return 10.0 * jax.nn.sigmoid(fused_linear(h, wh, bh, "none"))[:, 0]


def _movie_loss(params, x, y):
    pred = _movie_pred(params, x)
    return jnp.mean((pred - y) ** 2)


def _movie_eval(params, x, y):
    pred = _movie_pred(params, x)
    mse = jnp.mean((pred - y) ** 2)
    return mse, jnp.sqrt(mse)


MOVIE_RNN = ModelDef(
    name="movie_rnn",
    param_shapes=[
        (MOVIE_V, MOVIE_E),
        (MOVIE_H + MOVIE_E, 4 * MOVIE_H), (4 * MOVIE_H,),
        (MOVIE_H + MOVIE_E, 4 * MOVIE_H), (4 * MOVIE_H,),
        (2 * MOVIE_H, 1), (1,),
    ],
    batch=MOVIE_B,
    x_shape=(MOVIE_B, MOVIE_T),
    x_dtype="i32",
    y_shape=(MOVIE_B,),
    y_dtype="f32",
    infer_x_shape=(MOVIE_B, MOVIE_T),
    loss_fn=_movie_loss,
    eval_fn=_movie_eval,
    infer_fn=lambda params, x: _movie_pred(params, x),
    metric_name="rmse",
    lower_is_better=True,
    description="BiLSTM movie-rating prediction (alpha-test task 3)",
    hparam_defaults={"lr": 0.05},
)


# --------------------------------------------------------------------------
# 4. Face GAN (latent 32 -> 12x12 face sketch)
# --------------------------------------------------------------------------

GAN_Z = 32
GAN_D = 144  # 12x12 images
GAN_B = 32
_GAN_GEN_N = 4  # first 4 param arrays are the generator


def _gan_generate(gen_params, z):
    gw1, gb1, gw2, gb2 = gen_params
    h = fused_linear(z, gw1, gb1, "relu")
    return jax.nn.sigmoid(fused_linear(h, gw2, gb2, "none"))


def _gan_disc_logit(disc_params, img):
    dw1, db1, dw2, db2 = disc_params
    h = fused_linear(img, dw1, db1, "lrelu")
    return fused_linear(h, dw2, db2, "none")[:, 0]


def _gan_latents(x):
    """Deterministic per-batch latents derived from the real batch (keeps
    the AOT signature pure: no runtime PRNG plumbing)."""
    seed_row = jnp.sum(x, axis=1, keepdims=True)  # [B,1]
    base = jnp.arange(GAN_Z, dtype=jnp.float32)[None, :]
    return jnp.sin(seed_row * 0.37 + base * 1.7) * 1.5


def _gan_losses(params, x):
    gen, disc = list(params[:_GAN_GEN_N]), list(params[_GAN_GEN_N:])
    z = _gan_latents(x)
    fake = _gan_generate(gen, z)
    real_logit = _gan_disc_logit(disc, x)
    fake_logit = _gan_disc_logit(disc, jax.lax.stop_gradient(fake))
    d_loss = _bce_logits(real_logit, jnp.ones_like(real_logit)) + _bce_logits(
        fake_logit, jnp.zeros_like(fake_logit)
    )
    g_logit = _gan_disc_logit(disc, fake)
    g_loss = _bce_logits(g_logit, jnp.ones_like(g_logit))
    return d_loss, g_loss


def _gan_loss(params, x, y):
    # Combined objective (used for eval/monitoring; training uses the
    # alternating _gan_step below).
    d_loss, g_loss = _gan_losses(params, x)
    return d_loss + g_loss


def _gan_step(params, x, y, lr):
    """Alternating GAN update: D step on real/stop-grad-fake, then G step
    against the *updated* discriminator."""
    gen, disc = list(params[:_GAN_GEN_N]), list(params[_GAN_GEN_N:])
    z = _gan_latents(x)

    def d_obj(d):
        fake = jax.lax.stop_gradient(_gan_generate(gen, z))
        return _bce_logits(_gan_disc_logit(d, x), jnp.ones(x.shape[0])) + _bce_logits(
            _gan_disc_logit(d, fake), jnp.zeros(x.shape[0])
        )

    d_loss, d_grads = jax.value_and_grad(d_obj)(disc)
    disc_new = _sgd(disc, d_grads, lr)

    def g_obj(g):
        fake = _gan_generate(g, z)
        return _bce_logits(_gan_disc_logit(disc_new, fake), jnp.ones(x.shape[0]))

    g_loss, g_grads = jax.value_and_grad(g_obj)(gen)
    gen_new = _sgd(gen, g_grads, lr)
    return gen_new + disc_new, d_loss + g_loss


def _gan_eval(params, x, y):
    d_loss, g_loss = _gan_losses(params, x)
    gen, disc = list(params[:_GAN_GEN_N]), list(params[_GAN_GEN_N:])
    fake = _gan_generate(gen, _gan_latents(x))
    d_acc = 0.5 * (
        jnp.mean((_gan_disc_logit(disc, x) > 0).astype(jnp.float32))
        + jnp.mean((_gan_disc_logit(disc, fake) < 0).astype(jnp.float32))
    )
    return g_loss, d_acc


def _gan_infer(params, z):
    return _gan_generate(list(params[:_GAN_GEN_N]), z)


FACE_GAN = ModelDef(
    name="face_gan",
    param_shapes=[
        (GAN_Z, 128), (128,), (128, GAN_D), (GAN_D,),   # generator
        (GAN_D, 128), (128,), (128, 1), (1,),           # discriminator
    ],
    batch=GAN_B,
    x_shape=(GAN_B, GAN_D),
    x_dtype="f32",
    y_shape=(GAN_B,),
    y_dtype="f32",
    infer_x_shape=(GAN_B, GAN_Z),
    loss_fn=_gan_loss,
    eval_fn=_gan_eval,
    infer_fn=_gan_infer,
    metric_name="g_loss",
    lower_is_better=True,
    scan_k=SCAN_K,
    description="GAN face generation (alpha-test task 2)",
    hparam_defaults={"lr": 0.05},
    step_fn=_gan_step,
)


# --------------------------------------------------------------------------
# Registry
# --------------------------------------------------------------------------

MODELS = {m.name: m for m in [MNIST_MLP, EMOTION_CNN, MOVIE_RNN, FACE_GAN]}


def param_count(model: ModelDef) -> int:
    n = 0
    for s in model.param_shapes:
        c = 1
        for d in s:
            c *= d
        n += c
    return n
