"""Layer-2 entry point.

Re-exports the model registry; see :mod:`compile.models` for the model
definitions and :mod:`compile.aot` for the AOT lowering driver that turns
them into ``artifacts/*.hlo.txt`` for the Rust runtime.
"""

from .models import MODELS, ModelDef, param_count  # noqa: F401
