"""Tiled Pallas matmul — the MXU-idiomatic primitive.

TPU mapping (DESIGN.md §Hardware-Adaptation): the grid expresses the
HBM->VMEM schedule (each (i, j, k) step stages one (TM, TK) tile of ``x``
and one (TK, TN) tile of ``y`` into VMEM), the ``jnp.dot`` inside a block
targets the 128x128 MXU systolic array, and accumulation stays in f32 in
VMEM across the k dimension. Inputs whose dims are not tile multiples are
zero-padded by the wrapper (exact for matmul) — the same thing Mosaic
would require on real hardware.

VMEM footprint per core with the default (64, 128, 128) tiles:
    x tile  64*128*4  =  32 KiB
    y tile 128*128*4  =  64 KiB
    o tile  64*128*4  =  32 KiB      (double-buffered by pallas: x2)
    total ~256 KiB << 16 MiB VMEM  -> plenty of headroom for pipelining.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes: multiples of the 8x128 VPU lanes / 128x128 MXU.
# TILE_M = 256 (perf pass, iteration 2): the CNN's im2col matmuls have
# M = B*H*W = 8192 rows; 64-row tiles meant 128 grid steps whose loop
# overhead (the interpret-mode grid lowers to an XLA while) dominated.
# 256-row tiles cut grid steps 4x at ~0.5 MiB VMEM/step — still far under
# the 16 MiB budget.
TILE_M = 256
TILE_K = 128
TILE_N = 128


def _matmul_kernel(x_ref, y_ref, o_ref):
    """One grid step: o[i,j] += x[i,k] @ y[k,j] (f32 accumulation)."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )


def _pad_to(a, rows, cols):
    return jnp.pad(a, ((0, rows - a.shape[0]), (0, cols - a.shape[1])))


def _ceil_to(v, t):
    return -(-v // t) * t


def _shrink_tiles(m, k, n, tm, tk, tn):
    """Adapt tile sizes to the problem (the §Perf L1 fix).

    Fixed 128-wide tiles waste up to 14x padded MACs on small
    contractions (e.g. the CNN's im2col K=9, N=8). Real MXU tiles bottom
    out at the 8-sublane granule anyway, so for dims smaller than the
    default tile we shrink the tile to the dim rounded up to 8 — identical
    arithmetic on TPU (the hardware pads to its granule regardless) but
    ~100x less padded compute in the lowered HLO.
    """
    g = 8  # sublane granule
    tm = min(tm, _ceil_to(m, g))
    tk = min(tk, _ceil_to(k, g))
    tn = min(tn, _ceil_to(n, g))
    return tm, tk, tn


@functools.partial(jax.jit, static_argnames=("tm", "tk", "tn"))
def matmul(x, y, *, tm=TILE_M, tk=TILE_K, tn=TILE_N):
    """``x @ y`` through the tiled Pallas kernel (f32 in/out)."""
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"contraction mismatch {x.shape} @ {y.shape}"
    tm, tk, tn = _shrink_tiles(m, k, n, tm, tk, tn)
    mp, kp, np_ = _ceil_to(m, tm), _ceil_to(k, tk), _ceil_to(n, tn)
    xp = _pad_to(x.astype(jnp.float32), mp, kp)
    yp = _pad_to(y.astype(jnp.float32), kp, np_)
    grid = (mp // tm, np_ // tn, kp // tk)
    out = pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, tk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((tk, tn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(xp, yp)
    return out[:m, :n]


def estimate_vmem_bytes(tm=TILE_M, tk=TILE_K, tn=TILE_N, double_buffer=True):
    """Analytic VMEM footprint of one grid step (for DESIGN.md §Perf)."""
    tiles = tm * tk + tk * tn + tm * tn
    factor = 2 if double_buffer else 1
    return tiles * 4 * factor


def estimate_mxu_utilization(m, k, n, tm=TILE_M, tk=TILE_K, tn=TILE_N):
    """Fraction of MXU-issued MACs that are useful (not padding)."""
    tm, tk, tn = _shrink_tiles(m, k, n, tm, tk, tn)
    mp, kp, np_ = _ceil_to(m, tm), _ceil_to(k, tk), _ceil_to(n, tn)
    return (m * k * n) / (mp * kp * np_)
