"""Fused softmax cross-entropy Pallas kernel.

One kernel pass computes, per batch row: the max-shifted logits, the
log-sum-exp, and the negative log-likelihood of the label — without
materializing the softmax matrix in HBM (the classic fusion). A custom
VJP supplies ``softmax(z) - onehot(y)`` for the backward pass, again
without an HBM round-trip of intermediate probabilities in the forward.

Tiling: grid over batch tiles of 64 rows; the class dimension stays whole
inside a block (classifier heads here are <= a few hundred classes, well
inside one VMEM tile of 128-lane vectors).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_B = 64


def _xent_kernel(logits_ref, labels_ref, loss_ref):
    z = logits_ref[...]  # [TB, C]
    y = labels_ref[...]  # [TB]
    zmax = jnp.max(z, axis=1, keepdims=True)
    shifted = z - zmax
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=1)) + zmax[:, 0]
    picked = jnp.take_along_axis(z, y[:, None].astype(jnp.int32), axis=1)[:, 0]
    loss_ref[...] = lse - picked  # [TB]


def _ceil_to(v, t):
    return -(-v // t) * t


def _per_row_loss(logits, labels):
    b, c = logits.shape
    bp = _ceil_to(b, TILE_B)
    lp = jnp.pad(logits, ((0, bp - b), (0, 0)))
    # Pad labels with 0 (those rows are sliced off afterwards).
    yp = jnp.pad(labels.astype(jnp.int32), (0, bp - b))
    out = pl.pallas_call(
        _xent_kernel,
        grid=(bp // TILE_B,),
        in_specs=[
            pl.BlockSpec((TILE_B, c), lambda i: (i, 0)),
            pl.BlockSpec((TILE_B,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((TILE_B,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((bp,), jnp.float32),
        interpret=True,
    )(lp.astype(jnp.float32), yp)
    return out[:b]


@jax.custom_vjp
def softmax_xent(logits, labels):
    """Mean cross-entropy of integer ``labels`` under ``logits``."""
    return jnp.mean(_per_row_loss(logits, labels))


def _fwd(logits, labels):
    return softmax_xent(logits, labels), (logits, labels)


def _bwd(res, g):
    logits, labels = res
    b, c = logits.shape
    p = jax.nn.softmax(logits, axis=1)
    onehot = jax.nn.one_hot(labels, c, dtype=jnp.float32)
    dlogits = (p - onehot) * (g / b)
    return dlogits, None


softmax_xent.defvjp(_fwd, _bwd)


@functools.partial(jax.jit)
def accuracy(logits, labels):
    """Fraction of argmax hits (eval metric)."""
    return jnp.mean((jnp.argmax(logits, axis=1) == labels).astype(jnp.float32))
