"""Fused linear layer: ``act(x @ w + b)`` with a Pallas-backed custom VJP.

Forward runs the tiled Pallas matmul; the backward pass's three matmuls
(``dy @ w.T``, ``x.T @ dy``, and the activation-gradient elementwise op)
also go through the same kernel, so the platform's training hot path is
Pallas end to end. ``pallas_call`` defines no autodiff rule, hence the
explicit ``jax.custom_vjp``.
"""

import functools

import jax
import jax.numpy as jnp

from .pallas_matmul import matmul

ACTIVATIONS = ("none", "relu", "tanh", "sigmoid", "lrelu")


def _act(z, kind):
    if kind == "relu":
        return jnp.maximum(z, 0.0)
    if kind == "tanh":
        return jnp.tanh(z)
    if kind == "sigmoid":
        return jax.nn.sigmoid(z)
    if kind == "lrelu":
        return jnp.where(z >= 0.0, z, 0.2 * z)
    return z


def _act_grad(z, kind):
    if kind == "relu":
        return (z > 0.0).astype(jnp.float32)
    if kind == "tanh":
        t = jnp.tanh(z)
        return 1.0 - t * t
    if kind == "sigmoid":
        s = jax.nn.sigmoid(z)
        return s * (1.0 - s)
    if kind == "lrelu":
        return jnp.where(z >= 0.0, 1.0, 0.2)
    return jnp.ones_like(z)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def fused_linear(x, w, b, act="none"):
    """``act(x @ w + b)`` with x:[B,I], w:[I,O], b:[O]."""
    z = matmul(x, w) + b[None, :]
    return _act(z, act)


def _fwd(x, w, b, act):
    z = matmul(x, w) + b[None, :]
    return _act(z, act), (x, w, z)


def _bwd(act, res, dy):
    x, w, z = res
    dz = dy * _act_grad(z, act)
    dx = matmul(dz, w.T)
    dw = matmul(x.T, dz)
    db = jnp.sum(dz, axis=0)
    return dx, dw, db


fused_linear.defvjp(_fwd, _bwd)
