"""Pure-``jax.numpy`` oracles for the Pallas kernels.

pytest checks every kernel against these references — the core L1
correctness signal required before anything is AOT-exported.
"""

import jax
import jax.numpy as jnp


def matmul_ref(x, y):
    return jnp.dot(x.astype(jnp.float32), y.astype(jnp.float32))


def linear_ref(x, w, b, act="none"):
    z = x @ w + b[None, :]
    if act == "relu":
        return jnp.maximum(z, 0.0)
    if act == "tanh":
        return jnp.tanh(z)
    if act == "sigmoid":
        return jax.nn.sigmoid(z)
    if act == "lrelu":
        return jnp.where(z >= 0.0, z, 0.2 * z)
    return z


def softmax_xent_ref(logits, labels):
    logp = jax.nn.log_softmax(logits, axis=1)
    picked = jnp.take_along_axis(logp, labels[:, None].astype(jnp.int32), axis=1)[:, 0]
    return -jnp.mean(picked)


def conv2d_ref(x, k, b):
    """NHWC 'same' conv oracle (used by the emotion CNN tests)."""
    out = jax.lax.conv_general_dilated(
        x, k, window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return out + b[None, None, None, :]
