"""Layer-1 Pallas kernels (build-time only).

The platform's models route their compute hot-spots through these kernels:

* :mod:`pallas_matmul` — tiled MXU-style matmul, the primitive everything
  else builds on.
* :mod:`fused_linear` — linear + bias + activation with a custom VJP whose
  backward matmuls also run through the Pallas kernel.
* :mod:`softmax_xent` — fused log-softmax + NLL loss.
* :mod:`ref` — pure-``jax.numpy`` oracles used by pytest.

All kernels are lowered with ``interpret=True``: the image's CPU PJRT
plugin cannot execute Mosaic custom-calls, so kernel *structure* (tiling,
VMEM footprint, MXU-shaped blocks) is what we optimize; wall-clock TPU
performance is estimated analytically in EXPERIMENTS.md.
"""

from . import fused_linear, pallas_matmul, ref, softmax_xent  # noqa: F401
