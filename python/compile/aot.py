"""AOT lowering driver: JAX models -> HLO text + manifest for Rust.

Run as ``python -m compile.aot --out-dir ../artifacts`` (what
``make artifacts`` does). For every model in the registry it lowers five
entry points (init / train_step / train_scan / evaluate / infer) and
writes:

* ``artifacts/<model>.<entry>.hlo.txt`` — HLO **text**. Text, not a
  serialized ``HloModuleProto``: jax >= 0.5 emits protos with 64-bit
  instruction ids which the xla crate's XLA (xla_extension 0.5.1) rejects
  (``proto.id() <= INT_MAX``); the text parser reassigns ids and
  round-trips cleanly.
* ``artifacts/manifest.json`` — shapes/dtypes/arities so the Rust runtime
  can allocate inputs and decompose outputs without guessing.

Python runs only here, at build time; the Rust binary is self-contained
once artifacts exist.
"""

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .models import MODELS, ModelDef, param_count

DTYPES = {"f32": jnp.float32, "i32": jnp.int32}


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype="f32"):
    return jax.ShapeDtypeStruct(shape, DTYPES[dtype])


def entry_signatures(m: ModelDef):
    """Example-argument specs for each AOT entry point."""
    params = [spec(s) for s in m.param_shapes]
    x = spec(m.x_shape, m.x_dtype)
    y = spec(m.y_shape, m.y_dtype)
    xs = spec((m.scan_k, *m.x_shape), m.x_dtype)
    ys = spec((m.scan_k, *m.y_shape), m.y_dtype)
    lr = spec((), "f32")
    seed = spec((), "i32")
    return {
        "init": (m.init, [seed]),
        "train_step": (m.train_step, [*params, x, y, lr]),
        "train_scan": (m.train_scan, [*params, xs, ys, lr]),
        "evaluate": (m.evaluate, [*params, x, y]),
        "infer": (m.infer, [*params, spec(m.infer_x_shape, m.x_dtype if m.name != "face_gan" else "f32")]),
    }


def lower_model(m: ModelDef, out_dir: str, verbose: bool = True) -> dict:
    """Lower all entries of one model; returns its manifest fragment."""
    artifacts = {}
    for entry, (fn, args) in entry_signatures(m).items():
        # keep_unused: the runtime calling convention always passes every
        # declared input (e.g. the GAN ignores y but still receives it).
        lowered = jax.jit(fn, keep_unused=True).lower(*args)
        text = to_hlo_text(lowered)
        fname = f"{m.name}.{entry}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        artifacts[entry] = fname
        if verbose:
            print(f"  {fname:<34} {len(text):>9} bytes", file=sys.stderr)
    frag = {
        "param_shapes": [list(s) for s in m.param_shapes],
        "param_count": param_count(m),
        "batch": m.batch,
        "x_shape": list(m.x_shape),
        "x_dtype": m.x_dtype,
        "y_shape": list(m.y_shape),
        "y_dtype": m.y_dtype,
        "infer_x_shape": list(m.infer_x_shape),
        "infer_x_dtype": m.x_dtype if m.name != "face_gan" else "f32",
        "scan_k": m.scan_k,
        "metric_name": m.metric_name,
        "lower_is_better": m.lower_is_better,
        "description": m.description,
        "hparam_defaults": m.hparam_defaults,
        "artifacts": artifacts,
    }
    return frag


def main() -> None:
    ap = argparse.ArgumentParser(description="AOT-lower NSML models to HLO text")
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--models", default="all", help="comma-separated subset")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    wanted = list(MODELS) if args.models == "all" else args.models.split(",")
    manifest = {"format": 1, "models": {}}
    for name in wanted:
        m = MODELS[name]
        print(f"lowering {name} ({param_count(m):,} params)", file=sys.stderr)
        manifest["models"][name] = lower_model(m, args.out_dir)
    path = os.path.join(args.out_dir, "manifest.json")
    with open(path, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {path}", file=sys.stderr)


if __name__ == "__main__":
    main()
