"""AOT export contract: HLO text is produced, parseable-looking, and the
manifest faithfully describes the lowered signatures."""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile.aot import entry_signatures, lower_model, to_hlo_text
from compile.models import MODELS, param_count

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_entry_signatures_cover_all_entries():
    for m in MODELS.values():
        sigs = entry_signatures(m)
        assert set(sigs) == {"init", "train_step", "train_scan", "evaluate", "infer"}
        # train_step takes params + x + y + lr.
        _, args = sigs["train_step"]
        assert len(args) == len(m.param_shapes) + 3
        # train_scan stacks K batches.
        _, scan_args = sigs["train_scan"]
        assert scan_args[len(m.param_shapes)].shape[0] == m.scan_k


def test_hlo_text_is_hlo():
    m = MODELS["mnist_mlp"]
    fn, args = entry_signatures(m)["infer"]
    text = to_hlo_text(jax.jit(fn).lower(*args))
    assert "HloModule" in text
    assert "ROOT" in text
    # return_tuple=True: root computation returns a tuple.
    assert "(f32[" in text or "tuple(" in text


def test_init_hlo_takes_scalar_seed():
    m = MODELS["emotion_cnn"]
    fn, args = entry_signatures(m)["init"]
    assert args[0].shape == ()
    assert args[0].dtype == jnp.int32
    text = to_hlo_text(jax.jit(fn).lower(*args))
    assert "s32[]" in text


@pytest.mark.skipif(not os.path.exists(os.path.join(ART_DIR, "manifest.json")),
                    reason="artifacts not built (run make artifacts)")
def test_manifest_matches_models():
    with open(os.path.join(ART_DIR, "manifest.json")) as f:
        manifest = json.load(f)
    assert manifest["format"] == 1
    assert set(manifest["models"]) == set(MODELS)
    for name, m in MODELS.items():
        frag = manifest["models"][name]
        assert frag["param_shapes"] == [list(s) for s in m.param_shapes]
        assert frag["param_count"] == param_count(m)
        assert frag["batch"] == m.batch
        assert frag["x_shape"] == list(m.x_shape)
        assert frag["scan_k"] == m.scan_k
        assert frag["metric_name"] == m.metric_name
        for entry, fname in frag["artifacts"].items():
            path = os.path.join(ART_DIR, fname)
            assert os.path.exists(path), f"missing artifact {fname}"
            with open(path) as fh:
                head = fh.read(200)
            assert "HloModule" in head


def test_lower_model_writes_files(tmp_path):
    # Smallest model end to end into a temp dir.
    m = MODELS["mnist_mlp"]
    frag = lower_model(m, str(tmp_path), verbose=False)
    assert set(frag["artifacts"]) == {"init", "train_step", "train_scan", "evaluate", "infer"}
    for fname in frag["artifacts"].values():
        assert (tmp_path / fname).exists()
