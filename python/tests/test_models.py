"""L2 correctness: the four alpha-task models behave like models.

Shapes, determinism, loss descent under training, custom GAN step
semantics, conv building block vs the lax oracle, and scan/step
equivalence (the L2 perf variant must be numerically faithful).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import ref
from compile.models import MODELS, SCAN_K, conv2d, maxpool2, param_count

ALL = sorted(MODELS)


def make_batch(m, seed=0):
    rng = np.random.default_rng(seed)
    if m.x_dtype == "i32":
        x = jnp.asarray(rng.integers(0, 64, m.x_shape), jnp.int32)
    else:
        x = jnp.asarray(rng.random(m.x_shape), jnp.float32)
    if m.y_dtype == "i32":
        classes = 10 if m.name == "mnist_mlp" else 4
        y = jnp.asarray(rng.integers(0, classes, m.y_shape), jnp.int32)
    else:
        y = jnp.asarray(rng.random(m.y_shape) * 5.0, jnp.float32)
    return x, y


# ---------------------------------------------------------------------------
# Building blocks
# ---------------------------------------------------------------------------

def test_conv2d_matches_lax_oracle():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((2, 16, 16, 3)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((3, 3, 3, 5)) * 0.1, jnp.float32)
    b = jnp.asarray(rng.standard_normal(5), jnp.float32)
    np.testing.assert_allclose(conv2d(x, k, b), ref.conv2d_ref(x, k, b), rtol=1e-4, atol=1e-4)


def test_conv2d_grad_flows():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((2, 8, 8, 1)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((3, 3, 1, 4)) * 0.1, jnp.float32)
    b = jnp.zeros(4, jnp.float32)
    g = jax.grad(lambda k: jnp.sum(conv2d(x, k, b) ** 2))(k)
    assert g.shape == k.shape
    assert float(jnp.max(jnp.abs(g))) > 0.0


def test_maxpool2():
    x = jnp.arange(16, dtype=jnp.float32).reshape(1, 4, 4, 1)
    out = maxpool2(x)
    assert out.shape == (1, 2, 2, 1)
    np.testing.assert_allclose(out[0, :, :, 0], [[5.0, 7.0], [13.0, 15.0]])


# ---------------------------------------------------------------------------
# Per-model contracts
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ALL)
def test_init_shapes_and_determinism(name):
    m = MODELS[name]
    p1 = m.init(jnp.int32(3))
    p2 = m.init(jnp.int32(3))
    p3 = m.init(jnp.int32(4))
    assert [p.shape for p in p1] == [tuple(s) for s in m.param_shapes]
    for a, b in zip(p1, p2):
        np.testing.assert_array_equal(a, b)
    # Different seed differs somewhere (matrices; biases start at zero).
    assert any(float(jnp.max(jnp.abs(a - b))) > 0 for a, b in zip(p1, p3))
    assert param_count(m) == sum(int(np.prod(s)) for s in m.param_shapes)


@pytest.mark.parametrize("name", ALL)
def test_train_reduces_loss(name):
    m = MODELS[name]
    params = list(m.init(jnp.int32(0)))
    x, y = make_batch(m)
    lr = jnp.float32(m.hparam_defaults["lr"])
    step = jax.jit(m.train_step)
    first = None
    for i in range(12):
        out = step(*params, x, y, lr)
        params = list(out[:-1])
        if i == 0:
            first = float(out[-1])
    last = float(out[-1])
    assert np.isfinite(first) and np.isfinite(last)
    assert last < first, f"{name}: {first} -> {last}"


@pytest.mark.parametrize("name", ALL)
def test_scan_equals_repeated_steps(name):
    m = MODELS[name]
    params = list(m.init(jnp.int32(1)))
    xs = jnp.stack([make_batch(m, seed=i)[0] for i in range(m.scan_k)])
    ys = jnp.stack([make_batch(m, seed=i)[1] for i in range(m.scan_k)])
    lr = jnp.float32(m.hparam_defaults["lr"])

    scan_out = m.train_scan(*params, xs, ys, lr)
    scan_params, scan_loss = list(scan_out[:-1]), float(scan_out[-1])

    step = jax.jit(m.train_step)
    p = list(params)
    losses = []
    for i in range(m.scan_k):
        out = step(*p, xs[i], ys[i], lr)
        p = list(out[:-1])
        losses.append(float(out[-1]))
    for a, b in zip(scan_params, p):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)
    assert abs(scan_loss - np.mean(losses)) < 1e-4


@pytest.mark.parametrize("name", ALL)
def test_evaluate_and_infer_shapes(name):
    m = MODELS[name]
    params = list(m.init(jnp.int32(2)))
    x, y = make_batch(m)
    loss, metric = m.evaluate(*params, x, y)
    assert np.isfinite(float(loss)) and np.isfinite(float(metric))
    xi = x if m.infer_x_shape == m.x_shape else jnp.asarray(
        np.random.default_rng(0).random(m.infer_x_shape), jnp.float32
    )
    out = m.infer(*params, xi)
    assert out.shape[0] == m.batch


def test_mnist_probabilities_normalized():
    m = MODELS["mnist_mlp"]
    params = list(m.init(jnp.int32(0)))
    x, _ = make_batch(m)
    probs = m.infer(*params, x)
    np.testing.assert_allclose(jnp.sum(probs, axis=1), np.ones(m.batch), rtol=1e-5)


def test_movie_predictions_in_range():
    m = MODELS["movie_rnn"]
    params = list(m.init(jnp.int32(0)))
    x, _ = make_batch(m)
    pred = m.infer(*params, x)
    assert float(jnp.min(pred)) >= 0.0
    assert float(jnp.max(pred)) <= 10.0


def test_gan_step_updates_both_nets():
    m = MODELS["face_gan"]
    params = list(m.init(jnp.int32(0)))
    x, y = make_batch(m)
    out = m.train_step(*params, x, y, jnp.float32(0.05))
    new_params = list(out[:-1])
    # Generator (first 4) and discriminator (last 4) must both move.
    gen_moved = any(float(jnp.max(jnp.abs(a - b))) > 0 for a, b in zip(params[:4], new_params[:4]))
    disc_moved = any(float(jnp.max(jnp.abs(a - b))) > 0 for a, b in zip(params[4:], new_params[4:]))
    assert gen_moved and disc_moved


def test_gan_generator_output_is_image_like():
    m = MODELS["face_gan"]
    params = list(m.init(jnp.int32(0)))
    z = jnp.asarray(np.random.default_rng(0).standard_normal(m.infer_x_shape), jnp.float32)
    img = m.infer(*params, z)
    assert img.shape == (m.batch, 144)
    assert float(jnp.min(img)) >= 0.0 and float(jnp.max(img)) <= 1.0


def test_gan_training_reaches_adversarial_equilibrium():
    # GAN losses are adversarial, so "loss goes down" is the wrong check:
    # a healthy run keeps g_loss near ln 2 and the discriminator useful
    # (accuracy strictly better than chance) without divergence.
    m = MODELS["face_gan"]
    params = list(m.init(jnp.int32(0)))
    x, y = make_batch(m)
    step = jax.jit(m.train_step)
    for _ in range(25):
        out = step(*params, x, y, jnp.float32(0.05))
        params = list(out[:-1])
    g_loss, d_acc = (float(v) for v in m.evaluate(*params, x, y))
    assert np.isfinite(g_loss) and g_loss < 3.0, g_loss
    assert 0.5 < d_acc <= 1.0, d_acc
    assert np.isfinite(float(out[-1]))


def test_scan_k_constant_matches_registry():
    for m in MODELS.values():
        assert m.scan_k == SCAN_K
