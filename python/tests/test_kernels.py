"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes (including awkward non-tile-multiple ones) and
value ranges; assert_allclose against ref.py is the core signal.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.fused_linear import ACTIVATIONS, fused_linear
from compile.kernels.pallas_matmul import (
    estimate_mxu_utilization,
    estimate_vmem_bytes,
    matmul,
)
from compile.kernels.softmax_xent import accuracy, softmax_xent

RTOL = 2e-5
ATOL = 2e-5


def rand(rng, shape, scale=1.0):
    return jnp.asarray(rng.standard_normal(shape) * scale, jnp.float32)


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------

dims = st.integers(min_value=1, max_value=70)


@settings(max_examples=25, deadline=None)
@given(m=dims, k=dims, n=dims, seed=st.integers(0, 2**31 - 1))
def test_matmul_matches_ref_hypothesis(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x, y = rand(rng, (m, k)), rand(rng, (k, n))
    np.testing.assert_allclose(matmul(x, y), ref.matmul_ref(x, y), rtol=RTOL, atol=ATOL)


@pytest.mark.parametrize(
    "m,k,n",
    [
        (1, 1, 1),
        (64, 128, 128),   # exactly one tile
        (65, 129, 127),   # one past / one short of a tile
        (128, 256, 384),  # multiple tiles each way
        (3, 300, 5),      # deep contraction, small output
    ],
)
def test_matmul_tile_boundaries(m, k, n):
    rng = np.random.default_rng(42)
    x, y = rand(rng, (m, k)), rand(rng, (k, n))
    np.testing.assert_allclose(matmul(x, y), ref.matmul_ref(x, y), rtol=RTOL, atol=ATOL)


def test_matmul_custom_tiles():
    rng = np.random.default_rng(0)
    x, y = rand(rng, (40, 60)), rand(rng, (60, 24))
    out = matmul(x, y, tm=8, tk=16, tn=8)
    np.testing.assert_allclose(out, ref.matmul_ref(x, y), rtol=RTOL, atol=ATOL)


def test_matmul_large_values_stable():
    rng = np.random.default_rng(1)
    x, y = rand(rng, (16, 32), 100.0), rand(rng, (32, 8), 100.0)
    np.testing.assert_allclose(matmul(x, y), ref.matmul_ref(x, y), rtol=1e-4, atol=1e-1)


def test_vmem_estimate_under_budget():
    # Default tiles must sit far below the ~16 MiB VMEM of a TPU core.
    assert estimate_vmem_bytes() < 1 << 20
    assert 0.0 < estimate_mxu_utilization(60, 100, 10) <= 1.0
    assert estimate_mxu_utilization(64, 128, 128) == 1.0


# ---------------------------------------------------------------------------
# fused_linear (forward + custom VJP)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("act", ACTIVATIONS)
def test_fused_linear_forward(act):
    rng = np.random.default_rng(7)
    x, w, b = rand(rng, (33, 50)), rand(rng, (50, 20)), rand(rng, (20,))
    np.testing.assert_allclose(
        fused_linear(x, w, b, act), ref.linear_ref(x, w, b, act), rtol=RTOL, atol=ATOL
    )


@settings(max_examples=15, deadline=None)
@given(
    b=st.integers(1, 40),
    i=st.integers(1, 60),
    o=st.integers(1, 40),
    act=st.sampled_from(ACTIVATIONS),
    seed=st.integers(0, 2**31 - 1),
)
def test_fused_linear_hypothesis(b, i, o, act, seed):
    rng = np.random.default_rng(seed)
    x, w, bias = rand(rng, (b, i)), rand(rng, (i, o)), rand(rng, (o,))
    np.testing.assert_allclose(
        fused_linear(x, w, bias, act), ref.linear_ref(x, w, bias, act), rtol=RTOL, atol=ATOL
    )


@pytest.mark.parametrize("act", ["none", "relu", "tanh", "sigmoid", "lrelu"])
def test_fused_linear_grads_match_ref(act):
    rng = np.random.default_rng(3)
    x, w, b = rand(rng, (16, 24)), rand(rng, (24, 12)), rand(rng, (12,))

    def loss_pallas(x, w, b):
        return jnp.sum(fused_linear(x, w, b, act) ** 2)

    def loss_ref(x, w, b):
        return jnp.sum(ref.linear_ref(x, w, b, act) ** 2)

    gp = jax.grad(loss_pallas, argnums=(0, 1, 2))(x, w, b)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(x, w, b)
    for a, c in zip(gp, gr):
        np.testing.assert_allclose(a, c, rtol=1e-4, atol=1e-4)


def test_fused_linear_jit_and_vmap_compose():
    rng = np.random.default_rng(5)
    x, w, b = rand(rng, (8, 10)), rand(rng, (10, 6)), rand(rng, (6,))
    jitted = jax.jit(lambda x: fused_linear(x, w, b, "relu"))
    np.testing.assert_allclose(jitted(x), ref.linear_ref(x, w, b, "relu"), rtol=RTOL, atol=ATOL)


# ---------------------------------------------------------------------------
# softmax_xent
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(b=st.integers(1, 80), c=st.integers(2, 30), seed=st.integers(0, 2**31 - 1))
def test_softmax_xent_hypothesis(b, c, seed):
    rng = np.random.default_rng(seed)
    logits = rand(rng, (b, c), 3.0)
    labels = jnp.asarray(rng.integers(0, c, (b,)), jnp.int32)
    got = softmax_xent(logits, labels)
    want = ref.softmax_xent_ref(logits, labels)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_softmax_xent_extreme_logits_stable():
    logits = jnp.asarray([[1000.0, -1000.0], [-1000.0, 1000.0]], jnp.float32)
    labels = jnp.asarray([0, 1], jnp.int32)
    got = float(softmax_xent(logits, labels))
    assert np.isfinite(got)
    assert got < 1e-3


def test_softmax_xent_grad_matches_ref():
    rng = np.random.default_rng(11)
    logits = rand(rng, (20, 7), 2.0)
    labels = jnp.asarray(rng.integers(0, 7, (20,)), jnp.int32)
    gp = jax.grad(lambda l: softmax_xent(l, labels))(logits)
    gr = jax.grad(lambda l: ref.softmax_xent_ref(l, labels))(logits)
    np.testing.assert_allclose(gp, gr, rtol=1e-5, atol=1e-6)


def test_softmax_xent_perfect_prediction_low_loss():
    labels = jnp.asarray([0, 1, 2], jnp.int32)
    logits = 50.0 * jax.nn.one_hot(labels, 3)
    assert float(softmax_xent(logits, labels)) < 1e-3
    assert float(accuracy(logits, labels)) == 1.0


def test_accuracy_metric():
    logits = jnp.asarray([[2.0, 1.0], [0.0, 3.0], [5.0, 0.0], [0.0, 1.0]], jnp.float32)
    labels = jnp.asarray([0, 1, 1, 1], jnp.int32)
    assert float(accuracy(logits, labels)) == 0.75
