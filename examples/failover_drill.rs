//! SPOF / failure drill (paper §3.2 + §4.2, experiments E6/E12):
//! kill the scheduler leader mid-flight (Zookeeper-style re-election
//! takes over) and kill a worker node under a training session (the
//! session auto-recovers from its checkpoint).
//!
//! Run with: `cargo run --release --example failover_drill`

use nsml::api::{ApiRequest, ApiResponse, NsmlPlatform, PlatformConfig, PlatformService, RunParams};
use nsml::scheduler::ReplicaId;

fn main() -> anyhow::Result<()> {
    let cfg = PlatformConfig { sched_replicas: 3, ..PlatformConfig::default() };
    let service = PlatformService::new(NsmlPlatform::new(cfg)?);
    let platform = service.platform();
    println!("== NSML failover drill ==\n");

    // --- Part 1: scheduler leader election (E6) -----------------------
    let (leader0, epoch0) = platform.election.leader().unwrap();
    println!("scheduler leader: {} (epoch {})", leader0, epoch0);
    platform.election.kill(leader0);
    platform.sim.advance(50);
    let new_leader = platform.election.tick().expect("re-election");
    println!(
        "killed {} -> new leader {} (epoch {}), failover took {} virtual-ms",
        leader0,
        new_leader,
        platform.election.epoch(),
        platform.election.last_failover_ms().unwrap()
    );
    assert_ne!(new_leader, leader0);
    // The deposed leader is fenced out even after reviving.
    platform.election.revive(leader0);
    assert!(!platform.election.is_leader(leader0, epoch0));
    assert_eq!(platform.election.leader().unwrap().0, ReplicaId(1));

    // --- Part 2: worker-node failure mid-training (E12) ---------------
    // Everything below is service dispatches: run, drive, kill_node,
    // run_to_completion — the wire-level version of the drill.
    let mut params = RunParams::new("drill", "mnist");
    params.total_steps = 120;
    params.checkpoint_every = 20;
    params.eval_every = 30;
    let id = match service.dispatch(ApiRequest::Run(params)).into_result()? {
        ApiResponse::Submitted { session } => session,
        other => anyhow::bail!("unexpected reply: {:?}", other),
    };
    while platform.sessions.get(&id).unwrap().steps_done < 40 {
        service.dispatch(ApiRequest::Drive { chunk: 20 }).into_result()?;
    }
    let node = platform.sessions.get(&id).unwrap().node.unwrap();
    let steps_before = platform.sessions.get(&id).unwrap().steps_done;
    println!("\nsession {} at step {} on {}; killing the node…", id, steps_before, node);
    service.dispatch(ApiRequest::KillNode { node: node.0 }).into_result()?;

    service.dispatch(ApiRequest::RunToCompletion { chunk: 20, max_rounds: 100_000 }).into_result()?;
    let rec = platform.sessions.get(&id).unwrap();
    println!(
        "session finished: state={} steps={} recoveries={} (resumed from checkpoint <= step {})",
        rec.state.as_str(),
        rec.steps_done,
        rec.recoveries,
        steps_before
    );
    assert_eq!(rec.state, nsml::session::SessionState::Done);
    assert_eq!(rec.recoveries, 1);
    assert_eq!(rec.steps_done, 120);

    // The alpha testers' complaint ("sometimes unstable, recovers in a
    // few minutes") is now a bounded, observable property.
    println!("\nfailover drill OK");
    Ok(())
}
