//! SPOF / failure drill (paper §3.2 + §4.2, experiments E6/E12):
//! kill the scheduler leader mid-flight (Zookeeper-style re-election
//! takes over) and kill a worker node under a training session (the
//! session auto-recovers from its checkpoint).
//!
//! Run with: `cargo run --release --example failover_drill`

use nsml::api::{NsmlPlatform, PlatformConfig, RunOpts};
use nsml::scheduler::ReplicaId;

fn main() -> anyhow::Result<()> {
    let mut cfg = PlatformConfig::default();
    cfg.sched_replicas = 3;
    let platform = NsmlPlatform::new(cfg)?;
    println!("== NSML failover drill ==\n");

    // --- Part 1: scheduler leader election (E6) -----------------------
    let (leader0, epoch0) = platform.election.leader().unwrap();
    println!("scheduler leader: {} (epoch {})", leader0, epoch0);
    platform.election.kill(leader0);
    platform.sim.advance(50);
    let new_leader = platform.election.tick().expect("re-election");
    println!(
        "killed {} -> new leader {} (epoch {}), failover took {} virtual-ms",
        leader0,
        new_leader,
        platform.election.epoch(),
        platform.election.last_failover_ms().unwrap()
    );
    assert_ne!(new_leader, leader0);
    // The deposed leader is fenced out even after reviving.
    platform.election.revive(leader0);
    assert!(!platform.election.is_leader(leader0, epoch0));
    assert_eq!(platform.election.leader().unwrap().0, ReplicaId(1));

    // --- Part 2: worker-node failure mid-training (E12) ---------------
    let opts = RunOpts { total_steps: 120, checkpoint_every: 20, eval_every: 30, ..Default::default() };
    let id = platform.run("drill", "mnist", opts)?;
    while platform.sessions.get(&id).unwrap().steps_done < 40 {
        platform.drive(20)?;
    }
    let node = platform.sessions.get(&id).unwrap().node.unwrap();
    let steps_before = platform.sessions.get(&id).unwrap().steps_done;
    println!("\nsession {} at step {} on {}; killing the node…", id, steps_before, node);
    platform.kill_node(node);

    platform.run_to_completion(20, 100_000)?;
    let rec = platform.sessions.get(&id).unwrap();
    println!(
        "session finished: state={} steps={} recoveries={} (resumed from checkpoint <= step {})",
        rec.state.as_str(),
        rec.steps_done,
        rec.recoveries,
        steps_before
    );
    assert_eq!(rec.state, nsml::session::SessionState::Done);
    assert_eq!(rec.recoveries, 1);
    assert_eq!(rec.steps_done, 120);

    // The alpha testers' complaint ("sometimes unstable, recovers in a
    // few minutes") is now a bounded, observable property.
    println!("\nfailover drill OK");
    Ok(())
}
