//! Figure 4 reproduction: interactive classification on user input.
//!
//! Trains MNIST, draws a '1', classifies it, then "adds some lines" (the
//! strokes that turn a 1 into a 2) and shows the class probability mass
//! move from 1 to 2 — exactly the paper's web-demo interaction.
//!
//! Run with: `cargo run --release --example mnist_demo`

use nsml::api::{NsmlPlatform, PlatformConfig, RunOpts};
use nsml::data::digits::{ascii_digit, draw_digit, DIM};
use nsml::runtime::TensorData;

fn classify(platform: &NsmlPlatform, id: &str, img: &[f32]) -> anyhow::Result<Vec<f32>> {
    let x = TensorData::f32(img.repeat(64), &[64, DIM as i64]);
    Ok(platform.infer(id, &x)?[..10].to_vec())
}

fn show(probs: &[f32]) -> usize {
    let argmax = probs.iter().enumerate().max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap().0;
    for (i, p) in probs.iter().enumerate() {
        println!("  {} {:>6.3} {}{}", i, p, "#".repeat((p * 40.0) as usize), if i == argmax { "  <= prediction" } else { "" });
    }
    argmax
}

fn main() -> anyhow::Result<()> {
    let platform = NsmlPlatform::new(PlatformConfig::default())?;
    println!("== Fig. 4 demo: immediate classification on interactive input ==");
    let opts = RunOpts { total_steps: 300, eval_every: 50, checkpoint_every: 100, ..Default::default() };
    let id = platform.run("demo", "mnist", opts)?;
    platform.run_to_completion(50, 10_000)?;
    let rec = platform.sessions.get(&id).unwrap();
    println!("trained {}: accuracy {:.3}\n", id, rec.best_metric.unwrap_or(f64::NAN));

    // Upper panel: the user draws a '1'.
    let mut img = vec![0.0f32; DIM];
    draw_digit(1, 0, 0, 1.0, &mut img);
    println!("user draws:\n{}", ascii_digit(&img));
    let pred1 = show(&classify(&platform, &id, &img)?);

    // Lower panel: "input was modified by adding some lines".
    let mut two = vec![0.0f32; DIM];
    draw_digit(2, 0, 0, 1.0, &mut two);
    for (a, b) in img.iter_mut().zip(&two) {
        *a = a.max(*b);
    }
    println!("\nuser adds lines:\n{}", ascii_digit(&img));
    let pred2 = show(&classify(&platform, &id, &img)?);

    println!("\nprediction changed: {} -> {}", pred1, pred2);
    assert_eq!(pred1, 1, "initial drawing should classify as 1");
    assert_eq!(pred2, 2, "modified drawing should classify as 2");
    println!("mnist demo OK (label flipped 1 -> 2, as in the paper's Figure 4)");
    Ok(())
}
