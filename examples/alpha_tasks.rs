//! Alpha-test tasks (paper §4.1, Figure 3): run all four real-world
//! models through the platform concurrently — GAN face generation,
//! BiLSTM movie-rating prediction, CNN emotion recognition, plus the
//! MNIST baseline — and visualize every learning curve.
//!
//! Run with: `cargo run --release --example alpha_tasks`

use nsml::api::{NsmlPlatform, PlatformConfig, RunOpts};
use nsml::util::plot::ascii_chart;
use nsml::util::table::{fnum, Table};

fn main() -> anyhow::Result<()> {
    let platform = NsmlPlatform::new(PlatformConfig::default())?;
    println!("== NSML alpha tests: four real-world tasks (Fig. 3) ==\n");

    // Submit all four sessions; the scheduler spreads them across nodes.
    let tasks: &[(&str, u64)] = &[
        ("mnist", 250),
        ("emotions", 250),
        ("movie-reviews", 250),
        ("faces", 250),
    ];
    let mut ids = Vec::new();
    for (dataset, steps) in tasks {
        let opts = RunOpts {
            total_steps: *steps,
            eval_every: 25,
            checkpoint_every: 100,
            gpus: 2,
            ..Default::default()
        };
        let id = platform.run("alpha", dataset, opts)?;
        println!("submitted {} -> {}", dataset, id);
        ids.push((dataset.to_string(), id));
    }

    let t0 = std::time::Instant::now();
    platform.run_to_completion(25, 100_000)?;
    println!(
        "\nall sessions finished in {:.1}s wall; cluster utilization events logged: {}",
        t0.elapsed().as_secs_f64(),
        platform.events.len()
    );

    let mut summary = Table::new(&["DATASET", "SESSION", "STATE", "STEPS", "METRIC", "BEST"]).right(&[3, 5]);
    for (dataset, id) in &ids {
        let rec = platform.sessions.get(id).unwrap();
        let metric = platform
            .engine()
            .manifest()
            .model(&rec.spec.model)
            .map(|m| m.metric_name.clone())
            .unwrap_or_default();
        summary.row(&[
            dataset.clone(),
            id.clone(),
            rec.state.as_str().to_string(),
            format!("{}", rec.steps_done),
            metric,
            rec.best_metric.map(fnum).unwrap_or_else(|| "-".into()),
        ]);

        let loss = rec.metrics.plot_series("train_loss");
        println!("\n{}", ascii_chart(&format!("{} train_loss", dataset), &[loss], 70, 12));
    }
    println!("{}", summary.render());

    for (dataset, _) in &ids {
        println!("{}", platform.leaderboard.render(dataset));
    }

    // The curves must actually show learning (Fig. 3's point).
    for (dataset, id) in &ids {
        let rec = platform.sessions.get(id).unwrap();
        assert_eq!(rec.state, nsml::session::SessionState::Done, "{}", dataset);
        let losses = rec.metrics.series("train_loss");
        let early: f64 = losses[..10].iter().map(|p| p.1).sum::<f64>() / 10.0;
        let late: f64 = losses[losses.len() - 10..].iter().map(|p| p.1).sum::<f64>() / 10.0;
        // The GAN's adversarial loss plateaus rather than dropping.
        if *dataset != "faces" {
            assert!(late < early, "{}: {} -> {}", dataset, early, late);
        }
        println!("{:<14} mean loss first10={} last10={}", dataset, fnum(early), fnum(late));
    }
    println!("\nalpha tasks OK");
    Ok(())
}
