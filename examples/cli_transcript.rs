//! Figure 2 reproduction: an NSML-CLI session transcript on MNIST.
//!
//! Drives the actual `nsml` CLI entry point end to end against a
//! temporary state directory: dataset listing, a training run, `ps`,
//! the leaderboard, learning-curve plot and the logs — the workflow the
//! paper's Figure 2 screenshots.
//!
//! Run with: `cargo run --release --example cli_transcript`

fn sh(cmdline: &str, state: &str) {
    println!("\n$ nsml {}", cmdline);
    let mut args: Vec<String> = cmdline.split_whitespace().map(str::to_string).collect();
    args.push("--state".into());
    args.push(state.into());
    let code = nsml::cli::main(&args);
    assert_eq!(code, 0, "command failed: nsml {}", cmdline);
}

fn main() {
    let state_dir = std::env::temp_dir().join(format!("nsml-transcript-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&state_dir);
    let state = state_dir.to_string_lossy().to_string();

    println!("== NSML-CLI transcript (Fig. 2) ==");
    sh("models", &state);
    sh("dataset ls", &state);
    sh("run main.py -d mnist --steps 200 --user kim", &state);
    sh("ps", &state);
    sh("dataset board mnist", &state);
    sh("cluster", &state);

    // `nsml logs` / `nsml plot` need the session id from the state dir.
    let text = std::fs::read_to_string(state_dir.join("state.json")).unwrap();
    let doc = nsml::util::json::parse(&text).unwrap();
    let id = doc
        .get("sessions")
        .and_then(|s| s.as_arr())
        .and_then(|a| a.first())
        .and_then(|r| r.at(&["spec", "id"]))
        .and_then(|j| j.as_str())
        .expect("session id in state")
        .to_string();
    sh(&format!("plot {} --metric train_loss", id), &state);
    sh(&format!("infer {} --digit 1 --add-lines", id), &state);

    let _ = std::fs::remove_dir_all(&state_dir);
    println!("\ncli transcript OK");
}
