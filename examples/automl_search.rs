//! AutoML (paper §3.1, experiment E10): hyperparameter optimization over
//! real platform sessions, with performance prediction and best-model
//! saving. Compares exhaustive grid vs successive halving on the same
//! candidate set — same winner, a fraction of the budget — then places a
//! whole candidate ladder as cluster-parallel sessions with a single
//! `SubmitTrialBatch` dispatch through the service layer.
//!
//! Run with: `cargo run --release --example automl_search`

use nsml::api::{
    ApiRequest, ApiResponse, NsmlPlatform, PlatformConfig, PlatformService, PlatformTrialRunner,
    TrialSpec,
};
use nsml::automl::{log_grid, GridSearch, SuccessiveHalving};
use nsml::executor::ExecutorPool;
use nsml::util::table::{fnum, Table};
use std::sync::Arc;

const CANDIDATE_LRS: [f64; 6] = [0.0003, 0.003, 0.03, 0.1, 0.5, 3.0];
const BUDGET_PER_TRIAL: u64 = 60;

fn runner(
    platform: &NsmlPlatform,
    pool: &Arc<ExecutorPool>,
    tag: u64,
) -> anyhow::Result<PlatformTrialRunner> {
    Ok(PlatformTrialRunner::new(
        pool.clone(),
        "mnist",
        &format!("automl{}", tag),
        platform.sessions.clone(),
        platform.clock.clone(),
        CANDIDATE_LRS.len(),
        tag,
    )?)
}

fn main() -> anyhow::Result<()> {
    let service = PlatformService::new(NsmlPlatform::new(PlatformConfig::default())?);
    let platform = service.platform();
    println!("== AutoML: lr search over real MNIST sessions ==\n");

    // Trials train inside a dedicated executor pool: each grid/halving
    // rung fans its candidates out across the workers.
    let pool = platform.new_trial_pool();
    let t0 = std::time::Instant::now();
    let mut grid_runner = runner(platform, &pool, 1)?;
    let grid = GridSearch { lrs: CANDIDATE_LRS.to_vec(), steps_per_trial: BUDGET_PER_TRIAL }
        .run(&mut grid_runner);
    let grid_wall = t0.elapsed();

    let t1 = std::time::Instant::now();
    let mut sh_runner = runner(platform, &pool, 2)?;
    let sh = SuccessiveHalving {
        lrs: CANDIDATE_LRS.to_vec(),
        total_steps_per_trial: BUDGET_PER_TRIAL,
        eta: 2,
        rungs: 3,
    }
    .run(&mut sh_runner);
    let sh_wall = t1.elapsed();

    let mut t = Table::new(&["STRATEGY", "BEST LR", "BEST EVAL LOSS", "STEPS SPENT", "WALL"]).right(&[1, 2, 3, 4]);
    t.row(&[
        "grid (baseline)".into(),
        fnum(grid.best_lr),
        fnum(grid.best_loss),
        format!("{}", grid.steps_spent),
        format!("{:.1}s", grid_wall.as_secs_f64()),
    ]);
    t.row(&[
        "successive halving".into(),
        fnum(sh.best_lr),
        fnum(sh.best_loss),
        format!("{}", sh.steps_spent),
        format!("{:.1}s", sh_wall.as_secs_f64()),
    ]);
    println!("{}", t.render());

    println!("per-candidate budgets (successive halving):");
    for (i, (lr, loss, given)) in sh.trials.iter().enumerate() {
        println!(
            "  trial {}  lr={:<9} loss={:<9} steps={}{}",
            i,
            fnum(*lr),
            fnum(*loss),
            given,
            if i == sh.best_trial { "   <-- winner, model saved" } else { "" }
        );
    }

    // "The systems should save the model of best score."
    let ck = sh_runner.save_best(sh.best_trial)?;
    println!("\nbest model checkpoint: step {} object {}", ck.step, ck.params);

    // Cluster-parallel grid: one SubmitTrialBatch dispatch places the
    // whole lr ladder as independent scheduled sessions.
    let trials: Vec<TrialSpec> = log_grid(CANDIDATE_LRS.len(), -3.5, 0.5)
        .into_iter()
        .map(|lr| TrialSpec { lr, seed: 7, total_steps: BUDGET_PER_TRIAL, gpus: 1 })
        .collect();
    let batch = ApiRequest::SubmitTrialBatch {
        user: "automl3".into(),
        dataset: "mnist".into(),
        trials: trials.clone(),
    };
    let sessions = match service.dispatch(batch) {
        ApiResponse::BatchSubmitted { sessions } => sessions,
        other => anyhow::bail!("batch dispatch failed: {:?}", other),
    };
    println!("\nbatched parallel grid: {} trials placed in one dispatch", sessions.len());
    // Drive until every *batch* session finishes (the in-process trial
    // runners above left their sessions non-terminal in the store, so
    // run_to_completion would never converge here). Bounded like
    // run_to_completion's max_rounds so a wedged session errors out
    // instead of spinning forever.
    let mut rounds = 0;
    while sessions.iter().any(|id| !platform.sessions.get(id).unwrap().state.is_terminal()) {
        match service.dispatch(ApiRequest::Drive { chunk: 20 }) {
            ApiResponse::Progressed { .. } => {}
            other => anyhow::bail!("drive failed: {:?}", other),
        }
        rounds += 1;
        anyhow::ensure!(rounds < 100_000, "batch sessions still pending after {} drive rounds", rounds);
    }
    let mut best_batch: Option<(f64, f64)> = None; // (lr, accuracy)
    for (t, id) in trials.iter().zip(&sessions) {
        let rec = platform.sessions.get(id).unwrap();
        let acc = rec.best_metric.unwrap_or(0.0);
        println!("  lr={:<9} -> best accuracy {:.4}  ({})", fnum(t.lr), acc, id);
        if best_batch.map_or(true, |(_, b)| acc > b) {
            best_batch = Some((t.lr, acc));
        }
    }
    let (batch_lr, batch_acc) = best_batch.unwrap();
    assert!(batch_acc > 0.5, "parallel grid should find a working lr (best acc {})", batch_acc);
    println!("parallel grid winner: lr={} (accuracy {:.4})", fnum(batch_lr), batch_acc);

    assert!(sh.steps_spent < grid.steps_spent, "halving must use less budget");
    let order_of = |lr: f64| lr.log10();
    assert!(
        (order_of(sh.best_lr) - order_of(grid.best_lr)).abs() <= 1.01,
        "strategies should land in the same lr region: {} vs {}",
        sh.best_lr,
        grid.best_lr
    );
    println!(
        "\nautoml OK: halving found lr={} using {:.0}% of grid's budget",
        fnum(sh.best_lr),
        100.0 * sh.steps_spent as f64 / grid.steps_spent as f64
    );
    Ok(())
}
