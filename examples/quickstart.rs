//! Quickstart — the end-to-end validation driver (DESIGN.md E1).
//!
//! Boots the full platform (cluster → scheduler → containers → storage →
//! PJRT runtime), trains the MNIST model for a few hundred steps through
//! the complete `nsml run` path — dispatched through the v1 service
//! layer, the same surface the CLI and `POST /api/v1/*` use — logs the
//! loss curve, and prints the leaderboard. This is the run recorded in
//! EXPERIMENTS.md.
//!
//! Run with: `cargo run --release --example quickstart`

use nsml::api::{ApiRequest, ApiResponse, NsmlPlatform, PlatformConfig, PlatformService, RunParams};
use nsml::util::plot::ascii_chart;

fn main() -> anyhow::Result<()> {
    let cfg = PlatformConfig {
        latency: nsml::container::LatencyModel::default(), // virtual ms
        ..PlatformConfig::default()                        // 10 nodes × 8 GPUs, best-fit
    };
    let service = PlatformService::new(NsmlPlatform::new(cfg)?);
    let platform = service.platform();

    println!("== NSML quickstart ==");
    println!(
        "cluster: {} nodes / {} GPUs | scheduler leader: {}",
        platform.cluster.node_count(),
        platform.cluster.gpu_totals().0,
        platform.election.leader().map(|(l, _)| l.to_string()).unwrap_or_default()
    );

    // nsml run quickstart.py -d mnist --steps 300 (one service dispatch)
    let mut params = RunParams::new("quickstart", "mnist");
    params.total_steps = 300;
    params.eval_every = 25;
    params.checkpoint_every = 75;
    let id = match service.dispatch(ApiRequest::Run(params)) {
        ApiResponse::Submitted { session } => session,
        other => anyhow::bail!("run dispatch failed: {:?}", other),
    };
    println!("submitted session {}", id);

    let t0 = std::time::Instant::now();
    match service.dispatch(ApiRequest::RunToCompletion { chunk: 25, max_rounds: 10_000 }) {
        ApiResponse::Ack { .. } => {}
        other => anyhow::bail!("run_to_completion dispatch failed: {:?}", other),
    }
    let wall = t0.elapsed();

    let rec = platform.sessions.get(&id).unwrap();
    println!(
        "\nsession {}: {} after {} steps ({:.1}s wall, container startup {} virtual-ms)",
        id,
        rec.state.as_str(),
        rec.steps_done,
        wall.as_secs_f64(),
        platform.containers.get(rec.container.as_deref().unwrap_or("")).map(|c| c.startup_ms).unwrap_or(0),
    );
    println!("best accuracy: {:.4}", rec.best_metric.unwrap_or(f64::NAN));

    let loss = rec.metrics.plot_series("train_loss");
    let acc = rec.metrics.plot_series("accuracy");
    println!("\n{}", ascii_chart("train_loss", &[loss], 70, 14));
    println!("{}", ascii_chart("eval accuracy", &[acc], 70, 10));
    println!("{}", platform.leaderboard.render("mnist"));

    assert_eq!(rec.state, nsml::session::SessionState::Done);
    assert!(rec.best_metric.unwrap() > 0.8, "quickstart accuracy should exceed 0.8");
    println!("quickstart OK");
    Ok(())
}
