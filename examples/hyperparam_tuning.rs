//! In-training hyperparameter tuning (paper §3.3, experiment E9):
//! "NSML can achieve hyperparameter tuning in training time by pausing
//! user-written codes, downloading a model from storage container, and
//! resuming the code."
//!
//! Scenario: a session starts with a bad (too high) learning rate. A/B:
//!   A. left alone for the full budget;
//!   B. paused at 1/3 budget, lr edited down, resumed (same total steps).
//! B must end with a better eval loss.
//!
//! Run with: `cargo run --release --example hyperparam_tuning`

use nsml::api::{ApiRequest, ApiResponse, NsmlPlatform, PlatformConfig, PlatformService, RunParams};
use nsml::util::plot::ascii_chart;

const BAD_LR: f64 = 2.0;
const GOOD_LR: f64 = 0.1;
const STEPS: u64 = 240;

fn main() -> anyhow::Result<()> {
    let service = PlatformService::new(NsmlPlatform::new(PlatformConfig::default())?);
    let platform = service.platform();
    println!("== §3.3 hyperparameter tuning in training time ==\n");

    let params = || {
        let mut p = RunParams::new("kim", "mnist");
        p.total_steps = STEPS;
        p.lr = Some(BAD_LR);
        p.eval_every = 20;
        p.checkpoint_every = 40;
        p.seed = 1;
        p
    };
    let submit = |p| -> anyhow::Result<String> {
        match service.dispatch(ApiRequest::Run(p)).into_result()? {
            ApiResponse::Submitted { session } => Ok(session),
            other => anyhow::bail!("unexpected reply: {:?}", other),
        }
    };

    // A: stuck with the bad lr.
    let stuck = submit(params())?;
    // B: will be rescued by a mid-training edit.
    let tuned = submit(params())?;

    // Train both to 1/3 of the budget.
    while platform.sessions.get(&tuned).unwrap().steps_done < STEPS / 3 {
        service.dispatch(ApiRequest::Drive { chunk: 20 }).into_result()?;
    }

    // Pause B, edit lr, resume — the nsml REPL flow, as three dispatches.
    service.dispatch(ApiRequest::Pause { session: tuned.clone() }).into_result()?;
    println!("paused {} at step {}; lr {} -> {}", tuned, platform.sessions.get(&tuned).unwrap().steps_done, BAD_LR, GOOD_LR);
    service.dispatch(ApiRequest::Resume { session: tuned.clone(), lr: Some(GOOD_LR) }).into_result()?;

    service.dispatch(ApiRequest::RunToCompletion { chunk: 20, max_rounds: 100_000 }).into_result()?;

    let rec_stuck = platform.sessions.get(&stuck).unwrap();
    let rec_tuned = platform.sessions.get(&tuned).unwrap();
    let loss_stuck = rec_stuck.metrics.latest("eval_loss").unwrap();
    let loss_tuned = rec_tuned.metrics.latest("eval_loss").unwrap();
    let acc_stuck = rec_stuck.best_metric.unwrap_or(0.0);
    let acc_tuned = rec_tuned.best_metric.unwrap_or(0.0);

    let a = rec_stuck.metrics.plot_series("eval_loss");
    let mut b = rec_tuned.metrics.plot_series("eval_loss");
    b.name = "eval_loss (lr edited)".into();
    println!("{}", ascii_chart("stuck lr=2.0 vs tuned (edited to 0.1 mid-run)", &[a, b], 70, 14));

    println!("fixed bad lr : final eval_loss {:.4}, best accuracy {:.4}", loss_stuck, acc_stuck);
    println!("tuned mid-run: final eval_loss {:.4}, best accuracy {:.4}", loss_tuned, acc_tuned);
    assert!(
        loss_tuned < loss_stuck,
        "in-training tuning should beat the stuck run ({} vs {})",
        loss_tuned,
        loss_stuck
    );
    println!("\nhyperparameter tuning OK (mid-training edit rescued the run)");
    Ok(())
}
