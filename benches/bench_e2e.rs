//! E13: platform end-to-end capacity — sessions/sec through the full
//! submit → schedule → container → train → leaderboard pipeline, and the
//! coordination overhead (everything but training) isolated. Submissions
//! go through `PlatformService::dispatch` (the production entry point);
//! `bench_api` isolates the cost of that layer itself.
//!
//! Run: `cargo bench --bench bench_e2e`

use nsml::api::{ApiRequest, ApiResponse, NsmlPlatform, PlatformConfig, PlatformService, RunParams};
use nsml::util::bench::Bench;

fn submit(service: &PlatformService, params: RunParams) {
    match service.dispatch(ApiRequest::Run(params)) {
        ApiResponse::Submitted { .. } => {}
        other => panic!("run dispatch failed: {:?}", other),
    }
}

fn drain(service: &PlatformService, chunk: u64) {
    match service.dispatch(ApiRequest::RunToCompletion { chunk, max_rounds: 10_000 }) {
        ApiResponse::Ack { .. } => {}
        other => panic!("run_to_completion failed: {:?}", other),
    }
}

fn main() {
    let mut cfg = PlatformConfig::test_default();
    cfg.artifacts_dir = "artifacts".into();
    let service = PlatformService::new(NsmlPlatform::new(cfg).unwrap());
    let mut bench = Bench::new("platform_e2e").with_samples(5);

    // Tiny real sessions: 8 training steps each, 4 sessions per iteration.
    let opts = |seed: u64| {
        let mut p = RunParams::new("bench", "mnist");
        p.total_steps = 8;
        p.eval_every = 8;
        p.checkpoint_every = 8;
        p.seed = seed;
        p
    };
    bench.run_with_units("4 concurrent mnist sessions (8 steps each)", 4.0, || {
        for i in 0..4 {
            submit(&service, opts(i));
        }
        drain(&service, 8);
    });

    // Coordination overhead only: a session whose model is the cheapest
    // (mnist) with a single step — dominated by schedule+container+
    // checkpoint+leaderboard machinery.
    bench.run_with_units("1-step session (coordination overhead)", 1.0, || {
        let mut p = opts(0);
        p.total_steps = 1;
        p.eval_every = 1;
        p.checkpoint_every = 1;
        submit(&service, p);
        drain(&service, 1);
    });

    // Mixed-model wave across the cluster (all four alpha tasks).
    bench.run_with_units("mixed wave: 4 models x 8 steps", 4.0, || {
        for (i, ds) in ["mnist", "emotions", "movie-reviews", "faces"].iter().enumerate() {
            let mut p = opts(10 + i as u64);
            p.dataset = ds.to_string();
            submit(&service, p);
        }
        drain(&service, 8);
    });

    bench.finish();

    let platform = service.platform();
    let stats = platform.master.stats();
    println!(
        "scheduler totals: submitted={} fast_path={} queued={} completed={}",
        stats.submitted, stats.fast_path_hits, stats.queued, stats.completed
    );
    println!(
        "container cache: {} images cached, image stats {:?}, mount stats {:?}",
        platform.containers.images().cached_count(),
        platform.containers.images().stats(),
        platform.containers.mounts().stats()
    );
}
