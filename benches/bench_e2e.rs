//! E13: platform end-to-end capacity — sessions/sec through the full
//! submit → schedule → container → train → leaderboard pipeline, and the
//! coordination overhead (everything but training) isolated.
//!
//! Run: `cargo bench --bench bench_e2e`

use nsml::api::{NsmlPlatform, PlatformConfig, RunOpts};
use nsml::util::bench::Bench;

fn main() {
    let mut cfg = PlatformConfig::test_default();
    cfg.artifacts_dir = "artifacts".into();
    let platform = NsmlPlatform::new(cfg).unwrap();
    let mut bench = Bench::new("platform_e2e").with_samples(5);

    // Tiny real sessions: 8 training steps each, 4 sessions per iteration.
    let opts = RunOpts { total_steps: 8, eval_every: 8, checkpoint_every: 8, ..Default::default() };
    bench.run_with_units("4 concurrent mnist sessions (8 steps each)", 4.0, || {
        for i in 0..4 {
            let mut o = opts.clone();
            o.seed = i;
            platform.run("bench", "mnist", o).unwrap();
        }
        platform.run_to_completion(8, 10_000).unwrap();
    });

    // Coordination overhead only: a session whose model is the cheapest
    // (mnist) with a single step — dominated by schedule+container+
    // checkpoint+leaderboard machinery.
    let one = RunOpts { total_steps: 1, eval_every: 1, checkpoint_every: 1, ..Default::default() };
    bench.run_with_units("1-step session (coordination overhead)", 1.0, || {
        platform.run("bench", "mnist", one.clone()).unwrap();
        platform.run_to_completion(1, 10_000).unwrap();
    });

    // Mixed-model wave across the cluster (all four alpha tasks).
    bench.run_with_units("mixed wave: 4 models x 8 steps", 4.0, || {
        for (i, ds) in ["mnist", "emotions", "movie-reviews", "faces"].iter().enumerate() {
            let mut o = opts.clone();
            o.seed = 10 + i as u64;
            platform.run("bench", ds, o).unwrap();
        }
        platform.run_to_completion(8, 10_000).unwrap();
    });

    bench.finish();

    let stats = platform.master.stats();
    println!(
        "scheduler totals: submitted={} fast_path={} queued={} completed={}",
        stats.submitted, stats.fast_path_hits, stats.queued, stats.completed
    );
    println!(
        "container cache: {} images cached, image stats {:?}, mount stats {:?}",
        platform.containers.images().cached_count(),
        platform.containers.images().stats(),
        platform.containers.mounts().stats()
    );
}
