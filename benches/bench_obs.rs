//! Observability overhead: the spine must be close to free.
//!
//! Three phases:
//!
//! * **histogram record** — the hot-path primitive (three relaxed
//!   atomic adds + a log2). Gate: ≥ 10M records/s best-of-samples.
//! * **daemon e2e, obs on vs off** — the same concurrent serving
//!   workload through the daemon drive loop against two platforms that
//!   differ only in `[obs] enabled`. Gate: the instrumented platform's
//!   best wall-clock is within 5% of the uninstrumented one
//!   (min-of-samples on both sides to shed scheduler noise).
//! * **`GET /metrics` under load** — concurrent scrapers hammer the
//!   Prometheus endpoint over keep-alive sockets while the daemon
//!   serves inference. Scrapes render straight off the registry (no
//!   service-channel hop), so p99 must stay bounded. Gate: ≤ 50 ms.
//!
//! Verdicts land in `target/bench-results/BENCH_obs.json`.
//!
//! Run: `cargo bench --bench bench_obs` (BENCH_SMOKE=1 shrinks the
//! workload and skips the perf assertions).

use nsml::api::{
    service_channel, ApiRequest, ApiResponse, DaemonOpts, NsmlPlatform, PlatformConfig,
    PlatformService,
};
use nsml::obs::MetricsRegistry;
use nsml::util::bench::{smoke, Bench};
use nsml::web::{serve_with, ServeOpts, WebState};
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

const ROW: usize = 144; // one mnist_mlp request row

fn row(seed: usize) -> Vec<f32> {
    (0..ROW).map(|i| ((seed * 31 + i * 7) % 97) as f32 / 97.0).collect()
}

/// A service with one trained session promoted to endpoint "prod",
/// with the observability spine on or off.
fn serving_platform(obs: bool) -> PlatformService {
    let mut cfg = PlatformConfig::test_default();
    cfg.artifacts_dir = "artifacts".into();
    cfg.obs = obs;
    let p = NsmlPlatform::new(cfg).unwrap();
    let opts = nsml::api::RunOpts {
        total_steps: 16,
        eval_every: 8,
        checkpoint_every: 8,
        ..Default::default()
    };
    let id = p.run("bench", "mnist", opts).unwrap();
    p.run_to_completion(8, 10_000).unwrap();
    p.promote_endpoint("prod", &id).unwrap();
    PlatformService::new(p)
}

/// `clients` threads each push `per_client` serve requests through the
/// daemon; returns the wall-clock for the whole phase in ms.
fn serve_phase(service: &PlatformService, clients: usize, per_client: usize) -> f64 {
    let (handle, rx) = service_channel();
    let t0 = Instant::now();
    let workers: Vec<_> = (0..clients)
        .map(|c| {
            let h = handle.clone();
            std::thread::spawn(move || {
                for r in 0..per_client {
                    match h.call(ApiRequest::ServeInfer {
                        endpoint: "prod".into(),
                        user: format!("client{}", c),
                        x: row(c * 1000 + r),
                    }) {
                        ApiResponse::Served { probs, .. } => assert_eq!(probs.len(), 10),
                        other => panic!("serve_infer: {:?}", other),
                    }
                }
            })
        })
        .collect();
    drop(handle); // daemon exits once every client is answered and done
    let opts =
        DaemonOpts { chunk: 1, idle_wait: Duration::from_millis(1), ..DaemonOpts::default() };
    service.run_daemon(&rx, &opts).unwrap();
    for w in workers {
        w.join().unwrap();
    }
    t0.elapsed().as_secs_f64() * 1000.0
}

/// Read one HTTP/1.1 200 response (headers + Content-Length body) off a
/// keep-alive socket, leaving any extra bytes in `buf`.
fn read_one_response(stream: &mut TcpStream, buf: &mut Vec<u8>) {
    fn find(hay: &[u8], needle: &[u8]) -> Option<usize> {
        hay.windows(needle.len()).position(|w| w == needle)
    }
    let header_end = loop {
        if let Some(pos) = find(buf, b"\r\n\r\n") {
            break pos + 4;
        }
        let mut chunk = [0u8; 4096];
        let n = stream.read(&mut chunk).expect("read");
        assert!(n > 0, "server closed the keep-alive socket mid-response");
        buf.extend_from_slice(&chunk[..n]);
    };
    let head = String::from_utf8_lossy(&buf[..header_end]).to_string();
    assert!(head.starts_with("HTTP/1.1 200"), "{}", head);
    let body_len = head
        .lines()
        .find_map(|l| {
            let (k, v) = l.split_once(':')?;
            k.eq_ignore_ascii_case("content-length").then(|| v.trim().parse::<usize>().unwrap())
        })
        .unwrap_or(0);
    while buf.len() < header_end + body_len {
        let mut chunk = [0u8; 4096];
        let n = stream.read(&mut chunk).expect("read body");
        assert!(n > 0, "server closed the keep-alive socket mid-body");
        buf.extend_from_slice(&chunk[..n]);
    }
    buf.drain(..header_end + body_len);
}

fn p99(samples: &[f64]) -> f64 {
    let mut s = samples.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    s[((s.len() - 1) * 99) / 100]
}

fn min_of(samples: &[f64]) -> f64 {
    samples.iter().cloned().fold(f64::INFINITY, f64::min)
}

fn main() {
    let smoke = smoke();
    let mut bench = Bench::new("obs");

    // -----------------------------------------------------------------
    // Phase 1: the hot-path primitive, no platform needed.
    // -----------------------------------------------------------------
    let n: usize = if smoke { 10_000 } else { 2_000_000 };
    let reg = MetricsRegistry::new(true);
    let h = reg.histogram("nsml_bench_ms", &[("lane", "serve")]);
    // Log-uniform latencies spanning the bucket table, cycled.
    let vals: Vec<f64> =
        (0..1024).map(|i| 0.002 * 2f64.powf((i * 37 % 2400) as f64 / 100.0)).collect();
    bench.run_with_units("histogram record", n as f64, || {
        for i in 0..n {
            h.record(std::hint::black_box(vals[i & 1023]));
        }
    });
    let rec = bench.result("histogram record").unwrap();
    let record_ops = n as f64 / (min_of(&rec.samples_ms) / 1000.0);

    // -----------------------------------------------------------------
    // Phases 2 and 3 need the live platform (AOT artifacts).
    // -----------------------------------------------------------------
    let artifacts = std::path::Path::new("artifacts/manifest.json").exists();
    let (clients, per_client, reps) = if smoke { (2, 2, 1) } else { (8, 25, 5) };
    let total = (clients * per_client) as f64;
    let mut overhead = 0.0;
    let mut scrape_p99 = 0.0;
    if artifacts {
        // Obs off first, then on: identical workloads, min-of-samples.
        let off = serving_platform(false);
        let off_walls: Vec<f64> =
            (0..reps).map(|_| serve_phase(&off, clients, per_client)).collect();
        let on = serving_platform(true);
        let on_walls: Vec<f64> = (0..reps).map(|_| serve_phase(&on, clients, per_client)).collect();
        bench.record("daemon e2e obs=off", off_walls.clone(), Some(total));
        bench.record("daemon e2e obs=on", on_walls.clone(), Some(total));
        let (min_off, min_on) = (min_of(&off_walls), min_of(&on_walls));
        overhead = (min_on - min_off) / min_off;
        println!(
            "daemon e2e: obs=off {:.1} ms vs obs=on {:.1} ms (min of {} → {:+.2}% overhead)",
            min_off,
            min_on,
            reps,
            overhead * 100.0
        );

        // Concurrent scrapers against the instrumented platform while
        // the daemon keeps serving the same inference workload.
        let p = on.platform();
        let state = WebState {
            sessions: p.sessions.clone(),
            leaderboard: p.leaderboard.clone(),
            cluster: Some(p.cluster.clone()),
            events: p.events.clone(),
            api: None,
            obs: Some(p.obs.clone()),
        };
        let srv = serve_with(state, 0, ServeOpts { workers: 4, ..ServeOpts::default() }).unwrap();
        let port = srv.port();
        let scrapes_each = if smoke { 5 } else { 100 };
        let lats: Arc<Mutex<Vec<f64>>> = Arc::new(Mutex::new(Vec::new()));
        let scrapers: Vec<_> = (0..4)
            .map(|_| {
                let lats = lats.clone();
                std::thread::spawn(move || {
                    let mut stream = TcpStream::connect(("127.0.0.1", port)).expect("connect");
                    stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
                    let mut buf = Vec::new();
                    let mut mine = Vec::with_capacity(scrapes_each);
                    for _ in 0..scrapes_each {
                        let t0 = Instant::now();
                        write!(stream, "GET /metrics HTTP/1.1\r\nHost: bench\r\n\r\n")
                            .expect("write");
                        read_one_response(&mut stream, &mut buf);
                        mine.push(t0.elapsed().as_secs_f64() * 1000.0);
                    }
                    lats.lock().unwrap().extend(mine);
                })
            })
            .collect();
        serve_phase(&on, clients, per_client);
        for s in scrapers {
            s.join().unwrap();
        }
        srv.shutdown();
        let lats = Arc::try_unwrap(lats).unwrap().into_inner().unwrap();
        scrape_p99 = p99(&lats);
        println!(
            "GET /metrics: {} scrapes from 4 keep-alive clients, p99 {:.2} ms",
            lats.len(),
            scrape_p99
        );
        bench.record("GET /metrics under load", lats, None);
    } else {
        eprintln!("bench_obs: artifacts not built; skipping daemon e2e + scrape phases");
    }

    // Acceptance gates (full scale only — smoke exists to catch
    // bit-rot, not to measure). Recorded before finish() so the JSON
    // artifact carries the verdicts even when one fails the process.
    if !smoke {
        bench.gate(
            "histogram_record_throughput",
            record_ops >= 10_000_000.0,
            &format!("{:.1}M records/s >= 10M/s", record_ops / 1e6),
        );
        if artifacts {
            bench.gate(
                "obs_overhead_bounded",
                overhead <= 0.05,
                &format!("obs-on within 5% of obs-off: {:+.2}%", overhead * 100.0),
            );
            bench.gate(
                "metrics_scrape_p99_bounded",
                scrape_p99 <= 50.0,
                &format!("p99 {:.2} ms <= 50 ms under serving load", scrape_p99),
            );
        }
    }
    bench.finish();
    if !smoke {
        assert!(bench.gates_pass(), "an obs perf gate failed (see report above)");
    }
}
