//! Durability headline: WAL-mode per-mutation cost vs the legacy
//! per-mutation full-state dump, and its scaling as the world grows.
//!
//! Before the durability subsystem, crash safety meant rewriting all
//! of `state.json` on every mutation — O(sessions) per save. WAL mode
//! appends one length-prefixed record per durable event and amortizes
//! the full dump over `snapshot_every` records, so the per-mutation
//! cost is dominated by one small write regardless of store size.
//!
//! Acceptance bars (skipped in smoke mode):
//! * WAL-mode per-mutation cost at 10x the sessions is ≤1.5x the cost
//!   at 1x — durability no longer scales with the world.
//! * WAL-mode throughput is ≥5x the per-mutation full-dump baseline
//!   at the 1x world.
//!
//! Run: `cargo bench --bench bench_persist`
//! Smoke: `BENCH_SMOKE=1 cargo bench --bench bench_persist`

use nsml::api::persist;
use nsml::durability::Wal;
use nsml::events::{Event, EventKind, Level};
use nsml::leaderboard::{Leaderboard, Submission};
use nsml::session::{SessionRecord, SessionSpec, SessionStore};
use nsml::storage::{CheckpointStore, ObjectStore};
use nsml::tenancy::{TenantQuota, TenantRegistry};
use nsml::util::bench::{smoke, Bench};
use std::path::PathBuf;

/// Matches the `[durability] fsync_every` default.
const FSYNC_EVERY: u64 = 64;

fn tmp(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("nsml-bench-persist-{}-{}", tag, std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// A populated world of `n` mid-flight sessions with metric history —
/// the thing `persist::save` has to rewrite wholesale every time.
fn world(n: usize) -> (SessionStore, Leaderboard, CheckpointStore, TenantRegistry) {
    let sessions = SessionStore::new();
    let lb = Leaderboard::new();
    lb.ensure_board("mnist", "accuracy", false);
    let ckpts = CheckpointStore::new(ObjectStore::memory());
    let tenants = TenantRegistry::new(TenantQuota::default());
    for i in 0..n {
        let user = format!("user{}", i % 8);
        let id = format!("{}/mnist/{}", user, i);
        let mut spec = SessionSpec::new(&id, &user, "mnist", "mnist_mlp");
        spec.total_steps = 100;
        let mut rec = SessionRecord::new(spec, i as u64);
        rec.steps_done = 50;
        rec.best_metric = Some(0.5 + i as f64 * 1e-6);
        for step in (10..=50).step_by(10) {
            rec.metrics.log(step, "train_loss", 1.0 / step as f64);
            rec.metrics.log(step, "accuracy", step as f64 / 100.0);
        }
        sessions.insert(rec);
        lb.submit(
            "mnist",
            Submission {
                session: id,
                user,
                model: "mnist_mlp".into(),
                metric_name: "accuracy".into(),
                value: 0.5 + i as f64 * 1e-6,
                step: 50,
                at_ms: i as u64,
            },
        );
    }
    (sessions, lb, ckpts, tenants)
}

fn event(seq: u64) -> Event {
    Event {
        seq,
        at_ms: seq * 10,
        level: Level::Info,
        source: "session".into(),
        subject: "user0/mnist/0".into(),
        kind: EventKind::MetricReported { name: "accuracy".into(), step: seq, value: 0.9 },
    }
}

fn main() {
    let (n, burst, snapshot_every): (usize, u64, u64) =
        if smoke() { (40, 64, 64) } else { (400, 512, 512) };
    let mut bench = Bench::new("persist");
    println!(
        "persist bench: {} sessions (x1), {} (x10), {}-mutation bursts, snapshot every {}{}",
        n,
        n * 10,
        burst,
        snapshot_every,
        if smoke() { " [smoke]" } else { "" }
    );

    // Baseline: the legacy discipline — one full-state dump per
    // mutation, at the 1x world.
    let (sessions, lb, ckpts, tenants) = world(n);
    let dump_dir = tmp("dump");
    let dump_burst = 8u64;
    let save_label = format!("full dump per mutation at {}", n);
    bench.run_with_units(&save_label, dump_burst as f64, || {
        for _ in 0..dump_burst {
            persist::save(&dump_dir, &sessions, &lb, &ckpts, &tenants).unwrap();
        }
    });

    // WAL mode at the same world: one record append per mutation, one
    // full dump amortized over `snapshot_every` records (then the
    // segment rotates — exactly the facade's snapshot cycle).
    let mut run_wal_mode = |label: &str,
                            bench: &mut Bench,
                            sessions: &SessionStore,
                            lb: &Leaderboard,
                            ckpts: &CheckpointStore,
                            tenants: &TenantRegistry| {
        let dir = tmp(&label.replace(' ', "-"));
        let (mut wal, _) = Wal::open(dir.join("wal.log"), FSYNC_EVERY).unwrap();
        let mut seq = 0u64;
        bench.run_with_units(label, burst as f64, || {
            for _ in 0..burst {
                wal.append(&event(seq)).unwrap();
                seq += 1;
                if seq % snapshot_every == 0 {
                    persist::save(&dir, sessions, lb, ckpts, tenants).unwrap();
                    wal.rotate().unwrap();
                }
            }
        });
        let _ = std::fs::remove_dir_all(&dir);
    };

    let wal_1x = format!("wal mode per mutation at {}", n);
    run_wal_mode(&wal_1x, &mut bench, &sessions, &lb, &ckpts, &tenants);

    let (sessions10, lb10, ckpts10, tenants10) = world(n * 10);
    let wal_10x = format!("wal mode per mutation at {}", n * 10);
    run_wal_mode(&wal_10x, &mut bench, &sessions10, &lb10, &ckpts10, &tenants10);

    bench.finish();
    let _ = std::fs::remove_dir_all(&dump_dir);

    let per_unit = |label: &str, units: f64| bench.result(label).unwrap().mean_ms() / units;
    let dump_ms = per_unit(&save_label, dump_burst as f64);
    let wal1_ms = per_unit(&wal_1x, burst as f64);
    let wal10_ms = per_unit(&wal_10x, burst as f64);
    let growth = wal10_ms / wal1_ms;
    let speedup = dump_ms / wal1_ms;
    println!(
        "per-mutation: full dump {:.4}ms | wal x1 {:.4}ms | wal x10 {:.4}ms (growth {:.2}x, speedup {:.1}x)",
        dump_ms, wal1_ms, wal10_ms, growth, speedup
    );
    if smoke() {
        println!("smoke mode: skipping the scaling/speedup assertions");
    } else {
        assert!(
            growth <= 1.5,
            "wal-mode per-mutation cost grew {:.2}x when sessions grew 10x (bar: <=1.5x)",
            growth
        );
        assert!(
            speedup >= 5.0,
            "wal mode is only {:.2}x faster than per-mutation full dumps (bar: >=5x)",
            speedup
        );
        println!("OK: <=1.5x scaling and >=5x throughput bars met");
    }
}
