//! E6 (paper §3.2): SPOF handling — "electing new master node as in
//! Zookeeper when the master node fails".
//!
//! Measures: (a) virtual failover time (leader death -> new leader) as a
//! function of detection cadence, (b) real-time cost of the election
//! machinery itself, (c) job flow across a failover (nothing is lost).
//!
//! Run: `cargo bench --bench bench_failover`

use nsml::cluster::Cluster;
use nsml::events::EventLog;
use nsml::scheduler::{BestFit, ElectionGroup, JobSpec, Master};
use nsml::util::bench::Bench;
use nsml::util::clock::sim_clock;
use nsml::util::table::{fms, Table};

fn main() {
    let mut bench = Bench::new("failover");

    // (a) Virtual failover latency vs tick cadence (the real system's
    // watchdog period).
    let mut t = Table::new(&["DETECTION CADENCE", "FAILOVER (virtual)", "EPOCH BUMPS"]).right(&[1, 2]);
    for cadence_ms in [10u64, 100, 500, 1000] {
        let (clock, sim) = sim_clock();
        let events = EventLog::new(clock.clone()).with_echo(false);
        let group = ElectionGroup::new(clock, events, 3);
        let mut failovers = Vec::new();
        for round in 0..20 {
            let (leader, _) = group.leader().unwrap();
            group.kill(leader);
            // Watchdog notices at the next cadence boundary.
            loop {
                sim.advance(cadence_ms);
                for r in group.replica_ids() {
                    group.heartbeat(r);
                }
                if group.tick().is_some() {
                    break;
                }
            }
            failovers.push(group.last_failover_ms().unwrap() as f64);
            // Revive for the next round.
            group.revive(leader);
            let _ = round;
        }
        let mean = failovers.iter().sum::<f64>() / failovers.len() as f64;
        t.row(&[format!("{} ms", cadence_ms), fms(mean), format!("{}", group.epoch())]);
        bench.record(&format!("virtual failover @ cadence {} ms", cadence_ms), failovers, None);
    }
    println!("== E6: leader failover vs detection cadence ==\n{}", t.render());

    // (b) Real-time cost of kill -> detect -> elect.
    let (clock, sim) = sim_clock();
    let events = EventLog::new(clock.clone()).with_echo(false);
    let group = ElectionGroup::new(clock, events, 5);
    bench.run_with_units("kill+tick+elect+revive (real time)", 1.0, || {
        let (leader, _) = group.leader().unwrap();
        group.kill(leader);
        sim.advance(1);
        group.tick().unwrap();
        group.revive(leader);
    });

    // (c) Jobs keep flowing across a failover: the master's queue state
    // survives (centralized state store), only leadership moves.
    let (clock, sim) = sim_clock();
    let events = EventLog::new(clock.clone()).with_echo(false);
    let cluster = Cluster::homogeneous(clock.clone(), events.clone(), 4, 4, 24.0);
    let master = Master::new(cluster, Box::new(BestFit), events.clone());
    let group = ElectionGroup::new(clock, events, 3);
    for i in 0..32 {
        master.submit(JobSpec::new(&format!("pre{}", i), 1));
    }
    let queued_before = master.queue_len();
    let (leader, _) = group.leader().unwrap();
    group.kill(leader);
    sim.advance(5);
    group.tick().unwrap();
    // New leader drains the same queue.
    for i in 0..16 {
        master.complete(&format!("pre{}", i));
    }
    let placed = master.stats().placed_from_queue;
    println!(
        "jobs across failover: queued_before={} placed_from_queue_after={} (no jobs lost: {})",
        queued_before,
        placed,
        master.stats().submitted == master.stats().completed + master.running_jobs().len() as u64 + master.queue_len() as u64
    );
    assert!(placed >= queued_before.min(16) as u64);

    bench.finish();
}
