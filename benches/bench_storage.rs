//! Storage-container benchmarks: the minio-substitute object store,
//! checkpoint save/load (the §3.3 backup path every session exercises),
//! and NSML-CLI code packing.
//!
//! Run: `cargo bench --bench bench_storage`

use nsml::storage::{codepack, CheckpointStore, ObjectStore};
use nsml::util::bench::Bench;
use nsml::util::rng::Rng;
use std::collections::BTreeMap;

fn main() {
    let mut bench = Bench::new("storage");
    let mut rng = Rng::new(7);

    // 1 MiB blobs ≈ a small model checkpoint.
    let blob: Vec<u8> = (0..1 << 20).map(|_| rng.next_u64() as u8).collect();

    let mem = ObjectStore::memory();
    bench.run_with_units("objectstore put 1MiB (mem, unique)", 1.0, || {
        let mut b = blob.clone();
        let n = rng.next_u64();
        b[..8].copy_from_slice(&n.to_le_bytes());
        mem.put(&b).unwrap();
    });
    let id = mem.put(&blob).unwrap();
    bench.run_with_units("objectstore put 1MiB (mem, dedup hit)", 1.0, || {
        mem.put(&blob).unwrap();
    });
    bench.run_with_units("objectstore get 1MiB (mem, verified)", 1.0, || {
        mem.get(&id).unwrap();
    });

    let dir = std::env::temp_dir().join(format!("nsml-bench-store-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let fs = ObjectStore::filesystem(&dir).unwrap();
    bench.run_with_units("objectstore put 1MiB (fs, unique)", 1.0, || {
        let mut b = blob.clone();
        let n = rng.next_u64();
        b[..8].copy_from_slice(&n.to_le_bytes());
        fs.put(&b).unwrap();
    });
    let fid = fs.put(&blob).unwrap();
    bench.run_with_units("objectstore get 1MiB (fs, verified)", 1.0, || {
        fs.get(&fid).unwrap();
    });

    // Checkpoint store: save/load of a 71k-param model (mnist_mlp size).
    let params: Vec<u8> = (0..71_306 * 4).map(|_| rng.next_u64() as u8).collect();
    let ckpts = CheckpointStore::new(ObjectStore::memory());
    let mut hp = BTreeMap::new();
    hp.insert("lr".to_string(), 0.1);
    let mut step = 0u64;
    bench.run_with_units("checkpoint save (71k params)", 1.0, || {
        step += 1;
        let mut p = params.clone();
        p[..8].copy_from_slice(&step.to_le_bytes());
        ckpts.save("bench/session", step, 1.0, &hp, &p, step).unwrap();
    });
    let latest = ckpts.latest("bench/session").unwrap();
    bench.run_with_units("checkpoint load (71k params)", 1.0, || {
        ckpts.load_params(&latest).unwrap();
    });

    // Code packing: a 20-file project, the `nsml run` upload.
    let files: Vec<(String, Vec<u8>)> = (0..20)
        .map(|i| {
            (format!("src/mod{}.py", i), (0..2048).map(|_| rng.next_u64() as u8).collect::<Vec<u8>>())
        })
        .collect();
    let refs: Vec<(&str, &[u8])> = files.iter().map(|(n, b)| (n.as_str(), b.as_slice())).collect();
    bench.run_with_units("codepack zip 20 files / 40KiB", 1.0, || {
        codepack::pack_files(&refs).unwrap();
    });
    let archive = codepack::pack_files(&refs).unwrap();
    bench.run_with_units("codepack unzip", 1.0, || {
        codepack::unpack(&archive).unwrap();
    });

    bench.finish();
    let _ = std::fs::remove_dir_all(&dir);
}
