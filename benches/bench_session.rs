//! The training hot path (§Perf headline): steps/sec per model through
//! the PJRT runtime, ablating the two L2/L3 perf levers:
//!
//!  * per-step execute vs scan-fused K-step execute (dispatch amortization)
//!  * end-to-end session overhead vs raw model stepping
//!
//! Run: `cargo bench --bench bench_session`

use nsml::data::generator_for;
use nsml::runtime::{Batch, Engine, TrainableModel};
use nsml::util::bench::Bench;
use std::sync::Arc;

fn main() {
    let engine = Arc::new(Engine::new("artifacts").expect("run `make artifacts` first"));
    let mut bench = Bench::new("session");

    for name in engine.manifest().model_names() {
        let mut model = TrainableModel::init(engine.clone(), &name, 1).unwrap();
        let manifest = model.manifest().clone();
        let mut gen = generator_for(&name, 1).unwrap();
        let lr = manifest.default_lr as f32;
        let k = manifest.scan_k;

        // Pre-draw batches so data generation is excluded.
        let batches: Vec<Batch> = (0..k).map(|_| gen.batch(manifest.batch)).collect();

        bench.run_with_units(&format!("{} train_step x{}", name, k), k as f64, || {
            for b in &batches {
                model.train_step(b, lr).unwrap();
            }
        });
        bench.run_with_units(&format!("{} train_scan k={}", name, k), k as f64, || {
            model.train_scan(&batches, lr).unwrap();
        });
        bench.run_with_units(&format!("{} evaluate", name), 1.0, || {
            model.evaluate(&batches[0]).unwrap();
        });
        let xi = if name == "face_gan" {
            nsml::runtime::TensorData::f32(vec![0.1; 32 * 32], &[32, 32])
        } else {
            batches[0].x.clone()
        };
        bench.run_with_units(&format!("{} infer", name), 1.0, || {
            model.infer(&xi).unwrap();
        });
        bench.run_with_units(&format!("{} checkpoint serialize", name), 1.0, || {
            model.params_bytes().unwrap();
        });
    }

    // Data generation itself (must be negligible vs a train step).
    let mut gen = generator_for("mnist_mlp", 2).unwrap();
    bench.run_with_units("digit generator batch(64)", 1.0, || {
        gen.batch(64);
    });

    bench.finish();

    // Throughput summary in examples/s.
    println!("steps/s (p50) summary:");
    for name in engine.manifest().model_names() {
        let step = bench.result(&format!("{} train_step x8", name)).unwrap();
        let scan = bench.result(&format!("{} train_scan k=8", name)).unwrap();
        println!(
            "  {:<12} per-step {:>8.1} steps/s   scan-fused {:>8.1} steps/s   ({:.2}x)",
            name,
            step.throughput().unwrap_or(0.0),
            scan.throughput().unwrap_or(0.0),
            scan.throughput().unwrap_or(0.0) / step.throughput().unwrap_or(1.0)
        );
    }
}
