//! Event-spine headline: incremental subscriber reads vs the old
//! clone-on-read `EventLog::all()` at a full 100k-event ring, plus raw
//! publish and 8-way fan-out throughput.
//!
//! The old `EventLog` cloned its entire bounded deque on every read, so
//! a dashboard polling "what's new" paid for 100k clones per poll. The
//! bus's sequence-numbered cursors clone only the events published
//! since the last poll.
//!
//! Acceptance bar: reading one 128-event tail through a subscription is
//! ≥5× faster than one `EventLog::all()` snapshot at 100k events.
//!
//! Run: `cargo bench --bench bench_events`
//! Smoke: `BENCH_SMOKE=1 cargo bench --bench bench_events`

use nsml::events::{EventKind, EventLog, Level};
use nsml::util::bench::{smoke, Bench};
use nsml::util::clock::sim_clock;

/// Events published (and read) per subscription-poll iteration.
const BURST: usize = 128;
/// Concurrent subscribers in the fan-out scenario.
const SUBSCRIBERS: usize = 8;

fn publish_burst(log: &EventLog, n: usize) {
    for i in 0..n {
        log.bus().publish(
            Level::Info,
            "bench",
            "bench/events/1",
            EventKind::MetricReported { name: "train_loss".into(), step: i as u64, value: 0.5 },
        );
    }
}

fn main() {
    let backlog: usize = if smoke() { 2_000 } else { 100_000 };
    let mut bench = Bench::new("events");
    println!(
        "events bench: {} backlog, {}-event bursts, {} fan-out subscribers{}",
        backlog,
        BURST,
        SUBSCRIBERS,
        if smoke() { " [smoke]" } else { "" }
    );

    let (clock, _) = sim_clock();
    let log = EventLog::new(clock);
    publish_burst(&log, backlog);
    assert_eq!(log.len(), backlog);

    // Baseline: the legacy full-ring clone every reader used to pay.
    bench.run_with_units(&format!("EventLog::all clone at {}", backlog), backlog as f64, || {
        std::hint::black_box(log.all().len());
    });

    // Cursor read: publish a burst, then one subscriber reads only the
    // tail — the `nsml logs -f` / `GET /api/v1/events` polling shape.
    let mut sub = log.bus().subscribe();
    bench.run_with_units("subscription tail read", BURST as f64, || {
        publish_burst(&log, BURST);
        let got = sub.poll();
        assert_eq!(got.len(), BURST);
        std::hint::black_box(got.len());
    });

    // Fan-out: every consumer (leaderboard, monitor, web pollers…)
    // holds its own cursor over the same ring.
    let mut subs: Vec<_> = (0..SUBSCRIBERS).map(|_| log.bus().subscribe()).collect();
    bench.run_with_units(
        &format!("fan-out x{} subscribers", SUBSCRIBERS),
        (SUBSCRIBERS * BURST) as f64,
        || {
            publish_burst(&log, BURST);
            for sub in &mut subs {
                assert_eq!(sub.poll().len(), BURST);
            }
        },
    );

    // Raw publish throughput (ring append + seq assignment).
    bench.run_with_units("publish burst", BURST as f64, || {
        publish_burst(&log, BURST);
    });

    bench.finish();

    let all_ms = bench.result(&format!("EventLog::all clone at {}", backlog)).unwrap().mean_ms();
    let tail_ms = bench.result("subscription tail read").unwrap().mean_ms();
    let speedup = all_ms / tail_ms;
    println!(
        "subscriber tail read is {:.1}x faster than the full clone ({:.3}ms -> {:.3}ms)",
        speedup, all_ms, tail_ms
    );
    if smoke() {
        println!("smoke mode: skipping the speedup assertion");
    } else {
        assert!(
            speedup >= 5.0,
            "expected subscription reads >=5x faster than EventLog::all() at {} events, got {:.2}x",
            backlog,
            speedup
        );
        println!("OK: >=5x incremental-read bar met");
    }
}
