//! Serving throughput: autoscaled executor-pool replicas vs the
//! platform thread, and the micro-batcher vs one-request-per-execution.
//!
//! Four configurations of the same workload — concurrent daemon
//! clients serving against endpoint "prod" while a background training
//! run keeps the drive loop busy (the realistic case: serving competes
//! with training for the loop):
//!
//! * **ramp** — autoscaling on (`max_replicas = 4`): 8 clients, then
//!   16 against the *same* platform. The load ramp must hold p99
//!   within 1.5× of the low-QPS phase, and the replica set must be
//!   observed scaling up under load and back down once idle.
//! * **platform-thread baseline** — `max_replicas = 0` disables the
//!   serve lane, so every batch executes inline on the single
//!   platform-owning thread (the pre-replica architecture). The ramp's
//!   16-client phase must beat it ≥ 1.8× on aggregate throughput.
//! * **unbatched inline** — `max_batch = 1` *and* the lane off: the
//!   original one-execution-per-request path; the batched+replicated
//!   configuration must stay ≥ 2× faster wall-clock.
//!
//! A facade-level burst sweep also reports batch sizes 1 / 8 / 64.
//! Gate verdicts land in `target/bench-results/BENCH_serving.json`.
//!
//! Run: `cargo bench --bench bench_serving`

use nsml::api::{
    service_channel, ApiRequest, ApiResponse, DaemonOpts, NsmlPlatform, PlatformConfig,
    PlatformService, RunOpts,
};
use nsml::events::{EventFilter, EventKind};
use nsml::util::bench::{smoke, Bench};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

const ROW: usize = 144; // one mnist_mlp request row ([64, 144] tensor)

fn row(seed: usize) -> Vec<f32> {
    (0..ROW).map(|i| ((seed * 31 + i * 7) % 97) as f32 / 97.0).collect()
}

fn quick(steps: u64, seed: u64) -> RunOpts {
    RunOpts {
        total_steps: steps,
        eval_every: (steps / 2).max(1),
        checkpoint_every: (steps / 2).max(1),
        seed,
        ..Default::default()
    }
}

/// A service with one trained session promoted to endpoint "prod".
/// `max_replicas = 0` pins serving to the platform thread (baseline).
fn serving_platform(max_batch: usize, max_replicas: usize) -> PlatformService {
    let mut cfg = PlatformConfig::test_default();
    cfg.artifacts_dir = "artifacts".into();
    cfg.serving_max_batch = max_batch;
    cfg.serving_max_replicas = max_replicas;
    cfg.serving_scale_up_queue_depth = 8;
    cfg.serving_scale_down_idle_ms = 100;
    let p = NsmlPlatform::new(cfg).unwrap();
    let id = p.run("bench", "mnist", quick(16, 0)).unwrap();
    p.run_to_completion(8, 10_000).unwrap();
    p.promote_endpoint("prod", &id).unwrap();
    PlatformService::new(p)
}

/// Drive `clients` threads, each issuing `per_client` serve requests
/// through the daemon while a background session trains. Returns
/// (wall ms, per-request latencies ms, mean observed batch size).
fn concurrent_serve(
    service: &PlatformService,
    clients: usize,
    per_client: usize,
    bg_steps: u64,
) -> (f64, Vec<f64>, f64) {
    service.platform().run("bg", "mnist", quick(bg_steps, 9)).unwrap();
    let (handle, rx) = service_channel();
    let t0 = Instant::now();
    let workers: Vec<_> = (0..clients)
        .map(|c| {
            let h = handle.clone();
            std::thread::spawn(move || {
                let mut lat = Vec::with_capacity(per_client);
                let mut batch_sum = 0u64;
                for r in 0..per_client {
                    let t = Instant::now();
                    match h.call(ApiRequest::ServeInfer {
                        endpoint: "prod".into(),
                        user: format!("client{}", c),
                        x: row(c * 1000 + r),
                    }) {
                        ApiResponse::Served { batch, probs, .. } => {
                            assert_eq!(probs.len(), 10);
                            batch_sum += batch;
                        }
                        other => panic!("serve_infer: {:?}", other),
                    }
                    lat.push(t.elapsed().as_secs_f64() * 1000.0);
                }
                (lat, batch_sum)
            })
        })
        .collect();
    drop(handle); // daemon exits once every client is answered and done
    // chunk 1: training stays interleaved (one step between flushes)
    // without letting round cost swamp the serving signal.
    let opts =
        DaemonOpts { chunk: 1, idle_wait: Duration::from_millis(1), ..DaemonOpts::default() };
    service.run_daemon(&rx, &opts).unwrap();

    let mut lats = Vec::new();
    let mut batch_sum = 0u64;
    for w in workers {
        let (l, b) = w.join().unwrap();
        lats.extend(l);
        batch_sum += b;
    }
    // Replies fire from worker threads; the last join is the true end.
    let wall_ms = t0.elapsed().as_secs_f64() * 1000.0;
    let mean_batch = batch_sum as f64 / lats.len() as f64;
    (wall_ms, lats, mean_batch)
}

fn p99(samples: &[f64]) -> f64 {
    let mut s = samples.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    s[((s.len() - 1) * 99) / 100]
}

fn main() {
    let smoke = smoke();
    let (clients, per_client, bg_steps) = if smoke { (4, 2, 24) } else { (16, 16, 240) };
    let mut bench = Bench::new("serving");

    // Facade-level burst sweep: a burst of B requests flushes as one
    // shared micro-batch (B ≤ max_batch) onto a replica's worker;
    // replies fire asynchronously, so each iteration waits them out.
    let service = serving_platform(64, 4);
    let p = service.platform();
    for burst in [1usize, 8, 64] {
        bench.run_with_units(&format!("batched burst batch={}", burst), burst as f64, || {
            let served = Arc::new(Mutex::new(0usize));
            for i in 0..burst {
                let served = served.clone();
                p.serve_enqueue(
                    "prod",
                    "kim",
                    row(i),
                    Box::new(move |r| {
                        assert_eq!(r.unwrap().probs.len(), 10);
                        *served.lock().unwrap() += 1;
                    }),
                )
                .unwrap();
            }
            p.pump_serving(true);
            let deadline = Instant::now() + Duration::from_secs(60);
            while *served.lock().unwrap() < burst {
                assert!(Instant::now() < deadline, "burst of {} never fully answered", burst);
                std::thread::yield_now();
            }
        });
    }

    // Load ramp against one autoscaled platform: low QPS, then double
    // the client count. Replicas grow under the backlog.
    let low_clients = (clients / 2).max(1);
    let total_low = (low_clients * per_client) as f64;
    let total_high = (clients * per_client) as f64;
    let (low_ms, low_lats, _) = concurrent_serve(&service, low_clients, per_client, bg_steps);
    bench.record(&format!("ramp x{} autoscaled", low_clients), low_lats.clone(), None);
    let (high_ms, high_lats, mean_batch) =
        concurrent_serve(&service, clients, per_client, bg_steps);
    bench.record(&format!("ramp x{} autoscaled", clients), high_lats.clone(), None);

    // Idle drive rounds shrink the set back to the floor (virtual
    // time: 10 ms/round vs scale_down_idle_ms = 100).
    let mut final_replicas = p.endpoint_stats("prod").0;
    for _ in 0..200 {
        p.drive_round(1).unwrap();
        final_replicas = p.endpoint_stats("prod").0;
        if final_replicas == 1 {
            break;
        }
    }
    let scaled = p.events.bus().read_since(
        0,
        0,
        &EventFilter { kind: Some("replica".into()), ..Default::default() },
    );
    let peak_replicas = scaled
        .events
        .iter()
        .filter_map(|e| match &e.kind {
            EventKind::ReplicaScaled { replicas, .. } => Some(*replicas),
            _ => None,
        })
        .max()
        .unwrap_or(1);

    // Baseline 1: serve lane off — batches execute inline on the
    // platform thread (the pre-replica architecture), same batching.
    let baseline = serving_platform(64, 0);
    let (base_ms, _base_lats, _) = concurrent_serve(&baseline, clients, per_client, bg_steps);

    // Baseline 2: lane off *and* unbatched — the original
    // one-execution-per-request path.
    let unbatched = serving_platform(1, 0);
    let (unbatched_ms, unbatched_lats, _) =
        concurrent_serve(&unbatched, clients, per_client, bg_steps);
    bench.record(&format!("x{} unbatched platform-thread", clients), unbatched_lats, None);

    let low_tput = total_low / (low_ms / 1000.0);
    let high_tput = total_high / (high_ms / 1000.0);
    let base_tput = total_high / (base_ms / 1000.0);
    let speedup = unbatched_ms / high_ms;
    println!(
        "ramp x{}→x{}: {:.1} → {:.1} req/s (p99 {:.2} → {:.2} ms, mean batch {:.1}, replicas peak {} final {})",
        low_clients,
        clients,
        low_tput,
        high_tput,
        p99(&low_lats),
        p99(&high_lats),
        mean_batch,
        peak_replicas,
        final_replicas,
    );
    println!(
        "x{}: replicated {:.1} req/s vs platform-thread {:.1} req/s ({:.2}x) vs unbatched ({:.2}x wall)",
        clients,
        high_tput,
        base_tput,
        high_tput / base_tput,
        speedup,
    );

    // Acceptance gates (full scale only — smoke exists to catch
    // bit-rot, not to measure). Recorded before finish() so the JSON
    // artifact carries the verdicts even when one fails the process.
    if !smoke {
        bench.gate(
            "ramp_p99_bounded",
            p99(&high_lats) <= 1.5 * p99(&low_lats),
            &format!(
                "p99 {:.2} ms at x{} <= 1.5x {:.2} ms at x{}",
                p99(&high_lats),
                clients,
                p99(&low_lats),
                low_clients
            ),
        );
        bench.gate(
            "throughput_vs_platform_thread",
            high_tput >= 1.8 * base_tput,
            &format!("{:.1} req/s >= 1.8x {:.1} req/s", high_tput, base_tput),
        );
        bench.gate(
            "replicas_scale_up_then_down",
            peak_replicas > 1 && final_replicas == 1,
            &format!("peak {} replicas, {} after idle", peak_replicas, final_replicas),
        );
        bench.gate(
            "microbatching_active",
            mean_batch > 1.5,
            &format!("mean batch {:.2}", mean_batch),
        );
        bench.gate(
            "faster_than_unbatched",
            speedup >= 2.0,
            &format!("{:.2}x wall-clock vs unbatched inline", speedup),
        );
    }
    bench.finish();
    if !smoke {
        assert!(bench.gates_pass(), "a serving perf gate failed (see report above)");
    }
}
