//! Serving throughput: the micro-batcher vs one-request-per-execution.
//!
//! Two identically configured platforms — one with `[serving]
//! max_batch = 64` (the default), one pinned to `max_batch = 1` — each
//! train a session, promote it to an endpoint, and then serve 16
//! concurrent daemon clients while a background training run keeps the
//! drive loop busy (the realistic case: serving competes with
//! training for the loop). The acceptance gate is batched wall-clock
//! ≥ 2× better than unbatched at 16 clients, with a bounded p99.
//! A facade-level burst sweep also reports batch sizes 1 / 8 / 64.
//!
//! Run: `cargo bench --bench bench_serving`

use nsml::api::{
    service_channel, ApiRequest, ApiResponse, DaemonOpts, NsmlPlatform, PlatformConfig,
    PlatformService, RunOpts,
};
use nsml::util::bench::{smoke, Bench};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

const ROW: usize = 144; // one mnist_mlp request row ([64, 144] tensor)

fn row(seed: usize) -> Vec<f32> {
    (0..ROW).map(|i| ((seed * 31 + i * 7) % 97) as f32 / 97.0).collect()
}

fn quick(steps: u64, seed: u64) -> RunOpts {
    RunOpts {
        total_steps: steps,
        eval_every: (steps / 2).max(1),
        checkpoint_every: (steps / 2).max(1),
        seed,
        ..Default::default()
    }
}

/// A service with one trained session promoted to endpoint "prod".
fn serving_platform(max_batch: usize) -> PlatformService {
    let mut cfg = PlatformConfig::test_default();
    cfg.artifacts_dir = "artifacts".into();
    cfg.serving_max_batch = max_batch;
    let p = NsmlPlatform::new(cfg).unwrap();
    let id = p.run("bench", "mnist", quick(16, 0)).unwrap();
    p.run_to_completion(8, 10_000).unwrap();
    p.promote_endpoint("prod", &id).unwrap();
    PlatformService::new(p)
}

/// Drive `clients` threads, each issuing `per_client` serve requests
/// through the daemon while a background session trains. Returns
/// (wall ms, per-request latencies ms, mean observed batch size).
fn concurrent_serve(
    service: &PlatformService,
    clients: usize,
    per_client: usize,
    bg_steps: u64,
) -> (f64, Vec<f64>, f64) {
    service.platform().run("bg", "mnist", quick(bg_steps, 9)).unwrap();
    let (handle, rx) = service_channel();
    let t0 = Instant::now();
    let workers: Vec<_> = (0..clients)
        .map(|c| {
            let h = handle.clone();
            std::thread::spawn(move || {
                let mut lat = Vec::with_capacity(per_client);
                let mut batch_sum = 0u64;
                for r in 0..per_client {
                    let t = Instant::now();
                    match h.call(ApiRequest::ServeInfer {
                        endpoint: "prod".into(),
                        user: format!("client{}", c),
                        x: row(c * 1000 + r),
                    }) {
                        ApiResponse::Served { batch, probs, .. } => {
                            assert_eq!(probs.len(), 10);
                            batch_sum += batch;
                        }
                        other => panic!("serve_infer: {:?}", other),
                    }
                    lat.push(t.elapsed().as_secs_f64() * 1000.0);
                }
                (lat, batch_sum)
            })
        })
        .collect();
    drop(handle); // daemon exits once every client is answered and done
    // chunk 1: training stays interleaved (one step between flushes)
    // without letting round cost swamp the batched-vs-unbatched signal.
    let opts =
        DaemonOpts { chunk: 1, idle_wait: Duration::from_millis(1), ..DaemonOpts::default() };
    service.run_daemon(&rx, &opts).unwrap();
    let wall_ms = t0.elapsed().as_secs_f64() * 1000.0;

    let mut lats = Vec::new();
    let mut batch_sum = 0u64;
    for w in workers {
        let (l, b) = w.join().unwrap();
        lats.extend(l);
        batch_sum += b;
    }
    let mean_batch = batch_sum as f64 / lats.len() as f64;
    (wall_ms, lats, mean_batch)
}

fn p99(samples: &[f64]) -> f64 {
    let mut s = samples.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    s[((s.len() - 1) * 99) / 100]
}

fn main() {
    let smoke = smoke();
    let (clients, per_client, bg_steps) = if smoke { (4, 2, 24) } else { (16, 16, 240) };
    let mut bench = Bench::new("serving");

    // Facade-level burst sweep: a burst of B requests flushes as one
    // shared micro-batch (B ≤ max_batch), i.e. one engine execution.
    let service = serving_platform(64);
    let p = service.platform();
    for burst in [1usize, 8, 64] {
        bench.run_with_units(&format!("batched burst batch={}", burst), burst as f64, || {
            let served = Arc::new(Mutex::new(0usize));
            for i in 0..burst {
                let served = served.clone();
                p.serve_enqueue(
                    "prod",
                    "kim",
                    row(i),
                    Box::new(move |r| {
                        assert_eq!(r.unwrap().probs.len(), 10);
                        *served.lock().unwrap() += 1;
                    }),
                )
                .unwrap();
            }
            p.pump_serving(true);
            assert_eq!(*served.lock().unwrap(), burst);
        });
    }

    // 16 concurrent daemon clients, training in the background:
    // micro-batched (max_batch 64) vs unbatched (max_batch 1).
    let total = (clients * per_client) as f64;
    let (batched_ms, batched_lats, mean_batch) =
        concurrent_serve(&service, clients, per_client, bg_steps);
    bench.record(&format!("concurrent x{} batched", clients), batched_lats.clone(), None);

    let unbatched = serving_platform(1);
    let (unbatched_ms, unbatched_lats, _) =
        concurrent_serve(&unbatched, clients, per_client, bg_steps);
    bench.record(&format!("concurrent x{} unbatched", clients), unbatched_lats, None);

    let speedup = unbatched_ms / batched_ms;
    println!(
        "concurrent x{}: batched {:.1} req/s (mean batch {:.1}, p99 {:.2} ms) vs unbatched {:.1} req/s — {:.2}x",
        clients,
        total / (batched_ms / 1000.0),
        mean_batch,
        p99(&batched_lats),
        total / (unbatched_ms / 1000.0),
        speedup,
    );

    bench.finish();

    if !smoke {
        assert!(
            mean_batch > 1.5,
            "micro-batching never kicked in: mean batch {:.2}",
            mean_batch
        );
        assert!(
            speedup >= 2.0,
            "batched serving must be >= 2x unbatched at {} clients (got {:.2}x)",
            clients,
            speedup
        );
        assert!(
            p99(&batched_lats) <= 2_000.0,
            "p99 serving latency unbounded: {:.1} ms",
            p99(&batched_lats)
        );
    }
}
