//! E10 (paper §3.1 AutoML): hyperparameter-search strategies over real
//! MNIST sessions — budget spent vs quality of the found optimum, with
//! the curve-prediction early stopper in play for random search.
//!
//! Run: `cargo bench --bench bench_automl`

use nsml::api::{NsmlPlatform, PlatformConfig, PlatformTrialRunner};
use nsml::automl::{GridSearch, RandomSearch, SuccessiveHalving};
use nsml::executor::ExecutorPool;
use nsml::util::bench::Bench;
use nsml::util::table::{fnum, Table};
use std::sync::Arc;

const LRS: [f64; 6] = [0.0003, 0.003, 0.03, 0.1, 0.5, 3.0];
const BUDGET: u64 = 48;

fn runner(platform: &NsmlPlatform, pool: &Arc<ExecutorPool>, tag: u64, n: usize) -> PlatformTrialRunner {
    PlatformTrialRunner::new(
        pool.clone(),
        "mnist",
        &format!("bench{}", tag),
        platform.sessions.clone(),
        platform.clock.clone(),
        n,
        tag,
    )
    .unwrap()
}

fn main() {
    let mut cfg = PlatformConfig::test_default();
    cfg.artifacts_dir = "artifacts".into();
    let platform = NsmlPlatform::new(cfg).unwrap();
    // One shared trial pool: rungs fan out across its workers.
    let pool = platform.new_trial_pool();
    let mut bench = Bench::new("automl").with_samples(3);
    let mut table = Table::new(&["STRATEGY", "BEST LR", "BEST LOSS", "STEPS SPENT", "% OF GRID"]).right(&[1, 2, 3, 4]);

    let mut tag = 0u64;

    // Grid (exhaustive baseline).
    let mut result = None;
    bench.run("grid search (6 lrs x 48 steps)", || {
        tag += 1;
        let mut r = runner(&platform, &pool, tag, LRS.len());
        result = Some(GridSearch { lrs: LRS.to_vec(), steps_per_trial: BUDGET }.run(&mut r));
    });
    let grid = result.unwrap();
    let grid_spent = grid.steps_spent;
    table.row(&[
        "grid".into(),
        fnum(grid.best_lr),
        fnum(grid.best_loss),
        format!("{}", grid.steps_spent),
        "100%".into(),
    ]);

    // Successive halving.
    let mut result = None;
    bench.run("successive halving (eta=2, 3 rungs)", || {
        tag += 1;
        let mut r = runner(&platform, &pool, tag, LRS.len());
        result = Some(
            SuccessiveHalving { lrs: LRS.to_vec(), total_steps_per_trial: BUDGET, eta: 2, rungs: 3 }
                .run(&mut r),
        );
    });
    let sh = result.unwrap();
    table.row(&[
        "successive halving".into(),
        fnum(sh.best_lr),
        fnum(sh.best_loss),
        format!("{}", sh.steps_spent),
        format!("{:.0}%", 100.0 * sh.steps_spent as f64 / grid_spent as f64),
    ]);

    // Random + curve-prediction early stop.
    let mut result = None;
    bench.run("random search + curve prediction", || {
        tag += 1;
        let mut r = runner(&platform, &pool, tag, 6);
        result = Some(
            RandomSearch {
                candidates: 6,
                lr_log10_range: (-3.5, 0.5),
                steps_per_trial: BUDGET,
                probe_frac: 0.2,
                seed: tag,
            }
            .run(&mut r),
        );
    });
    let rs = result.unwrap();
    table.row(&[
        "random + prediction".into(),
        fnum(rs.best_lr),
        fnum(rs.best_loss),
        format!("{}", rs.steps_spent),
        format!("{:.0}%", 100.0 * rs.steps_spent as f64 / grid_spent as f64),
    ]);

    bench.finish();
    println!("== E10: search strategies on real sessions ==\n{}", table.render());
    println!("expected shape: halving/predictive find the same lr decade at a fraction of grid's budget.");
}
