//! Tenancy headlines: two-user fairness on a saturated pool, and the
//! wall-clock overhead of fair-share admission control.
//!
//! Acceptance bars (full mode; skipped in smoke):
//!  * fairness — alice and bob each submit 8 sessions on a 1-node /
//!    2-GPU pool (alice's whole burst first, the FIFO worst case);
//!    their aggregate accounted GPU-seconds AND their last completion
//!    times (virtual ms) end within 20% of each other. Under FIFO the
//!    first user's batch would finish in half the span — the last-
//!    finish gate is what proves the interleave.
//!  * overhead — driving the same 16-session workload with tenancy
//!    enabled costs ≤5% wall-clock over the no-tenancy drive.
//!
//! Run: `cargo bench --bench bench_tenancy`
//! Smoke: `BENCH_SMOKE=1 cargo bench --bench bench_tenancy`

use nsml::api::{NsmlPlatform, PlatformConfig, RunOpts};
use nsml::session::SessionState;
use nsml::util::bench::{smoke, Bench};

const USERS: [&str; 2] = ["alice", "bob"];
const PER_USER: usize = 8;

fn cfg(tenancy: bool) -> PlatformConfig {
    PlatformConfig {
        nodes: 1,
        gpus_per_node: 2,
        latency: nsml::container::LatencyModel::fast(),
        artifacts_dir: "artifacts".into(),
        tenancy,
        ..PlatformConfig::default()
    }
}

fn opts(steps: u64, seed: u64) -> RunOpts {
    RunOpts { total_steps: steps, eval_every: 0, checkpoint_every: 0, seed, ..Default::default() }
}

/// Submit alice's burst, then bob's, and drive everything to done.
fn drive_two_users(p: &NsmlPlatform, steps: u64) {
    for (u, user) in USERS.iter().enumerate() {
        for i in 0..PER_USER {
            p.run(user, "mnist", opts(steps, (u * PER_USER + i) as u64)).unwrap();
        }
    }
    p.run_to_completion(steps.min(12), 100_000).unwrap();
}

fn within(a: f64, b: f64, tol: f64) -> bool {
    (a - b).abs() <= tol * a.max(b)
}

/// `(mean, last)` completion times in virtual ms for a user's sessions.
fn finish_stats_ms(p: &NsmlPlatform, user: &str) -> (f64, f64) {
    let finishes: Vec<f64> = p
        .sessions
        .list()
        .into_iter()
        .filter(|r| r.spec.user == user)
        .map(|r| {
            assert_eq!(r.state, SessionState::Done, "{}", r.spec.id);
            r.finished_at_ms.expect("done session has a finish time") as f64
        })
        .collect();
    let mean = finishes.iter().sum::<f64>() / finishes.len() as f64;
    let last = finishes.iter().fold(0.0f64, |a, &b| a.max(b));
    (mean, last)
}

fn main() {
    let steps: u64 = if smoke() { 8 } else { 24 };
    println!(
        "tenancy bench: {} users x {} sessions x {} steps on 1 node / 2 GPUs{}",
        USERS.len(),
        PER_USER,
        steps,
        if smoke() { " [smoke]" } else { "" }
    );

    // ---- fairness: one full tenancy-enabled run, inspected in depth.
    let p = NsmlPlatform::new(cfg(true)).expect("run `make artifacts` first");
    drive_two_users(&p, steps);
    let now = p.clock.now_ms();
    let gpu_sec: Vec<f64> =
        USERS.iter().map(|u| p.tenancy.accountant.usage_at(u, now)).collect();
    let fin: Vec<(f64, f64)> = USERS.iter().map(|u| finish_stats_ms(&p, u)).collect();
    println!(
        "fairness: gpu-seconds alice={:.3} bob={:.3} | finish (mean/last) alice={:.0}/{:.0}ms bob={:.0}/{:.0}ms",
        gpu_sec[0], gpu_sec[1], fin[0].0, fin[0].1, fin[1].0, fin[1].1
    );
    if !smoke() {
        assert!(
            within(gpu_sec[0], gpu_sec[1], 0.20),
            "aggregate GPU-seconds diverge >20%: {:?}",
            gpu_sec
        );
        assert!(
            within(fin[0].1, fin[1].1, 0.20),
            "last completions diverge >20% (FIFO-like starvation): {:?}",
            fin
        );
    }

    // ---- overhead: tenancy-on vs tenancy-off wall-clock for the same
    // workload (fresh platform per iteration so state never accretes).
    let mut bench = Bench::new("tenancy");
    bench.run("drive 16 sessions, tenancy off", || {
        let p = NsmlPlatform::new(cfg(false)).expect("artifacts");
        drive_two_users(&p, steps);
    });
    bench.run("drive 16 sessions, tenancy on", || {
        let p = NsmlPlatform::new(cfg(true)).expect("artifacts");
        drive_two_users(&p, steps);
    });
    bench.finish();

    let off = bench.result("drive 16 sessions, tenancy off").unwrap().p50_ms();
    let on = bench.result("drive 16 sessions, tenancy on").unwrap().p50_ms();
    println!(
        "admission overhead: {:+.2}% (off {:.1}ms -> on {:.1}ms)",
        (on / off - 1.0) * 100.0,
        off,
        on
    );
    if smoke() {
        println!("smoke mode: skipping the fairness/overhead assertions");
    } else {
        assert!(
            on <= off * 1.05,
            "fair-share admission must cost <=5% wall-clock, got {:.1}ms -> {:.1}ms ({:+.2}%)",
            off,
            on,
            (on / off - 1.0) * 100.0
        );
        println!("OK: fairness within 20% and admission overhead <=5%");
    }
}
