//! Dispatch overhead of the v1 service layer: the same operations issued
//! as `PlatformService::dispatch(ApiRequest)` vs direct facade calls,
//! plus the wire tax (JSON parse + dispatch + serialize) on top. The
//! acceptance bar for the service layer is dispatch ≤ 2× direct.
//!
//! Run: `cargo bench --bench bench_api`

use nsml::api::{ApiRequest, ApiResponse, NsmlPlatform, PlatformConfig, PlatformService, RunParams};
use nsml::util::bench::Bench;

fn main() {
    let mut cfg = PlatformConfig::test_default();
    cfg.artifacts_dir = "artifacts".into();
    let service = PlatformService::new(NsmlPlatform::new(cfg).unwrap());

    // Seed real state so queries return non-trivial payloads.
    let mut ids = Vec::new();
    for i in 0..4 {
        let mut p = RunParams::new("bench", "mnist");
        p.total_steps = 8;
        p.eval_every = 4;
        p.checkpoint_every = 4;
        p.seed = i;
        match service.dispatch(ApiRequest::Run(p)) {
            ApiResponse::Submitted { session } => ids.push(session),
            other => panic!("run dispatch failed: {:?}", other),
        }
    }
    match service.dispatch(ApiRequest::RunToCompletion { chunk: 8, max_rounds: 10_000 }) {
        ApiResponse::Ack { .. } => {}
        other => panic!("run_to_completion failed: {:?}", other),
    }
    let id = ids[0].clone();
    let platform = service.platform();

    let mut bench = Bench::new("api_dispatch");

    // Query pairs: facade vs dispatch.
    bench.run("facade: sessions.list", || {
        assert_eq!(platform.sessions.list().len(), 4);
    });
    bench.run("dispatch: list_sessions", || {
        match service.dispatch(ApiRequest::list_sessions()) {
            ApiResponse::Sessions { sessions } => assert_eq!(sessions.len(), 4),
            other => panic!("{:?}", other),
        }
    });

    bench.run("facade: sessions.get", || {
        assert!(platform.sessions.get(&id).is_some());
    });
    bench.run("dispatch: get_session", || {
        let req = ApiRequest::GetSession { session: id.clone() };
        assert!(matches!(service.dispatch(req), ApiResponse::Session { .. }));
    });

    bench.run("facade: leaderboard.top", || {
        assert!(!platform.leaderboard.top("mnist", 100).is_empty());
    });
    bench.run("dispatch: board", || {
        let req = ApiRequest::Board { dataset: "mnist".into(), limit: 100, user: None };
        assert!(matches!(service.dispatch(req), ApiResponse::Board { .. }));
    });

    bench.run("facade: cluster snapshot", || {
        let (_total, _free) = platform.cluster.gpu_totals();
        assert_eq!(platform.cluster.snapshot().len(), 3);
    });
    bench.run("dispatch: cluster_status", || {
        assert!(matches!(service.dispatch(ApiRequest::ClusterStatus), ApiResponse::Cluster { .. }));
    });

    // Mutation pair: stopping an already-terminal session exercises the
    // full control path (event log, scheduler bookkeeping) on both sides.
    bench.run("facade: stop (terminal)", || {
        platform.stop(&id).unwrap();
    });
    bench.run("dispatch: stop (terminal)", || {
        let req = ApiRequest::Stop { session: id.clone() };
        assert!(matches!(service.dispatch(req), ApiResponse::Ack { .. }));
    });

    // The wire tax: parse the JSON envelope, dispatch, serialize back.
    let wire_req = ApiRequest::list_sessions().to_json().to_string();
    bench.run("wire: dispatch_json list_sessions", || {
        let out = service.dispatch_json(&wire_req);
        assert!(out.contains("\"kind\":\"sessions\""));
    });

    bench.finish();

    println!("dispatch overhead (p50 dispatch / p50 facade):");
    let mut worst: f64 = 0.0;
    for (facade, dispatch) in [
        ("facade: sessions.list", "dispatch: list_sessions"),
        ("facade: sessions.get", "dispatch: get_session"),
        ("facade: leaderboard.top", "dispatch: board"),
        ("facade: cluster snapshot", "dispatch: cluster_status"),
        ("facade: stop (terminal)", "dispatch: stop (terminal)"),
    ] {
        let f = bench.result(facade).unwrap().p50_ms();
        let d = bench.result(dispatch).unwrap().p50_ms();
        let ratio = if f > 0.0 { d / f } else { f64::NAN };
        worst = worst.max(ratio);
        println!("  {:<28} {:>6.2}x  ({:.4}ms vs {:.4}ms)", dispatch, ratio, d, f);
    }
    println!(
        "worst ratio: {:.2}x — {}",
        worst,
        if worst <= 2.0 { "OK (within the 2x budget)" } else { "WARN: above the 2x budget" }
    );
}
