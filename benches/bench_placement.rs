//! E11 (paper §2): placement-policy ablation against the ResNet-152
//! anecdote — "the total number of GPUs in a cluster is sufficient, but
//! due to bad scheduling no single server with eight idling GPUs is
//! available".
//!
//! Workload: Poisson churn of small jobs (1–4 GPUs) with periodic 8-GPU
//! jobs. Reports, per policy: 8-GPU admission rate, mean utilization,
//! and decision latency.
//!
//! Run: `cargo bench --bench bench_placement`

use nsml::cluster::Cluster;
use nsml::events::EventLog;
use nsml::scheduler::{policy_by_name, JobSpec, Master};
use nsml::util::bench::Bench;
use nsml::util::clock::sim_clock;
use nsml::util::rng::Rng;
use nsml::util::table::Table;

struct Outcome {
    big_admitted: usize,
    big_total: usize,
    mean_util: f64,
}

fn simulate(policy: &str, seed: u64) -> Outcome {
    let (clock, _) = sim_clock();
    let events = EventLog::new(clock.clone()).with_echo(false);
    let cluster = Cluster::homogeneous(clock, events.clone(), 10, 8, 24.0);
    let master = Master::new(cluster.clone(), policy_by_name(policy, seed), events);
    let mut rng = Rng::new(seed);
    let mut running: Vec<(String, u64)> = Vec::new(); // (job, finish tick)
    let mut seq = 0u64;
    let mut big_admitted = 0;
    let mut big_total = 0;
    let mut util_acc = 0.0;
    const TICKS: u64 = 2000;
    for tick in 0..TICKS {
        // Finish due jobs.
        running.retain(|(id, finish)| {
            if *finish <= tick {
                master.complete(id);
                false
            } else {
                true
            }
        });
        // Small-job arrivals tuned for ~55% mean utilization — the regime
        // where placement policy decides whether whole nodes stay free.
        if rng.chance(0.45) {
            let gpus = rng.range(1, 5);
            let id = format!("s{}", seq);
            seq += 1;
            master.submit(JobSpec::new(&id, gpus));
            running.push((id, tick + rng.range(20, 60) as u64));
        }
        // Every 50 ticks: one 8-GPU job attempt. Count immediate
        // schedulability (the §2 pain point is "can it start *now*").
        if tick % 50 == 25 {
            big_total += 1;
            let id = format!("big{}", seq);
            seq += 1;
            match master.submit(JobSpec::new(&id, 8)) {
                nsml::scheduler::SubmitOutcome::PlacedImmediately(_) => {
                    big_admitted += 1;
                    running.push((id, tick + 40));
                }
                _ => {
                    master.cancel_queued(&id);
                }
            }
        }
        master.pump();
        util_acc += master.cluster().utilization();
    }
    Outcome { big_admitted, big_total, mean_util: util_acc / TICKS as f64 }
}

fn main() {
    let mut bench = Bench::new("placement");
    let policies = ["best_fit", "first_fit", "worst_fit", "random"];
    let mut table = Table::new(&["POLICY", "8-GPU ADMIT RATE", "MEAN UTILIZATION"]).right(&[1, 2]);

    for policy in policies {
        // Decision latency: average over the whole simulated run.
        bench.run(&format!("simulate 2000 ticks [{}]", policy), || {
            simulate(policy, 1);
        });
        // Quality metrics over 3 seeds.
        let mut admit = 0.0;
        let mut util = 0.0;
        for seed in 1..=3 {
            let o = simulate(policy, seed);
            admit += o.big_admitted as f64 / o.big_total as f64;
            util += o.mean_util;
        }
        table.row(&[
            policy.to_string(),
            format!("{:.1}%", 100.0 * admit / 3.0),
            format!("{:.1}%", 100.0 * util / 3.0),
        ]);
    }
    bench.finish();
    println!("\n== E11: fragmentation vs policy (paper §2 anecdote) ==\n{}", table.render());
    println!("expected shape: best_fit admits 8-GPU jobs most often; worst_fit/random fragment the cluster.");
}
