//! E7 + E8 (paper §3.3): the two container-startup bottlenecks and their
//! fixes, measured in *virtual* milliseconds (the latency model is the
//! documented docker-realistic default).
//!
//!  1. "We removed the first bottleneck by reusing existing docker
//!     images" — cold build vs warm reuse.
//!  2. "The other can be solved by sharing dataset directories among all
//!     ML containers … at the same host machine" — copy vs shared mount.
//!
//! Run: `cargo bench --bench bench_container`

use nsml::cluster::NodeId;
use nsml::container::{ContainerManager, ImageSpec, LatencyModel};
use nsml::events::EventLog;
use nsml::util::bench::Bench;
use nsml::util::clock::sim_clock;
use nsml::util::table::{fms, Table};

fn mgr() -> (ContainerManager, nsml::util::clock::SharedClock) {
    let (clock, _) = sim_clock();
    let events = EventLog::new(clock.clone()).with_echo(false);
    (ContainerManager::new(clock.clone(), events, LatencyModel::default()), clock)
}

fn main() {
    let mut bench = Bench::new("container");
    let dataset_gb = 10.0; // ImageNet-ish

    // --- E7/E8 virtual-latency matrix -------------------------------
    let (m, _) = mgr();
    let cold = m.launch("cold", NodeId(0), &ImageSpec::tensorflow(), "imagenet", dataset_gb);
    let warm = m.launch("warm", NodeId(0), &ImageSpec::tensorflow(), "imagenet", dataset_gb);
    let warm_img_new_node = m.launch("half", NodeId(1), &ImageSpec::tensorflow(), "imagenet", dataset_gb);

    // Ablations: disable each fix.
    let (m_noimg, _) = mgr();
    m_noimg.images().set_enabled(false);
    m_noimg.launch("a", NodeId(0), &ImageSpec::tensorflow(), "imagenet", dataset_gb);
    let no_reuse = m_noimg.launch("b", NodeId(0), &ImageSpec::tensorflow(), "imagenet", dataset_gb);

    let (m_noshare, _) = mgr();
    m_noshare.mounts().set_sharing(false);
    m_noshare.launch("a", NodeId(0), &ImageSpec::tensorflow(), "imagenet", dataset_gb);
    let no_share = m_noshare.launch("b", NodeId(0), &ImageSpec::tensorflow(), "imagenet", dataset_gb);

    let mut t = Table::new(&["SCENARIO", "STARTUP (virtual)", "IMAGE", "DATASET"]).right(&[1]);
    for (name, c) in [
        ("cold start (first ever)", &cold),
        ("warm start (same node, both fixes)", &warm),
        ("warm image, new node (copy dataset)", &warm_img_new_node),
        ("ablation: image reuse OFF", &no_reuse),
        ("ablation: mount sharing OFF", &no_share),
    ] {
        t.row(&[
            name.to_string(),
            fms(c.startup_ms as f64),
            format!("{:?}", c.image_outcome),
            format!("{:?}", c.mount_outcome),
        ]);
    }
    println!("== E7/E8: container startup (virtual ms; docker-realistic latency model) ==\n{}", t.render());
    println!(
        "speedup from both fixes: {:.0}x (cold {} -> warm {})\n",
        cold.startup_ms as f64 / warm.startup_ms as f64,
        fms(cold.startup_ms as f64),
        fms(warm.startup_ms as f64)
    );
    bench.record(
        "cold start (virtual ms)",
        vec![cold.startup_ms as f64],
        None,
    );
    bench.record("warm start (virtual ms)", vec![warm.startup_ms as f64], None);

    // --- real-time cost of the bookkeeping itself -------------------
    let (m2, _) = mgr();
    m2.launch("seed", NodeId(0), &ImageSpec::pytorch(), "d", 1.0);
    let mut n = 0u64;
    bench.run_with_units("launch+stop bookkeeping (warm, real time)", 100.0, || {
        for _ in 0..100 {
            let c = m2.launch(&format!("j{}", n), NodeId(0), &ImageSpec::pytorch(), "d", 1.0);
            m2.stop(&c.id);
            n += 1;
        }
    });

    bench.finish();
}
