//! HTTP front-end throughput: the pooled keep-alive server vs the old
//! thread-per-connection baseline, measured while the daemon drive loop
//! trains sessions on the same platform (the `nsml serve` deployment
//! shape). N concurrent clients hammer `GET /` — a route rendered
//! straight off the shared stores, so the comparison isolates the HTTP
//! layer itself: per-request connect + thread spawn (baseline) vs a
//! reused socket into a bounded worker pool (pooled).
//!
//! Acceptance: pooled keep-alive sustains >= 2x the baseline's req/s at
//! 16 concurrent clients, with bounded p99 per-request latency.
//!
//! Run: `cargo bench --bench bench_web` (BENCH_SMOKE=1 shrinks the
//! client count and workload and skips the perf assertions).

use nsml::api::{
    ApiRequest, ApiResponse, DaemonOpts, NsmlPlatform, PlatformConfig, PlatformService, RunParams,
};
use nsml::util::bench::{self, Bench};
use nsml::web::{serve_thread_per_conn, serve_with, ServeOpts, WebState};
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::atomic::Ordering;
use std::time::{Duration, Instant};

fn find(hay: &[u8], needle: &[u8], from: usize) -> Option<usize> {
    hay.windows(needle.len()).skip(from).position(|w| w == needle).map(|p| p + from)
}

/// Read exactly one HTTP/1.1 response off a keep-alive socket: headers,
/// then `Content-Length` bytes of body. Leftover bytes stay in `buf`.
fn read_one_response(stream: &mut TcpStream, buf: &mut Vec<u8>) {
    let mut scanned = 0;
    let header_end = loop {
        if let Some(pos) = find(buf, b"\r\n\r\n", scanned) {
            break pos + 4;
        }
        scanned = buf.len().saturating_sub(3);
        let mut chunk = [0u8; 4096];
        let n = stream.read(&mut chunk).expect("read");
        assert!(n > 0, "server closed the keep-alive socket mid-response");
        buf.extend_from_slice(&chunk[..n]);
    };
    let head = String::from_utf8_lossy(&buf[..header_end]).to_string();
    assert!(head.starts_with("HTTP/1.1 200"), "{}", head);
    let body_len = head
        .lines()
        .find_map(|l| {
            let (k, v) = l.split_once(':')?;
            k.eq_ignore_ascii_case("content-length").then(|| v.trim().parse::<usize>().unwrap())
        })
        .unwrap_or(0);
    while buf.len() < header_end + body_len {
        let mut chunk = [0u8; 4096];
        let n = stream.read(&mut chunk).expect("read body");
        assert!(n > 0, "server closed the keep-alive socket mid-body");
        buf.extend_from_slice(&chunk[..n]);
    }
    buf.drain(..header_end + body_len);
}

/// One socket, `n` sequential requests: the keep-alive client.
fn keepalive_client(port: u16, n: usize) -> Vec<f64> {
    let mut lat = Vec::with_capacity(n);
    let mut stream = TcpStream::connect(("127.0.0.1", port)).expect("connect");
    stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let mut buf = Vec::new();
    for _ in 0..n {
        let t0 = Instant::now();
        write!(stream, "GET / HTTP/1.1\r\nHost: bench\r\n\r\n").expect("write");
        read_one_response(&mut stream, &mut buf);
        lat.push(t0.elapsed().as_secs_f64() * 1000.0);
    }
    lat
}

/// A fresh connection per request: how the old accept loop was used.
fn reconnect_client(port: u16, n: usize) -> Vec<f64> {
    let mut lat = Vec::with_capacity(n);
    for _ in 0..n {
        let t0 = Instant::now();
        let mut s = TcpStream::connect(("127.0.0.1", port)).expect("connect");
        s.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        write!(s, "GET / HTTP/1.1\r\nHost: bench\r\nConnection: close\r\n\r\n").expect("write");
        let mut out = Vec::new();
        s.read_to_end(&mut out).expect("read");
        assert!(out.starts_with(b"HTTP/1.1 200"));
        lat.push(t0.elapsed().as_secs_f64() * 1000.0);
    }
    lat
}

/// Run `clients` concurrent client threads; returns (all per-request
/// latencies in ms, aggregate req/s).
fn phase(port: u16, clients: usize, per_client: usize, keepalive: bool) -> (Vec<f64>, f64) {
    let t0 = Instant::now();
    let handles: Vec<_> = (0..clients)
        .map(|_| {
            std::thread::spawn(move || {
                if keepalive {
                    keepalive_client(port, per_client)
                } else {
                    reconnect_client(port, per_client)
                }
            })
        })
        .collect();
    let mut all = Vec::new();
    for h in handles {
        all.extend(h.join().expect("client thread"));
    }
    let rps = (clients * per_client) as f64 / t0.elapsed().as_secs_f64();
    (all, rps)
}

fn pctl(samples: &mut [f64], q: f64) -> f64 {
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    samples[((samples.len() as f64 - 1.0) * q).round() as usize]
}

fn main() {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("bench_web: artifacts not built (rust/artifacts/manifest.json); skipping");
        return;
    }
    let smoke = bench::smoke();
    let clients = if smoke { 2 } else { 16 };
    let per_client = if smoke { 10 } else { 150 };

    // Live platform with sessions that keep training for the whole
    // measurement window; the main thread runs the daemon drive loop
    // exactly as `nsml serve` does.
    let mut cfg = PlatformConfig::test_default();
    cfg.artifacts_dir = "artifacts".into();
    let service = PlatformService::new(NsmlPlatform::new(cfg).unwrap());
    for i in 0..4u64 {
        let mut p = RunParams::new("bench", "mnist");
        p.total_steps = if smoke { 64 } else { 1_000_000 };
        p.eval_every = p.total_steps;
        p.checkpoint_every = p.total_steps;
        p.seed = i;
        match service.dispatch(ApiRequest::Run(p)) {
            ApiResponse::Submitted { .. } => {}
            other => panic!("run dispatch failed: {:?}", other),
        }
    }

    // Both servers render off the same shared stores. The handle must
    // outlive the daemon (a disconnected channel would stop the loop).
    let platform = service.platform();
    let mk_state = || WebState {
        sessions: platform.sessions.clone(),
        leaderboard: platform.leaderboard.clone(),
        cluster: Some(platform.cluster.clone()),
        events: platform.events.clone(),
        api: None,
        obs: None,
    };
    let (_keep_api, rx) = nsml::api::service_channel();
    let (base_port, _baseline) = serve_thread_per_conn(mk_state(), 0).unwrap();
    let pooled =
        serve_with(mk_state(), 0, ServeOpts { workers: clients.max(8), ..ServeOpts::default() })
            .unwrap();
    let pooled_port = pooled.port();

    let opts = DaemonOpts {
        chunk: 8,
        idle_wait: Duration::from_millis(5),
        ..DaemonOpts::default()
    };
    let stop = opts.stop.clone();
    let meas = std::thread::spawn(move || {
        let base = phase(base_port, clients, per_client, false);
        let pool = phase(pooled_port, clients, per_client, true);
        stop.store(true, Ordering::SeqCst);
        (base, pool)
    });
    service.run_daemon(&rx, &opts).unwrap();
    let ((mut base_lat, base_rps), (mut pool_lat, pool_rps)) = meas.join().expect("measurement");
    pooled.shutdown();

    let mut b = Bench::new("web_http");
    b.record("thread-per-conn GET /", base_lat.clone(), None);
    b.record("pooled keep-alive GET /", pool_lat.clone(), None);
    b.finish();

    let base_p99 = pctl(&mut base_lat, 0.99);
    let pool_p99 = pctl(&mut pool_lat, 0.99);
    let status = service.platform().service_status();
    println!(
        "{} clients x {} requests while the daemon drove {} rounds ({:.1} rounds/s)",
        clients, per_client, status.rounds, status.rounds_per_sec
    );
    println!("  thread-per-conn:   {:>8.0} req/s   p99 {:>7.2}ms", base_rps, base_p99);
    println!(
        "  pooled keep-alive: {:>8.0} req/s   p99 {:>7.2}ms   ({:.2}x req/s)",
        pool_rps,
        pool_p99,
        pool_rps / base_rps
    );

    if smoke {
        println!("smoke mode: perf assertions skipped");
        return;
    }
    assert!(
        pool_rps >= 2.0 * base_rps,
        "pooled keep-alive must sustain >= 2x the thread-per-conn baseline: {:.0} vs {:.0} req/s",
        pool_rps,
        base_rps
    );
    assert!(
        pool_p99 <= 500.0,
        "pooled p99 latency must stay bounded under load: {:.2}ms",
        pool_p99
    );
}
