//! E5 (paper §3.2): the empty-queue fast path "allows the scheduler to
//! avoid queue operation overhead". Measures submit-to-placement decision
//! latency with and without the fast path, plus sustained scheduler
//! throughput under churn.
//!
//! Run: `cargo bench --bench bench_scheduler`

use nsml::cluster::Cluster;
use nsml::events::EventLog;
use nsml::scheduler::{BestFit, JobSpec, Master, SubmitOutcome};
use nsml::util::bench::Bench;
use nsml::util::clock::sim_clock;

fn master(fast_path: bool) -> Master {
    let (clock, _) = sim_clock();
    let events = EventLog::new(clock.clone()).with_echo(false);
    let cluster = Cluster::homogeneous(clock, events.clone(), 10, 8, 24.0);
    let m = Master::new(cluster, Box::new(BestFit), events);
    if fast_path {
        m
    } else {
        m.without_fast_path()
    }
}

fn main() {
    let mut bench = Bench::new("scheduler");

    // Decision latency on an idle cluster: submit one job, then complete
    // it so the cluster returns to idle. 1000 jobs per iteration.
    let m = master(true);
    let mut n = 0u64;
    bench.run_with_units("submit+complete fast-path (idle queue)", 1000.0, || {
        for _ in 0..1000 {
            let id = format!("j{}", n);
            n += 1;
            match m.submit(JobSpec::new(&id, 1)) {
                SubmitOutcome::PlacedImmediately(_) => {}
                other => panic!("expected fast path, got {:?}", other),
            }
            m.complete(&id);
        }
    });

    let m2 = master(false);
    let mut n2 = 0u64;
    bench.run_with_units("submit+complete queue-path (fast path off)", 1000.0, || {
        for _ in 0..1000 {
            let id = format!("j{}", n2);
            n2 += 1;
            m2.submit(JobSpec::new(&id, 1));
            m2.pump();
            m2.complete(&id);
        }
    });

    // Sustained churn at ~70% utilization: queue is never empty, so this
    // exercises the queue path + placement over a fragmented cluster.
    let m3 = master(true);
    let mut seq = 0u64;
    let mut running: Vec<String> = Vec::new();
    // Prefill to 56/80 GPUs.
    for _ in 0..56 {
        let id = format!("pre{}", seq);
        seq += 1;
        m3.submit(JobSpec::new(&id, 1));
        running.push(id);
    }
    bench.run_with_units("churn @70% utilization (submit+complete)", 500.0, || {
        for _ in 0..500 {
            let id = format!("c{}", seq);
            seq += 1;
            m3.submit(JobSpec::new(&id, 1 + (seq % 4) as usize));
            if let Some(old) = running.first().cloned() {
                running.remove(0);
                m3.complete(&old);
            }
            running.push(id);
        }
    });

    bench.finish();

    let s = m.stats();
    println!(
        "fast-path hit rate on idle cluster: {}/{} ({}%)",
        s.fast_path_hits,
        s.submitted,
        100 * s.fast_path_hits / s.submitted.max(1)
    );
}
