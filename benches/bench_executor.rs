//! The executor headline: wall-clock for an 8-session training batch,
//! serial (inline, one thread — the pre-pool platform behaviour) vs the
//! worker pool at 1 and 4 workers. Acceptance bar: the 4-worker pool is
//! ≥2× faster than serial on a ≥4-core machine.
//!
//! Run: `cargo bench --bench bench_executor`
//! Smoke: `BENCH_SMOKE=1 cargo bench --bench bench_executor`

use nsml::cluster::NodeId;
use nsml::data::generator_for;
use nsml::events::EventLog;
use nsml::executor::{ExecutorPool, SessionOutcome, WorkerCtx};
use nsml::runtime::Engine;
use nsml::session::{SessionRecord, SessionRun, SessionSpec, SessionStore};
use nsml::storage::{CheckpointStore, ObjectStore};
use nsml::util::bench::{smoke, Bench};
use nsml::util::clock::sim_clock;
use std::sync::Arc;

const SESSIONS: usize = 8;
const CHUNK: u64 = 12;

fn ctx() -> WorkerCtx {
    let (clock, _) = sim_clock();
    WorkerCtx {
        artifacts_dir: "artifacts".into(),
        checkpoints: CheckpointStore::new(ObjectStore::memory()),
        sessions: SessionStore::new(),
        events: EventLog::new(clock.clone()).with_echo(false),
        clock,
    }
}

fn spec(tag: &str, i: usize, steps: u64) -> SessionSpec {
    let mut spec =
        SessionSpec::new(&format!("bench/exec/{}-{}", tag, i), "bench", "mnist", "mnist_mlp");
    spec.total_steps = steps;
    spec.eval_every = 0;
    spec.checkpoint_every = 0;
    spec.seed = i as u64;
    spec
}

/// Serial baseline: the pre-pool execution model — every run stepped
/// inline on the calling thread, sharing one engine.
fn run_serial(ctx: &WorkerCtx, engine: &Arc<Engine>, tag: &str, steps: u64) {
    let mut runs = Vec::new();
    for i in 0..SESSIONS {
        let spec = spec(tag, i, steps);
        ctx.sessions.insert(SessionRecord::new(spec.clone(), 0));
        let gen = generator_for(&spec.model, spec.seed).unwrap();
        runs.push(
            SessionRun::start(
                engine.clone(),
                spec,
                gen,
                ctx.checkpoints.clone(),
                ctx.sessions.clone(),
                ctx.events.clone(),
                ctx.clock.clone(),
            )
            .unwrap(),
        );
    }
    let mut pending = runs.len();
    while pending > 0 {
        pending = 0;
        for run in &mut runs {
            if run.steps_done() < steps {
                run.step_chunk(CHUNK).unwrap();
                if run.steps_done() < steps {
                    pending += 1;
                }
            }
        }
    }
}

/// Pool run: submit the batch spread across workers, then drive fork-
/// join step rounds until every session completes.
fn run_pool(ctx: &WorkerCtx, pool: &ExecutorPool, tag: &str, steps: u64) {
    for i in 0..SESSIONS {
        let spec = spec(tag, i, steps);
        ctx.sessions.insert(SessionRecord::new(spec.clone(), 0));
        pool.submit(spec, false, Some(NodeId(i as u32))).unwrap();
    }
    let mut done = 0;
    while done < SESSIONS {
        for (id, outcome) in pool.step_round(CHUNK) {
            match outcome {
                SessionOutcome::Completed => done += 1,
                SessionOutcome::Failed(e) => panic!("session {} failed: {}", id, e),
                _ => {}
            }
        }
    }
}

fn main() {
    let steps: u64 = if smoke() { 12 } else { 48 };
    let mut bench = Bench::new("executor");
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!(
        "executor bench: {} sessions x {} steps, chunk {}, {} cores{}",
        SESSIONS,
        steps,
        CHUNK,
        cores,
        if smoke() { " [smoke]" } else { "" }
    );

    // Serial baseline (shared engine, inline stepping).
    let serial_ctx = ctx();
    let engine = Arc::new(Engine::new("artifacts").expect("run `make artifacts` first"));
    let mut tag = 0usize;
    bench.run(&format!("serial inline x{} sessions", SESSIONS), || {
        tag += 1;
        run_serial(&serial_ctx, &engine, &format!("serial-{}", tag), steps);
    });

    // Pool with a single worker: same machinery, no parallelism — shows
    // the pure pool overhead.
    let pool1_ctx = ctx();
    let pool1 = ExecutorPool::new(1, pool1_ctx.clone());
    bench.run("pool x1 worker", || {
        tag += 1;
        run_pool(&pool1_ctx, &pool1, &format!("p1-{}", tag), steps);
    });

    // Pool with 4 workers: the headline.
    let pool4_ctx = ctx();
    let pool4 = ExecutorPool::new(4, pool4_ctx.clone());
    bench.run("pool x4 workers", || {
        tag += 1;
        run_pool(&pool4_ctx, &pool4, &format!("p4-{}", tag), steps);
    });

    bench.finish();

    let serial = bench.result(&format!("serial inline x{} sessions", SESSIONS)).unwrap().mean_ms();
    let p1 = bench.result("pool x1 worker").unwrap().mean_ms();
    let p4 = bench.result("pool x4 workers").unwrap().mean_ms();
    let speedup = serial / p4;
    println!(
        "speedup: pool x4 is {:.2}x vs serial ({:.1}ms -> {:.1}ms); pool x1 overhead {:.2}x",
        speedup,
        serial,
        p4,
        p1 / serial,
    );
    if smoke() {
        println!("smoke mode: skipping the >=2x speedup assertion");
    } else if cores < 4 {
        println!("only {} cores: skipping the >=2x speedup assertion", cores);
    } else {
        assert!(
            speedup >= 2.0,
            "expected >=2x speedup for {} sessions on 4 workers, got {:.2}x",
            SESSIONS,
            speedup
        );
        println!("OK: >=2x speedup bar met");
    }
}
