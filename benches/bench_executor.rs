//! The executor headlines: wall-clock for an 8-session training batch,
//! serial (inline, one thread — the pre-pool platform behaviour) vs the
//! worker pool at 1 and 4 workers, plus the work-steal ablation — the
//! same batch pinned to a single node (the skewed scheduler decision)
//! with static `node % workers` routing vs stealing enabled.
//!
//! Acceptance bars on a ≥4-core machine:
//!  * the 4-worker pool is ≥2× faster than serial, and
//!  * work-steal is ≥1.5× faster than static routing when all 8
//!    sessions land on one node (static serializes them on one worker).
//!
//! Run: `cargo bench --bench bench_executor`
//! Smoke: `BENCH_SMOKE=1 cargo bench --bench bench_executor`

use nsml::cluster::NodeId;
use nsml::data::generator_for;
use nsml::events::EventLog;
use nsml::executor::{ExecutorPool, SessionOutcome, WorkerCtx};
use nsml::runtime::Engine;
use nsml::session::{SessionRecord, SessionRun, SessionSpec, SessionStore};
use nsml::storage::{CheckpointStore, ObjectStore};
use nsml::util::bench::{smoke, Bench};
use nsml::util::clock::sim_clock;
use std::sync::Arc;

const SESSIONS: usize = 8;
const CHUNK: u64 = 12;

fn ctx() -> WorkerCtx {
    let (clock, _) = sim_clock();
    WorkerCtx {
        artifacts_dir: "artifacts".into(),
        checkpoints: CheckpointStore::new(ObjectStore::memory()),
        sessions: SessionStore::new(),
        events: EventLog::new(clock.clone()).with_echo(false),
        clock,
    }
}

fn spec(tag: &str, i: usize, steps: u64) -> SessionSpec {
    let mut spec =
        SessionSpec::new(&format!("bench/exec/{}-{}", tag, i), "bench", "mnist", "mnist_mlp");
    spec.total_steps = steps;
    spec.eval_every = 0;
    spec.checkpoint_every = 0;
    spec.seed = i as u64;
    spec
}

/// Serial baseline: the pre-pool execution model — every run stepped
/// inline on the calling thread, sharing one engine.
fn run_serial(ctx: &WorkerCtx, engine: &Arc<Engine>, tag: &str, steps: u64) {
    let mut runs = Vec::new();
    for i in 0..SESSIONS {
        let spec = spec(tag, i, steps);
        ctx.sessions.insert(SessionRecord::new(spec.clone(), 0));
        let gen = generator_for(&spec.model, spec.seed).unwrap();
        runs.push(
            SessionRun::start(
                engine.clone(),
                spec,
                gen,
                ctx.checkpoints.clone(),
                ctx.sessions.clone(),
                ctx.events.clone(),
                ctx.clock.clone(),
            )
            .unwrap(),
        );
    }
    let mut pending = runs.len();
    while pending > 0 {
        pending = 0;
        for run in &mut runs {
            if run.steps_done() < steps {
                run.step_chunk(CHUNK).unwrap();
                if run.steps_done() < steps {
                    pending += 1;
                }
            }
        }
    }
}

/// Pool run: submit the batch, then drive fork-join step rounds until
/// every session completes. `node_of` maps session index → pinned node
/// (spread for the headline, all-zero for the skewed scenario).
fn run_pool(
    ctx: &WorkerCtx,
    pool: &ExecutorPool,
    tag: &str,
    steps: u64,
    node_of: impl Fn(usize) -> u32,
) {
    for i in 0..SESSIONS {
        let spec = spec(tag, i, steps);
        ctx.sessions.insert(SessionRecord::new(spec.clone(), 0));
        pool.submit(spec, false, Some(NodeId(node_of(i)))).unwrap();
    }
    let mut done = 0;
    while done < SESSIONS {
        for (id, outcome) in pool.step_round(CHUNK) {
            match outcome {
                SessionOutcome::Completed => done += 1,
                SessionOutcome::Failed(e) => panic!("session {} failed: {}", id, e),
                _ => {}
            }
        }
    }
}

fn main() {
    let steps: u64 = if smoke() { 12 } else { 48 };
    let mut bench = Bench::new("executor");
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!(
        "executor bench: {} sessions x {} steps, chunk {}, {} cores{}",
        SESSIONS,
        steps,
        CHUNK,
        cores,
        if smoke() { " [smoke]" } else { "" }
    );

    // Serial baseline (shared engine, inline stepping).
    let serial_ctx = ctx();
    let engine = Arc::new(Engine::new("artifacts").expect("run `make artifacts` first"));
    let mut tag = 0usize;
    bench.run(&format!("serial inline x{} sessions", SESSIONS), || {
        tag += 1;
        run_serial(&serial_ctx, &engine, &format!("serial-{}", tag), steps);
    });

    // Pool with a single worker: same machinery, no parallelism — shows
    // the pure pool overhead.
    let pool1_ctx = ctx();
    let pool1 = ExecutorPool::new(1, pool1_ctx.clone());
    bench.run("pool x1 worker", || {
        tag += 1;
        run_pool(&pool1_ctx, &pool1, &format!("p1-{}", tag), steps, |i| i as u32);
    });

    // Pool with 4 workers, sessions spread over nodes: the headline.
    let pool4_ctx = ctx();
    let pool4 = ExecutorPool::new(4, pool4_ctx.clone());
    bench.run("pool x4 workers", || {
        tag += 1;
        run_pool(&pool4_ctx, &pool4, &format!("p4-{}", tag), steps, |i| i as u32);
    });

    // Skewed load: the scheduler pinned every session to node 0. Static
    // routing serializes the batch on worker 0; stealing rebalances it.
    let static_ctx = ctx();
    let static_pool = ExecutorPool::with_stealing(4, static_ctx.clone(), false);
    bench.run("skewed x4 static routing", || {
        tag += 1;
        run_pool(&static_ctx, &static_pool, &format!("sk-static-{}", tag), steps, |_| 0);
    });

    let steal_ctx = ctx();
    let steal_pool = ExecutorPool::with_stealing(4, steal_ctx.clone(), true);
    bench.run("skewed x4 work-steal", || {
        tag += 1;
        run_pool(&steal_ctx, &steal_pool, &format!("sk-steal-{}", tag), steps, |_| 0);
    });

    bench.finish();

    let serial = bench.result(&format!("serial inline x{} sessions", SESSIONS)).unwrap().mean_ms();
    let p1 = bench.result("pool x1 worker").unwrap().mean_ms();
    let p4 = bench.result("pool x4 workers").unwrap().mean_ms();
    let sk_static = bench.result("skewed x4 static routing").unwrap().mean_ms();
    let sk_steal = bench.result("skewed x4 work-steal").unwrap().mean_ms();
    let speedup = serial / p4;
    let steal_speedup = sk_static / sk_steal;
    println!(
        "speedup: pool x4 is {:.2}x vs serial ({:.1}ms -> {:.1}ms); pool x1 overhead {:.2}x",
        speedup,
        serial,
        p4,
        p1 / serial,
    );
    println!(
        "work-steal: {:.2}x vs static routing on a skewed node ({:.1}ms -> {:.1}ms), {} steals",
        steal_speedup,
        sk_static,
        sk_steal,
        steal_pool.total_steals(),
    );
    if smoke() {
        println!("smoke mode: skipping the speedup assertions");
    } else if cores < 4 {
        println!("only {} cores: skipping the speedup assertions", cores);
    } else {
        assert!(
            speedup >= 2.0,
            "expected >=2x speedup for {} sessions on 4 workers, got {:.2}x",
            SESSIONS,
            speedup
        );
        assert!(
            steal_speedup >= 1.5,
            "expected work-steal >=1.5x over static routing for {} sessions pinned to one node, got {:.2}x",
            SESSIONS,
            steal_speedup
        );
        assert!(steal_pool.total_steals() > 0, "work-steal pool recorded no steals");
        println!("OK: >=2x pool and >=1.5x work-steal bars met");
    }
}
