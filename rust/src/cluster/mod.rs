//! Simulated GPU cluster — the physical substrate NSML schedules onto.
//!
//! The paper's prototype ran on "a server cluster equipped with 80 P40
//! GPUs". That hardware is unavailable here, so this module provides a
//! faithful stand-in: nodes with GPU/CPU/memory capacities, a heartbeat
//! protocol (slaves periodically report resources to the master, §3.2),
//! and failure injection for the SPOF / instability experiments (§4.2).
//!
//! Everything observable by the scheduler flows through the same
//! interfaces a real agent would provide: capacity vectors, heartbeat
//! timestamps and allocation/release calls.

mod node;
mod failure;
pub mod monitor;

pub use failure::FailurePlan;
pub use monitor::UtilizationMonitor;
pub use node::{GpuDevice, Node, NodeId, NodeStatus, ResourceReq};

use crate::events::EventLog;
use crate::util::clock::{Millis, SharedClock};
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

/// How long without a heartbeat before the master declares a node dead.
pub const HEARTBEAT_TIMEOUT_MS: Millis = 3_000;
/// How often slave nodes report their resources (paper §3.2: "periodically
/// report ... to the master node").
pub const HEARTBEAT_INTERVAL_MS: Millis = 500;

/// A snapshot of one node's schedulable state, as reported by heartbeat.
#[derive(Debug, Clone)]
pub struct NodeView {
    pub id: NodeId,
    pub hostname: String,
    pub total_gpus: usize,
    pub free_gpus: usize,
    pub total_cpus: u32,
    pub free_cpus: u32,
    pub total_mem_gb: f64,
    pub free_mem_gb: f64,
    pub alive: bool,
    pub last_heartbeat_ms: Millis,
    /// Job ids currently running here.
    pub jobs: Vec<String>,
}

impl NodeView {
    pub fn fits(&self, req: &ResourceReq) -> bool {
        self.alive
            && self.free_gpus >= req.gpus
            && self.free_cpus >= req.cpus
            && self.free_mem_gb >= req.mem_gb
    }
}

/// The shared cluster state. Thread-safe; cheap to clone (Arc inside).
#[derive(Clone)]
pub struct Cluster {
    inner: Arc<Mutex<ClusterState>>,
    clock: SharedClock,
    events: EventLog,
}

struct ClusterState {
    nodes: BTreeMap<NodeId, Node>,
    /// Allocation table: job id -> (node, gpu indexes).
    allocations: BTreeMap<String, (NodeId, Vec<usize>)>,
}

impl Cluster {
    pub fn new(clock: SharedClock, events: EventLog) -> Cluster {
        Cluster {
            inner: Arc::new(Mutex::new(ClusterState {
                nodes: BTreeMap::new(),
                allocations: BTreeMap::new(),
            })),
            clock,
            events,
        }
    }

    /// Build a homogeneous cluster: `nodes` hosts × `gpus_per_node` GPUs.
    /// The paper's prototype shape is `Cluster::homogeneous(10, 8, ...)`
    /// (80 P40s).
    pub fn homogeneous(
        clock: SharedClock,
        events: EventLog,
        nodes: usize,
        gpus_per_node: usize,
        gpu_mem_gb: f64,
    ) -> Cluster {
        let c = Cluster::new(clock, events);
        for i in 0..nodes {
            c.add_node(Node::new(
                &format!("node-{:02}", i),
                gpus_per_node,
                gpu_mem_gb,
                64,
                256.0,
            ));
        }
        c
    }

    pub fn add_node(&self, mut node: Node) -> NodeId {
        let mut st = self.inner.lock().unwrap();
        let id = NodeId(st.nodes.len() as u32);
        node.id = id;
        node.last_heartbeat_ms = self.clock.now_ms();
        self.events.info("cluster", &node.hostname.clone(), format!("node joined with {} GPUs", node.gpus.len()));
        st.nodes.insert(id, node);
        id
    }

    /// Record a heartbeat from `node` (slave → master resource report).
    pub fn heartbeat(&self, node: NodeId) {
        let now = self.clock.now_ms();
        let mut st = self.inner.lock().unwrap();
        if let Some(n) = st.nodes.get_mut(&node) {
            n.last_heartbeat_ms = now;
            if n.status == NodeStatus::Dead {
                n.status = NodeStatus::Alive;
                self.events.info("cluster", &n.hostname.clone(), "node recovered");
            }
        }
    }

    /// Heartbeat all currently-alive nodes (driver convenience).
    pub fn heartbeat_all(&self) {
        let ids: Vec<NodeId> = {
            let st = self.inner.lock().unwrap();
            st.nodes.values().filter(|n| n.status == NodeStatus::Alive).map(|n| n.id).collect()
        };
        for id in ids {
            self.heartbeat(id);
        }
    }

    /// Mark nodes dead whose heartbeat is stale; returns the jobs that were
    /// running on them (the scheduler requeues those).
    pub fn reap_dead(&self) -> Vec<String> {
        let now = self.clock.now_ms();
        let mut st = self.inner.lock().unwrap();
        let mut orphans = Vec::new();
        let mut dead_nodes = Vec::new();
        for n in st.nodes.values_mut() {
            if n.status == NodeStatus::Alive && now.saturating_sub(n.last_heartbeat_ms) > HEARTBEAT_TIMEOUT_MS {
                n.status = NodeStatus::Dead;
                dead_nodes.push(n.id);
                self.events.warn("cluster", &n.hostname.clone(), "heartbeat timeout; marking dead");
            }
        }
        for dead in dead_nodes {
            let jobs: Vec<String> = st
                .allocations
                .iter()
                .filter(|(_, (nid, _))| *nid == dead)
                .map(|(j, _)| j.clone())
                .collect();
            for j in jobs {
                st.allocations.remove(&j);
                if let Some(n) = st.nodes.get_mut(&dead) {
                    n.release_job(&j);
                }
                orphans.push(j);
            }
        }
        orphans
    }

    /// Kill a node outright (failure injection). Returns orphaned jobs.
    pub fn kill_node(&self, node: NodeId) -> Vec<String> {
        let mut st = self.inner.lock().unwrap();
        let mut orphans = Vec::new();
        if let Some(n) = st.nodes.get_mut(&node) {
            n.status = NodeStatus::Dead;
            self.events.error("cluster", &n.hostname.clone(), "node killed (failure injection)");
        }
        let jobs: Vec<String> = st
            .allocations
            .iter()
            .filter(|(_, (nid, _))| *nid == node)
            .map(|(j, _)| j.clone())
            .collect();
        for j in jobs {
            st.allocations.remove(&j);
            if let Some(n) = st.nodes.get_mut(&node) {
                n.release_job(&j);
            }
            orphans.push(j);
        }
        orphans
    }

    /// Revive a previously killed node.
    pub fn revive_node(&self, node: NodeId) {
        let now = self.clock.now_ms();
        let mut st = self.inner.lock().unwrap();
        if let Some(n) = st.nodes.get_mut(&node) {
            n.status = NodeStatus::Alive;
            n.last_heartbeat_ms = now;
            self.events.info("cluster", &n.hostname.clone(), "node revived");
        }
    }

    /// Try to allocate `req` for `job` on `node`. Returns the GPU indexes.
    pub fn allocate(&self, node: NodeId, job: &str, req: &ResourceReq) -> Option<Vec<usize>> {
        let mut st = self.inner.lock().unwrap();
        if st.allocations.contains_key(job) {
            return None; // double allocation is a bug upstream
        }
        let n = st.nodes.get_mut(&node)?;
        let gpus = n.try_allocate(job, req)?;
        st.allocations.insert(job.to_string(), (node, gpus.clone()));
        self.events.debug(
            "cluster",
            job,
            format!("allocated {} GPU(s) on node {}", req.gpus, node.0),
        );
        Some(gpus)
    }

    /// Release the job's resources (job finished or was stopped).
    pub fn release(&self, job: &str) -> bool {
        let mut st = self.inner.lock().unwrap();
        if let Some((node, _)) = st.allocations.remove(job) {
            if let Some(n) = st.nodes.get_mut(&node) {
                n.release_job(job);
            }
            self.events.debug("cluster", job, "released resources");
            true
        } else {
            false
        }
    }

    /// Where is this job running, if anywhere?
    pub fn locate(&self, job: &str) -> Option<NodeId> {
        self.inner.lock().unwrap().allocations.get(job).map(|(n, _)| *n)
    }

    /// Schedulable view of every node (what the master sees).
    pub fn snapshot(&self) -> Vec<NodeView> {
        let st = self.inner.lock().unwrap();
        st.nodes.values().map(|n| n.view()).collect()
    }

    pub fn node_count(&self) -> usize {
        self.inner.lock().unwrap().nodes.len()
    }

    pub fn alive_count(&self) -> usize {
        self.inner.lock().unwrap().nodes.values().filter(|n| n.status == NodeStatus::Alive).count()
    }

    /// Total / free GPU counts over alive nodes.
    pub fn gpu_totals(&self) -> (usize, usize) {
        let st = self.inner.lock().unwrap();
        let mut total = 0;
        let mut free = 0;
        for n in st.nodes.values() {
            if n.status == NodeStatus::Alive {
                total += n.gpus.len();
                free += n.free_gpu_count();
            }
        }
        (total, free)
    }

    /// Fraction of alive GPUs currently allocated (cluster utilization).
    pub fn utilization(&self) -> f64 {
        let (total, free) = self.gpu_totals();
        if total == 0 {
            0.0
        } else {
            (total - free) as f64 / total as f64
        }
    }

    pub fn events(&self) -> &EventLog {
        &self.events
    }

    pub fn clock(&self) -> &SharedClock {
        &self.clock
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::clock::sim_clock;

    fn mk() -> (Cluster, crate::util::clock::SimClock) {
        let (clock, sim) = sim_clock();
        let events = EventLog::new(clock.clone()).with_echo(false);
        (Cluster::homogeneous(clock, events, 3, 4, 24.0), sim)
    }

    #[test]
    fn homogeneous_shape() {
        let (c, _) = mk();
        assert_eq!(c.node_count(), 3);
        assert_eq!(c.gpu_totals(), (12, 12));
        assert_eq!(c.utilization(), 0.0);
    }

    #[test]
    fn allocate_and_release() {
        let (c, _) = mk();
        let req = ResourceReq::gpus(2);
        let gpus = c.allocate(NodeId(0), "job-1", &req).unwrap();
        assert_eq!(gpus.len(), 2);
        assert_eq!(c.gpu_totals(), (12, 10));
        assert_eq!(c.locate("job-1"), Some(NodeId(0)));
        assert!(c.release("job-1"));
        assert_eq!(c.gpu_totals(), (12, 12));
        assert!(!c.release("job-1")); // double release is a no-op
    }

    #[test]
    fn cannot_overallocate_node() {
        let (c, _) = mk();
        assert!(c.allocate(NodeId(0), "a", &ResourceReq::gpus(4)).is_some());
        assert!(c.allocate(NodeId(0), "b", &ResourceReq::gpus(1)).is_none());
        // Other nodes unaffected.
        assert!(c.allocate(NodeId(1), "b", &ResourceReq::gpus(1)).is_some());
    }

    #[test]
    fn double_allocation_rejected() {
        let (c, _) = mk();
        assert!(c.allocate(NodeId(0), "a", &ResourceReq::gpus(1)).is_some());
        assert!(c.allocate(NodeId(1), "a", &ResourceReq::gpus(1)).is_none());
    }

    #[test]
    fn heartbeat_timeout_reaps_and_orphans() {
        let (c, sim) = mk();
        c.allocate(NodeId(1), "job-x", &ResourceReq::gpus(2)).unwrap();
        sim.advance(HEARTBEAT_TIMEOUT_MS + 1);
        // Nodes 0 and 2 heartbeat in time; node 1 does not.
        c.heartbeat(NodeId(0));
        c.heartbeat(NodeId(2));
        let orphans = c.reap_dead();
        assert_eq!(orphans, vec!["job-x".to_string()]);
        assert_eq!(c.alive_count(), 2);
        // Orphaned job no longer located anywhere.
        assert_eq!(c.locate("job-x"), None);
    }

    #[test]
    fn kill_and_revive() {
        let (c, _) = mk();
        c.allocate(NodeId(2), "j", &ResourceReq::gpus(1)).unwrap();
        let orphans = c.kill_node(NodeId(2));
        assert_eq!(orphans.len(), 1);
        assert_eq!(c.alive_count(), 2);
        c.revive_node(NodeId(2));
        assert_eq!(c.alive_count(), 3);
        // Revived node comes back empty.
        let view = &c.snapshot()[2];
        assert_eq!(view.free_gpus, 4);
    }

    #[test]
    fn snapshot_fits() {
        let (c, _) = mk();
        c.allocate(NodeId(0), "a", &ResourceReq::gpus(3)).unwrap();
        let snap = c.snapshot();
        assert!(!snap[0].fits(&ResourceReq::gpus(2)));
        assert!(snap[0].fits(&ResourceReq::gpus(1)));
        assert!(snap[1].fits(&ResourceReq::gpus(4)));
    }
}
