//! Failure injection plans for the §4.2 instability experiments
//! ("sometimes the system has no response and has been recovered after a
//! few minutes") — deterministic node-flap schedules driven by a seed.

use super::{Cluster, NodeId};
use crate::util::clock::Millis;
use crate::util::rng::Rng;

/// One scheduled node outage.
#[derive(Debug, Clone, PartialEq)]
pub struct Outage {
    pub node: NodeId,
    pub start_ms: Millis,
    pub duration_ms: Millis,
}

/// A reproducible schedule of node outages over a horizon.
#[derive(Debug, Clone, Default)]
pub struct FailurePlan {
    pub outages: Vec<Outage>,
    applied_down: Vec<bool>,
    applied_up: Vec<bool>,
}

impl FailurePlan {
    /// Random plan: each node independently flaps with `rate` outages per
    /// minute of simulated time, each lasting `mean_outage_ms` on average.
    pub fn random(
        seed: u64,
        nodes: usize,
        horizon_ms: Millis,
        rate_per_min: f64,
        mean_outage_ms: f64,
    ) -> FailurePlan {
        let mut rng = Rng::new(seed);
        let mut outages = Vec::new();
        for node in 0..nodes {
            let mut t = 0.0f64;
            loop {
                // Poisson arrivals.
                t += rng.exponential(60_000.0 / rate_per_min.max(1e-9));
                if t >= horizon_ms as f64 {
                    break;
                }
                let dur = rng.exponential(mean_outage_ms).max(100.0);
                outages.push(Outage {
                    node: NodeId(node as u32),
                    start_ms: t as Millis,
                    duration_ms: dur as Millis,
                });
            }
        }
        outages.sort_by_key(|o| o.start_ms);
        let n = outages.len();
        FailurePlan { outages, applied_down: vec![false; n], applied_up: vec![false; n] }
    }

    /// Explicit plan from a list of outages.
    pub fn fixed(outages: Vec<Outage>) -> FailurePlan {
        let n = outages.len();
        FailurePlan { outages, applied_down: vec![false; n], applied_up: vec![false; n] }
    }

    /// Apply due outage transitions at the current virtual time; returns
    /// job ids orphaned by kills in this step.
    pub fn step(&mut self, cluster: &Cluster) -> Vec<String> {
        let now = cluster.clock().now_ms();
        let mut orphans = Vec::new();
        for (i, o) in self.outages.iter().enumerate() {
            if !self.applied_down[i] && now >= o.start_ms {
                orphans.extend(cluster.kill_node(o.node));
                self.applied_down[i] = true;
            }
            if self.applied_down[i] && !self.applied_up[i] && now >= o.start_ms + o.duration_ms {
                cluster.revive_node(o.node);
                self.applied_up[i] = true;
            }
        }
        orphans
    }

    pub fn done(&self) -> bool {
        self.applied_up.iter().all(|&b| b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ResourceReq;
    use crate::events::EventLog;
    use crate::util::clock::sim_clock;

    #[test]
    fn random_plan_reproducible() {
        let a = FailurePlan::random(7, 5, 60_000, 2.0, 3_000.0);
        let b = FailurePlan::random(7, 5, 60_000, 2.0, 3_000.0);
        assert_eq!(a.outages, b.outages);
        assert!(!a.outages.is_empty());
        assert!(a.outages.windows(2).all(|w| w[0].start_ms <= w[1].start_ms));
    }

    #[test]
    fn fixed_plan_kills_and_revives() {
        let (clock, sim) = sim_clock();
        let events = EventLog::new(clock.clone()).with_echo(false);
        let cluster = Cluster::homogeneous(clock, events, 2, 2, 24.0);
        cluster.allocate(NodeId(0), "victim", &ResourceReq::gpus(1)).unwrap();

        let mut plan = FailurePlan::fixed(vec![Outage { node: NodeId(0), start_ms: 100, duration_ms: 500 }]);
        assert!(plan.step(&cluster).is_empty()); // t=0: nothing yet
        sim.advance(150);
        let orphans = plan.step(&cluster);
        assert_eq!(orphans, vec!["victim".to_string()]);
        assert_eq!(cluster.alive_count(), 1);
        sim.advance(500);
        plan.step(&cluster);
        assert_eq!(cluster.alive_count(), 2);
        assert!(plan.done());
    }
}
