//! Utilization monitoring (§3.1: "Better computational resource
//! management to improve utilization and job scheduling").
//!
//! Two time series feed the CLI, web UI and benches:
//!
//! * [`Sample`] — cluster-level utilization / free GPUs / queue depth /
//!   alive-node count, recorded by the platform drive loop.
//! * [`WorkerSample`] — per-executor-worker busy-time, live sessions,
//!   pending-queue depth and steal count, recorded after every
//!   fork-join step round from
//!   [`ExecutorPool::stats`](crate::executor::ExecutorPool::stats).
//!
//! Together they are the ops view a platform team actually watches:
//! the first shows *whether* the cluster is loaded, the second shows
//! whether the executor spread that load evenly (and how much the
//! work-stealer had to intervene).

use super::Cluster;
use crate::util::clock::Millis;
use crate::util::plot::Series;
use std::sync::{Arc, Mutex};

/// One utilization sample.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Sample {
    pub at_ms: Millis,
    pub utilization: f64,
    pub free_gpus: usize,
    pub alive_nodes: usize,
    pub queue_depth: usize,
}

/// Retention cap for the per-worker series: old samples age out FIFO
/// so a long-lived drive loop cannot grow the monitor without bound.
const MAX_WORKER_SAMPLES: usize = 4096;

/// One per-executor-worker sample (recorded each drive round).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WorkerSample {
    pub at_ms: Millis,
    /// Worker index within the executor pool.
    pub worker: usize,
    /// Cumulative wall-clock busy time (message execution) so far.
    pub busy_ms: f64,
    /// Live sessions owned by the worker at sample time.
    pub live_sessions: usize,
    /// Pending-deque depth at sample time.
    pub queue_depth: usize,
    /// Cumulative sessions stolen from peers so far.
    pub steals: u64,
}

/// Rolling utilization history.
#[derive(Clone, Default)]
pub struct UtilizationMonitor {
    samples: Arc<Mutex<Vec<Sample>>>,
    worker_samples: Arc<Mutex<Vec<WorkerSample>>>,
}

impl UtilizationMonitor {
    pub fn new() -> UtilizationMonitor {
        UtilizationMonitor::default()
    }

    /// Record the cluster's current state (direct-read convenience for
    /// tests; the platform populates the monitor through
    /// [`record_sample`](Self::record_sample) off the event bus).
    pub fn sample(&self, cluster: &Cluster, queue_depth: usize) {
        let (_, free) = cluster.gpu_totals();
        self.record_sample(Sample {
            at_ms: cluster.clock().now_ms(),
            utilization: cluster.utilization(),
            free_gpus: free,
            alive_nodes: cluster.alive_count(),
            queue_depth,
        });
    }

    /// Record a pre-built cluster sample (the bus-consumer path: the
    /// drive loop publishes `UtilizationSampled` events and the
    /// platform's consumer subscription materializes them here).
    pub fn record_sample(&self, s: Sample) {
        self.samples.lock().unwrap().push(s);
    }

    pub fn len(&self) -> usize {
        self.samples.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn all(&self) -> Vec<Sample> {
        self.samples.lock().unwrap().clone()
    }

    /// Mean utilization across the window.
    pub fn mean_utilization(&self) -> f64 {
        let s = self.samples.lock().unwrap();
        if s.is_empty() {
            return 0.0;
        }
        s.iter().map(|x| x.utilization).sum::<f64>() / s.len() as f64
    }

    /// Peak queue depth (the §2 "waiting for GPUs" pain, quantified).
    pub fn peak_queue_depth(&self) -> usize {
        self.samples.lock().unwrap().iter().map(|s| s.queue_depth).max().unwrap_or(0)
    }

    /// Fraction of samples with at least one job waiting while GPUs were
    /// free — scheduling inefficiency (fragmentation or policy misses).
    pub fn starvation_fraction(&self) -> f64 {
        let s = self.samples.lock().unwrap();
        if s.is_empty() {
            return 0.0;
        }
        let starved = s.iter().filter(|x| x.queue_depth > 0 && x.free_gpus > 0).count();
        starved as f64 / s.len() as f64
    }

    /// Utilization time series for the plot renderers.
    pub fn utilization_series(&self) -> Series {
        Series::new(
            "utilization",
            self.all().iter().map(|s| (s.at_ms as f64, s.utilization)).collect(),
        )
    }

    pub fn queue_series(&self) -> Series {
        Series::new(
            "queue_depth",
            self.all().iter().map(|s| (s.at_ms as f64, s.queue_depth as f64)).collect(),
        )
    }

    // -- per-worker executor series -----------------------------------

    /// Append one round's per-worker samples (one entry per worker).
    /// Retention is capped at [`MAX_WORKER_SAMPLES`]; the oldest
    /// samples age out first.
    pub fn record_workers(&self, samples: Vec<WorkerSample>) {
        let mut w = self.worker_samples.lock().unwrap();
        w.extend(samples);
        if w.len() > MAX_WORKER_SAMPLES {
            let excess = w.len() - MAX_WORKER_SAMPLES;
            w.drain(..excess);
        }
    }

    /// Record a single worker sample (the bus-consumer path, one
    /// `WorkerSampled` event at a time). Same capped retention.
    pub fn record_worker(&self, s: WorkerSample) {
        self.record_workers(vec![s]);
    }

    /// Full per-worker sample history, in recording order.
    pub fn worker_history(&self) -> Vec<WorkerSample> {
        self.worker_samples.lock().unwrap().clone()
    }

    /// The most recent sample of each worker (the live per-worker view
    /// `nsml cluster` renders).
    pub fn latest_workers(&self) -> Vec<WorkerSample> {
        let mut latest: std::collections::BTreeMap<usize, WorkerSample> =
            std::collections::BTreeMap::new();
        for s in self.worker_samples.lock().unwrap().iter() {
            latest.insert(s.worker, *s);
        }
        latest.into_values().collect()
    }

    /// Total sessions stolen across workers, per the latest samples.
    pub fn total_steals(&self) -> u64 {
        self.latest_workers().iter().map(|s| s.steals).sum()
    }

    /// One worker's busy-time series for the plot renderers.
    pub fn worker_busy_series(&self, worker: usize) -> Series {
        Series::new(
            &format!("w{} busy_ms", worker),
            self.worker_samples
                .lock()
                .unwrap()
                .iter()
                .filter(|s| s.worker == worker)
                .map(|s| (s.at_ms as f64, s.busy_ms))
                .collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{NodeId, ResourceReq};
    use crate::events::EventLog;
    use crate::util::clock::sim_clock;

    fn cluster() -> (Cluster, crate::util::clock::SimClock) {
        let (clock, sim) = sim_clock();
        let events = EventLog::new(clock.clone()).with_echo(false);
        (Cluster::homogeneous(clock, events, 2, 4, 24.0), sim)
    }

    #[test]
    fn samples_track_cluster_state() {
        let (c, sim) = cluster();
        let mon = UtilizationMonitor::new();
        mon.sample(&c, 0);
        c.allocate(NodeId(0), "j", &ResourceReq::gpus(4)).unwrap();
        sim.advance(100);
        mon.sample(&c, 2);
        let all = mon.all();
        assert_eq!(all.len(), 2);
        assert_eq!(all[0].utilization, 0.0);
        assert_eq!(all[1].utilization, 0.5);
        assert_eq!(all[1].at_ms, 100);
        assert_eq!(all[1].queue_depth, 2);
        assert!((mon.mean_utilization() - 0.25).abs() < 1e-9);
        assert_eq!(mon.peak_queue_depth(), 2);
    }

    #[test]
    fn starvation_detected() {
        let (c, _) = cluster();
        let mon = UtilizationMonitor::new();
        // Queue non-empty while 8 GPUs free: starvation sample.
        mon.sample(&c, 3);
        c.allocate(NodeId(0), "a", &ResourceReq::gpus(4)).unwrap();
        c.allocate(NodeId(1), "b", &ResourceReq::gpus(4)).unwrap();
        // Queue non-empty, zero free: not starvation (genuinely full).
        mon.sample(&c, 3);
        assert!((mon.starvation_fraction() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn series_render() {
        let (c, sim) = cluster();
        let mon = UtilizationMonitor::new();
        for i in 0..5 {
            mon.sample(&c, i);
            sim.advance(10);
        }
        assert_eq!(mon.utilization_series().points.len(), 5);
        assert_eq!(mon.queue_series().points[4], (40.0, 4.0));
        let chart = crate::util::plot::ascii_chart("util", &[mon.queue_series()], 30, 8);
        assert!(chart.contains('*'));
    }

    #[test]
    fn empty_monitor_safe() {
        let mon = UtilizationMonitor::new();
        assert!(mon.is_empty());
        assert_eq!(mon.mean_utilization(), 0.0);
        assert_eq!(mon.starvation_fraction(), 0.0);
        assert_eq!(mon.peak_queue_depth(), 0);
        assert!(mon.latest_workers().is_empty());
        assert_eq!(mon.total_steals(), 0);
    }

    #[test]
    fn worker_samples_keep_latest_per_worker() {
        let mon = UtilizationMonitor::new();
        let s = |at_ms, worker, busy_ms, live, depth, steals| WorkerSample {
            at_ms,
            worker,
            busy_ms,
            live_sessions: live,
            queue_depth: depth,
            steals,
        };
        mon.record_workers(vec![s(10, 0, 1.0, 2, 1, 0), s(10, 1, 0.5, 1, 0, 1)]);
        mon.record_workers(vec![s(20, 0, 3.0, 1, 0, 0), s(20, 1, 2.5, 2, 0, 3)]);
        assert_eq!(mon.worker_history().len(), 4);
        let latest = mon.latest_workers();
        assert_eq!(latest.len(), 2);
        assert_eq!(latest[0].busy_ms, 3.0);
        assert_eq!(latest[1].steals, 3);
        assert_eq!(mon.total_steals(), 3);
        // Per-worker busy series grows monotonically over time.
        let series = mon.worker_busy_series(1);
        assert_eq!(series.points, vec![(10.0, 0.5), (20.0, 2.5)]);
    }

    #[test]
    fn worker_series_retention_is_capped() {
        let mon = UtilizationMonitor::new();
        for i in 0..(MAX_WORKER_SAMPLES + 10) {
            mon.record_workers(vec![WorkerSample {
                at_ms: i as u64,
                worker: 0,
                busy_ms: 0.0,
                live_sessions: 0,
                queue_depth: 0,
                steals: 0,
            }]);
        }
        let h = mon.worker_history();
        assert_eq!(h.len(), MAX_WORKER_SAMPLES);
        // Oldest samples aged out first.
        assert_eq!(h[0].at_ms, 10);
    }
}
