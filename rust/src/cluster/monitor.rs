//! Utilization monitoring (§3.1: "Better computational resource
//! management to improve utilization and job scheduling").
//!
//! Samples cluster utilization / queue depth / alive-node count over
//! (virtual) time into a time series the CLI, web UI and benches can
//! render — the ops view a platform team actually watches.

use super::Cluster;
use crate::util::clock::Millis;
use crate::util::plot::Series;
use std::sync::{Arc, Mutex};

/// One utilization sample.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Sample {
    pub at_ms: Millis,
    pub utilization: f64,
    pub free_gpus: usize,
    pub alive_nodes: usize,
    pub queue_depth: usize,
}

/// Rolling utilization history.
#[derive(Clone, Default)]
pub struct UtilizationMonitor {
    samples: Arc<Mutex<Vec<Sample>>>,
}

impl UtilizationMonitor {
    pub fn new() -> UtilizationMonitor {
        UtilizationMonitor::default()
    }

    /// Record the cluster's current state (call from the platform loop).
    pub fn sample(&self, cluster: &Cluster, queue_depth: usize) {
        let (_, free) = cluster.gpu_totals();
        let s = Sample {
            at_ms: cluster.clock().now_ms(),
            utilization: cluster.utilization(),
            free_gpus: free,
            alive_nodes: cluster.alive_count(),
            queue_depth,
        };
        self.samples.lock().unwrap().push(s);
    }

    pub fn len(&self) -> usize {
        self.samples.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn all(&self) -> Vec<Sample> {
        self.samples.lock().unwrap().clone()
    }

    /// Mean utilization across the window.
    pub fn mean_utilization(&self) -> f64 {
        let s = self.samples.lock().unwrap();
        if s.is_empty() {
            return 0.0;
        }
        s.iter().map(|x| x.utilization).sum::<f64>() / s.len() as f64
    }

    /// Peak queue depth (the §2 "waiting for GPUs" pain, quantified).
    pub fn peak_queue_depth(&self) -> usize {
        self.samples.lock().unwrap().iter().map(|s| s.queue_depth).max().unwrap_or(0)
    }

    /// Fraction of samples with at least one job waiting while GPUs were
    /// free — scheduling inefficiency (fragmentation or policy misses).
    pub fn starvation_fraction(&self) -> f64 {
        let s = self.samples.lock().unwrap();
        if s.is_empty() {
            return 0.0;
        }
        let starved = s.iter().filter(|x| x.queue_depth > 0 && x.free_gpus > 0).count();
        starved as f64 / s.len() as f64
    }

    /// Utilization time series for the plot renderers.
    pub fn utilization_series(&self) -> Series {
        Series::new(
            "utilization",
            self.all().iter().map(|s| (s.at_ms as f64, s.utilization)).collect(),
        )
    }

    pub fn queue_series(&self) -> Series {
        Series::new(
            "queue_depth",
            self.all().iter().map(|s| (s.at_ms as f64, s.queue_depth as f64)).collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{NodeId, ResourceReq};
    use crate::events::EventLog;
    use crate::util::clock::sim_clock;

    fn cluster() -> (Cluster, crate::util::clock::SimClock) {
        let (clock, sim) = sim_clock();
        let events = EventLog::new(clock.clone()).with_echo(false);
        (Cluster::homogeneous(clock, events, 2, 4, 24.0), sim)
    }

    #[test]
    fn samples_track_cluster_state() {
        let (c, sim) = cluster();
        let mon = UtilizationMonitor::new();
        mon.sample(&c, 0);
        c.allocate(NodeId(0), "j", &ResourceReq::gpus(4)).unwrap();
        sim.advance(100);
        mon.sample(&c, 2);
        let all = mon.all();
        assert_eq!(all.len(), 2);
        assert_eq!(all[0].utilization, 0.0);
        assert_eq!(all[1].utilization, 0.5);
        assert_eq!(all[1].at_ms, 100);
        assert_eq!(all[1].queue_depth, 2);
        assert!((mon.mean_utilization() - 0.25).abs() < 1e-9);
        assert_eq!(mon.peak_queue_depth(), 2);
    }

    #[test]
    fn starvation_detected() {
        let (c, _) = cluster();
        let mon = UtilizationMonitor::new();
        // Queue non-empty while 8 GPUs free: starvation sample.
        mon.sample(&c, 3);
        c.allocate(NodeId(0), "a", &ResourceReq::gpus(4)).unwrap();
        c.allocate(NodeId(1), "b", &ResourceReq::gpus(4)).unwrap();
        // Queue non-empty, zero free: not starvation (genuinely full).
        mon.sample(&c, 3);
        assert!((mon.starvation_fraction() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn series_render() {
        let (c, sim) = cluster();
        let mon = UtilizationMonitor::new();
        for i in 0..5 {
            mon.sample(&c, i);
            sim.advance(10);
        }
        assert_eq!(mon.utilization_series().points.len(), 5);
        assert_eq!(mon.queue_series().points[4], (40.0, 4.0));
        let chart = crate::util::plot::ascii_chart("util", &[mon.queue_series()], 30, 8);
        assert!(chart.contains('*'));
    }

    #[test]
    fn empty_monitor_safe() {
        let mon = UtilizationMonitor::new();
        assert!(mon.is_empty());
        assert_eq!(mon.mean_utilization(), 0.0);
        assert_eq!(mon.starvation_fraction(), 0.0);
        assert_eq!(mon.peak_queue_depth(), 0);
    }
}
