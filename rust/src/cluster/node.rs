//! Node-local state: GPUs, CPU/memory capacity, per-GPU allocation.

use crate::util::clock::Millis;
use std::collections::BTreeMap;

/// Opaque node identifier assigned by the cluster.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct NodeId(pub u32);

impl std::fmt::Display for NodeId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "node-{:02}", self.0)
    }
}

/// Liveness as seen by the master.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NodeStatus {
    Alive,
    Dead,
}

/// One physical accelerator.
#[derive(Debug, Clone)]
pub struct GpuDevice {
    pub index: usize,
    pub model: String,
    pub mem_gb: f64,
    /// Job currently pinned to this device, if any.
    pub owner: Option<String>,
}

/// A resource request for one job (paper: jobs ask for k GPUs and must
/// land on a single server — the ResNet-152 8-GPU anecdote in §2).
#[derive(Debug, Clone, PartialEq)]
pub struct ResourceReq {
    pub gpus: usize,
    pub cpus: u32,
    pub mem_gb: f64,
}

impl ResourceReq {
    /// GPUs only, with proportional default CPU/memory.
    pub fn gpus(n: usize) -> ResourceReq {
        ResourceReq { gpus: n, cpus: (2 * n.max(1)) as u32, mem_gb: 8.0 * n.max(1) as f64 }
    }

    pub fn cpu_only() -> ResourceReq {
        ResourceReq { gpus: 0, cpus: 2, mem_gb: 4.0 }
    }
}

/// A cluster host with its devices and bookkeeping.
#[derive(Debug, Clone)]
pub struct Node {
    pub id: NodeId,
    pub hostname: String,
    pub gpus: Vec<GpuDevice>,
    pub total_cpus: u32,
    pub total_mem_gb: f64,
    pub status: NodeStatus,
    pub last_heartbeat_ms: Millis,
    /// job -> (cpus, mem) reserved beyond GPUs.
    reservations: BTreeMap<String, (u32, f64)>,
}

impl Node {
    pub fn new(hostname: &str, gpus: usize, gpu_mem_gb: f64, cpus: u32, mem_gb: f64) -> Node {
        Node {
            id: NodeId(u32::MAX),
            hostname: hostname.to_string(),
            gpus: (0..gpus)
                .map(|i| GpuDevice { index: i, model: "P40".to_string(), mem_gb: gpu_mem_gb, owner: None })
                .collect(),
            total_cpus: cpus,
            total_mem_gb: mem_gb,
            status: NodeStatus::Alive,
            last_heartbeat_ms: 0,
            reservations: BTreeMap::new(),
        }
    }

    pub fn free_gpu_count(&self) -> usize {
        self.gpus.iter().filter(|g| g.owner.is_none()).count()
    }

    pub fn used_cpus(&self) -> u32 {
        self.reservations.values().map(|(c, _)| *c).sum()
    }

    pub fn used_mem_gb(&self) -> f64 {
        self.reservations.values().map(|(_, m)| *m).sum()
    }

    /// Allocate GPUs + CPU/memory for a job if everything fits.
    pub fn try_allocate(&mut self, job: &str, req: &ResourceReq) -> Option<Vec<usize>> {
        if self.status != NodeStatus::Alive {
            return None;
        }
        if self.free_gpu_count() < req.gpus
            || self.total_cpus - self.used_cpus() < req.cpus
            || self.total_mem_gb - self.used_mem_gb() < req.mem_gb
        {
            return None;
        }
        let mut taken = Vec::with_capacity(req.gpus);
        for g in self.gpus.iter_mut() {
            if taken.len() == req.gpus {
                break;
            }
            if g.owner.is_none() {
                g.owner = Some(job.to_string());
                taken.push(g.index);
            }
        }
        self.reservations.insert(job.to_string(), (req.cpus, req.mem_gb));
        Some(taken)
    }

    /// Free everything owned by `job`.
    pub fn release_job(&mut self, job: &str) {
        for g in self.gpus.iter_mut() {
            if g.owner.as_deref() == Some(job) {
                g.owner = None;
            }
        }
        self.reservations.remove(job);
    }

    /// Jobs with any reservation here.
    pub fn jobs(&self) -> Vec<String> {
        self.reservations.keys().cloned().collect()
    }

    pub fn view(&self) -> super::NodeView {
        super::NodeView {
            id: self.id,
            hostname: self.hostname.clone(),
            total_gpus: self.gpus.len(),
            free_gpus: self.free_gpu_count(),
            total_cpus: self.total_cpus,
            free_cpus: self.total_cpus - self.used_cpus(),
            total_mem_gb: self.total_mem_gb,
            free_mem_gb: self.total_mem_gb - self.used_mem_gb(),
            alive: self.status == NodeStatus::Alive,
            last_heartbeat_ms: self.last_heartbeat_ms,
            jobs: self.jobs(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allocation_tracks_devices() {
        let mut n = Node::new("h", 4, 24.0, 16, 64.0);
        let got = n.try_allocate("j1", &ResourceReq::gpus(2)).unwrap();
        assert_eq!(got, vec![0, 1]);
        assert_eq!(n.free_gpu_count(), 2);
        let got2 = n.try_allocate("j2", &ResourceReq::gpus(2)).unwrap();
        assert_eq!(got2, vec![2, 3]);
        assert!(n.try_allocate("j3", &ResourceReq::gpus(1)).is_none());
        n.release_job("j1");
        assert_eq!(n.free_gpu_count(), 2);
        // Released devices are reusable.
        let got3 = n.try_allocate("j3", &ResourceReq::gpus(2)).unwrap();
        assert_eq!(got3, vec![0, 1]);
    }

    #[test]
    fn cpu_memory_limits_enforced() {
        let mut n = Node::new("h", 8, 24.0, 4, 16.0);
        // gpus(2) asks 4 cpus, 16 GB: fits exactly.
        assert!(n.try_allocate("a", &ResourceReq::gpus(2)).is_some());
        // Nothing left for even a cpu-only job.
        assert!(n.try_allocate("b", &ResourceReq::cpu_only()).is_none());
        n.release_job("a");
        assert!(n.try_allocate("b", &ResourceReq::cpu_only()).is_some());
    }

    #[test]
    fn cpu_only_jobs_take_no_gpu() {
        let mut n = Node::new("h", 2, 24.0, 16, 64.0);
        let got = n.try_allocate("cpu-job", &ResourceReq::cpu_only()).unwrap();
        assert!(got.is_empty());
        assert_eq!(n.free_gpu_count(), 2);
        assert_eq!(n.jobs(), vec!["cpu-job".to_string()]);
    }

    #[test]
    fn view_reflects_state() {
        let mut n = Node::new("h", 4, 24.0, 16, 64.0);
        n.id = NodeId(3);
        n.try_allocate("x", &ResourceReq::gpus(1)).unwrap();
        let v = n.view();
        assert_eq!(v.free_gpus, 3);
        assert_eq!(v.jobs, vec!["x".to_string()]);
        assert_eq!(format!("{}", v.id), "node-03");
    }
}
