//! Web UI (paper §3.2): "The *web UI* wraps NSML-CLI in a web application
//! and is more intuitive … provides visualizations such as graphs, logs,
//! and demos."
//!
//! nginx is unavailable offline, so this is a from-scratch minimal
//! HTTP/1.1 server (std TcpListener + a thread per connection) exposing:
//!
//! * `GET /`                     — HTML dashboard (sessions, cluster, boards)
//! * `GET /board/<dataset>`      — HTML leaderboard
//! * `GET /session/<id…>`        — HTML session page with SVG curves
//! * `GET /plot/<id…>.svg`       — standalone SVG learning curves
//! * `GET /api/sessions`         — JSON
//! * `GET /api/session/<id…>`    — JSON (with metrics)
//! * `GET /api/board/<dataset>`  — JSON
//! * `GET /api/cluster`          — JSON
//! * `GET /api/v1/executor`      — JSON executor-pool telemetry
//!   (per-worker busy-time, live sessions, queue depth, steal counts)
//!   dispatched as an `executor_status` query through the attached
//!   service
//! * `GET /api/v1/tenants`       — JSON per-user fair-share report
//!   (quotas, GPU-second usage, occupancy, admission-queue depth)
//!   dispatched as a `tenant_report` query
//! * `GET /api/v1/durability`    — JSON WAL/snapshot/GC counters
//!   (records and bytes in the live segment, snapshot cadence
//!   progress, subscription drop counts, last GC sweep) dispatched
//!   as a `durability_status` query
//! * `GET /api/v1/board?dataset=<ds>&user=<u>&limit=<n>` — leaderboard
//!   rows, optionally sliced to one user (global ranks kept),
//!   dispatched as a `board` query
//! * `GET /api/v1/events?since=<cursor>&kind=<name>&subject=<id>&limit=<n>`
//!   — cursor-paged incremental read of the platform event bus
//!   (dispatched as an `events_since` query). The reply carries the
//!   matching events, the `next` cursor to resume from, and a
//!   `dropped` count when the reader fell a full ring behind; polling
//!   with the returned cursor streams new events without ever
//!   re-reading old ones.
//! * `POST /api/v1/<verb>`       — dispatch any `ApiRequest` verb (`run`,
//!   `pause`, `resume`, `stop`, `infer`, `drive`, `run_to_completion`,
//!   `kill_node`, `list_sessions`, `get_session`, `board`,
//!   `cluster_status`, `executor_status`, `events_since`,
//!   `submit_trial_batch`, `tenant_report`, `set_quota`,
//!   `durability_status`) into the attached
//!   [`PlatformService`](crate::api::PlatformService); the JSON body is
//!   the verb's `args` object and the reply is an `ApiResponse`
//!   envelope. Error codes map to HTTP: `not_found`→404,
//!   `invalid_argument`→400, `failed_precondition`→409, `internal`→500.
//!
//! Path segments are percent-decoded before routing; unsupported methods
//! get `405` with an `Allow` header. Routing logic is a pure function
//! ([`handle`]) so tests exercise it without sockets.
//!
//! Mutations dispatched here land on the platform thread, which drives
//! training through the [`crate::executor`] worker pool — a web `drive`
//! request therefore advances every running session in parallel across
//! the pool's workers before its reply comes back.

use crate::api::{ApiError, ApiRequest, ApiResponse, ErrorCode, ServiceHandle};
use crate::cluster::Cluster;
use crate::events::EventLog;
use crate::leaderboard::Leaderboard;
use crate::session::{SessionRecord, SessionStore};
use crate::util::json::Json;
use crate::util::plot::{svg_chart, xml_escape, Series};
use std::io::{Read, Write};
use std::net::TcpListener;

/// Shareable snapshot handles the server reads from (all thread-safe),
/// plus the optional dispatcher for `POST /api/v1/*` mutations.
#[derive(Clone)]
pub struct WebState {
    pub sessions: SessionStore,
    pub leaderboard: Leaderboard,
    pub cluster: Option<Cluster>,
    pub events: EventLog,
    /// When attached, POST verbs dispatch into the platform service on
    /// its owning thread; when `None`, mutations answer 503.
    pub api: Option<ServiceHandle>,
}

/// An HTTP response.
pub struct Response {
    pub status: u16,
    pub content_type: &'static str,
    pub body: String,
    /// `Allow` header value for 405 responses.
    pub allow: Option<&'static str>,
}

impl Response {
    fn html(body: String) -> Response {
        Response { status: 200, content_type: "text/html; charset=utf-8", body, allow: None }
    }

    fn json(j: Json) -> Response {
        Response { status: 200, content_type: "application/json", body: j.to_string(), allow: None }
    }

    fn svg(body: String) -> Response {
        Response { status: 200, content_type: "image/svg+xml", body, allow: None }
    }

    fn not_found(msg: &str) -> Response {
        Response { status: 404, content_type: "text/plain", body: format!("not found: {}\n", msg), allow: None }
    }

    fn method_not_allowed(allow: &'static str) -> Response {
        Response {
            status: 405,
            content_type: "text/plain",
            body: format!("method not allowed (allow: {})\n", allow),
            allow: Some(allow),
        }
    }
}

fn status_text(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        409 => "Conflict",
        411 => "Length Required",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Unknown",
    }
}

/// Decode `%XX` escapes in a path (invalid escapes pass through as-is).
fn percent_decode(s: &str) -> String {
    let bytes = s.as_bytes();
    let mut out = Vec::with_capacity(bytes.len());
    let mut i = 0;
    while i < bytes.len() {
        if bytes[i] == b'%' && i + 2 < bytes.len() {
            let hex = |b: u8| (b as char).to_digit(16);
            if let (Some(hi), Some(lo)) = (hex(bytes[i + 1]), hex(bytes[i + 2])) {
                out.push((hi * 16 + lo) as u8);
                i += 3;
                continue;
            }
        }
        out.push(bytes[i]);
        i += 1;
    }
    String::from_utf8_lossy(&out).into_owned()
}

/// Route a request (pure; no I/O). `body` is the request body (only
/// meaningful for POST).
pub fn handle(state: &WebState, method: &str, path: &str, body: &str) -> Response {
    let (route, query) = match path.split_once('?') {
        Some((r, q)) => (r, q),
        None => (path, ""),
    };
    let path = percent_decode(route);
    match method {
        "GET" => handle_get(state, &path, query),
        "POST" => match path.strip_prefix("/api/v1/") {
            Some(verb) => handle_api_post(state, verb, body),
            None => Response::method_not_allowed("GET"),
        },
        _ => {
            if path.starts_with("/api/v1/") {
                Response::method_not_allowed("POST")
            } else {
                Response::method_not_allowed("GET, POST")
            }
        }
    }
}

/// The v1 dispatch surface: `POST /api/v1/<verb>` with the args object
/// as body (empty body = `{}`); the web UI thus *wraps* the CLI verbs.
fn handle_api_post(state: &WebState, verb: &str, body: &str) -> Response {
    let Some(api) = &state.api else {
        return service_unavailable();
    };
    let resp = if body.trim().is_empty() {
        match ApiRequest::from_verb_args(verb, &Json::obj()) {
            Ok(req) => api.call(req),
            Err(error) => ApiResponse::Error { error },
        }
    } else {
        match crate::util::json::parse(body) {
            Err(e) => ApiResponse::Error { error: ApiError::invalid(format!("request body: {}", e)) },
            Ok(args) => match ApiRequest::from_verb_args(verb, &args) {
                Ok(req) => api.call(req),
                Err(error) => ApiResponse::Error { error },
            },
        }
    };
    api_response(resp)
}

fn service_unavailable() -> Response {
    Response {
        status: 503,
        content_type: "text/plain",
        body: "platform service not attached (read-only web ui)\n".into(),
        allow: None,
    }
}

/// Serialize an `ApiResponse` envelope with its HTTP status mapping.
fn api_response(resp: ApiResponse) -> Response {
    let status = match &resp {
        ApiResponse::Error { error } => match error.code {
            ErrorCode::NotFound => 404,
            ErrorCode::InvalidArgument => 400,
            ErrorCode::FailedPrecondition => 409,
            ErrorCode::Internal => 500,
        },
        _ => 200,
    };
    Response { status, content_type: "application/json", body: resp.to_json().to_string(), allow: None }
}

/// `GET /api/v1/executor`: the executor-status query as a read route,
/// so dashboards can poll per-worker load without a POST body.
fn executor_json(state: &WebState) -> Response {
    let Some(api) = &state.api else {
        return service_unavailable();
    };
    api_response(api.call(ApiRequest::ExecutorStatus))
}

/// `GET /api/v1/tenants`: the per-user fair-share report (quotas,
/// GPU-second usage, admission-queue depth) as a read route.
fn tenants_json(state: &WebState) -> Response {
    let Some(api) = &state.api else {
        return service_unavailable();
    };
    api_response(api.call(ApiRequest::TenantReport))
}

/// `GET /api/v1/durability`: the WAL/snapshot/GC counters as a read
/// route, so dashboards can poll crash-safety health without a POST
/// body.
fn durability_json(state: &WebState) -> Response {
    let Some(api) = &state.api else {
        return service_unavailable();
    };
    api_response(api.call(ApiRequest::DurabilityStatus))
}

/// `GET /api/v1/board?dataset=&user=&limit=`: the leaderboard query as
/// a read route — `user=` slices to one tenant's rows while keeping
/// their global ranks. The query string becomes a `board` dispatch, so
/// the wire layer validates the arguments.
fn board_query_json(state: &WebState, query: &str) -> Response {
    let Some(api) = &state.api else {
        return service_unavailable();
    };
    let mut args = Json::obj();
    for (k, v) in parse_query(query) {
        match k.as_str() {
            "limit" => match v.parse::<u64>() {
                Ok(n) => {
                    args.set(&k, n.into());
                }
                Err(_) => {
                    return api_response(ApiResponse::Error {
                        error: ApiError::invalid(
                            "board: query parameter 'limit' must be a non-negative integer",
                        ),
                    })
                }
            },
            "dataset" | "user" => {
                args.set(&k, v.as_str().into());
            }
            _ => {} // unknown parameters are ignored
        }
    }
    match ApiRequest::from_verb_args("board", &args) {
        Ok(req) => api_response(api.call(req)),
        Err(error) => api_response(ApiResponse::Error { error }),
    }
}

/// Decoded `key=value` pairs of a query string.
fn parse_query(query: &str) -> Vec<(String, String)> {
    query
        .split('&')
        .filter(|p| !p.is_empty())
        .map(|p| {
            let (k, v) = p.split_once('=').unwrap_or((p, ""));
            (percent_decode(k), percent_decode(v))
        })
        .collect()
}

/// `GET /api/v1/events?since=&kind=&subject=&limit=`: the event-bus
/// cursor read as a pollable route — the query string becomes an
/// `events_since` dispatch, so the wire layer validates the arguments.
fn events_json(state: &WebState, query: &str) -> Response {
    let Some(api) = &state.api else {
        return service_unavailable();
    };
    let mut args = Json::obj();
    for (k, v) in parse_query(query) {
        match k.as_str() {
            "since" | "limit" => match v.parse::<u64>() {
                Ok(n) => {
                    args.set(&k, n.into());
                }
                Err(_) => {
                    return api_response(ApiResponse::Error {
                        error: ApiError::invalid(format!(
                            "events: query parameter '{}' must be a non-negative integer",
                            k
                        )),
                    })
                }
            },
            "kind" | "subject" => {
                args.set(&k, v.as_str().into());
            }
            _ => {} // unknown parameters are ignored
        }
    }
    match ApiRequest::from_verb_args("events_since", &args) {
        Ok(req) => api_response(api.call(req)),
        Err(error) => api_response(ApiResponse::Error { error }),
    }
}

fn handle_get(state: &WebState, path: &str, query: &str) -> Response {
    if path.starts_with("/api/v1/") {
        if path == "/api/v1/executor" {
            return executor_json(state);
        }
        if path == "/api/v1/events" {
            return events_json(state, query);
        }
        if path == "/api/v1/tenants" {
            return tenants_json(state);
        }
        if path == "/api/v1/durability" {
            return durability_json(state);
        }
        if path == "/api/v1/board" {
            return board_query_json(state, query);
        }
        return Response::method_not_allowed("POST");
    }
    match path {
        "/" => Response::html(dashboard_html(state)),
        "/api/sessions" => Response::json(sessions_json(state)),
        "/api/cluster" => Response::json(cluster_json(state)),
        p if p.starts_with("/api/board/") => {
            let ds = &p["/api/board/".len()..];
            board_json(state, ds)
        }
        p if p.starts_with("/api/session/") => {
            let id = &p["/api/session/".len()..];
            match state.sessions.get(id) {
                Some(rec) => Response::json(session_json(&rec, true)),
                None => Response::not_found(id),
            }
        }
        p if p.starts_with("/plot/") && p.ends_with(".svg") => {
            let id = &p["/plot/".len()..p.len() - 4];
            match state.sessions.get(id) {
                Some(rec) => Response::svg(session_svg(&rec)),
                None => Response::not_found(id),
            }
        }
        p if p.starts_with("/board/") => {
            let ds = &p["/board/".len()..];
            Response::html(board_html(state, ds))
        }
        p if p.starts_with("/session/") => {
            let id = &p["/session/".len()..];
            match state.sessions.get(id) {
                Some(rec) => Response::html(session_html(&rec)),
                None => Response::not_found(id),
            }
        }
        other => Response::not_found(other),
    }
}

// ---------------------------------------------------------------------
// JSON views
// ---------------------------------------------------------------------

fn session_json(rec: &SessionRecord, with_metrics: bool) -> Json {
    let mut o = Json::obj();
    o.set("id", rec.spec.id.as_str().into())
        .set("user", rec.spec.user.as_str().into())
        .set("dataset", rec.spec.dataset.as_str().into())
        .set("model", rec.spec.model.as_str().into())
        .set("state", rec.state.as_str().into())
        .set("steps_done", rec.steps_done.into())
        .set("total_steps", rec.spec.total_steps.into())
        .set("lr", rec.spec.lr.into())
        .set("best_metric", rec.best_metric.map(Json::Num).unwrap_or(Json::Null))
        .set("recoveries", (rec.recoveries as u64).into());
    if with_metrics {
        let mut metrics = Json::obj();
        for name in rec.metrics.names() {
            let pts: Vec<Json> = rec
                .metrics
                .series(&name)
                .into_iter()
                .map(|(s, v)| Json::Arr(vec![s.into(), v.into()]))
                .collect();
            metrics.set(&name, Json::Arr(pts));
        }
        o.set("metrics", metrics);
    }
    o
}

fn sessions_json(state: &WebState) -> Json {
    Json::Arr(state.sessions.list().iter().map(|r| session_json(r, false)).collect())
}

fn cluster_json(state: &WebState) -> Json {
    let mut o = Json::obj();
    match &state.cluster {
        None => {
            o.set("available", false.into());
        }
        Some(c) => {
            let (total, free) = c.gpu_totals();
            let nodes: Vec<Json> = c
                .snapshot()
                .iter()
                .map(|n| {
                    let mut j = Json::obj();
                    j.set("hostname", n.hostname.as_str().into())
                        .set("alive", n.alive.into())
                        .set("total_gpus", n.total_gpus.into())
                        .set("free_gpus", n.free_gpus.into())
                        .set("jobs", Json::Arr(n.jobs.iter().map(|s| Json::Str(s.clone())).collect()));
                    j
                })
                .collect();
            o.set("available", true.into())
                .set("total_gpus", total.into())
                .set("free_gpus", free.into())
                .set("utilization", c.utilization().into())
                .set("nodes", Json::Arr(nodes));
        }
    }
    o
}

fn board_json(state: &WebState, dataset: &str) -> Response {
    if !state.leaderboard.datasets().contains(&dataset.to_string()) {
        return Response::not_found(dataset);
    }
    let rows: Vec<Json> = state
        .leaderboard
        .top(dataset, 100)
        .iter()
        .enumerate()
        .map(|(i, s)| {
            let mut o = Json::obj();
            o.set("rank", (i + 1).into())
                .set("session", s.session.as_str().into())
                .set("user", s.user.as_str().into())
                .set("model", s.model.as_str().into())
                .set("metric", s.metric_name.as_str().into())
                .set("value", s.value.into())
                .set("step", s.step.into());
            o
        })
        .collect();
    Response::json(Json::Arr(rows))
}

// ---------------------------------------------------------------------
// HTML views
// ---------------------------------------------------------------------

const STYLE: &str = "<style>body{font-family:monospace;margin:2em;background:#fafafa}
table{border-collapse:collapse}td,th{border:1px solid #ccc;padding:4px 10px;text-align:left}
th{background:#eee}h1,h2{color:#234}a{color:#1a6}</style>";

fn page(title: &str, body: String) -> String {
    format!(
        "<!doctype html><html><head><title>{}</title>{}</head><body><h1>{}</h1>{}</body></html>",
        xml_escape(title),
        STYLE,
        xml_escape(title),
        body
    )
}

fn dashboard_html(state: &WebState) -> String {
    let mut body = String::new();
    if let Some(c) = &state.cluster {
        let (total, free) = c.gpu_totals();
        body.push_str(&format!(
            "<p>cluster: {} nodes alive, {}/{} GPUs in use ({:.0}% utilization)</p>",
            c.alive_count(),
            total - free,
            total,
            c.utilization() * 100.0
        ));
    }
    body.push_str("<h2>Sessions</h2><table><tr><th>session</th><th>state</th><th>steps</th><th>best metric</th><th>plot</th></tr>");
    for r in state.sessions.list() {
        body.push_str(&format!(
            "<tr><td><a href=\"/session/{id}\">{id}</a></td><td>{}</td><td>{}/{}</td><td>{}</td><td><a href=\"/plot/{id}.svg\">svg</a></td></tr>",
            r.state.as_str(),
            r.steps_done,
            r.spec.total_steps,
            r.best_metric.map(|v| format!("{:.4}", v)).unwrap_or_else(|| "-".into()),
            id = xml_escape(&r.spec.id),
        ));
    }
    body.push_str("</table><h2>Leaderboards</h2><ul>");
    for ds in state.leaderboard.datasets() {
        body.push_str(&format!("<li><a href=\"/board/{0}\">{0}</a> ({1} entries)</li>", ds, state.leaderboard.board_len(&ds)));
    }
    body.push_str("</ul>");
    page("NSML dashboard", body)
}

fn board_html(state: &WebState, dataset: &str) -> String {
    let mut body = String::from("<table><tr><th>rank</th><th>session</th><th>user</th><th>model</th><th>value</th><th>step</th></tr>");
    for (i, s) in state.leaderboard.top(dataset, 100).iter().enumerate() {
        body.push_str(&format!(
            "<tr><td>{0}</td><td><a href=\"/session/{1}\">{1}</a></td><td>{2}</td><td>{3}</td><td>{4:.4}</td><td>{5}</td></tr>",
            i + 1,
            xml_escape(&s.session),
            xml_escape(&s.user),
            xml_escape(&s.model),
            s.value,
            s.step
        ));
    }
    body.push_str("</table><p><a href=\"/\">back</a></p>");
    page(&format!("leaderboard: {}", dataset), body)
}

fn session_svg(rec: &SessionRecord) -> String {
    let series: Vec<Series> =
        rec.metrics.names().iter().map(|n| rec.metrics.plot_series(n)).collect();
    svg_chart(&rec.spec.id, &series, 640, 360)
}

fn session_html(rec: &SessionRecord) -> String {
    let mut body = format!(
        "<p>state: {} | steps: {}/{} | lr: {} | model: {} | dataset: {}</p>",
        rec.state.as_str(),
        rec.steps_done,
        rec.spec.total_steps,
        rec.spec.lr,
        xml_escape(&rec.spec.model),
        xml_escape(&rec.spec.dataset)
    );
    body.push_str(&session_svg(rec));
    body.push_str("<p><a href=\"/\">back</a></p>");
    page(&rec.spec.id.clone(), body)
}

// ---------------------------------------------------------------------
// The actual server
// ---------------------------------------------------------------------

/// Serve until the process exits. Returns the bound port.
pub fn serve(state: WebState, port: u16) -> std::io::Result<(u16, std::thread::JoinHandle<()>)> {
    let listener = TcpListener::bind(("127.0.0.1", port))?;
    let bound = listener.local_addr()?.port();
    let handle = std::thread::spawn(move || {
        for stream in listener.incoming() {
            let Ok(mut stream) = stream else { continue };
            let state = state.clone();
            std::thread::spawn(move || {
                let mut buf = [0u8; 8192];
                let mut req = Vec::new();
                // Read headers, then keep reading until Content-Length
                // bytes of body have arrived (POST bodies). The header
                // terminator is searched incrementally and headers are
                // parsed once, so receipt stays O(n).
                let mut header_end: Option<usize> = None;
                let mut body_len = 0usize;
                let mut scanned = 0usize;
                loop {
                    if header_end.is_none() {
                        // Resume the terminator scan where the last read
                        // left off (back up 3 bytes for a split match).
                        let start = scanned.saturating_sub(3);
                        if let Some(pos) = req[start..].windows(4).position(|w| w == b"\r\n\r\n") {
                            let he = start + pos + 4;
                            header_end = Some(he);
                            body_len = String::from_utf8_lossy(&req[..he])
                                .lines()
                                .find_map(|l| {
                                    let (k, v) = l.split_once(':')?;
                                    k.trim()
                                        .eq_ignore_ascii_case("content-length")
                                        .then(|| v.trim().parse::<usize>().ok())?
                                })
                                .unwrap_or(0);
                        }
                        scanned = req.len();
                    }
                    if let Some(he) = header_end {
                        if req.len() >= he + body_len {
                            break;
                        }
                    }
                    if req.len() > 4 * 1024 * 1024 {
                        break;
                    }
                    match stream.read(&mut buf) {
                        Ok(0) | Err(_) => break,
                        Ok(n) => req.extend_from_slice(&buf[..n]),
                    }
                }
                let header_end = header_end.unwrap_or(req.len());
                let head = String::from_utf8_lossy(&req[..header_end]).to_string();
                let body = String::from_utf8_lossy(&req[header_end..]).to_string();
                let mut parts = head.lines().next().unwrap_or("").split_whitespace();
                let method = parts.next().unwrap_or("GET").to_string();
                let path = parts.next().unwrap_or("/").to_string();
                // Only Content-Length framing is supported; a POST
                // without it (e.g. chunked) would be read
                // nondeterministically, so reject it outright.
                let has_length = head.lines().any(|l| {
                    l.split_once(':').map_or(false, |(k, _)| k.trim().eq_ignore_ascii_case("content-length"))
                });
                let resp = if method == "POST" && !has_length {
                    Response {
                        status: 411,
                        content_type: "text/plain",
                        body: "length required: POST needs Content-Length\n".into(),
                        allow: None,
                    }
                } else {
                    handle(&state, &method, &path, &body)
                };
                let allow_header =
                    resp.allow.map(|a| format!("Allow: {}\r\n", a)).unwrap_or_default();
                let _ = write!(
                    stream,
                    "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\n{}Connection: close\r\n\r\n{}",
                    resp.status,
                    status_text(resp.status),
                    resp.content_type,
                    resp.body.len(),
                    allow_header,
                    resp.body
                );
            });
        }
    });
    Ok((bound, handle))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::session::{SessionRecord, SessionSpec};
    use crate::util::clock::sim_clock;

    fn state() -> WebState {
        let (clock, _) = sim_clock();
        let events = EventLog::new(clock.clone()).with_echo(false);
        let sessions = SessionStore::new();
        let mut rec = SessionRecord::new(SessionSpec::new("kim/mnist/1", "kim", "mnist", "mnist_mlp"), 0);
        rec.steps_done = 50;
        rec.best_metric = Some(0.9);
        rec.metrics.log(10, "train_loss", 1.2);
        rec.metrics.log(20, "train_loss", 0.8);
        sessions.insert(rec);
        let leaderboard = Leaderboard::new();
        leaderboard.ensure_board("mnist", "accuracy", false);
        leaderboard.submit(
            "mnist",
            crate::leaderboard::Submission {
                session: "kim/mnist/1".into(),
                user: "kim".into(),
                model: "mnist_mlp".into(),
                metric_name: "accuracy".into(),
                value: 0.9,
                step: 50,
                at_ms: 1,
            },
        );
        let cluster = Cluster::homogeneous(clock, events.clone(), 2, 4, 24.0);
        WebState { sessions, leaderboard, cluster: Some(cluster), events, api: None }
    }

    #[test]
    fn dashboard_lists_sessions_and_boards() {
        let s = state();
        let r = handle(&s, "GET", "/", "");
        assert_eq!(r.status, 200);
        assert!(r.body.contains("kim/mnist/1"));
        assert!(r.body.contains("/board/mnist"));
        assert!(r.body.contains("8 GPUs") || r.body.contains("0/8"));
    }

    #[test]
    fn api_sessions_json_parses() {
        let s = state();
        let r = handle(&s, "GET", "/api/sessions", "");
        let j = crate::util::json::parse(&r.body).unwrap();
        let arr = j.as_arr().unwrap();
        assert_eq!(arr.len(), 1);
        assert_eq!(arr[0].get("state").unwrap().as_str(), Some("queued"));
    }

    #[test]
    fn api_session_detail_has_metrics() {
        let s = state();
        let r = handle(&s, "GET", "/api/session/kim/mnist/1", "");
        let j = crate::util::json::parse(&r.body).unwrap();
        let pts = j.at(&["metrics", "train_loss"]).unwrap().as_arr().unwrap();
        assert_eq!(pts.len(), 2);
    }

    #[test]
    fn percent_encoded_paths_decode() {
        let s = state();
        // kim/mnist/1 with the slashes percent-encoded.
        let r = handle(&s, "GET", "/api/session/kim%2Fmnist%2F1", "");
        assert_eq!(r.status, 200);
        let j = crate::util::json::parse(&r.body).unwrap();
        assert_eq!(j.get("id").unwrap().as_str(), Some("kim/mnist/1"));
        // Invalid escapes pass through untouched.
        assert_eq!(percent_decode("a%2Fb"), "a/b");
        assert_eq!(percent_decode("a%zzb"), "a%zzb");
        assert_eq!(percent_decode("100%"), "100%");
    }

    #[test]
    fn plot_svg_renders() {
        let s = state();
        let r = handle(&s, "GET", "/plot/kim/mnist/1.svg", "");
        assert_eq!(r.status, 200);
        assert!(r.body.starts_with("<svg"));
        assert!(r.body.contains("train_loss"));
    }

    #[test]
    fn board_json_and_html() {
        let s = state();
        let j = handle(&s, "GET", "/api/board/mnist", "");
        assert_eq!(j.status, 200);
        assert!(j.body.contains("\"rank\":1"));
        let h = handle(&s, "GET", "/board/mnist", "");
        assert!(h.body.contains("kim/mnist/1"));
        assert_eq!(handle(&s, "GET", "/api/board/nope", "").status, 404);
    }

    #[test]
    fn cluster_json() {
        let s = state();
        let r = handle(&s, "GET", "/api/cluster", "");
        let j = crate::util::json::parse(&r.body).unwrap();
        assert_eq!(j.get("total_gpus").unwrap().as_i64(), Some(8));
    }

    #[test]
    fn unknown_routes_404_and_method_routing() {
        let s = state();
        assert_eq!(handle(&s, "GET", "/nope", "").status, 404);
        assert_eq!(handle(&s, "GET", "/api/session/missing", "").status, 404);
        // POST outside /api/v1/ -> 405 with Allow: GET.
        let r = handle(&s, "POST", "/", "");
        assert_eq!(r.status, 405);
        assert_eq!(r.allow, Some("GET"));
        // GET on a v1 verb -> 405 with Allow: POST.
        let r = handle(&s, "GET", "/api/v1/run", "");
        assert_eq!(r.status, 405);
        assert_eq!(r.allow, Some("POST"));
        // Exotic methods advertise both.
        let r = handle(&s, "DELETE", "/", "");
        assert_eq!(r.status, 405);
        assert_eq!(r.allow, Some("GET, POST"));
    }

    #[test]
    fn post_without_service_is_503() {
        let s = state();
        let r = handle(&s, "POST", "/api/v1/list_sessions", "");
        assert_eq!(r.status, 503);
        // The executor/events/tenants/board read routes need the
        // service too.
        assert_eq!(handle(&s, "GET", "/api/v1/executor", "").status, 503);
        assert_eq!(handle(&s, "GET", "/api/v1/events?since=0", "").status, 503);
        assert_eq!(handle(&s, "GET", "/api/v1/tenants", "").status, 503);
        assert_eq!(handle(&s, "GET", "/api/v1/durability", "").status, 503);
        assert_eq!(handle(&s, "GET", "/api/v1/board?dataset=mnist", "").status, 503);
    }

    #[test]
    fn tenants_and_board_routes_dispatch_queries() {
        use crate::api::TenantView;
        // Stub service: a canned tenant report, and board dispatches
        // echoing the parsed user filter.
        let (api, rx) = crate::api::service_channel();
        std::thread::spawn(move || {
            while let Ok(call) = rx.recv() {
                let resp = match call.request() {
                    ApiRequest::TenantReport => ApiResponse::Tenants {
                        tenants: vec![TenantView {
                            user: "kim".into(),
                            weight: 2,
                            class: "high".into(),
                            max_concurrent: 3,
                            max_gpus: 8,
                            gpu_second_budget: 60.0,
                            gpu_seconds_used: 12.5,
                            active_sessions: 1,
                            gpus_in_use: 2,
                            waiting: 1,
                            preemptions: 1,
                        }],
                    },
                    ApiRequest::Board { dataset, limit, user } => {
                        assert_eq!(dataset, "mnist");
                        assert_eq!(*limit, 5);
                        assert_eq!(user.as_deref(), Some("kim"));
                        ApiResponse::Board { dataset: dataset.clone(), rows: vec![] }
                    }
                    _ => ApiResponse::Sessions { sessions: vec![] },
                };
                call.respond(resp);
            }
        });
        let mut s = state();
        s.api = Some(api);
        let r = handle(&s, "GET", "/api/v1/tenants", "");
        assert_eq!(r.status, 200);
        let j = crate::util::json::parse(&r.body).unwrap();
        assert_eq!(j.get("kind").unwrap().as_str(), Some("tenants"));
        let tenants = j.at(&["data", "tenants"]).unwrap().as_arr().unwrap();
        assert_eq!(tenants[0].get("user").unwrap().as_str(), Some("kim"));
        assert_eq!(tenants[0].get("waiting").unwrap().as_i64(), Some(1));

        let r = handle(&s, "GET", "/api/v1/board?dataset=mnist&user=kim&limit=5", "");
        assert_eq!(r.status, 200);
        let j = crate::util::json::parse(&r.body).unwrap();
        assert_eq!(j.get("kind").unwrap().as_str(), Some("board"));
        // Bad limit 400s before reaching the service; a missing
        // dataset is rejected by the wire layer.
        assert_eq!(handle(&s, "GET", "/api/v1/board?dataset=mnist&limit=soon", "").status, 400);
        assert_eq!(handle(&s, "GET", "/api/v1/board?user=kim", "").status, 400);
    }

    #[test]
    fn durability_route_serves_wal_counters() {
        use crate::api::DurabilityView;
        // Stub service answering a canned durability snapshot.
        let (api, rx) = crate::api::service_channel();
        std::thread::spawn(move || {
            while let Ok(call) = rx.recv() {
                let resp = match call.request() {
                    ApiRequest::DurabilityStatus => ApiResponse::Durability {
                        durability: DurabilityView {
                            enabled: true,
                            wal_records: 7,
                            wal_bytes: 1024,
                            wal_last_seq: Some(41),
                            records_since_snapshot: 7,
                            snapshot_every: 512,
                            snapshots: 2,
                            last_snapshot_seq: 34,
                            wal_dropped: 0,
                            consumer_dropped: 0,
                            gc_enabled: true,
                            gc_live_objects: 10,
                            gc_live_bytes: 4096,
                            gc_swept_objects: 1,
                            gc_swept_bytes: 128,
                        },
                    },
                    _ => ApiResponse::Sessions { sessions: vec![] },
                };
                call.respond(resp);
            }
        });
        let mut s = state();
        s.api = Some(api);
        let r = handle(&s, "GET", "/api/v1/durability", "");
        assert_eq!(r.status, 200);
        let j = crate::util::json::parse(&r.body).unwrap();
        assert_eq!(j.get("kind").unwrap().as_str(), Some("durability"));
        assert_eq!(j.at(&["data", "durability", "wal_records"]).unwrap().as_i64(), Some(7));
        assert_eq!(j.at(&["data", "durability", "snapshots"]).unwrap().as_i64(), Some(2));
        assert_eq!(j.at(&["data", "durability", "wal_last_seq"]).unwrap().as_i64(), Some(41));
    }

    #[test]
    fn events_route_pages_cursor_reads() {
        use crate::events::{Event, EventKind, Level};
        // Stub service echoing the parsed events_since arguments back
        // through a canned page, so the query-string plumbing is
        // verified without a platform.
        let (api, rx) = crate::api::service_channel();
        std::thread::spawn(move || {
            while let Ok(call) = rx.recv() {
                let resp = match call.request() {
                    ApiRequest::EventsSince { since, kind, subject, limit } => {
                        assert_eq!(*since, 5);
                        assert_eq!(kind.as_deref(), Some("state"));
                        assert_eq!(subject.as_deref(), Some("kim/mnist/1"));
                        assert_eq!(*limit, 2);
                        ApiResponse::Events {
                            events: vec![Event {
                                seq: 6,
                                at_ms: 100,
                                level: Level::Info,
                                source: "session".into(),
                                subject: "kim/mnist/1".into(),
                                kind: EventKind::StateChanged {
                                    from: "running".into(),
                                    to: "done".into(),
                                    step: 40,
                                },
                            }],
                            next: 7,
                            dropped: 0,
                        }
                    }
                    _ => ApiResponse::Sessions { sessions: vec![] },
                };
                call.respond(resp);
            }
        });
        let mut s = state();
        s.api = Some(api);
        // Subject slashes travel percent-encoded in the query string.
        let r = handle(
            &s,
            "GET",
            "/api/v1/events?since=5&kind=state&subject=kim%2Fmnist%2F1&limit=2",
            "",
        );
        assert_eq!(r.status, 200);
        let j = crate::util::json::parse(&r.body).unwrap();
        assert_eq!(j.get("kind").unwrap().as_str(), Some("events"));
        assert_eq!(j.at(&["data", "next"]).unwrap().as_i64(), Some(7));
        let events = j.at(&["data", "events"]).unwrap().as_arr().unwrap();
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].get("kind").unwrap().as_str(), Some("state"));
        assert_eq!(events[0].at(&["data", "to"]).unwrap().as_str(), Some("done"));
        // Rendered message rides along for dumb consumers.
        assert!(events[0].get("message").unwrap().as_str().unwrap().contains("done"));
        // Bad cursor values 400 before reaching the service.
        let bad = handle(&s, "GET", "/api/v1/events?since=yesterday", "");
        assert_eq!(bad.status, 400);
    }

    #[test]
    fn executor_route_serves_worker_telemetry() {
        use crate::api::{ExecutorStats, WorkerStatView};
        // Stub service answering a canned executor snapshot.
        let (api, rx) = crate::api::service_channel();
        std::thread::spawn(move || {
            while let Ok(call) = rx.recv() {
                let resp = match call.request() {
                    ApiRequest::ExecutorStatus => ApiResponse::Executor {
                        executor: ExecutorStats {
                            workers: vec![
                                WorkerStatView {
                                    worker: 0,
                                    live_sessions: 2,
                                    queue_depth: 0,
                                    steals: 0,
                                    busy_ms: 12.5,
                                },
                                WorkerStatView {
                                    worker: 1,
                                    live_sessions: 2,
                                    queue_depth: 0,
                                    steals: 2,
                                    busy_ms: 11.0,
                                },
                            ],
                            live_sessions: 4,
                            queue_depth: 0,
                            total_steals: 2,
                            work_steal: true,
                        },
                    },
                    _ => ApiResponse::Sessions { sessions: vec![] },
                };
                call.respond(resp);
            }
        });
        let mut s = state();
        s.api = Some(api);
        let r = handle(&s, "GET", "/api/v1/executor", "");
        assert_eq!(r.status, 200);
        let j = crate::util::json::parse(&r.body).unwrap();
        assert_eq!(j.get("kind").unwrap().as_str(), Some("executor"));
        assert_eq!(j.at(&["data", "executor", "total_steals"]).unwrap().as_i64(), Some(2));
        let workers = j.at(&["data", "executor", "workers"]).unwrap().as_arr().unwrap();
        assert_eq!(workers.len(), 2);
        assert_eq!(workers[1].get("steals").unwrap().as_i64(), Some(2));
        // Other GET paths under /api/v1/ still require POST.
        assert_eq!(handle(&s, "GET", "/api/v1/cluster_status", "").status, 405);
    }

    #[test]
    fn post_with_service_dispatches_and_maps_errors() {
        // A stub service thread that answers canned responses without a
        // real platform: not_found for get_session, sessions otherwise.
        let (api, rx) = crate::api::service_channel();
        std::thread::spawn(move || {
            while let Ok(call) = rx.recv() {
                let resp = match call.request() {
                    ApiRequest::GetSession { session } => ApiResponse::Error {
                        error: ApiError::not_found(format!("unknown session '{}'", session)),
                    },
                    _ => ApiResponse::Sessions { sessions: vec![] },
                };
                call.respond(resp);
            }
        });
        let mut s = state();
        s.api = Some(api);

        let ok = handle(&s, "POST", "/api/v1/list_sessions", "");
        assert_eq!(ok.status, 200);
        let j = crate::util::json::parse(&ok.body).unwrap();
        assert_eq!(j.get("kind").unwrap().as_str(), Some("sessions"));

        let nf = handle(&s, "POST", "/api/v1/get_session", r#"{"session":"missing"}"#);
        assert_eq!(nf.status, 404);
        assert!(nf.body.contains("not_found"));

        // Bad args never reach the service: 400 straight from the wire layer.
        let bad = handle(&s, "POST", "/api/v1/pause", "{}");
        assert_eq!(bad.status, 400);
        let garbled = handle(&s, "POST", "/api/v1/pause", "{not json");
        assert_eq!(garbled.status, 400);
        let unknown = handle(&s, "POST", "/api/v1/frobnicate", "");
        assert_eq!(unknown.status, 400);
    }

    #[test]
    fn live_server_round_trip() {
        let s = state();
        let (port, _h) = serve(s, 0).unwrap();
        let mut stream = std::net::TcpStream::connect(("127.0.0.1", port)).unwrap();
        write!(stream, "GET /api/cluster HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        let mut out = String::new();
        stream.read_to_string(&mut out).unwrap();
        assert!(out.starts_with("HTTP/1.1 200"));
        assert!(out.contains("total_gpus"));
    }
}
