//! Web UI + HTTP API (paper §3.2): "The *web UI* wraps NSML-CLI in a
//! web application and is more intuitive … provides visualizations such
//! as graphs, logs, and demos."
//!
//! nginx is unavailable offline, so this is a from-scratch minimal
//! HTTP/1.1 server: a bounded worker pool over `std::net::TcpListener`
//! with keep-alive connection reuse ([`serve`]); the old
//! thread-per-connection accept loop survives only as the `bench_web`
//! baseline ([`serve_thread_per_conn`]). Routes:
//!
//! * `GET /`                     — HTML dashboard (sessions, cluster, boards)
//! * `GET /board/<dataset>`      — HTML leaderboard
//! * `GET /session/<id…>`        — HTML session page with SVG curves
//! * `GET /plot/<id…>.svg`       — standalone SVG learning curves
//! * `GET /api/v1/sessions?limit=&offset=&user=` — paged session list,
//!   dispatched as a `list_sessions` query
//! * `GET /api/v1/executor`      — JSON executor-pool telemetry
//!   (per-worker busy-time, live sessions, queue depth, steal counts)
//!   dispatched as an `executor_status` query through the attached
//!   service
//! * `GET /api/v1/tenants`       — JSON per-user fair-share report
//!   (quotas, GPU-second usage, occupancy, admission-queue depth)
//!   dispatched as a `tenant_report` query
//! * `GET /api/v1/durability`    — JSON WAL/snapshot/GC counters
//!   dispatched as a `durability_status` query
//! * `GET /api/v1/endpoints`     — JSON serving-endpoint registry
//!   (active version, promotion history, live replica count and
//!   queue depth per endpoint) dispatched as an `endpoints` query
//! * `POST /api/v1/endpoints/<name>/infer` — micro-batched inference
//!   against a promoted endpoint; the body is
//!   `{"user": "...", "x": [...]}` and the path names the endpoint.
//!   Dispatched as a `serve_infer` verb — concurrent requests from
//!   many connections coalesce into shared engine batches on the
//!   platform thread
//! * `GET /api/v1/board?dataset=<ds>&user=<u>&limit=<n>` — leaderboard
//!   rows, optionally sliced to one user (global ranks kept),
//!   dispatched as a `board` query
//! * `GET /metrics`              — Prometheus text exposition (0.0.4)
//!   rendered straight from the in-process metrics registry; scrapes
//!   never cross the service channel, so they stay cheap while the
//!   platform thread drives rounds
//! * `GET /api/v1/metrics`       — the same registry as JSON
//!   (dispatched as a `metrics_report` query)
//! * `GET /api/v1/trace/<id>`    — every span recorded under one trace
//!   id (dispatched as a `trace` query). Requests carry an
//!   `X-Trace-Id` header (minted when absent, echoed on the response),
//!   so one HTTP inference can be followed dispatch → queue → batch
//! * `GET /api/v1/events?since=<cursor>&kind=<name>&subject=<id>&limit=<n>`
//!   — cursor-paged incremental read of the platform event bus
//!   (dispatched as an `events_since` query)
//! * `GET /api/v1/events/stream?kind=&subject=` — Server-Sent Events:
//!   a push stream fed from a bus [`Subscription`], one SSE frame per
//!   event (`id:` = bus seq, `event:` = kind, `data:` = JSON
//!   envelope). Clients resume after a disconnect with the standard
//!   `Last-Event-ID` header (or `last_event_id=` query parameter);
//!   retained events after that seq replay first, then live events
//!   follow. `nsml logs -f` consumers and the dashboard thus stop
//!   polling. Streams run on dedicated threads, capped at
//!   [`ServeOpts::max_sse_clients`] (503 beyond).
//! * `POST /api/v1/<verb>`       — dispatch any `ApiRequest` verb into
//!   the attached [`PlatformService`](crate::api::PlatformService);
//!   the JSON body is the verb's `args` object and the reply is an
//!   `ApiResponse` envelope. Error codes map to HTTP: `not_found`→404,
//!   `invalid_argument`→400, `failed_precondition`→409, `internal`→500,
//!   `unknown_route`→404.
//!
//! **Deprecated aliases** (kept for old dashboards, served as exact
//! re-routes through `PlatformService::dispatch` with a
//! `Deprecation: true` header and a `Link: …; rel="successor-version"`
//! pointing at the v1 replacement — bodies are byte-identical to their
//! v1 counterparts):
//!
//! * `GET /api/sessions`        → `list_sessions` (see `/api/v1/sessions`)
//! * `GET /api/session/<id…>`   → `get_session`   (see `POST /api/v1/get_session`)
//! * `GET /api/board/<dataset>` → `board`         (see `/api/v1/board`)
//! * `GET /api/cluster`         → `cluster_status` (see `POST /api/v1/cluster_status`)
//!
//! Every `/api/*` response — including unknown paths, which answer a
//! machine-readable `unknown_route` error — flows through the
//! `ApiResponse`/`ApiError` wire envelopes; no hand-rolled JSON.
//!
//! Path segments are percent-decoded before routing; unsupported
//! methods get `405` with an `Allow` header. Routing logic is a pure
//! function ([`handle`]) so tests exercise it without sockets.
//!
//! Mutations dispatched here land on the platform thread (under
//! `nsml serve`, between daemon drive rounds), which drives training
//! through the [`crate::executor`] worker pool.

use crate::api::{ApiError, ApiRequest, ApiResponse, ErrorCode, ServiceHandle, ALL_VERBS};
use crate::cluster::Cluster;
use crate::events::{EventFilter, EventLog, ALL_EVENT_KINDS};
use crate::leaderboard::Leaderboard;
use crate::session::{SessionRecord, SessionStore};
use crate::util::json::Json;
use crate::util::plot::{svg_chart, xml_escape, Series};
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Duration;

/// Shareable snapshot handles the server reads from (all thread-safe),
/// plus the optional dispatcher for `/api/*` routes.
#[derive(Clone)]
pub struct WebState {
    pub sessions: SessionStore,
    pub leaderboard: Leaderboard,
    pub cluster: Option<Cluster>,
    pub events: EventLog,
    /// When attached, API verbs dispatch into the platform service on
    /// its owning thread; when `None`, API routes answer 503 (the
    /// HTML views still render from the snapshot handles).
    pub api: Option<ServiceHandle>,
    /// The platform's observability spine. When attached, every
    /// request is timed into the registry, joined to a trace (the
    /// `X-Trace-Id` header or a minted id), and `GET /metrics` renders
    /// the Prometheus exposition; when `None`, `/metrics` answers 503.
    pub obs: Option<crate::obs::Obs>,
}

/// An HTTP response.
pub struct Response {
    pub status: u16,
    pub content_type: &'static str,
    pub body: String,
    /// `Allow` header value for 405 responses.
    pub allow: Option<&'static str>,
    /// Successor route for deprecated legacy aliases; emitted as
    /// `Deprecation: true` plus `Link: <…>; rel="successor-version"`.
    pub deprecation: Option<&'static str>,
    /// The request's trace id, echoed back as `X-Trace-Id` so clients
    /// can fetch the span chain from `/api/v1/trace/<id>`.
    pub trace: Option<String>,
}

impl Response {
    fn html(body: String) -> Response {
        Response {
            status: 200,
            content_type: "text/html; charset=utf-8",
            body,
            allow: None,
            deprecation: None,
            trace: None,
        }
    }

    fn svg(body: String) -> Response {
        Response {
            status: 200,
            content_type: "image/svg+xml",
            body,
            allow: None,
            deprecation: None,
            trace: None,
        }
    }

    fn text(status: u16, body: impl Into<String>) -> Response {
        Response {
            status,
            content_type: "text/plain",
            body: body.into(),
            allow: None,
            deprecation: None,
            trace: None,
        }
    }

    fn not_found(msg: &str) -> Response {
        Response::text(404, format!("not found: {}\n", msg))
    }

    fn method_not_allowed(allow: &'static str) -> Response {
        Response {
            allow: Some(allow),
            ..Response::text(405, format!("method not allowed (allow: {})\n", allow))
        }
    }

    fn deprecated(mut self, successor: &'static str) -> Response {
        self.deprecation = Some(successor);
        self
    }
}

fn status_text(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        409 => "Conflict",
        411 => "Length Required",
        500 => "Internal Server Error",
        501 => "Not Implemented",
        503 => "Service Unavailable",
        _ => "Unknown",
    }
}

/// Decode `%XX` escapes in a path (invalid escapes pass through as-is).
fn percent_decode(s: &str) -> String {
    let bytes = s.as_bytes();
    let mut out = Vec::with_capacity(bytes.len());
    let mut i = 0;
    while i < bytes.len() {
        if bytes[i] == b'%' && i + 2 < bytes.len() {
            let hex = |b: u8| (b as char).to_digit(16);
            if let (Some(hi), Some(lo)) = (hex(bytes[i + 1]), hex(bytes[i + 2])) {
                out.push((hi * 16 + lo) as u8);
                i += 3;
                continue;
            }
        }
        out.push(bytes[i]);
        i += 1;
    }
    String::from_utf8_lossy(&out).into_owned()
}

/// Route a request (pure; no I/O). `body` is the request body (only
/// meaningful for POST). The one route this function cannot serve is
/// `GET /api/v1/events/stream` — streaming needs the live connection,
/// so the pooled server intercepts it before routing here.
pub fn handle(state: &WebState, method: &str, path: &str, body: &str) -> Response {
    let (route, query) = match path.split_once('?') {
        Some((r, q)) => (r, q),
        None => (path, ""),
    };
    let path = percent_decode(route);
    match method {
        "GET" => handle_get(state, &path, query),
        "POST" => match path.strip_prefix("/api/v1/") {
            Some(verb) => handle_api_post(state, verb, body),
            None => Response::method_not_allowed("GET"),
        },
        _ => {
            if path.starts_with("/api/v1/") {
                Response::method_not_allowed("POST")
            } else {
                Response::method_not_allowed("GET, POST")
            }
        }
    }
}

/// The v1 dispatch surface: `POST /api/v1/<verb>` with the args object
/// as body (empty body = `{}`); the web UI thus *wraps* the CLI verbs.
fn handle_api_post(state: &WebState, verb: &str, body: &str) -> Response {
    let Some(api) = &state.api else {
        return service_unavailable();
    };
    // `POST /api/v1/endpoints/<name>/infer`: the serving shorthand —
    // the path names the endpoint, the body carries `user` and `x`,
    // and the whole thing dispatches as a `serve_infer` verb.
    if let Some(name) = verb.strip_prefix("endpoints/").and_then(|r| r.strip_suffix("/infer")) {
        let parsed = if body.trim().is_empty() {
            Ok(Json::obj())
        } else {
            crate::util::json::parse(body)
        };
        return match parsed {
            Err(e) => {
                api_response(ApiResponse::Error {
                    error: ApiError::invalid(format!("request body: {}", e)),
                })
            }
            Ok(mut args) => {
                args.set("endpoint", name.into());
                match ApiRequest::from_verb_args("serve_infer", &args) {
                    Ok(req) => api_response(api.call(req)),
                    Err(error) => api_response(ApiResponse::Error { error }),
                }
            }
        };
    }
    let resp = if body.trim().is_empty() {
        match ApiRequest::from_verb_args(verb, &Json::obj()) {
            Ok(req) => api.call(req),
            Err(error) => ApiResponse::Error { error },
        }
    } else {
        match crate::util::json::parse(body) {
            Err(e) => ApiResponse::Error { error: ApiError::invalid(format!("request body: {}", e)) },
            Ok(args) => match ApiRequest::from_verb_args(verb, &args) {
                Ok(req) => api.call(req),
                Err(error) => ApiResponse::Error { error },
            },
        }
    };
    api_response(resp)
}

fn service_unavailable() -> Response {
    Response::text(503, "platform service not attached (read-only web ui)\n")
}

/// Serialize an `ApiResponse` envelope with its HTTP status mapping.
fn api_response(resp: ApiResponse) -> Response {
    let status = match &resp {
        ApiResponse::Error { error } => match error.code {
            ErrorCode::NotFound => 404,
            ErrorCode::InvalidArgument => 400,
            ErrorCode::FailedPrecondition => 409,
            ErrorCode::Internal => 500,
            ErrorCode::UnknownRoute => 404,
        },
        _ => 200,
    };
    Response {
        status,
        content_type: "application/json",
        body: resp.to_json().to_string(),
        allow: None,
        deprecation: None,
        trace: None,
    }
}

/// Unknown `/api/*` path: a machine-readable `unknown_route` envelope
/// (404), never plain text — API clients should not have to sniff.
fn unknown_route(method: &str, path: &str) -> Response {
    api_response(ApiResponse::Error {
        error: ApiError::unknown_route(format!(
            "no API route '{} {}'; see the /api/v1/* surface",
            method, path
        )),
    })
}

/// A deprecated legacy alias: exactly the dispatch its v1 counterpart
/// performs (same wire defaults, byte-identical body), plus the
/// `Deprecation`/`Link` headers naming the successor route.
fn alias_dispatch(
    state: &WebState,
    verb: &str,
    args: &Json,
    successor: &'static str,
) -> Response {
    let Some(api) = &state.api else {
        return service_unavailable();
    };
    let resp = match ApiRequest::from_verb_args(verb, args) {
        Ok(req) => api.call(req),
        Err(error) => ApiResponse::Error { error },
    };
    api_response(resp).deprecated(successor)
}

/// `GET /api/v1/executor`: the executor-status query as a read route,
/// so dashboards can poll per-worker load without a POST body.
fn executor_json(state: &WebState) -> Response {
    let Some(api) = &state.api else {
        return service_unavailable();
    };
    api_response(api.call(ApiRequest::ExecutorStatus))
}

/// `GET /api/v1/tenants`: the per-user fair-share report (quotas,
/// GPU-second usage, admission-queue depth) as a read route.
fn tenants_json(state: &WebState) -> Response {
    let Some(api) = &state.api else {
        return service_unavailable();
    };
    api_response(api.call(ApiRequest::TenantReport))
}

/// `GET /api/v1/durability`: the WAL/snapshot/GC counters as a read
/// route, so dashboards can poll crash-safety health without a POST
/// body.
fn durability_json(state: &WebState) -> Response {
    let Some(api) = &state.api else {
        return service_unavailable();
    };
    api_response(api.call(ApiRequest::DurabilityStatus))
}

/// `GET /api/v1/service`: the daemon drive-loop counters (rounds,
/// last-round duration, rounds/sec, dispatches) as a read route.
fn service_status_json(state: &WebState) -> Response {
    let Some(api) = &state.api else {
        return service_unavailable();
    };
    api_response(api.call(ApiRequest::ServiceStatus))
}

/// `GET /api/v1/endpoints`: the serving-endpoint registry (active
/// version + promotion history per endpoint) as a read route.
fn endpoints_json(state: &WebState) -> Response {
    let Some(api) = &state.api else {
        return service_unavailable();
    };
    api_response(api.call(ApiRequest::Endpoints))
}

/// `GET /metrics`: Prometheus text exposition (0.0.4) rendered straight
/// from the in-process registry — no service-channel hop, so scrapes
/// stay cheap while the platform thread is busy driving rounds.
fn metrics_text(state: &WebState) -> Response {
    let Some(obs) = &state.obs else {
        return Response::text(503, "metrics registry not attached (read-only web ui)\n");
    };
    Response {
        status: 200,
        content_type: "text/plain; version=0.0.4; charset=utf-8",
        body: obs.metrics.render_prometheus(),
        allow: None,
        deprecation: None,
        trace: None,
    }
}

/// `GET /api/v1/metrics`: the metrics report (counters, gauges,
/// histogram quantiles) as JSON, dispatched as a `metrics_report`
/// query.
fn metrics_json(state: &WebState) -> Response {
    let Some(api) = &state.api else {
        return service_unavailable();
    };
    api_response(api.call(ApiRequest::MetricsReport))
}

/// `GET /api/v1/trace/<id>`: every span recorded under one trace id,
/// dispatched as a `trace` query (unknown ids are 404 envelopes).
fn trace_json(state: &WebState, id: &str) -> Response {
    let Some(api) = &state.api else {
        return service_unavailable();
    };
    api_response(api.call(ApiRequest::Trace { id: id.to_string() }))
}

/// `GET /api/v1/board?dataset=&user=&limit=`: the leaderboard query as
/// a read route — `user=` slices to one tenant's rows while keeping
/// their global ranks. The query string becomes a `board` dispatch, so
/// the wire layer validates the arguments.
fn board_query_json(state: &WebState, query: &str) -> Response {
    let Some(api) = &state.api else {
        return service_unavailable();
    };
    let mut args = Json::obj();
    for (k, v) in parse_query(query) {
        match k.as_str() {
            "limit" => match v.parse::<u64>() {
                Ok(n) => {
                    args.set(&k, n.into());
                }
                Err(_) => {
                    return api_response(ApiResponse::Error {
                        error: ApiError::invalid(
                            "board: query parameter 'limit' must be a non-negative integer",
                        ),
                    })
                }
            },
            "dataset" | "user" => {
                args.set(&k, v.as_str().into());
            }
            _ => {} // unknown parameters are ignored
        }
    }
    match ApiRequest::from_verb_args("board", &args) {
        Ok(req) => api_response(api.call(req)),
        Err(error) => api_response(ApiResponse::Error { error }),
    }
}

/// `GET /api/v1/sessions?limit=&offset=&user=`: the paged session list
/// as a read route — bad paging values 400 before dispatch, exactly
/// like `board`/`events`.
fn sessions_query_json(state: &WebState, query: &str) -> Response {
    let Some(api) = &state.api else {
        return service_unavailable();
    };
    let mut args = Json::obj();
    for (k, v) in parse_query(query) {
        match k.as_str() {
            "limit" | "offset" => match v.parse::<u64>() {
                Ok(n) => {
                    args.set(&k, n.into());
                }
                Err(_) => {
                    return api_response(ApiResponse::Error {
                        error: ApiError::invalid(format!(
                            "sessions: query parameter '{}' must be a non-negative integer",
                            k
                        )),
                    })
                }
            },
            "user" => {
                args.set(&k, v.as_str().into());
            }
            _ => {} // unknown parameters are ignored
        }
    }
    match ApiRequest::from_verb_args("list_sessions", &args) {
        Ok(req) => api_response(api.call(req)),
        Err(error) => api_response(ApiResponse::Error { error }),
    }
}

/// Decoded `key=value` pairs of a query string.
fn parse_query(query: &str) -> Vec<(String, String)> {
    query
        .split('&')
        .filter(|p| !p.is_empty())
        .map(|p| {
            let (k, v) = p.split_once('=').unwrap_or((p, ""));
            (percent_decode(k), percent_decode(v))
        })
        .collect()
}

/// `GET /api/v1/events?since=&kind=&subject=&limit=`: the event-bus
/// cursor read as a pollable route — the query string becomes an
/// `events_since` dispatch, so the wire layer validates the arguments.
fn events_json(state: &WebState, query: &str) -> Response {
    let Some(api) = &state.api else {
        return service_unavailable();
    };
    let mut args = Json::obj();
    for (k, v) in parse_query(query) {
        match k.as_str() {
            "since" | "limit" => match v.parse::<u64>() {
                Ok(n) => {
                    args.set(&k, n.into());
                }
                Err(_) => {
                    return api_response(ApiResponse::Error {
                        error: ApiError::invalid(format!(
                            "events: query parameter '{}' must be a non-negative integer",
                            k
                        )),
                    })
                }
            },
            "kind" | "subject" => {
                args.set(&k, v.as_str().into());
            }
            _ => {} // unknown parameters are ignored
        }
    }
    match ApiRequest::from_verb_args("events_since", &args) {
        Ok(req) => api_response(api.call(req)),
        Err(error) => api_response(ApiResponse::Error { error }),
    }
}

fn handle_get(state: &WebState, path: &str, query: &str) -> Response {
    if let Some(rest) = path.strip_prefix("/api/v1/") {
        return match rest {
            "sessions" => sessions_query_json(state, query),
            "executor" => executor_json(state),
            "metrics" => metrics_json(state),
            "events" => events_json(state, query),
            "events/stream" => Response::text(
                501,
                "event streaming needs a live connection (serve with `nsml serve`)\n",
            ),
            "tenants" => tenants_json(state),
            "durability" => durability_json(state),
            "service" => service_status_json(state),
            "endpoints" => endpoints_json(state),
            "board" => board_query_json(state, query),
            rest if rest.starts_with("trace/") => trace_json(state, &rest["trace/".len()..]),
            verb if ALL_VERBS.contains(&verb) => Response::method_not_allowed("POST"),
            _ => unknown_route("GET", path),
        };
    }
    match path {
        "/" => Response::html(dashboard_html(state)),
        "/metrics" => metrics_text(state),
        "/api/sessions" => alias_dispatch(state, "list_sessions", &Json::obj(), "/api/v1/sessions"),
        "/api/cluster" => {
            alias_dispatch(state, "cluster_status", &Json::obj(), "/api/v1/cluster_status")
        }
        p if p.starts_with("/api/board/") => {
            let mut args = Json::obj();
            args.set("dataset", p["/api/board/".len()..].into());
            alias_dispatch(state, "board", &args, "/api/v1/board")
        }
        p if p.starts_with("/api/session/") => {
            let mut args = Json::obj();
            args.set("session", p["/api/session/".len()..].into());
            alias_dispatch(state, "get_session", &args, "/api/v1/get_session")
        }
        p if p.starts_with("/api/") => unknown_route("GET", path),
        p if p.starts_with("/plot/") && p.ends_with(".svg") => {
            let id = &p["/plot/".len()..p.len() - 4];
            match state.sessions.get(id) {
                Some(rec) => Response::svg(session_svg(&rec)),
                None => Response::not_found(id),
            }
        }
        p if p.starts_with("/board/") => {
            let ds = &p["/board/".len()..];
            Response::html(board_html(state, ds))
        }
        p if p.starts_with("/session/") => {
            let id = &p["/session/".len()..];
            match state.sessions.get(id) {
                Some(rec) => Response::html(session_html(&rec)),
                None => Response::not_found(id),
            }
        }
        other => Response::not_found(other),
    }
}

// ---------------------------------------------------------------------
// HTML views
// ---------------------------------------------------------------------

const STYLE: &str = "<style>body{font-family:monospace;margin:2em;background:#fafafa}
table{border-collapse:collapse}td,th{border:1px solid #ccc;padding:4px 10px;text-align:left}
th{background:#eee}h1,h2{color:#234}a{color:#1a6}</style>";

fn page(title: &str, body: String) -> String {
    format!(
        "<!doctype html><html><head><title>{}</title>{}</head><body><h1>{}</h1>{}</body></html>",
        xml_escape(title),
        STYLE,
        xml_escape(title),
        body
    )
}

fn dashboard_html(state: &WebState) -> String {
    let mut body = String::new();
    if let Some(c) = &state.cluster {
        let (total, free) = c.gpu_totals();
        body.push_str(&format!(
            "<p>cluster: {} nodes alive, {}/{} GPUs in use ({:.0}% utilization)</p>",
            c.alive_count(),
            total - free,
            total,
            c.utilization() * 100.0
        ));
    }
    body.push_str("<h2>Sessions</h2><table><tr><th>session</th><th>state</th><th>steps</th><th>best metric</th><th>plot</th></tr>");
    for r in state.sessions.list() {
        body.push_str(&format!(
            "<tr><td><a href=\"/session/{id}\">{id}</a></td><td>{}</td><td>{}/{}</td><td>{}</td><td><a href=\"/plot/{id}.svg\">svg</a></td></tr>",
            r.state.as_str(),
            r.steps_done,
            r.spec.total_steps,
            r.best_metric.map(|v| format!("{:.4}", v)).unwrap_or_else(|| "-".into()),
            id = xml_escape(&r.spec.id),
        ));
    }
    body.push_str("</table><h2>Leaderboards</h2><ul>");
    for ds in state.leaderboard.datasets() {
        body.push_str(&format!("<li><a href=\"/board/{0}\">{0}</a> ({1} entries)</li>", ds, state.leaderboard.board_len(&ds)));
    }
    body.push_str("</ul>");
    page("NSML dashboard", body)
}

fn board_html(state: &WebState, dataset: &str) -> String {
    let mut body = String::from("<table><tr><th>rank</th><th>session</th><th>user</th><th>model</th><th>value</th><th>step</th></tr>");
    for (i, s) in state.leaderboard.top(dataset, 100).iter().enumerate() {
        body.push_str(&format!(
            "<tr><td>{0}</td><td><a href=\"/session/{1}\">{1}</a></td><td>{2}</td><td>{3}</td><td>{4:.4}</td><td>{5}</td></tr>",
            i + 1,
            xml_escape(&s.session),
            xml_escape(&s.user),
            xml_escape(&s.model),
            s.value,
            s.step
        ));
    }
    body.push_str("</table><p><a href=\"/\">back</a></p>");
    page(&format!("leaderboard: {}", dataset), body)
}

fn session_svg(rec: &SessionRecord) -> String {
    let series: Vec<Series> =
        rec.metrics.names().iter().map(|n| rec.metrics.plot_series(n)).collect();
    svg_chart(&rec.spec.id, &series, 640, 360)
}

fn session_html(rec: &SessionRecord) -> String {
    let mut body = format!(
        "<p>state: {} | steps: {}/{} | lr: {} | model: {} | dataset: {}</p>",
        rec.state.as_str(),
        rec.steps_done,
        rec.spec.total_steps,
        rec.spec.lr,
        xml_escape(&rec.spec.model),
        xml_escape(&rec.spec.dataset)
    );
    body.push_str(&session_svg(rec));
    body.push_str("<p><a href=\"/\">back</a></p>");
    page(&rec.spec.id.clone(), body)
}

// ---------------------------------------------------------------------
// HTTP plumbing shared by the pooled server and the baseline
// ---------------------------------------------------------------------

/// First matching header value (case-insensitive name), trimmed.
fn header_value<'a>(head: &'a str, name: &str) -> Option<&'a str> {
    head.lines().find_map(|l| {
        let (k, v) = l.split_once(':')?;
        if k.trim().eq_ignore_ascii_case(name) {
            Some(v.trim())
        } else {
            None
        }
    })
}

/// Read one HTTP request off the stream. `buf` carries bytes left over
/// from a previous keep-alive request on the same socket. The header
/// terminator is searched incrementally and headers are parsed once,
/// so receipt stays O(n). Returns `None` on EOF, read timeout,
/// malformed framing, or an oversized (>4 MiB) request — the caller
/// closes the connection.
fn read_request(stream: &mut TcpStream, buf: &mut Vec<u8>) -> Option<(String, String)> {
    let mut scratch = [0u8; 8192];
    let mut header_end: Option<usize> = None;
    let mut body_len = 0usize;
    let mut scanned = 0usize;
    loop {
        if header_end.is_none() && !buf.is_empty() {
            // Resume the terminator scan where the last read left off
            // (back up 3 bytes for a split match).
            let start = scanned.saturating_sub(3);
            if let Some(pos) = buf[start..].windows(4).position(|w| w == b"\r\n\r\n") {
                let he = start + pos + 4;
                header_end = Some(he);
                body_len = String::from_utf8_lossy(&buf[..he])
                    .lines()
                    .find_map(|l| {
                        let (k, v) = l.split_once(':')?;
                        k.trim()
                            .eq_ignore_ascii_case("content-length")
                            .then(|| v.trim().parse::<usize>().ok())?
                    })
                    .unwrap_or(0);
            }
            scanned = buf.len();
        }
        if let Some(he) = header_end {
            if buf.len() >= he + body_len {
                let head = String::from_utf8_lossy(&buf[..he]).to_string();
                let body = String::from_utf8_lossy(&buf[he..he + body_len]).to_string();
                buf.drain(..he + body_len);
                return Some((head, body));
            }
        }
        if buf.len() > 4 * 1024 * 1024 {
            return None;
        }
        match stream.read(&mut scratch) {
            Ok(0) | Err(_) => return None,
            Ok(n) => buf.extend_from_slice(&scratch[..n]),
        }
    }
}

/// Low-cardinality route label for the HTTP latency histogram: path
/// parameters (session ids, endpoint names, trace ids) collapse so
/// every label value names a route, never a resource.
fn route_group(path: &str) -> &'static str {
    let route = path.split('?').next().unwrap_or(path);
    match route {
        "/" => "/",
        "/metrics" => "/metrics",
        "/api/v1/sessions" => "/api/v1/sessions",
        "/api/v1/events" => "/api/v1/events",
        "/api/v1/events/stream" => "/api/v1/events/stream",
        "/api/v1/endpoints" => "/api/v1/endpoints",
        "/api/v1/board" => "/api/v1/board",
        "/api/v1/metrics" => "/api/v1/metrics",
        _ if route.starts_with("/api/v1/trace/") => "/api/v1/trace/:id",
        _ if route.starts_with("/api/v1/endpoints/") => "/api/v1/endpoints/:name/infer",
        _ if route.starts_with("/api/v1/") => "/api/v1/:verb",
        _ if route.starts_with("/api/") => "/api/legacy",
        _ if route.starts_with("/plot/") => "/plot/:id",
        _ if route.starts_with("/board/") => "/board/:dataset",
        _ if route.starts_with("/session/") => "/session/:id",
        _ => "other",
    }
}

/// Parse the request line, apply the Content-Length guard, and route
/// through the pure [`handle`] — under the request's trace context
/// (`X-Trace-Id` header, or a minted id), timed into the registry.
fn route_request(state: &WebState, head: &str, body: &str) -> Response {
    let mut parts = head.lines().next().unwrap_or("").split_whitespace();
    let method = parts.next().unwrap_or("GET");
    let path = parts.next().unwrap_or("/");
    // Only Content-Length framing is supported; a POST without it
    // (e.g. chunked) would be read nondeterministically, so reject it
    // outright.
    if method == "POST" && header_value(head, "content-length").is_none() {
        return Response::text(411, "length required: POST needs Content-Length\n");
    }
    let trace = header_value(head, "x-trace-id")
        .and_then(crate::obs::trace::sanitize)
        .unwrap_or_else(crate::obs::trace::mint);
    // Span timestamp is platform time at receipt; the dispatch below
    // may advance it.
    let at_ms = state.obs.as_ref().map(|o| o.now_ms()).unwrap_or(0);
    let t0 = std::time::Instant::now();
    crate::obs::trace::set_current(Some(trace.clone()));
    let mut resp = handle(state, method, path, body);
    crate::obs::trace::set_current(None);
    if let Some(obs) = state.obs.as_ref().filter(|o| o.enabled()) {
        let dur_ms = t0.elapsed().as_secs_f64() * 1000.0;
        let status = resp.status.to_string();
        obs.metrics.counter("nsml_http_requests_total", &[("status", &status)]).inc();
        obs.metrics.histogram("nsml_http_requests_ms", &[("route", route_group(path))]).record(dur_ms);
        let name = format!("http {} {}", method, path.split('?').next().unwrap_or(path));
        obs.traces.record(&trace, at_ms, dur_ms, &name, "web", &format!("status={}", status));
    }
    resp.trace = Some(trace);
    resp
}

/// Whether the client wants the connection kept open (HTTP/1.1 default
/// unless `Connection: close`; HTTP/1.0 only with an explicit
/// `Connection: keep-alive`).
fn wants_keepalive(head: &str) -> bool {
    let version =
        head.lines().next().unwrap_or("").split_whitespace().nth(2).unwrap_or("HTTP/1.1");
    let conn = header_value(head, "connection").unwrap_or("").to_ascii_lowercase();
    if conn.contains("close") {
        return false;
    }
    version != "HTTP/1.0" || conn.contains("keep-alive")
}

fn write_response(stream: &mut TcpStream, resp: &Response, keep_alive: bool) -> std::io::Result<()> {
    let mut out = format!(
        "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\n",
        resp.status,
        status_text(resp.status),
        resp.content_type,
        resp.body.len(),
    );
    if let Some(allow) = resp.allow {
        out.push_str(&format!("Allow: {}\r\n", allow));
    }
    if let Some(successor) = resp.deprecation {
        out.push_str("Deprecation: true\r\n");
        out.push_str(&format!("Link: <{}>; rel=\"successor-version\"\r\n", successor));
    }
    if let Some(trace) = &resp.trace {
        out.push_str(&format!("X-Trace-Id: {}\r\n", trace));
    }
    out.push_str(if keep_alive { "Connection: keep-alive\r\n" } else { "Connection: close\r\n" });
    out.push_str("\r\n");
    out.push_str(&resp.body);
    stream.write_all(out.as_bytes())
}

// ---------------------------------------------------------------------
// The pooled server
// ---------------------------------------------------------------------

/// Tuning knobs for [`serve_with`].
#[derive(Debug, Clone)]
pub struct ServeOpts {
    /// Worker threads handling connections (`[service] http_workers`).
    pub workers: usize,
    /// Keep-alive idle timeout before a worker recycles the socket
    /// (`[service] keepalive_ms`).
    pub keepalive: Duration,
    /// Concurrent SSE streams; each gets a dedicated thread so it
    /// never pins a pool worker (503 beyond the cap).
    pub max_sse_clients: usize,
}

impl Default for ServeOpts {
    fn default() -> ServeOpts {
        ServeOpts { workers: 8, keepalive: Duration::from_millis(500), max_sse_clients: 64 }
    }
}

/// A running pooled server. Dropping the handle leaves the server
/// running (threads are detached only at process exit); call
/// [`shutdown`](WebServer::shutdown) for a clean stop or
/// [`join`](WebServer::join) to serve forever.
pub struct WebServer {
    port: u16,
    stop: Arc<AtomicBool>,
    threads: Vec<std::thread::JoinHandle<()>>,
}

impl WebServer {
    pub fn port(&self) -> u16 {
        self.port
    }

    /// Signal every loop to exit and join the pool. In-flight
    /// responses finish; keep-alive sockets close at their next idle
    /// timeout; SSE streams notice the flag within one poll interval.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // Wake the blocking accept so it observes the flag.
        let _ = TcpStream::connect(("127.0.0.1", self.port));
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }

    /// Block on the accept loop (the CLI's serve-forever path).
    pub fn join(mut self) {
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

/// Serve with default [`ServeOpts`]. Returns once the listener is
/// bound; connections are handled by the worker pool.
pub fn serve(state: WebState, port: u16) -> std::io::Result<WebServer> {
    serve_with(state, port, ServeOpts::default())
}

/// Bounded worker pool + HTTP/1.1 keep-alive: one accept thread feeds
/// a channel; `opts.workers` threads pull connections and serve as
/// many requests per socket as the client pipelines before the
/// keep-alive timeout. SSE streams hop onto dedicated threads.
pub fn serve_with(state: WebState, port: u16, opts: ServeOpts) -> std::io::Result<WebServer> {
    let listener = TcpListener::bind(("127.0.0.1", port))?;
    let bound = listener.local_addr()?.port();
    let stop = Arc::new(AtomicBool::new(false));
    let (tx, rx) = mpsc::channel::<TcpStream>();
    let rx = Arc::new(Mutex::new(rx));
    let sse_clients = Arc::new(AtomicUsize::new(0));
    let mut threads = Vec::with_capacity(opts.workers + 1);
    {
        let stop = stop.clone();
        threads.push(std::thread::spawn(move || {
            for stream in listener.incoming() {
                if stop.load(Ordering::SeqCst) {
                    break;
                }
                let Ok(stream) = stream else { continue };
                if tx.send(stream).is_err() {
                    break;
                }
            }
        }));
    }
    for _ in 0..opts.workers.max(1) {
        let rx = rx.clone();
        let state = state.clone();
        let stop = stop.clone();
        let sse_clients = sse_clients.clone();
        let opts = opts.clone();
        threads.push(std::thread::spawn(move || loop {
            let next = rx.lock().unwrap().recv_timeout(Duration::from_millis(100));
            match next {
                Ok(stream) => handle_connection(stream, &state, &opts, &stop, &sse_clients),
                Err(mpsc::RecvTimeoutError::Timeout) => {
                    if stop.load(Ordering::SeqCst) {
                        break;
                    }
                }
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }));
    }
    Ok(WebServer { port: bound, stop, threads })
}

/// The pre-pool accept loop — one thread per connection, one request
/// per connection, `Connection: close`. Kept verbatim as the
/// `bench_web` baseline; everything else should use [`serve`].
pub fn serve_thread_per_conn(
    state: WebState,
    port: u16,
) -> std::io::Result<(u16, std::thread::JoinHandle<()>)> {
    let listener = TcpListener::bind(("127.0.0.1", port))?;
    let bound = listener.local_addr()?.port();
    let handle = std::thread::spawn(move || {
        for stream in listener.incoming() {
            let Ok(mut stream) = stream else { continue };
            let state = state.clone();
            std::thread::spawn(move || {
                let mut buf = Vec::new();
                if let Some((head, body)) = read_request(&mut stream, &mut buf) {
                    let resp = route_request(&state, &head, &body);
                    let _ = write_response(&mut stream, &resp, false);
                }
            });
        }
    });
    Ok((bound, handle))
}

/// One pooled connection: keep serving requests until the client
/// closes, goes idle past the keep-alive timeout, or asks for
/// `Connection: close`. The SSE route hands the socket to a dedicated
/// streaming thread and returns the worker to the pool.
fn handle_connection(
    mut stream: TcpStream,
    state: &WebState,
    opts: &ServeOpts,
    stop: &Arc<AtomicBool>,
    sse_clients: &Arc<AtomicUsize>,
) {
    let _ = stream.set_read_timeout(Some(opts.keepalive));
    let mut buf = Vec::new();
    while !stop.load(Ordering::SeqCst) {
        let Some((head, body)) = read_request(&mut stream, &mut buf) else { break };
        let first = head.lines().next().unwrap_or("");
        let mut parts = first.split_whitespace();
        let method = parts.next().unwrap_or("GET");
        let path = parts.next().unwrap_or("/");
        let (route, query) = path.split_once('?').unwrap_or((path, ""));
        if method == "GET" && percent_decode(route) == "/api/v1/events/stream" {
            let query = query.to_string();
            let head = head.clone();
            serve_sse(stream, state, &query, &head, opts, stop, sse_clients);
            return; // the socket now belongs to the stream (or is closed)
        }
        let resp = route_request(state, &head, &body);
        let keep = wants_keepalive(&head);
        if write_response(&mut stream, &resp, keep).is_err() || !keep {
            break;
        }
    }
}

/// `GET /api/v1/events/stream`: validate the filters, then hand the
/// socket to a dedicated thread that pushes one SSE frame per bus
/// event. Resume honors the standard `Last-Event-ID` header (or the
/// `last_event_id=` query parameter): the subscription starts at
/// `last_seen + 1`, replaying retained events before going live.
fn serve_sse(
    mut stream: TcpStream,
    state: &WebState,
    query: &str,
    head: &str,
    opts: &ServeOpts,
    stop: &Arc<AtomicBool>,
    sse_clients: &Arc<AtomicUsize>,
) {
    // Validate before committing to the stream: bad input gets a
    // normal JSON error response on the still-plain connection.
    let mut filter = EventFilter::default();
    let mut resume: Option<u64> = None;
    for (k, v) in parse_query(query) {
        match k.as_str() {
            "kind" => {
                if !ALL_EVENT_KINDS.contains(&v.as_str()) {
                    let resp = api_response(ApiResponse::Error {
                        error: ApiError::invalid(format!(
                            "events/stream: unknown event kind '{}'",
                            v
                        )),
                    });
                    let _ = write_response(&mut stream, &resp, false);
                    return;
                }
                filter.kind = Some(v);
            }
            "subject" => filter.subject = Some(v),
            "last_event_id" => match v.parse::<u64>() {
                Ok(n) => resume = Some(n),
                Err(_) => {
                    let resp = api_response(ApiResponse::Error {
                        error: ApiError::invalid(
                            "events/stream: 'last_event_id' must be a non-negative integer",
                        ),
                    });
                    let _ = write_response(&mut stream, &resp, false);
                    return;
                }
            },
            _ => {} // unknown parameters are ignored
        }
    }
    if let Some(h) = header_value(head, "last-event-id") {
        match h.parse::<u64>() {
            Ok(n) => resume = Some(n),
            Err(_) => {
                let resp = api_response(ApiResponse::Error {
                    error: ApiError::invalid(
                        "events/stream: Last-Event-ID must be a bus sequence number",
                    ),
                });
                let _ = write_response(&mut stream, &resp, false);
                return;
            }
        }
    }
    if sse_clients.fetch_add(1, Ordering::SeqCst) >= opts.max_sse_clients {
        sse_clients.fetch_sub(1, Ordering::SeqCst);
        let resp = Response::text(503, "too many event streams\n");
        let _ = write_response(&mut stream, &resp, false);
        return;
    }
    let bus = state.events.bus().clone();
    let stop = stop.clone();
    let sse_clients = sse_clients.clone();
    std::thread::spawn(move || {
        let mut sub = match resume {
            Some(last_seen) => bus.subscribe_from(last_seen + 1),
            None => bus.subscribe(),
        }
        .with_filter(filter);
        let _ = stream.set_read_timeout(None);
        let _ = sse_stream(&mut stream, &mut sub, &stop);
        sse_clients.fetch_sub(1, Ordering::SeqCst);
    });
}

/// The push loop: frames are `id:` (bus seq) / `event:` (kind name) /
/// `data:` (the event's JSON envelope). Idle periods emit comment
/// pings so dead clients are detected even when no events flow.
fn sse_stream(
    stream: &mut TcpStream,
    sub: &mut crate::events::Subscription,
    stop: &AtomicBool,
) -> std::io::Result<()> {
    stream.write_all(
        b"HTTP/1.1 200 OK\r\nContent-Type: text/event-stream\r\nCache-Control: no-cache\r\nConnection: close\r\n\r\n",
    )?;
    stream.flush()?;
    let mut idle_polls = 0u32;
    loop {
        if stop.load(Ordering::SeqCst) {
            return Ok(());
        }
        let events = sub.poll_max(256);
        if events.is_empty() {
            std::thread::sleep(Duration::from_millis(15));
            idle_polls += 1;
            if idle_polls >= 130 {
                // ~2s of silence: a comment ping flushes out dead
                // clients (the write fails once the peer is gone).
                idle_polls = 0;
                stream.write_all(b": ping\n\n")?;
                stream.flush()?;
            }
            continue;
        }
        idle_polls = 0;
        let mut frame = String::new();
        for e in &events {
            frame.push_str(&format!(
                "id: {}\nevent: {}\ndata: {}\n\n",
                e.seq,
                e.kind.name(),
                e.to_json()
            ));
        }
        stream.write_all(frame.as_bytes())?;
        stream.flush()?;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::events::{EventKind, Level};
    use crate::session::{SessionRecord, SessionSpec};
    use crate::util::clock::sim_clock;

    fn state() -> WebState {
        let (clock, _) = sim_clock();
        let events = EventLog::new(clock.clone()).with_echo(false);
        let sessions = SessionStore::new();
        let mut rec = SessionRecord::new(SessionSpec::new("kim/mnist/1", "kim", "mnist", "mnist_mlp"), 0);
        rec.steps_done = 50;
        rec.best_metric = Some(0.9);
        rec.metrics.log(10, "train_loss", 1.2);
        rec.metrics.log(20, "train_loss", 0.8);
        sessions.insert(rec);
        let leaderboard = Leaderboard::new();
        leaderboard.ensure_board("mnist", "accuracy", false);
        leaderboard.submit(
            "mnist",
            crate::leaderboard::Submission {
                session: "kim/mnist/1".into(),
                user: "kim".into(),
                model: "mnist_mlp".into(),
                metric_name: "accuracy".into(),
                value: 0.9,
                step: 50,
                at_ms: 1,
            },
        );
        let cluster = Cluster::homogeneous(clock, events.clone(), 2, 4, 24.0);
        WebState { sessions, leaderboard, cluster: Some(cluster), events, api: None, obs: None }
    }

    /// A stub service answering each request with `f` on its own
    /// thread, so routing tests run without a platform.
    fn stub_api<F>(f: F) -> ServiceHandle
    where
        F: Fn(&ApiRequest) -> ApiResponse + Send + 'static,
    {
        let (api, rx) = crate::api::service_channel();
        std::thread::spawn(move || {
            while let Ok(call) = rx.recv() {
                let resp = f(call.request());
                call.respond(resp);
            }
        });
        api
    }

    /// Read from `stream` into `acc` until `acc[from..]` contains
    /// `pat` (the stream's read timeout bounds the wait — no
    /// wall-clock sleeps).
    fn read_until(stream: &mut TcpStream, acc: &mut String, from: usize, pat: &str) {
        let mut buf = [0u8; 4096];
        while !acc[from..].contains(pat) {
            match stream.read(&mut buf) {
                Ok(0) => panic!("eof before '{}' in {:?}", pat, acc),
                Ok(n) => acc.push_str(&String::from_utf8_lossy(&buf[..n])),
                Err(e) => panic!("read waiting for '{}': {} (have {:?})", pat, e, acc),
            }
        }
    }

    #[test]
    fn dashboard_lists_sessions_and_boards() {
        let s = state();
        let r = handle(&s, "GET", "/", "");
        assert_eq!(r.status, 200);
        assert!(r.body.contains("kim/mnist/1"));
        assert!(r.body.contains("/board/mnist"));
        assert!(r.body.contains("8 GPUs") || r.body.contains("0/8"));
    }

    #[test]
    fn percent_encoded_paths_decode() {
        let s = state();
        // kim/mnist/1 with the slashes percent-encoded.
        let r = handle(&s, "GET", "/session/kim%2Fmnist%2F1", "");
        assert_eq!(r.status, 200);
        assert!(r.body.contains("mnist_mlp"));
        // Invalid escapes pass through untouched.
        assert_eq!(percent_decode("a%2Fb"), "a/b");
        assert_eq!(percent_decode("a%zzb"), "a%zzb");
        assert_eq!(percent_decode("100%"), "100%");
    }

    #[test]
    fn plot_svg_renders() {
        let s = state();
        let r = handle(&s, "GET", "/plot/kim/mnist/1.svg", "");
        assert_eq!(r.status, 200);
        assert!(r.body.starts_with("<svg"));
        assert!(r.body.contains("train_loss"));
    }

    #[test]
    fn board_html_renders() {
        let s = state();
        let h = handle(&s, "GET", "/board/mnist", "");
        assert!(h.body.contains("kim/mnist/1"));
    }

    #[test]
    fn legacy_aliases_match_v1_and_deprecate() {
        let api = stub_api(|req| match req {
            ApiRequest::ListSessions { limit, offset, user } => {
                // Aliases must dispatch the same wire defaults as the
                // bare v1 request.
                assert_eq!((*limit, *offset, user.as_deref()), (100, 0, None));
                ApiResponse::Sessions { sessions: vec![] }
            }
            ApiRequest::GetSession { session } if session == "kim/mnist/1" => {
                ApiResponse::Session {
                    session: crate::api::SessionView::from_record(&SessionRecord::new(
                        SessionSpec::new("kim/mnist/1", "kim", "mnist", "mnist_mlp"),
                        0,
                    )),
                }
            }
            ApiRequest::GetSession { session } => ApiResponse::Error {
                error: ApiError::not_found(format!("unknown session '{}'", session)),
            },
            ApiRequest::Board { dataset, .. } => {
                ApiResponse::Board { dataset: dataset.clone(), rows: vec![] }
            }
            ApiRequest::ClusterStatus => {
                ApiResponse::Ack { verb: "cluster_status".into(), session: None }
            }
            _ => ApiResponse::Sessions { sessions: vec![] },
        });
        let mut s = state();
        s.api = Some(api);

        // (alias, v1 method, v1 path, v1 body, successor route)
        let cases = [
            ("/api/sessions", "POST", "/api/v1/list_sessions", "", "/api/v1/sessions"),
            (
                "/api/session/kim%2Fmnist%2F1",
                "POST",
                "/api/v1/get_session",
                r#"{"session":"kim/mnist/1"}"#,
                "/api/v1/get_session",
            ),
            ("/api/board/mnist", "GET", "/api/v1/board?dataset=mnist", "", "/api/v1/board"),
            ("/api/cluster", "POST", "/api/v1/cluster_status", "", "/api/v1/cluster_status"),
        ];
        for (alias, v1_method, v1_path, v1_body, successor) in cases {
            let a = handle(&s, "GET", alias, "");
            let b = handle(&s, v1_method, v1_path, v1_body);
            assert_eq!(a.status, b.status, "{}", alias);
            assert_eq!(a.body, b.body, "alias body must byte-match v1: {}", alias);
            assert_eq!(a.content_type, "application/json", "{}", alias);
            assert_eq!(a.deprecation, Some(successor), "{}", alias);
            assert_eq!(b.deprecation, None, "{}", v1_path);
        }

        // Failures keep the uniform error envelope *and* the header.
        let miss = handle(&s, "GET", "/api/session/missing", "");
        assert_eq!(miss.status, 404);
        let j = crate::util::json::parse(&miss.body).unwrap();
        assert_eq!(j.at(&["data", "error", "code"]).unwrap().as_str(), Some("not_found"));
        assert_eq!(miss.deprecation, Some("/api/v1/get_session"));
    }

    #[test]
    fn sessions_query_route_paginates() {
        let api = stub_api(|req| match req {
            ApiRequest::ListSessions { limit, offset, user } => {
                assert_eq!(*limit, 5);
                assert_eq!(*offset, 10);
                assert_eq!(user.as_deref(), Some("kim"));
                ApiResponse::Sessions { sessions: vec![] }
            }
            _ => panic!("unexpected dispatch"),
        });
        let mut s = state();
        s.api = Some(api);
        let r = handle(&s, "GET", "/api/v1/sessions?limit=5&offset=10&user=kim", "");
        assert_eq!(r.status, 200);
        let j = crate::util::json::parse(&r.body).unwrap();
        assert_eq!(j.get("kind").unwrap().as_str(), Some("sessions"));
        // Bad paging values 400 before reaching the service.
        assert_eq!(handle(&s, "GET", "/api/v1/sessions?limit=lots", "").status, 400);
        assert_eq!(handle(&s, "GET", "/api/v1/sessions?offset=-1", "").status, 400);
    }

    #[test]
    fn unknown_api_routes_return_error_envelopes() {
        let s = state();
        // Plain text 404 outside the API surface…
        let r = handle(&s, "GET", "/nope", "");
        assert_eq!(r.status, 404);
        assert_eq!(r.content_type, "text/plain");
        // …but /api/* unknowns are machine-readable envelopes, even
        // with no service attached.
        for path in ["/api/nope", "/api/v1/frobnicate", "/api/session"] {
            let r = handle(&s, "GET", path, "");
            assert_eq!(r.status, 404, "{}", path);
            assert_eq!(r.content_type, "application/json", "{}", path);
            let j = crate::util::json::parse(&r.body).unwrap();
            assert_eq!(
                j.at(&["data", "error", "code"]).unwrap().as_str(),
                Some("unknown_route"),
                "{}",
                path
            );
        }
        // Known verbs under /api/v1/ still advertise POST.
        let r = handle(&s, "GET", "/api/v1/run", "");
        assert_eq!(r.status, 405);
        assert_eq!(r.allow, Some("POST"));
        // Exotic methods advertise both.
        let r = handle(&s, "DELETE", "/", "");
        assert_eq!(r.status, 405);
        assert_eq!(r.allow, Some("GET, POST"));
        // POST outside /api/v1/ -> 405 with Allow: GET.
        let r = handle(&s, "POST", "/", "");
        assert_eq!(r.status, 405);
        assert_eq!(r.allow, Some("GET"));
    }

    #[test]
    fn post_without_service_is_503() {
        let s = state();
        let r = handle(&s, "POST", "/api/v1/list_sessions", "");
        assert_eq!(r.status, 503);
        // Every dispatch-backed read route needs the service too —
        // including the deprecated aliases, which now re-route.
        assert_eq!(handle(&s, "GET", "/api/v1/executor", "").status, 503);
        assert_eq!(handle(&s, "GET", "/api/v1/events?since=0", "").status, 503);
        assert_eq!(handle(&s, "GET", "/api/v1/tenants", "").status, 503);
        assert_eq!(handle(&s, "GET", "/api/v1/durability", "").status, 503);
        assert_eq!(handle(&s, "GET", "/api/v1/service", "").status, 503);
        assert_eq!(handle(&s, "GET", "/api/v1/endpoints", "").status, 503);
        assert_eq!(handle(&s, "GET", "/api/v1/metrics", "").status, 503);
        assert_eq!(handle(&s, "GET", "/api/v1/trace/abc", "").status, 503);
        assert_eq!(handle(&s, "GET", "/metrics", "").status, 503);
        assert_eq!(handle(&s, "POST", "/api/v1/endpoints/x/infer", "{}").status, 503);
        assert_eq!(handle(&s, "GET", "/api/v1/board?dataset=mnist", "").status, 503);
        assert_eq!(handle(&s, "GET", "/api/v1/sessions", "").status, 503);
        assert_eq!(handle(&s, "GET", "/api/sessions", "").status, 503);
        assert_eq!(handle(&s, "GET", "/api/cluster", "").status, 503);
        assert_eq!(handle(&s, "GET", "/api/board/mnist", "").status, 503);
        assert_eq!(handle(&s, "GET", "/api/session/kim%2Fmnist%2F1", "").status, 503);
    }

    #[test]
    fn tenants_and_board_routes_dispatch_queries() {
        use crate::api::TenantView;
        let api = stub_api(|req| match req {
            ApiRequest::TenantReport => ApiResponse::Tenants {
                tenants: vec![TenantView {
                    user: "kim".into(),
                    weight: 2,
                    class: "high".into(),
                    max_concurrent: 3,
                    max_gpus: 8,
                    gpu_second_budget: 60.0,
                    gpu_seconds_used: 12.5,
                    active_sessions: 1,
                    gpus_in_use: 2,
                    waiting: 1,
                    preemptions: 1,
                }],
            },
            ApiRequest::Board { dataset, limit, user } => {
                assert_eq!(dataset, "mnist");
                assert_eq!(*limit, 5);
                assert_eq!(user.as_deref(), Some("kim"));
                ApiResponse::Board { dataset: dataset.clone(), rows: vec![] }
            }
            _ => ApiResponse::Sessions { sessions: vec![] },
        });
        let mut s = state();
        s.api = Some(api);
        let r = handle(&s, "GET", "/api/v1/tenants", "");
        assert_eq!(r.status, 200);
        let j = crate::util::json::parse(&r.body).unwrap();
        assert_eq!(j.get("kind").unwrap().as_str(), Some("tenants"));
        let tenants = j.at(&["data", "tenants"]).unwrap().as_arr().unwrap();
        assert_eq!(tenants[0].get("user").unwrap().as_str(), Some("kim"));
        assert_eq!(tenants[0].get("waiting").unwrap().as_i64(), Some(1));

        let r = handle(&s, "GET", "/api/v1/board?dataset=mnist&user=kim&limit=5", "");
        assert_eq!(r.status, 200);
        let j = crate::util::json::parse(&r.body).unwrap();
        assert_eq!(j.get("kind").unwrap().as_str(), Some("board"));
        // Bad limit 400s before reaching the service; a missing
        // dataset is rejected by the wire layer.
        assert_eq!(handle(&s, "GET", "/api/v1/board?dataset=mnist&limit=soon", "").status, 400);
        assert_eq!(handle(&s, "GET", "/api/v1/board?user=kim", "").status, 400);
    }

    #[test]
    fn endpoint_routes_dispatch_serving_verbs() {
        let api = stub_api(|req| match req {
            ApiRequest::Endpoints => ApiResponse::Endpoints { endpoints: vec![] },
            ApiRequest::ServeInfer { endpoint, user, x } => {
                assert_eq!(endpoint, "mnist-prod");
                assert_eq!(user, "kim");
                assert_eq!(x, &[0.1, 0.2, 0.3]);
                ApiResponse::Served {
                    endpoint: endpoint.clone(),
                    version: 2,
                    batch: 1,
                    probs: vec![0.5, 0.5],
                }
            }
            _ => panic!("unexpected dispatch"),
        });
        let mut s = state();
        s.api = Some(api);
        let r = handle(&s, "GET", "/api/v1/endpoints", "");
        assert_eq!(r.status, 200);
        let j = crate::util::json::parse(&r.body).unwrap();
        assert_eq!(j.get("kind").unwrap().as_str(), Some("endpoints"));

        // The path names the endpoint; the body carries user + input.
        let r = handle(
            &s,
            "POST",
            "/api/v1/endpoints/mnist-prod/infer",
            r#"{"user":"kim","x":[0.1,0.2,0.3]}"#,
        );
        assert_eq!(r.status, 200);
        let j = crate::util::json::parse(&r.body).unwrap();
        assert_eq!(j.get("kind").unwrap().as_str(), Some("served"));
        assert_eq!(j.at(&["data", "version"]).unwrap().as_i64(), Some(2));
        assert_eq!(j.at(&["data", "batch"]).unwrap().as_i64(), Some(1));

        // A body missing `x` is rejected by the wire layer before any
        // dispatch reaches the stub (which would panic on it).
        let r = handle(&s, "POST", "/api/v1/endpoints/mnist-prod/infer", r#"{"user":"kim"}"#);
        assert_eq!(r.status, 400);
        // GET on the infer route advertises POST.
        let r = handle(&s, "GET", "/api/v1/endpoints/mnist-prod/infer", "");
        assert_eq!(r.status, 404, "unknown GET route keeps the uniform envelope");
    }

    #[test]
    fn durability_route_serves_wal_counters() {
        use crate::api::DurabilityView;
        let api = stub_api(|req| match req {
            ApiRequest::DurabilityStatus => ApiResponse::Durability {
                durability: DurabilityView {
                    enabled: true,
                    wal_records: 7,
                    wal_bytes: 1024,
                    wal_last_seq: Some(41),
                    records_since_snapshot: 7,
                    snapshot_every: 512,
                    snapshots: 2,
                    last_snapshot_seq: 34,
                    wal_dropped: 0,
                    consumer_dropped: 0,
                    gc_enabled: true,
                    gc_live_objects: 10,
                    gc_live_bytes: 4096,
                    gc_swept_objects: 1,
                    gc_swept_bytes: 128,
                },
            },
            _ => ApiResponse::Sessions { sessions: vec![] },
        });
        let mut s = state();
        s.api = Some(api);
        let r = handle(&s, "GET", "/api/v1/durability", "");
        assert_eq!(r.status, 200);
        let j = crate::util::json::parse(&r.body).unwrap();
        assert_eq!(j.get("kind").unwrap().as_str(), Some("durability"));
        assert_eq!(j.at(&["data", "durability", "wal_records"]).unwrap().as_i64(), Some(7));
        assert_eq!(j.at(&["data", "durability", "snapshots"]).unwrap().as_i64(), Some(2));
        assert_eq!(j.at(&["data", "durability", "wal_last_seq"]).unwrap().as_i64(), Some(41));
    }

    #[test]
    fn service_route_serves_loop_counters() {
        use crate::api::ServiceStatusView;
        let api = stub_api(|req| match req {
            ApiRequest::ServiceStatus => ApiResponse::Service {
                service: ServiceStatusView {
                    running: true,
                    rounds: 12,
                    last_round_ms: 1.5,
                    rounds_per_sec: 80.0,
                    progressed_total: 30,
                    dispatches: 4,
                },
            },
            _ => ApiResponse::Sessions { sessions: vec![] },
        });
        let mut s = state();
        s.api = Some(api);
        let r = handle(&s, "GET", "/api/v1/service", "");
        assert_eq!(r.status, 200);
        let j = crate::util::json::parse(&r.body).unwrap();
        assert_eq!(j.get("kind").unwrap().as_str(), Some("service"));
        assert_eq!(j.at(&["data", "service", "rounds"]).unwrap().as_i64(), Some(12));
        assert_eq!(j.at(&["data", "service", "running"]).unwrap().as_bool(), Some(true));
    }

    #[test]
    fn events_route_pages_cursor_reads() {
        use crate::events::Event;
        let api = stub_api(|req| match req {
            ApiRequest::EventsSince { since, kind, subject, limit } => {
                assert_eq!(*since, 5);
                assert_eq!(kind.as_deref(), Some("state"));
                assert_eq!(subject.as_deref(), Some("kim/mnist/1"));
                assert_eq!(*limit, 2);
                ApiResponse::Events {
                    events: vec![Event {
                        seq: 6,
                        at_ms: 100,
                        level: Level::Info,
                        source: "session".into(),
                        subject: "kim/mnist/1".into(),
                        kind: EventKind::StateChanged {
                            from: "running".into(),
                            to: "done".into(),
                            step: 40,
                        },
                    }],
                    next: 7,
                    dropped: 0,
                    overflow: 0,
                }
            }
            _ => ApiResponse::Sessions { sessions: vec![] },
        });
        let mut s = state();
        s.api = Some(api);
        // Subject slashes travel percent-encoded in the query string.
        let r = handle(
            &s,
            "GET",
            "/api/v1/events?since=5&kind=state&subject=kim%2Fmnist%2F1&limit=2",
            "",
        );
        assert_eq!(r.status, 200);
        let j = crate::util::json::parse(&r.body).unwrap();
        assert_eq!(j.get("kind").unwrap().as_str(), Some("events"));
        assert_eq!(j.at(&["data", "next"]).unwrap().as_i64(), Some(7));
        let events = j.at(&["data", "events"]).unwrap().as_arr().unwrap();
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].get("kind").unwrap().as_str(), Some("state"));
        assert_eq!(events[0].at(&["data", "to"]).unwrap().as_str(), Some("done"));
        // Rendered message rides along for dumb consumers.
        assert!(events[0].get("message").unwrap().as_str().unwrap().contains("done"));
        // Bad cursor values 400 before reaching the service.
        let bad = handle(&s, "GET", "/api/v1/events?since=yesterday", "");
        assert_eq!(bad.status, 400);
    }

    #[test]
    fn executor_route_serves_worker_telemetry() {
        use crate::api::{ExecutorStats, WorkerStatView};
        let api = stub_api(|req| match req {
            ApiRequest::ExecutorStatus => ApiResponse::Executor {
                executor: ExecutorStats {
                    workers: vec![
                        WorkerStatView {
                            worker: 0,
                            live_sessions: 2,
                            queue_depth: 0,
                            steals: 0,
                            busy_ms: 12.5,
                        },
                        WorkerStatView {
                            worker: 1,
                            live_sessions: 2,
                            queue_depth: 0,
                            steals: 2,
                            busy_ms: 11.0,
                        },
                    ],
                    live_sessions: 4,
                    queue_depth: 0,
                    total_steals: 2,
                    work_steal: true,
                },
            },
            _ => ApiResponse::Sessions { sessions: vec![] },
        });
        let mut s = state();
        s.api = Some(api);
        let r = handle(&s, "GET", "/api/v1/executor", "");
        assert_eq!(r.status, 200);
        let j = crate::util::json::parse(&r.body).unwrap();
        assert_eq!(j.get("kind").unwrap().as_str(), Some("executor"));
        assert_eq!(j.at(&["data", "executor", "total_steals"]).unwrap().as_i64(), Some(2));
        let workers = j.at(&["data", "executor", "workers"]).unwrap().as_arr().unwrap();
        assert_eq!(workers.len(), 2);
        assert_eq!(workers[1].get("steals").unwrap().as_i64(), Some(2));
        // Other GET paths under /api/v1/ still require POST.
        assert_eq!(handle(&s, "GET", "/api/v1/cluster_status", "").status, 405);
    }

    #[test]
    fn post_with_service_dispatches_and_maps_errors() {
        let api = stub_api(|req| match req {
            ApiRequest::GetSession { session } => ApiResponse::Error {
                error: ApiError::not_found(format!("unknown session '{}'", session)),
            },
            _ => ApiResponse::Sessions { sessions: vec![] },
        });
        let mut s = state();
        s.api = Some(api);

        let ok = handle(&s, "POST", "/api/v1/list_sessions", "");
        assert_eq!(ok.status, 200);
        let j = crate::util::json::parse(&ok.body).unwrap();
        assert_eq!(j.get("kind").unwrap().as_str(), Some("sessions"));

        let nf = handle(&s, "POST", "/api/v1/get_session", r#"{"session":"missing"}"#);
        assert_eq!(nf.status, 404);
        assert!(nf.body.contains("not_found"));

        // Bad args never reach the service: 400 straight from the wire layer.
        let bad = handle(&s, "POST", "/api/v1/pause", "{}");
        assert_eq!(bad.status, 400);
        let garbled = handle(&s, "POST", "/api/v1/pause", "{not json");
        assert_eq!(garbled.status, 400);
        let unknown = handle(&s, "POST", "/api/v1/frobnicate", "");
        assert_eq!(unknown.status, 400);
    }

    #[test]
    fn pooled_server_reuses_keep_alive_connections() {
        let api = stub_api(|req| match req {
            ApiRequest::ListSessions { .. } => ApiResponse::Sessions { sessions: vec![] },
            _ => ApiResponse::Sessions { sessions: vec![] },
        });
        let mut s = state();
        s.api = Some(api);
        let srv = serve_with(s, 0, ServeOpts { workers: 2, ..ServeOpts::default() }).unwrap();

        // Two requests over ONE socket: the pooled server must answer
        // both without the client reconnecting.
        let mut stream = TcpStream::connect(("127.0.0.1", srv.port())).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        let mut acc = String::new();
        write!(stream, "GET /api/v1/sessions HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        read_until(&mut stream, &mut acc, 0, "\"kind\":\"sessions\"");
        assert!(acc.contains("HTTP/1.1 200"));
        assert!(acc.contains("Connection: keep-alive"));

        let mark = acc.len();
        write!(stream, "GET / HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        read_until(&mut stream, &mut acc, mark, "NSML dashboard");
        assert!(acc[mark..].contains("HTTP/1.1 200"));

        // An explicit close is honored.
        let mark = acc.len();
        write!(stream, "GET / HTTP/1.1\r\nHost: x\r\nConnection: close\r\n\r\n").unwrap();
        read_until(&mut stream, &mut acc, mark, "Connection: close");
        srv.shutdown();
    }

    #[test]
    fn sse_stream_delivers_and_resumes() {
        let s = state();
        let bus = s.events.bus().clone();
        let srv = serve_with(s, 0, ServeOpts { workers: 2, ..ServeOpts::default() }).unwrap();
        let port = srv.port();

        let mut c1 = TcpStream::connect(("127.0.0.1", port)).unwrap();
        c1.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        let mut acc = String::new();
        write!(c1, "GET /api/v1/events/stream?kind=log HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        read_until(&mut c1, &mut acc, 0, "\r\n\r\n");
        assert!(acc.contains("HTTP/1.1 200"));
        assert!(acc.contains("text/event-stream"));

        // An event published *after* subscribing is pushed to the
        // client — no polling involved.
        let first =
            bus.publish(Level::Info, "test", "s1", EventKind::LogLine { message: "hello".into() });
        read_until(&mut c1, &mut acc, 0, "hello");
        assert!(acc.contains(&format!("id: {}", first)));
        assert!(acc.contains("event: log"));
        drop(c1);

        // Events published while disconnected replay on resume via
        // Last-Event-ID.
        let second =
            bus.publish(Level::Info, "test", "s1", EventKind::LogLine { message: "again".into() });
        let mut c2 = TcpStream::connect(("127.0.0.1", port)).unwrap();
        c2.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        let mut acc = String::new();
        write!(
            c2,
            "GET /api/v1/events/stream HTTP/1.1\r\nHost: x\r\nLast-Event-ID: {}\r\n\r\n",
            first
        )
        .unwrap();
        read_until(&mut c2, &mut acc, 0, "again");
        assert!(acc.contains(&format!("id: {}", second)));
        assert!(!acc.contains("hello"), "resume must skip already-seen events");
        drop(c2);

        // Bad filters are rejected before the stream starts.
        let mut c3 = TcpStream::connect(("127.0.0.1", port)).unwrap();
        c3.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        let mut acc = String::new();
        write!(c3, "GET /api/v1/events/stream?kind=bogus HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        read_until(&mut c3, &mut acc, 0, "invalid_argument");
        assert!(acc.contains("HTTP/1.1 400"));
        srv.shutdown();
    }

    #[test]
    fn metrics_route_renders_prometheus_text() {
        let mut s = state();
        let (clock, _) = sim_clock();
        let obs = crate::obs::Obs::new(clock, true, 64);
        obs.metrics.counter("nsml_http_requests_total", &[("status", "200")]).inc();
        s.obs = Some(obs);
        let r = handle(&s, "GET", "/metrics", "");
        assert_eq!(r.status, 200);
        assert!(r.content_type.starts_with("text/plain; version=0.0.4"), "{}", r.content_type);
        assert!(r.body.contains("nsml_http_requests_total"), "{}", r.body);
        // The trace route answers a trace envelope through the service.
        let api = stub_api(|req| match req {
            ApiRequest::Trace { id } => ApiResponse::Trace {
                trace: crate::api::TraceView { id: id.clone(), spans: vec![] },
            },
            ApiRequest::MetricsReport => ApiResponse::Metrics {
                metrics: crate::api::MetricsReportView { enabled: true, ..Default::default() },
            },
            _ => ApiResponse::Sessions { sessions: vec![] },
        });
        s.api = Some(api);
        let r = handle(&s, "GET", "/api/v1/trace/abc-123", "");
        assert_eq!(r.status, 200);
        let j = crate::util::json::parse(&r.body).unwrap();
        assert_eq!(j.get("kind").unwrap().as_str(), Some("trace"));
        assert_eq!(j.at(&["data", "trace", "id"]).unwrap().as_str(), Some("abc-123"));
        let r = handle(&s, "GET", "/api/v1/metrics", "");
        assert_eq!(r.status, 200);
        let j = crate::util::json::parse(&r.body).unwrap();
        assert_eq!(j.get("kind").unwrap().as_str(), Some("metrics"));
    }

    #[test]
    fn http_requests_join_the_trace_and_registry() {
        let api = stub_api(|_| ApiResponse::Sessions { sessions: vec![] });
        let mut s = state();
        s.api = Some(api);
        let (clock, _) = sim_clock();
        let obs = crate::obs::Obs::new(clock, true, 64);
        s.obs = Some(obs.clone());
        let srv = serve_with(s, 0, ServeOpts { workers: 2, ..ServeOpts::default() }).unwrap();
        let mut stream = TcpStream::connect(("127.0.0.1", srv.port())).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        let mut acc = String::new();
        write!(stream, "GET /api/v1/sessions HTTP/1.1\r\nHost: x\r\nX-Trace-Id: web-t1\r\n\r\n")
            .unwrap();
        read_until(&mut stream, &mut acc, 0, "\"kind\":\"sessions\"");
        // The caller's trace id is echoed and carries the http span.
        assert!(acc.contains("X-Trace-Id: web-t1"), "{}", acc);
        let spans = obs.traces.get("web-t1");
        assert_eq!(spans.len(), 1, "{:?}", spans);
        assert_eq!(spans[0].name, "http GET /api/v1/sessions");
        assert_eq!(spans[0].source, "web");
        let snap = obs.metrics.snapshot();
        assert!(snap.counters.iter().any(|c| c.name == "nsml_http_requests_total"));
        assert!(snap.histograms.iter().any(|h| h.name == "nsml_http_requests_ms"));
        // A request without the header gets a minted id echoed back.
        let mark = acc.len();
        write!(stream, "GET / HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        read_until(&mut stream, &mut acc, mark, "NSML dashboard");
        assert!(acc[mark..].contains("X-Trace-Id: "), "{}", &acc[mark..]);
        // And /metrics over the wire exposes the counters just recorded.
        let mark = acc.len();
        write!(stream, "GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        read_until(&mut stream, &mut acc, mark, "nsml_http_requests_total");
        srv.shutdown();
    }

    #[test]
    fn thread_per_conn_baseline_still_serves() {
        let s = state();
        let (port, _h) = serve_thread_per_conn(s, 0).unwrap();
        let mut stream = TcpStream::connect(("127.0.0.1", port)).unwrap();
        write!(stream, "GET / HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        let mut out = String::new();
        stream.read_to_string(&mut out).unwrap();
        assert!(out.starts_with("HTTP/1.1 200"));
        assert!(out.contains("NSML dashboard"));
        assert!(out.contains("Connection: close"));
    }
}
