//! Web UI (paper §3.2): "The *web UI* wraps NSML-CLI in a web application
//! and is more intuitive … provides visualizations such as graphs, logs,
//! and demos."
//!
//! nginx is unavailable offline, so this is a from-scratch minimal
//! HTTP/1.1 server (std TcpListener + a thread per connection) exposing:
//!
//! * `GET /`                     — HTML dashboard (sessions, cluster, boards)
//! * `GET /board/<dataset>`      — HTML leaderboard
//! * `GET /session/<id…>`        — HTML session page with SVG curves
//! * `GET /plot/<id…>.svg`       — standalone SVG learning curves
//! * `GET /api/sessions`         — JSON
//! * `GET /api/session/<id…>`    — JSON (with metrics)
//! * `GET /api/board/<dataset>`  — JSON
//! * `GET /api/cluster`          — JSON
//!
//! Routing logic is a pure function ([`handle`]) so tests exercise it
//! without sockets.

use crate::cluster::Cluster;
use crate::events::EventLog;
use crate::leaderboard::Leaderboard;
use crate::session::{SessionRecord, SessionStore};
use crate::util::json::Json;
use crate::util::plot::{svg_chart, xml_escape, Series};
use std::io::{Read, Write};
use std::net::TcpListener;

/// Shareable snapshot handles the server reads from (all thread-safe).
#[derive(Clone)]
pub struct WebState {
    pub sessions: SessionStore,
    pub leaderboard: Leaderboard,
    pub cluster: Option<Cluster>,
    pub events: EventLog,
}

/// An HTTP response.
pub struct Response {
    pub status: u16,
    pub content_type: &'static str,
    pub body: String,
}

impl Response {
    fn html(body: String) -> Response {
        Response { status: 200, content_type: "text/html; charset=utf-8", body }
    }

    fn json(j: Json) -> Response {
        Response { status: 200, content_type: "application/json", body: j.to_string() }
    }

    fn svg(body: String) -> Response {
        Response { status: 200, content_type: "image/svg+xml", body }
    }

    fn not_found(msg: &str) -> Response {
        Response { status: 404, content_type: "text/plain", body: format!("not found: {}\n", msg) }
    }
}

/// Route a request (pure; no I/O).
pub fn handle(state: &WebState, method: &str, path: &str) -> Response {
    if method != "GET" {
        return Response { status: 405, content_type: "text/plain", body: "only GET\n".into() };
    }
    let path = path.split('?').next().unwrap_or(path);
    match path {
        "/" => Response::html(dashboard_html(state)),
        "/api/sessions" => Response::json(sessions_json(state)),
        "/api/cluster" => Response::json(cluster_json(state)),
        p if p.starts_with("/api/board/") => {
            let ds = &p["/api/board/".len()..];
            board_json(state, ds)
        }
        p if p.starts_with("/api/session/") => {
            let id = &p["/api/session/".len()..];
            match state.sessions.get(id) {
                Some(rec) => Response::json(session_json(&rec, true)),
                None => Response::not_found(id),
            }
        }
        p if p.starts_with("/plot/") && p.ends_with(".svg") => {
            let id = &p["/plot/".len()..p.len() - 4];
            match state.sessions.get(id) {
                Some(rec) => Response::svg(session_svg(&rec)),
                None => Response::not_found(id),
            }
        }
        p if p.starts_with("/board/") => {
            let ds = &p["/board/".len()..];
            Response::html(board_html(state, ds))
        }
        p if p.starts_with("/session/") => {
            let id = &p["/session/".len()..];
            match state.sessions.get(id) {
                Some(rec) => Response::html(session_html(&rec)),
                None => Response::not_found(id),
            }
        }
        other => Response::not_found(other),
    }
}

// ---------------------------------------------------------------------
// JSON views
// ---------------------------------------------------------------------

fn session_json(rec: &SessionRecord, with_metrics: bool) -> Json {
    let mut o = Json::obj();
    o.set("id", rec.spec.id.as_str().into())
        .set("user", rec.spec.user.as_str().into())
        .set("dataset", rec.spec.dataset.as_str().into())
        .set("model", rec.spec.model.as_str().into())
        .set("state", rec.state.as_str().into())
        .set("steps_done", rec.steps_done.into())
        .set("total_steps", rec.spec.total_steps.into())
        .set("lr", rec.spec.lr.into())
        .set("best_metric", rec.best_metric.map(Json::Num).unwrap_or(Json::Null))
        .set("recoveries", (rec.recoveries as u64).into());
    if with_metrics {
        let mut metrics = Json::obj();
        for name in rec.metrics.names() {
            let pts: Vec<Json> = rec
                .metrics
                .series(&name)
                .into_iter()
                .map(|(s, v)| Json::Arr(vec![s.into(), v.into()]))
                .collect();
            metrics.set(&name, Json::Arr(pts));
        }
        o.set("metrics", metrics);
    }
    o
}

fn sessions_json(state: &WebState) -> Json {
    Json::Arr(state.sessions.list().iter().map(|r| session_json(r, false)).collect())
}

fn cluster_json(state: &WebState) -> Json {
    let mut o = Json::obj();
    match &state.cluster {
        None => {
            o.set("available", false.into());
        }
        Some(c) => {
            let (total, free) = c.gpu_totals();
            let nodes: Vec<Json> = c
                .snapshot()
                .iter()
                .map(|n| {
                    let mut j = Json::obj();
                    j.set("hostname", n.hostname.as_str().into())
                        .set("alive", n.alive.into())
                        .set("total_gpus", n.total_gpus.into())
                        .set("free_gpus", n.free_gpus.into())
                        .set("jobs", Json::Arr(n.jobs.iter().map(|s| Json::Str(s.clone())).collect()));
                    j
                })
                .collect();
            o.set("available", true.into())
                .set("total_gpus", total.into())
                .set("free_gpus", free.into())
                .set("utilization", c.utilization().into())
                .set("nodes", Json::Arr(nodes));
        }
    }
    o
}

fn board_json(state: &WebState, dataset: &str) -> Response {
    if !state.leaderboard.datasets().contains(&dataset.to_string()) {
        return Response::not_found(dataset);
    }
    let rows: Vec<Json> = state
        .leaderboard
        .top(dataset, 100)
        .iter()
        .enumerate()
        .map(|(i, s)| {
            let mut o = Json::obj();
            o.set("rank", (i + 1).into())
                .set("session", s.session.as_str().into())
                .set("user", s.user.as_str().into())
                .set("model", s.model.as_str().into())
                .set("metric", s.metric_name.as_str().into())
                .set("value", s.value.into())
                .set("step", s.step.into());
            o
        })
        .collect();
    Response::json(Json::Arr(rows))
}

// ---------------------------------------------------------------------
// HTML views
// ---------------------------------------------------------------------

const STYLE: &str = "<style>body{font-family:monospace;margin:2em;background:#fafafa}
table{border-collapse:collapse}td,th{border:1px solid #ccc;padding:4px 10px;text-align:left}
th{background:#eee}h1,h2{color:#234}a{color:#1a6}</style>";

fn page(title: &str, body: String) -> String {
    format!(
        "<!doctype html><html><head><title>{}</title>{}</head><body><h1>{}</h1>{}</body></html>",
        xml_escape(title),
        STYLE,
        xml_escape(title),
        body
    )
}

fn dashboard_html(state: &WebState) -> String {
    let mut body = String::new();
    if let Some(c) = &state.cluster {
        let (total, free) = c.gpu_totals();
        body.push_str(&format!(
            "<p>cluster: {} nodes alive, {}/{} GPUs in use ({:.0}% utilization)</p>",
            c.alive_count(),
            total - free,
            total,
            c.utilization() * 100.0
        ));
    }
    body.push_str("<h2>Sessions</h2><table><tr><th>session</th><th>state</th><th>steps</th><th>best metric</th><th>plot</th></tr>");
    for r in state.sessions.list() {
        body.push_str(&format!(
            "<tr><td><a href=\"/session/{id}\">{id}</a></td><td>{}</td><td>{}/{}</td><td>{}</td><td><a href=\"/plot/{id}.svg\">svg</a></td></tr>",
            r.state.as_str(),
            r.steps_done,
            r.spec.total_steps,
            r.best_metric.map(|v| format!("{:.4}", v)).unwrap_or_else(|| "-".into()),
            id = xml_escape(&r.spec.id),
        ));
    }
    body.push_str("</table><h2>Leaderboards</h2><ul>");
    for ds in state.leaderboard.datasets() {
        body.push_str(&format!("<li><a href=\"/board/{0}\">{0}</a> ({1} entries)</li>", ds, state.leaderboard.board_len(&ds)));
    }
    body.push_str("</ul>");
    page("NSML dashboard", body)
}

fn board_html(state: &WebState, dataset: &str) -> String {
    let mut body = String::from("<table><tr><th>rank</th><th>session</th><th>user</th><th>model</th><th>value</th><th>step</th></tr>");
    for (i, s) in state.leaderboard.top(dataset, 100).iter().enumerate() {
        body.push_str(&format!(
            "<tr><td>{0}</td><td><a href=\"/session/{1}\">{1}</a></td><td>{2}</td><td>{3}</td><td>{4:.4}</td><td>{5}</td></tr>",
            i + 1,
            xml_escape(&s.session),
            xml_escape(&s.user),
            xml_escape(&s.model),
            s.value,
            s.step
        ));
    }
    body.push_str("</table><p><a href=\"/\">back</a></p>");
    page(&format!("leaderboard: {}", dataset), body)
}

fn session_svg(rec: &SessionRecord) -> String {
    let series: Vec<Series> =
        rec.metrics.names().iter().map(|n| rec.metrics.plot_series(n)).collect();
    svg_chart(&rec.spec.id, &series, 640, 360)
}

fn session_html(rec: &SessionRecord) -> String {
    let mut body = format!(
        "<p>state: {} | steps: {}/{} | lr: {} | model: {} | dataset: {}</p>",
        rec.state.as_str(),
        rec.steps_done,
        rec.spec.total_steps,
        rec.spec.lr,
        xml_escape(&rec.spec.model),
        xml_escape(&rec.spec.dataset)
    );
    body.push_str(&session_svg(rec));
    body.push_str("<p><a href=\"/\">back</a></p>");
    page(&rec.spec.id.clone(), body)
}

// ---------------------------------------------------------------------
// The actual server
// ---------------------------------------------------------------------

/// Serve until the process exits. Returns the bound port.
pub fn serve(state: WebState, port: u16) -> std::io::Result<(u16, std::thread::JoinHandle<()>)> {
    let listener = TcpListener::bind(("127.0.0.1", port))?;
    let bound = listener.local_addr()?.port();
    let handle = std::thread::spawn(move || {
        for stream in listener.incoming() {
            let Ok(mut stream) = stream else { continue };
            let state = state.clone();
            std::thread::spawn(move || {
                let mut buf = [0u8; 8192];
                let mut req = Vec::new();
                // Read until end of headers (GET only; no bodies).
                loop {
                    match stream.read(&mut buf) {
                        Ok(0) | Err(_) => break,
                        Ok(n) => {
                            req.extend_from_slice(&buf[..n]);
                            if req.windows(4).any(|w| w == b"\r\n\r\n") || req.len() > 64 * 1024 {
                                break;
                            }
                        }
                    }
                }
                let text = String::from_utf8_lossy(&req);
                let mut parts = text.lines().next().unwrap_or("").split_whitespace();
                let method = parts.next().unwrap_or("GET").to_string();
                let path = parts.next().unwrap_or("/").to_string();
                let resp = handle(&state, &method, &path);
                let _ = write!(
                    stream,
                    "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{}",
                    resp.status,
                    if resp.status == 200 { "OK" } else { "Not Found" },
                    resp.content_type,
                    resp.body.len(),
                    resp.body
                );
            });
        }
    });
    Ok((bound, handle))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::session::{SessionRecord, SessionSpec};
    use crate::util::clock::sim_clock;

    fn state() -> WebState {
        let (clock, _) = sim_clock();
        let events = EventLog::new(clock.clone()).with_echo(false);
        let sessions = SessionStore::new();
        let mut rec = SessionRecord::new(SessionSpec::new("kim/mnist/1", "kim", "mnist", "mnist_mlp"), 0);
        rec.steps_done = 50;
        rec.best_metric = Some(0.9);
        rec.metrics.log(10, "train_loss", 1.2);
        rec.metrics.log(20, "train_loss", 0.8);
        sessions.insert(rec);
        let leaderboard = Leaderboard::new();
        leaderboard.ensure_board("mnist", "accuracy", false);
        leaderboard.submit(
            "mnist",
            crate::leaderboard::Submission {
                session: "kim/mnist/1".into(),
                user: "kim".into(),
                model: "mnist_mlp".into(),
                metric_name: "accuracy".into(),
                value: 0.9,
                step: 50,
                at_ms: 1,
            },
        );
        let cluster = Cluster::homogeneous(clock, events.clone(), 2, 4, 24.0);
        WebState { sessions, leaderboard, cluster: Some(cluster), events }
    }

    #[test]
    fn dashboard_lists_sessions_and_boards() {
        let s = state();
        let r = handle(&s, "GET", "/");
        assert_eq!(r.status, 200);
        assert!(r.body.contains("kim/mnist/1"));
        assert!(r.body.contains("/board/mnist"));
        assert!(r.body.contains("8 GPUs") || r.body.contains("0/8"));
    }

    #[test]
    fn api_sessions_json_parses() {
        let s = state();
        let r = handle(&s, "GET", "/api/sessions");
        let j = crate::util::json::parse(&r.body).unwrap();
        let arr = j.as_arr().unwrap();
        assert_eq!(arr.len(), 1);
        assert_eq!(arr[0].get("state").unwrap().as_str(), Some("queued"));
    }

    #[test]
    fn api_session_detail_has_metrics() {
        let s = state();
        let r = handle(&s, "GET", "/api/session/kim/mnist/1");
        let j = crate::util::json::parse(&r.body).unwrap();
        let pts = j.at(&["metrics", "train_loss"]).unwrap().as_arr().unwrap();
        assert_eq!(pts.len(), 2);
    }

    #[test]
    fn plot_svg_renders() {
        let s = state();
        let r = handle(&s, "GET", "/plot/kim/mnist/1.svg");
        assert_eq!(r.status, 200);
        assert!(r.body.starts_with("<svg"));
        assert!(r.body.contains("train_loss"));
    }

    #[test]
    fn board_json_and_html() {
        let s = state();
        let j = handle(&s, "GET", "/api/board/mnist");
        assert_eq!(j.status, 200);
        assert!(j.body.contains("\"rank\":1"));
        let h = handle(&s, "GET", "/board/mnist");
        assert!(h.body.contains("kim/mnist/1"));
        assert_eq!(handle(&s, "GET", "/api/board/nope").status, 404);
    }

    #[test]
    fn cluster_json() {
        let s = state();
        let r = handle(&s, "GET", "/api/cluster");
        let j = crate::util::json::parse(&r.body).unwrap();
        assert_eq!(j.get("total_gpus").unwrap().as_i64(), Some(8));
    }

    #[test]
    fn unknown_routes_404_and_post_405() {
        let s = state();
        assert_eq!(handle(&s, "GET", "/nope").status, 404);
        assert_eq!(handle(&s, "GET", "/api/session/missing").status, 404);
        assert_eq!(handle(&s, "POST", "/").status, 405);
    }

    #[test]
    fn live_server_round_trip() {
        let s = state();
        let (port, _h) = serve(s, 0).unwrap();
        let mut stream = std::net::TcpStream::connect(("127.0.0.1", port)).unwrap();
        write!(stream, "GET /api/cluster HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        let mut out = String::new();
        stream.read_to_string(&mut out).unwrap();
        assert!(out.starts_with("HTTP/1.1 200"));
        assert!(out.contains("total_gpus"));
    }
}
