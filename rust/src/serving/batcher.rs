//! The serving micro-batcher: pack concurrent requests into one
//! engine execution.
//!
//! Compiled PJRT executables have a *fixed* input shape (the manifest's
//! `infer_x_shape`, e.g. `[64, 144]` for the MNIST MLP), so a serving
//! request is defined as **one row** of that shape. The
//! [`ServingQueue`] holds per-endpoint FIFOs of pending rows; a flush
//! drains each FIFO into batches of at most `max_batch` rows, and
//! [`ServedModel::serve_rows`] packs each batch into the fixed tensor
//! (zero-padding unused rows), runs the executable **once**, and slices
//! the output back into per-request rows. Because every alpha-test
//! model computes output row *i* from input row *i* alone, a row served
//! in a batch of 64 is bit-for-bit identical to the same row served
//! alone — `rust/tests/serving.rs` gates exactly that.
//!
//! Flush policy (checked against virtual time, so it is deterministic
//! under test): a FIFO is due when it holds `max_batch` rows, when its
//! oldest row has waited `max_wait_ms`, or when the caller forces a
//! flush (`nsml serve` flushes after each burst of queued service
//! calls — requests that arrived together leave together).

use crate::runtime::{TensorData, TrainableModel};
use std::collections::BTreeMap;
use std::sync::Mutex;

/// What a flushed request learns about its own execution.
#[derive(Debug, Clone, PartialEq)]
pub struct ServedRow {
    /// The model output for this request's row.
    pub probs: Vec<f32>,
    /// Endpoint version that produced it (attribution).
    pub version: u64,
    /// How many requests shared the execution.
    pub batch: usize,
}

/// Completion callback: one per request, called exactly once.
pub type ServeReply = Box<dyn FnOnce(Result<ServedRow, String>) + Send>;

/// One queued inference request (a single input row).
pub struct PendingInfer {
    pub user: String,
    pub x: Vec<f32>,
    pub enqueued_at_ms: u64,
    pub reply: ServeReply,
    /// Trace id of the dispatch that queued this request, if the caller
    /// carried one — the flush/batch spans attach to it rounds later.
    pub trace: Option<String>,
}

struct Inner {
    queues: BTreeMap<String, Vec<PendingInfer>>,
    requests: u64,
    batches: u64,
}

/// Counters + current depth (`service_status` / tests).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ServingQueueStats {
    pub depth: usize,
    pub requests: u64,
    pub batches: u64,
}

/// Per-endpoint pending-request FIFOs (see module docs).
pub struct ServingQueue {
    max_batch: usize,
    max_wait_ms: u64,
    inner: Mutex<Inner>,
}

impl ServingQueue {
    pub fn new(max_batch: usize, max_wait_ms: u64) -> ServingQueue {
        ServingQueue {
            max_batch: max_batch.max(1),
            max_wait_ms,
            inner: Mutex::new(Inner { queues: BTreeMap::new(), requests: 0, batches: 0 }),
        }
    }

    pub fn max_batch(&self) -> usize {
        self.max_batch
    }

    pub fn max_wait_ms(&self) -> u64 {
        self.max_wait_ms
    }

    pub fn enqueue(&self, endpoint: &str, req: PendingInfer) {
        let mut inner = self.inner.lock().unwrap();
        inner.requests += 1;
        inner.queues.entry(endpoint.to_string()).or_default().push(req);
    }

    /// Pending rows across all endpoints.
    pub fn depth(&self) -> usize {
        self.inner.lock().unwrap().queues.values().map(Vec::len).sum()
    }

    /// Pending rows queued for one endpoint (the autoscaler's signal).
    pub fn depth_of(&self, endpoint: &str) -> usize {
        self.inner.lock().unwrap().queues.get(endpoint).map(Vec::len).unwrap_or(0)
    }

    /// Drain everything queued for one endpoint regardless of due-ness,
    /// still in batch-sized chunks. Used by the registry drain paths:
    /// requests admitted before a promote/rollback/retire are flushed
    /// at the version they were admitted under before the active
    /// cursor moves.
    pub fn take_endpoint(&self, endpoint: &str) -> Vec<Vec<PendingInfer>> {
        let mut inner = self.inner.lock().unwrap();
        let Some(mut q) = inner.queues.remove(endpoint) else { return Vec::new() };
        let mut out = Vec::new();
        while !q.is_empty() {
            let take = q.len().min(self.max_batch);
            out.push(q.drain(..take).collect());
        }
        inner.batches += out.len() as u64;
        out
    }

    pub fn stats(&self) -> ServingQueueStats {
        let inner = self.inner.lock().unwrap();
        ServingQueueStats {
            depth: inner.queues.values().map(Vec::len).sum(),
            requests: inner.requests,
            batches: inner.batches,
        }
    }

    /// Drain every due batch: full FIFOs always, FIFOs whose oldest row
    /// has waited `max_wait_ms` by `now_ms`, and everything when
    /// `flush_all` is set. No returned batch exceeds `max_batch`; a
    /// leftover shorter than `max_batch` stays queued unless due.
    pub fn take_due(&self, now_ms: u64, flush_all: bool) -> Vec<(String, Vec<PendingInfer>)> {
        let mut inner = self.inner.lock().unwrap();
        let mut out = Vec::new();
        let max_batch = self.max_batch;
        let max_wait = self.max_wait_ms;
        for (name, q) in inner.queues.iter_mut() {
            loop {
                if q.is_empty() {
                    break;
                }
                let expired = now_ms >= q[0].enqueued_at_ms.saturating_add(max_wait);
                if !(flush_all || q.len() >= max_batch || expired) {
                    break;
                }
                let take = q.len().min(max_batch);
                let batch: Vec<PendingInfer> = q.drain(..take).collect();
                out.push((name.clone(), batch));
            }
        }
        inner.queues.retain(|_, q| !q.is_empty());
        inner.batches += out.len() as u64;
        out
    }

    /// Fail every pending request for `endpoint` (it was retired while
    /// requests were queued). Each reply still fires exactly once.
    pub fn fail_endpoint(&self, endpoint: &str, reason: &str) {
        let drained = self.inner.lock().unwrap().queues.remove(endpoint);
        for req in drained.unwrap_or_default() {
            (req.reply)(Err(reason.to_string()));
        }
    }
}

/// A checkpoint loaded for serving: the fixed-shape executable plus
/// the row geometry derived from its manifest.
pub struct ServedModel {
    model: TrainableModel,
    /// Rows per execution (`infer_x_shape[0]`).
    pub rows: usize,
    /// Values per request (`infer_x_shape[1..]` flattened).
    pub row_len: usize,
    shape: Vec<i64>,
}

impl ServedModel {
    pub fn new(model: TrainableModel) -> Result<ServedModel, String> {
        let shape = model.manifest().infer_x_shape.clone();
        if shape.is_empty() || shape.iter().any(|&d| d <= 0) {
            return Err(format!(
                "model '{}' has no usable infer_x_shape ({:?})",
                model.name(),
                shape
            ));
        }
        let rows = shape[0] as usize;
        let row_len = shape[1..].iter().product::<i64>().max(1) as usize;
        Ok(ServedModel { model, rows, row_len, shape })
    }

    /// Serve `rows_in` (each exactly `row_len` values) through as few
    /// fixed-shape executions as possible: `ceil(n / rows)` engine
    /// calls, unused rows zero-padded, outputs sliced per request.
    pub fn serve_rows(&self, rows_in: &[Vec<f32>]) -> Result<Vec<Vec<f32>>, String> {
        for r in rows_in {
            if r.len() != self.row_len {
                return Err(format!(
                    "request has {} values but one '{}' row is {} values",
                    r.len(),
                    self.model.name(),
                    self.row_len
                ));
            }
        }
        let mut out = Vec::with_capacity(rows_in.len());
        for chunk in rows_in.chunks(self.rows) {
            let mut flat = vec![0.0f32; self.rows * self.row_len];
            for (i, r) in chunk.iter().enumerate() {
                flat[i * self.row_len..(i + 1) * self.row_len].copy_from_slice(r);
            }
            let y = self
                .model
                .infer(&TensorData::f32(flat, &self.shape))
                .map_err(|e| e.to_string())?;
            let per_row = y.len() / self.rows;
            for i in 0..chunk.len() {
                out.push(y[i * per_row..(i + 1) * per_row].to_vec());
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    fn req(user: &str, at_ms: u64, answered: &Arc<AtomicUsize>) -> PendingInfer {
        let answered = answered.clone();
        PendingInfer {
            user: user.to_string(),
            x: vec![0.0],
            enqueued_at_ms: at_ms,
            reply: Box::new(move |_| {
                answered.fetch_add(1, Ordering::SeqCst);
            }),
            trace: None,
        }
    }

    #[test]
    fn full_queue_flushes_without_waiting() {
        let q = ServingQueue::new(3, 1000);
        let n = Arc::new(AtomicUsize::new(0));
        for _ in 0..7 {
            q.enqueue("prod", req("kim", 0, &n));
        }
        // Two full batches leave immediately; the short tail waits.
        let batches = q.take_due(0, false);
        assert_eq!(batches.len(), 2);
        assert!(batches.iter().all(|(name, b)| name == "prod" && b.len() == 3));
        assert_eq!(q.depth(), 1);
        // The tail expires once its oldest row has waited max_wait_ms.
        assert!(q.take_due(999, false).is_empty());
        let late = q.take_due(1000, false);
        assert_eq!(late.len(), 1);
        assert_eq!(late[0].1.len(), 1);
        assert_eq!(q.depth(), 0);
        assert_eq!(q.stats().requests, 7);
        assert_eq!(q.stats().batches, 3);
    }

    #[test]
    fn flush_all_drains_every_endpoint_in_batch_sized_chunks() {
        let q = ServingQueue::new(2, u64::MAX);
        let n = Arc::new(AtomicUsize::new(0));
        for _ in 0..3 {
            q.enqueue("a", req("kim", 5, &n));
        }
        q.enqueue("b", req("lee", 5, &n));
        let batches = q.take_due(5, true);
        let sizes: Vec<(String, usize)> =
            batches.iter().map(|(name, b)| (name.clone(), b.len())).collect();
        assert_eq!(sizes, vec![("a".into(), 2), ("a".into(), 1), ("b".into(), 1)]);
        assert_eq!(q.depth(), 0);
    }

    #[test]
    fn fail_endpoint_answers_each_pending_request_once() {
        let q = ServingQueue::new(8, u64::MAX);
        let n = Arc::new(AtomicUsize::new(0));
        q.enqueue("gone", req("kim", 0, &n));
        q.enqueue("gone", req("kim", 0, &n));
        q.enqueue("kept", req("lee", 0, &n));
        q.fail_endpoint("gone", "endpoint retired");
        assert_eq!(n.load(Ordering::SeqCst), 2);
        assert_eq!(q.depth(), 1);
    }
}
