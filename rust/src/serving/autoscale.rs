//! The serving autoscaler: a pure decision function over the
//! telemetry the drive loop already has.
//!
//! Each drive round the platform observes, per endpoint, the pending
//! queue depth and how long the endpoint has been idle (no queued and
//! no in-flight work, tracked in virtual milliseconds so decisions are
//! deterministic under test). [`AutoscalePolicy::decide`] maps that to
//! one of three moves:
//!
//! * **Up** — the queue is at least `scale_up_queue_depth` deep and the
//!   endpoint is below `max_replicas`: demand outruns the replicas we
//!   have, add one.
//! * **Down** — the endpoint has been idle for `scale_down_idle_ms`
//!   and sits above `min_replicas`: shed one replica and give its
//!   worker back to training.
//! * **Hold** — anything else. Scaling one step per round keeps the
//!   loop from flapping: a burst grows the set gradually and a lull
//!   shrinks it gradually.
//!
//! The policy is plain data + arithmetic on purpose: placement, event
//! publishing and draining live in [`super::ReplicaManager`] and the
//! facade, so this file is exhaustively testable without a platform.

/// Tuning knobs, read from `[serving]` config keys of the same names.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AutoscalePolicy {
    /// Replicas an endpoint keeps even when idle (>= 1).
    pub min_replicas: usize,
    /// Replica ceiling per endpoint. 0 means the executor serve lane
    /// is disabled entirely (inline platform-thread serving).
    pub max_replicas: usize,
    /// Queue depth that triggers a scale-up.
    pub scale_up_queue_depth: usize,
    /// Idle virtual milliseconds that trigger a scale-down.
    pub scale_down_idle_ms: u64,
}

/// One autoscale verdict for one endpoint.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScaleDecision {
    /// Add one replica.
    Up,
    /// Remove one replica.
    Down,
    /// Leave the set alone.
    Hold,
}

impl AutoscalePolicy {
    pub fn new(
        min_replicas: usize,
        max_replicas: usize,
        scale_up_queue_depth: usize,
        scale_down_idle_ms: u64,
    ) -> AutoscalePolicy {
        AutoscalePolicy {
            min_replicas: min_replicas.max(1),
            max_replicas,
            scale_up_queue_depth: scale_up_queue_depth.max(1),
            scale_down_idle_ms: scale_down_idle_ms.max(1),
        }
    }

    /// Is the executor serve lane on at all? With `max_replicas = 0`
    /// the facade executes batches inline (the pre-replica baseline).
    pub fn enabled(&self) -> bool {
        self.max_replicas > 0
    }

    /// The replica count a fresh endpoint starts with.
    pub fn initial_replicas(&self) -> usize {
        self.min_replicas.min(self.max_replicas.max(1))
    }

    /// Decide one endpoint's move from this round's observations.
    /// `idle_ms` is how long the endpoint has had neither queued nor
    /// in-flight work (0 whenever it is busy).
    pub fn decide(&self, replicas: usize, queue_depth: usize, idle_ms: u64) -> ScaleDecision {
        if !self.enabled() {
            return ScaleDecision::Hold;
        }
        if queue_depth >= self.scale_up_queue_depth && replicas < self.max_replicas {
            return ScaleDecision::Up;
        }
        if queue_depth == 0 && idle_ms >= self.scale_down_idle_ms && replicas > self.min_replicas {
            return ScaleDecision::Down;
        }
        ScaleDecision::Hold
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy() -> AutoscalePolicy {
        AutoscalePolicy::new(1, 4, 16, 250)
    }

    #[test]
    fn deep_queue_scales_up_until_the_ceiling() {
        let p = policy();
        assert_eq!(p.decide(1, 16, 0), ScaleDecision::Up);
        assert_eq!(p.decide(3, 40, 0), ScaleDecision::Up);
        // At max_replicas the queue no longer grows the set.
        assert_eq!(p.decide(4, 400, 0), ScaleDecision::Hold);
        // Below the threshold nothing happens.
        assert_eq!(p.decide(1, 15, 0), ScaleDecision::Hold);
    }

    #[test]
    fn sustained_idle_scales_down_to_the_floor() {
        let p = policy();
        assert_eq!(p.decide(3, 0, 249), ScaleDecision::Hold);
        assert_eq!(p.decide(3, 0, 250), ScaleDecision::Down);
        // Never below min_replicas, no matter how idle.
        assert_eq!(p.decide(1, 0, 10_000), ScaleDecision::Hold);
        // A non-empty queue is never idle.
        assert_eq!(p.decide(3, 1, 10_000), ScaleDecision::Hold);
    }

    #[test]
    fn disabled_policy_always_holds() {
        let p = AutoscalePolicy::new(1, 0, 16, 250);
        assert!(!p.enabled());
        assert_eq!(p.decide(1, 1_000, 0), ScaleDecision::Hold);
        assert_eq!(p.decide(1, 0, 1_000_000), ScaleDecision::Hold);
    }

    #[test]
    fn constructor_clamps_degenerate_knobs() {
        let p = AutoscalePolicy::new(0, 2, 0, 0);
        assert_eq!(p.min_replicas, 1);
        assert_eq!(p.scale_up_queue_depth, 1);
        assert_eq!(p.scale_down_idle_ms, 1);
        assert_eq!(p.initial_replicas(), 1);
        // min above max still starts within the ceiling.
        let q = AutoscalePolicy::new(8, 2, 4, 100);
        assert_eq!(q.initial_replicas(), 2);
    }
}
