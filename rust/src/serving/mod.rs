//! Inference serving: leaderboard checkpoints promoted to named,
//! micro-batched endpoints.
//!
//! NSML's follow-up work (the MLaaS case study, arXiv 1810.09957) is
//! serving-centric: a model that wins the leaderboard is only useful
//! once it answers real traffic. This module turns the one-shot
//! `infer` verb into a serving *workload*:
//!
//! * [`EndpointRegistry`] — named endpoints, each a history of
//!   promoted checkpoint versions with an active cursor
//!   (promote / rollback / rollforward / retire). The history pins
//!   params objects against GC and survives restart through both the
//!   snapshot (`persist::save`) and the WAL
//!   (`EventKind::EndpointChanged` replay).
//! * [`ServingQueue`] — per-endpoint FIFOs that micro-batch concurrent
//!   requests under `[serving]` `max_batch` / `max_wait_ms` limits.
//! * [`ServedModel`] — a checkpoint loaded behind the compile cache;
//!   packs a batch of single-row requests into the model's fixed
//!   `infer_x_shape` tensor, executes once, slices per-row outputs.
//!
//! * [`ReplicaManager`] — places 1..N replicas of each endpoint onto
//!   executor workers and tracks in-flight batches, so inference runs
//!   on the pool's serve lane instead of the platform thread; batches
//!   round-robin across the set and registry mutations drain it before
//!   moving the active cursor (no mixed-version batches).
//! * [`AutoscalePolicy`] — grows the set when the queue backs up and
//!   shrinks it after sustained idle, one step per drive round,
//!   publishing `EventKind::ReplicaScaled`.
//!
//! The facade (`api::NsmlPlatform`) owns one of each and pumps the
//! queue from the drive loop; `PlatformService` routes the `promote` /
//! `endpoints` / `serve_infer` verbs; per-tenant QPS quotas gate
//! enqueues through `tenancy::TenantRegistry::try_request`.

mod autoscale;
mod batcher;
mod registry;
mod replica;

pub use autoscale::{AutoscalePolicy, ScaleDecision};
pub use batcher::{
    PendingInfer, ServeReply, ServedModel, ServedRow, ServingQueue, ServingQueueStats,
};
pub use registry::{Endpoint, EndpointRegistry, EndpointVersion};
pub use replica::{InFlightGuard, ReplicaManager, ServeWork};
