//! Replica placement and lifetime for the executor serve lane.
//!
//! PR 8 executed every serving micro-batch inline on the single
//! platform-owning thread, serializing inference against training and
//! capping throughput at one core. This module moves execution onto
//! the executor pool: each endpoint owns a *replica set* — 1..N worker
//! indices, each hosting a [`super::ServedModel`] rebuilt from the
//! same checkpoint bytes — and the facade round-robins due batches
//! across the set as fire-and-forget [`ServeWork`] messages. Replies
//! fire from the worker thread, so the drive loop keeps training while
//! inference runs.
//!
//! Three invariants live here:
//!
//! * **Load once, share forever.** Checkpoint params are read from the
//!   object store once per object id and `Arc`-shared to every replica
//!   ([`ReplicaManager::params_for`]); each worker deserializes into
//!   its own thread-local PJRT engine, whose compile cache already
//!   de-duplicates executables, so adding a replica never recompiles
//!   or re-reads anything.
//! * **No mixed-version batches.** A batch binds its endpoint version
//!   when dispatched, and every dispatch holds an [`InFlightGuard`].
//!   The registry mutation paths call [`ReplicaManager::drain`] before
//!   moving the active cursor, so a rollback/retire waits for in-flight
//!   work admitted under the old version to answer first.
//! * **Workers never block on the platform.** The guard is a plain
//!   RAII counter: workers only decrement and notify, so the drain
//!   wait cannot deadlock against the pool.
//!
//! Placement prefers the worker with the least combined load (live
//! training sessions + replicas already placed), one distinct worker
//! per replica, so a scale-up lands on the quietest thread instead of
//! stacking on a busy one.

use super::batcher::PendingInfer;
use crate::storage::ObjectId;
use std::collections::BTreeMap;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

/// How long [`ReplicaManager::drain`] waits for in-flight batches
/// before giving up (real time; workers answer in milliseconds, so
/// hitting this means a worker thread died mid-batch).
const DRAIN_TIMEOUT_MS: u64 = 5_000;

/// One serving batch handed to an executor worker: everything needed
/// to rebuild the served model on that thread (`Send` only — the
/// non-`Send` PJRT state is built worker-side from these bytes).
pub struct ServeWork {
    pub endpoint: String,
    /// Endpoint version the batch was admitted under (attribution —
    /// the worker answers with exactly this version).
    pub version: u64,
    /// Model name (`manifest.json` key) for checkpoint deserialization.
    pub model: String,
    /// Shared checkpoint bytes (loaded once, `Arc`-shared per replica).
    pub params: Arc<Vec<u8>>,
    pub batch: Vec<PendingInfer>,
    /// Keeps the endpoint's in-flight count up until the batch answers.
    pub guard: InFlightGuard,
}

/// In-flight batch counter + wakeup for drainers.
struct Gate {
    count: Mutex<u64>,
    cv: Condvar,
}

/// RAII token for one dispatched batch: dropping it (worker-side, after
/// every reply fired — or facade-side on a failed dispatch) decrements
/// the endpoint's in-flight count and wakes any drain waiter.
pub struct InFlightGuard(Arc<Gate>);

impl Drop for InFlightGuard {
    fn drop(&mut self) {
        let mut count = self.0.count.lock().unwrap();
        *count = count.saturating_sub(1);
        self.0.cv.notify_all();
    }
}

/// One endpoint's replicas: which workers host one, plus the dispatch
/// cursor and idle bookkeeping the autoscaler reads.
struct ReplicaSet {
    /// Distinct worker indices hosting a replica (dispatch targets).
    workers: Vec<usize>,
    /// Round-robin cursor over `workers`.
    next: usize,
    gate: Arc<Gate>,
    /// Virtual ms when the endpoint last had queued or in-flight work.
    last_busy_ms: u64,
}

/// All replica sets plus the shared params cache (see module docs).
pub struct ReplicaManager {
    pool_size: usize,
    sets: Mutex<BTreeMap<String, ReplicaSet>>,
    /// Checkpoint bytes by content address — load once, share forever.
    /// Pruned against the registry's pinned set after retires.
    params: Mutex<BTreeMap<ObjectId, Arc<Vec<u8>>>>,
}

impl ReplicaManager {
    pub fn new(pool_size: usize) -> ReplicaManager {
        ReplicaManager {
            pool_size: pool_size.max(1),
            sets: Mutex::new(BTreeMap::new()),
            params: Mutex::new(BTreeMap::new()),
        }
    }

    /// Make sure `endpoint` has a set with `initial` replicas (no-op if
    /// it already exists). `loads` is the per-worker live-session count
    /// used for placement; `now_ms` seeds the idle clock.
    pub fn ensure(&self, endpoint: &str, initial: usize, loads: &[usize], now_ms: u64) {
        let mut sets = self.sets.lock().unwrap();
        if sets.contains_key(endpoint) {
            return;
        }
        let want = initial.clamp(1, self.pool_size);
        let mut set = ReplicaSet {
            workers: Vec::new(),
            next: 0,
            gate: Arc::new(Gate { count: Mutex::new(0), cv: Condvar::new() }),
            last_busy_ms: now_ms,
        };
        for _ in 0..want {
            if let Some(w) = pick_worker(self.pool_size, &set.workers, &sets, loads) {
                set.workers.push(w);
            }
        }
        sets.insert(endpoint.to_string(), set);
    }

    /// Current replica count (0 if the endpoint has no set).
    pub fn replicas(&self, endpoint: &str) -> usize {
        self.sets.lock().unwrap().get(endpoint).map(|s| s.workers.len()).unwrap_or(0)
    }

    /// Batches dispatched but not yet fully answered.
    pub fn in_flight(&self, endpoint: &str) -> u64 {
        self.sets
            .lock()
            .unwrap()
            .get(endpoint)
            .map(|s| *s.gate.count.lock().unwrap())
            .unwrap_or(0)
    }

    /// Pick the next replica for a batch (round robin) and charge one
    /// in-flight batch against the endpoint. Returns the worker index
    /// and the guard to embed in the [`ServeWork`].
    pub fn checkout(&self, endpoint: &str) -> Option<(usize, InFlightGuard)> {
        let mut sets = self.sets.lock().unwrap();
        let set = sets.get_mut(endpoint)?;
        if set.workers.is_empty() {
            return None;
        }
        let worker = set.workers[set.next % set.workers.len()];
        set.next = set.next.wrapping_add(1);
        *set.gate.count.lock().unwrap() += 1;
        Some((worker, InFlightGuard(set.gate.clone())))
    }

    /// Add one replica on the least-loaded worker not already hosting
    /// this endpoint. Returns the new count, or `None` when every
    /// worker already hosts one (or the endpoint has no set).
    pub fn scale_up(&self, endpoint: &str, loads: &[usize]) -> Option<usize> {
        let mut sets = self.sets.lock().unwrap();
        let taken: Vec<usize> =
            sets.get(endpoint).map(|s| s.workers.clone()).unwrap_or_default();
        let w = pick_worker(self.pool_size, &taken, &sets, loads)?;
        let set = sets.get_mut(endpoint)?;
        set.workers.push(w);
        Some(set.workers.len())
    }

    /// Remove the most recently added replica. Returns the new count;
    /// never drops below one (retire removes the whole set instead).
    pub fn scale_down(&self, endpoint: &str) -> Option<usize> {
        let mut sets = self.sets.lock().unwrap();
        let set = sets.get_mut(endpoint)?;
        if set.workers.len() <= 1 {
            return None;
        }
        set.workers.pop();
        Some(set.workers.len())
    }

    /// One autoscaler observation: refresh the idle clock and return
    /// `(replicas, idle_ms)` for [`super::AutoscalePolicy::decide`].
    /// The endpoint counts as busy while anything is queued or in
    /// flight.
    pub fn observe(&self, endpoint: &str, queue_depth: usize, now_ms: u64) -> (usize, u64) {
        let mut sets = self.sets.lock().unwrap();
        let Some(set) = sets.get_mut(endpoint) else { return (0, 0) };
        let busy = queue_depth > 0 || *set.gate.count.lock().unwrap() > 0;
        if busy {
            set.last_busy_ms = now_ms;
        }
        (set.workers.len(), now_ms.saturating_sub(set.last_busy_ms))
    }

    /// Mark `endpoint` busy at `now_ms` without reading it — called
    /// when `InferServed` bus telemetry shows a batch answered since
    /// the last drive round, so the idle clock only starts once
    /// traffic has truly stopped.
    pub fn touch(&self, endpoint: &str, now_ms: u64) {
        if let Some(set) = self.sets.lock().unwrap().get_mut(endpoint) {
            set.last_busy_ms = now_ms;
        }
    }

    /// Block until every in-flight batch for `endpoint` has answered
    /// (bounded by [`DRAIN_TIMEOUT_MS`]). Workers only ever decrement
    /// the gate, so this cannot deadlock against the pool. Returns
    /// whether the drain completed.
    pub fn drain(&self, endpoint: &str) -> bool {
        let gate = {
            let sets = self.sets.lock().unwrap();
            match sets.get(endpoint) {
                Some(s) => s.gate.clone(),
                None => return true,
            }
        };
        let deadline = Duration::from_millis(DRAIN_TIMEOUT_MS);
        let mut count = gate.count.lock().unwrap();
        while *count > 0 {
            let (next, timeout) = gate.cv.wait_timeout(count, deadline).unwrap();
            count = next;
            if timeout.timed_out() {
                return *count == 0;
            }
        }
        true
    }

    /// Forget `endpoint`'s set entirely (retire).
    pub fn remove(&self, endpoint: &str) {
        self.sets.lock().unwrap().remove(endpoint);
    }

    /// Every endpoint with a live set.
    pub fn endpoints(&self) -> Vec<String> {
        self.sets.lock().unwrap().keys().cloned().collect()
    }

    /// Checkpoint bytes for `id`, loading (once) through `load` on the
    /// first request and `Arc`-sharing every subsequent one.
    pub fn params_for(
        &self,
        id: &ObjectId,
        load: impl FnOnce() -> Result<Vec<u8>, String>,
    ) -> Result<Arc<Vec<u8>>, String> {
        let mut params = self.params.lock().unwrap();
        if let Some(bytes) = params.get(id) {
            return Ok(bytes.clone());
        }
        let bytes = Arc::new(load()?);
        params.insert(id.clone(), bytes.clone());
        Ok(bytes)
    }

    /// Drop cached params whose object is no longer pinned by any
    /// endpoint version (called after retires alongside GC).
    pub fn prune_params(&self, pinned: &[ObjectId]) {
        self.params.lock().unwrap().retain(|id, _| pinned.contains(id));
    }
}

/// Least-loaded worker not in `taken`: load = live training sessions
/// (`loads`) + replicas every set already placed there.
fn pick_worker(
    pool_size: usize,
    taken: &[usize],
    sets: &BTreeMap<String, ReplicaSet>,
    loads: &[usize],
) -> Option<usize> {
    let mut placed = vec![0usize; pool_size];
    for set in sets.values() {
        for &w in &set.workers {
            if w < pool_size {
                placed[w] += 1;
            }
        }
    }
    (0..pool_size)
        .filter(|w| !taken.contains(w))
        .min_by_key(|&w| (loads.get(w).copied().unwrap_or(0) + placed[w], w))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn oid(s: &str) -> ObjectId {
        ObjectId(s.to_string())
    }

    #[test]
    fn placement_prefers_the_quietest_worker() {
        let m = ReplicaManager::new(4);
        // Worker 2 is idle; 0/1/3 carry training sessions.
        m.ensure("prod", 1, &[2, 1, 0, 3], 0);
        let (w, _guard) = m.checkout("prod").unwrap();
        assert_eq!(w, 2);
        // Scale-ups land on distinct workers, least-loaded first.
        assert_eq!(m.scale_up("prod", &[2, 1, 0, 3]), Some(2));
        assert_eq!(m.scale_up("prod", &[2, 1, 0, 3]), Some(3));
        assert_eq!(m.scale_up("prod", &[2, 1, 0, 3]), Some(4));
        // Every worker hosts one: no fifth replica.
        assert_eq!(m.scale_up("prod", &[2, 1, 0, 3]), None);
    }

    #[test]
    fn checkout_round_robins_and_scale_down_keeps_one() {
        let m = ReplicaManager::new(3);
        m.ensure("prod", 3, &[0, 0, 0], 0);
        assert_eq!(m.replicas("prod"), 3);
        let mut seen = Vec::new();
        for _ in 0..6 {
            let (w, _g) = m.checkout("prod").unwrap();
            seen.push(w);
        }
        assert_eq!(&seen[0..3], &seen[3..6], "round robin repeats the rotation");
        assert_eq!(m.scale_down("prod"), Some(2));
        assert_eq!(m.scale_down("prod"), Some(1));
        assert_eq!(m.scale_down("prod"), None, "the last replica stays");
        assert_eq!(m.replicas("prod"), 1);
    }

    #[test]
    fn drain_waits_for_guards_and_observe_tracks_idle() {
        let m = ReplicaManager::new(2);
        m.ensure("prod", 1, &[0, 0], 100);
        let (_, guard) = m.checkout("prod").unwrap();
        assert_eq!(m.in_flight("prod"), 1);
        // Busy while in flight: the idle clock pins to now.
        assert_eq!(m.observe("prod", 0, 150), (1, 0));
        // Another thread answers the batch; drain unblocks.
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            drop(guard);
        });
        assert!(m.drain("prod"));
        t.join().unwrap();
        assert_eq!(m.in_flight("prod"), 0);
        // Idle accumulates from the last busy observation.
        assert_eq!(m.observe("prod", 0, 400), (1, 250));
        // Queued work resets it.
        assert_eq!(m.observe("prod", 3, 500), (1, 0));
        // Unknown endpoints are trivially drained and replica-less.
        assert!(m.drain("nope"));
        assert_eq!(m.observe("nope", 9, 0), (0, 0));
    }

    #[test]
    fn params_cache_loads_once_and_prunes_unpinned() {
        let m = ReplicaManager::new(1);
        let mut loads = 0;
        for _ in 0..3 {
            let bytes = m
                .params_for(&oid("abc"), || {
                    loads += 1;
                    Ok(vec![1, 2, 3])
                })
                .unwrap();
            assert_eq!(*bytes, vec![1, 2, 3]);
        }
        assert_eq!(loads, 1, "the object store is read once per object");
        // Load errors propagate and are not cached.
        assert!(m.params_for(&oid("bad"), || Err("missing".into())).is_err());
        m.prune_params(&[]);
        let bytes = m
            .params_for(&oid("abc"), || {
                loads += 1;
                Ok(vec![9])
            })
            .unwrap();
        assert_eq!(*bytes, vec![9], "pruned entries reload");
        assert_eq!(loads, 2);
    }
}
