//! Named serving endpoints: which checkpoint answers which name.
//!
//! An endpoint is a stable, user-facing name (`"mnist-prod"`) bound to
//! a *history* of promoted checkpoint versions. `promote` appends a new
//! version and activates it; `rollback` / `rollforward` move the active
//! cursor along the history without losing any version (so a bad
//! promote is reversible, and a rollback is itself reversible); `retire`
//! removes the endpoint. Every version in the history pins its params
//! object against GC — a rolled-back-to checkpoint must still be
//! loadable.
//!
//! The registry is plain data behind a mutex: persistence (snapshot
//! JSON + WAL replay of `EventKind::EndpointChanged`) and the actual
//! model execution live above it.

use crate::storage::ObjectId;
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::sync::Mutex;

/// One promoted checkpoint in an endpoint's history.
#[derive(Debug, Clone, PartialEq)]
pub struct EndpointVersion {
    /// 1-based position in the endpoint's promote history.
    pub version: u64,
    /// Session the checkpoint came from.
    pub session: String,
    /// Model architecture name (manifest key) — fixes the serving
    /// shape and lets recovery rebuild without a session lookup.
    pub model: String,
    /// Training step of the promoted checkpoint.
    pub step: u64,
    /// Content address of the serialized parameters.
    pub object: ObjectId,
    pub promoted_at_ms: u64,
}

/// A named endpoint: a version history plus the active cursor.
#[derive(Debug, Clone, PartialEq)]
pub struct Endpoint {
    pub name: String,
    pub versions: Vec<EndpointVersion>,
    /// Index into `versions` of the currently served version.
    pub active: usize,
}

impl Endpoint {
    pub fn active_version(&self) -> &EndpointVersion {
        &self.versions[self.active]
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("name", self.name.as_str().into())
            .set("active", self.active.into())
            .set(
                "versions",
                Json::Arr(
                    self.versions
                        .iter()
                        .map(|v| {
                            let mut vo = Json::obj();
                            vo.set("version", v.version.into())
                                .set("session", v.session.as_str().into())
                                .set("model", v.model.as_str().into())
                                .set("step", v.step.into())
                                .set("object", v.object.0.as_str().into())
                                .set("promoted_at_ms", v.promoted_at_ms.into());
                            vo
                        })
                        .collect(),
                ),
            );
        o
    }

    pub fn from_json(j: &Json) -> Result<Endpoint, String> {
        let str_of = |j: &Json, k: &str| {
            j.get(k)
                .and_then(Json::as_str)
                .map(str::to_string)
                .ok_or_else(|| format!("endpoint json missing string '{}'", k))
        };
        let u64_of = |j: &Json, k: &str| {
            j.get(k)
                .and_then(Json::as_f64)
                .filter(|f| *f >= 0.0 && f.fract() == 0.0)
                .map(|f| f as u64)
                .ok_or_else(|| format!("endpoint json missing integer '{}'", k))
        };
        let mut versions = Vec::new();
        for vj in j.get("versions").and_then(Json::as_arr).unwrap_or(&Vec::new()) {
            versions.push(EndpointVersion {
                version: u64_of(vj, "version")?,
                session: str_of(vj, "session")?,
                model: str_of(vj, "model")?,
                step: u64_of(vj, "step")?,
                object: ObjectId(str_of(vj, "object")?),
                promoted_at_ms: u64_of(vj, "promoted_at_ms")?,
            });
        }
        if versions.is_empty() {
            return Err("endpoint json has no versions".to_string());
        }
        let active = u64_of(j, "active")? as usize;
        if active >= versions.len() {
            return Err(format!(
                "endpoint active index {} out of range ({} versions)",
                active,
                versions.len()
            ));
        }
        Ok(Endpoint { name: str_of(j, "name")?, versions, active })
    }
}

/// Thread-safe endpoint table (name → [`Endpoint`]).
pub struct EndpointRegistry {
    inner: Mutex<BTreeMap<String, Endpoint>>,
}

impl Default for EndpointRegistry {
    fn default() -> Self {
        Self::new()
    }
}

impl EndpointRegistry {
    pub fn new() -> EndpointRegistry {
        EndpointRegistry { inner: Mutex::new(BTreeMap::new()) }
    }

    /// Append a new version to `name` (creating the endpoint on first
    /// promote) and activate it. Returns the new version snapshot.
    pub fn promote(
        &self,
        name: &str,
        session: &str,
        model: &str,
        step: u64,
        object: ObjectId,
        now_ms: u64,
    ) -> EndpointVersion {
        let mut inner = self.inner.lock().unwrap();
        let ep = inner.entry(name.to_string()).or_insert_with(|| Endpoint {
            name: name.to_string(),
            versions: Vec::new(),
            active: 0,
        });
        let v = EndpointVersion {
            version: ep.versions.len() as u64 + 1,
            session: session.to_string(),
            model: model.to_string(),
            step,
            object,
            promoted_at_ms: now_ms,
        };
        ep.versions.push(v.clone());
        ep.active = ep.versions.len() - 1;
        v
    }

    /// Move the active cursor one version back (to the previous
    /// promote). Errors at the oldest version.
    pub fn rollback(&self, name: &str) -> Result<EndpointVersion, String> {
        let mut inner = self.inner.lock().unwrap();
        let ep = inner.get_mut(name).ok_or_else(|| format!("unknown endpoint '{}'", name))?;
        if ep.active == 0 {
            return Err(format!(
                "endpoint '{}' is already at its oldest version (v{})",
                name,
                ep.versions[ep.active].version
            ));
        }
        ep.active -= 1;
        Ok(ep.versions[ep.active].clone())
    }

    /// Move the active cursor one version forward (undo a rollback).
    /// Errors at the newest version.
    pub fn rollforward(&self, name: &str) -> Result<EndpointVersion, String> {
        let mut inner = self.inner.lock().unwrap();
        let ep = inner.get_mut(name).ok_or_else(|| format!("unknown endpoint '{}'", name))?;
        if ep.active + 1 >= ep.versions.len() {
            return Err(format!(
                "endpoint '{}' is already at its newest version (v{})",
                name,
                ep.versions[ep.active].version
            ));
        }
        ep.active += 1;
        Ok(ep.versions[ep.active].clone())
    }

    /// Remove the endpoint entirely. Returns the version that was
    /// active, or an error for unknown names.
    pub fn retire(&self, name: &str) -> Result<EndpointVersion, String> {
        let mut inner = self.inner.lock().unwrap();
        let ep = inner.remove(name).ok_or_else(|| format!("unknown endpoint '{}'", name))?;
        Ok(ep.versions[ep.active].clone())
    }

    pub fn get(&self, name: &str) -> Option<Endpoint> {
        self.inner.lock().unwrap().get(name).cloned()
    }

    /// Every endpoint, name-ordered.
    pub fn list(&self) -> Vec<Endpoint> {
        self.inner.lock().unwrap().values().cloned().collect()
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.inner.lock().unwrap().is_empty()
    }

    /// Params objects pinned by *any* version of *any* live endpoint
    /// (GC must keep rollback targets loadable, not just the active
    /// version).
    pub fn pinned_objects(&self) -> Vec<ObjectId> {
        let inner = self.inner.lock().unwrap();
        inner.values().flat_map(|ep| ep.versions.iter().map(|v| v.object.clone())).collect()
    }

    /// Replay one durable `EndpointChanged` WAL record (see
    /// `durability::recovery`). Unknown actions are reported so a
    /// corrupt tail is loud, not silently skipped.
    pub fn apply_event(
        &self,
        name: &str,
        action: &str,
        session: &str,
        model: &str,
        step: u64,
        object: &str,
        at_ms: u64,
    ) -> Result<(), String> {
        match action {
            "promote" => {
                self.promote(name, session, model, step, ObjectId(object.to_string()), at_ms);
                Ok(())
            }
            // Replayed cursor moves can hit the history edge if the
            // snapshot already contains the move; edge errors are
            // idempotency, not corruption.
            "rollback" => match self.rollback(name) {
                Ok(_) => Ok(()),
                Err(e) if e.contains("already at") => Ok(()),
                Err(e) => Err(e),
            },
            "rollforward" => match self.rollforward(name) {
                Ok(_) => Ok(()),
                Err(e) if e.contains("already at") => Ok(()),
                Err(e) => Err(e),
            },
            "retire" => {
                // Retiring an already-absent endpoint is idempotent.
                let _ = self.retire(name);
                Ok(())
            }
            other => Err(format!("unknown endpoint action '{}'", other)),
        }
    }

    /// Snapshot shape: a name-ordered array of endpoint objects.
    pub fn to_json(&self) -> Json {
        Json::Arr(self.list().iter().map(Endpoint::to_json).collect())
    }

    /// Replace the registry's contents from a snapshot array.
    pub fn restore(&self, j: &Json) -> Result<(), String> {
        let mut table = BTreeMap::new();
        for ej in j.as_arr().ok_or("endpoints json must be an array")? {
            let ep = Endpoint::from_json(ej)?;
            table.insert(ep.name.clone(), ep);
        }
        *self.inner.lock().unwrap() = table;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::parse;

    fn oid(s: &str) -> ObjectId {
        ObjectId(s.to_string())
    }

    #[test]
    fn promote_appends_and_activates() {
        let r = EndpointRegistry::new();
        let v1 = r.promote("prod", "kim/mnist/1", "mnist_mlp", 100, oid("a"), 10);
        assert_eq!(v1.version, 1);
        let v2 = r.promote("prod", "kim/mnist/2", "mnist_mlp", 200, oid("b"), 20);
        assert_eq!(v2.version, 2);
        let ep = r.get("prod").unwrap();
        assert_eq!(ep.versions.len(), 2);
        assert_eq!(ep.active_version().object, oid("b"));
        assert_eq!(r.list().len(), 1);
    }

    #[test]
    fn rollback_and_rollforward_walk_the_history() {
        let r = EndpointRegistry::new();
        r.promote("prod", "s1", "mnist_mlp", 100, oid("a"), 0);
        r.promote("prod", "s2", "mnist_mlp", 200, oid("b"), 0);
        let back = r.rollback("prod").unwrap();
        assert_eq!(back.version, 1);
        assert!(r.rollback("prod").unwrap_err().contains("oldest"));
        let fwd = r.rollforward("prod").unwrap();
        assert_eq!(fwd.version, 2);
        assert!(r.rollforward("prod").unwrap_err().contains("newest"));
        assert!(r.rollback("missing").unwrap_err().contains("unknown endpoint"));
    }

    #[test]
    fn retire_removes_but_promote_history_pins_everything() {
        let r = EndpointRegistry::new();
        r.promote("a", "s1", "mnist_mlp", 1, oid("x"), 0);
        r.promote("a", "s2", "mnist_mlp", 2, oid("y"), 0);
        r.promote("b", "s3", "mnist_mlp", 3, oid("z"), 0);
        let mut pins: Vec<String> = r.pinned_objects().into_iter().map(|o| o.0).collect();
        pins.sort();
        assert_eq!(pins, vec!["x", "y", "z"]);
        r.retire("a").unwrap();
        assert_eq!(r.pinned_objects().len(), 1);
        assert!(r.retire("a").is_err());
        assert_eq!(r.len(), 1);
    }

    #[test]
    fn json_round_trip() {
        let r = EndpointRegistry::new();
        r.promote("prod", "kim/mnist/1", "mnist_mlp", 100, oid("sha-a"), 5);
        r.promote("prod", "kim/mnist/2", "mnist_mlp", 200, oid("sha-b"), 9);
        r.rollback("prod").unwrap();
        r.promote("canary", "lee/mnist/3", "mnist_mlp", 50, oid("sha-c"), 11);
        let text = r.to_json().to_string();
        let restored = EndpointRegistry::new();
        restored.restore(&parse(&text).unwrap()).unwrap();
        assert_eq!(restored.list(), r.list());
        assert_eq!(restored.get("prod").unwrap().active, 0);
    }

    #[test]
    fn restore_rejects_malformed_shapes() {
        let r = EndpointRegistry::new();
        assert!(r.restore(&parse("{}").unwrap()).is_err());
        let bad = r#"[{"name":"p","active":3,"versions":[{"version":1,"session":"s","model":"m","step":1,"object":"o","promoted_at_ms":0}]}]"#;
        assert!(r.restore(&parse(bad).unwrap()).unwrap_err().contains("out of range"));
    }

    #[test]
    fn apply_event_replays_a_lifecycle() {
        let r = EndpointRegistry::new();
        r.apply_event("prod", "promote", "s1", "mnist_mlp", 100, "a", 1).unwrap();
        r.apply_event("prod", "promote", "s2", "mnist_mlp", 200, "b", 2).unwrap();
        r.apply_event("prod", "rollback", "", "", 0, "", 3).unwrap();
        assert_eq!(r.get("prod").unwrap().active_version().version, 1);
        // Edge-idempotent: replaying a rollback at the oldest version
        // (already applied via snapshot) is a no-op, not an error.
        r.apply_event("prod", "rollback", "", "", 0, "", 4).unwrap();
        r.apply_event("gone", "retire", "", "", 0, "", 5).unwrap();
        assert!(r.apply_event("prod", "frobnicate", "", "", 0, "", 6).is_err());
    }
}
