//! Leader election among scheduler replicas (paper §3.2).
//!
//! "A centralized model often suffers from a single point of failure
//! (SPOF). We handle this issue with the leader election process by
//! electing new master node as in Zookeeper."
//!
//! Zookeeper itself is not available offline, so this implements the same
//! guarantee with a bully-style election: every replica has an id and a
//! heartbeat; when the leader's heartbeat goes stale, the highest-id alive
//! replica claims leadership under a new epoch. Epochs fence stale
//! leaders: any action stamped with an old epoch is rejected.

use crate::events::EventLog;
use crate::util::clock::{Millis, SharedClock};
use std::collections::BTreeMap;
use std::sync::Mutex;

/// Scheduler replica identifier.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct ReplicaId(pub u32);

impl std::fmt::Display for ReplicaId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "sched-{}", self.0)
    }
}

/// Leader's heartbeat is stale after this long → election.
pub const LEADER_TIMEOUT_MS: Millis = 1_000;

#[derive(Debug, Clone)]
struct Replica {
    alive: bool,
    last_seen_ms: Millis,
}

/// The election group: a set of scheduler replicas with one leader.
pub struct ElectionGroup {
    clock: SharedClock,
    events: EventLog,
    inner: Mutex<GroupState>,
}

#[derive(Debug)]
struct GroupState {
    replicas: BTreeMap<ReplicaId, Replica>,
    leader: Option<ReplicaId>,
    epoch: u64,
    /// (time leader died, time new leader elected) of the last failover.
    last_failover: Option<(Millis, Millis)>,
    leader_died_at: Option<Millis>,
}

impl ElectionGroup {
    pub fn new(clock: SharedClock, events: EventLog, replicas: usize) -> ElectionGroup {
        let now = clock.now_ms();
        let mut map = BTreeMap::new();
        for i in 0..replicas {
            map.insert(ReplicaId(i as u32), Replica { alive: true, last_seen_ms: now });
        }
        let g = ElectionGroup {
            clock,
            events,
            inner: Mutex::new(GroupState {
                replicas: map,
                leader: None,
                epoch: 0,
                last_failover: None,
                leader_died_at: None,
            }),
        };
        g.elect();
        g
    }

    /// Current leader and epoch.
    pub fn leader(&self) -> Option<(ReplicaId, u64)> {
        let st = self.inner.lock().unwrap();
        st.leader.map(|l| (l, st.epoch))
    }

    pub fn epoch(&self) -> u64 {
        self.inner.lock().unwrap().epoch
    }

    /// Is `id` the current leader at `epoch`? (Epoch fencing: a deposed
    /// leader holding an old epoch gets `false`.)
    pub fn is_leader(&self, id: ReplicaId, epoch: u64) -> bool {
        let st = self.inner.lock().unwrap();
        st.leader == Some(id) && st.epoch == epoch
    }

    /// Replica heartbeat (replicas ping the group; the leader's ping
    /// keeps its lease alive).
    pub fn heartbeat(&self, id: ReplicaId) {
        let now = self.clock.now_ms();
        let mut st = self.inner.lock().unwrap();
        if let Some(r) = st.replicas.get_mut(&id) {
            if r.alive {
                r.last_seen_ms = now;
            }
        }
    }

    /// Kill a replica (failure injection). If it was the leader the group
    /// is leaderless until the next [`tick`](Self::tick) detects it.
    pub fn kill(&self, id: ReplicaId) {
        let now = self.clock.now_ms();
        let mut st = self.inner.lock().unwrap();
        if let Some(r) = st.replicas.get_mut(&id) {
            r.alive = false;
        }
        if st.leader == Some(id) {
            st.leader = None;
            st.leader_died_at = Some(now);
            self.events.error("election", &id.to_string(), "leader died");
        } else {
            self.events.warn("election", &id.to_string(), "replica died");
        }
    }

    /// Revive a replica. It does not reclaim leadership (no preemption);
    /// it simply becomes electable again.
    pub fn revive(&self, id: ReplicaId) {
        let now = self.clock.now_ms();
        let mut st = self.inner.lock().unwrap();
        if let Some(r) = st.replicas.get_mut(&id) {
            r.alive = true;
            r.last_seen_ms = now;
        }
        self.events.info("election", &id.to_string(), "replica revived");
    }

    /// Detect leader staleness and elect if needed. Returns the new leader
    /// if a failover happened on this tick.
    pub fn tick(&self) -> Option<ReplicaId> {
        let now = self.clock.now_ms();
        {
            let mut st = self.inner.lock().unwrap();
            if let Some(l) = st.leader {
                let stale = st
                    .replicas
                    .get(&l)
                    .map(|r| !r.alive || now.saturating_sub(r.last_seen_ms) > LEADER_TIMEOUT_MS)
                    .unwrap_or(true);
                if stale {
                    st.leader = None;
                    if st.leader_died_at.is_none() {
                        st.leader_died_at = Some(now);
                    }
                    self.events.warn("election", &l.to_string(), "leader lease expired");
                } else {
                    return None; // healthy leader
                }
            }
        }
        self.elect()
    }

    /// Bully election: highest-id alive replica with a *fresh* heartbeat
    /// wins (a stale-but-not-declared-dead replica is not electable);
    /// epoch increments.
    pub fn elect(&self) -> Option<ReplicaId> {
        let now = self.clock.now_ms();
        let mut st = self.inner.lock().unwrap();
        let winner = st
            .replicas
            .iter()
            .filter(|(_, r)| r.alive && now.saturating_sub(r.last_seen_ms) <= LEADER_TIMEOUT_MS)
            .map(|(id, _)| *id)
            .max()?;
        if st.leader == Some(winner) {
            return None;
        }
        st.epoch += 1;
        st.leader = Some(winner);
        if let Some(died) = st.leader_died_at.take() {
            st.last_failover = Some((died, now));
        }
        let epoch = st.epoch;
        self.events.info("election", &winner.to_string(), format!("elected leader (epoch {})", epoch));
        Some(winner)
    }

    /// Duration of the most recent failover (death → re-election), if any.
    pub fn last_failover_ms(&self) -> Option<Millis> {
        let st = self.inner.lock().unwrap();
        st.last_failover.map(|(died, elected)| elected.saturating_sub(died))
    }

    pub fn alive_count(&self) -> usize {
        self.inner.lock().unwrap().replicas.values().filter(|r| r.alive).count()
    }

    pub fn replica_ids(&self) -> Vec<ReplicaId> {
        self.inner.lock().unwrap().replicas.keys().copied().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::clock::sim_clock;

    fn mk(n: usize) -> (ElectionGroup, crate::util::clock::SimClock) {
        let (clock, sim) = sim_clock();
        let events = EventLog::new(clock.clone()).with_echo(false);
        (ElectionGroup::new(clock, events, n), sim)
    }

    #[test]
    fn initial_leader_is_highest_id() {
        let (g, _) = mk(3);
        assert_eq!(g.leader().unwrap().0, ReplicaId(2));
        assert_eq!(g.epoch(), 1);
    }

    #[test]
    fn failover_elects_next_highest() {
        let (g, sim) = mk(3);
        g.kill(ReplicaId(2));
        sim.advance(10);
        let new = g.tick().unwrap();
        assert_eq!(new, ReplicaId(1));
        assert_eq!(g.epoch(), 2);
        assert_eq!(g.last_failover_ms(), Some(10));
    }

    #[test]
    fn epoch_fencing_rejects_deposed_leader() {
        let (g, sim) = mk(2);
        let (old_leader, old_epoch) = g.leader().unwrap();
        g.kill(old_leader);
        sim.advance(5);
        g.tick();
        // Old leader comes back with its stale epoch: fenced out.
        g.revive(old_leader);
        assert!(!g.is_leader(old_leader, old_epoch));
        let (cur, cur_epoch) = g.leader().unwrap();
        assert!(g.is_leader(cur, cur_epoch));
        assert_eq!(cur, ReplicaId(0));
    }

    #[test]
    fn lease_expiry_triggers_election() {
        let (g, sim) = mk(3);
        // Leader stops heartbeating; others keep going.
        sim.advance(LEADER_TIMEOUT_MS + 1);
        g.heartbeat(ReplicaId(0));
        g.heartbeat(ReplicaId(1));
        let new = g.tick().unwrap();
        assert_eq!(new, ReplicaId(1));
    }

    #[test]
    fn healthy_leader_means_no_election() {
        let (g, sim) = mk(3);
        for _ in 0..5 {
            sim.advance(LEADER_TIMEOUT_MS / 2);
            g.heartbeat(ReplicaId(2));
            assert!(g.tick().is_none());
        }
        assert_eq!(g.epoch(), 1);
    }

    #[test]
    fn no_leader_when_all_dead_then_recover() {
        let (g, sim) = mk(2);
        g.kill(ReplicaId(0));
        g.kill(ReplicaId(1));
        sim.advance(1);
        assert!(g.tick().is_none());
        assert_eq!(g.leader(), None);
        g.revive(ReplicaId(0));
        assert_eq!(g.tick(), Some(ReplicaId(0)));
    }

    #[test]
    fn revived_higher_id_does_not_preempt() {
        let (g, sim) = mk(3);
        g.kill(ReplicaId(2));
        sim.advance(1);
        g.tick();
        assert_eq!(g.leader().unwrap().0, ReplicaId(1));
        g.revive(ReplicaId(2));
        // Healthy current leader: revived replica must wait its turn.
        g.heartbeat(ReplicaId(1));
        assert!(g.tick().is_none());
        assert_eq!(g.leader().unwrap().0, ReplicaId(1));
    }
}
