//! The scheduler master: the single node that "is in charge of monitoring
//! all computational resources and scheduling tasks for all clients"
//! (paper §3.2).

use super::placement::PlacementPolicy;
use super::queue::JobQueue;
use super::JobSpec;
use crate::cluster::{Cluster, NodeId};
use crate::events::{EventKind, EventLog, Level};
use std::sync::Mutex;

/// Result of a job submission.
#[derive(Debug, Clone, PartialEq)]
pub enum SubmitOutcome {
    /// Empty-queue fast path: the client is immediately told its node.
    PlacedImmediately(NodeId),
    /// Queued behind other work (or nothing currently fits).
    Queued { position: usize },
}

/// Scheduling counters, exposed by `nsml cluster` and the benches.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct SchedStats {
    pub submitted: u64,
    pub fast_path_hits: u64,
    pub queued: u64,
    pub placed_from_queue: u64,
    pub requeued: u64,
    pub completed: u64,
    pub cancelled: u64,
}

/// Default skip window for the master's job queue (`[scheduler]
/// skip_window` config overrides it via [`Master::with_skip_window`]).
pub const DEFAULT_SKIP_WINDOW: usize = 16;

/// The master scheduler. Thread-safe: submissions and completions may come
/// from any client thread.
pub struct Master {
    cluster: Cluster,
    inner: Mutex<Inner>,
    events: EventLog,
    /// Paper §3.2: skip the queue entirely when it is empty.
    pub fast_path: bool,
}

struct Inner {
    queue: JobQueue,
    policy: Box<dyn PlacementPolicy>,
    stats: SchedStats,
    /// Jobs currently placed: id -> (spec, node).
    running: std::collections::BTreeMap<String, (JobSpec, NodeId)>,
}

impl Master {
    pub fn new(cluster: Cluster, policy: Box<dyn PlacementPolicy>, events: EventLog) -> Master {
        Master {
            cluster,
            inner: Mutex::new(Inner {
                queue: JobQueue::with_skip_window(DEFAULT_SKIP_WINDOW),
                policy,
                stats: SchedStats::default(),
                running: std::collections::BTreeMap::new(),
            }),
            events,
            fast_path: true,
        }
    }

    /// Disable the §3.2 fast path (ablation E5).
    pub fn without_fast_path(mut self) -> Master {
        self.fast_path = false;
        self
    }

    /// Use strict head-of-line blocking instead of a skip window.
    pub fn with_skip_window(self, window: usize) -> Master {
        self.inner.lock().unwrap().queue.skip_window = window;
        self
    }

    /// Admission hook: would `req` fit on some alive node right now?
    /// The tenancy layer holds submissions back in its own fair-share
    /// queue until this says yes, so the master's queue only carries
    /// already-admitted work (allocation races, orphan requeues).
    pub fn can_place(&self, req: &crate::cluster::ResourceReq) -> bool {
        self.inner.lock().unwrap().policy.place(req, &self.cluster.snapshot()).is_some()
    }

    /// Submit a job. Fast path: empty queue + a fitting node → place now.
    pub fn submit(&self, job: JobSpec) -> SubmitOutcome {
        let mut inner = self.inner.lock().unwrap();
        inner.stats.submitted += 1;
        if self.fast_path && inner.queue.is_empty() {
            if let Some(node) = inner.policy.place(&job.req, &self.cluster.snapshot()) {
                if self.cluster.allocate(node, &job.id, &job.req).is_some() {
                    inner.stats.fast_path_hits += 1;
                    inner.running.insert(job.id.clone(), (job.clone(), node));
                    self.events.bus().publish(
                        Level::Info,
                        "scheduler",
                        &job.id,
                        EventKind::PlacementDecided { node: node.0, from_queue: false },
                    );
                    return SubmitOutcome::PlacedImmediately(node);
                }
            }
        }
        inner.stats.queued += 1;
        self.events.info("scheduler", &job.id, "queued");
        inner.queue.push(job);
        SubmitOutcome::Queued { position: inner.queue.len() - 1 }
    }

    /// Schedule as many queued jobs as currently fit. Returns placements.
    /// Called by the platform on completions, heartbeats and timers.
    pub fn pump(&self) -> Vec<(JobSpec, NodeId)> {
        let mut placed = Vec::new();
        let mut inner = self.inner.lock().unwrap();
        loop {
            let snapshot = self.cluster.snapshot();
            let Inner { queue, policy, .. } = &mut *inner;
            let Some(job) = queue.pop_placeable(|j| policy.place(&j.req, &snapshot).is_some()) else {
                break;
            };
            // Between pop and allocate nothing can interleave (we hold the
            // lock), so placement must succeed; be defensive anyway.
            let node = inner.policy.place(&job.req, &snapshot).expect("pop_placeable guaranteed fit");
            if self.cluster.allocate(node, &job.id, &job.req).is_none() {
                self.events.warn("scheduler", &job.id, "allocation raced; requeueing");
                inner.queue.push_front(job);
                break;
            }
            inner.stats.placed_from_queue += 1;
            inner.running.insert(job.id.clone(), (job.clone(), node));
            self.events.bus().publish(
                Level::Info,
                "scheduler",
                &job.id,
                EventKind::PlacementDecided { node: node.0, from_queue: true },
            );
            placed.push((job, node));
        }
        placed
    }

    /// A job finished (or was stopped): release its resources and try to
    /// schedule more work. Returns newly placed jobs.
    pub fn complete(&self, job_id: &str) -> Vec<(JobSpec, NodeId)> {
        {
            let mut inner = self.inner.lock().unwrap();
            if inner.running.remove(job_id).is_some() {
                inner.stats.completed += 1;
            }
        }
        self.cluster.release(job_id);
        self.events.info("scheduler", job_id, "completed");
        self.pump()
    }

    /// Cancel a queued (not yet placed) job.
    pub fn cancel_queued(&self, job_id: &str) -> bool {
        let mut inner = self.inner.lock().unwrap();
        let removed = inner.queue.remove(job_id).is_some();
        if removed {
            inner.stats.cancelled += 1;
            self.events.info("scheduler", job_id, "cancelled while queued");
        }
        removed
    }

    /// Handle node failures: requeue orphaned jobs at lane fronts, then
    /// pump. Returns (requeued ids, new placements).
    pub fn handle_orphans(&self, orphans: &[String]) -> (Vec<String>, Vec<(JobSpec, NodeId)>) {
        let mut requeued = Vec::new();
        {
            let mut inner = self.inner.lock().unwrap();
            for id in orphans {
                if let Some((spec, _)) = inner.running.remove(id) {
                    inner.stats.requeued += 1;
                    self.events.warn("scheduler", id, "node lost; requeueing job");
                    inner.queue.push_front(spec);
                    requeued.push(id.clone());
                }
            }
        }
        let placed = self.pump();
        (requeued, placed)
    }

    /// Periodic maintenance: reap dead nodes, requeue their jobs, pump.
    pub fn tick(&self) -> Vec<(JobSpec, NodeId)> {
        let orphans = self.cluster.reap_dead();
        if orphans.is_empty() {
            self.pump()
        } else {
            self.handle_orphans(&orphans).1
        }
    }

    pub fn stats(&self) -> SchedStats {
        self.inner.lock().unwrap().stats
    }

    pub fn queue_len(&self) -> usize {
        self.inner.lock().unwrap().queue.len()
    }

    pub fn queued_jobs(&self) -> Vec<JobSpec> {
        self.inner.lock().unwrap().queue.snapshot()
    }

    pub fn running_jobs(&self) -> Vec<(JobSpec, NodeId)> {
        self.inner.lock().unwrap().running.values().cloned().collect()
    }

    pub fn is_running(&self, job_id: &str) -> Option<NodeId> {
        self.inner.lock().unwrap().running.get(job_id).map(|(_, n)| *n)
    }

    pub fn policy_name(&self) -> &'static str {
        self.inner.lock().unwrap().policy.name()
    }

    pub fn cluster(&self) -> &Cluster {
        &self.cluster
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::placement::BestFit;
    use crate::scheduler::Priority;
    use crate::util::clock::sim_clock;

    fn mk(nodes: usize, gpus: usize) -> Master {
        let (clock, _) = sim_clock();
        let events = EventLog::new(clock.clone()).with_echo(false);
        let cluster = Cluster::homogeneous(clock, events.clone(), nodes, gpus, 24.0);
        Master::new(cluster, Box::new(BestFit), events)
    }

    #[test]
    fn fast_path_on_empty_queue() {
        let m = mk(2, 4);
        match m.submit(JobSpec::new("a", 2)) {
            SubmitOutcome::PlacedImmediately(_) => {}
            other => panic!("expected fast path, got {:?}", other),
        }
        assert_eq!(m.stats().fast_path_hits, 1);
        assert_eq!(m.queue_len(), 0);
    }

    #[test]
    fn queues_when_full_then_pumps_on_complete() {
        let m = mk(1, 2);
        assert!(matches!(m.submit(JobSpec::new("a", 2)), SubmitOutcome::PlacedImmediately(_)));
        assert!(matches!(m.submit(JobSpec::new("b", 2)), SubmitOutcome::Queued { .. }));
        assert_eq!(m.queue_len(), 1);
        let placed = m.complete("a");
        assert_eq!(placed.len(), 1);
        assert_eq!(placed[0].0.id, "b");
        assert_eq!(m.queue_len(), 0);
        assert_eq!(m.stats().placed_from_queue, 1);
    }

    #[test]
    fn no_fast_path_when_queue_nonempty() {
        let m = mk(1, 4);
        m.submit(JobSpec::new("a", 4));
        m.submit(JobSpec::new("b", 4)); // queued, cluster full
        // c fits nowhere anyway, but even a 0-gpu job must queue behind b.
        let out = m.submit(JobSpec::new("c", 1));
        assert!(matches!(out, SubmitOutcome::Queued { .. }));
        assert_eq!(m.stats().fast_path_hits, 1);
    }

    #[test]
    fn priority_order_from_queue() {
        let m = mk(1, 2);
        m.submit(JobSpec::new("hog", 2));
        m.submit(JobSpec::new("low", 1).with_priority(Priority::Low));
        m.submit(JobSpec::new("high", 1).with_priority(Priority::High));
        let placed = m.complete("hog");
        // Both fit after hog leaves; high must come first.
        assert_eq!(placed[0].0.id, "high");
        assert_eq!(placed[1].0.id, "low");
    }

    #[test]
    fn orphan_requeue_preserves_turn() {
        let m = mk(2, 2);
        m.submit(JobSpec::new("a", 2));
        m.submit(JobSpec::new("b", 2));
        // Cluster full; queue c.
        m.submit(JobSpec::new("c", 2));
        assert_eq!(m.queue_len(), 1);
        let node_a = m.is_running("a").unwrap();
        let orphans = m.cluster().kill_node(node_a);
        let (requeued, placed) = m.handle_orphans(&orphans);
        assert_eq!(requeued, vec!["a".to_string()]);
        // One node left with 2 GPUs free only after... kill freed node_a but
        // it's dead, so nothing fits: both a and c stay queued.
        assert!(placed.is_empty());
        assert_eq!(m.queue_len(), 2);
        // Requeued job goes first.
        assert_eq!(m.queued_jobs()[0].id, "a");
        // Revive → tick places the requeued job first (only 2 GPUs free).
        m.cluster().revive_node(node_a);
        let placed = m.tick();
        assert_eq!(placed.len(), 1);
        assert_eq!(placed[0].0.id, "a");
        // Once b finishes, c gets its node too.
        let placed = m.complete("b");
        assert_eq!(placed.len(), 1);
        assert_eq!(placed[0].0.id, "c");
        assert_eq!(m.queue_len(), 0);
    }

    #[test]
    fn can_place_tracks_capacity() {
        let m = mk(1, 2);
        assert!(m.can_place(&crate::cluster::ResourceReq::gpus(2)));
        assert!(!m.can_place(&crate::cluster::ResourceReq::gpus(3)));
        m.submit(JobSpec::new("a", 2));
        assert!(!m.can_place(&crate::cluster::ResourceReq::gpus(1)));
        m.complete("a");
        assert!(m.can_place(&crate::cluster::ResourceReq::gpus(1)));
    }

    #[test]
    fn skip_window_is_configurable() {
        // Strict head-of-line (window 0): a blocked big job gates the
        // small one behind it.
        let m = mk(1, 2).with_skip_window(0);
        m.submit(JobSpec::new("hog", 2));
        m.submit(JobSpec::new("big", 2));
        m.submit(JobSpec::new("small", 1));
        assert!(m.pump().is_empty(), "strict mode: blocked head admits nothing");
        // Default window lets the small job through the same shape.
        let m = mk(1, 2);
        m.submit(JobSpec::new("hog", 2));
        m.submit(JobSpec::new("big", 2));
        m.submit(JobSpec::new("small", 1));
        assert!(m.pump().is_empty(), "still no room while hog runs");
        let placed = m.complete("hog");
        // 2 GPUs free: big fits; after big there is no room for small.
        assert_eq!(placed.len(), 1);
        assert_eq!(placed[0].0.id, "big");
    }

    #[test]
    fn cancel_queued_job() {
        let m = mk(1, 1);
        m.submit(JobSpec::new("a", 1));
        m.submit(JobSpec::new("b", 1));
        assert!(m.cancel_queued("b"));
        assert!(!m.cancel_queued("b"));
        assert!(!m.cancel_queued("a")); // running, not queued
        assert_eq!(m.stats().cancelled, 1);
    }

    #[test]
    fn stats_conservation() {
        // Every submitted job is exactly one of: running, queued, completed.
        let m = mk(2, 4);
        for i in 0..20 {
            m.submit(JobSpec::new(&format!("j{}", i), 1 + i % 4));
        }
        for i in 0..10 {
            m.complete(&format!("j{}", i));
        }
        m.pump();
        let s = m.stats();
        let accounted = m.running_jobs().len() as u64 + m.queue_len() as u64 + s.completed;
        assert_eq!(accounted, s.submitted, "conservation: {:?}", s);
    }
}
