//! The master's job queue: strict priority order, FIFO within a priority.

use super::{JobSpec, Priority};
use std::collections::VecDeque;

/// Priority job queue. `pop_first_fit` supports scheduling the highest
/// priority job that can currently be placed (skipping blocked jobs would
/// starve big jobs, so by default we only skip within a bounded window).
#[derive(Debug, Default)]
pub struct JobQueue {
    lanes: [VecDeque<JobSpec>; 3],
    len: usize,
    /// How many blocked jobs a scheduling pass may skip per lane before
    /// stopping (0 = strict head-of-line; large = fully work-conserving).
    pub skip_window: usize,
}

impl JobQueue {
    pub fn new() -> JobQueue {
        JobQueue { lanes: Default::default(), len: 0, skip_window: 0 }
    }

    /// Work-conserving variant: may skip up to `window` unplaceable jobs.
    pub fn with_skip_window(window: usize) -> JobQueue {
        JobQueue { lanes: Default::default(), len: 0, skip_window: window }
    }

    pub fn push(&mut self, job: JobSpec) {
        self.lanes[job.priority as usize].push_back(job);
        self.len += 1;
    }

    /// Push back at the *front* of its lane (requeue after node failure, so
    /// the victim does not lose its turn).
    pub fn push_front(&mut self, job: JobSpec) {
        self.lanes[job.priority as usize].push_front(job);
        self.len += 1;
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Peek at the job that would be popped next (highest priority, FIFO).
    pub fn peek(&self) -> Option<&JobSpec> {
        for lane in [Priority::High, Priority::Normal, Priority::Low] {
            if let Some(j) = self.lanes[lane as usize].front() {
                return Some(j);
            }
        }
        None
    }

    /// Pop the first job (priority order) for which `placeable` returns
    /// true, skipping at most `skip_window` blocked jobs per lane.
    pub fn pop_placeable<F: FnMut(&JobSpec) -> bool>(&mut self, mut placeable: F) -> Option<JobSpec> {
        for lane in [Priority::High, Priority::Normal, Priority::Low] {
            let q = &mut self.lanes[lane as usize];
            let limit = self.skip_window.min(q.len().saturating_sub(1));
            for idx in 0..=limit {
                if idx >= q.len() {
                    break;
                }
                if placeable(&q[idx]) {
                    let job = q.remove(idx).unwrap();
                    self.len -= 1;
                    return Some(job);
                }
                if idx == limit {
                    // Head (and window) blocked: strict lanes do not let
                    // lower lanes jump ahead of a blocked high lane.
                    return None;
                }
            }
        }
        None
    }

    /// Remove a queued job by id (client cancelled before placement).
    pub fn remove(&mut self, id: &str) -> Option<JobSpec> {
        for lane in self.lanes.iter_mut() {
            if let Some(pos) = lane.iter().position(|j| j.id == id) {
                self.len -= 1;
                return lane.remove(pos);
            }
        }
        None
    }

    /// Snapshot of queued jobs in pop order.
    pub fn snapshot(&self) -> Vec<JobSpec> {
        let mut v = Vec::with_capacity(self.len);
        for lane in [Priority::High, Priority::Normal, Priority::Low] {
            v.extend(self.lanes[lane as usize].iter().cloned());
        }
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn job(id: &str, p: Priority) -> JobSpec {
        JobSpec::new(id, 1).with_priority(p)
    }

    #[test]
    fn priority_then_fifo() {
        let mut q = JobQueue::new();
        q.push(job("n1", Priority::Normal));
        q.push(job("h1", Priority::High));
        q.push(job("n2", Priority::Normal));
        q.push(job("l1", Priority::Low));
        q.push(job("h2", Priority::High));
        let order: Vec<String> = std::iter::from_fn(|| q.pop_placeable(|_| true)).map(|j| j.id).collect();
        assert_eq!(order, vec!["h1", "h2", "n1", "n2", "l1"]);
        assert!(q.is_empty());
    }

    #[test]
    fn strict_head_of_line_blocks() {
        let mut q = JobQueue::new();
        q.push(job("big", Priority::Normal)); // pretend unplaceable
        q.push(job("small", Priority::Normal));
        // skip_window = 0: blocked head means nothing pops.
        assert!(q.pop_placeable(|j| j.id == "small").is_none());
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn skip_window_lets_small_jobs_through() {
        let mut q = JobQueue::with_skip_window(4);
        q.push(job("big", Priority::Normal));
        q.push(job("small", Priority::Normal));
        let got = q.pop_placeable(|j| j.id == "small").unwrap();
        assert_eq!(got.id, "small");
        assert_eq!(q.len(), 1);
        assert_eq!(q.peek().unwrap().id, "big");
    }

    #[test]
    fn high_lane_blocks_lower_lanes() {
        // A blocked High job must not be overtaken by Normal (priority
        // inversion guard).
        let mut q = JobQueue::with_skip_window(8);
        q.push(job("high-big", Priority::High));
        q.push(job("norm", Priority::Normal));
        assert!(q.pop_placeable(|j| j.id == "norm").is_none());
    }

    #[test]
    fn requeue_at_front() {
        let mut q = JobQueue::new();
        q.push(job("a", Priority::Normal));
        q.push_front(job("victim", Priority::Normal));
        assert_eq!(q.pop_placeable(|_| true).unwrap().id, "victim");
    }

    #[test]
    fn skip_window_boundary_is_exact() {
        // window = 2: indexes 0..=2 are candidates; index 3 is beyond
        // the starvation bound and must never be reached.
        let mut q = JobQueue::with_skip_window(2);
        for id in ["a", "b", "c", "d"] {
            q.push(job(id, Priority::Normal));
        }
        assert!(q.pop_placeable(|j| j.id == "d").is_none(), "index 3 > window");
        assert_eq!(q.len(), 4, "a blocked pass removes nothing");
        // Index 2 == window: still reachable.
        assert_eq!(q.pop_placeable(|j| j.id == "c").unwrap().id, "c");
        assert_eq!(q.len(), 3);
        // The window also clamps to the lane length (no out-of-bounds
        // probing on short lanes).
        let mut q = JobQueue::with_skip_window(100);
        q.push(job("only", Priority::Normal));
        assert!(q.pop_placeable(|_| false).is_none());
        assert_eq!(q.pop_placeable(|_| true).unwrap().id, "only");
    }

    #[test]
    fn requeue_front_preserves_lane_order_under_skip() {
        // A requeued victim keeps its turn: FIFO from the front when
        // everything fits...
        let mut q = JobQueue::with_skip_window(4);
        q.push(job("a", Priority::Normal));
        q.push(job("b", Priority::Normal));
        q.push_front(job("victim", Priority::Normal));
        let order: Vec<String> =
            std::iter::from_fn(|| q.pop_placeable(|_| true)).map(|j| j.id).collect();
        assert_eq!(order, vec!["victim", "a", "b"]);
        // ...and when the victim is blocked, the window admits later
        // jobs while the victim keeps the head slot for its next shot.
        let mut q = JobQueue::with_skip_window(4);
        q.push(job("a", Priority::Normal));
        q.push_front(job("victim", Priority::Normal));
        assert_eq!(q.pop_placeable(|j| j.id == "a").unwrap().id, "a");
        assert_eq!(q.peek().unwrap().id, "victim");
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn blocked_high_head_gates_lower_lanes_even_with_requeue() {
        // Cross-lane interaction: a requeued High victim at its lane
        // head still gates Normal/Low entirely — the skip window only
        // skips *within* a lane, never across a blocked higher lane.
        let mut q = JobQueue::with_skip_window(8);
        q.push(job("h-tail", Priority::High));
        q.push_front(job("h-victim", Priority::High));
        q.push(job("n", Priority::Normal));
        q.push(job("l", Priority::Low));
        assert!(q.pop_placeable(|j| j.priority != Priority::High).is_none());
        // Unblock: the victim pops first, then its lane, then lower lanes.
        assert_eq!(q.pop_placeable(|j| j.id == "h-victim").unwrap().id, "h-victim");
        assert_eq!(q.pop_placeable(|_| true).unwrap().id, "h-tail");
        assert_eq!(q.pop_placeable(|_| true).unwrap().id, "n");
        assert_eq!(q.pop_placeable(|_| true).unwrap().id, "l");
        assert!(q.is_empty());
    }

    #[test]
    fn remove_by_id() {
        let mut q = JobQueue::new();
        q.push(job("a", Priority::Normal));
        q.push(job("b", Priority::Low));
        assert_eq!(q.remove("b").unwrap().id, "b");
        assert!(q.remove("b").is_none());
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn snapshot_in_pop_order() {
        let mut q = JobQueue::new();
        q.push(job("l", Priority::Low));
        q.push(job("h", Priority::High));
        let ids: Vec<String> = q.snapshot().into_iter().map(|j| j.id).collect();
        assert_eq!(ids, vec!["h", "l"]);
        assert_eq!(q.len(), 2); // snapshot does not consume
    }
}
