//! The NSML scheduler (paper §3.2) — the platform's core coordination
//! contribution.
//!
//! A **centralized master–slave** design: one master node watches every
//! node's resources (via [`crate::cluster`] heartbeats) and places jobs;
//! slaves only report state. The paper's two distinguishing behaviours are
//! implemented faithfully:
//!
//! 1. **Empty-queue fast path** — "If the job queue is empty, the scheduler
//!    immediately selects an available slave node and informs the client
//!    about its address … this approach allows the scheduler to avoid queue
//!    operation overhead." ([`Master::submit`] with `fast_path`.)
//! 2. **SPOF handling via leader election** — "We handle this issue with the
//!    leader election process by electing new master node as in Zookeeper."
//!    ([`election`] implements a bully-style election over scheduler
//!    replicas with epochs.)

pub mod election;
pub mod master;
pub mod placement;
pub mod queue;

pub use election::{ElectionGroup, ReplicaId};
pub use master::{Master, SchedStats, SubmitOutcome, DEFAULT_SKIP_WINDOW};
pub use placement::{policy_by_name, BestFit, FirstFit, PlacementPolicy, RandomFit, WorstFit};
pub use queue::JobQueue;

use crate::cluster::ResourceReq;

/// Job priority; higher schedules first (paper §3.1: "parallel runs with
/// different jobs priorities").
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Priority {
    Low = 0,
    Normal = 1,
    High = 2,
}

impl Priority {
    /// Parse a wire-format priority (inherent, not `FromStr`: parsing is
    /// total here — unknown strings fall back to `Normal`).
    #[allow(clippy::should_implement_trait)]
    pub fn from_str(s: &str) -> Priority {
        match s {
            "low" => Priority::Low,
            "high" => Priority::High,
            _ => Priority::Normal,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            Priority::Low => "low",
            Priority::Normal => "normal",
            Priority::High => "high",
        }
    }
}

/// What a client submits to the scheduler: "clients have to submit a job to
/// the scheduler for obtaining computational resources" (§3.2).
#[derive(Debug, Clone)]
pub struct JobSpec {
    pub id: String,
    pub user: String,
    pub dataset: String,
    pub req: ResourceReq,
    pub priority: Priority,
}

impl JobSpec {
    pub fn new(id: &str, gpus: usize) -> JobSpec {
        JobSpec {
            id: id.to_string(),
            user: "anon".to_string(),
            dataset: "default".to_string(),
            req: ResourceReq::gpus(gpus),
            priority: Priority::Normal,
        }
    }

    pub fn with_priority(mut self, p: Priority) -> Self {
        self.priority = p;
        self
    }

    pub fn with_user(mut self, u: &str) -> Self {
        self.user = u.to_string();
        self
    }

    pub fn with_dataset(mut self, d: &str) -> Self {
        self.dataset = d.to_string();
        self
    }
}
