//! Placement policies: which node gets the job.
//!
//! The paper motivates this with the ResNet-152 anecdote (§2): a cluster
//! may have enough total GPUs while no *single* node has eight free — bad
//! placement causes exactly that fragmentation. `bench_placement.rs`
//! ablates these policies (experiment E11).

use crate::cluster::{NodeId, NodeView, ResourceReq};
use crate::util::rng::Rng;
use std::sync::Mutex;

/// A node-selection strategy.
pub trait PlacementPolicy: Send + Sync {
    fn name(&self) -> &'static str;
    /// Choose a node for `req` among `nodes`, or `None` if nothing fits.
    fn place(&self, req: &ResourceReq, nodes: &[NodeView]) -> Option<NodeId>;
}

/// First node (by id) that fits. O(n), minimal decision latency.
pub struct FirstFit;

impl PlacementPolicy for FirstFit {
    fn name(&self) -> &'static str {
        "first_fit"
    }

    fn place(&self, req: &ResourceReq, nodes: &[NodeView]) -> Option<NodeId> {
        nodes.iter().find(|n| n.fits(req)).map(|n| n.id)
    }
}

/// Node that leaves the fewest free GPUs after placement — keeps big
/// contiguous blocks available for 8-GPU jobs (the anti-fragmentation
/// choice; NSML's default).
pub struct BestFit;

impl PlacementPolicy for BestFit {
    fn name(&self) -> &'static str {
        "best_fit"
    }

    fn place(&self, req: &ResourceReq, nodes: &[NodeView]) -> Option<NodeId> {
        nodes
            .iter()
            .filter(|n| n.fits(req))
            .min_by_key(|n| (n.free_gpus - req.gpus, n.id))
            .map(|n| n.id)
    }
}

/// Node with the most free GPUs (spread / load-balance). Deliberately
/// fragmentation-prone; the ablation baseline.
pub struct WorstFit;

impl PlacementPolicy for WorstFit {
    fn name(&self) -> &'static str {
        "worst_fit"
    }

    fn place(&self, req: &ResourceReq, nodes: &[NodeView]) -> Option<NodeId> {
        nodes
            .iter()
            .filter(|n| n.fits(req))
            .max_by_key(|n| (n.free_gpus, std::cmp::Reverse(n.id)))
            .map(|n| n.id)
    }
}

/// Uniformly random among fitting nodes (the "manual assignment by
/// developers sharing servers" baseline from §2).
pub struct RandomFit {
    rng: Mutex<Rng>,
}

impl RandomFit {
    pub fn new(seed: u64) -> RandomFit {
        RandomFit { rng: Mutex::new(Rng::new(seed)) }
    }
}

impl PlacementPolicy for RandomFit {
    fn name(&self) -> &'static str {
        "random"
    }

    fn place(&self, req: &ResourceReq, nodes: &[NodeView]) -> Option<NodeId> {
        let fits: Vec<NodeId> = nodes.iter().filter(|n| n.fits(req)).map(|n| n.id).collect();
        if fits.is_empty() {
            None
        } else {
            let mut rng = self.rng.lock().unwrap();
            Some(*rng.choice(&fits))
        }
    }
}

/// Look up a policy by config name.
pub fn policy_by_name(name: &str, seed: u64) -> Box<dyn PlacementPolicy> {
    match name {
        "first_fit" => Box::new(FirstFit),
        "worst_fit" | "spread" => Box::new(WorstFit),
        "random" => Box::new(RandomFit::new(seed)),
        _ => Box::new(BestFit),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::clock::Millis;

    fn view(id: u32, total: usize, free: usize) -> NodeView {
        NodeView {
            id: NodeId(id),
            hostname: format!("node-{:02}", id),
            total_gpus: total,
            free_gpus: free,
            total_cpus: 64,
            free_cpus: 64,
            total_mem_gb: 256.0,
            free_mem_gb: 256.0,
            alive: true,
            last_heartbeat_ms: 0 as Millis,
            jobs: vec![],
        }
    }

    fn req(gpus: usize) -> ResourceReq {
        ResourceReq { gpus, cpus: 1, mem_gb: 1.0 }
    }

    #[test]
    fn first_fit_takes_lowest_id() {
        let nodes = vec![view(0, 8, 2), view(1, 8, 8), view(2, 8, 8)];
        assert_eq!(FirstFit.place(&req(2), &nodes), Some(NodeId(0)));
        assert_eq!(FirstFit.place(&req(4), &nodes), Some(NodeId(1)));
    }

    #[test]
    fn best_fit_minimizes_leftover() {
        let nodes = vec![view(0, 8, 8), view(1, 8, 3), view(2, 8, 5)];
        // req 2: node 1 leaves 1 free — tightest.
        assert_eq!(BestFit.place(&req(2), &nodes), Some(NodeId(1)));
        // req 8: only node 0.
        assert_eq!(BestFit.place(&req(8), &nodes), Some(NodeId(0)));
    }

    #[test]
    fn worst_fit_maximizes_leftover() {
        let nodes = vec![view(0, 8, 4), view(1, 8, 8), view(2, 8, 6)];
        assert_eq!(WorstFit.place(&req(2), &nodes), Some(NodeId(1)));
    }

    #[test]
    fn none_when_fragmented() {
        // The §2 anecdote: 8 total GPUs free, but no node has 8.
        let nodes = vec![view(0, 8, 4), view(1, 8, 4)];
        for p in [&FirstFit as &dyn PlacementPolicy, &BestFit, &WorstFit] {
            assert_eq!(p.place(&req(8), &nodes), None, "{}", p.name());
        }
    }

    #[test]
    fn dead_nodes_excluded() {
        let mut n = view(0, 8, 8);
        n.alive = false;
        assert_eq!(BestFit.place(&req(1), &[n]), None);
    }

    #[test]
    fn random_fit_only_picks_fitting() {
        let nodes = vec![view(0, 8, 0), view(1, 8, 8), view(2, 8, 1)];
        let p = RandomFit::new(42);
        for _ in 0..50 {
            let got = p.place(&req(2), &nodes).unwrap();
            assert_eq!(got, NodeId(1));
        }
        // With two candidates both get picked eventually.
        let nodes2 = vec![view(0, 8, 4), view(1, 8, 4)];
        let picks: std::collections::BTreeSet<u32> =
            (0..50).map(|_| p.place(&req(2), &nodes2).unwrap().0).collect();
        assert_eq!(picks.len(), 2);
    }

    #[test]
    fn policy_by_name_round_trip() {
        for name in ["first_fit", "best_fit", "worst_fit", "random"] {
            let p = policy_by_name(name, 1);
            if name == "spread" || name == "worst_fit" {
                assert_eq!(p.name(), "worst_fit");
            } else {
                assert_eq!(p.name(), name);
            }
        }
        assert_eq!(policy_by_name("unknown", 1).name(), "best_fit");
    }
}
