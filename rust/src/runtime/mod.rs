//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`) produced
//! by `python/compile/aot.py` and executes them from the Layer-3 hot path.
//!
//! Python never runs here. The flow per model is:
//!
//! ```text
//! manifest.json ──> ModelManifest (shapes/dtypes/arities)
//! *.hlo.txt     ──> HloModuleProto::from_text_file ──> client.compile (cached)
//! TrainableModel: params live as device literals; train_step/evaluate/
//!                 infer shuttle batches in and scalars out.
//! ```
//!
//! The PJRT wrapper types hold raw pointers and are used from one thread;
//! the platform funnels all model execution through a single session
//! runner (see [`crate::session`]), matching how one NSML ML-container
//! owns its GPUs.

mod engine;
mod manifest;
mod model;
mod tensor;

pub use engine::Engine;
pub use manifest::{Manifest, ModelManifest};
pub use model::TrainableModel;
pub use tensor::{Batch, TensorData};
