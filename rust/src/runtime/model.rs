//! A trainable model instance: AOT executables + live parameters.
//!
//! This is what an NSML "ML container" runs: parameters are initialized
//! (or restored from a checkpoint), then driven by `train_step` /
//! `train_scan` / `evaluate` / `infer` executions through the PJRT
//! engine. Parameter serialization feeds [`crate::storage::CheckpointStore`].

use super::engine::Engine;
use super::manifest::ModelManifest;
use super::tensor::{Batch, TensorData};
use anyhow::{anyhow, Result};
use std::sync::Arc;

/// A model instance bound to an engine, holding its parameters host-side
/// between steps.
pub struct TrainableModel {
    engine: Arc<Engine>,
    manifest: ModelManifest,
    params: Vec<xla::Literal>,
    pub steps_taken: u64,
}

impl TrainableModel {
    /// Create with parameters from the AOT `init(seed)` executable.
    pub fn init(engine: Arc<Engine>, model: &str, seed: i32) -> Result<TrainableModel> {
        let manifest = engine.manifest().model(model)?.clone();
        let params = engine.run(model, "init", &[xla::Literal::scalar(seed)])?;
        if params.len() != manifest.param_shapes.len() {
            return Err(anyhow!(
                "init returned {} arrays, manifest declares {}",
                params.len(),
                manifest.param_shapes.len()
            ));
        }
        Ok(TrainableModel { engine, manifest, params, steps_taken: 0 })
    }

    /// Create with parameters restored from serialized checkpoint bytes.
    pub fn from_checkpoint(engine: Arc<Engine>, model: &str, bytes: &[u8]) -> Result<TrainableModel> {
        let manifest = engine.manifest().model(model)?.clone();
        let params = deserialize_params(bytes, &manifest.param_shapes)?;
        Ok(TrainableModel { engine, manifest, params, steps_taken: 0 })
    }

    pub fn manifest(&self) -> &ModelManifest {
        &self.manifest
    }

    pub fn name(&self) -> &str {
        &self.manifest.name
    }

    fn args_with_params(&self, rest: Vec<xla::Literal>) -> Vec<xla::Literal> {
        let mut args: Vec<xla::Literal> = self.params.iter().map(clone_literal).collect();
        args.extend(rest);
        args
    }

    /// One SGD step on a batch; returns the loss.
    pub fn train_step(&mut self, batch: &Batch, lr: f32) -> Result<f32> {
        let args =
            self.args_with_params(vec![batch.x.to_literal()?, batch.y.to_literal()?, xla::Literal::scalar(lr)]);
        let mut out = self.engine.run(&self.manifest.name, "train_step", &args)?;
        let loss_lit = out.pop().ok_or_else(|| anyhow!("train_step returned nothing"))?;
        self.params = out;
        self.steps_taken += 1;
        Ok(loss_lit.to_vec::<f32>()?[0])
    }

    /// `scan_k` fused steps (the L2 perf path); returns mean loss.
    pub fn train_scan(&mut self, batches: &[Batch], lr: f32) -> Result<f32> {
        if batches.len() != self.manifest.scan_k {
            return Err(anyhow!(
                "train_scan needs exactly {} batches, got {}",
                self.manifest.scan_k,
                batches.len()
            ));
        }
        let xs = TensorData::stack(&batches.iter().map(|b| b.x.clone()).collect::<Vec<_>>())?;
        let ys = TensorData::stack(&batches.iter().map(|b| b.y.clone()).collect::<Vec<_>>())?;
        let args =
            self.args_with_params(vec![xs.to_literal()?, ys.to_literal()?, xla::Literal::scalar(lr)]);
        let mut out = self.engine.run(&self.manifest.name, "train_scan", &args)?;
        let loss_lit = out.pop().ok_or_else(|| anyhow!("train_scan returned nothing"))?;
        self.params = out;
        self.steps_taken += self.manifest.scan_k as u64;
        Ok(loss_lit.to_vec::<f32>()?[0])
    }

    /// Evaluate on a batch: (loss, metric).
    pub fn evaluate(&self, batch: &Batch) -> Result<(f32, f32)> {
        let args = self.args_with_params(vec![batch.x.to_literal()?, batch.y.to_literal()?]);
        let out = self.engine.run(&self.manifest.name, "evaluate", &args)?;
        if out.len() != 2 {
            return Err(anyhow!("evaluate returned {} outputs", out.len()));
        }
        Ok((out[0].to_vec::<f32>()?[0], out[1].to_vec::<f32>()?[0]))
    }

    /// Run inference; returns the flat f32 output.
    pub fn infer(&self, x: &TensorData) -> Result<Vec<f32>> {
        let args = self.args_with_params(vec![x.to_literal()?]);
        let out = self.engine.run(&self.manifest.name, "infer", &args)?;
        Ok(out[0].to_vec::<f32>()?)
    }

    /// Serialize parameters (checkpoint payload).
    pub fn params_bytes(&self) -> Result<Vec<u8>> {
        serialize_params(&self.params)
    }

    /// Replace parameters from checkpoint bytes (hyperparameter tuning in
    /// training time: pause, rewind/edit, resume — §3.3).
    pub fn load_params(&mut self, bytes: &[u8]) -> Result<()> {
        self.params = deserialize_params(bytes, &self.manifest.param_shapes)?;
        Ok(())
    }

    /// Parameter L2 norm (a quick structural fingerprint for tests/logs).
    pub fn params_norm(&self) -> Result<f64> {
        let mut acc = 0.0f64;
        for p in &self.params {
            for v in p.to_vec::<f32>()? {
                acc += (v as f64) * (v as f64);
            }
        }
        Ok(acc.sqrt())
    }
}

fn clone_literal(l: &xla::Literal) -> xla::Literal {
    // The xla crate's Literal has no Clone; round-trip through host data.
    // Shapes here are static so reshape never fails.
    let shape = l.array_shape().expect("literal shape");
    let dims: Vec<i64> = shape.dims().to_vec();
    match shape.ty() {
        xla::ElementType::F32 => {
            let v: Vec<f32> = l.to_vec().expect("literal data");
            xla::Literal::vec1(&v).reshape(&dims).expect("reshape")
        }
        xla::ElementType::S32 => {
            let v: Vec<i32> = l.to_vec().expect("literal data");
            xla::Literal::vec1(&v).reshape(&dims).expect("reshape")
        }
        other => panic!("unsupported literal type {:?}", other),
    }
}

/// Binary format: [n:u32] then per array [ndims:u32][dims:i64...][f32 data].
fn serialize_params(params: &[xla::Literal]) -> Result<Vec<u8>> {
    let mut out = Vec::new();
    out.extend_from_slice(&(params.len() as u32).to_le_bytes());
    for p in params {
        let shape = p.array_shape()?;
        let dims = shape.dims();
        out.extend_from_slice(&(dims.len() as u32).to_le_bytes());
        for d in dims {
            out.extend_from_slice(&(*d).to_le_bytes());
        }
        let data: Vec<f32> = p.to_vec()?;
        for v in &data {
            out.extend_from_slice(&v.to_le_bytes());
        }
    }
    Ok(out)
}

fn deserialize_params(bytes: &[u8], expect_shapes: &[Vec<i64>]) -> Result<Vec<xla::Literal>> {
    let mut pos = 0usize;
    let take = |pos: &mut usize, n: usize| -> Result<&[u8]> {
        if *pos + n > bytes.len() {
            return Err(anyhow!("checkpoint truncated at byte {}", pos));
        }
        let s = &bytes[*pos..*pos + n];
        *pos += n;
        Ok(s)
    };
    let n = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap()) as usize;
    if n != expect_shapes.len() {
        return Err(anyhow!("checkpoint has {} arrays, model expects {}", n, expect_shapes.len()));
    }
    let mut params = Vec::with_capacity(n);
    for shape in expect_shapes {
        let ndims = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap()) as usize;
        let mut dims = Vec::with_capacity(ndims);
        for _ in 0..ndims {
            dims.push(i64::from_le_bytes(take(&mut pos, 8)?.try_into().unwrap()));
        }
        if &dims != shape {
            return Err(anyhow!("checkpoint shape {:?} does not match model shape {:?}", dims, shape));
        }
        let count: i64 = dims.iter().product();
        let raw = take(&mut pos, count as usize * 4)?;
        let mut data = Vec::with_capacity(count as usize);
        for chunk in raw.chunks_exact(4) {
            data.push(f32::from_le_bytes(chunk.try_into().unwrap()));
        }
        params.push(xla::Literal::vec1(&data).reshape(&dims)?);
    }
    if pos != bytes.len() {
        return Err(anyhow!("checkpoint has {} trailing bytes", bytes.len() - pos));
    }
    Ok(params)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn engine() -> Option<Arc<Engine>> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json").exists().then(|| Arc::new(Engine::new(&dir).unwrap()))
    }

    fn mnist_batch(seed: u64, m: &ModelManifest) -> Batch {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(seed);
        let n: i64 = m.x_shape.iter().product();
        let x = TensorData::f32((0..n).map(|_| rng.f64() as f32).collect(), &m.x_shape);
        let b = m.y_shape[0] as usize;
        let y = TensorData::i32((0..b).map(|_| rng.below(10) as i32).collect(), &m.y_shape);
        Batch { x, y }
    }

    #[test]
    fn init_step_and_loss_decreases() {
        let Some(engine) = engine() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let mut model = TrainableModel::init(engine.clone(), "mnist_mlp", 7).unwrap();
        let batch = mnist_batch(1, model.manifest());
        let first = model.train_step(&batch, 0.1).unwrap();
        let mut last = first;
        for _ in 0..8 {
            last = model.train_step(&batch, 0.1).unwrap();
        }
        assert!(first.is_finite() && last.is_finite());
        assert!(last < first, "{} -> {}", first, last);
        assert_eq!(model.steps_taken, 9);
    }

    #[test]
    fn scan_matches_step_trajectory() {
        let Some(engine) = engine() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let mut by_step = TrainableModel::init(engine.clone(), "mnist_mlp", 3).unwrap();
        let mut by_scan = TrainableModel::init(engine.clone(), "mnist_mlp", 3).unwrap();
        let k = by_step.manifest().scan_k;
        let batches: Vec<Batch> = (0..k).map(|i| mnist_batch(100 + i as u64, by_step.manifest())).collect();
        let mut losses = Vec::new();
        for b in &batches {
            losses.push(by_step.train_step(b, 0.05).unwrap());
        }
        let scan_loss = by_scan.train_scan(&batches, 0.05).unwrap();
        let mean: f32 = losses.iter().sum::<f32>() / losses.len() as f32;
        assert!((scan_loss - mean).abs() < 1e-3, "{} vs {}", scan_loss, mean);
        let n1 = by_step.params_norm().unwrap();
        let n2 = by_scan.params_norm().unwrap();
        assert!((n1 - n2).abs() < 1e-3, "{} vs {}", n1, n2);
    }

    #[test]
    fn checkpoint_roundtrip_resumes_identically() {
        let Some(engine) = engine() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let mut model = TrainableModel::init(engine.clone(), "mnist_mlp", 11).unwrap();
        let batch = mnist_batch(5, model.manifest());
        model.train_step(&batch, 0.1).unwrap();
        let bytes = model.params_bytes().unwrap();
        let norm_before = model.params_norm().unwrap();

        let mut restored = TrainableModel::from_checkpoint(engine.clone(), "mnist_mlp", &bytes).unwrap();
        assert!((restored.params_norm().unwrap() - norm_before).abs() < 1e-9);
        // Training both one more step stays in lockstep.
        let l1 = model.train_step(&batch, 0.1).unwrap();
        let l2 = restored.train_step(&batch, 0.1).unwrap();
        assert_eq!(l1, l2);
    }

    #[test]
    fn corrupt_checkpoints_rejected() {
        let Some(engine) = engine() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let model = TrainableModel::init(engine.clone(), "mnist_mlp", 1).unwrap();
        let mut bytes = model.params_bytes().unwrap();
        bytes.truncate(bytes.len() - 3);
        assert!(TrainableModel::from_checkpoint(engine.clone(), "mnist_mlp", &bytes).is_err());
        assert!(TrainableModel::from_checkpoint(engine, "mnist_mlp", b"junk").is_err());
    }

    #[test]
    fn evaluate_and_infer() {
        let Some(engine) = engine() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let model = TrainableModel::init(engine.clone(), "mnist_mlp", 2).unwrap();
        let batch = mnist_batch(9, model.manifest());
        let (loss, acc) = model.evaluate(&batch).unwrap();
        assert!(loss.is_finite());
        assert!((0.0..=1.0).contains(&acc));
        let probs = model.infer(&batch.x).unwrap();
        assert_eq!(probs.len(), 64 * 10);
        let row: f32 = probs[..10].iter().sum();
        assert!((row - 1.0).abs() < 1e-4);
    }

    #[test]
    fn all_models_init_and_step() {
        let Some(engine) = engine() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        use crate::util::rng::Rng;
        for name in engine.manifest().model_names() {
            let mut model = TrainableModel::init(engine.clone(), &name, 1).unwrap();
            let m = model.manifest().clone();
            let mut rng = Rng::new(7);
            let xn: i64 = m.x_shape.iter().product();
            let x = if m.x_dtype == "i32" {
                TensorData::i32((0..xn).map(|_| rng.below(60) as i32).collect(), &m.x_shape)
            } else {
                TensorData::f32((0..xn).map(|_| rng.f64() as f32).collect(), &m.x_shape)
            };
            let yn: i64 = m.y_shape.iter().product();
            let y = if m.y_dtype == "i32" {
                TensorData::i32((0..yn).map(|_| rng.below(4) as i32).collect(), &m.y_shape)
            } else {
                TensorData::f32((0..yn).map(|_| rng.f64() as f32 * 5.0).collect(), &m.y_shape)
            };
            let batch = Batch { x, y };
            let loss = model.train_step(&batch, m.default_lr as f32).unwrap();
            assert!(loss.is_finite(), "{}: loss {}", name, loss);
        }
    }
}
