//! Host-side tensors shuttled between the platform and PJRT.

use anyhow::{anyhow, Result};

/// A dense host tensor (f32 or i32) with explicit shape.
#[derive(Debug, Clone, PartialEq)]
pub enum TensorData {
    F32 { data: Vec<f32>, shape: Vec<i64> },
    I32 { data: Vec<i32>, shape: Vec<i64> },
}

impl TensorData {
    pub fn f32(data: Vec<f32>, shape: &[i64]) -> TensorData {
        debug_assert_eq!(data.len() as i64, shape.iter().product::<i64>());
        TensorData::F32 { data, shape: shape.to_vec() }
    }

    pub fn i32(data: Vec<i32>, shape: &[i64]) -> TensorData {
        debug_assert_eq!(data.len() as i64, shape.iter().product::<i64>());
        TensorData::I32 { data, shape: shape.to_vec() }
    }

    pub fn shape(&self) -> &[i64] {
        match self {
            TensorData::F32 { shape, .. } => shape,
            TensorData::I32 { shape, .. } => shape,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            TensorData::F32 { data, .. } => data.len(),
            TensorData::I32 { data, .. } => data.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            TensorData::F32 { data, .. } => Ok(data),
            _ => Err(anyhow!("tensor is not f32")),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            TensorData::I32 { data, .. } => Ok(data),
            _ => Err(anyhow!("tensor is not i32")),
        }
    }

    /// Convert to an XLA literal.
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let lit = match self {
            TensorData::F32 { data, shape } => xla::Literal::vec1(data).reshape(shape)?,
            TensorData::I32 { data, shape } => xla::Literal::vec1(data).reshape(shape)?,
        };
        Ok(lit)
    }

    /// Stack `k` same-shape tensors along a new leading axis (scan input).
    pub fn stack(parts: &[TensorData]) -> Result<TensorData> {
        let first = parts.first().ok_or_else(|| anyhow!("stack of nothing"))?;
        let mut shape = vec![parts.len() as i64];
        shape.extend_from_slice(first.shape());
        match first {
            TensorData::F32 { .. } => {
                let mut data = Vec::with_capacity(first.len() * parts.len());
                for p in parts {
                    if p.shape() != first.shape() {
                        return Err(anyhow!("stack shape mismatch"));
                    }
                    data.extend_from_slice(p.as_f32()?);
                }
                Ok(TensorData::F32 { data, shape })
            }
            TensorData::I32 { .. } => {
                let mut data = Vec::with_capacity(first.len() * parts.len());
                for p in parts {
                    if p.shape() != first.shape() {
                        return Err(anyhow!("stack shape mismatch"));
                    }
                    data.extend_from_slice(p.as_i32()?);
                }
                Ok(TensorData::I32 { data, shape })
            }
        }
    }
}

/// One training batch: inputs + targets.
#[derive(Debug, Clone)]
pub struct Batch {
    pub x: TensorData,
    pub y: TensorData,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_access() {
        let t = TensorData::f32(vec![1.0, 2.0, 3.0, 4.0], &[2, 2]);
        assert_eq!(t.shape(), &[2, 2]);
        assert_eq!(t.len(), 4);
        assert_eq!(t.as_f32().unwrap()[3], 4.0);
        assert!(t.as_i32().is_err());
    }

    #[test]
    fn stack_f32() {
        let a = TensorData::f32(vec![1.0, 2.0], &[2]);
        let b = TensorData::f32(vec![3.0, 4.0], &[2]);
        let s = TensorData::stack(&[a, b]).unwrap();
        assert_eq!(s.shape(), &[2, 2]);
        assert_eq!(s.as_f32().unwrap(), &[1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn stack_mismatch_rejected() {
        let a = TensorData::i32(vec![1], &[1]);
        let b = TensorData::i32(vec![1, 2], &[2]);
        assert!(TensorData::stack(&[a, b]).is_err());
        assert!(TensorData::stack(&[]).is_err());
    }

    #[test]
    fn to_literal_roundtrip() {
        let t = TensorData::f32(vec![1.5, -2.5, 0.0, 9.0], &[2, 2]);
        let lit = t.to_literal().unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![1.5, -2.5, 0.0, 9.0]);
        let ti = TensorData::i32(vec![7, 8, 9], &[3]);
        let lit = ti.to_literal().unwrap();
        assert_eq!(lit.to_vec::<i32>().unwrap(), vec![7, 8, 9]);
    }
}
