//! Parse `artifacts/manifest.json` (written by `python/compile/aot.py`).

use crate::util::json::{parse, Json};
use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Manifest for one model's AOT artifacts.
#[derive(Debug, Clone)]
pub struct ModelManifest {
    pub name: String,
    pub param_shapes: Vec<Vec<i64>>,
    pub param_count: usize,
    pub batch: usize,
    pub x_shape: Vec<i64>,
    pub x_dtype: String,
    pub y_shape: Vec<i64>,
    pub y_dtype: String,
    pub infer_x_shape: Vec<i64>,
    pub infer_x_dtype: String,
    pub scan_k: usize,
    pub metric_name: String,
    pub lower_is_better: bool,
    pub description: String,
    pub default_lr: f64,
    /// entry name -> artifact file name.
    pub artifacts: BTreeMap<String, String>,
}

/// The whole manifest: model name -> [`ModelManifest`].
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub models: BTreeMap<String, ModelManifest>,
}

fn shape_list(j: &Json) -> Result<Vec<i64>> {
    Ok(j.as_arr()
        .ok_or_else(|| anyhow!("expected shape array"))?
        .iter()
        .map(|d| d.as_i64().unwrap_or(0))
        .collect())
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts` first)", path.display()))?;
        Self::parse_str(&text, dir)
    }

    /// Parse manifest text (exposed for tests).
    pub fn parse_str(text: &str, dir: PathBuf) -> Result<Manifest> {
        let j = parse(text).map_err(|e| anyhow!("manifest json: {}", e))?;
        let format = j.get("format").and_then(Json::as_i64).unwrap_or(0);
        if format != 1 {
            return Err(anyhow!("unsupported manifest format {}", format));
        }
        let mut models = BTreeMap::new();
        let model_obj = j.get("models").and_then(Json::as_obj).ok_or_else(|| anyhow!("no models"))?;
        for (name, frag) in model_obj {
            let get = |k: &str| frag.get(k).ok_or_else(|| anyhow!("model {}: missing '{}'", name, k));
            let mut artifacts = BTreeMap::new();
            for (entry, fname) in get("artifacts")?.as_obj().ok_or_else(|| anyhow!("artifacts not obj"))? {
                artifacts.insert(entry.clone(), fname.as_str().unwrap_or_default().to_string());
            }
            let param_shapes = get("param_shapes")?
                .as_arr()
                .ok_or_else(|| anyhow!("param_shapes not array"))?
                .iter()
                .map(shape_list)
                .collect::<Result<Vec<_>>>()?;
            models.insert(
                name.clone(),
                ModelManifest {
                    name: name.clone(),
                    param_shapes,
                    param_count: get("param_count")?.as_usize().unwrap_or(0),
                    batch: get("batch")?.as_usize().unwrap_or(0),
                    x_shape: shape_list(get("x_shape")?)?,
                    x_dtype: get("x_dtype")?.as_str().unwrap_or("f32").to_string(),
                    y_shape: shape_list(get("y_shape")?)?,
                    y_dtype: get("y_dtype")?.as_str().unwrap_or("f32").to_string(),
                    infer_x_shape: shape_list(get("infer_x_shape")?)?,
                    infer_x_dtype: get("infer_x_dtype")?.as_str().unwrap_or("f32").to_string(),
                    scan_k: get("scan_k")?.as_usize().unwrap_or(1),
                    metric_name: get("metric_name")?.as_str().unwrap_or("loss").to_string(),
                    lower_is_better: get("lower_is_better")?.as_bool().unwrap_or(true),
                    description: frag.get("description").and_then(Json::as_str).unwrap_or("").to_string(),
                    default_lr: frag
                        .at(&["hparam_defaults", "lr"])
                        .and_then(Json::as_f64)
                        .unwrap_or(0.1),
                    artifacts,
                },
            );
        }
        Ok(Manifest { dir, models })
    }

    pub fn model(&self, name: &str) -> Result<&ModelManifest> {
        self.models.get(name).ok_or_else(|| {
            anyhow!("model '{}' not in manifest (have: {:?})", name, self.models.keys().collect::<Vec<_>>())
        })
    }

    /// Absolute path of one model's artifact.
    pub fn artifact_path(&self, model: &str, entry: &str) -> Result<PathBuf> {
        let m = self.model(model)?;
        let fname = m
            .artifacts
            .get(entry)
            .ok_or_else(|| anyhow!("model '{}' has no entry '{}'", model, entry))?;
        Ok(self.dir.join(fname))
    }

    pub fn model_names(&self) -> Vec<String> {
        self.models.keys().cloned().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "format": 1,
      "models": {
        "toy": {
          "param_shapes": [[4, 2], [2]],
          "param_count": 10,
          "batch": 8,
          "x_shape": [8, 4], "x_dtype": "f32",
          "y_shape": [8], "y_dtype": "i32",
          "infer_x_shape": [8, 4], "infer_x_dtype": "f32",
          "scan_k": 4,
          "metric_name": "accuracy",
          "lower_is_better": false,
          "description": "toy",
          "hparam_defaults": {"lr": 0.5},
          "artifacts": {"init": "toy.init.hlo.txt", "train_step": "toy.train_step.hlo.txt"}
        }
      }
    }"#;

    #[test]
    fn parse_sample() {
        let m = Manifest::parse_str(SAMPLE, PathBuf::from("/tmp/a")).unwrap();
        let toy = m.model("toy").unwrap();
        assert_eq!(toy.param_shapes, vec![vec![4, 2], vec![2]]);
        assert_eq!(toy.batch, 8);
        assert_eq!(toy.y_dtype, "i32");
        assert_eq!(toy.scan_k, 4);
        assert!(!toy.lower_is_better);
        assert_eq!(toy.default_lr, 0.5);
        assert_eq!(
            m.artifact_path("toy", "init").unwrap(),
            PathBuf::from("/tmp/a/toy.init.hlo.txt")
        );
        assert!(m.artifact_path("toy", "nope").is_err());
        assert!(m.model("missing").is_err());
    }

    #[test]
    fn rejects_bad_format() {
        assert!(Manifest::parse_str(r#"{"format": 2, "models": {}}"#, PathBuf::new()).is_err());
        assert!(Manifest::parse_str("not json", PathBuf::new()).is_err());
    }

    #[test]
    fn loads_real_manifest_if_built() {
        // Integration check against the actual artifacts dir when present.
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.models.contains_key("mnist_mlp"));
            let mm = m.model("mnist_mlp").unwrap();
            assert_eq!(mm.x_shape, vec![64, 144]);
            for entry in ["init", "train_step", "train_scan", "evaluate", "infer"] {
                assert!(m.artifact_path("mnist_mlp", entry).unwrap().exists());
            }
        }
    }
}
