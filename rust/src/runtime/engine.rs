//! PJRT engine: CPU client + executable compile cache.

use super::manifest::Manifest;
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::path::Path;
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Compile statistics (exposed in `nsml cluster` / benches).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CompileStats {
    pub compiles: u64,
    pub cache_hits: u64,
    pub compile_ms_total: f64,
}

/// One PJRT client + cache of compiled executables, keyed by artifact
/// path. The underlying `xla` types are not `Send`, so an engine never
/// crosses threads: each executor worker builds its own (thread-local)
/// engine, and model execution funnels through the session runner that
/// owns it. The interior cache/stats use a `Mutex` purely so shared
/// `Arc<Engine>` handles on one thread (runner + trial evaluator) can
/// borrow concurrently without `RefCell` panics.
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: Mutex<BTreeMap<String, Arc<xla::PjRtLoadedExecutable>>>,
    stats: Mutex<CompileStats>,
}

impl Engine {
    /// Create a CPU engine over an artifacts directory.
    pub fn new(artifacts_dir: impl AsRef<Path>) -> Result<Engine> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine {
            client,
            manifest,
            cache: Mutex::new(BTreeMap::new()),
            stats: Mutex::new(CompileStats::default()),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform_name(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile (or fetch cached) the executable for a model entry.
    pub fn executable(&self, model: &str, entry: &str) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        let path = self.manifest.artifact_path(model, entry)?;
        let key = path.to_string_lossy().to_string();
        if let Some(exe) = self.cache.lock().unwrap().get(&key) {
            self.stats.lock().unwrap().cache_hits += 1;
            return Ok(exe.clone());
        }
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&key)
            .with_context(|| format!("parsing HLO text {}", key))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Arc::new(self.client.compile(&comp).with_context(|| format!("compiling {}", key))?);
        {
            let mut s = self.stats.lock().unwrap();
            s.compiles += 1;
            s.compile_ms_total += t0.elapsed().as_secs_f64() * 1000.0;
        }
        self.cache.lock().unwrap().insert(key, exe.clone());
        Ok(exe)
    }

    /// Execute an entry with literal inputs; outputs are the decomposed
    /// elements of the root tuple (aot.py lowers with return_tuple=True).
    pub fn run(&self, model: &str, entry: &str, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exe = self.executable(model, entry)?;
        let result = exe.execute::<xla::Literal>(args)?;
        let tuple = result[0][0].to_literal_sync()?;
        Ok(tuple.to_tuple()?)
    }

    /// Warm the cache for every entry of a model (container start does
    /// this so the first training step is not a compile stall).
    pub fn warmup(&self, model: &str) -> Result<usize> {
        let entries: Vec<String> = self.manifest.model(model)?.artifacts.keys().cloned().collect();
        for e in &entries {
            self.executable(model, e)?;
        }
        Ok(entries.len())
    }

    pub fn stats(&self) -> CompileStats {
        *self.stats.lock().unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn artifacts_dir() -> Option<PathBuf> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json").exists().then_some(dir)
    }

    #[test]
    fn engine_loads_and_runs_init() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let engine = Engine::new(&dir).unwrap();
        assert!(engine.platform_name().to_lowercase().contains("cpu") || !engine.platform_name().is_empty());
        let seed = xla::Literal::scalar(7i32);
        let params = engine.run("mnist_mlp", "init", &[seed]).unwrap();
        let mm = engine.manifest().model("mnist_mlp").unwrap();
        assert_eq!(params.len(), mm.param_shapes.len());
        // First weight matrix has the declared number of elements.
        let w1: Vec<f32> = params[0].to_vec().unwrap();
        assert_eq!(w1.len() as i64, mm.param_shapes[0].iter().product::<i64>());
        // Glorot init: nonzero, small-ish.
        assert!(w1.iter().any(|&v| v != 0.0));
        assert!(w1.iter().all(|&v| v.abs() < 1.0));
    }

    #[test]
    fn compile_cache_hits() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let engine = Engine::new(&dir).unwrap();
        engine.executable("mnist_mlp", "infer").unwrap();
        engine.executable("mnist_mlp", "infer").unwrap();
        let s = engine.stats();
        assert_eq!(s.compiles, 1);
        assert_eq!(s.cache_hits, 1);
        assert!(s.compile_ms_total > 0.0);
    }

    #[test]
    fn unknown_model_errors() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let engine = Engine::new(&dir).unwrap();
        assert!(engine.executable("nope", "init").is_err());
        assert!(engine.executable("mnist_mlp", "nope").is_err());
    }
}
