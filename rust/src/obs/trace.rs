//! Request-scoped tracing: trace ids, a bounded span ring, and the
//! thread-local trace context that carries an id across layers.
//!
//! A trace id is minted at ingress — the web server honours an
//! `X-Trace-Id` request header (sanitized) and mints one otherwise; CLI
//! and daemon `ServiceCall`s mint at dispatch. The id rides a thread-local
//! ([`set_current`]/[`current`]) on whichever thread is executing the
//! request: the web worker sets it before routing, `ServiceHandle::call`
//! reads it off the calling thread into the `ServiceCall`, and the
//! platform thread re-establishes it around `dispatch`, so interior
//! layers (admission, placement, serving enqueue/flush) can record spans
//! without threading an argument through every signature.
//!
//! Spans land in a bounded ring ([`Tracer`]) stamped with virtual-clock
//! time plus a wall-clock duration; `get` assembles the per-trace
//! timeline ordered by `(at_ms, seq)`. Background work (e.g. executor
//! rounds) is attached via subject tags: `tag(session, trace)` lets the
//! obs pump turn bus events about that session into spans after the fact.

use std::cell::RefCell;
use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{SystemTime, UNIX_EPOCH};

/// Longest accepted client-supplied trace id.
pub const MAX_TRACE_ID_LEN: usize = 64;

/// Most subjects (sessions) that can be tagged with a trace at once.
const MAX_TAGS: usize = 1024;

/// One timestamped step of a request's journey.
#[derive(Debug, Clone, PartialEq)]
pub struct Span {
    pub trace: String,
    /// Global record order; ties in `at_ms` sort by `seq`.
    pub seq: u64,
    /// Virtual-clock timestamp (ms) when the spanned work started.
    pub at_ms: u64,
    /// Wall-clock duration of the spanned work (0 for point events).
    pub dur_ms: f64,
    /// What happened, e.g. `dispatch.run` or `serving.flush`.
    pub name: String,
    /// Layer that recorded it: `web`, `service`, `serving`, `platform`.
    pub source: String,
    /// Free-form context (endpoint, node, decision…).
    pub detail: String,
}

struct RingInner {
    spans: VecDeque<Span>,
    next_seq: u64,
    /// subject (session id) -> trace id, FIFO-evicted.
    tags: HashMap<String, String>,
    tag_order: VecDeque<String>,
}

/// A bounded, shared ring of spans. Cloning shares the ring.
#[derive(Clone)]
pub struct Tracer {
    enabled: bool,
    capacity: usize,
    inner: Arc<Mutex<RingInner>>,
}

impl Tracer {
    pub fn new(enabled: bool, capacity: usize) -> Tracer {
        Tracer {
            enabled,
            capacity: capacity.max(16),
            inner: Arc::new(Mutex::new(RingInner {
                spans: VecDeque::new(),
                next_seq: 0,
                tags: HashMap::new(),
                tag_order: VecDeque::new(),
            })),
        }
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// Record one span. Oldest spans are evicted past `capacity`.
    #[allow(clippy::too_many_arguments)]
    pub fn record(
        &self,
        trace: &str,
        at_ms: u64,
        dur_ms: f64,
        name: &str,
        source: &str,
        detail: &str,
    ) {
        if !self.enabled || trace.is_empty() {
            return;
        }
        let mut inner = self.inner.lock().unwrap();
        let seq = inner.next_seq;
        inner.next_seq += 1;
        inner.spans.push_back(Span {
            trace: trace.to_string(),
            seq,
            at_ms,
            dur_ms,
            name: name.to_string(),
            source: source.to_string(),
            detail: detail.to_string(),
        });
        while inner.spans.len() > self.capacity {
            inner.spans.pop_front();
        }
    }

    /// Assemble the timeline for `trace`, ordered by `(at_ms, seq)`.
    pub fn get(&self, trace: &str) -> Vec<Span> {
        let inner = self.inner.lock().unwrap();
        let mut spans: Vec<Span> =
            inner.spans.iter().filter(|s| s.trace == trace).cloned().collect();
        spans.sort_by(|a, b| (a.at_ms, a.seq).cmp(&(b.at_ms, b.seq)));
        spans
    }

    /// Total spans currently retained (across all traces).
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().spans.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Associate a subject (session id) with a trace so later bus events
    /// about it can be recorded as spans by the obs pump.
    pub fn tag(&self, subject: &str, trace: &str) {
        if !self.enabled {
            return;
        }
        let mut inner = self.inner.lock().unwrap();
        if inner.tags.insert(subject.to_string(), trace.to_string()).is_none() {
            inner.tag_order.push_back(subject.to_string());
            while inner.tag_order.len() > MAX_TAGS {
                if let Some(old) = inner.tag_order.pop_front() {
                    inner.tags.remove(&old);
                }
            }
        }
    }

    /// The trace tagged for `subject`, if any.
    pub fn tag_of(&self, subject: &str) -> Option<String> {
        self.inner.lock().unwrap().tags.get(subject).cloned()
    }
}

static MINT_SEQ: AtomicU64 = AtomicU64::new(0);

/// Mint a fresh 16-hex-digit trace id. Mixes wall time, the pid, and a
/// process-local counter through a 64-bit finalizer so ids are unique
/// across threads and (practically) across processes.
pub fn mint() -> String {
    let n = MINT_SEQ.fetch_add(1, Ordering::Relaxed);
    let t = SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_nanos() as u64)
        .unwrap_or(0);
    let mut h =
        t ^ (std::process::id() as u64).rotate_left(32) ^ n.wrapping_mul(0x9e37_79b9_7f4a_7c15);
    h ^= h >> 33;
    h = h.wrapping_mul(0xff51_afd7_ed55_8ccd);
    h ^= h >> 33;
    format!("{:016x}", h)
}

/// Accept a client-supplied trace id if it is 1..=64 chars of
/// `[A-Za-z0-9_-]`; anything else is rejected (caller mints instead).
pub fn sanitize(id: &str) -> Option<String> {
    let id = id.trim();
    if id.is_empty() || id.len() > MAX_TRACE_ID_LEN {
        return None;
    }
    if id.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-') {
        Some(id.to_string())
    } else {
        None
    }
}

thread_local! {
    static CURRENT_TRACE: RefCell<Option<String>> = RefCell::new(None);
}

/// Set (or clear) the current thread's trace context.
pub fn set_current(trace: Option<String>) {
    CURRENT_TRACE.with(|c| *c.borrow_mut() = trace);
}

/// The current thread's trace context, if any.
pub fn current() -> Option<String> {
    CURRENT_TRACE.with(|c| c.borrow().clone())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mint_is_unique_and_hex() {
        let a = mint();
        let b = mint();
        assert_ne!(a, b);
        assert_eq!(a.len(), 16);
        assert!(a.chars().all(|c| c.is_ascii_hexdigit()));
    }

    #[test]
    fn sanitize_filters_garbage() {
        assert_eq!(sanitize("abc-DEF_123"), Some("abc-DEF_123".to_string()));
        assert_eq!(sanitize("  t1  "), Some("t1".to_string()));
        assert_eq!(sanitize(""), None);
        assert_eq!(sanitize("has space"), None);
        assert_eq!(sanitize("semi;colon"), None);
        assert_eq!(sanitize(&"x".repeat(65)), None);
    }

    #[test]
    fn ring_orders_and_evicts() {
        let t = Tracer::new(true, 16);
        t.record("t1", 10, 1.0, "a", "web", "");
        t.record("t2", 5, 0.0, "x", "web", "");
        t.record("t1", 5, 0.5, "b", "service", "n");
        let spans = t.get("t1");
        assert_eq!(spans.len(), 2);
        // Ordered by (at_ms, seq): the later-recorded-but-earlier span first.
        assert_eq!(spans[0].name, "b");
        assert_eq!(spans[1].name, "a");
        for _ in 0..40 {
            t.record("t3", 20, 0.0, "c", "web", "");
        }
        assert_eq!(t.len(), 16);
        assert!(t.get("t1").is_empty(), "old spans evicted");
    }

    #[test]
    fn disabled_tracer_records_nothing() {
        let t = Tracer::new(false, 64);
        t.record("t1", 1, 0.0, "a", "web", "");
        t.tag("s1", "t1");
        assert!(t.is_empty());
        assert_eq!(t.tag_of("s1"), None);
    }

    #[test]
    fn tags_evict_fifo() {
        let t = Tracer::new(true, 64);
        t.tag("sess-1", "t1");
        assert_eq!(t.tag_of("sess-1"), Some("t1".to_string()));
        // Re-tagging overwrites without duplicating the order entry.
        t.tag("sess-1", "t2");
        assert_eq!(t.tag_of("sess-1"), Some("t2".to_string()));
    }

    #[test]
    fn thread_local_context_roundtrip() {
        assert_eq!(current(), None);
        set_current(Some("abc".to_string()));
        assert_eq!(current(), Some("abc".to_string()));
        set_current(None);
        assert_eq!(current(), None);
        // Other threads see their own context.
        set_current(Some("outer".to_string()));
        let inner = std::thread::spawn(|| current()).join().unwrap();
        assert_eq!(inner, None);
        set_current(None);
    }
}
