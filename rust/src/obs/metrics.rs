//! Metrics registry: lock-cheap counters, gauges, and log-bucket histograms.
//!
//! The registry hands out cheap cloneable handles ([`Counter`], [`Gauge`],
//! [`Histogram`]); every record operation is a handful of relaxed atomics, so
//! hot paths (dispatch, WAL append, histogram record) cache a handle once and
//! pay no lock afterwards. Series are keyed by `(name, sorted labels)` in a
//! `BTreeMap` behind a mutex that is only taken on get-or-create and on
//! snapshot/render.
//!
//! Histograms use fixed log2 buckets (`0.001 · 2^i` ms — 1 µs up to ~9 min),
//! which makes recording O(1), snapshots mergeable by bucket-wise addition,
//! and quantile estimates accurate to within one bucket width (a factor of
//! two). Windowed quantiles come from a small ring of cumulative snapshots:
//! [`MetricsRegistry::rotate_windows`] is called once per drive round by the
//! obs pump, and `windowed_quantile` answers over the delta between now and
//! the oldest retained snapshot.
//!
//! A registry built with `enabled = false` hands out inert handles whose
//! record paths are a single branch, so `[obs] enabled = false` reduces the
//! instrumentation to (nearly) zero cost — `bench_obs.rs` gates the delta.

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Number of log2 histogram buckets. Bucket `i` covers
/// `(0.001·2^(i-1), 0.001·2^i]` ms; bucket 0 covers everything `<= 1 µs`.
pub const HIST_BUCKETS: usize = 40;

/// Upper bound (inclusive) of bucket `i`, in milliseconds.
pub fn bucket_bound(i: usize) -> f64 {
    0.001 * (1u64 << i.min(HIST_BUCKETS - 1)) as f64
}

/// The bucket a value lands in: the smallest `i` with `v <= bucket_bound(i)`.
/// Values beyond the last bound are clamped into the last bucket.
pub fn bucket_index(v_ms: f64) -> usize {
    if !(v_ms > 0.001) {
        return 0; // also catches NaN and negatives
    }
    let mut i = ((v_ms / 0.001).log2().ceil()) as i64;
    i = i.clamp(0, (HIST_BUCKETS - 1) as i64);
    // Guard against float rounding at the bucket boundaries: walk to the
    // exact `le` bucket so the invariant `bound(i-1) < v <= bound(i)` holds.
    while i > 0 && v_ms <= bucket_bound((i - 1) as usize) {
        i -= 1;
    }
    while (i as usize) < HIST_BUCKETS - 1 && v_ms > bucket_bound(i as usize) {
        i += 1;
    }
    i as usize
}

/// Sorted `(key, value)` label pairs identifying one series.
pub type Labels = Vec<(String, String)>;

fn labels_of(pairs: &[(&str, &str)]) -> Labels {
    let mut l: Labels =
        pairs.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect();
    l.sort();
    l
}

/// A monotonically increasing counter.
#[derive(Clone)]
pub struct Counter {
    core: Arc<CounterCore>,
}

struct CounterCore {
    enabled: bool,
    value: AtomicU64,
}

impl Counter {
    fn new(enabled: bool) -> Counter {
        Counter { core: Arc::new(CounterCore { enabled, value: AtomicU64::new(0) }) }
    }

    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        if self.core.enabled {
            self.core.value.fetch_add(n, Ordering::Relaxed);
        }
    }

    pub fn get(&self) -> u64 {
        self.core.value.load(Ordering::Relaxed)
    }
}

/// A gauge holding the latest `f64` sample (stored as raw bits).
#[derive(Clone)]
pub struct Gauge {
    core: Arc<GaugeCore>,
}

struct GaugeCore {
    enabled: bool,
    bits: AtomicU64,
}

impl Gauge {
    fn new(enabled: bool) -> Gauge {
        Gauge { core: Arc::new(GaugeCore { enabled, bits: AtomicU64::new(0f64.to_bits()) }) }
    }

    pub fn set(&self, v: f64) {
        if self.core.enabled {
            self.core.bits.store(v.to_bits(), Ordering::Relaxed);
        }
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.core.bits.load(Ordering::Relaxed))
    }
}

/// Point-in-time totals of one histogram: per-bucket counts (not
/// cumulative), total count, and sum in milliseconds. Snapshots merge by
/// bucket-wise addition and subtract to form window deltas.
#[derive(Debug, Clone, PartialEq)]
pub struct HistSnapshot {
    pub buckets: Vec<u64>,
    pub count: u64,
    pub sum_ms: f64,
}

impl HistSnapshot {
    fn zero() -> HistSnapshot {
        HistSnapshot { buckets: vec![0; HIST_BUCKETS], count: 0, sum_ms: 0.0 }
    }

    /// `self - older`, saturating (tolerates snapshots racing a record).
    fn delta(&self, older: &HistSnapshot) -> HistSnapshot {
        HistSnapshot {
            buckets: self
                .buckets
                .iter()
                .zip(older.buckets.iter())
                .map(|(a, b)| a.saturating_sub(*b))
                .collect(),
            count: self.count.saturating_sub(older.count),
            sum_ms: (self.sum_ms - older.sum_ms).max(0.0),
        }
    }

    /// Quantile estimate: upper bound of the bucket holding rank
    /// `ceil(q · count)`. Exact to within one bucket width; 0 when empty.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let rank = ((q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut cum = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            cum += b;
            if cum >= rank {
                return bucket_bound(i);
            }
        }
        bucket_bound(HIST_BUCKETS - 1)
    }
}

/// A fixed log-bucket latency histogram with a window ring for quantiles.
#[derive(Clone)]
pub struct Histogram {
    core: Arc<HistogramCore>,
}

struct HistogramCore {
    enabled: bool,
    buckets: [AtomicU64; HIST_BUCKETS],
    count: AtomicU64,
    sum_us: AtomicU64,
    /// Ring of cumulative snapshots, one per rotation (drive round).
    window: Mutex<VecDeque<HistSnapshot>>,
}

impl Histogram {
    fn new(enabled: bool) -> Histogram {
        Histogram {
            core: Arc::new(HistogramCore {
                enabled,
                buckets: std::array::from_fn(|_| AtomicU64::new(0)),
                count: AtomicU64::new(0),
                sum_us: AtomicU64::new(0),
                window: Mutex::new(VecDeque::new()),
            }),
        }
    }

    /// Record one sample in milliseconds. O(1): three relaxed atomic adds.
    pub fn record(&self, v_ms: f64) {
        if !self.core.enabled {
            return;
        }
        let i = bucket_index(v_ms);
        self.core.buckets[i].fetch_add(1, Ordering::Relaxed);
        self.core.count.fetch_add(1, Ordering::Relaxed);
        let us = if v_ms > 0.0 { (v_ms * 1000.0).round() as u64 } else { 0 };
        self.core.sum_us.fetch_add(us, Ordering::Relaxed);
    }

    /// Cumulative totals since creation.
    pub fn snapshot(&self) -> HistSnapshot {
        HistSnapshot {
            buckets: self.core.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect(),
            count: self.core.count.load(Ordering::Relaxed),
            sum_ms: self.core.sum_us.load(Ordering::Relaxed) as f64 / 1000.0,
        }
    }

    /// All-time quantile (upper bucket bound at the rank).
    pub fn quantile(&self, q: f64) -> f64 {
        self.snapshot().quantile(q)
    }

    /// Push the current totals into the window ring, keeping `window`
    /// snapshots. Called once per drive round by the obs pump.
    pub fn rotate(&self, window: usize) {
        let snap = self.snapshot();
        let mut ring = self.core.window.lock().unwrap();
        ring.push_back(snap);
        while ring.len() > window.max(1) {
            ring.pop_front();
        }
    }

    /// Quantile over the samples recorded since the oldest retained
    /// snapshot (i.e. the last `window` rotations). Falls back to the
    /// all-time quantile before the first rotation.
    pub fn windowed_quantile(&self, q: f64) -> f64 {
        let now = self.snapshot();
        let ring = self.core.window.lock().unwrap();
        match ring.front() {
            Some(oldest) => now.delta(oldest).quantile(q),
            None => now.quantile(q),
        }
    }
}

type SeriesKey = (String, Labels);

struct Inner {
    counters: Mutex<BTreeMap<SeriesKey, Counter>>,
    gauges: Mutex<BTreeMap<SeriesKey, Gauge>>,
    histograms: Mutex<BTreeMap<SeriesKey, Histogram>>,
}

/// The process-wide metrics registry. Cloning shares the underlying series.
#[derive(Clone)]
pub struct MetricsRegistry {
    enabled: bool,
    inner: Arc<Inner>,
}

/// One scalar series in a [`RegistrySnapshot`].
#[derive(Debug, Clone, PartialEq)]
pub struct MetricPointSnap {
    pub name: String,
    pub labels: Labels,
    pub value: f64,
}

/// One histogram series in a [`RegistrySnapshot`], with windowed quantiles.
#[derive(Debug, Clone, PartialEq)]
pub struct HistogramSnap {
    pub name: String,
    pub labels: Labels,
    pub count: u64,
    pub sum_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
}

/// A plain-data view of every registered series.
#[derive(Debug, Clone, PartialEq)]
pub struct RegistrySnapshot {
    pub enabled: bool,
    pub counters: Vec<MetricPointSnap>,
    pub gauges: Vec<MetricPointSnap>,
    pub histograms: Vec<HistogramSnap>,
}

impl MetricsRegistry {
    pub fn new(enabled: bool) -> MetricsRegistry {
        MetricsRegistry {
            enabled,
            inner: Arc::new(Inner {
                counters: Mutex::new(BTreeMap::new()),
                gauges: Mutex::new(BTreeMap::new()),
                histograms: Mutex::new(BTreeMap::new()),
            }),
        }
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// Get or create the counter `name{labels}`.
    pub fn counter(&self, name: &str, labels: &[(&str, &str)]) -> Counter {
        if !self.enabled {
            return Counter::new(false);
        }
        let key = (name.to_string(), labels_of(labels));
        let mut map = self.inner.counters.lock().unwrap();
        map.entry(key).or_insert_with(|| Counter::new(true)).clone()
    }

    /// Get or create the gauge `name{labels}`.
    pub fn gauge(&self, name: &str, labels: &[(&str, &str)]) -> Gauge {
        if !self.enabled {
            return Gauge::new(false);
        }
        let key = (name.to_string(), labels_of(labels));
        let mut map = self.inner.gauges.lock().unwrap();
        map.entry(key).or_insert_with(|| Gauge::new(true)).clone()
    }

    /// Get or create the histogram `name{labels}`.
    pub fn histogram(&self, name: &str, labels: &[(&str, &str)]) -> Histogram {
        if !self.enabled {
            return Histogram::new(false);
        }
        let key = (name.to_string(), labels_of(labels));
        let mut map = self.inner.histograms.lock().unwrap();
        map.entry(key).or_insert_with(|| Histogram::new(true)).clone()
    }

    /// Rotate every histogram's quantile window. One call per drive round.
    pub fn rotate_windows(&self, window: usize) {
        if !self.enabled {
            return;
        }
        let hists: Vec<Histogram> =
            self.inner.histograms.lock().unwrap().values().cloned().collect();
        for h in hists {
            h.rotate(window);
        }
    }

    /// Plain-data snapshot of every series (for the `metrics_report` verb).
    pub fn snapshot(&self) -> RegistrySnapshot {
        let counters = self
            .inner
            .counters
            .lock()
            .unwrap()
            .iter()
            .map(|((name, labels), c)| MetricPointSnap {
                name: name.clone(),
                labels: labels.clone(),
                value: c.get() as f64,
            })
            .collect();
        let gauges = self
            .inner
            .gauges
            .lock()
            .unwrap()
            .iter()
            .map(|((name, labels), g)| MetricPointSnap {
                name: name.clone(),
                labels: labels.clone(),
                value: g.get(),
            })
            .collect();
        let histograms = self
            .inner
            .histograms
            .lock()
            .unwrap()
            .iter()
            .map(|((name, labels), h)| {
                let snap = h.snapshot();
                HistogramSnap {
                    name: name.clone(),
                    labels: labels.clone(),
                    count: snap.count,
                    sum_ms: snap.sum_ms,
                    p50_ms: h.windowed_quantile(0.50),
                    p95_ms: h.windowed_quantile(0.95),
                    p99_ms: h.windowed_quantile(0.99),
                }
            })
            .collect();
        RegistrySnapshot { enabled: self.enabled, counters, gauges, histograms }
    }

    /// Render every series in the Prometheus text exposition format
    /// (version 0.0.4): `# TYPE` lines per family, escaped label values,
    /// and `_bucket`/`_sum`/`_count` series with cumulative `le` buckets.
    pub fn render_prometheus(&self) -> String {
        let mut out = String::new();
        if !self.enabled {
            out.push_str("# nsml observability disabled ([obs] enabled = false)\n");
            return out;
        }

        let counters = self.inner.counters.lock().unwrap().clone();
        let mut last_family = String::new();
        for ((name, labels), c) in &counters {
            type_line(&mut out, &mut last_family, name, "counter");
            series_line(&mut out, name, labels, None, c.get() as f64);
        }

        let gauges = self.inner.gauges.lock().unwrap().clone();
        last_family.clear();
        for ((name, labels), g) in &gauges {
            type_line(&mut out, &mut last_family, name, "gauge");
            series_line(&mut out, name, labels, None, g.get());
        }

        let hists = self.inner.histograms.lock().unwrap().clone();
        last_family.clear();
        for ((name, labels), h) in &hists {
            type_line(&mut out, &mut last_family, name, "histogram");
            let snap = h.snapshot();
            let mut cum = 0u64;
            for (i, b) in snap.buckets.iter().enumerate() {
                cum += b;
                // Elide empty leading/inner buckets except the last real one
                // to keep the payload small; cumulative counts stay correct
                // because `le` buckets are monotone.
                if *b == 0 && i + 1 < HIST_BUCKETS && cum < snap.count {
                    continue;
                }
                let le = format!("{}", bucket_bound(i));
                series_line(&mut out, &format!("{}_bucket", name), labels, Some(&le), cum as f64);
                if cum >= snap.count {
                    break;
                }
            }
            let total = snap.count as f64;
            series_line(&mut out, &format!("{}_bucket", name), labels, Some("+Inf"), total);
            series_line(&mut out, &format!("{}_sum", name), labels, None, snap.sum_ms);
            series_line(&mut out, &format!("{}_count", name), labels, None, snap.count as f64);
        }
        out
    }
}

fn type_line(out: &mut String, last_family: &mut String, name: &str, kind: &str) {
    if name != last_family {
        out.push_str(&format!("# TYPE {} {}\n", name, kind));
        *last_family = name.to_string();
    }
}

fn series_line(out: &mut String, name: &str, labels: &Labels, le: Option<&str>, value: f64) {
    out.push_str(name);
    if !labels.is_empty() || le.is_some() {
        out.push('{');
        let mut first = true;
        for (k, v) in labels {
            if !first {
                out.push(',');
            }
            first = false;
            out.push_str(k);
            out.push_str("=\"");
            escape_label(out, v);
            out.push('"');
        }
        if let Some(le) = le {
            if !first {
                out.push(',');
            }
            out.push_str("le=\"");
            out.push_str(le);
            out.push('"');
        }
        out.push('}');
    }
    out.push(' ');
    if value.is_finite() {
        out.push_str(&format!("{}", value));
    } else {
        out.push_str("NaN");
    }
    out.push('\n');
}

/// Prometheus label-value escaping: backslash, double-quote, newline.
fn escape_label(out: &mut String, v: &str) {
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_bounds_double() {
        assert_eq!(bucket_bound(0), 0.001);
        assert_eq!(bucket_bound(1), 0.002);
        assert_eq!(bucket_bound(10), 1.024);
        for i in 1..HIST_BUCKETS {
            assert_eq!(bucket_bound(i), 2.0 * bucket_bound(i - 1));
        }
    }

    #[test]
    fn bucket_index_le_invariant() {
        for &v in &[0.0, 0.0005, 0.001, 0.0011, 0.5, 1.0, 1.024, 3.7, 1000.0, 1e12] {
            let i = bucket_index(v);
            assert!(v <= bucket_bound(i) || i == HIST_BUCKETS - 1, "v={} i={}", v, i);
            if i > 0 {
                assert!(v > bucket_bound(i - 1), "v={} i={}", v, i);
            }
        }
        assert_eq!(bucket_index(f64::NAN), 0);
        assert_eq!(bucket_index(-3.0), 0);
    }

    #[test]
    fn counter_and_gauge_roundtrip() {
        let reg = MetricsRegistry::new(true);
        let c = reg.counter("nsml_test_total", &[("k", "v")]);
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        // Same key returns the same series.
        assert_eq!(reg.counter("nsml_test_total", &[("k", "v")]).get(), 5);
        let g = reg.gauge("nsml_test_gauge", &[]);
        g.set(2.5);
        assert_eq!(g.get(), 2.5);
    }

    #[test]
    fn disabled_registry_is_inert() {
        let reg = MetricsRegistry::new(false);
        let c = reg.counter("nsml_test_total", &[]);
        c.add(10);
        assert_eq!(c.get(), 0);
        let h = reg.histogram("nsml_test_ms", &[]);
        h.record(5.0);
        assert_eq!(h.snapshot().count, 0);
        let snap = reg.snapshot();
        assert!(!snap.enabled);
        assert!(snap.counters.is_empty());
        assert!(reg.render_prometheus().starts_with('#'));
    }

    #[test]
    fn histogram_windowed_quantile_tracks_recent() {
        let reg = MetricsRegistry::new(true);
        let h = reg.histogram("nsml_test_ms", &[]);
        for _ in 0..100 {
            h.record(1.0);
        }
        h.rotate(4);
        for _ in 0..100 {
            h.record(100.0);
        }
        // All-time p50 straddles both phases; the window only sees the
        // second phase (everything after the oldest retained snapshot).
        let w50 = h.windowed_quantile(0.5);
        assert!(w50 >= 100.0 && w50 <= 200.0, "w50={}", w50);
        assert!(h.quantile(0.25) <= 2.0);
    }

    #[test]
    fn prometheus_rendering_has_families() {
        let reg = MetricsRegistry::new(true);
        reg.counter("nsml_a_total", &[("user", "kim")]).inc();
        reg.gauge("nsml_b", &[]).set(1.0);
        let h = reg.histogram("nsml_c_ms", &[]);
        h.record(0.5);
        h.record(4.0);
        let text = reg.render_prometheus();
        assert!(text.contains("# TYPE nsml_a_total counter"));
        assert!(text.contains("nsml_a_total{user=\"kim\"} 1"));
        assert!(text.contains("# TYPE nsml_b gauge"));
        assert!(text.contains("# TYPE nsml_c_ms histogram"));
        assert!(text.contains("nsml_c_ms_bucket"));
        assert!(text.contains("le=\"+Inf\"} 2"));
        assert!(text.contains("nsml_c_ms_count 2"));
    }
}
