//! Observability: the metrics registry, request tracing, and Prometheus
//! exposition that turn the raw event bus into operable telemetry.
//!
//! Three pillars:
//!
//! * [`metrics`] — counters, gauges, and log-bucket histograms with
//!   windowed p50/p95/p99, populated by the platform's obs pump (a
//!   derived bus consumer rolled forward each drive round) plus direct
//!   instrumentation on paths the bus doesn't time (dispatch, HTTP,
//!   WAL append/fsync).
//! * [`trace`] — request-scoped trace ids minted at ingress and carried
//!   via a thread-local through dispatch, admission, placement, executor
//!   rounds, and serving micro-batch flushes into a bounded span ring.
//! * Exposition — `GET /metrics` (Prometheus text 0.0.4),
//!   `GET /api/v1/metrics` / the `metrics_report` verb (JSON), and
//!   `GET /api/v1/trace/<id>` / the `trace` verb / `nsml trace`.
//!
//! [`Obs`] bundles the two stores with the platform clock; it is cheap to
//! clone and is shared by the facade, the service layer, and the web tier.

pub mod metrics;
pub mod trace;

pub use metrics::{
    bucket_bound, bucket_index, Counter, Gauge, HistSnapshot, Histogram, HistogramSnap, Labels,
    MetricPointSnap, MetricsRegistry, RegistrySnapshot, HIST_BUCKETS,
};
pub use trace::{Span, Tracer};

use crate::util::clock::SharedClock;

/// The shared observability handle: metrics registry + trace ring + clock.
#[derive(Clone)]
pub struct Obs {
    pub metrics: MetricsRegistry,
    pub traces: Tracer,
    clock: SharedClock,
}

impl Obs {
    pub fn new(clock: SharedClock, enabled: bool, trace_capacity: usize) -> Obs {
        Obs {
            metrics: MetricsRegistry::new(enabled),
            traces: Tracer::new(enabled, trace_capacity),
            clock,
        }
    }

    /// A disabled handle for contexts that have no platform (all record
    /// paths become no-ops).
    pub fn disabled() -> Obs {
        Obs::new(crate::util::clock::real_clock(), false, 16)
    }

    pub fn enabled(&self) -> bool {
        self.metrics.enabled()
    }

    /// Current platform time (virtual in tests/benches, wall in live runs).
    pub fn now_ms(&self) -> u64 {
        self.clock.now_ms()
    }

    /// Record a span at the current platform time for the given trace.
    pub fn span(&self, trace: &str, dur_ms: f64, name: &str, source: &str, detail: &str) {
        self.traces.record(trace, self.clock.now_ms(), dur_ms, name, source, detail);
    }

    /// Record a span for the current thread's trace context, if any.
    pub fn span_current(&self, dur_ms: f64, name: &str, source: &str, detail: &str) {
        if let Some(t) = trace::current() {
            self.span(&t, dur_ms, name, source, detail);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::clock::sim_clock;

    #[test]
    fn obs_spans_use_platform_clock() {
        let (clock, sim) = sim_clock();
        let obs = Obs::new(clock, true, 64);
        sim.advance(42);
        obs.span("t1", 1.5, "dispatch.run", "service", "");
        let spans = obs.traces.get("t1");
        assert_eq!(spans.len(), 1);
        assert_eq!(spans[0].at_ms, 42);
        assert_eq!(spans[0].dur_ms, 1.5);
    }

    #[test]
    fn span_current_uses_thread_context() {
        let obs = Obs::new(crate::util::clock::real_clock(), true, 64);
        obs.span_current(0.0, "noop", "service", "");
        assert!(obs.traces.is_empty());
        trace::set_current(Some("ctx".to_string()));
        obs.span_current(0.0, "dispatch.status", "service", "");
        trace::set_current(None);
        assert_eq!(obs.traces.get("ctx").len(), 1);
    }

    #[test]
    fn disabled_handle_is_inert() {
        let obs = Obs::disabled();
        assert!(!obs.enabled());
        obs.span("t", 0.0, "a", "web", "");
        assert!(obs.traces.is_empty());
    }
}
