//! Kaggle-like per-dataset leaderboard (§3.1, §3.4).
//!
//! "Storage containers … store the performance of all models trained with
//! the respectively provided dataset as well as display the results in a
//! leaderboard to make clear which model performed best."

use crate::util::clock::Millis;
use crate::util::table::{fnum, Table};
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

/// One scored session on a dataset's board.
#[derive(Debug, Clone, PartialEq)]
pub struct Submission {
    pub session: String,
    pub user: String,
    pub model: String,
    pub metric_name: String,
    pub value: f64,
    pub step: u64,
    pub at_ms: Millis,
}

#[derive(Debug, Default)]
struct Board {
    metric_name: String,
    lower_is_better: bool,
    /// Best submission per session (resubmits keep the better score).
    entries: BTreeMap<String, Submission>,
}

/// All leaderboards, keyed by dataset.
#[derive(Clone, Default)]
pub struct Leaderboard {
    inner: Arc<Mutex<BTreeMap<String, Board>>>,
}

impl Leaderboard {
    pub fn new() -> Leaderboard {
        Leaderboard::default()
    }

    /// Declare a dataset's board (idempotent).
    pub fn ensure_board(&self, dataset: &str, metric_name: &str, lower_is_better: bool) {
        let mut inner = self.inner.lock().unwrap();
        inner.entry(dataset.to_string()).or_insert_with(|| Board {
            metric_name: metric_name.to_string(),
            lower_is_better,
            entries: BTreeMap::new(),
        });
    }

    /// Record a result. Returns the session's new rank (1-based), or None
    /// if the board does not exist.
    pub fn submit(&self, dataset: &str, sub: Submission) -> Option<usize> {
        let mut inner = self.inner.lock().unwrap();
        let board = inner.get_mut(dataset)?;
        let keep_new = match board.entries.get(&sub.session) {
            None => true,
            Some(old) => {
                if board.lower_is_better {
                    sub.value < old.value
                } else {
                    sub.value > old.value
                }
            }
        };
        if keep_new {
            board.entries.insert(sub.session.clone(), sub.clone());
        }
        drop(inner);
        self.rank_of(dataset, &sub.session)
    }

    fn sorted(board: &Board) -> Vec<Submission> {
        let mut v: Vec<Submission> = board.entries.values().cloned().collect();
        v.sort_by(|a, b| {
            let ord = a.value.partial_cmp(&b.value).unwrap_or(std::cmp::Ordering::Equal);
            let ord = if board.lower_is_better { ord } else { ord.reverse() };
            // Tie-break: earlier submission wins, then session id.
            ord.then(a.at_ms.cmp(&b.at_ms)).then(a.session.cmp(&b.session))
        });
        v
    }

    /// Top-k submissions in rank order.
    pub fn top(&self, dataset: &str, k: usize) -> Vec<Submission> {
        let inner = self.inner.lock().unwrap();
        match inner.get(dataset) {
            Some(board) => Self::sorted(board).into_iter().take(k).collect(),
            None => Vec::new(),
        }
    }

    /// Current best entry.
    pub fn best(&self, dataset: &str) -> Option<Submission> {
        self.top(dataset, 1).into_iter().next()
    }

    /// 1-based rank of a session.
    pub fn rank_of(&self, dataset: &str, session: &str) -> Option<usize> {
        let inner = self.inner.lock().unwrap();
        let board = inner.get(dataset)?;
        Self::sorted(board).iter().position(|s| s.session == session).map(|p| p + 1)
    }

    pub fn datasets(&self) -> Vec<String> {
        self.inner.lock().unwrap().keys().cloned().collect()
    }

    pub fn board_len(&self, dataset: &str) -> usize {
        self.inner.lock().unwrap().get(dataset).map(|b| b.entries.len()).unwrap_or(0)
    }

    /// Render as `nsml dataset board DATASET` does (Fig. 2).
    pub fn render(&self, dataset: &str) -> String {
        let inner = self.inner.lock().unwrap();
        let Some(board) = inner.get(dataset) else {
            return format!("no leaderboard for dataset '{}'\n", dataset);
        };
        let dir = if board.lower_is_better { "↓" } else { "↑" };
        let mut t = Table::new(&["RANK", "SESSION", "USER", "MODEL", &format!("{} {}", board.metric_name.to_uppercase(), dir), "STEP"])
            .right(&[0, 4, 5]);
        for (i, s) in Self::sorted(board).iter().enumerate() {
            t.row(&[
                format!("{}", i + 1),
                s.session.clone(),
                s.user.clone(),
                s.model.clone(),
                fnum(s.value),
                format!("{}", s.step),
            ]);
        }
        format!("== leaderboard: {} ==\n{}", dataset, t.render())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sub(session: &str, value: f64, at: Millis) -> Submission {
        Submission {
            session: session.to_string(),
            user: "kim".to_string(),
            model: "mnist_mlp".to_string(),
            metric_name: "accuracy".to_string(),
            value,
            step: 100,
            at_ms: at,
        }
    }

    #[test]
    fn ranking_higher_is_better() {
        let lb = Leaderboard::new();
        lb.ensure_board("mnist", "accuracy", false);
        assert_eq!(lb.submit("mnist", sub("a", 0.8, 1)), Some(1));
        assert_eq!(lb.submit("mnist", sub("b", 0.9, 2)), Some(1));
        assert_eq!(lb.rank_of("mnist", "a"), Some(2));
        assert_eq!(lb.best("mnist").unwrap().session, "b");
    }

    #[test]
    fn ranking_lower_is_better() {
        let lb = Leaderboard::new();
        lb.ensure_board("movie-reviews", "rmse", true);
        lb.submit("movie-reviews", sub("a", 1.5, 1));
        lb.submit("movie-reviews", sub("b", 0.9, 2));
        assert_eq!(lb.best("movie-reviews").unwrap().session, "b");
    }

    #[test]
    fn resubmit_keeps_best() {
        let lb = Leaderboard::new();
        lb.ensure_board("mnist", "accuracy", false);
        lb.submit("mnist", sub("a", 0.7, 1));
        lb.submit("mnist", sub("a", 0.9, 2));
        lb.submit("mnist", sub("a", 0.8, 3)); // worse: ignored
        assert_eq!(lb.board_len("mnist"), 1);
        assert!((lb.best("mnist").unwrap().value - 0.9).abs() < 1e-12);
    }

    #[test]
    fn resubmit_keeps_best_lower_is_better() {
        // Under lower_is_better the *smaller* score must survive a
        // worse (larger) resubmission — the mirror of the accuracy case.
        let lb = Leaderboard::new();
        lb.ensure_board("movie-reviews", "rmse", true);
        lb.submit("movie-reviews", sub("a", 1.5, 1));
        lb.submit("movie-reviews", sub("a", 0.9, 2)); // better: kept
        lb.submit("movie-reviews", sub("a", 1.2, 3)); // worse: ignored
        assert_eq!(lb.board_len("movie-reviews"), 1);
        let best = lb.best("movie-reviews").unwrap();
        assert!((best.value - 0.9).abs() < 1e-12);
        assert_eq!(best.at_ms, 2, "the kept submission is the better one, not the latest");
    }

    #[test]
    fn tie_ordering_is_deterministic() {
        // Equal value and equal timestamp: session id breaks the tie,
        // and the order must not depend on submission order.
        let lb = Leaderboard::new();
        lb.ensure_board("mnist", "accuracy", false);
        lb.submit("mnist", sub("zeta", 0.9, 5));
        lb.submit("mnist", sub("alpha", 0.9, 5));
        lb.submit("mnist", sub("mid", 0.9, 5));
        let order: Vec<String> = lb.top("mnist", 10).iter().map(|s| s.session.clone()).collect();
        assert_eq!(order, vec!["alpha", "mid", "zeta"]);

        let lb2 = Leaderboard::new();
        lb2.ensure_board("mnist", "accuracy", false);
        lb2.submit("mnist", sub("mid", 0.9, 5));
        lb2.submit("mnist", sub("alpha", 0.9, 5));
        lb2.submit("mnist", sub("zeta", 0.9, 5));
        let order2: Vec<String> = lb2.top("mnist", 10).iter().map(|s| s.session.clone()).collect();
        assert_eq!(order2, order, "tie order is independent of submission order");
        // Ranks reflect the same deterministic order.
        assert_eq!(lb2.rank_of("mnist", "alpha"), Some(1));
        assert_eq!(lb2.rank_of("mnist", "zeta"), Some(3));
    }

    #[test]
    fn tie_break_earlier_submission() {
        let lb = Leaderboard::new();
        lb.ensure_board("mnist", "accuracy", false);
        lb.submit("mnist", sub("late", 0.9, 10));
        lb.submit("mnist", sub("early", 0.9, 5));
        assert_eq!(lb.top("mnist", 2)[0].session, "early");
    }

    #[test]
    fn unknown_board() {
        let lb = Leaderboard::new();
        assert_eq!(lb.submit("nope", sub("a", 1.0, 1)), None);
        assert!(lb.top("nope", 5).is_empty());
        assert!(lb.render("nope").contains("no leaderboard"));
    }

    #[test]
    fn render_contains_ranks() {
        let lb = Leaderboard::new();
        lb.ensure_board("mnist", "accuracy", false);
        lb.submit("mnist", sub("kim/mnist/1", 0.91, 1));
        lb.submit("mnist", sub("kim/mnist/2", 0.85, 2));
        let out = lb.render("mnist");
        assert!(out.contains("RANK"));
        assert!(out.contains("kim/mnist/1"));
        assert!(out.contains("ACCURACY ↑"));
        let lines: Vec<&str> = out.lines().collect();
        // Rank 1 row lists the higher accuracy.
        assert!(lines[3].contains("0.91"));
    }
}
