//! The publish/subscribe core: a bounded, sequence-numbered event ring
//! plus cheap incremental cursors.
//!
//! Every published [`Event`] gets the next sequence number in a single
//! total order. The ring retains the most recent `capacity` events;
//! readers address events *by sequence number*, so a reader that falls
//! more than a full ring behind loses exactly the aged-out span — and
//! learns how much it lost through [`EventBatch::dropped`] /
//! [`Subscription::dropped`] instead of silently skipping.
//!
//! Reads are incremental by construction: [`EventBus::read_since`]
//! clones only the events past the cursor (at most `limit`), never the
//! whole ring. The full-snapshot path survives as
//! [`EventBus::snapshot`] for the legacy `EventLog` shim — and as the
//! clone-on-read baseline that `benches/bench_events.rs` measures the
//! cursor path against.

use super::{Event, EventKind, Level};
use crate::util::clock::SharedClock;
use std::collections::VecDeque;
use std::sync::{Arc, Mutex};

/// Default ring retention (events), matching the old `EventLog` cap.
pub const DEFAULT_CAPACITY: usize = 100_000;

struct Ring {
    buf: VecDeque<Event>,
    /// Sequence number the *next* published event will get.
    next_seq: u64,
    /// Total events evicted from the ring since creation (overflow).
    evicted: u64,
}

impl Ring {
    /// Oldest retained sequence number.
    fn first_seq(&self) -> u64 {
        self.next_seq - self.buf.len() as u64
    }
}

/// A filter over events; empty fields match everything.
#[derive(Debug, Clone, Default)]
pub struct EventFilter {
    /// Exact [`EventKind::name`] match (e.g. "metric").
    pub kind: Option<String>,
    /// Exact subject match (a session id).
    pub subject: Option<String>,
    /// Exact source match (e.g. "scheduler").
    pub source: Option<String>,
    /// Minimum severity.
    pub min_level: Option<Level>,
}

impl EventFilter {
    pub fn with_kind(mut self, kind: &str) -> Self {
        self.kind = Some(kind.to_string());
        self
    }

    pub fn with_subject(mut self, subject: &str) -> Self {
        self.subject = Some(subject.to_string());
        self
    }

    pub fn with_source(mut self, source: &str) -> Self {
        self.source = Some(source.to_string());
        self
    }

    pub fn with_min_level(mut self, level: Level) -> Self {
        self.min_level = Some(level);
        self
    }

    pub fn matches(&self, e: &Event) -> bool {
        self.kind.as_deref().map_or(true, |k| e.kind.name() == k)
            && self.subject.as_deref().map_or(true, |s| e.subject == s)
            && self.source.as_deref().map_or(true, |s| e.source == s)
            && self.min_level.map_or(true, |l| e.level >= l)
    }
}

/// One incremental read's result.
#[derive(Debug, Clone)]
pub struct EventBatch {
    /// Matching events, oldest first.
    pub events: Vec<Event>,
    /// Cursor to pass to the next read (first unseen sequence number).
    pub next: u64,
    /// Events that aged out of the ring before this cursor could read
    /// them (reader lag), 0 when the reader kept up.
    pub dropped: u64,
}

/// The shared event bus. Cloning shares the ring; `echo` is a
/// per-handle debugging aid (events print to stderr as they publish).
#[derive(Clone)]
pub struct EventBus {
    ring: Arc<Mutex<Ring>>,
    clock: SharedClock,
    capacity: usize,
    echo: bool,
}

impl EventBus {
    pub fn new(clock: SharedClock) -> EventBus {
        EventBus {
            ring: Arc::new(Mutex::new(Ring { buf: VecDeque::new(), next_seq: 0, evicted: 0 })),
            clock,
            capacity: DEFAULT_CAPACITY,
            echo: false,
        }
    }

    /// Echo events to stderr as they publish (live `nsml logs -f`
    /// feel). Controlled by `[events] echo` in the platform config —
    /// never sniffed from the environment.
    pub fn with_echo(mut self, echo: bool) -> Self {
        self.echo = echo;
        self
    }

    /// Override the ring retention (events).
    pub fn with_capacity(mut self, capacity: usize) -> Self {
        self.capacity = capacity.max(1);
        self
    }

    /// Publish one event; returns its sequence number.
    pub fn publish(&self, level: Level, source: &str, subject: &str, kind: EventKind) -> u64 {
        let echo_line;
        let seq;
        {
            let mut ring = self.ring.lock().unwrap();
            seq = ring.next_seq;
            let e = Event {
                seq,
                at_ms: self.clock.now_ms(),
                level,
                source: source.to_string(),
                subject: subject.to_string(),
                kind,
            };
            // Render inside the lock (cheap), write outside it: a slow
            // stderr consumer must not stall every publisher/reader.
            echo_line = self.echo.then(|| e.render());
            ring.next_seq = seq + 1;
            if ring.buf.len() >= self.capacity {
                ring.buf.pop_front();
                ring.evicted += 1;
            }
            ring.buf.push_back(e);
        }
        if let Some(line) = echo_line {
            eprintln!("{}", line);
        }
        seq
    }

    /// The cursor a brand-new reader should start from (sequence number
    /// of the next event to be published).
    pub fn head(&self) -> u64 {
        self.ring.lock().unwrap().next_seq
    }

    /// Oldest sequence number still retained.
    pub fn first(&self) -> u64 {
        self.ring.lock().unwrap().first_seq()
    }

    /// Retained event count.
    pub fn len(&self) -> usize {
        self.ring.lock().unwrap().buf.len()
    }

    /// Total events that have aged out of the ring since creation —
    /// the ring-overflow count surfaced by the obs registry and the
    /// `events_since` response.
    pub fn overflow(&self) -> u64 {
        self.ring.lock().unwrap().evicted
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Incremental read: up to `limit` events matching `filter` with
    /// `seq >= cursor` (0 = unlimited), plus the cursor to resume from
    /// and how many events aged out unread. Cost is proportional to the
    /// events scanned past the cursor — never a full-ring clone.
    pub fn read_since(&self, cursor: u64, limit: usize, filter: &EventFilter) -> EventBatch {
        let limit = if limit == 0 { usize::MAX } else { limit };
        let ring = self.ring.lock().unwrap();
        let first = ring.first_seq();
        let dropped = first.saturating_sub(cursor);
        let start = cursor.max(first);
        let mut events = Vec::new();
        let mut next = start;
        for e in ring.buf.iter().skip((start - first) as usize) {
            next = e.seq + 1;
            if filter.matches(e) {
                events.push(e.clone());
                if events.len() >= limit {
                    return EventBatch { events, next, dropped };
                }
            }
        }
        EventBatch { events, next: ring.next_seq.max(next), dropped }
    }

    /// A cursor positioned at the current head: `poll` yields only
    /// events published after this call.
    pub fn subscribe(&self) -> Subscription {
        Subscription {
            cursor: self.head(),
            bus: self.clone(),
            filter: EventFilter::default(),
            dropped: 0,
        }
    }

    /// A cursor over the full retained history, then live events.
    pub fn subscribe_from_start(&self) -> Subscription {
        Subscription { cursor: 0, bus: self.clone(), filter: EventFilter::default(), dropped: 0 }
    }

    /// A cursor starting at an explicit sequence number — the SSE
    /// `Last-Event-ID` resume path (pass `last_seen + 1`): retained
    /// events from the cursor replay first, then live events follow.
    pub fn subscribe_from(&self, cursor: u64) -> Subscription {
        Subscription { cursor, bus: self.clone(), filter: EventFilter::default(), dropped: 0 }
    }

    /// Full clone of the retained ring (legacy `EventLog::all` path;
    /// prefer a [`Subscription`] for anything called repeatedly).
    pub fn snapshot(&self) -> Vec<Event> {
        self.ring.lock().unwrap().buf.iter().cloned().collect()
    }
}

/// A stateful incremental reader: remembers its cursor, accumulates a
/// dropped-events counter when it falls a full ring behind, and
/// optionally filters. Polling is cheap — only events published since
/// the last poll are cloned out.
pub struct Subscription {
    bus: EventBus,
    filter: EventFilter,
    cursor: u64,
    dropped: u64,
}

impl Subscription {
    /// Restrict this subscription to events matching `filter`.
    pub fn with_filter(mut self, filter: EventFilter) -> Self {
        self.filter = filter;
        self
    }

    /// All matching events published since the last poll.
    pub fn poll(&mut self) -> Vec<Event> {
        self.poll_max(0)
    }

    /// Like [`poll`](Subscription::poll) but at most `limit` events
    /// (0 = unlimited); call again to continue.
    pub fn poll_max(&mut self, limit: usize) -> Vec<Event> {
        let batch = self.bus.read_since(self.cursor, limit, &self.filter);
        self.cursor = batch.next;
        self.dropped += batch.dropped;
        batch.events
    }

    /// First unseen sequence number.
    pub fn cursor(&self) -> u64 {
        self.cursor
    }

    /// Total events this subscriber lost to ring overflow.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::clock::sim_clock;

    fn bus() -> EventBus {
        let (clock, _) = sim_clock();
        EventBus::new(clock)
    }

    fn log(bus: &EventBus, source: &str, subject: &str, msg: &str) -> u64 {
        bus.publish(Level::Info, source, subject, EventKind::LogLine { message: msg.into() })
    }

    #[test]
    fn sequence_numbers_are_total_order() {
        let b = bus();
        assert_eq!(b.head(), 0);
        log(&b, "a", "", "one");
        log(&b, "b", "", "two");
        let all = b.snapshot();
        assert_eq!(all.len(), 2);
        assert_eq!(all[0].seq, 0);
        assert_eq!(all[1].seq, 1);
        assert_eq!(b.head(), 2);
        assert_eq!(b.first(), 0);
    }

    #[test]
    fn subscription_reads_incrementally() {
        let b = bus();
        log(&b, "x", "", "before");
        let mut sub = b.subscribe();
        assert!(sub.poll().is_empty(), "subscribe starts at head");
        log(&b, "x", "", "after-1");
        log(&b, "x", "", "after-2");
        let got = sub.poll();
        assert_eq!(got.len(), 2);
        assert_eq!(got[0].message(), "after-1");
        assert!(sub.poll().is_empty(), "poll drains");
        // From-start subscriptions replay history first.
        let mut replay = b.subscribe_from_start();
        assert_eq!(replay.poll().len(), 3);
    }

    #[test]
    fn lag_is_counted_not_silently_skipped() {
        let (clock, _) = sim_clock();
        let b = EventBus::new(clock).with_capacity(10);
        let mut sub = b.subscribe();
        for i in 0..25 {
            log(&b, "x", "", &format!("{}", i));
        }
        // 25 published, 10 retained: the subscriber lost 15.
        let got = sub.poll();
        assert_eq!(got.len(), 10);
        assert_eq!(got[0].message(), "15");
        assert_eq!(sub.dropped(), 15);
        // Once caught up, no further drops accrue.
        log(&b, "x", "", "fresh");
        assert_eq!(sub.poll().len(), 1);
        assert_eq!(sub.dropped(), 15);
        // The ring itself counts every eviction: 26 published, 10 kept.
        assert_eq!(b.overflow(), 16);
    }

    #[test]
    fn filters_match_kind_subject_source_level() {
        let b = bus();
        log(&b, "scheduler", "job-1", "queued");
        b.publish(
            Level::Debug,
            "session",
            "job-1",
            EventKind::MetricReported { name: "loss".into(), step: 1, value: 0.5 },
        );
        b.publish(Level::Error, "cluster", "node-2", EventKind::LogLine { message: "dead".into() });

        let by_kind = b.read_since(0, 0, &EventFilter::default().with_kind("metric"));
        assert_eq!(by_kind.events.len(), 1);
        let by_subject = b.read_since(0, 0, &EventFilter::default().with_subject("job-1"));
        assert_eq!(by_subject.events.len(), 2);
        let by_source = b.read_since(0, 0, &EventFilter::default().with_source("cluster"));
        assert_eq!(by_source.events.len(), 1);
        let by_level = b.read_since(0, 0, &EventFilter::default().with_min_level(Level::Warn));
        assert_eq!(by_level.events.len(), 1);
        // A filtered read still advances past non-matching events.
        assert_eq!(by_kind.next, b.head());
    }

    #[test]
    fn limited_reads_page_through() {
        let b = bus();
        for i in 0..7 {
            log(&b, "x", "", &format!("{}", i));
        }
        let filter = EventFilter::default();
        let first = b.read_since(0, 3, &filter);
        assert_eq!(first.events.len(), 3);
        assert_eq!(first.next, 3);
        let second = b.read_since(first.next, 3, &filter);
        assert_eq!(second.events.len(), 3);
        let last = b.read_since(second.next, 3, &filter);
        assert_eq!(last.events.len(), 1);
        assert_eq!(last.next, b.head());
        // Reading at the head returns nothing and stays put.
        let empty = b.read_since(b.head(), 3, &filter);
        assert!(empty.events.is_empty());
        assert_eq!(empty.next, b.head());
    }

    #[test]
    fn cross_thread_publish_and_poll() {
        let b = bus();
        let mut sub = b.subscribe();
        let publisher = {
            let b = b.clone();
            std::thread::spawn(move || {
                for i in 0..100 {
                    log(&b, "worker", "s", &format!("{}", i));
                }
            })
        };
        publisher.join().unwrap();
        let got = sub.poll();
        assert_eq!(got.len(), 100);
        // Order is the publish order.
        assert!(got.windows(2).all(|w| w[0].seq + 1 == w[1].seq));
    }
}
