//! Compatibility shim: the old string-based `EventLog` API, now a thin
//! wrapper over the typed [`EventBus`].
//!
//! `emit`/`info`/`warn`/`error`/`debug` publish
//! [`EventKind::LogLine`] events; the read methods (`all`,
//! `for_subject`, `query`) are snapshot-style and kept only so existing
//! call sites migrate incrementally — new consumers should hold a
//! [`Subscription`](super::Subscription) (incremental, lag-aware)
//! against [`EventLog::bus`] instead.

use super::{Event, EventBus, EventFilter, EventKind, Level};
use crate::util::clock::SharedClock;

/// String-emit facade over the platform event bus.
#[derive(Clone)]
pub struct EventLog {
    bus: EventBus,
}

impl EventLog {
    pub fn new(clock: SharedClock) -> EventLog {
        EventLog { bus: EventBus::new(clock) }
    }

    /// Wrap an existing bus (share one spine between facades).
    pub fn with_bus(bus: EventBus) -> EventLog {
        EventLog { bus }
    }

    /// Echo events to stderr as they arrive (live `nsml logs -f` feel).
    /// Explicit only: set from `[events] echo` config or test code,
    /// never sniffed from the environment.
    pub fn with_echo(mut self, echo: bool) -> Self {
        self.bus = self.bus.with_echo(echo);
        self
    }

    /// Override the bus ring retention (events).
    pub fn with_capacity(mut self, capacity: usize) -> Self {
        self.bus = self.bus.with_capacity(capacity);
        self
    }

    /// The typed bus underneath — publish typed events and open
    /// subscriptions through this.
    pub fn bus(&self) -> &EventBus {
        &self.bus
    }

    pub fn emit(&self, level: Level, source: &str, subject: &str, message: impl Into<String>) {
        self.bus.publish(level, source, subject, EventKind::LogLine { message: message.into() });
    }

    pub fn info(&self, source: &str, subject: &str, msg: impl Into<String>) {
        self.emit(Level::Info, source, subject, msg);
    }

    pub fn warn(&self, source: &str, subject: &str, msg: impl Into<String>) {
        self.emit(Level::Warn, source, subject, msg);
    }

    pub fn error(&self, source: &str, subject: &str, msg: impl Into<String>) {
        self.emit(Level::Error, source, subject, msg);
    }

    pub fn debug(&self, source: &str, subject: &str, msg: impl Into<String>) {
        self.emit(Level::Debug, source, subject, msg);
    }

    /// All retained events (cloned snapshot — the slow path the bench
    /// gates subscriptions against; avoid in loops).
    pub fn all(&self) -> Vec<Event> {
        self.bus.snapshot()
    }

    /// Retained events whose subject matches exactly.
    pub fn for_subject(&self, subject: &str) -> Vec<Event> {
        self.bus.read_since(0, 0, &EventFilter::default().with_subject(subject)).events
    }

    /// Retained events from a given source at or above a level.
    pub fn query(&self, source: Option<&str>, min_level: Level) -> Vec<Event> {
        let filter = EventFilter {
            source: source.map(str::to_string),
            min_level: Some(min_level),
            ..Default::default()
        };
        self.bus.read_since(0, 0, &filter).events
    }

    pub fn len(&self) -> usize {
        self.bus.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::clock::sim_clock;

    #[test]
    fn emit_and_query() {
        let (clock, sim) = sim_clock();
        let log = EventLog::new(clock).with_echo(false);
        log.info("scheduler", "job-1", "queued");
        sim.advance(10);
        log.warn("cluster", "node-2", "heartbeat late");
        log.error("scheduler", "job-1", "failed");

        assert_eq!(log.len(), 3);
        assert_eq!(log.for_subject("job-1").len(), 2);
        let warns = log.query(None, Level::Warn);
        assert_eq!(warns.len(), 2);
        assert_eq!(log.query(Some("cluster"), Level::Debug).len(), 1);
        assert_eq!(warns[0].at_ms, 10);
    }

    #[test]
    fn render_matches_legacy_format() {
        let (clock, _) = sim_clock();
        let log = EventLog::new(clock).with_echo(false);
        log.info("session", "kim/mnist/1", "started");
        let e = &log.all()[0];
        let s = e.render();
        assert!(s.contains("INFO"));
        assert!(s.contains("kim/mnist/1"));
        assert!(s.contains("started"));
    }

    #[test]
    fn bounded_capacity() {
        let (clock, _) = sim_clock();
        let log = EventLog::new(clock).with_echo(false).with_capacity(10);
        for i in 0..25 {
            log.info("x", "", format!("{}", i));
        }
        assert_eq!(log.len(), 10);
        assert_eq!(log.all()[0].message(), "15");
    }

    #[test]
    fn string_emits_are_typed_log_lines_on_the_bus() {
        let (clock, _) = sim_clock();
        let log = EventLog::new(clock).with_echo(false);
        let mut sub = log.bus().subscribe();
        log.info("platform", "s-1", "stopped by user");
        let got = sub.poll();
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].kind, EventKind::LogLine { message: "stopped by user".into() });
        assert_eq!(got[0].kind.name(), "log");
    }
}
