//! Platform event log: structured, timestamped events from every subsystem.
//!
//! NSML surfaces "what happened to my job" through logs and the web UI;
//! this module is the shared spine: subsystems emit [`Event`]s into an
//! [`EventLog`], the CLI (`nsml logs`) and web UI read them back.

use crate::util::clock::{Millis, SharedClock};
use std::collections::VecDeque;
use std::sync::{Arc, Mutex};

/// Event severity.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Debug,
    Info,
    Warn,
    Error,
}

impl Level {
    pub fn as_str(&self) -> &'static str {
        match self {
            Level::Debug => "DEBUG",
            Level::Info => "INFO",
            Level::Warn => "WARN",
            Level::Error => "ERROR",
        }
    }
}

/// A structured platform event.
#[derive(Debug, Clone)]
pub struct Event {
    pub at_ms: Millis,
    pub level: Level,
    /// Emitting subsystem, e.g. "scheduler", "session".
    pub source: String,
    /// Correlation key, e.g. a session or job id ("" if none).
    pub subject: String,
    pub message: String,
}

impl Event {
    pub fn render(&self) -> String {
        if self.subject.is_empty() {
            format!("[{:>8}ms {:<5} {}] {}", self.at_ms, self.level.as_str(), self.source, self.message)
        } else {
            format!(
                "[{:>8}ms {:<5} {}] ({}) {}",
                self.at_ms,
                self.level.as_str(),
                self.source,
                self.subject,
                self.message
            )
        }
    }
}

/// Bounded in-memory event log, shareable across threads.
#[derive(Clone)]
pub struct EventLog {
    inner: Arc<Mutex<VecDeque<Event>>>,
    clock: SharedClock,
    capacity: usize,
    echo: bool,
}

impl EventLog {
    pub fn new(clock: SharedClock) -> EventLog {
        EventLog {
            inner: Arc::new(Mutex::new(VecDeque::new())),
            clock,
            capacity: 100_000,
            echo: std::env::var("NSML_LOG").is_ok(),
        }
    }

    /// Echo events to stderr as they arrive (live `nsml logs -f` feel).
    pub fn with_echo(mut self, echo: bool) -> Self {
        self.echo = echo;
        self
    }

    pub fn emit(&self, level: Level, source: &str, subject: &str, message: impl Into<String>) {
        let e = Event {
            at_ms: self.clock.now_ms(),
            level,
            source: source.to_string(),
            subject: subject.to_string(),
            message: message.into(),
        };
        if self.echo {
            eprintln!("{}", e.render());
        }
        let mut q = self.inner.lock().unwrap();
        if q.len() >= self.capacity {
            q.pop_front();
        }
        q.push_back(e);
    }

    pub fn info(&self, source: &str, subject: &str, msg: impl Into<String>) {
        self.emit(Level::Info, source, subject, msg);
    }

    pub fn warn(&self, source: &str, subject: &str, msg: impl Into<String>) {
        self.emit(Level::Warn, source, subject, msg);
    }

    pub fn error(&self, source: &str, subject: &str, msg: impl Into<String>) {
        self.emit(Level::Error, source, subject, msg);
    }

    pub fn debug(&self, source: &str, subject: &str, msg: impl Into<String>) {
        self.emit(Level::Debug, source, subject, msg);
    }

    /// All events (cloned snapshot).
    pub fn all(&self) -> Vec<Event> {
        self.inner.lock().unwrap().iter().cloned().collect()
    }

    /// Events whose subject matches exactly.
    pub fn for_subject(&self, subject: &str) -> Vec<Event> {
        self.inner.lock().unwrap().iter().filter(|e| e.subject == subject).cloned().collect()
    }

    /// Events from a given source at or above a level.
    pub fn query(&self, source: Option<&str>, min_level: Level) -> Vec<Event> {
        self.inner
            .lock()
            .unwrap()
            .iter()
            .filter(|e| e.level >= min_level && source.map_or(true, |s| e.source == s))
            .cloned()
            .collect()
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::clock::sim_clock;

    #[test]
    fn emit_and_query() {
        let (clock, sim) = sim_clock();
        let log = EventLog::new(clock).with_echo(false);
        log.info("scheduler", "job-1", "queued");
        sim.advance(10);
        log.warn("cluster", "node-2", "heartbeat late");
        log.error("scheduler", "job-1", "failed");

        assert_eq!(log.len(), 3);
        assert_eq!(log.for_subject("job-1").len(), 2);
        let warns = log.query(None, Level::Warn);
        assert_eq!(warns.len(), 2);
        assert_eq!(log.query(Some("cluster"), Level::Debug).len(), 1);
        assert_eq!(warns[0].at_ms, 10);
    }

    #[test]
    fn render_format() {
        let (clock, _) = sim_clock();
        let log = EventLog::new(clock).with_echo(false);
        log.info("session", "kim/mnist/1", "started");
        let e = &log.all()[0];
        let s = e.render();
        assert!(s.contains("INFO"));
        assert!(s.contains("kim/mnist/1"));
        assert!(s.contains("started"));
    }

    #[test]
    fn bounded_capacity() {
        let (clock, _) = sim_clock();
        let mut log = EventLog::new(clock).with_echo(false);
        log.capacity = 10;
        for i in 0..25 {
            log.info("x", "", format!("{}", i));
        }
        assert_eq!(log.len(), 10);
        assert_eq!(log.all()[0].message, "15");
    }
}
