//! The platform event spine: a typed publish/subscribe bus.
//!
//! NSML's promise is that researchers see "what happened to my job"
//! without manual bookkeeping (§3.1–§3.4). Every subsystem publishes
//! structured [`Event`]s — an [`EventKind`] payload plus level, source
//! and subject — into a bounded, sequence-numbered [`EventBus`] ring.
//! Consumers read *incrementally* through [`Subscription`] cursors (or
//! raw [`EventBus::read_since`] calls): a reader only ever clones the
//! events published since its cursor, and falling behind a full ring is
//! surfaced as a per-subscriber dropped-events counter, never a
//! full-deque clone.
//!
//! Producers: the scheduler publishes [`EventKind::PlacementDecided`],
//! the executor [`EventKind::WorkerStolen`], sessions
//! [`EventKind::StateChanged`] / [`EventKind::MetricReported`] /
//! [`EventKind::CheckpointSaved`], the platform drive loop
//! [`EventKind::UtilizationSampled`] / [`EventKind::WorkerSampled`],
//! and the tenancy layer [`EventKind::AdmissionDecided`].
//! Consumers: the leaderboard, `UtilizationMonitor` and the per-user
//! GPU-second accountant are *derived* from bus subscriptions (see
//! `api::NsmlPlatform`), `nsml logs -f` follows a polling
//! subscription, and `GET /api/v1/events` pages a cursor over the
//! wire (`events_since` verb).
//!
//! [`EventLog`] survives as a thin compatibility shim over the bus
//! (string emit + snapshot reads) so call sites migrate incrementally.

mod bus;
mod log;

pub use bus::{EventBatch, EventBus, EventFilter, Subscription, DEFAULT_CAPACITY};
pub use log::EventLog;

use crate::util::clock::Millis;
use crate::util::json::Json;

/// Event severity.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Debug,
    Info,
    Warn,
    Error,
}

impl Level {
    pub fn as_str(&self) -> &'static str {
        match self {
            Level::Debug => "DEBUG",
            Level::Info => "INFO",
            Level::Warn => "WARN",
            Level::Error => "ERROR",
        }
    }

    /// Inverse of [`Level::as_str`] (wire-format deserialization).
    #[allow(clippy::should_implement_trait)]
    pub fn from_str(s: &str) -> Option<Level> {
        match s {
            "DEBUG" => Some(Level::Debug),
            "INFO" => Some(Level::Info),
            "WARN" => Some(Level::Warn),
            "ERROR" => Some(Level::Error),
            _ => None,
        }
    }
}

/// Every kind name, in the order of the [`EventKind`] variants (wire
/// filter validation and docs).
pub const ALL_EVENT_KINDS: &[&str] = &[
    "log",
    "metric",
    "state",
    "checkpoint",
    "placement",
    "steal",
    "util",
    "worker",
    "admission",
    "loop",
    "endpoint",
    "infer",
    "replica",
];

/// The typed payload of an [`Event`]. Plain data only — the events
/// module sits below every other subsystem, so states, nodes and
/// workers travel as strings/integers, not domain types.
#[derive(Debug, Clone, PartialEq)]
pub enum EventKind {
    /// Free-form message (the legacy `EventLog::emit` path).
    LogLine { message: String },
    /// A session reported a metric value (eval loss, task metric).
    MetricReported { name: String, step: u64, value: f64 },
    /// A session changed lifecycle state. `to` is always a
    /// `SessionState::as_str` name; `from` is too, except `"new"` on
    /// the initial submission transition (record creation → queued).
    StateChanged { from: String, to: String, step: u64 },
    /// A session persisted a checkpoint (`object` = params address).
    CheckpointSaved { step: u64, object: String },
    /// The scheduler placed a job on a node.
    PlacementDecided { node: u32, from_queue: bool },
    /// An idle executor worker stole a pending session from a peer.
    WorkerStolen { thief: usize, victim: usize },
    /// One drive round's cluster-level utilization sample.
    UtilizationSampled {
        utilization: f64,
        free_gpus: usize,
        alive_nodes: usize,
        queue_depth: usize,
    },
    /// One drive round's snapshot of a single executor worker.
    WorkerSampled {
        worker: usize,
        busy_ms: f64,
        live_sessions: usize,
        queue_depth: usize,
        steals: u64,
    },
    /// A fair-share admission decision for a pending submission
    /// (subject = session id). `decision` is one of `admit`,
    /// `readmit` (a preempted session re-entering), `defer` (held
    /// back by quota or capacity; published once per submission), or
    /// `preempt` (a running session evicted for a waiting user).
    AdmissionDecided { decision: String, user: String },
    /// One daemon drive-loop round (`nsml serve`): round counter,
    /// wall-clock round duration and sustained loop throughput.
    LoopSampled { round: u64, round_ms: f64, progressed: u64, rounds_per_sec: f64 },
    /// A serving-endpoint lifecycle mutation (subject = endpoint name).
    /// `action` is one of `promote`, `rollback`, `rollforward` or
    /// `retire`; the remaining fields describe the checkpoint version
    /// involved so recovery can replay the registry from the WAL.
    EndpointChanged {
        action: String,
        version: u64,
        session: String,
        model: String,
        step: u64,
        object: String,
    },
    /// One micro-batched serving execution (subject = endpoint name):
    /// how many queued requests were packed into the single engine
    /// call and the wall-clock latency of that call.
    InferServed { batch: u64, latency_ms: f64 },
    /// The autoscaler resized an endpoint's replica set (subject =
    /// endpoint name): the new replica count and the queue depth that
    /// triggered the decision (0 on idle scale-downs).
    ReplicaScaled { replicas: u64, queue_depth: u64 },
}

impl EventKind {
    /// Stable kind name (wire filters, `ALL_EVENT_KINDS`).
    pub fn name(&self) -> &'static str {
        match self {
            EventKind::LogLine { .. } => "log",
            EventKind::MetricReported { .. } => "metric",
            EventKind::StateChanged { .. } => "state",
            EventKind::CheckpointSaved { .. } => "checkpoint",
            EventKind::PlacementDecided { .. } => "placement",
            EventKind::WorkerStolen { .. } => "steal",
            EventKind::UtilizationSampled { .. } => "util",
            EventKind::WorkerSampled { .. } => "worker",
            EventKind::AdmissionDecided { .. } => "admission",
            EventKind::LoopSampled { .. } => "loop",
            EventKind::EndpointChanged { .. } => "endpoint",
            EventKind::InferServed { .. } => "infer",
            EventKind::ReplicaScaled { .. } => "replica",
        }
    }

    /// Human-readable rendering (the `nsml logs` line body).
    pub fn message(&self) -> String {
        match self {
            EventKind::LogLine { message } => message.clone(),
            EventKind::MetricReported { name, step, value } => {
                format!("metric {} = {} at step {}", name, value, step)
            }
            EventKind::StateChanged { from, to, step } => {
                format!("state {} -> {} at step {}", from, to, step)
            }
            EventKind::CheckpointSaved { step, object } => {
                format!("checkpoint at step {} ({})", step, object)
            }
            EventKind::PlacementDecided { node, from_queue } => {
                if *from_queue {
                    format!("placed on node-{} from queue", node)
                } else {
                    format!("fast-path placed on node-{}", node)
                }
            }
            EventKind::WorkerStolen { thief, victim } => {
                format!("stolen by worker {} from worker {}", thief, victim)
            }
            EventKind::UtilizationSampled { utilization, free_gpus, alive_nodes, queue_depth } => {
                format!(
                    "utilization {:.2}, {} free GPUs, {} alive nodes, queue {}",
                    utilization, free_gpus, alive_nodes, queue_depth
                )
            }
            EventKind::WorkerSampled { worker, busy_ms, live_sessions, queue_depth, steals } => {
                format!(
                    "worker {}: busy {:.1}ms, {} live, {} queued, {} steals",
                    worker, busy_ms, live_sessions, queue_depth, steals
                )
            }
            EventKind::AdmissionDecided { decision, user } => {
                format!("admission {} (user {})", decision, user)
            }
            EventKind::LoopSampled { round, round_ms, progressed, rounds_per_sec } => {
                format!(
                    "loop round {}: {:.1}ms, {} progressed, {:.1} rounds/s",
                    round, round_ms, progressed, rounds_per_sec
                )
            }
            EventKind::EndpointChanged { action, version, session, model, step, object } => {
                format!(
                    "endpoint {} v{} ({} {} step {}, {})",
                    action, version, session, model, step, object
                )
            }
            EventKind::InferServed { batch, latency_ms } => {
                format!("served batch of {} in {:.2}ms", batch, latency_ms)
            }
            EventKind::ReplicaScaled { replicas, queue_depth } => {
                format!("scaled to {} replicas (queue depth {})", replicas, queue_depth)
            }
        }
    }

    /// Payload fields as a JSON object (kind name travels separately).
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        match self {
            EventKind::LogLine { message } => {
                o.set("message", message.as_str().into());
            }
            EventKind::MetricReported { name, step, value } => {
                o.set("name", name.as_str().into())
                    .set("step", (*step).into())
                    .set("value", (*value).into());
            }
            EventKind::StateChanged { from, to, step } => {
                o.set("from", from.as_str().into())
                    .set("to", to.as_str().into())
                    .set("step", (*step).into());
            }
            EventKind::CheckpointSaved { step, object } => {
                o.set("step", (*step).into()).set("object", object.as_str().into());
            }
            EventKind::PlacementDecided { node, from_queue } => {
                o.set("node", (*node).into()).set("from_queue", (*from_queue).into());
            }
            EventKind::WorkerStolen { thief, victim } => {
                o.set("thief", (*thief).into()).set("victim", (*victim).into());
            }
            EventKind::UtilizationSampled { utilization, free_gpus, alive_nodes, queue_depth } => {
                o.set("utilization", (*utilization).into())
                    .set("free_gpus", (*free_gpus).into())
                    .set("alive_nodes", (*alive_nodes).into())
                    .set("queue_depth", (*queue_depth).into());
            }
            EventKind::WorkerSampled { worker, busy_ms, live_sessions, queue_depth, steals } => {
                o.set("worker", (*worker).into())
                    .set("busy_ms", (*busy_ms).into())
                    .set("live_sessions", (*live_sessions).into())
                    .set("queue_depth", (*queue_depth).into())
                    .set("steals", (*steals).into());
            }
            EventKind::AdmissionDecided { decision, user } => {
                o.set("decision", decision.as_str().into()).set("user", user.as_str().into());
            }
            EventKind::LoopSampled { round, round_ms, progressed, rounds_per_sec } => {
                o.set("round", (*round).into())
                    .set("round_ms", (*round_ms).into())
                    .set("progressed", (*progressed).into())
                    .set("rounds_per_sec", (*rounds_per_sec).into());
            }
            EventKind::EndpointChanged { action, version, session, model, step, object } => {
                o.set("action", action.as_str().into())
                    .set("version", (*version).into())
                    .set("session", session.as_str().into())
                    .set("model", model.as_str().into())
                    .set("step", (*step).into())
                    .set("object", object.as_str().into());
            }
            EventKind::InferServed { batch, latency_ms } => {
                o.set("batch", (*batch).into()).set("latency_ms", (*latency_ms).into());
            }
            EventKind::ReplicaScaled { replicas, queue_depth } => {
                o.set("replicas", (*replicas).into()).set("queue_depth", (*queue_depth).into());
            }
        }
        o
    }

    /// Rebuild a payload from its kind name + field object.
    pub fn from_json(name: &str, data: &Json) -> Result<EventKind, String> {
        let str_of = |k: &str| {
            data.get(k)
                .and_then(Json::as_str)
                .map(str::to_string)
                .ok_or_else(|| format!("event '{}' payload missing string '{}'", name, k))
        };
        let u64_of = |k: &str| {
            data.get(k)
                .and_then(Json::as_f64)
                .filter(|f| *f >= 0.0 && f.fract() == 0.0)
                .map(|f| f as u64)
                .ok_or_else(|| format!("event '{}' payload missing integer '{}'", name, k))
        };
        let f64_of = |k: &str| {
            data.get(k)
                .and_then(Json::as_f64)
                .ok_or_else(|| format!("event '{}' payload missing number '{}'", name, k))
        };
        let bool_of = |k: &str| {
            data.get(k)
                .and_then(Json::as_bool)
                .ok_or_else(|| format!("event '{}' payload missing boolean '{}'", name, k))
        };
        match name {
            "log" => Ok(EventKind::LogLine { message: str_of("message")? }),
            "metric" => Ok(EventKind::MetricReported {
                name: str_of("name")?,
                step: u64_of("step")?,
                value: f64_of("value")?,
            }),
            "state" => Ok(EventKind::StateChanged {
                from: str_of("from")?,
                to: str_of("to")?,
                step: u64_of("step")?,
            }),
            "checkpoint" => Ok(EventKind::CheckpointSaved {
                step: u64_of("step")?,
                object: str_of("object")?,
            }),
            "placement" => {
                let node = u64_of("node")?;
                if node > u32::MAX as u64 {
                    return Err(format!("event 'placement' field 'node' out of range: {}", node));
                }
                Ok(EventKind::PlacementDecided {
                    node: node as u32,
                    from_queue: bool_of("from_queue")?,
                })
            }
            "steal" => Ok(EventKind::WorkerStolen {
                thief: u64_of("thief")? as usize,
                victim: u64_of("victim")? as usize,
            }),
            "util" => Ok(EventKind::UtilizationSampled {
                utilization: f64_of("utilization")?,
                free_gpus: u64_of("free_gpus")? as usize,
                alive_nodes: u64_of("alive_nodes")? as usize,
                queue_depth: u64_of("queue_depth")? as usize,
            }),
            "worker" => Ok(EventKind::WorkerSampled {
                worker: u64_of("worker")? as usize,
                busy_ms: f64_of("busy_ms")?,
                live_sessions: u64_of("live_sessions")? as usize,
                queue_depth: u64_of("queue_depth")? as usize,
                steals: u64_of("steals")?,
            }),
            "admission" => Ok(EventKind::AdmissionDecided {
                decision: str_of("decision")?,
                user: str_of("user")?,
            }),
            "loop" => Ok(EventKind::LoopSampled {
                round: u64_of("round")?,
                round_ms: f64_of("round_ms")?,
                progressed: u64_of("progressed")?,
                rounds_per_sec: f64_of("rounds_per_sec")?,
            }),
            "endpoint" => Ok(EventKind::EndpointChanged {
                action: str_of("action")?,
                version: u64_of("version")?,
                session: str_of("session")?,
                model: str_of("model")?,
                step: u64_of("step")?,
                object: str_of("object")?,
            }),
            "infer" => Ok(EventKind::InferServed {
                batch: u64_of("batch")?,
                latency_ms: f64_of("latency_ms")?,
            }),
            "replica" => Ok(EventKind::ReplicaScaled {
                replicas: u64_of("replicas")?,
                queue_depth: u64_of("queue_depth")?,
            }),
            other => Err(format!(
                "unknown event kind '{}' (expected one of: {})",
                other,
                ALL_EVENT_KINDS.join(", ")
            )),
        }
    }
}

/// A structured platform event, sequence-numbered by the bus.
#[derive(Debug, Clone, PartialEq)]
pub struct Event {
    /// Position in the bus's total order (cursor arithmetic).
    pub seq: u64,
    pub at_ms: Millis,
    pub level: Level,
    /// Emitting subsystem, e.g. "scheduler", "session".
    pub source: String,
    /// Correlation key, e.g. a session or job id ("" if none).
    pub subject: String,
    pub kind: EventKind,
}

impl Event {
    /// Human-readable body (the old `Event.message` field).
    pub fn message(&self) -> String {
        self.kind.message()
    }

    pub fn render(&self) -> String {
        if self.subject.is_empty() {
            format!(
                "[{:>8}ms {:<5} {}] {}",
                self.at_ms,
                self.level.as_str(),
                self.source,
                self.message()
            )
        } else {
            format!(
                "[{:>8}ms {:<5} {}] ({}) {}",
                self.at_ms,
                self.level.as_str(),
                self.source,
                self.subject,
                self.message()
            )
        }
    }

    /// Wire shape: flat envelope + kind-tagged payload. `message` is
    /// included for display-only consumers and ignored on parse.
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("seq", self.seq.into())
            .set("at_ms", self.at_ms.into())
            .set("level", self.level.as_str().into())
            .set("source", self.source.as_str().into())
            .set("subject", self.subject.as_str().into())
            .set("kind", self.kind.name().into())
            .set("data", self.kind.to_json())
            .set("message", self.message().as_str().into());
        o
    }

    pub fn from_json(j: &Json) -> Result<Event, String> {
        let str_of = |k: &str| {
            j.get(k)
                .and_then(Json::as_str)
                .map(str::to_string)
                .ok_or_else(|| format!("event missing string field '{}'", k))
        };
        let u64_of = |k: &str| {
            j.get(k)
                .and_then(Json::as_f64)
                .filter(|f| *f >= 0.0 && f.fract() == 0.0)
                .map(|f| f as u64)
                .ok_or_else(|| format!("event missing integer field '{}'", k))
        };
        let level = str_of("level")?;
        let kind_name = str_of("kind")?;
        let empty = Json::obj();
        let data = j.get("data").unwrap_or(&empty);
        Ok(Event {
            seq: u64_of("seq")?,
            at_ms: u64_of("at_ms")?,
            level: Level::from_str(&level).ok_or_else(|| format!("unknown level '{}'", level))?,
            source: str_of("source")?,
            subject: str_of("subject")?,
            kind: EventKind::from_json(&kind_name, data)?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::parse;

    fn sample_kinds() -> Vec<EventKind> {
        vec![
            EventKind::LogLine { message: "container up".into() },
            EventKind::MetricReported { name: "accuracy".into(), step: 40, value: 0.91 },
            EventKind::StateChanged { from: "running".into(), to: "done".into(), step: 120 },
            EventKind::CheckpointSaved { step: 30, object: "sha-abc".into() },
            EventKind::PlacementDecided { node: 2, from_queue: true },
            EventKind::WorkerStolen { thief: 1, victim: 0 },
            EventKind::UtilizationSampled {
                utilization: 0.5,
                free_gpus: 4,
                alive_nodes: 3,
                queue_depth: 2,
            },
            EventKind::WorkerSampled {
                worker: 3,
                busy_ms: 12.5,
                live_sessions: 2,
                queue_depth: 1,
                steals: 4,
            },
            EventKind::AdmissionDecided { decision: "preempt".into(), user: "kim".into() },
            EventKind::LoopSampled {
                round: 9,
                round_ms: 1.75,
                progressed: 6,
                rounds_per_sec: 210.5,
            },
            EventKind::EndpointChanged {
                action: "promote".into(),
                version: 1,
                session: "kim/mnist/1".into(),
                model: "mnist_mlp".into(),
                step: 120,
                object: "sha-def".into(),
            },
            EventKind::InferServed { batch: 8, latency_ms: 3.25 },
            EventKind::ReplicaScaled { replicas: 3, queue_depth: 17 },
        ]
    }

    #[test]
    fn every_kind_round_trips_through_json() {
        let kinds = sample_kinds();
        let names: Vec<&str> = kinds.iter().map(|k| k.name()).collect();
        assert_eq!(names, ALL_EVENT_KINDS, "sample set must cover every kind");
        for kind in kinds {
            let e = Event {
                seq: 7,
                at_ms: 1234,
                level: Level::Info,
                source: "test".into(),
                subject: "kim/mnist/1".into(),
                kind,
            };
            let text = e.to_json().to_string();
            let back = Event::from_json(&parse(&text).unwrap())
                .unwrap_or_else(|err| panic!("{}: {}", text, err));
            assert_eq!(back, e, "{}", text);
        }
    }

    #[test]
    fn unknown_kind_and_missing_fields_are_named() {
        let err = EventKind::from_json("frobnicate", &Json::obj()).unwrap_err();
        assert!(err.contains("frobnicate"), "{}", err);
        let err = EventKind::from_json("metric", &Json::obj()).unwrap_err();
        assert!(err.contains("name"), "{}", err);
    }

    #[test]
    fn render_format() {
        let e = Event {
            seq: 0,
            at_ms: 10,
            level: Level::Info,
            source: "session".into(),
            subject: "kim/mnist/1".into(),
            kind: EventKind::LogLine { message: "started".into() },
        };
        let s = e.render();
        assert!(s.contains("INFO"));
        assert!(s.contains("kim/mnist/1"));
        assert!(s.contains("started"));
        // Subject-less events omit the parenthesized correlation key.
        let bare = Event { subject: String::new(), ..e };
        assert!(!bare.render().contains('('));
    }

    #[test]
    fn levels_order_and_round_trip() {
        assert!(Level::Error > Level::Warn);
        assert!(Level::Warn > Level::Info);
        assert!(Level::Info > Level::Debug);
        for l in [Level::Debug, Level::Info, Level::Warn, Level::Error] {
            assert_eq!(Level::from_str(l.as_str()), Some(l));
        }
        assert_eq!(Level::from_str("TRACE"), None);
    }
}
