//! Dataset registry (paper §3.1 Data Management):
//! "Users should be able to post datasets once and reuse them for multiple
//! models. Users should be able to share datasets with others."
//!
//! A dataset is a named, versioned bundle of objects in the
//! [`ObjectStore`](super::ObjectStore) plus metadata (owner, visibility,
//! nominal size). The synthetic data generators in [`crate::data`]
//! register themselves here so sessions mount datasets exactly the way
//! real uploads would be.

use super::{ObjectId, ObjectStore};
use anyhow::{anyhow, Result};
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

/// Dataset metadata + content manifest.
#[derive(Debug, Clone)]
pub struct DatasetInfo {
    pub name: String,
    pub owner: String,
    pub public: bool,
    pub version: u32,
    /// Logical file name -> object address.
    pub files: BTreeMap<String, ObjectId>,
    /// Nominal on-disk size in GB as seen by the mount subsystem. For
    /// synthetic datasets this is declared, mirroring the real multi-GB
    /// corpora the paper manages (ImageNet, YouTube-8M).
    pub nominal_size_gb: f64,
    /// Free-form description shown by `nsml dataset ls`.
    pub description: String,
}

impl DatasetInfo {
    /// Total physical bytes of the manifest's objects.
    pub fn physical_bytes(&self, store: &ObjectStore) -> u64 {
        self.files.values().filter_map(|id| store.get(id).ok()).map(|b| b.len() as u64).sum()
    }
}

/// Thread-safe registry of datasets.
#[derive(Clone)]
pub struct DatasetRegistry {
    store: ObjectStore,
    inner: Arc<Mutex<BTreeMap<String, DatasetInfo>>>,
}

impl DatasetRegistry {
    pub fn new(store: ObjectStore) -> DatasetRegistry {
        DatasetRegistry { store, inner: Arc::new(Mutex::new(BTreeMap::new())) }
    }

    /// Post (or re-post, bumping the version) a dataset.
    pub fn push(
        &self,
        name: &str,
        owner: &str,
        public: bool,
        files: &[(&str, &[u8])],
        nominal_size_gb: f64,
        description: &str,
    ) -> Result<DatasetInfo> {
        let mut manifest = BTreeMap::new();
        for (fname, bytes) in files {
            manifest.insert(fname.to_string(), self.store.put(bytes)?);
        }
        let mut reg = self.inner.lock().unwrap();
        let version = reg.get(name).map(|d| d.version + 1).unwrap_or(1);
        if let Some(existing) = reg.get(name) {
            if existing.owner != owner {
                return Err(anyhow!("dataset '{}' is owned by {}", name, existing.owner));
            }
        }
        let info = DatasetInfo {
            name: name.to_string(),
            owner: owner.to_string(),
            public,
            version,
            files: manifest,
            nominal_size_gb,
            description: description.to_string(),
        };
        reg.insert(name.to_string(), info.clone());
        Ok(info)
    }

    /// Fetch a dataset the given user may read (owner or public).
    pub fn get(&self, name: &str, user: &str) -> Result<DatasetInfo> {
        let reg = self.inner.lock().unwrap();
        let d = reg.get(name).ok_or_else(|| anyhow!("no such dataset '{}'", name))?;
        if !d.public && d.owner != user {
            return Err(anyhow!("dataset '{}' is private to {}", name, d.owner));
        }
        Ok(d.clone())
    }

    /// Does the dataset exist (regardless of visibility)?
    pub fn exists(&self, name: &str) -> bool {
        self.inner.lock().unwrap().contains_key(name)
    }

    /// Datasets visible to `user`.
    pub fn list(&self, user: &str) -> Vec<DatasetInfo> {
        self.inner
            .lock()
            .unwrap()
            .values()
            .filter(|d| d.public || d.owner == user)
            .cloned()
            .collect()
    }

    /// Read one file of a dataset.
    pub fn read_file(&self, name: &str, user: &str, file: &str) -> Result<Vec<u8>> {
        let d = self.get(name, user)?;
        let id = d.files.get(file).ok_or_else(|| anyhow!("dataset '{}' has no file '{}'", name, file))?;
        self.store.get(id)
    }

    pub fn store(&self) -> &ObjectStore {
        &self.store
    }

    /// Every object referenced by any dataset, regardless of
    /// visibility (the GC mark pass must see private manifests too).
    pub fn all_object_ids(&self) -> Vec<ObjectId> {
        let reg = self.inner.lock().unwrap();
        let mut ids: Vec<ObjectId> =
            reg.values().flat_map(|d| d.files.values().cloned()).collect();
        ids.sort();
        ids.dedup();
        ids
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn reg() -> DatasetRegistry {
        DatasetRegistry::new(ObjectStore::memory())
    }

    #[test]
    fn push_and_get() {
        let r = reg();
        let d = r.push("mnist", "kim", true, &[("train.bin", b"xx"), ("test.bin", b"yy")], 0.1, "digits").unwrap();
        assert_eq!(d.version, 1);
        assert_eq!(d.files.len(), 2);
        let got = r.get("mnist", "anyone").unwrap();
        assert_eq!(got.name, "mnist");
        assert_eq!(r.read_file("mnist", "anyone", "train.bin").unwrap(), b"xx");
    }

    #[test]
    fn repost_bumps_version() {
        let r = reg();
        r.push("d", "kim", true, &[("f", b"v1")], 1.0, "").unwrap();
        let d2 = r.push("d", "kim", true, &[("f", b"v2")], 1.0, "").unwrap();
        assert_eq!(d2.version, 2);
        assert_eq!(r.read_file("d", "kim", "f").unwrap(), b"v2");
    }

    #[test]
    fn ownership_enforced_on_repost() {
        let r = reg();
        r.push("d", "kim", true, &[], 1.0, "").unwrap();
        assert!(r.push("d", "lee", true, &[], 1.0, "").is_err());
    }

    #[test]
    fn private_datasets_hidden() {
        let r = reg();
        r.push("secret", "kim", false, &[("f", b"x")], 1.0, "").unwrap();
        r.push("open", "kim", true, &[], 1.0, "").unwrap();
        assert!(r.get("secret", "lee").is_err());
        assert!(r.get("secret", "kim").is_ok());
        let visible: Vec<String> = r.list("lee").into_iter().map(|d| d.name).collect();
        assert_eq!(visible, vec!["open"]);
        assert_eq!(r.list("kim").len(), 2);
    }

    #[test]
    fn missing_lookups_error() {
        let r = reg();
        assert!(r.get("nope", "x").is_err());
        r.push("d", "kim", true, &[("a", b"1")], 1.0, "").unwrap();
        assert!(r.read_file("d", "kim", "b").is_err());
    }

    #[test]
    fn all_object_ids_sees_private_manifests() {
        let r = reg();
        r.push("secret", "kim", false, &[("f", b"hidden")], 1.0, "").unwrap();
        r.push("open", "kim", true, &[("g", b"shown"), ("h", b"hidden")], 1.0, "").unwrap();
        // Two distinct objects ("hidden" dedups across datasets).
        assert_eq!(r.all_object_ids().len(), 2);
    }

    #[test]
    fn same_content_shares_objects() {
        let r = reg();
        r.push("d1", "kim", true, &[("f", b"shared-bytes")], 1.0, "").unwrap();
        r.push("d2", "kim", true, &[("g", b"shared-bytes")], 1.0, "").unwrap();
        // One physical object backs both datasets.
        assert_eq!(r.store().usage().0, 1);
    }
}
