//! Checkpoint store: "NSML stores intermediate trained models into the
//! storage container. With these backup files, NSML supports reproducing
//! the same model and tuning hyperparameters during training" (§3.3).
//!
//! Checkpoints carry the serialized model parameters plus the training
//! cursor (step, metric, hyperparameters), so a session can be paused,
//! edited and resumed, and any past experiment can be replayed.

use super::{ObjectId, ObjectStore};
use crate::util::json::{parse, Json};
use anyhow::{anyhow, Result};
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

/// One saved snapshot of a training session.
#[derive(Debug, Clone)]
pub struct Checkpoint {
    pub session: String,
    pub step: u64,
    /// Loss or task metric at save time.
    pub metric: f64,
    /// Hyperparameters active when the snapshot was taken.
    pub hparams: BTreeMap<String, f64>,
    /// Content address of the serialized parameters.
    pub params: ObjectId,
    pub saved_at_ms: u64,
}

impl Checkpoint {
    fn to_json(&self) -> Json {
        let mut hp = Json::obj();
        for (k, v) in &self.hparams {
            hp.set(k, (*v).into());
        }
        let mut o = Json::obj();
        o.set("session", self.session.as_str().into())
            .set("step", self.step.into())
            .set("metric", self.metric.into())
            .set("hparams", hp)
            .set("params", self.params.0.as_str().into())
            .set("saved_at_ms", self.saved_at_ms.into());
        o
    }

    fn from_json(j: &Json) -> Result<Checkpoint> {
        let get = |k: &str| j.get(k).ok_or_else(|| anyhow!("checkpoint json missing '{}'", k));
        let mut hparams = BTreeMap::new();
        if let Some(Json::Obj(m)) = j.get("hparams") {
            for (k, v) in m {
                // A malformed value must fail the parse naming the key —
                // silently coercing e.g. lr to 0.0 would make a resumed
                // session train with a garbage hyperparameter.
                let f = v.as_f64().ok_or_else(|| {
                    anyhow!("checkpoint hparam '{}' is not a number: {}", k, v.to_string())
                })?;
                hparams.insert(k.clone(), f);
            }
        }
        Ok(Checkpoint {
            session: get("session")?.as_str().unwrap_or_default().to_string(),
            step: get("step")?.as_i64().unwrap_or(0) as u64,
            metric: get("metric")?.as_f64().unwrap_or(f64::NAN),
            hparams,
            params: ObjectId(get("params")?.as_str().unwrap_or_default().to_string()),
            saved_at_ms: get("saved_at_ms")?.as_i64().unwrap_or(0) as u64,
        })
    }
}

/// Per-session checkpoint history backed by the object store.
#[derive(Clone)]
pub struct CheckpointStore {
    store: ObjectStore,
    index: Arc<Mutex<BTreeMap<String, Vec<Checkpoint>>>>,
}

impl CheckpointStore {
    pub fn new(store: ObjectStore) -> CheckpointStore {
        CheckpointStore { store, index: Arc::new(Mutex::new(BTreeMap::new())) }
    }

    /// Save a checkpoint (params as raw bytes) and index it.
    pub fn save(
        &self,
        session: &str,
        step: u64,
        metric: f64,
        hparams: &BTreeMap<String, f64>,
        params: &[u8],
        now_ms: u64,
    ) -> Result<Checkpoint> {
        let params_id = self.store.put(params)?;
        let ckpt = Checkpoint {
            session: session.to_string(),
            step,
            metric,
            hparams: hparams.clone(),
            params: params_id,
            saved_at_ms: now_ms,
        };
        // The metadata record itself also lives in the object store, so a
        // fresh process could rebuild the index (reproducibility).
        self.store.put(ckpt.to_json().to_string().as_bytes())?;
        self.index.lock().unwrap().entry(session.to_string()).or_default().push(ckpt.clone());
        Ok(ckpt)
    }

    /// All checkpoints of a session, oldest first.
    pub fn list(&self, session: &str) -> Vec<Checkpoint> {
        self.index.lock().unwrap().get(session).cloned().unwrap_or_default()
    }

    /// Most recent checkpoint.
    pub fn latest(&self, session: &str) -> Option<Checkpoint> {
        self.list(session).into_iter().max_by_key(|c| c.step)
    }

    /// Checkpoint with the best (lowest by default) metric — AutoML's
    /// "save the model of best score" (§3.1).
    pub fn best(&self, session: &str, lower_is_better: bool) -> Option<Checkpoint> {
        let list = self.list(session);
        if lower_is_better {
            list.into_iter().min_by(|a, b| a.metric.partial_cmp(&b.metric).unwrap())
        } else {
            list.into_iter().max_by(|a, b| a.metric.partial_cmp(&b.metric).unwrap())
        }
    }

    /// Checkpoint at an exact step.
    pub fn at_step(&self, session: &str, step: u64) -> Option<Checkpoint> {
        self.list(session).into_iter().find(|c| c.step == step)
    }

    /// Load a checkpoint's parameter bytes.
    pub fn load_params(&self, ckpt: &Checkpoint) -> Result<Vec<u8>> {
        self.store.get(&ckpt.params)
    }

    /// Re-parse a checkpoint metadata record from raw json bytes (used to
    /// rebuild indexes; exercised by tests for format stability).
    pub fn parse_record(bytes: &[u8]) -> Result<Checkpoint> {
        let j = parse(std::str::from_utf8(bytes)?).map_err(|e| anyhow!("bad checkpoint json: {}", e))?;
        Checkpoint::from_json(&j)
    }

    pub fn store(&self) -> &ObjectStore {
        &self.store
    }

    /// Every indexed checkpoint (for persistence).
    pub fn dump(&self) -> Vec<Checkpoint> {
        self.index.lock().unwrap().values().flatten().cloned().collect()
    }

    /// Serialize a checkpoint's metadata record (inverse of
    /// [`parse_record`](Self::parse_record)).
    pub fn record_bytes(ckpt: &Checkpoint) -> Vec<u8> {
        ckpt.to_json().to_string().into_bytes()
    }

    /// Re-index a checkpoint (used when reloading persisted state).
    pub fn restore(&self, ckpt: Checkpoint) {
        self.index.lock().unwrap().entry(ckpt.session.clone()).or_default().push(ckpt);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hp(lr: f64) -> BTreeMap<String, f64> {
        let mut m = BTreeMap::new();
        m.insert("lr".to_string(), lr);
        m
    }

    fn cs() -> CheckpointStore {
        CheckpointStore::new(ObjectStore::memory())
    }

    #[test]
    fn save_list_latest() {
        let c = cs();
        c.save("s1", 10, 2.0, &hp(0.1), b"p10", 100).unwrap();
        c.save("s1", 20, 1.5, &hp(0.1), b"p20", 200).unwrap();
        c.save("other", 5, 9.0, &hp(0.2), b"px", 300).unwrap();
        assert_eq!(c.list("s1").len(), 2);
        let latest = c.latest("s1").unwrap();
        assert_eq!(latest.step, 20);
        assert_eq!(c.load_params(&latest).unwrap(), b"p20");
        assert!(c.latest("missing").is_none());
    }

    #[test]
    fn best_metric_selection() {
        let c = cs();
        c.save("s", 1, 3.0, &hp(0.1), b"a", 0).unwrap();
        c.save("s", 2, 1.0, &hp(0.1), b"b", 0).unwrap();
        c.save("s", 3, 2.0, &hp(0.1), b"c", 0).unwrap();
        assert_eq!(c.best("s", true).unwrap().step, 2); // loss: lower wins
        assert_eq!(c.best("s", false).unwrap().step, 1); // accuracy-style
    }

    #[test]
    fn at_step_lookup() {
        let c = cs();
        c.save("s", 7, 1.0, &hp(0.5), b"x", 0).unwrap();
        assert_eq!(c.at_step("s", 7).unwrap().hparams["lr"], 0.5);
        assert!(c.at_step("s", 8).is_none());
    }

    #[test]
    fn record_roundtrip() {
        let c = cs();
        let ck = c.save("kim/mnist/3", 42, 0.123, &hp(0.01), b"params-bytes", 5_000).unwrap();
        let rec = ck.to_json().to_string();
        let back = CheckpointStore::parse_record(rec.as_bytes()).unwrap();
        assert_eq!(back.session, "kim/mnist/3");
        assert_eq!(back.step, 42);
        assert!((back.metric - 0.123).abs() < 1e-12);
        assert_eq!(back.hparams["lr"], 0.01);
        assert_eq!(back.params, ck.params);
    }

    #[test]
    fn malformed_hparam_is_an_error_naming_the_key() {
        let bad = br#"{"session":"s","step":1,"metric":0.5,"params":"obj-1",
                       "saved_at_ms":0,"hparams":{"lr":"fast","seed":3}}"#;
        let err = CheckpointStore::parse_record(bad).unwrap_err();
        let msg = format!("{:#}", err);
        assert!(msg.contains("lr"), "{}", msg);
        assert!(msg.contains("not a number"), "{}", msg);
        // Well-formed hparams still parse.
        let ok = br#"{"session":"s","step":1,"metric":0.5,"params":"obj-1",
                      "saved_at_ms":0,"hparams":{"lr":0.1}}"#;
        assert_eq!(CheckpointStore::parse_record(ok).unwrap().hparams["lr"], 0.1);
    }

    #[test]
    fn identical_params_dedup() {
        let c = cs();
        c.save("a", 1, 0.0, &hp(0.1), b"same-params", 0).unwrap();
        c.save("b", 1, 0.0, &hp(0.1), b"same-params", 1).unwrap();
        // 2 metadata records + 1 shared params object.
        assert_eq!(c.store().usage().0, 3);
    }
}
