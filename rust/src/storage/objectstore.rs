//! Content-addressed object store (the minio substitute).
//!
//! Objects are keyed by the SHA-256 of their contents: identical uploads
//! dedup for free (one physical copy however many sessions reference it),
//! and every read can be integrity-checked against its key.

use anyhow::{anyhow, Context, Result};
use sha2::{Digest, Sha256};
use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};

/// Content address: lowercase hex SHA-256.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct ObjectId(pub String);

impl ObjectId {
    pub fn of(bytes: &[u8]) -> ObjectId {
        let mut h = Sha256::new();
        h.update(bytes);
        ObjectId(hex(&h.finalize()))
    }

    /// Abbreviated id for display.
    pub fn short(&self) -> &str {
        &self.0[..12.min(self.0.len())]
    }
}

impl std::fmt::Display for ObjectId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.short())
    }
}

fn hex(bytes: &[u8]) -> String {
    let mut s = String::with_capacity(bytes.len() * 2);
    for b in bytes {
        s.push_str(&format!("{:02x}", b));
    }
    s
}

enum Backend {
    Mem(Mutex<BTreeMap<ObjectId, Arc<Vec<u8>>>>),
    Fs(PathBuf),
}

/// The store. Clone-cheap (`Arc` inside).
#[derive(Clone)]
pub struct ObjectStore {
    backend: Arc<Backend>,
}

impl ObjectStore {
    /// In-memory store (tests, benches, ephemeral platforms).
    pub fn memory() -> ObjectStore {
        ObjectStore { backend: Arc::new(Backend::Mem(Mutex::new(BTreeMap::new()))) }
    }

    /// Filesystem store rooted at `dir` (sharded by key prefix like git).
    pub fn filesystem(dir: impl Into<PathBuf>) -> Result<ObjectStore> {
        let dir = dir.into();
        std::fs::create_dir_all(&dir).with_context(|| format!("creating {}", dir.display()))?;
        Ok(ObjectStore { backend: Arc::new(Backend::Fs(dir)) })
    }

    fn fs_path(dir: &PathBuf, id: &ObjectId) -> PathBuf {
        dir.join(&id.0[..2]).join(&id.0[2..])
    }

    /// Store bytes; returns the content address. Idempotent.
    pub fn put(&self, bytes: &[u8]) -> Result<ObjectId> {
        let id = ObjectId::of(bytes);
        match &*self.backend {
            Backend::Mem(m) => {
                m.lock().unwrap().entry(id.clone()).or_insert_with(|| Arc::new(bytes.to_vec()));
            }
            Backend::Fs(dir) => {
                let path = Self::fs_path(dir, &id);
                if !path.exists() {
                    std::fs::create_dir_all(path.parent().unwrap())?;
                    // Write via temp + rename for atomicity.
                    let tmp = path.with_extension("tmp");
                    std::fs::write(&tmp, bytes)?;
                    std::fs::rename(&tmp, &path)?;
                }
            }
        }
        Ok(id)
    }

    /// Fetch bytes, verifying content integrity.
    pub fn get(&self, id: &ObjectId) -> Result<Vec<u8>> {
        let bytes = match &*self.backend {
            Backend::Mem(m) => m
                .lock()
                .unwrap()
                .get(id)
                .cloned()
                .map(|a| a.as_ref().clone())
                .ok_or_else(|| anyhow!("object {} not found", id))?,
            Backend::Fs(dir) => {
                let path = Self::fs_path(dir, id);
                std::fs::read(&path).with_context(|| format!("object {} not found", id))?
            }
        };
        let actual = ObjectId::of(&bytes);
        if &actual != id {
            return Err(anyhow!("integrity failure: wanted {}, content hashes to {}", id, actual));
        }
        Ok(bytes)
    }

    pub fn has(&self, id: &ObjectId) -> bool {
        match &*self.backend {
            Backend::Mem(m) => m.lock().unwrap().contains_key(id),
            Backend::Fs(dir) => Self::fs_path(dir, id).exists(),
        }
    }

    pub fn delete(&self, id: &ObjectId) -> bool {
        match &*self.backend {
            Backend::Mem(m) => m.lock().unwrap().remove(id).is_some(),
            Backend::Fs(dir) => {
                let path = Self::fs_path(dir, id);
                let deleted = std::fs::remove_file(&path).is_ok();
                if deleted {
                    // Prune the fan-out shard dir if this was its last
                    // object; remove_dir refuses non-empty dirs, so a
                    // concurrent put can at worst make this a no-op.
                    if let Some(shard) = path.parent() {
                        let _ = std::fs::remove_dir(shard);
                    }
                }
                deleted
            }
        }
    }

    /// Every stored content address (GC enumeration; recovery's
    /// checkpoint-index rebuild). O(n) on the fs backend.
    pub fn list(&self) -> Vec<ObjectId> {
        match &*self.backend {
            Backend::Mem(m) => m.lock().unwrap().keys().cloned().collect(),
            Backend::Fs(dir) => {
                let mut ids = Vec::new();
                if let Ok(shards) = std::fs::read_dir(dir) {
                    for shard in shards.flatten() {
                        let prefix = shard.file_name().to_string_lossy().to_string();
                        if prefix.len() != 2 {
                            continue;
                        }
                        if let Ok(files) = std::fs::read_dir(shard.path()) {
                            for f in files.flatten() {
                                let rest = f.file_name().to_string_lossy().to_string();
                                let full = format!("{}{}", prefix, rest);
                                // Skip in-flight temp files and anything
                                // that is not a 64-hex content address.
                                if full.len() == 64
                                    && full.chars().all(|c| c.is_ascii_hexdigit())
                                {
                                    ids.push(ObjectId(full));
                                }
                            }
                        }
                    }
                }
                ids.sort();
                ids
            }
        }
    }

    /// Size in bytes of one object, if present.
    pub fn size_of(&self, id: &ObjectId) -> Option<u64> {
        match &*self.backend {
            Backend::Mem(m) => m.lock().unwrap().get(id).map(|v| v.len() as u64),
            Backend::Fs(dir) => {
                std::fs::metadata(Self::fs_path(dir, id)).ok().filter(|m| m.is_file()).map(|m| m.len())
            }
        }
    }

    /// (object count, total bytes). O(n) on the fs backend.
    pub fn usage(&self) -> (usize, u64) {
        match &*self.backend {
            Backend::Mem(m) => {
                let m = m.lock().unwrap();
                (m.len(), m.values().map(|v| v.len() as u64).sum())
            }
            Backend::Fs(dir) => {
                let mut count = 0;
                let mut bytes = 0;
                if let Ok(shards) = std::fs::read_dir(dir) {
                    for shard in shards.flatten() {
                        if let Ok(files) = std::fs::read_dir(shard.path()) {
                            for f in files.flatten() {
                                if let Ok(meta) = f.metadata() {
                                    if meta.is_file() {
                                        count += 1;
                                        bytes += meta.len();
                                    }
                                }
                            }
                        }
                    }
                }
                (count, bytes)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn put_get_roundtrip_memory() {
        let s = ObjectStore::memory();
        let id = s.put(b"hello nsml").unwrap();
        assert_eq!(s.get(&id).unwrap(), b"hello nsml");
        assert!(s.has(&id));
        assert!(!s.has(&ObjectId::of(b"other")));
    }

    #[test]
    fn content_addressing_dedups() {
        let s = ObjectStore::memory();
        let a = s.put(b"same").unwrap();
        let b = s.put(b"same").unwrap();
        assert_eq!(a, b);
        assert_eq!(s.usage(), (1, 4));
    }

    #[test]
    fn distinct_content_distinct_ids() {
        let a = ObjectId::of(b"a");
        let b = ObjectId::of(b"b");
        assert_ne!(a, b);
        assert_eq!(a.0.len(), 64);
    }

    #[test]
    fn missing_object_errors() {
        let s = ObjectStore::memory();
        assert!(s.get(&ObjectId::of(b"nope")).is_err());
    }

    #[test]
    fn delete_frees() {
        let s = ObjectStore::memory();
        let id = s.put(b"x").unwrap();
        assert!(s.delete(&id));
        assert!(!s.delete(&id));
        assert!(!s.has(&id));
    }

    #[test]
    fn fs_backend_roundtrip_and_shard_layout() {
        let dir = std::env::temp_dir().join(format!("nsml-os-test-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let s = ObjectStore::filesystem(&dir).unwrap();
        let id = s.put(b"persisted bytes").unwrap();
        assert!(s.has(&id));
        assert_eq!(s.get(&id).unwrap(), b"persisted bytes");
        // Shard dir layout: <root>/<2 hex>/<62 hex>.
        assert!(dir.join(&id.0[..2]).join(&id.0[2..]).exists());
        // Reopen sees the same data (durability).
        let s2 = ObjectStore::filesystem(&dir).unwrap();
        assert_eq!(s2.get(&id).unwrap(), b"persisted bytes");
        let (n, bytes) = s2.usage();
        assert_eq!(n, 1);
        assert_eq!(bytes, 15);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn fs_delete_prunes_empty_shard_and_usage_tracks() {
        let dir = std::env::temp_dir().join(format!("nsml-os-prune-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let s = ObjectStore::filesystem(&dir).unwrap();
        assert_eq!(s.usage(), (0, 0));
        let a = s.put(b"object a").unwrap();
        let b = s.put(b"object bb").unwrap();
        assert_eq!(s.usage(), (2, 17));
        // Delete one: count and bytes shrink, its shard dir is pruned
        // once empty (a and b land in different shards w.h.p., but we
        // only assert a's own shard is gone).
        assert!(s.delete(&a));
        assert_eq!(s.usage(), (1, 9));
        assert!(!dir.join(&a.0[..2]).exists(), "empty fan-out dir must be pruned");
        assert!(s.has(&b));
        // Deleting a missing object is a no-op on usage.
        assert!(!s.delete(&a));
        assert_eq!(s.usage(), (1, 9));
        assert!(s.delete(&b));
        assert_eq!(s.usage(), (0, 0));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn list_and_size_of_cover_both_backends() {
        let mem = ObjectStore::memory();
        let a = mem.put(b"aaa").unwrap();
        let b = mem.put(b"bbbb").unwrap();
        let mut want = vec![a.clone(), b.clone()];
        want.sort();
        assert_eq!(mem.list(), want);
        assert_eq!(mem.size_of(&a), Some(3));
        assert_eq!(mem.size_of(&ObjectId::of(b"missing")), None);

        let dir = std::env::temp_dir().join(format!("nsml-os-list-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let fs = ObjectStore::filesystem(&dir).unwrap();
        fs.put(b"aaa").unwrap();
        fs.put(b"bbbb").unwrap();
        // A stray temp file must not surface as an object.
        std::fs::write(dir.join(&a.0[..2]).join("leftover.tmp"), b"junk").unwrap();
        assert_eq!(fs.list(), want);
        assert_eq!(fs.size_of(&b), Some(4));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn fs_integrity_check_detects_corruption() {
        let dir = std::env::temp_dir().join(format!("nsml-os-corrupt-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let s = ObjectStore::filesystem(&dir).unwrap();
        let id = s.put(b"good data").unwrap();
        let path = dir.join(&id.0[..2]).join(&id.0[2..]);
        std::fs::write(&path, b"tampered!").unwrap();
        let err = s.get(&id).unwrap_err().to_string();
        assert!(err.contains("integrity"), "{}", err);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
