//! Code packing: what `nsml run` does first — "package the code in the
//! current directory, send it to the NSML server" (§3.4), so every
//! experiment's exact source is stored and reproducible (§2: tracking
//! experiment environments over time).

use super::{ObjectId, ObjectStore};
use anyhow::{Context, Result};
use std::io::{Read, Write};
use std::path::Path;

/// Zip an in-memory file set (name → contents) into one archive.
pub fn pack_files(files: &[(&str, &[u8])]) -> Result<Vec<u8>> {
    let mut buf = std::io::Cursor::new(Vec::new());
    {
        let mut zip = zip::ZipWriter::new(&mut buf);
        let opts =
            zip::write::FileOptions::default().compression_method(zip::CompressionMethod::Deflated);
        for (name, bytes) in files {
            zip.start_file(name.to_string(), opts)?;
            zip.write_all(bytes)?;
        }
        zip.finish()?;
    }
    Ok(buf.into_inner())
}

/// Zip a directory tree from disk (skips hidden files and `target/`).
pub fn pack_dir(dir: &Path) -> Result<Vec<u8>> {
    let mut entries: Vec<(String, Vec<u8>)> = Vec::new();
    collect(dir, dir, &mut entries)?;
    entries.sort_by(|a, b| a.0.cmp(&b.0)); // deterministic archives
    let refs: Vec<(&str, &[u8])> = entries.iter().map(|(n, b)| (n.as_str(), b.as_slice())).collect();
    pack_files(&refs)
}

fn collect(root: &Path, dir: &Path, out: &mut Vec<(String, Vec<u8>)>) -> Result<()> {
    for entry in std::fs::read_dir(dir).with_context(|| format!("reading {}", dir.display()))? {
        let entry = entry?;
        let name = entry.file_name().to_string_lossy().to_string();
        if name.starts_with('.') || name == "target" || name == "__pycache__" {
            continue;
        }
        let path = entry.path();
        if path.is_dir() {
            collect(root, &path, out)?;
        } else {
            let rel = path.strip_prefix(root)?.to_string_lossy().replace('\\', "/");
            out.push((rel, std::fs::read(&path)?));
        }
    }
    Ok(())
}

/// Unpack an archive into (name → contents) pairs.
pub fn unpack(archive: &[u8]) -> Result<Vec<(String, Vec<u8>)>> {
    let mut zip = zip::ZipArchive::new(std::io::Cursor::new(archive))?;
    let mut out = Vec::new();
    for i in 0..zip.len() {
        let mut f = zip.by_index(i)?;
        if f.is_dir() {
            continue;
        }
        let mut bytes = Vec::with_capacity(f.size() as usize);
        f.read_to_end(&mut bytes)?;
        out.push((f.name().to_string(), bytes));
    }
    Ok(out)
}

/// Pack + store: returns the code bundle's content address.
pub fn store_codepack(store: &ObjectStore, files: &[(&str, &[u8])]) -> Result<ObjectId> {
    store.put(&pack_files(files)?)
}

/// Fetch + unpack a stored code bundle.
pub fn load_codepack(store: &ObjectStore, id: &ObjectId) -> Result<Vec<(String, Vec<u8>)>> {
    unpack(&store.get(id)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pack_unpack_roundtrip() {
        let files: Vec<(&str, &[u8])> =
            vec![("main.py", b"print('hi')".as_slice()), ("model/net.py", b"class Net: pass")];
        let archive = pack_files(&files).unwrap();
        let back = unpack(&archive).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back[0].0, "main.py");
        assert_eq!(back[0].1, b"print('hi')");
        assert_eq!(back[1].0, "model/net.py");
    }

    #[test]
    fn store_and_load() {
        let store = ObjectStore::memory();
        let files: Vec<(&str, &[u8])> = vec![("a.py", b"aaaa".as_slice())];
        let id = store_codepack(&store, &files).unwrap();
        let back = load_codepack(&store, &id).unwrap();
        assert_eq!(back[0].1, b"aaaa");
    }

    #[test]
    fn deterministic_packing_dedups() {
        let store = ObjectStore::memory();
        let files: Vec<(&str, &[u8])> = vec![("a.py", b"same".as_slice())];
        let id1 = store_codepack(&store, &files).unwrap();
        let id2 = store_codepack(&store, &files).unwrap();
        assert_eq!(id1, id2);
        assert_eq!(store.usage().0, 1);
    }

    #[test]
    fn pack_dir_skips_hidden_and_target() {
        let dir = std::env::temp_dir().join(format!("nsml-pack-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(dir.join("src")).unwrap();
        std::fs::create_dir_all(dir.join("target")).unwrap();
        std::fs::write(dir.join("main.py"), b"m").unwrap();
        std::fs::write(dir.join("src/lib.py"), b"l").unwrap();
        std::fs::write(dir.join(".secret"), b"s").unwrap();
        std::fs::write(dir.join("target/junk.bin"), b"j").unwrap();
        let archive = pack_dir(&dir).unwrap();
        let names: Vec<String> = unpack(&archive).unwrap().into_iter().map(|(n, _)| n).collect();
        assert_eq!(names, vec!["main.py", "src/lib.py"]);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_archive_rejected() {
        assert!(unpack(b"this is not a zip").is_err());
    }
}
