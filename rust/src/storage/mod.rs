//! Storage containers (paper §3.2) — the minio stand-in.
//!
//! "Storage containers use *minio* to store and supply datasets to ML
//! containers. They also store the performance of all models … back up
//! intermediate and final results of trained models and also store the
//! source code associated with the experiments so that users can easily
//! reproduce … models."
//!
//! minio is unavailable offline, so [`ObjectStore`] provides the same
//! contract: a content-addressed blob store (SHA-256 keys ⇒ free dedup,
//! integrity checks) with in-memory and filesystem backends. On top of it:
//!
//! * [`DatasetRegistry`] — post once, reuse for many models, share with
//!   other users (§3.1 Data Management).
//! * [`CheckpointStore`] — intermediate/final model snapshots, the
//!   substrate for pause/resume, hyperparameter tuning in training time,
//!   and "reproducing the past experiments".
//! * [`codepack`] — zip/unzip the user's code directory (what NSML-CLI
//!   uploads with `nsml run`).

mod objectstore;
mod dataset;
mod checkpoint;
pub mod codepack;

pub use checkpoint::{Checkpoint, CheckpointStore};
pub use dataset::{DatasetInfo, DatasetRegistry};
pub use objectstore::{ObjectId, ObjectStore};
