//! Event-sourced durability: WAL + snapshot/replay recovery +
//! object-store GC.
//!
//! `persist::save` used to rewrite the whole world as one
//! `state.json` on every mutation — O(sessions) per save and lossy
//! on a crash mid-write. This subsystem turns durability into a
//! *derived consumer* of the PR-4 event bus instead of a hot-path
//! tax:
//!
//! * [`wal`] — an append-only, fsync-batched log fed by a dedicated
//!   bus [`Subscription`]; every `StateChanged` / `MetricReported` /
//!   `CheckpointSaved` / `AdmissionDecided` event becomes a
//!   length-prefixed, checksummed record, and torn tails are
//!   truncated on open.
//! * [`snapshot`] — periodic compacted snapshots: the `persist::save`
//!   world dump, demoted from per-mutation to every
//!   `[durability] snapshot_every` WAL records, plus a
//!   [`SnapshotMeta`] recording the bus sequence number the dump
//!   covers and the usage-accounting ledger. After a snapshot the
//!   WAL segment rotates.
//! * [`recovery`] — startup = load the newest valid snapshot, then
//!   replay the WAL tail (`seq > last_seq` only, hence idempotent)
//!   through the same consumer paths the live platform pumps.
//! * [`gc`] — mark-and-sweep over the content-addressed object
//!   store: checkpoint chains, dataset manifests and code bundles
//!   stay, orphans go, and per-tenant storage bytes join
//!   GPU-seconds in the tenant registry.
//!
//! The facade (`api::NsmlPlatform`) owns one [`Durability`] manager:
//! its subscription is created before any subsystem can publish, the
//! drive loop pumps it once per round, `save_state` becomes
//! snapshot-on-demand, and a lagging subscription (ring overflow)
//! triggers an immediate full snapshot so nothing is ever silently
//! lost. Surfaces: the `durability_status` wire verb,
//! `GET /api/v1/durability`, and `nsml gc`.
//!
//! [`Subscription`]: crate::events::Subscription

pub mod gc;
pub mod recovery;
pub mod snapshot;
pub mod wal;

pub use gc::GcReport;
pub use recovery::{rebuild_checkpoint_index, replay, ReplayStats};
pub use snapshot::SnapshotMeta;
pub use wal::{Wal, WalScan};

use crate::events::{Event, EventKind, Subscription};
use anyhow::Result;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

/// WAL file name under the durability directory.
pub const WAL_FILE: &str = "wal.log";

/// Should this event reach the log? The durable kinds are exactly
/// the ones recovery can apply; high-volume telemetry (util/worker
/// samples, placement, steals, log lines) stays in the ring only.
pub fn is_durable(e: &Event) -> bool {
    matches!(
        e.kind,
        EventKind::StateChanged { .. }
            | EventKind::MetricReported { .. }
            | EventKind::CheckpointSaved { .. }
            | EventKind::AdmissionDecided { .. }
            | EventKind::EndpointChanged { .. }
    )
}

/// One [`Durability::pump`]'s outcome.
#[derive(Debug, Clone, Copy, Default)]
pub struct PumpOutcome {
    /// Durable events appended this pump.
    pub appended: u64,
    /// The subscription lost events to ring overflow since the last
    /// pump — the WAL has a gap and only a full snapshot closes it.
    pub overflowed: bool,
    /// `snapshot_every` records have accumulated since the last
    /// snapshot.
    pub snapshot_due: bool,
}

struct Inner {
    wal: Wal,
    sub: Subscription,
    /// Durable records appended since the last snapshot.
    records_since_snapshot: u64,
    snapshots: u64,
    last_snapshot_seq: u64,
    last_gc: Option<GcReport>,
}

/// Counters for the `durability_status` surface.
#[derive(Debug, Clone, Default)]
pub struct DurabilityStats {
    pub wal_records: u64,
    pub wal_bytes: u64,
    pub wal_last_seq: Option<u64>,
    pub records_since_snapshot: u64,
    pub snapshots: u64,
    pub last_snapshot_seq: u64,
    /// Events the WAL subscription lost to ring overflow (each loss
    /// is healed by an immediate snapshot, but the counter remains).
    pub wal_dropped: u64,
    pub last_gc: Option<GcReport>,
}

/// The facade-owned durability manager (see module docs).
pub struct Durability {
    dir: PathBuf,
    snapshot_every: u64,
    gc_enabled: bool,
    inner: Mutex<Inner>,
}

impl Durability {
    /// Open (or create) the durability directory under `state_dir`,
    /// scan the WAL, and load the snapshot metadata. `sub` must be a
    /// subscription created before any subsystem publishes, so the
    /// log sees every durable event from process start.
    #[allow(clippy::type_complexity)]
    pub fn open(
        state_dir: &Path,
        sub: Subscription,
        fsync_every: u64,
        snapshot_every: u64,
        gc_enabled: bool,
    ) -> Result<(Durability, WalScan, Option<SnapshotMeta>)> {
        let dir = state_dir.join("durability");
        let meta = SnapshotMeta::load(&dir)?;
        let (wal, scan) = Wal::open(dir.join(WAL_FILE), fsync_every)?;
        let durability = Durability {
            dir,
            snapshot_every: snapshot_every.max(1),
            gc_enabled,
            inner: Mutex::new(Inner {
                wal,
                sub,
                records_since_snapshot: 0,
                snapshots: 0,
                last_snapshot_seq: meta.as_ref().map(|m| m.last_seq).unwrap_or(0),
                last_gc: None,
            }),
        };
        Ok((durability, scan, meta))
    }

    /// Instrument WAL append/fsync with timing histograms
    /// (`nsml_wal_append_ms` / `nsml_wal_fsync_ms`). The platform
    /// calls this once right after `open`.
    pub fn set_metrics(&self, append: crate::obs::Histogram, sync: crate::obs::Histogram) {
        self.inner.lock().unwrap().wal.set_metrics(append, sync);
    }

    /// Drain the subscription and append every durable event.
    pub fn pump(&self) -> Result<PumpOutcome> {
        let mut inner = self.inner.lock().unwrap();
        let before = inner.sub.dropped();
        let events = inner.sub.poll();
        let overflowed = inner.sub.dropped() > before;
        let mut appended = 0;
        for e in events.iter().filter(|e| is_durable(e)) {
            inner.wal.append(e)?;
            appended += 1;
        }
        inner.records_since_snapshot += appended;
        Ok(PumpOutcome {
            appended,
            overflowed,
            snapshot_due: inner.records_since_snapshot >= self.snapshot_every,
        })
    }

    /// Record that a world dump covering `meta.last_seq` was just
    /// written: persist the metadata atomically, rotate the WAL
    /// segment it subsumes, and reset the snapshot cadence.
    pub fn mark_snapshot(&self, meta: &SnapshotMeta) -> Result<()> {
        let mut inner = self.inner.lock().unwrap();
        meta.save(&self.dir)?;
        inner.wal.rotate()?;
        inner.records_since_snapshot = 0;
        inner.snapshots += 1;
        inner.last_snapshot_seq = meta.last_seq;
        Ok(())
    }

    /// Flush unsynced WAL appends to stable storage.
    pub fn sync(&self) -> Result<()> {
        self.inner.lock().unwrap().wal.sync()
    }

    /// Remember the latest GC sweep for the status surface.
    pub fn note_gc(&self, report: GcReport) {
        self.inner.lock().unwrap().last_gc = Some(report);
    }

    pub fn gc_enabled(&self) -> bool {
        self.gc_enabled
    }

    pub fn snapshot_every(&self) -> u64 {
        self.snapshot_every
    }

    /// Durability directory (`<state_dir>/durability`).
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn stats(&self) -> DurabilityStats {
        let inner = self.inner.lock().unwrap();
        DurabilityStats {
            wal_records: inner.wal.records(),
            wal_bytes: inner.wal.bytes(),
            wal_last_seq: inner.wal.last_seq(),
            records_since_snapshot: inner.records_since_snapshot,
            snapshots: inner.snapshots,
            last_snapshot_seq: inner.last_snapshot_seq,
            wal_dropped: inner.sub.dropped(),
            last_gc: inner.last_gc.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::events::{EventBus, Level};
    use crate::util::clock::sim_clock;

    fn tmp(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("nsml-dur-{}-{}", tag, std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn publish_state(bus: &EventBus, subject: &str, to: &str, step: u64) {
        bus.publish(
            Level::Info,
            "session",
            subject,
            EventKind::StateChanged { from: "x".into(), to: to.into(), step },
        );
    }

    #[test]
    fn pump_appends_only_durable_kinds_and_snapshots_on_cadence() {
        let dir = tmp("pump");
        let (clock, _sim) = sim_clock();
        let bus = EventBus::new(clock);
        let sub = bus.subscribe();
        let (d, scan, meta) = Durability::open(&dir, sub, 4, 3, true).unwrap();
        assert!(scan.events.is_empty());
        assert!(meta.is_none());

        publish_state(&bus, "s1", "running", 0);
        bus.publish(Level::Debug, "platform", "", EventKind::LogLine { message: "noise".into() });
        bus.publish(
            Level::Debug,
            "platform",
            "",
            EventKind::UtilizationSampled {
                utilization: 0.5,
                free_gpus: 1,
                alive_nodes: 1,
                queue_depth: 0,
            },
        );
        publish_state(&bus, "s1", "done", 10);
        let out = d.pump().unwrap();
        assert_eq!(out.appended, 2, "telemetry noise stays out of the WAL");
        assert!(!out.overflowed);
        assert!(!out.snapshot_due, "2 of 3 records accumulated");

        publish_state(&bus, "s2", "running", 0);
        let out = d.pump().unwrap();
        assert!(out.snapshot_due, "third record hits the cadence");
        d.mark_snapshot(&SnapshotMeta { last_seq: bus.head() - 1, ..Default::default() }).unwrap();
        let stats = d.stats();
        assert_eq!(stats.wal_records, 0, "segment rotated");
        assert_eq!(stats.snapshots, 1);
        assert_eq!(stats.records_since_snapshot, 0);
        assert_eq!(stats.last_snapshot_seq, bus.head() - 1);

        // The rotated-away prefix is subsumed: a reopen replays nothing.
        drop(d);
        let sub2 = bus.subscribe();
        let (d2, scan2, meta2) = Durability::open(&dir, sub2, 4, 3, true).unwrap();
        assert!(scan2.events.is_empty());
        assert_eq!(meta2.unwrap().last_seq, bus.head() - 1);
        assert_eq!(d2.stats().last_snapshot_seq, bus.head() - 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn overflow_is_reported_once_per_loss() {
        let dir = tmp("overflow");
        let (clock, _sim) = sim_clock();
        let bus = EventBus::new(clock).with_capacity(4);
        let sub = bus.subscribe();
        let (d, _, _) = Durability::open(&dir, sub, 1, 1_000, false).unwrap();
        for i in 0..10 {
            publish_state(&bus, "s", "running", i);
        }
        let out = d.pump().unwrap();
        assert!(out.overflowed, "ring of 4 lost 6 of 10");
        assert_eq!(out.appended, 4);
        assert!(d.stats().wal_dropped >= 6);
        // Caught up now: the next pump reports no new loss.
        publish_state(&bus, "s", "done", 10);
        let out = d.pump().unwrap();
        assert!(!out.overflowed);
        assert_eq!(out.appended, 1);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
