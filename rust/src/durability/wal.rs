//! Append-only, fsync-batched write-ahead log of bus events.
//!
//! Record format: `[u32 LE payload length][u32 LE FNV-1a checksum]
//! [payload]`, where the payload is the event's JSON envelope
//! (`Event::to_json`). Appends go straight to the file and are
//! fsynced once per `fsync_every` records, so the per-mutation cost
//! is one small buffered write — not the O(sessions) `state.json`
//! rewrite it replaces.
//!
//! On open the log is scanned front to back; the first record that
//! fails its length bound, checksum or JSON parse marks a torn tail
//! (a crash mid-append), and the file is truncated back to the last
//! valid record. Everything before the tear replays losslessly.

use crate::events::Event;
use crate::obs::Histogram;
use crate::util::json::parse;
use anyhow::{Context, Result};
use std::fs::{File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

/// Length sanity bound while scanning a possibly-corrupt log: no
/// event envelope comes anywhere near this, so a larger claimed
/// length means we are reading garbage, not a record header.
const MAX_RECORD_BYTES: u32 = 16 * 1024 * 1024;

/// 32-bit FNV-1a — dependency-free, cheap, and plenty to detect the
/// partial writes torn-tail scanning cares about (this is not a
/// content address; the object store does cryptographic hashing).
pub fn checksum(bytes: &[u8]) -> u32 {
    let mut h: u32 = 0x811c_9dc5;
    for b in bytes {
        h ^= *b as u32;
        h = h.wrapping_mul(0x0100_0193);
    }
    h
}

/// What [`Wal::open`] found on disk.
pub struct WalScan {
    /// Every valid record, oldest first.
    pub events: Vec<Event>,
    /// Bytes cut off a torn tail (0 = the log was clean).
    pub truncated_bytes: u64,
}

/// The open log. Single-writer by construction — the platform owns
/// it behind a mutex and appends from the drive loop only.
pub struct Wal {
    path: PathBuf,
    file: File,
    fsync_every: u64,
    /// Appends since the last fsync.
    unsynced: u64,
    /// Records in the current segment.
    records: u64,
    /// Bytes in the current segment.
    bytes: u64,
    /// Sequence number of the segment's newest record.
    last_seq: Option<u64>,
    /// Wall-clock timing histograms set by the platform after open
    /// (`nsml_wal_append_ms` / `nsml_wal_fsync_ms`); `None` until then.
    append_hist: Option<Histogram>,
    sync_hist: Option<Histogram>,
}

impl Wal {
    /// Open (creating if absent) the log at `path`, scan it, and
    /// truncate any torn tail. `fsync_every` = 1 syncs every append.
    pub fn open(path: impl Into<PathBuf>, fsync_every: u64) -> Result<(Wal, WalScan)> {
        let path = path.into();
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(false)
            .open(&path)
            .with_context(|| format!("opening WAL {}", path.display()))?;
        let mut raw = Vec::new();
        file.read_to_end(&mut raw)?;
        let (events, valid_len) = scan(&raw);
        let truncated_bytes = raw.len() as u64 - valid_len;
        if truncated_bytes > 0 {
            file.set_len(valid_len)?;
        }
        file.seek(SeekFrom::Start(valid_len))?;
        let wal = Wal {
            path,
            file,
            fsync_every: fsync_every.max(1),
            unsynced: 0,
            records: events.len() as u64,
            bytes: valid_len,
            last_seq: events.last().map(|e| e.seq),
            append_hist: None,
            sync_hist: None,
        };
        Ok((wal, WalScan { events, truncated_bytes }))
    }

    /// Instrument append/fsync with timing histograms. The platform
    /// calls this once after construction; the signature of `open`
    /// stays free of observability concerns.
    pub fn set_metrics(&mut self, append: Histogram, sync: Histogram) {
        self.append_hist = Some(append);
        self.sync_hist = Some(sync);
    }

    /// Append one event as a length-prefixed, checksummed record.
    pub fn append(&mut self, e: &Event) -> Result<()> {
        let t0 = std::time::Instant::now();
        let payload = e.to_json().to_string().into_bytes();
        let mut rec = Vec::with_capacity(8 + payload.len());
        rec.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        rec.extend_from_slice(&checksum(&payload).to_le_bytes());
        rec.extend_from_slice(&payload);
        self.file.write_all(&rec)?;
        self.records += 1;
        self.bytes += rec.len() as u64;
        self.last_seq = Some(e.seq);
        self.unsynced += 1;
        if let Some(h) = &self.append_hist {
            h.record(t0.elapsed().as_secs_f64() * 1000.0);
        }
        if self.unsynced >= self.fsync_every {
            self.sync()?;
        }
        Ok(())
    }

    /// Flush any unsynced appends to stable storage.
    pub fn sync(&mut self) -> Result<()> {
        if self.unsynced > 0 {
            let t0 = std::time::Instant::now();
            self.file.sync_data()?;
            self.unsynced = 0;
            if let Some(h) = &self.sync_hist {
                h.record(t0.elapsed().as_secs_f64() * 1000.0);
            }
        }
        Ok(())
    }

    /// Start a fresh segment: a snapshot just subsumed every record,
    /// so the current segment's contents are dead weight.
    pub fn rotate(&mut self) -> Result<()> {
        self.file.set_len(0)?;
        self.file.seek(SeekFrom::Start(0))?;
        self.file.sync_data()?;
        self.unsynced = 0;
        self.records = 0;
        self.bytes = 0;
        self.last_seq = None;
        Ok(())
    }

    pub fn records(&self) -> u64 {
        self.records
    }

    pub fn bytes(&self) -> u64 {
        self.bytes
    }

    /// Sequence number of the newest record in the current segment.
    pub fn last_seq(&self) -> Option<u64> {
        self.last_seq
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

/// Walk `raw` record by record; returns the parsed events and the
/// byte length of the valid prefix (everything past it is torn).
fn scan(raw: &[u8]) -> (Vec<Event>, u64) {
    let mut events = Vec::new();
    let mut off = 0usize;
    while off + 8 <= raw.len() {
        let len = u32::from_le_bytes(raw[off..off + 4].try_into().unwrap());
        let sum = u32::from_le_bytes(raw[off + 4..off + 8].try_into().unwrap());
        if len == 0 || len > MAX_RECORD_BYTES {
            break;
        }
        let end = off + 8 + len as usize;
        if end > raw.len() {
            break;
        }
        let payload = &raw[off + 8..end];
        if checksum(payload) != sum {
            break;
        }
        let Ok(text) = std::str::from_utf8(payload) else { break };
        let Ok(json) = parse(text) else { break };
        let Ok(event) = Event::from_json(&json) else { break };
        events.push(event);
        off = end;
    }
    (events, off as u64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::events::{EventKind, Level};

    fn event(seq: u64, to: &str) -> Event {
        Event {
            seq,
            at_ms: seq * 10,
            level: Level::Info,
            source: "session".into(),
            subject: "kim/mnist/1".into(),
            kind: EventKind::StateChanged { from: "x".into(), to: to.into(), step: seq },
        }
    }

    fn tmp(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("nsml-wal-{}-{}", tag, std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir.join("wal.log")
    }

    #[test]
    fn append_reopen_round_trips() {
        let path = tmp("roundtrip");
        {
            let (mut wal, scan) = Wal::open(&path, 2).unwrap();
            assert!(scan.events.is_empty());
            assert_eq!(scan.truncated_bytes, 0);
            for i in 0..5 {
                wal.append(&event(i, "running")).unwrap();
            }
            assert_eq!(wal.records(), 5);
            assert_eq!(wal.last_seq(), Some(4));
            assert!(wal.bytes() > 0);
        } // dropped without an explicit sync — a "crash"
        let (wal, scan) = Wal::open(&path, 2).unwrap();
        assert_eq!(scan.events.len(), 5);
        assert_eq!(scan.truncated_bytes, 0);
        assert_eq!(scan.events[3], event(3, "running"));
        assert_eq!(wal.records(), 5);
        let _ = std::fs::remove_dir_all(path.parent().unwrap());
    }

    #[test]
    fn torn_tail_is_truncated_on_open() {
        let path = tmp("torn");
        {
            let (mut wal, _) = Wal::open(&path, 1).unwrap();
            wal.append(&event(0, "running")).unwrap();
            wal.append(&event(1, "done")).unwrap();
        }
        // Simulate a crash mid-append: a header promising more bytes
        // than exist, followed by garbage.
        let clean_len = std::fs::metadata(&path).unwrap().len();
        let mut f = OpenOptions::new().append(true).open(&path).unwrap();
        f.write_all(&500u32.to_le_bytes()).unwrap();
        f.write_all(&0xdead_beefu32.to_le_bytes()).unwrap();
        f.write_all(b"partial garbage").unwrap();
        drop(f);

        let (wal, scan) = Wal::open(&path, 1).unwrap();
        assert_eq!(scan.events.len(), 2, "valid prefix survives");
        assert!(scan.truncated_bytes > 0);
        assert_eq!(std::fs::metadata(&path).unwrap().len(), clean_len, "tail cut off");
        assert_eq!(wal.last_seq(), Some(1));
        let _ = std::fs::remove_dir_all(path.parent().unwrap());
    }

    #[test]
    fn corrupted_checksum_stops_the_scan() {
        let path = tmp("checksum");
        {
            let (mut wal, _) = Wal::open(&path, 1).unwrap();
            for i in 0..3 {
                wal.append(&event(i, "running")).unwrap();
            }
        }
        // Flip one payload byte of the last record.
        let mut raw = std::fs::read(&path).unwrap();
        let n = raw.len();
        raw[n - 3] ^= 0xff;
        std::fs::write(&path, &raw).unwrap();
        let (_, scan) = Wal::open(&path, 1).unwrap();
        assert_eq!(scan.events.len(), 2, "only the corrupted record is lost");
        assert!(scan.truncated_bytes > 0);
        let _ = std::fs::remove_dir_all(path.parent().unwrap());
    }

    #[test]
    fn rotate_starts_a_fresh_segment() {
        let path = tmp("rotate");
        let (mut wal, _) = Wal::open(&path, 8).unwrap();
        for i in 0..4 {
            wal.append(&event(i, "running")).unwrap();
        }
        wal.rotate().unwrap();
        assert_eq!(wal.records(), 0);
        assert_eq!(wal.bytes(), 0);
        assert_eq!(wal.last_seq(), None);
        assert_eq!(std::fs::metadata(&path).unwrap().len(), 0);
        // Appends keep working after the reset.
        wal.append(&event(9, "done")).unwrap();
        assert_eq!(wal.records(), 1);
        assert_eq!(wal.last_seq(), Some(9));
        drop(wal);
        let (_, scan) = Wal::open(&path, 8).unwrap();
        assert_eq!(scan.events.len(), 1);
        assert_eq!(scan.events[0].seq, 9);
        let _ = std::fs::remove_dir_all(path.parent().unwrap());
    }

    #[test]
    fn checksum_is_stable() {
        // FNV-1a reference vectors.
        assert_eq!(checksum(b""), 0x811c_9dc5);
        assert_eq!(checksum(b"a"), 0xe40c_292c);
        assert_eq!(checksum(b"foobar"), 0xbf9c_f968);
    }
}
