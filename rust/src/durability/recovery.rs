//! Startup recovery: newest valid snapshot + WAL-tail replay.
//!
//! The snapshot (`persist::load` + [`SnapshotMeta`]) restores the
//! world as of `last_seq`; everything the platform did after that
//! lives only in the WAL. Replay pushes each logged event through
//! the *same* consumer paths the live platform uses — the usage
//! accountant's `observe`, session-record state transitions, metric
//! logging with the engine's best-metric rule, and leaderboard
//! submission on completion — so a recovered platform is
//! indistinguishable from one that never crashed.
//!
//! Replay is seq-gated (`seq > last_seq` only) and therefore
//! idempotent: a crash between writing the snapshot metadata and
//! rotating the WAL merely makes replay skip the subsumed prefix.
//!
//! Checkpoints saved after the snapshot are missing from the
//! persisted index, but their metadata records live in the object
//! store by design ("a fresh process could rebuild the index") —
//! [`rebuild_checkpoint_index`] scans for them.
//!
//! [`SnapshotMeta`]: super::SnapshotMeta

use crate::events::{Event, EventKind};
use crate::leaderboard::{Leaderboard, Submission};
use crate::session::{SessionState, SessionStore};
use crate::storage::{CheckpointStore, ObjectStore};
use crate::tenancy::UsageAccountant;
use std::collections::BTreeSet;

/// Checkpoint metadata records are small JSON blobs; anything larger
/// is params/dataset payload and not worth a parse attempt.
const MAX_RECORD_PROBE_BYTES: u64 = 16 * 1024;

/// What one replay pass did (surfaced in logs and tests).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ReplayStats {
    /// WAL events applied (past the seq gate).
    pub applied: u64,
    /// Events skipped because the snapshot already covered them.
    pub skipped: u64,
    /// `done` transitions that produced a leaderboard submission.
    pub completions: u64,
}

/// Replay `events` on top of snapshot state. `last_seq` is the
/// snapshot's coverage bound (`None` = no snapshot, replay all).
/// `resolve_metric` maps a model name to its manifest's
/// `(metric_name, lower_is_better)` — the same rule `run_eval` uses
/// to maintain `best_metric` live.
pub fn replay(
    events: &[Event],
    last_seq: Option<u64>,
    sessions: &SessionStore,
    leaderboard: &Leaderboard,
    accountant: &UsageAccountant,
    endpoints: &crate::serving::EndpointRegistry,
    resolve_metric: &dyn Fn(&str) -> Option<(String, bool)>,
) -> ReplayStats {
    let mut stats = ReplayStats::default();
    for e in events {
        if let Some(bound) = last_seq {
            if e.seq <= bound {
                stats.skipped += 1;
                continue;
            }
        }
        stats.applied += 1;
        accountant.observe(e);
        match &e.kind {
            EventKind::StateChanged { to, step, .. } => {
                sessions.update(&e.subject, |r| {
                    if let Some(state) = SessionState::from_str(to) {
                        r.state = state;
                        if state.is_terminal() {
                            r.finished_at_ms = Some(e.at_ms);
                        }
                    }
                    r.steps_done = r.steps_done.max(*step);
                });
                if to == "done"
                    && submit_completed(&e.subject, e.at_ms, sessions, leaderboard, resolve_metric)
                {
                    stats.completions += 1;
                }
            }
            EventKind::MetricReported { name, step, value } => {
                sessions.update(&e.subject, |r| {
                    r.metrics.log(*step, name, *value);
                    // Mirror run_eval's best-metric rule exactly: only
                    // the manifest's task metric moves `best_metric`.
                    if let Some((metric_name, lower)) = resolve_metric(&r.spec.model) {
                        if *name == metric_name {
                            let better = match r.best_metric {
                                None => true,
                                Some(b) => {
                                    if lower {
                                        *value < b
                                    } else {
                                        *value > b
                                    }
                                }
                            };
                            if better {
                                r.best_metric = Some(*value);
                            }
                        }
                    }
                });
            }
            // Endpoint mutations carry everything the registry needs
            // (the event is the registry's WAL record).
            EventKind::EndpointChanged { action, session, model, step, object, .. } => {
                let _ = endpoints.apply_event(
                    &e.subject,
                    action,
                    session,
                    model,
                    *step,
                    object,
                    e.at_ms,
                );
            }
            // The checkpoint index is rebuilt from the object store
            // (the event only carries the params address), and
            // admission decisions are informational.
            EventKind::CheckpointSaved { .. } | EventKind::AdmissionDecided { .. } => {}
            _ => {}
        }
    }
    stats
}

/// Resubmit a completed session to its dataset's board — the replay
/// twin of the facade's consumer-pump completion path. Idempotent:
/// the leaderboard keeps the best entry per session.
fn submit_completed(
    id: &str,
    at_ms: u64,
    sessions: &SessionStore,
    leaderboard: &Leaderboard,
    resolve_metric: &dyn Fn(&str) -> Option<(String, bool)>,
) -> bool {
    let Some(rec) = sessions.get(id) else { return false };
    let Some(best) = rec.best_metric else { return false };
    let Some((metric_name, lower)) = resolve_metric(&rec.spec.model) else { return false };
    leaderboard.ensure_board(&rec.spec.dataset, &metric_name, lower);
    leaderboard.submit(
        &rec.spec.dataset,
        Submission {
            session: rec.spec.id.clone(),
            user: rec.spec.user.clone(),
            model: rec.spec.model.clone(),
            metric_name,
            value: best,
            step: rec.steps_done,
            at_ms,
        },
    );
    true
}

/// Re-index checkpoints whose metadata records are in the object
/// store but not in the (snapshot-restored) index — i.e. checkpoints
/// saved after the last snapshot. Probes every small object; a
/// record only counts if it parses and its params object exists.
/// Returns how many checkpoints were restored.
pub fn rebuild_checkpoint_index(store: &ObjectStore, ckpts: &CheckpointStore) -> usize {
    let mut seen: BTreeSet<(String, u64, String)> = ckpts
        .dump()
        .iter()
        .map(|c| (c.session.clone(), c.step, c.params.0.clone()))
        .collect();
    let mut restored = 0;
    for id in store.list() {
        match store.size_of(&id) {
            Some(size) if size <= MAX_RECORD_PROBE_BYTES => {}
            _ => continue,
        }
        let Ok(bytes) = store.get(&id) else { continue };
        let Ok(ck) = CheckpointStore::parse_record(&bytes) else { continue };
        if ck.session.is_empty() || !store.has(&ck.params) {
            continue;
        }
        let key = (ck.session.clone(), ck.step, ck.params.0.clone());
        if seen.insert(key) {
            ckpts.restore(ck);
            restored += 1;
        }
    }
    restored
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::events::Level;
    use crate::session::{SessionRecord, SessionSpec};
    use std::collections::BTreeMap;

    fn ev(seq: u64, at_ms: u64, subject: &str, kind: EventKind) -> Event {
        Event {
            seq,
            at_ms,
            level: Level::Info,
            source: "session".into(),
            subject: subject.into(),
            kind,
        }
    }

    fn state(seq: u64, at_ms: u64, subject: &str, to: &str, step: u64) -> Event {
        ev(seq, at_ms, subject, EventKind::StateChanged {
            from: "x".into(),
            to: to.into(),
            step,
        })
    }

    fn metric(seq: u64, subject: &str, name: &str, step: u64, value: f64) -> Event {
        ev(seq, step * 10, subject, EventKind::MetricReported {
            name: name.into(),
            step,
            value,
        })
    }

    fn resolve(model: &str) -> Option<(String, bool)> {
        (model == "mnist_mlp").then(|| ("accuracy".to_string(), false))
    }

    #[test]
    fn replay_rebuilds_state_metrics_board_and_usage() {
        let sessions = SessionStore::new();
        sessions.insert(SessionRecord::new(
            SessionSpec::new("kim/mnist/1", "kim", "mnist", "mnist_mlp"),
            0,
        ));
        let lb = Leaderboard::new();
        let acc = UsageAccountant::new();
        acc.register("kim/mnist/1", "kim", 2);

        let events = vec![
            state(1, 100, "kim/mnist/1", "running", 0),
            metric(2, "kim/mnist/1", "eval_loss", 25, 0.9),
            metric(3, "kim/mnist/1", "accuracy", 25, 0.70),
            metric(4, "kim/mnist/1", "accuracy", 50, 0.85),
            metric(5, "kim/mnist/1", "accuracy", 75, 0.80), // worse: best stays
            state(6, 3_100, "kim/mnist/1", "done", 100),
        ];
        let eps = crate::serving::EndpointRegistry::new();
        let stats = replay(&events, None, &sessions, &lb, &acc, &eps, &resolve);
        assert_eq!(stats.applied, 6);
        assert_eq!(stats.skipped, 0);
        assert_eq!(stats.completions, 1);

        let r = sessions.get("kim/mnist/1").unwrap();
        assert_eq!(r.state, SessionState::Done);
        assert_eq!(r.steps_done, 100);
        assert_eq!(r.best_metric, Some(0.85));
        assert_eq!(r.finished_at_ms, Some(3_100));
        assert_eq!(r.metrics.series("accuracy").len(), 3);
        assert_eq!(r.metrics.series("eval_loss").len(), 1);
        // eval_loss is not the task metric; it never moves best_metric.
        let best = lb.best("mnist").unwrap();
        assert_eq!(best.session, "kim/mnist/1");
        assert_eq!(best.value, 0.85);
        // 2 GPUs for 3 virtual seconds.
        assert!((acc.usage_at("kim", 99_999) - 6.0).abs() < 1e-9);
    }

    #[test]
    fn seq_gate_skips_snapshot_covered_events() {
        let sessions = SessionStore::new();
        sessions.insert(SessionRecord::new(
            SessionSpec::new("kim/mnist/1", "kim", "mnist", "mnist_mlp"),
            0,
        ));
        let lb = Leaderboard::new();
        let acc = UsageAccountant::new();
        let events = vec![
            metric(3, "kim/mnist/1", "accuracy", 25, 0.70),
            metric(7, "kim/mnist/1", "accuracy", 50, 0.90),
        ];
        let eps = crate::serving::EndpointRegistry::new();
        let stats = replay(&events, Some(5), &sessions, &lb, &acc, &eps, &resolve);
        assert_eq!(stats.skipped, 1);
        assert_eq!(stats.applied, 1);
        let r = sessions.get("kim/mnist/1").unwrap();
        assert_eq!(r.metrics.series("accuracy").len(), 1, "covered event not re-applied");
        assert_eq!(r.best_metric, Some(0.90));
        // Replaying the same tail again changes nothing structural:
        // metrics dedup is the caller's concern (the facade replays
        // once per process start), but best/board stay idempotent.
        replay(&events, Some(5), &sessions, &lb, &acc, &eps, &resolve);
        assert_eq!(sessions.get("kim/mnist/1").unwrap().best_metric, Some(0.90));
    }

    #[test]
    fn events_for_unknown_sessions_are_ignored() {
        let sessions = SessionStore::new();
        let lb = Leaderboard::new();
        let acc = UsageAccountant::new();
        let events = vec![
            state(1, 0, "ghost/x/1", "running", 0),
            state(2, 1_000, "ghost/x/1", "done", 50),
        ];
        let eps = crate::serving::EndpointRegistry::new();
        let stats = replay(&events, None, &sessions, &lb, &acc, &eps, &resolve);
        assert_eq!(stats.applied, 2);
        assert_eq!(stats.completions, 0);
        assert!(sessions.is_empty());
    }

    #[test]
    fn rebuild_index_finds_post_snapshot_checkpoints() {
        let store = ObjectStore::memory();
        let ckpts = CheckpointStore::new(store.clone());
        let mut hp = BTreeMap::new();
        hp.insert("lr".to_string(), 0.1);
        ckpts.save("kim/mnist/1", 50, 0.4, &hp, b"params-50", 1_000).unwrap();
        ckpts.save("kim/mnist/1", 75, 0.3, &hp, b"params-75", 2_000).unwrap();
        // Junk objects must not confuse the probe.
        store.put(b"not json at all").unwrap();
        store.put(b"{\"some\": \"other json\"}").unwrap();

        // A fresh process: empty index, same object store.
        let fresh = CheckpointStore::new(store.clone());
        assert_eq!(rebuild_checkpoint_index(&store, &fresh), 2);
        assert_eq!(fresh.list("kim/mnist/1").len(), 2);
        assert_eq!(fresh.latest("kim/mnist/1").unwrap().step, 75);
        assert_eq!(fresh.load_params(&fresh.latest("kim/mnist/1").unwrap()).unwrap(), b"params-75");
        // Idempotent: nothing new on a second pass.
        assert_eq!(rebuild_checkpoint_index(&store, &fresh), 0);
        assert_eq!(fresh.list("kim/mnist/1").len(), 2);
    }
}
