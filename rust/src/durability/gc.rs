//! Object-store garbage collection: mark live, sweep the rest.
//!
//! The object store is content-addressed and append-only in normal
//! operation, so orphans accumulate: params of checkpoints whose
//! index entries were superseded, aborted uploads, datasets re-posted
//! with different contents. The mark pass walks every *reachable*
//! object — checkpoint params + metadata records for every indexed
//! checkpoint (a live session's whole checkpoint chain is indexed,
//! so nothing a resume could need is ever swept), every dataset
//! manifest object regardless of visibility, and code bundles (zip
//! archives are the reproducibility record of `nsml run`). The sweep
//! deletes everything else.
//!
//! As a side effect the mark pass attributes each user's checkpoint
//! bytes (params + records, deduped per user) to
//! [`TenantRegistry::set_storage_bytes`], so storage joins
//! GPU-seconds in the per-tenant accounting.

use crate::storage::{CheckpointStore, DatasetRegistry, ObjectId, ObjectStore};
use crate::tenancy::TenantRegistry;
use std::collections::{BTreeMap, BTreeSet};

/// What one sweep did.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct GcReport {
    pub live_objects: u64,
    pub live_bytes: u64,
    pub swept_objects: u64,
    pub swept_bytes: u64,
    /// Checkpoint bytes attributed per user (also written to the
    /// tenant registry).
    pub per_user_bytes: Vec<(String, u64)>,
}

/// Mark-and-sweep over `store`. `owner_of` maps a session id to its
/// owning user (the facade passes a session-store lookup); `pinned`
/// is extra roots the caller must keep — the facade passes every
/// params object referenced by a live serving endpoint's version
/// history, so a promoted (or rolled-back-to) checkpoint is never
/// swept even if its index entry vanished.
pub fn sweep(
    store: &ObjectStore,
    ckpts: &CheckpointStore,
    datasets: &DatasetRegistry,
    owner_of: &dyn Fn(&str) -> Option<String>,
    registry: &TenantRegistry,
    pinned: &[ObjectId],
) -> GcReport {
    // Mark: dataset manifests (private ones too) + caller pins.
    let mut live: BTreeSet<ObjectId> = datasets.all_object_ids().into_iter().collect();
    live.extend(pinned.iter().cloned());
    // Mark: every indexed checkpoint's params + metadata record, and
    // attribute their bytes to the session's owner.
    let mut per_user: BTreeMap<String, BTreeSet<ObjectId>> = BTreeMap::new();
    for ck in ckpts.dump() {
        let record_id = ObjectId::of(&CheckpointStore::record_bytes(&ck));
        live.insert(ck.params.clone());
        live.insert(record_id.clone());
        if let Some(user) = owner_of(&ck.session) {
            let set = per_user.entry(user).or_default();
            set.insert(ck.params.clone());
            set.insert(record_id);
        }
    }
    // Mark: code bundles. They are zip archives (see storage::codepack)
    // and nothing else in the store is, so the magic header is a
    // reliable liveness proof for the reproducibility record.
    let all = store.list();
    for id in &all {
        if live.contains(id) {
            continue;
        }
        if let Ok(bytes) = store.get(id) {
            if bytes.starts_with(b"PK") {
                live.insert(id.clone());
            }
        }
    }

    // Sweep everything unmarked; tally the survivors.
    let mut report = GcReport::default();
    for id in &all {
        let size = store.size_of(id).unwrap_or(0);
        if live.contains(id) {
            report.live_objects += 1;
            report.live_bytes += size;
        } else if store.delete(id) {
            report.swept_objects += 1;
            report.swept_bytes += size;
        }
    }

    // Per-tenant storage accounting (absolute overwrite — idempotent).
    for (user, ids) in &per_user {
        let bytes: u64 = ids.iter().filter_map(|id| store.size_of(id)).sum();
        registry.set_storage_bytes(user, bytes);
        report.per_user_bytes.push((user.clone(), bytes));
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::codepack;
    use crate::tenancy::TenantQuota;
    use std::collections::BTreeMap;

    fn owner(session: &str) -> Option<String> {
        session.split('/').next().map(str::to_string)
    }

    #[test]
    fn sweep_keeps_chains_datasets_codepacks_and_drops_junk() {
        let store = ObjectStore::memory();
        let ckpts = CheckpointStore::new(store.clone());
        let datasets = DatasetRegistry::new(store.clone());
        let registry = TenantRegistry::new(TenantQuota::default());

        // A live session's full checkpoint chain (two checkpoints).
        let mut hp = BTreeMap::new();
        hp.insert("lr".to_string(), 0.1);
        let ck1 = ckpts.save("kim/mnist/1", 50, 0.4, &hp, b"params-at-50", 1_000).unwrap();
        let ck2 = ckpts.save("kim/mnist/1", 75, 0.3, &hp, b"params-at-75", 2_000).unwrap();
        // A dataset (private: the mark pass must still see it).
        datasets.push("secret", "lee", false, &[("f.bin", b"dataset bytes")], 0.1, "").unwrap();
        // A code bundle.
        let code =
            codepack::store_codepack(&store, &[("main.py", b"print('hi')".as_slice())]).unwrap();
        // Unreferenced junk: an aborted upload.
        let junk = store.put(b"orphaned upload bytes").unwrap();

        let before = store.usage().0;
        let report = sweep(&store, &ckpts, &datasets, &owner, &registry, &[]);
        assert_eq!(report.swept_objects, 1);
        assert_eq!(report.swept_bytes, b"orphaned upload bytes".len() as u64);
        assert_eq!(report.live_objects as usize, before - 1);
        assert!(!store.has(&junk));
        // The full chain survives — params and records of BOTH
        // checkpoints, not just the latest.
        assert!(store.has(&ck1.params));
        assert!(store.has(&ck2.params));
        assert!(store.has(&ObjectId::of(&CheckpointStore::record_bytes(&ck1))));
        assert!(store.has(&ObjectId::of(&CheckpointStore::record_bytes(&ck2))));
        assert!(store.has(&code));
        assert_eq!(datasets.read_file("secret", "lee", "f.bin").unwrap(), b"dataset bytes");
        // Checkpoints still load after the sweep.
        assert_eq!(ckpts.load_params(&ckpts.latest("kim/mnist/1").unwrap()).unwrap(), b"params-at-75");

        // Per-tenant storage accounting landed in the registry.
        assert!(registry.storage_bytes_of("kim") > 0);
        assert_eq!(registry.storage_bytes_of("lee"), 0, "datasets are not charged (yet)");
        let kim = report
            .per_user_bytes
            .iter()
            .find(|(u, _)| u == "kim")
            .map(|(_, b)| *b)
            .unwrap();
        assert_eq!(kim, registry.storage_bytes_of("kim"));

        // Idempotent: a second sweep finds nothing to delete.
        let again = sweep(&store, &ckpts, &datasets, &owner, &registry, &[]);
        assert_eq!(again.swept_objects, 0);
        assert_eq!(again.live_objects, report.live_objects);
    }

    #[test]
    fn pinned_objects_survive_even_unindexed() {
        let store = ObjectStore::memory();
        let ckpts = CheckpointStore::new(store.clone());
        let datasets = DatasetRegistry::new(store.clone());
        let registry = TenantRegistry::new(TenantQuota::default());
        // An object nothing indexes — only the caller's pin roots it
        // (the endpoint-registry case).
        let pinned = store.put(b"endpoint params").unwrap();
        let junk = store.put(b"junk").unwrap();
        let report = sweep(&store, &ckpts, &datasets, &owner, &registry, &[pinned.clone()]);
        assert_eq!(report.swept_objects, 1);
        assert!(store.has(&pinned));
        assert!(!store.has(&junk));
    }

    #[test]
    fn empty_store_sweeps_nothing() {
        let store = ObjectStore::memory();
        let ckpts = CheckpointStore::new(store.clone());
        let datasets = DatasetRegistry::new(store.clone());
        let registry = TenantRegistry::new(TenantQuota::default());
        let report = sweep(&store, &ckpts, &datasets, &owner, &registry, &[]);
        assert_eq!(report, GcReport::default());
    }
}
