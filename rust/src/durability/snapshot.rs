//! Snapshot metadata: which WAL prefix a compacted snapshot subsumes.
//!
//! The snapshot itself is the existing `persist::save` world dump
//! (sessions, leaderboard, checkpoint index, quota overrides) — this
//! module records what the dump *covers*: the highest bus sequence
//! number whose effects it contains, so recovery replays only WAL
//! records with `seq > last_seq`, and the usage-accounting ledger
//! (closed per-user GPU-second totals plus still-open intervals),
//! which lives nowhere else once the pre-snapshot WAL segment
//! rotates away.
//!
//! Written via temp file + atomic rename: a crash leaves either the
//! old metadata or the new, never a torn file. A crash *between* the
//! metadata write and the WAL rotation is also safe — the stale
//! segment's records all carry `seq <= last_seq` and replay skips
//! them (replay is seq-gated, hence idempotent).

use crate::util::json::{parse, Json};
use anyhow::{anyhow, Result};
use std::path::Path;

/// File name under the durability directory.
pub const META_FILE: &str = "snapshot.json";

/// See the module docs.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct SnapshotMeta {
    /// Highest bus sequence number the snapshot's world dump covers.
    pub last_seq: u64,
    /// Virtual time of the snapshot.
    pub at_ms: u64,
    /// Per-user closed GPU-second totals at snapshot time.
    pub closed_usage: Vec<(String, f64)>,
    /// Open `(session, running-since-ms)` intervals at snapshot time.
    pub open_usage: Vec<(String, u64)>,
}

impl SnapshotMeta {
    /// Write atomically under `dir`.
    pub fn save(&self, dir: &Path) -> Result<()> {
        std::fs::create_dir_all(dir)?;
        let mut doc = Json::obj();
        doc.set("format", 1u64.into())
            .set("last_seq", self.last_seq.into())
            .set("at_ms", self.at_ms.into());
        let closed: Vec<Json> = self
            .closed_usage
            .iter()
            .map(|(user, secs)| {
                let mut o = Json::obj();
                o.set("user", user.as_str().into()).set("gpu_seconds", (*secs).into());
                o
            })
            .collect();
        doc.set("closed_usage", Json::Arr(closed));
        let open: Vec<Json> = self
            .open_usage
            .iter()
            .map(|(session, since)| {
                let mut o = Json::obj();
                o.set("session", session.as_str().into()).set("since_ms", (*since).into());
                o
            })
            .collect();
        doc.set("open_usage", Json::Arr(open));
        let tmp = dir.join(format!("{}.tmp", META_FILE));
        std::fs::write(&tmp, doc.to_pretty())?;
        std::fs::rename(&tmp, dir.join(META_FILE))?;
        Ok(())
    }

    /// Load from `dir`; `None` when no snapshot has been taken yet.
    pub fn load(dir: &Path) -> Result<Option<SnapshotMeta>> {
        let path = dir.join(META_FILE);
        if !path.exists() {
            return Ok(None);
        }
        let text = std::fs::read_to_string(&path)?;
        let doc = parse(&text).map_err(|e| anyhow!("{}: {}", META_FILE, e))?;
        let u64_of = |k: &str| doc.get(k).and_then(Json::as_i64).unwrap_or(0) as u64;
        let mut meta = SnapshotMeta {
            last_seq: u64_of("last_seq"),
            at_ms: u64_of("at_ms"),
            closed_usage: Vec::new(),
            open_usage: Vec::new(),
        };
        if let Some(arr) = doc.get("closed_usage").and_then(Json::as_arr) {
            for o in arr {
                let Some(user) = o.get("user").and_then(Json::as_str) else { continue };
                let secs = o.get("gpu_seconds").and_then(Json::as_f64).unwrap_or(0.0);
                meta.closed_usage.push((user.to_string(), secs));
            }
        }
        if let Some(arr) = doc.get("open_usage").and_then(Json::as_arr) {
            for o in arr {
                let Some(session) = o.get("session").and_then(Json::as_str) else { continue };
                let since = o.get("since_ms").and_then(Json::as_i64).unwrap_or(0) as u64;
                meta.open_usage.push((session.to_string(), since));
            }
        }
        Ok(Some(meta))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn tmp(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("nsml-snapmeta-{}-{}", tag, std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn round_trips_and_missing_is_none() {
        let dir = tmp("roundtrip");
        assert_eq!(SnapshotMeta::load(&dir).unwrap(), None);
        let meta = SnapshotMeta {
            last_seq: 4242,
            at_ms: 99_000,
            closed_usage: vec![("kim".into(), 12.5), ("lee".into(), 0.25)],
            open_usage: vec![("kim/mnist/1".into(), 88_000)],
        };
        meta.save(&dir).unwrap();
        assert_eq!(SnapshotMeta::load(&dir).unwrap(), Some(meta.clone()));
        // Overwrite wins (atomic rename, no append).
        let newer = SnapshotMeta { last_seq: 9000, ..meta };
        newer.save(&dir).unwrap();
        assert_eq!(SnapshotMeta::load(&dir).unwrap().unwrap().last_seq, 9000);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn malformed_meta_is_an_error() {
        let dir = tmp("malformed");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join(META_FILE), b"{ nope").unwrap();
        assert!(SnapshotMeta::load(&dir).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
