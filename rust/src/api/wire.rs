//! The v1 wire format: every platform verb as serializable data.
//!
//! [`ApiRequest`] / [`ApiResponse`] are the exhaustive command/query
//! vocabulary of the platform. Both round-trip losslessly through
//! `util::json` (`to_json` / `from_json`), so any client that can speak
//! JSON — the CLI, the web UI's `POST /api/v1/*` routes, a notebook, a
//! remote automl driver — drives the platform through the exact same
//! surface. Failures travel as a uniform [`ApiError`] envelope instead of
//! ad-hoc strings.
//!
//! Envelope shapes (all versioned with [`API_VERSION`]):
//!
//! ```json
//! {"v":1,"verb":"resume","args":{"session":"kim/mnist/1","lr":0.05}}
//! {"v":1,"kind":"ack","data":{"verb":"resume","session":"kim/mnist/1"}}
//! {"v":1,"kind":"error","data":{"error":{"code":"not_found","message":"…"}}}
//! ```

use crate::events::Event;
use crate::session::{SessionRecord, SessionState};
use crate::util::json::Json;
use std::fmt;

/// Wire protocol version; bump on breaking envelope changes.
pub const API_VERSION: u64 = 1;

/// Largest `events_since` page a wire client may request. One page is
/// cloned out of the bus ring under its lock, so this bounds both the
/// response size and the publisher stall.
pub const MAX_EVENT_PAGE: u64 = 10_000;

/// Every request verb, in the order of the [`ApiRequest`] variants.
pub const ALL_VERBS: &[&str] = &[
    "run",
    "pause",
    "resume",
    "stop",
    "infer",
    "drive",
    "run_to_completion",
    "kill_node",
    "list_sessions",
    "get_session",
    "board",
    "cluster_status",
    "executor_status",
    "events_since",
    "submit_trial_batch",
    "tenant_report",
    "set_quota",
    "durability_status",
    "service_status",
    "promote",
    "endpoints",
    "serve_infer",
    "metrics_report",
    "trace",
];

/// Every response kind, in the order of the [`ApiResponse`] variants.
pub const ALL_KINDS: &[&str] = &[
    "submitted",
    "batch_submitted",
    "ack",
    "progressed",
    "probs",
    "sessions",
    "session",
    "board",
    "cluster",
    "executor",
    "events",
    "tenants",
    "durability",
    "service",
    "endpoint",
    "endpoints",
    "served",
    "metrics",
    "trace",
    "error",
];

// ---------------------------------------------------------------------
// Error envelope
// ---------------------------------------------------------------------

/// Coarse error class, mapped to HTTP status by the web layer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrorCode {
    /// The addressed session/dataset/node does not exist.
    NotFound,
    /// The request itself is malformed or names an unknown verb/dataset.
    InvalidArgument,
    /// The request is well-formed but the target is in the wrong state
    /// (e.g. pausing a session that is not active).
    FailedPrecondition,
    /// The platform failed while executing a valid request.
    Internal,
    /// The HTTP path does not name any API route (web layer only —
    /// dispatch never produces it, but clients see it in the same
    /// uniform envelope instead of a bare 404 body).
    UnknownRoute,
}

impl ErrorCode {
    pub fn as_str(&self) -> &'static str {
        match self {
            ErrorCode::NotFound => "not_found",
            ErrorCode::InvalidArgument => "invalid_argument",
            ErrorCode::FailedPrecondition => "failed_precondition",
            ErrorCode::Internal => "internal",
            ErrorCode::UnknownRoute => "unknown_route",
        }
    }

    #[allow(clippy::should_implement_trait)]
    pub fn from_str(s: &str) -> Option<ErrorCode> {
        match s {
            "not_found" => Some(ErrorCode::NotFound),
            "invalid_argument" => Some(ErrorCode::InvalidArgument),
            "failed_precondition" => Some(ErrorCode::FailedPrecondition),
            "internal" => Some(ErrorCode::Internal),
            "unknown_route" => Some(ErrorCode::UnknownRoute),
            _ => None,
        }
    }
}

/// The uniform error envelope carried by [`ApiResponse::Error`].
#[derive(Debug, Clone, PartialEq)]
pub struct ApiError {
    pub code: ErrorCode,
    pub message: String,
    /// The session the error is about, when there is one.
    pub session: Option<String>,
}

impl ApiError {
    pub fn not_found(message: impl Into<String>) -> ApiError {
        ApiError { code: ErrorCode::NotFound, message: message.into(), session: None }
    }

    pub fn invalid(message: impl Into<String>) -> ApiError {
        ApiError { code: ErrorCode::InvalidArgument, message: message.into(), session: None }
    }

    pub fn failed(message: impl Into<String>) -> ApiError {
        ApiError { code: ErrorCode::FailedPrecondition, message: message.into(), session: None }
    }

    pub fn internal(message: impl Into<String>) -> ApiError {
        ApiError { code: ErrorCode::Internal, message: message.into(), session: None }
    }

    pub fn unknown_route(message: impl Into<String>) -> ApiError {
        ApiError { code: ErrorCode::UnknownRoute, message: message.into(), session: None }
    }

    pub fn with_session(mut self, id: &str) -> ApiError {
        self.session = Some(id.to_string());
        self
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("code", self.code.as_str().into()).set("message", self.message.as_str().into());
        if let Some(s) = &self.session {
            o.set("session", s.as_str().into());
        }
        o
    }

    pub fn from_json(j: &Json) -> Result<ApiError, ApiError> {
        let code = need_str(j, "code")?;
        Ok(ApiError {
            code: ErrorCode::from_str(&code)
                .ok_or_else(|| ApiError::invalid(format!("unknown error code '{}'", code)))?,
            message: need_str(j, "message")?,
            session: opt_str(j, "session")?,
        })
    }
}

impl fmt::Display for ApiError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match &self.session {
            Some(s) => write!(f, "[{}] {} (session {})", self.code.as_str(), self.message, s),
            None => write!(f, "[{}] {}", self.code.as_str(), self.message),
        }
    }
}

impl std::error::Error for ApiError {}

// ---------------------------------------------------------------------
// Requests
// ---------------------------------------------------------------------

/// The `nsml run` arguments on the wire (mirror of `RunOpts` + identity).
#[derive(Debug, Clone, PartialEq)]
pub struct RunParams {
    pub user: String,
    pub dataset: String,
    pub gpus: usize,
    pub total_steps: u64,
    pub lr: Option<f64>,
    pub seed: u64,
    pub use_scan: bool,
    /// Priority name (`low` | `normal` | `high`).
    pub priority: String,
    pub checkpoint_every: u64,
    pub eval_every: u64,
}

impl RunParams {
    pub fn new(user: &str, dataset: &str) -> RunParams {
        let d = super::RunOpts::default();
        RunParams {
            user: user.to_string(),
            dataset: dataset.to_string(),
            gpus: d.gpus,
            total_steps: d.total_steps,
            lr: d.lr,
            seed: d.seed,
            use_scan: d.use_scan,
            priority: d.priority.as_str().to_string(),
            checkpoint_every: d.checkpoint_every,
            eval_every: d.eval_every,
        }
    }

    /// Convert to the facade's typed options.
    pub fn run_opts(&self) -> super::RunOpts {
        super::RunOpts {
            gpus: self.gpus,
            total_steps: self.total_steps,
            lr: self.lr,
            seed: self.seed,
            use_scan: self.use_scan,
            priority: crate::scheduler::Priority::from_str(&self.priority),
            checkpoint_every: self.checkpoint_every,
            eval_every: self.eval_every,
        }
    }

    fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("user", self.user.as_str().into())
            .set("dataset", self.dataset.as_str().into())
            .set("gpus", self.gpus.into())
            .set("total_steps", self.total_steps.into())
            .set("lr", self.lr.map(Json::Num).unwrap_or(Json::Null))
            .set("seed", self.seed.into())
            .set("use_scan", self.use_scan.into())
            .set("priority", self.priority.as_str().into())
            .set("checkpoint_every", self.checkpoint_every.into())
            .set("eval_every", self.eval_every.into());
        o
    }

    fn from_json(j: &Json) -> Result<RunParams, ApiError> {
        let mut p = RunParams::new(&need_str(j, "user")?, &need_str(j, "dataset")?);
        if let Some(v) = opt_u64(j, "gpus")? {
            p.gpus = v as usize;
        }
        if let Some(v) = opt_u64(j, "total_steps")? {
            p.total_steps = v;
        }
        p.lr = opt_f64(j, "lr")?;
        if let Some(v) = opt_u64(j, "seed")? {
            p.seed = v;
        }
        if let Some(v) = opt_bool(j, "use_scan")? {
            p.use_scan = v;
        }
        if let Some(v) = opt_str(j, "priority")? {
            p.priority = v;
        }
        if let Some(v) = opt_u64(j, "checkpoint_every")? {
            p.checkpoint_every = v;
        }
        if let Some(v) = opt_u64(j, "eval_every")? {
            p.eval_every = v;
        }
        Ok(p)
    }
}

/// One hyperparameter trial inside a [`ApiRequest::SubmitTrialBatch`].
#[derive(Debug, Clone, PartialEq)]
pub struct TrialSpec {
    pub lr: f64,
    pub seed: u64,
    pub total_steps: u64,
    pub gpus: usize,
}

impl TrialSpec {
    fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("lr", self.lr.into())
            .set("seed", self.seed.into())
            .set("total_steps", self.total_steps.into())
            .set("gpus", self.gpus.into());
        o
    }

    fn from_json(j: &Json) -> Result<TrialSpec, ApiError> {
        Ok(TrialSpec {
            lr: need_f64(j, "lr")?,
            seed: opt_u64(j, "seed")?.unwrap_or(0),
            total_steps: need_u64(j, "total_steps")?,
            gpus: opt_u64(j, "gpus")?.unwrap_or(1) as usize,
        })
    }
}

/// Every command and query the platform accepts — the single API surface
/// shared by CLI, web, examples and benches.
#[derive(Debug, Clone, PartialEq)]
pub enum ApiRequest {
    /// Submit a training session (`nsml run`).
    Run(RunParams),
    /// Pause a running session (checkpoints first).
    Pause { session: String },
    /// Resume a paused session, optionally with a new learning rate.
    Resume { session: String, lr: Option<f64> },
    /// Stop a session outright.
    Stop { session: String },
    /// Run inference against a session's best checkpoint.
    Infer { session: String, x: Vec<f32>, shape: Vec<i64> },
    /// Advance every active session by up to `chunk` steps.
    Drive { chunk: u64 },
    /// Drive until every session is terminal (bounded by `max_rounds`).
    RunToCompletion { chunk: u64, max_rounds: usize },
    /// Inject a node failure (drills); affected sessions auto-recover.
    KillNode { node: u32 },
    /// Session records, newest-submitted last, paged uniformly with the
    /// other list surfaces: skip `offset`, return at most `limit`,
    /// optionally sliced to one `user`'s sessions (the filter applies
    /// before paging). Defaults (`limit` 100, `offset` 0, no user) keep
    /// old bare `list_sessions` envelopes working.
    ListSessions { limit: usize, offset: usize, user: Option<String> },
    /// One session record.
    GetSession { session: String },
    /// Top entries of a dataset's leaderboard, optionally sliced to
    /// one user's rows (ranks stay global, so a filtered row keeps the
    /// rank it holds on the full board).
    Board { dataset: String, limit: usize, user: Option<String> },
    /// Cluster + scheduler snapshot.
    ClusterStatus,
    /// Executor-pool snapshot: per-worker load + steal telemetry.
    ExecutorStatus,
    /// Cursor-paged incremental read of the platform event bus:
    /// events with `seq >= since`, optionally filtered by kind name
    /// and/or subject, at most `limit` per page (`GET /api/v1/events`,
    /// `nsml logs -f`). `limit` is 1..=[`MAX_EVENT_PAGE`] on the wire —
    /// unbounded reads (which would clone the whole ring under its
    /// lock) stay an in-process-only capability.
    EventsSince { since: u64, kind: Option<String>, subject: Option<String>, limit: usize },
    /// Place N hyperparameter trials in one dispatch (automl batching).
    SubmitTrialBatch { user: String, dataset: String, trials: Vec<TrialSpec> },
    /// Per-user fair-share report: quotas, GPU-second usage, occupancy
    /// and admission-queue depth for every known tenant.
    TenantReport,
    /// Edit a user's fair-share quota. Partial update: absent fields
    /// keep their current values; limits use 0 for "unlimited".
    /// Audited mutation.
    SetQuota {
        user: String,
        max_concurrent: Option<u64>,
        max_gpus: Option<u64>,
        gpu_second_budget: Option<f64>,
        weight: Option<u64>,
        /// Priority class name (`low` | `normal` | `high`).
        class: Option<String>,
        /// Max serving requests per sliding second (0 = unlimited).
        max_qps: Option<u64>,
    },
    /// WAL / snapshot / GC counters (`nsml gc --status`,
    /// `GET /api/v1/durability`).
    DurabilityStatus,
    /// Daemon drive-loop telemetry: rounds, last-round duration,
    /// rounds/sec and dispatch counts (`nsml serve`,
    /// `GET /api/v1/service`).
    ServiceStatus,
    /// Manage a named serving endpoint (`nsml promote`). `action` is
    /// `promote` (requires `session`: its best checkpoint becomes the
    /// new active version) | `rollback` | `rollforward` | `retire`.
    /// Audited mutation.
    Promote { endpoint: String, action: String, session: Option<String> },
    /// Every serving endpoint with its version history
    /// (`nsml endpoints`, `GET /api/v1/endpoints`).
    Endpoints,
    /// Micro-batched inference against an endpoint's active version:
    /// `x` is exactly ONE row of the model's inference shape
    /// (`POST /api/v1/endpoints/<name>/infer`). Requests dispatched
    /// concurrently share an engine execution.
    ServeInfer { endpoint: String, user: String, x: Vec<f32> },
    /// Every registered metric series — counters, gauges, and
    /// histograms with windowed p50/p95/p99 (`nsml metrics`,
    /// `GET /api/v1/metrics`; `GET /metrics` renders the same registry
    /// as Prometheus text).
    MetricsReport,
    /// The assembled span timeline of one trace id
    /// (`nsml trace <id>`, `GET /api/v1/trace/<id>`).
    Trace { id: String },
}

impl ApiRequest {
    /// The default `list_sessions` page: first 100 records, every user —
    /// what a bare `{"verb":"list_sessions"}` envelope parses to.
    pub fn list_sessions() -> ApiRequest {
        ApiRequest::ListSessions { limit: 100, offset: 0, user: None }
    }

    pub fn verb(&self) -> &'static str {
        match self {
            ApiRequest::Run(_) => "run",
            ApiRequest::Pause { .. } => "pause",
            ApiRequest::Resume { .. } => "resume",
            ApiRequest::Stop { .. } => "stop",
            ApiRequest::Infer { .. } => "infer",
            ApiRequest::Drive { .. } => "drive",
            ApiRequest::RunToCompletion { .. } => "run_to_completion",
            ApiRequest::KillNode { .. } => "kill_node",
            ApiRequest::ListSessions { .. } => "list_sessions",
            ApiRequest::GetSession { .. } => "get_session",
            ApiRequest::Board { .. } => "board",
            ApiRequest::ClusterStatus => "cluster_status",
            ApiRequest::ExecutorStatus => "executor_status",
            ApiRequest::EventsSince { .. } => "events_since",
            ApiRequest::SubmitTrialBatch { .. } => "submit_trial_batch",
            ApiRequest::TenantReport => "tenant_report",
            ApiRequest::SetQuota { .. } => "set_quota",
            ApiRequest::DurabilityStatus => "durability_status",
            ApiRequest::ServiceStatus => "service_status",
            ApiRequest::Promote { .. } => "promote",
            ApiRequest::Endpoints => "endpoints",
            ApiRequest::ServeInfer { .. } => "serve_infer",
            ApiRequest::MetricsReport => "metrics_report",
            ApiRequest::Trace { .. } => "trace",
        }
    }

    /// True for verbs that change platform state (these are audited).
    pub fn is_mutation(&self) -> bool {
        !matches!(
            self,
            ApiRequest::ListSessions { .. }
                | ApiRequest::GetSession { .. }
                | ApiRequest::Board { .. }
                | ApiRequest::ClusterStatus
                | ApiRequest::ExecutorStatus
                | ApiRequest::EventsSince { .. }
                | ApiRequest::TenantReport
                | ApiRequest::DurabilityStatus
                | ApiRequest::ServiceStatus
                | ApiRequest::Infer { .. }
                | ApiRequest::Endpoints
                | ApiRequest::ServeInfer { .. }
                | ApiRequest::MetricsReport
                | ApiRequest::Trace { .. }
        )
    }

    pub fn to_json(&self) -> Json {
        let mut args = Json::obj();
        match self {
            ApiRequest::Run(p) => {
                args = p.to_json();
            }
            ApiRequest::Pause { session } | ApiRequest::Stop { session } | ApiRequest::GetSession { session } => {
                args.set("session", session.as_str().into());
            }
            ApiRequest::Resume { session, lr } => {
                args.set("session", session.as_str().into())
                    .set("lr", lr.map(Json::Num).unwrap_or(Json::Null));
            }
            ApiRequest::Infer { session, x, shape } => {
                args.set("session", session.as_str().into())
                    .set("x", Json::Arr(x.iter().map(|&v| Json::Num(v as f64)).collect()))
                    .set("shape", Json::Arr(shape.iter().map(|&d| Json::Num(d as f64)).collect()));
            }
            ApiRequest::Drive { chunk } => {
                args.set("chunk", (*chunk).into());
            }
            ApiRequest::RunToCompletion { chunk, max_rounds } => {
                args.set("chunk", (*chunk).into()).set("max_rounds", (*max_rounds).into());
            }
            ApiRequest::KillNode { node } => {
                args.set("node", (*node).into());
            }
            ApiRequest::ListSessions { limit, offset, user } => {
                args.set("limit", (*limit).into())
                    .set("offset", (*offset).into())
                    .set("user", user.as_deref().map(Json::from).unwrap_or(Json::Null));
            }
            ApiRequest::ClusterStatus
            | ApiRequest::ExecutorStatus
            | ApiRequest::TenantReport
            | ApiRequest::DurabilityStatus
            | ApiRequest::ServiceStatus
            | ApiRequest::Endpoints
            | ApiRequest::MetricsReport => {}
            ApiRequest::Trace { id } => {
                args.set("id", id.as_str().into());
            }
            ApiRequest::Promote { endpoint, action, session } => {
                args.set("endpoint", endpoint.as_str().into())
                    .set("action", action.as_str().into())
                    .set("session", session.as_deref().map(Json::from).unwrap_or(Json::Null));
            }
            ApiRequest::ServeInfer { endpoint, user, x } => {
                args.set("endpoint", endpoint.as_str().into())
                    .set("user", user.as_str().into())
                    .set("x", Json::Arr(x.iter().map(|&v| Json::Num(v as f64)).collect()));
            }
            ApiRequest::SetQuota {
                user,
                max_concurrent,
                max_gpus,
                gpu_second_budget,
                weight,
                class,
                max_qps,
            } => {
                args.set("user", user.as_str().into())
                    .set(
                        "max_concurrent",
                        max_concurrent.map(|v| Json::Num(v as f64)).unwrap_or(Json::Null),
                    )
                    .set("max_gpus", max_gpus.map(|v| Json::Num(v as f64)).unwrap_or(Json::Null))
                    .set("gpu_second_budget", gpu_second_budget.map(Json::Num).unwrap_or(Json::Null))
                    .set("weight", weight.map(|v| Json::Num(v as f64)).unwrap_or(Json::Null))
                    .set("class", class.as_deref().map(Json::from).unwrap_or(Json::Null))
                    .set("max_qps", max_qps.map(|v| Json::Num(v as f64)).unwrap_or(Json::Null));
            }
            ApiRequest::EventsSince { since, kind, subject, limit } => {
                args.set("since", (*since).into())
                    .set("kind", kind.as_deref().map(Json::from).unwrap_or(Json::Null))
                    .set("subject", subject.as_deref().map(Json::from).unwrap_or(Json::Null))
                    .set("limit", (*limit).into());
            }
            ApiRequest::Board { dataset, limit, user } => {
                args.set("dataset", dataset.as_str().into())
                    .set("limit", (*limit).into())
                    .set("user", user.as_deref().map(Json::from).unwrap_or(Json::Null));
            }
            ApiRequest::SubmitTrialBatch { user, dataset, trials } => {
                args.set("user", user.as_str().into())
                    .set("dataset", dataset.as_str().into())
                    .set("trials", Json::Arr(trials.iter().map(|t| t.to_json()).collect()));
            }
        }
        envelope("verb", self.verb(), "args", args)
    }

    /// Parse a full request envelope (version + verb + args).
    pub fn from_json(j: &Json) -> Result<ApiRequest, ApiError> {
        check_version(j)?;
        let verb = need_str(j, "verb")?;
        let empty = Json::obj();
        let args = j.get("args").unwrap_or(&empty);
        ApiRequest::from_verb_args(&verb, args)
    }

    /// Build a request from a verb name (e.g. the `POST /api/v1/<verb>`
    /// path) and its argument object.
    pub fn from_verb_args(verb: &str, args: &Json) -> Result<ApiRequest, ApiError> {
        match verb {
            "run" => Ok(ApiRequest::Run(RunParams::from_json(args)?)),
            "pause" => Ok(ApiRequest::Pause { session: need_str(args, "session")? }),
            "resume" => Ok(ApiRequest::Resume {
                session: need_str(args, "session")?,
                lr: opt_f64(args, "lr")?,
            }),
            "stop" => Ok(ApiRequest::Stop { session: need_str(args, "session")? }),
            "infer" => {
                let x = need_arr(args, "x")?
                    .iter()
                    .map(|v| v.as_f64().map(|f| f as f32))
                    .collect::<Option<Vec<f32>>>()
                    .ok_or_else(|| ApiError::invalid("infer: 'x' must be an array of numbers"))?;
                let shape = need_arr(args, "shape")?
                    .iter()
                    .map(|v| v.as_i64())
                    .collect::<Option<Vec<i64>>>()
                    .ok_or_else(|| ApiError::invalid("infer: 'shape' must be an array of integers"))?;
                Ok(ApiRequest::Infer { session: need_str(args, "session")?, x, shape })
            }
            "drive" => Ok(ApiRequest::Drive { chunk: need_u64(args, "chunk")? }),
            "run_to_completion" => Ok(ApiRequest::RunToCompletion {
                chunk: need_u64(args, "chunk")?,
                max_rounds: need_u64(args, "max_rounds")? as usize,
            }),
            "kill_node" => Ok(ApiRequest::KillNode { node: need_u64(args, "node")? as u32 }),
            "list_sessions" => Ok(ApiRequest::ListSessions {
                limit: opt_u64(args, "limit")?.unwrap_or(100) as usize,
                offset: opt_u64(args, "offset")?.unwrap_or(0) as usize,
                user: opt_str(args, "user")?,
            }),
            "get_session" => Ok(ApiRequest::GetSession { session: need_str(args, "session")? }),
            "board" => Ok(ApiRequest::Board {
                dataset: need_str(args, "dataset")?,
                limit: opt_u64(args, "limit")?.unwrap_or(100) as usize,
                user: opt_str(args, "user")?,
            }),
            "cluster_status" => Ok(ApiRequest::ClusterStatus),
            "executor_status" => Ok(ApiRequest::ExecutorStatus),
            "events_since" => {
                let limit = opt_u64(args, "limit")?.unwrap_or(256);
                if limit == 0 || limit > MAX_EVENT_PAGE {
                    return Err(ApiError::invalid(format!(
                        "events_since: 'limit' must be 1..={} (got {})",
                        MAX_EVENT_PAGE, limit
                    )));
                }
                Ok(ApiRequest::EventsSince {
                    since: opt_u64(args, "since")?.unwrap_or(0),
                    kind: opt_str(args, "kind")?,
                    subject: opt_str(args, "subject")?,
                    limit: limit as usize,
                })
            }
            "tenant_report" => Ok(ApiRequest::TenantReport),
            "durability_status" => Ok(ApiRequest::DurabilityStatus),
            "service_status" => Ok(ApiRequest::ServiceStatus),
            "promote" => {
                let action = opt_str(args, "action")?.unwrap_or_else(|| "promote".to_string());
                if !matches!(action.as_str(), "promote" | "rollback" | "rollforward" | "retire") {
                    return Err(ApiError::invalid(format!(
                        "promote: unknown action '{}' (expected promote | rollback | rollforward | retire)",
                        action
                    )));
                }
                let session = opt_str(args, "session")?;
                if action == "promote" && session.is_none() {
                    return Err(ApiError::invalid(
                        "promote: 'session' is required when action is 'promote'",
                    ));
                }
                Ok(ApiRequest::Promote { endpoint: need_str(args, "endpoint")?, action, session })
            }
            "endpoints" => Ok(ApiRequest::Endpoints),
            "metrics_report" => Ok(ApiRequest::MetricsReport),
            "trace" => Ok(ApiRequest::Trace { id: need_str(args, "id")? }),
            "serve_infer" => {
                let x = need_arr(args, "x")?
                    .iter()
                    .map(|v| v.as_f64().map(|f| f as f32))
                    .collect::<Option<Vec<f32>>>()
                    .ok_or_else(|| {
                        ApiError::invalid("serve_infer: 'x' must be an array of numbers")
                    })?;
                Ok(ApiRequest::ServeInfer {
                    endpoint: need_str(args, "endpoint")?,
                    user: need_str(args, "user")?,
                    x,
                })
            }
            "set_quota" => Ok(ApiRequest::SetQuota {
                user: need_str(args, "user")?,
                max_concurrent: opt_u64(args, "max_concurrent")?,
                max_gpus: opt_u64(args, "max_gpus")?,
                gpu_second_budget: opt_f64(args, "gpu_second_budget")?,
                weight: opt_u64(args, "weight")?,
                class: opt_str(args, "class")?,
                max_qps: opt_u64(args, "max_qps")?,
            }),
            "submit_trial_batch" => {
                let trials = need_arr(args, "trials")?
                    .iter()
                    .map(TrialSpec::from_json)
                    .collect::<Result<Vec<TrialSpec>, ApiError>>()?;
                Ok(ApiRequest::SubmitTrialBatch {
                    user: need_str(args, "user")?,
                    dataset: need_str(args, "dataset")?,
                    trials,
                })
            }
            other => Err(ApiError::invalid(format!(
                "unknown verb '{}' (expected one of: {})",
                other,
                ALL_VERBS.join(", ")
            ))),
        }
    }
}

// ---------------------------------------------------------------------
// Response views
// ---------------------------------------------------------------------

/// Serializable session snapshot (no metric series; use the web metrics
/// endpoint or the facade for those).
#[derive(Debug, Clone, PartialEq)]
pub struct SessionView {
    pub id: String,
    pub user: String,
    pub dataset: String,
    pub model: String,
    pub state: SessionState,
    pub node: Option<u32>,
    pub steps_done: u64,
    pub total_steps: u64,
    pub lr: f64,
    pub best_metric: Option<f64>,
    pub recoveries: u32,
    /// Fair-share evictions this session has survived.
    pub preemptions: u32,
}

impl SessionView {
    pub fn from_record(rec: &SessionRecord) -> SessionView {
        SessionView {
            id: rec.spec.id.clone(),
            user: rec.spec.user.clone(),
            dataset: rec.spec.dataset.clone(),
            model: rec.spec.model.clone(),
            state: rec.state,
            node: rec.node.map(|n| n.0),
            steps_done: rec.steps_done,
            total_steps: rec.spec.total_steps,
            lr: rec.spec.lr,
            best_metric: rec.best_metric,
            recoveries: rec.recoveries,
            preemptions: rec.preemptions,
        }
    }

    fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("id", self.id.as_str().into())
            .set("user", self.user.as_str().into())
            .set("dataset", self.dataset.as_str().into())
            .set("model", self.model.as_str().into())
            .set("state", self.state.as_str().into())
            .set("node", self.node.map(|n| Json::from(n)).unwrap_or(Json::Null))
            .set("steps_done", self.steps_done.into())
            .set("total_steps", self.total_steps.into())
            .set("lr", self.lr.into())
            .set("best_metric", self.best_metric.map(Json::Num).unwrap_or(Json::Null))
            .set("recoveries", self.recoveries.into())
            .set("preemptions", self.preemptions.into());
        o
    }

    fn from_json(j: &Json) -> Result<SessionView, ApiError> {
        let state = need_str(j, "state")?;
        Ok(SessionView {
            id: need_str(j, "id")?,
            user: need_str(j, "user")?,
            dataset: need_str(j, "dataset")?,
            model: need_str(j, "model")?,
            state: SessionState::from_str(&state)
                .ok_or_else(|| ApiError::invalid(format!("unknown session state '{}'", state)))?,
            node: opt_u64(j, "node")?.map(|n| n as u32),
            steps_done: need_u64(j, "steps_done")?,
            total_steps: need_u64(j, "total_steps")?,
            lr: need_f64(j, "lr")?,
            best_metric: opt_f64(j, "best_metric")?,
            recoveries: opt_u64(j, "recoveries")?.unwrap_or(0) as u32,
            preemptions: opt_u64(j, "preemptions")?.unwrap_or(0) as u32,
        })
    }
}

/// One leaderboard row.
#[derive(Debug, Clone, PartialEq)]
pub struct BoardRow {
    pub rank: usize,
    pub session: String,
    pub user: String,
    pub model: String,
    pub metric: String,
    pub value: f64,
    pub step: u64,
}

impl BoardRow {
    fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("rank", self.rank.into())
            .set("session", self.session.as_str().into())
            .set("user", self.user.as_str().into())
            .set("model", self.model.as_str().into())
            .set("metric", self.metric.as_str().into())
            .set("value", self.value.into())
            .set("step", self.step.into());
        o
    }

    fn from_json(j: &Json) -> Result<BoardRow, ApiError> {
        Ok(BoardRow {
            rank: need_u64(j, "rank")? as usize,
            session: need_str(j, "session")?,
            user: need_str(j, "user")?,
            model: need_str(j, "model")?,
            metric: need_str(j, "metric")?,
            value: need_f64(j, "value")?,
            step: need_u64(j, "step")?,
        })
    }
}

/// One node in a [`ClusterView`].
#[derive(Debug, Clone, PartialEq)]
pub struct NodeStatusView {
    pub hostname: String,
    pub alive: bool,
    pub total_gpus: usize,
    pub free_gpus: usize,
    pub jobs: Vec<String>,
}

impl NodeStatusView {
    fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("hostname", self.hostname.as_str().into())
            .set("alive", self.alive.into())
            .set("total_gpus", self.total_gpus.into())
            .set("free_gpus", self.free_gpus.into())
            .set("jobs", Json::Arr(self.jobs.iter().map(|s| Json::Str(s.clone())).collect()));
        o
    }

    fn from_json(j: &Json) -> Result<NodeStatusView, ApiError> {
        Ok(NodeStatusView {
            hostname: need_str(j, "hostname")?,
            alive: need_bool(j, "alive")?,
            total_gpus: need_u64(j, "total_gpus")? as usize,
            free_gpus: need_u64(j, "free_gpus")? as usize,
            jobs: need_arr(j, "jobs")?
                .iter()
                .map(|v| v.as_str().map(str::to_string))
                .collect::<Option<Vec<String>>>()
                .ok_or_else(|| ApiError::invalid("node 'jobs' must be strings"))?,
        })
    }
}

/// Cluster + scheduler snapshot.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterView {
    pub nodes: Vec<NodeStatusView>,
    pub total_gpus: usize,
    pub free_gpus: usize,
    pub utilization: f64,
    pub queue_len: usize,
    pub policy: String,
    pub fast_path: bool,
    pub leader: Option<String>,
    pub epoch: u64,
}

impl ClusterView {
    fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("nodes", Json::Arr(self.nodes.iter().map(|n| n.to_json()).collect()))
            .set("total_gpus", self.total_gpus.into())
            .set("free_gpus", self.free_gpus.into())
            .set("utilization", self.utilization.into())
            .set("queue_len", self.queue_len.into())
            .set("policy", self.policy.as_str().into())
            .set("fast_path", self.fast_path.into())
            .set("leader", self.leader.as_deref().map(Json::from).unwrap_or(Json::Null))
            .set("epoch", self.epoch.into());
        o
    }

    fn from_json(j: &Json) -> Result<ClusterView, ApiError> {
        Ok(ClusterView {
            nodes: need_arr(j, "nodes")?
                .iter()
                .map(NodeStatusView::from_json)
                .collect::<Result<Vec<NodeStatusView>, ApiError>>()?,
            total_gpus: need_u64(j, "total_gpus")? as usize,
            free_gpus: need_u64(j, "free_gpus")? as usize,
            utilization: need_f64(j, "utilization")?,
            queue_len: need_u64(j, "queue_len")? as usize,
            policy: need_str(j, "policy")?,
            fast_path: need_bool(j, "fast_path")?,
            leader: opt_str(j, "leader")?,
            epoch: need_u64(j, "epoch")?,
        })
    }
}

/// One executor worker's telemetry row (work-steal observability).
#[derive(Debug, Clone, PartialEq)]
pub struct WorkerStatView {
    pub worker: usize,
    /// Live (materialized) sessions the worker owns.
    pub live_sessions: usize,
    /// Depth of the worker's pending deque.
    pub queue_depth: usize,
    /// Pending sessions stolen from peers since pool start.
    pub steals: u64,
    /// Cumulative wall-clock busy time, in milliseconds.
    pub busy_ms: f64,
}

impl WorkerStatView {
    fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("worker", self.worker.into())
            .set("live_sessions", self.live_sessions.into())
            .set("queue_depth", self.queue_depth.into())
            .set("steals", self.steals.into())
            .set("busy_ms", self.busy_ms.into());
        o
    }

    fn from_json(j: &Json) -> Result<WorkerStatView, ApiError> {
        Ok(WorkerStatView {
            worker: need_u64(j, "worker")? as usize,
            live_sessions: need_u64(j, "live_sessions")? as usize,
            queue_depth: need_u64(j, "queue_depth")? as usize,
            steals: need_u64(j, "steals")?,
            busy_ms: need_f64(j, "busy_ms")?,
        })
    }
}

/// Executor-pool snapshot: per-worker load plus pool-level totals.
#[derive(Debug, Clone, PartialEq)]
pub struct ExecutorStats {
    pub workers: Vec<WorkerStatView>,
    /// Live sessions across all workers.
    pub live_sessions: usize,
    /// Pending (not yet materialized) sessions across all deques.
    pub queue_depth: usize,
    /// Total sessions stolen since pool start.
    pub total_steals: u64,
    /// Whether work stealing is enabled on the pool.
    pub work_steal: bool,
}

impl ExecutorStats {
    fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("workers", Json::Arr(self.workers.iter().map(|w| w.to_json()).collect()))
            .set("live_sessions", self.live_sessions.into())
            .set("queue_depth", self.queue_depth.into())
            .set("total_steals", self.total_steals.into())
            .set("work_steal", self.work_steal.into());
        o
    }

    fn from_json(j: &Json) -> Result<ExecutorStats, ApiError> {
        Ok(ExecutorStats {
            workers: need_arr(j, "workers")?
                .iter()
                .map(WorkerStatView::from_json)
                .collect::<Result<Vec<WorkerStatView>, ApiError>>()?,
            live_sessions: need_u64(j, "live_sessions")? as usize,
            queue_depth: need_u64(j, "queue_depth")? as usize,
            total_steals: need_u64(j, "total_steals")?,
            work_steal: need_bool(j, "work_steal")?,
        })
    }
}

/// One user's fair-share row (`tenant_report`, `GET /api/v1/tenants`,
/// `nsml tenants`). Limits use 0 (or 0.0) for "unlimited".
#[derive(Debug, Clone, PartialEq)]
pub struct TenantView {
    pub user: String,
    /// Stride weight (admissions per round relative to peers).
    pub weight: u32,
    /// Priority class name (`low` | `normal` | `high`).
    pub class: String,
    pub max_concurrent: usize,
    pub max_gpus: usize,
    pub gpu_second_budget: f64,
    /// Accounted GPU-seconds (virtual time), open intervals included.
    pub gpu_seconds_used: f64,
    /// Sessions currently charged against the user (queued-on-master,
    /// preparing, running or paused-with-allocation).
    pub active_sessions: usize,
    pub gpus_in_use: usize,
    /// Submissions waiting in the user's admission lane.
    pub waiting: usize,
    /// Total fair-share evictions across the user's sessions.
    pub preemptions: u64,
}

impl TenantView {
    fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("user", self.user.as_str().into())
            .set("weight", self.weight.into())
            .set("class", self.class.as_str().into())
            .set("max_concurrent", self.max_concurrent.into())
            .set("max_gpus", self.max_gpus.into())
            .set("gpu_second_budget", self.gpu_second_budget.into())
            .set("gpu_seconds_used", self.gpu_seconds_used.into())
            .set("active_sessions", self.active_sessions.into())
            .set("gpus_in_use", self.gpus_in_use.into())
            .set("waiting", self.waiting.into())
            .set("preemptions", self.preemptions.into());
        o
    }

    fn from_json(j: &Json) -> Result<TenantView, ApiError> {
        Ok(TenantView {
            user: need_str(j, "user")?,
            weight: need_u64(j, "weight")? as u32,
            class: need_str(j, "class")?,
            max_concurrent: need_u64(j, "max_concurrent")? as usize,
            max_gpus: need_u64(j, "max_gpus")? as usize,
            gpu_second_budget: need_f64(j, "gpu_second_budget")?,
            gpu_seconds_used: need_f64(j, "gpu_seconds_used")?,
            active_sessions: need_u64(j, "active_sessions")? as usize,
            gpus_in_use: need_u64(j, "gpus_in_use")? as usize,
            waiting: need_u64(j, "waiting")? as usize,
            preemptions: need_u64(j, "preemptions")?,
        })
    }
}

/// Durability-subsystem counters (`durability_status`,
/// `GET /api/v1/durability`): WAL segment size, snapshot cadence
/// progress, subscription lag and the latest GC sweep. All zeros with
/// `enabled = false` when the subsystem is off (no state dir, or
/// `[durability] enabled = false`).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct DurabilityView {
    pub enabled: bool,
    /// Records in the current WAL segment (resets on rotation).
    pub wal_records: u64,
    /// Bytes in the current WAL segment.
    pub wal_bytes: u64,
    /// Bus sequence number of the segment's newest record.
    pub wal_last_seq: Option<u64>,
    /// Durable records appended since the last snapshot.
    pub records_since_snapshot: u64,
    /// Snapshot cadence (`[durability] snapshot_every`).
    pub snapshot_every: u64,
    /// Snapshots taken this process.
    pub snapshots: u64,
    /// Coverage bound of the newest snapshot.
    pub last_snapshot_seq: u64,
    /// Events the WAL subscription lost to ring overflow (each loss
    /// triggered an immediate healing snapshot).
    pub wal_dropped: u64,
    /// Events the derived-view consumer subscription lost (each loss
    /// triggered a reconcile pass).
    pub consumer_dropped: u64,
    pub gc_enabled: bool,
    /// Latest sweep's survivors / reclaimed totals (zeros before the
    /// first sweep).
    pub gc_live_objects: u64,
    pub gc_live_bytes: u64,
    pub gc_swept_objects: u64,
    pub gc_swept_bytes: u64,
}

impl DurabilityView {
    fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("enabled", self.enabled.into())
            .set("wal_records", self.wal_records.into())
            .set("wal_bytes", self.wal_bytes.into())
            .set(
                "wal_last_seq",
                self.wal_last_seq.map(|v| Json::Num(v as f64)).unwrap_or(Json::Null),
            )
            .set("records_since_snapshot", self.records_since_snapshot.into())
            .set("snapshot_every", self.snapshot_every.into())
            .set("snapshots", self.snapshots.into())
            .set("last_snapshot_seq", self.last_snapshot_seq.into())
            .set("wal_dropped", self.wal_dropped.into())
            .set("consumer_dropped", self.consumer_dropped.into())
            .set("gc_enabled", self.gc_enabled.into())
            .set("gc_live_objects", self.gc_live_objects.into())
            .set("gc_live_bytes", self.gc_live_bytes.into())
            .set("gc_swept_objects", self.gc_swept_objects.into())
            .set("gc_swept_bytes", self.gc_swept_bytes.into());
        o
    }

    fn from_json(j: &Json) -> Result<DurabilityView, ApiError> {
        Ok(DurabilityView {
            enabled: need_bool(j, "enabled")?,
            wal_records: need_u64(j, "wal_records")?,
            wal_bytes: need_u64(j, "wal_bytes")?,
            wal_last_seq: opt_u64(j, "wal_last_seq")?,
            records_since_snapshot: need_u64(j, "records_since_snapshot")?,
            snapshot_every: need_u64(j, "snapshot_every")?,
            snapshots: need_u64(j, "snapshots")?,
            last_snapshot_seq: need_u64(j, "last_snapshot_seq")?,
            wal_dropped: need_u64(j, "wal_dropped")?,
            consumer_dropped: need_u64(j, "consumer_dropped")?,
            gc_enabled: need_bool(j, "gc_enabled")?,
            gc_live_objects: need_u64(j, "gc_live_objects")?,
            gc_live_bytes: need_u64(j, "gc_live_bytes")?,
            gc_swept_objects: need_u64(j, "gc_swept_objects")?,
            gc_swept_bytes: need_u64(j, "gc_swept_bytes")?,
        })
    }
}

/// Daemon drive-loop counters (`service_status`, `GET /api/v1/service`):
/// whether a background loop is running, how many rounds it has
/// completed, how long the last round took and the sustained
/// rounds-per-second since the loop started. `dispatches` counts the
/// requests the loop answered between rounds. All zeros with `running =
/// false` when no daemon loop has ever run in this process.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct ServiceStatusView {
    /// A `run_daemon` loop is currently active.
    pub running: bool,
    /// Drive rounds completed by the loop.
    pub rounds: u64,
    /// Wall-clock duration of the most recent round, in milliseconds.
    pub last_round_ms: f64,
    /// Rounds per wall-clock second since the loop started.
    pub rounds_per_sec: f64,
    /// Sessions progressed across all rounds.
    pub progressed_total: u64,
    /// Requests the loop dispatched between rounds.
    pub dispatches: u64,
}

impl ServiceStatusView {
    fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("running", self.running.into())
            .set("rounds", self.rounds.into())
            .set("last_round_ms", self.last_round_ms.into())
            .set("rounds_per_sec", self.rounds_per_sec.into())
            .set("progressed_total", self.progressed_total.into())
            .set("dispatches", self.dispatches.into());
        o
    }

    fn from_json(j: &Json) -> Result<ServiceStatusView, ApiError> {
        Ok(ServiceStatusView {
            running: need_bool(j, "running")?,
            rounds: need_u64(j, "rounds")?,
            last_round_ms: need_f64(j, "last_round_ms")?,
            rounds_per_sec: need_f64(j, "rounds_per_sec")?,
            progressed_total: need_u64(j, "progressed_total")?,
            dispatches: need_u64(j, "dispatches")?,
        })
    }
}

/// One entry of an endpoint's promote history (oldest first).
#[derive(Debug, Clone, PartialEq)]
pub struct EndpointVersionView {
    /// 1-based, monotonic per endpoint.
    pub version: u64,
    /// Session whose checkpoint was promoted.
    pub session: String,
    pub model: String,
    /// Training step of the promoted checkpoint.
    pub step: u64,
    /// Virtual time of the promote.
    pub promoted_at_ms: u64,
}

impl EndpointVersionView {
    fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("version", self.version.into())
            .set("session", self.session.as_str().into())
            .set("model", self.model.as_str().into())
            .set("step", self.step.into())
            .set("promoted_at_ms", self.promoted_at_ms.into());
        o
    }

    fn from_json(j: &Json) -> Result<EndpointVersionView, ApiError> {
        Ok(EndpointVersionView {
            version: need_u64(j, "version")?,
            session: need_str(j, "session")?,
            model: need_str(j, "model")?,
            step: need_u64(j, "step")?,
            promoted_at_ms: need_u64(j, "promoted_at_ms")?,
        })
    }
}

/// One named serving endpoint: which version currently serves, plus
/// the full promote history rollback/rollforward moves over
/// (`endpoints`, `GET /api/v1/endpoints`, `nsml endpoints`).
#[derive(Debug, Clone, PartialEq)]
pub struct EndpointView {
    pub name: String,
    /// Version number currently serving requests.
    pub active_version: u64,
    /// Convenience copies of the active version's identity.
    pub model: String,
    pub session: String,
    pub step: u64,
    /// Serving replicas currently placed on executor workers (1 when
    /// the serve lane is disabled — the platform thread itself).
    pub replicas: u64,
    /// Requests queued in the micro-batcher, not yet dispatched.
    pub queue_depth: u64,
    /// Windowed serving-latency quantiles (ms) from the obs registry's
    /// per-endpoint histogram; 0 before any request is served (or with
    /// observability disabled).
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub versions: Vec<EndpointVersionView>,
}

impl EndpointView {
    /// Project the registry's endpoint record onto the wire. Live
    /// serving stats default to zero; callers with a platform in hand
    /// layer them on with [`EndpointView::with_stats`].
    pub fn from_endpoint(ep: &crate::serving::Endpoint) -> EndpointView {
        let active = ep.active_version();
        EndpointView {
            name: ep.name.clone(),
            active_version: active.version,
            model: active.model.clone(),
            session: active.session.clone(),
            step: active.step,
            replicas: 0,
            queue_depth: 0,
            p50_ms: 0.0,
            p99_ms: 0.0,
            versions: ep
                .versions
                .iter()
                .map(|v| EndpointVersionView {
                    version: v.version,
                    session: v.session.clone(),
                    model: v.model.clone(),
                    step: v.step,
                    promoted_at_ms: v.promoted_at_ms,
                })
                .collect(),
        }
    }

    /// Attach live replica/queue counts (the `endpoints` handler calls
    /// this with the platform's `endpoint_stats` output).
    pub fn with_stats(mut self, replicas: u64, queue_depth: u64) -> EndpointView {
        self.replicas = replicas;
        self.queue_depth = queue_depth;
        self
    }

    /// Attach windowed latency quantiles (the `endpoints` handler calls
    /// this with the platform's `endpoint_latency` output).
    pub fn with_latency(mut self, p50_ms: f64, p99_ms: f64) -> EndpointView {
        self.p50_ms = p50_ms;
        self.p99_ms = p99_ms;
        self
    }

    fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("name", self.name.as_str().into())
            .set("active_version", self.active_version.into())
            .set("model", self.model.as_str().into())
            .set("session", self.session.as_str().into())
            .set("step", self.step.into())
            .set("replicas", self.replicas.into())
            .set("queue_depth", self.queue_depth.into())
            .set("p50_ms", self.p50_ms.into())
            .set("p99_ms", self.p99_ms.into())
            .set("versions", Json::Arr(self.versions.iter().map(|v| v.to_json()).collect()));
        o
    }

    fn from_json(j: &Json) -> Result<EndpointView, ApiError> {
        Ok(EndpointView {
            name: need_str(j, "name")?,
            active_version: need_u64(j, "active_version")?,
            model: need_str(j, "model")?,
            session: need_str(j, "session")?,
            step: need_u64(j, "step")?,
            replicas: need_u64(j, "replicas")?,
            queue_depth: need_u64(j, "queue_depth")?,
            p50_ms: opt_f64(j, "p50_ms")?.unwrap_or(0.0),
            p99_ms: opt_f64(j, "p99_ms")?.unwrap_or(0.0),
            versions: need_arr(j, "versions")?
                .iter()
                .map(EndpointVersionView::from_json)
                .collect::<Result<Vec<EndpointVersionView>, ApiError>>()?,
        })
    }
}

/// One counter or gauge sample in a metrics report. Labels travel as a
/// JSON object (sorted keys), so the wire form is stable across runs.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct MetricPointView {
    pub name: String,
    pub labels: Vec<(String, String)>,
    pub value: f64,
}

impl MetricPointView {
    fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("name", self.name.as_str().into())
            .set("labels", labels_to_json(&self.labels))
            .set("value", self.value.into());
        o
    }

    fn from_json(j: &Json) -> Result<MetricPointView, ApiError> {
        Ok(MetricPointView {
            name: need_str(j, "name")?,
            labels: labels_from_json(j)?,
            value: need_f64(j, "value")?,
        })
    }
}

/// One histogram in a metrics report: lifetime count/sum plus windowed
/// quantiles (the registry's ring of bucket snapshots, not lifetime).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct HistogramView {
    pub name: String,
    pub labels: Vec<(String, String)>,
    pub count: u64,
    pub sum_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
}

impl HistogramView {
    fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("name", self.name.as_str().into())
            .set("labels", labels_to_json(&self.labels))
            .set("count", self.count.into())
            .set("sum_ms", self.sum_ms.into())
            .set("p50_ms", self.p50_ms.into())
            .set("p95_ms", self.p95_ms.into())
            .set("p99_ms", self.p99_ms.into());
        o
    }

    fn from_json(j: &Json) -> Result<HistogramView, ApiError> {
        Ok(HistogramView {
            name: need_str(j, "name")?,
            labels: labels_from_json(j)?,
            count: need_u64(j, "count")?,
            sum_ms: need_f64(j, "sum_ms")?,
            p50_ms: need_f64(j, "p50_ms")?,
            p95_ms: need_f64(j, "p95_ms")?,
            p99_ms: need_f64(j, "p99_ms")?,
        })
    }
}

/// The full metrics registry (`metrics_report`, `GET /api/v1/metrics`):
/// every counter, gauge and histogram the platform has registered.
/// `enabled = false` (with empty series) when `[obs] enabled = false`.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct MetricsReportView {
    pub enabled: bool,
    pub counters: Vec<MetricPointView>,
    pub gauges: Vec<MetricPointView>,
    pub histograms: Vec<HistogramView>,
}

impl MetricsReportView {
    /// Build the wire view from a live registry snapshot.
    pub fn from_snapshot(snap: crate::obs::RegistrySnapshot) -> MetricsReportView {
        let point = |p: crate::obs::MetricPointSnap| MetricPointView {
            name: p.name,
            labels: p.labels,
            value: p.value,
        };
        MetricsReportView {
            enabled: snap.enabled,
            counters: snap.counters.into_iter().map(point).collect(),
            gauges: snap.gauges.into_iter().map(point).collect(),
            histograms: snap
                .histograms
                .into_iter()
                .map(|h| HistogramView {
                    name: h.name,
                    labels: h.labels,
                    count: h.count,
                    sum_ms: h.sum_ms,
                    p50_ms: h.p50_ms,
                    p95_ms: h.p95_ms,
                    p99_ms: h.p99_ms,
                })
                .collect(),
        }
    }

    fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("enabled", self.enabled.into())
            .set("counters", Json::Arr(self.counters.iter().map(|p| p.to_json()).collect()))
            .set("gauges", Json::Arr(self.gauges.iter().map(|p| p.to_json()).collect()))
            .set("histograms", Json::Arr(self.histograms.iter().map(|h| h.to_json()).collect()));
        o
    }

    fn from_json(j: &Json) -> Result<MetricsReportView, ApiError> {
        Ok(MetricsReportView {
            enabled: need_bool(j, "enabled")?,
            counters: need_arr(j, "counters")?
                .iter()
                .map(MetricPointView::from_json)
                .collect::<Result<Vec<MetricPointView>, ApiError>>()?,
            gauges: need_arr(j, "gauges")?
                .iter()
                .map(MetricPointView::from_json)
                .collect::<Result<Vec<MetricPointView>, ApiError>>()?,
            histograms: need_arr(j, "histograms")?
                .iter()
                .map(HistogramView::from_json)
                .collect::<Result<Vec<HistogramView>, ApiError>>()?,
        })
    }
}

fn labels_to_json(labels: &[(String, String)]) -> Json {
    let mut o = Json::obj();
    for (k, v) in labels {
        o.set(k, v.as_str().into());
    }
    o
}

fn labels_from_json(j: &Json) -> Result<Vec<(String, String)>, ApiError> {
    let obj = need(j, "labels")?
        .as_obj()
        .ok_or_else(|| ApiError::invalid("'labels' must be an object"))?;
    let mut out = Vec::with_capacity(obj.len());
    for (k, v) in obj {
        let s = v.as_str().ok_or_else(|| ApiError::invalid("label values must be strings"))?;
        out.push((k.clone(), s.to_string()));
    }
    Ok(out)
}

/// One span of a request-scoped trace (`trace`, `GET /api/v1/trace/<id>`).
/// `at_ms` is platform time (virtual under sim clocks); `dur_ms` is the
/// measured wall duration, 0 for instant markers.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct SpanView {
    pub seq: u64,
    pub at_ms: u64,
    pub dur_ms: f64,
    pub name: String,
    pub source: String,
    pub detail: String,
}

impl SpanView {
    /// Build the wire view from a recorded span.
    pub fn from_span(s: &crate::obs::Span) -> SpanView {
        SpanView {
            seq: s.seq,
            at_ms: s.at_ms,
            dur_ms: s.dur_ms,
            name: s.name.clone(),
            source: s.source.clone(),
            detail: s.detail.clone(),
        }
    }

    fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("seq", self.seq.into())
            .set("at_ms", self.at_ms.into())
            .set("dur_ms", self.dur_ms.into())
            .set("name", self.name.as_str().into())
            .set("source", self.source.as_str().into())
            .set("detail", self.detail.as_str().into());
        o
    }

    fn from_json(j: &Json) -> Result<SpanView, ApiError> {
        Ok(SpanView {
            seq: need_u64(j, "seq")?,
            at_ms: need_u64(j, "at_ms")?,
            dur_ms: need_f64(j, "dur_ms")?,
            name: need_str(j, "name")?,
            source: need_str(j, "source")?,
            detail: opt_str(j, "detail")?.unwrap_or_default(),
        })
    }
}

/// All spans recorded for one trace id, ordered by `(at_ms, seq)`.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct TraceView {
    pub id: String,
    pub spans: Vec<SpanView>,
}

impl TraceView {
    fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("id", self.id.as_str().into())
            .set("spans", Json::Arr(self.spans.iter().map(|s| s.to_json()).collect()));
        o
    }

    fn from_json(j: &Json) -> Result<TraceView, ApiError> {
        Ok(TraceView {
            id: need_str(j, "id")?,
            spans: need_arr(j, "spans")?
                .iter()
                .map(SpanView::from_json)
                .collect::<Result<Vec<SpanView>, ApiError>>()?,
        })
    }
}

// ---------------------------------------------------------------------
// Responses
// ---------------------------------------------------------------------

/// Every reply the service produces. Exactly one variant per outcome
/// shape; errors always travel as [`ApiResponse::Error`].
#[derive(Debug, Clone, PartialEq)]
pub enum ApiResponse {
    /// A session was placed or queued.
    Submitted { session: String },
    /// A trial batch was placed; ids in trial order.
    BatchSubmitted { sessions: Vec<String> },
    /// A mutation succeeded with nothing to return.
    Ack { verb: String, session: Option<String> },
    /// `drive` advanced this many sessions.
    Progressed { sessions: usize },
    /// Inference output probabilities.
    Probs { probs: Vec<f32> },
    Sessions { sessions: Vec<SessionView> },
    Session { session: SessionView },
    Board { dataset: String, rows: Vec<BoardRow> },
    Cluster { cluster: ClusterView },
    Executor { executor: ExecutorStats },
    /// One page of the event bus: events since the request cursor,
    /// the cursor to resume from, how many events the reader lost to
    /// ring overflow (0 when it kept up), and the bus's lifetime
    /// ring-eviction total across all readers.
    Events { events: Vec<Event>, next: u64, dropped: u64, overflow: u64 },
    /// Per-user fair-share report (`tenant_report`).
    Tenants { tenants: Vec<TenantView> },
    /// Durability counters (`durability_status`).
    Durability { durability: DurabilityView },
    /// Daemon drive-loop counters (`service_status`).
    Service { service: ServiceStatusView },
    /// One endpoint after a `promote` mutation (any action but retire,
    /// which answers an ack — the endpoint is gone).
    Endpoint { endpoint: EndpointView },
    /// Every serving endpoint (`endpoints`).
    Endpoints { endpoints: Vec<EndpointView> },
    /// One micro-batched serving result: the output row, which version
    /// produced it, and how many requests shared the execution.
    Served { endpoint: String, version: u64, batch: u64, probs: Vec<f32> },
    /// The full metrics registry (`metrics_report`).
    Metrics { metrics: MetricsReportView },
    /// One request-scoped trace (`trace`).
    Trace { trace: TraceView },
    Error { error: ApiError },
}

impl ApiResponse {
    pub fn kind(&self) -> &'static str {
        match self {
            ApiResponse::Submitted { .. } => "submitted",
            ApiResponse::BatchSubmitted { .. } => "batch_submitted",
            ApiResponse::Ack { .. } => "ack",
            ApiResponse::Progressed { .. } => "progressed",
            ApiResponse::Probs { .. } => "probs",
            ApiResponse::Sessions { .. } => "sessions",
            ApiResponse::Session { .. } => "session",
            ApiResponse::Board { .. } => "board",
            ApiResponse::Cluster { .. } => "cluster",
            ApiResponse::Executor { .. } => "executor",
            ApiResponse::Events { .. } => "events",
            ApiResponse::Tenants { .. } => "tenants",
            ApiResponse::Durability { .. } => "durability",
            ApiResponse::Service { .. } => "service",
            ApiResponse::Endpoint { .. } => "endpoint",
            ApiResponse::Endpoints { .. } => "endpoints",
            ApiResponse::Served { .. } => "served",
            ApiResponse::Metrics { .. } => "metrics",
            ApiResponse::Trace { .. } => "trace",
            ApiResponse::Error { .. } => "error",
        }
    }

    pub fn is_error(&self) -> bool {
        matches!(self, ApiResponse::Error { .. })
    }

    /// Unwrap into a uniform `Result` for callers that only need
    /// success/failure (the CLI).
    pub fn into_result(self) -> Result<ApiResponse, ApiError> {
        match self {
            ApiResponse::Error { error } => Err(error),
            other => Ok(other),
        }
    }

    pub fn to_json(&self) -> Json {
        let mut data = Json::obj();
        match self {
            ApiResponse::Submitted { session } => {
                data.set("session", session.as_str().into());
            }
            ApiResponse::BatchSubmitted { sessions } => {
                data.set("sessions", Json::Arr(sessions.iter().map(|s| Json::Str(s.clone())).collect()));
            }
            ApiResponse::Ack { verb, session } => {
                data.set("verb", verb.as_str().into())
                    .set("session", session.as_deref().map(Json::from).unwrap_or(Json::Null));
            }
            ApiResponse::Progressed { sessions } => {
                data.set("sessions", (*sessions).into());
            }
            ApiResponse::Probs { probs } => {
                data.set("probs", Json::Arr(probs.iter().map(|&p| Json::Num(p as f64)).collect()));
            }
            ApiResponse::Sessions { sessions } => {
                data.set("sessions", Json::Arr(sessions.iter().map(|s| s.to_json()).collect()));
            }
            ApiResponse::Session { session } => {
                data.set("session", session.to_json());
            }
            ApiResponse::Board { dataset, rows } => {
                data.set("dataset", dataset.as_str().into())
                    .set("rows", Json::Arr(rows.iter().map(|r| r.to_json()).collect()));
            }
            ApiResponse::Cluster { cluster } => {
                data.set("cluster", cluster.to_json());
            }
            ApiResponse::Executor { executor } => {
                data.set("executor", executor.to_json());
            }
            ApiResponse::Events { events, next, dropped, overflow } => {
                data.set("events", Json::Arr(events.iter().map(|e| e.to_json()).collect()))
                    .set("next", (*next).into())
                    .set("dropped", (*dropped).into())
                    .set("overflow", (*overflow).into());
            }
            ApiResponse::Tenants { tenants } => {
                data.set("tenants", Json::Arr(tenants.iter().map(|t| t.to_json()).collect()));
            }
            ApiResponse::Durability { durability } => {
                data.set("durability", durability.to_json());
            }
            ApiResponse::Service { service } => {
                data.set("service", service.to_json());
            }
            ApiResponse::Endpoint { endpoint } => {
                data.set("endpoint", endpoint.to_json());
            }
            ApiResponse::Endpoints { endpoints } => {
                data.set("endpoints", Json::Arr(endpoints.iter().map(|e| e.to_json()).collect()));
            }
            ApiResponse::Served { endpoint, version, batch, probs } => {
                data.set("endpoint", endpoint.as_str().into())
                    .set("version", (*version).into())
                    .set("batch", (*batch).into())
                    .set("probs", Json::Arr(probs.iter().map(|&p| Json::Num(p as f64)).collect()));
            }
            ApiResponse::Metrics { metrics } => {
                data.set("metrics", metrics.to_json());
            }
            ApiResponse::Trace { trace } => {
                data.set("trace", trace.to_json());
            }
            ApiResponse::Error { error } => {
                data.set("error", error.to_json());
            }
        }
        envelope("kind", self.kind(), "data", data)
    }

    pub fn from_json(j: &Json) -> Result<ApiResponse, ApiError> {
        check_version(j)?;
        let kind = need_str(j, "kind")?;
        let empty = Json::obj();
        let data = j.get("data").unwrap_or(&empty);
        match kind.as_str() {
            "submitted" => Ok(ApiResponse::Submitted { session: need_str(data, "session")? }),
            "batch_submitted" => Ok(ApiResponse::BatchSubmitted {
                sessions: need_arr(data, "sessions")?
                    .iter()
                    .map(|v| v.as_str().map(str::to_string))
                    .collect::<Option<Vec<String>>>()
                    .ok_or_else(|| ApiError::invalid("'sessions' must be strings"))?,
            }),
            "ack" => Ok(ApiResponse::Ack {
                verb: need_str(data, "verb")?,
                session: opt_str(data, "session")?,
            }),
            "progressed" => Ok(ApiResponse::Progressed { sessions: need_u64(data, "sessions")? as usize }),
            "probs" => Ok(ApiResponse::Probs {
                probs: need_arr(data, "probs")?
                    .iter()
                    .map(|v| v.as_f64().map(|f| f as f32))
                    .collect::<Option<Vec<f32>>>()
                    .ok_or_else(|| ApiError::invalid("'probs' must be numbers"))?,
            }),
            "sessions" => Ok(ApiResponse::Sessions {
                sessions: need_arr(data, "sessions")?
                    .iter()
                    .map(SessionView::from_json)
                    .collect::<Result<Vec<SessionView>, ApiError>>()?,
            }),
            "session" => Ok(ApiResponse::Session {
                session: SessionView::from_json(need(data, "session")?)?,
            }),
            "board" => Ok(ApiResponse::Board {
                dataset: need_str(data, "dataset")?,
                rows: need_arr(data, "rows")?
                    .iter()
                    .map(BoardRow::from_json)
                    .collect::<Result<Vec<BoardRow>, ApiError>>()?,
            }),
            "cluster" => Ok(ApiResponse::Cluster { cluster: ClusterView::from_json(need(data, "cluster")?)? }),
            "executor" => Ok(ApiResponse::Executor {
                executor: ExecutorStats::from_json(need(data, "executor")?)?,
            }),
            "events" => Ok(ApiResponse::Events {
                events: need_arr(data, "events")?
                    .iter()
                    .map(|e| Event::from_json(e).map_err(ApiError::invalid))
                    .collect::<Result<Vec<Event>, ApiError>>()?,
                next: need_u64(data, "next")?,
                dropped: need_u64(data, "dropped")?,
                overflow: opt_u64(data, "overflow")?.unwrap_or(0),
            }),
            "tenants" => Ok(ApiResponse::Tenants {
                tenants: need_arr(data, "tenants")?
                    .iter()
                    .map(TenantView::from_json)
                    .collect::<Result<Vec<TenantView>, ApiError>>()?,
            }),
            "durability" => Ok(ApiResponse::Durability {
                durability: DurabilityView::from_json(need(data, "durability")?)?,
            }),
            "service" => Ok(ApiResponse::Service {
                service: ServiceStatusView::from_json(need(data, "service")?)?,
            }),
            "endpoint" => Ok(ApiResponse::Endpoint {
                endpoint: EndpointView::from_json(need(data, "endpoint")?)?,
            }),
            "endpoints" => Ok(ApiResponse::Endpoints {
                endpoints: need_arr(data, "endpoints")?
                    .iter()
                    .map(EndpointView::from_json)
                    .collect::<Result<Vec<EndpointView>, ApiError>>()?,
            }),
            "served" => Ok(ApiResponse::Served {
                endpoint: need_str(data, "endpoint")?,
                version: need_u64(data, "version")?,
                batch: need_u64(data, "batch")?,
                probs: need_arr(data, "probs")?
                    .iter()
                    .map(|v| v.as_f64().map(|f| f as f32))
                    .collect::<Option<Vec<f32>>>()
                    .ok_or_else(|| ApiError::invalid("'probs' must be numbers"))?,
            }),
            "metrics" => Ok(ApiResponse::Metrics {
                metrics: MetricsReportView::from_json(need(data, "metrics")?)?,
            }),
            "trace" => Ok(ApiResponse::Trace { trace: TraceView::from_json(need(data, "trace")?)? }),
            "error" => Ok(ApiResponse::Error { error: ApiError::from_json(need(data, "error")?)? }),
            other => Err(ApiError::invalid(format!("unknown response kind '{}'", other))),
        }
    }
}

// ---------------------------------------------------------------------
// Envelope + field helpers
// ---------------------------------------------------------------------

fn envelope(tag_key: &str, tag: &str, payload_key: &str, payload: Json) -> Json {
    let mut env = Json::obj();
    env.set("v", API_VERSION.into()).set(tag_key, tag.into()).set(payload_key, payload);
    env
}

fn check_version(j: &Json) -> Result<(), ApiError> {
    match j.get("v").map(as_safe_u64) {
        Some(Some(v)) if v == API_VERSION => Ok(()),
        Some(Some(v)) => {
            Err(ApiError::invalid(format!("unsupported api version {} (this is v{})", v, API_VERSION)))
        }
        Some(None) => Err(ApiError::invalid("version field 'v' must be an integer")),
        None => Err(ApiError::invalid("missing api version field 'v'")),
    }
}

fn need<'a>(j: &'a Json, key: &str) -> Result<&'a Json, ApiError> {
    j.get(key).ok_or_else(|| ApiError::invalid(format!("missing field '{}'", key)))
}

fn need_str(j: &Json, key: &str) -> Result<String, ApiError> {
    need(j, key)?
        .as_str()
        .map(str::to_string)
        .ok_or_else(|| ApiError::invalid(format!("field '{}' must be a string", key)))
}

/// Integers ride in JSON numbers (f64), which are exact only up to
/// 2^53; anything beyond — or fractional — is rejected rather than
/// silently rounded.
const MAX_SAFE_INT: f64 = 9_007_199_254_740_992.0; // 2^53

fn as_safe_u64(v: &Json) -> Option<u64> {
    v.as_f64()
        .filter(|f| *f >= 0.0 && *f <= MAX_SAFE_INT && f.fract() == 0.0)
        .map(|f| f as u64)
}

fn need_u64(j: &Json, key: &str) -> Result<u64, ApiError> {
    as_safe_u64(need(j, key)?).ok_or_else(|| {
        ApiError::invalid(format!("field '{}' must be a non-negative integer (<= 2^53)", key))
    })
}

fn need_f64(j: &Json, key: &str) -> Result<f64, ApiError> {
    need(j, key)?.as_f64().ok_or_else(|| ApiError::invalid(format!("field '{}' must be a number", key)))
}

fn need_bool(j: &Json, key: &str) -> Result<bool, ApiError> {
    need(j, key)?.as_bool().ok_or_else(|| ApiError::invalid(format!("field '{}' must be a boolean", key)))
}

fn need_arr<'a>(j: &'a Json, key: &str) -> Result<&'a [Json], ApiError> {
    need(j, key)?.as_arr().ok_or_else(|| ApiError::invalid(format!("field '{}' must be an array", key)))
}

/// Optional field: absent or `null` is `None`; present with the wrong
/// type is an error, not a silent fallback to the default.
fn opt_field<'a>(j: &'a Json, key: &str) -> Option<&'a Json> {
    match j.get(key) {
        None | Some(Json::Null) => None,
        Some(v) => Some(v),
    }
}

fn opt_str(j: &Json, key: &str) -> Result<Option<String>, ApiError> {
    match opt_field(j, key) {
        None => Ok(None),
        Some(v) => v
            .as_str()
            .map(|s| Some(s.to_string()))
            .ok_or_else(|| ApiError::invalid(format!("field '{}' must be a string", key))),
    }
}

fn opt_f64(j: &Json, key: &str) -> Result<Option<f64>, ApiError> {
    match opt_field(j, key) {
        None => Ok(None),
        Some(v) => v
            .as_f64()
            .map(Some)
            .ok_or_else(|| ApiError::invalid(format!("field '{}' must be a number", key))),
    }
}

fn opt_u64(j: &Json, key: &str) -> Result<Option<u64>, ApiError> {
    match opt_field(j, key) {
        None => Ok(None),
        Some(v) => as_safe_u64(v).map(Some).ok_or_else(|| {
            ApiError::invalid(format!("field '{}' must be a non-negative integer (<= 2^53)", key))
        }),
    }
}

fn opt_bool(j: &Json, key: &str) -> Result<Option<bool>, ApiError> {
    match opt_field(j, key) {
        None => Ok(None),
        Some(v) => v
            .as_bool()
            .map(Some)
            .ok_or_else(|| ApiError::invalid(format!("field '{}' must be a boolean", key))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::parse;

    #[test]
    fn version_is_checked() {
        let ok = ApiRequest::list_sessions().to_json().to_string();
        assert!(ApiRequest::from_json(&parse(&ok).unwrap()).is_ok());
        let bad = ok.replace("\"v\":1", "\"v\":2");
        let err = ApiRequest::from_json(&parse(&bad).unwrap()).unwrap_err();
        assert_eq!(err.code, ErrorCode::InvalidArgument);
        let missing = parse(r#"{"verb":"list_sessions"}"#).unwrap();
        assert!(ApiRequest::from_json(&missing).is_err());
    }

    #[test]
    fn unknown_verb_is_invalid_argument() {
        let err = ApiRequest::from_verb_args("frobnicate", &Json::obj()).unwrap_err();
        assert_eq!(err.code, ErrorCode::InvalidArgument);
        assert!(err.message.contains("frobnicate"));
    }

    #[test]
    fn run_args_default_like_run_opts() {
        let p = ApiRequest::from_verb_args("run", &parse(r#"{"user":"kim","dataset":"mnist"}"#).unwrap())
            .unwrap();
        match p {
            ApiRequest::Run(p) => {
                let d = crate::api::RunOpts::default();
                assert_eq!(p.gpus, d.gpus);
                assert_eq!(p.total_steps, d.total_steps);
                assert_eq!(p.lr, d.lr);
                assert_eq!(p.run_opts().priority, d.priority);
            }
            other => panic!("expected Run, got {:?}", other),
        }
    }

    #[test]
    fn error_envelope_round_trips() {
        let e = ApiError::failed("not active").with_session("kim/mnist/1");
        let resp = ApiResponse::Error { error: e.clone() };
        let back = ApiResponse::from_json(&parse(&resp.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back, resp);
        assert_eq!(format!("{}", e), "[failed_precondition] not active (session kim/mnist/1)");
    }

    #[test]
    fn missing_fields_are_named() {
        let err = ApiRequest::from_verb_args("pause", &Json::obj()).unwrap_err();
        assert!(err.message.contains("session"), "{}", err);
        let err = ApiRequest::from_verb_args("board", &Json::obj()).unwrap_err();
        assert!(err.message.contains("dataset"), "{}", err);
    }

    #[test]
    fn mistyped_optional_fields_rejected() {
        // Wrong-typed optionals must 400, not silently fall back to defaults.
        let args = parse(r#"{"user":"a","dataset":"mnist","total_steps":"500"}"#).unwrap();
        let err = ApiRequest::from_verb_args("run", &args).unwrap_err();
        assert!(err.message.contains("total_steps"), "{}", err);
        let args = parse(r#"{"session":"s","lr":"0.05"}"#).unwrap();
        let err = ApiRequest::from_verb_args("resume", &args).unwrap_err();
        assert!(err.message.contains("lr"), "{}", err);
        // Explicit null still means "absent".
        let args = parse(r#"{"session":"s","lr":null}"#).unwrap();
        assert_eq!(
            ApiRequest::from_verb_args("resume", &args).unwrap(),
            ApiRequest::Resume { session: "s".into(), lr: None }
        );
    }

    #[test]
    fn unsafe_integers_rejected() {
        // Fractional and beyond-2^53 numbers must error, not round.
        let err = ApiRequest::from_verb_args("drive", &parse(r#"{"chunk":5.7}"#).unwrap()).unwrap_err();
        assert_eq!(err.code, ErrorCode::InvalidArgument);
        let err = ApiRequest::from_verb_args("drive", &parse(r#"{"chunk":9007199254740994}"#).unwrap())
            .unwrap_err();
        assert_eq!(err.code, ErrorCode::InvalidArgument);
        assert!(ApiRequest::from_verb_args("drive", &parse(r#"{"chunk":25}"#).unwrap()).is_ok());
    }

    #[test]
    fn mutation_classification() {
        assert!(ApiRequest::Pause { session: "s".into() }.is_mutation());
        assert!(ApiRequest::Drive { chunk: 1 }.is_mutation());
        assert!(!ApiRequest::list_sessions().is_mutation());
        assert!(!ApiRequest::ServiceStatus.is_mutation());
        assert!(!ApiRequest::Infer { session: "s".into(), x: vec![], shape: vec![] }.is_mutation());
        assert!(!ApiRequest::Board { dataset: "mnist".into(), limit: 5, user: None }.is_mutation());
        assert!(!ApiRequest::EventsSince { since: 0, kind: None, subject: None, limit: 10 }
            .is_mutation());
        assert!(!ApiRequest::TenantReport.is_mutation());
        assert!(!ApiRequest::DurabilityStatus.is_mutation());
        assert!(!ApiRequest::MetricsReport.is_mutation());
        assert!(!ApiRequest::Trace { id: "t".into() }.is_mutation());
        assert!(ApiRequest::Promote {
            endpoint: "prod".into(),
            action: "promote".into(),
            session: Some("s".into())
        }
        .is_mutation());
        assert!(!ApiRequest::Endpoints.is_mutation());
        assert!(!ApiRequest::ServeInfer { endpoint: "prod".into(), user: "kim".into(), x: vec![] }
            .is_mutation());
        assert!(ApiRequest::SetQuota {
            user: "kim".into(),
            max_concurrent: None,
            max_gpus: None,
            gpu_second_budget: None,
            weight: None,
            class: None,
            max_qps: None,
        }
        .is_mutation());
    }

    #[test]
    fn set_quota_partial_fields_parse() {
        // Only the named fields travel; everything else stays None so
        // the service applies a partial update.
        let args = parse(r#"{"user":"kim","max_gpus":4,"class":"high","max_qps":25}"#).unwrap();
        match ApiRequest::from_verb_args("set_quota", &args).unwrap() {
            ApiRequest::SetQuota {
                user,
                max_concurrent,
                max_gpus,
                gpu_second_budget,
                weight,
                class,
                max_qps,
            } => {
                assert_eq!(user, "kim");
                assert_eq!(max_concurrent, None);
                assert_eq!(max_gpus, Some(4));
                assert_eq!(gpu_second_budget, None);
                assert_eq!(weight, None);
                assert_eq!(class.as_deref(), Some("high"));
                assert_eq!(max_qps, Some(25));
            }
            other => panic!("{:?}", other),
        }
        // user is mandatory; mistyped optionals are named errors.
        assert!(ApiRequest::from_verb_args("set_quota", &Json::obj()).is_err());
        let bad = parse(r#"{"user":"kim","weight":"heavy"}"#).unwrap();
        let err = ApiRequest::from_verb_args("set_quota", &bad).unwrap_err();
        assert!(err.message.contains("weight"), "{}", err);
    }

    #[test]
    fn durability_view_round_trips() {
        let view = DurabilityView {
            enabled: true,
            wal_records: 12,
            wal_bytes: 2048,
            wal_last_seq: Some(99),
            records_since_snapshot: 12,
            snapshot_every: 512,
            snapshots: 3,
            last_snapshot_seq: 87,
            wal_dropped: 0,
            consumer_dropped: 0,
            gc_enabled: true,
            gc_live_objects: 40,
            gc_live_bytes: 1 << 20,
            gc_swept_objects: 7,
            gc_swept_bytes: 4096,
        };
        let resp = ApiResponse::Durability { durability: view };
        let back = ApiResponse::from_json(&parse(&resp.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back, resp);
        // A fresh-segment view (no records yet) keeps `None` through
        // the null on the wire.
        let resp = ApiResponse::Durability { durability: DurabilityView::default() };
        let back = ApiResponse::from_json(&parse(&resp.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back, resp);
        assert_eq!(ApiRequest::DurabilityStatus.to_json().get("verb").and_then(Json::as_str), Some("durability_status"));
    }

    #[test]
    fn board_user_filter_parses() {
        let args = parse(r#"{"dataset":"mnist","user":"kim"}"#).unwrap();
        match ApiRequest::from_verb_args("board", &args).unwrap() {
            ApiRequest::Board { dataset, limit, user } => {
                assert_eq!(dataset, "mnist");
                assert_eq!(limit, 100);
                assert_eq!(user.as_deref(), Some("kim"));
            }
            other => panic!("{:?}", other),
        }
        // Absent and explicit-null both mean "no filter".
        let args = parse(r#"{"dataset":"mnist","user":null}"#).unwrap();
        assert!(matches!(
            ApiRequest::from_verb_args("board", &args).unwrap(),
            ApiRequest::Board { user: None, .. }
        ));
    }

    #[test]
    fn events_since_defaults() {
        // All arguments optional: bare POST /api/v1/events_since works.
        match ApiRequest::from_verb_args("events_since", &Json::obj()).unwrap() {
            ApiRequest::EventsSince { since, kind, subject, limit } => {
                assert_eq!(since, 0);
                assert_eq!(kind, None);
                assert_eq!(subject, None);
                assert_eq!(limit, 256);
            }
            other => panic!("{:?}", other),
        }
        let args =
            parse(r#"{"since":42,"kind":"state","subject":"kim/mnist/1","limit":5}"#).unwrap();
        match ApiRequest::from_verb_args("events_since", &args).unwrap() {
            ApiRequest::EventsSince { since, kind, subject, limit } => {
                assert_eq!(since, 42);
                assert_eq!(kind.as_deref(), Some("state"));
                assert_eq!(subject.as_deref(), Some("kim/mnist/1"));
                assert_eq!(limit, 5);
            }
            other => panic!("{:?}", other),
        }
        // Page size is bounded on the wire: 0 (= unlimited internally)
        // and beyond-cap values are rejected, not passed through.
        for bad in [r#"{"limit":0}"#, r#"{"limit":10001}"#] {
            let err =
                ApiRequest::from_verb_args("events_since", &parse(bad).unwrap()).unwrap_err();
            assert_eq!(err.code, ErrorCode::InvalidArgument, "{}", bad);
            assert!(err.message.contains("limit"), "{}", err);
        }
    }

    #[test]
    fn list_sessions_pagination_parses() {
        // Bare envelope keeps the old everything-list behaviour.
        assert_eq!(
            ApiRequest::from_verb_args("list_sessions", &Json::obj()).unwrap(),
            ApiRequest::list_sessions(),
        );
        let args = parse(r#"{"limit":2,"offset":4,"user":"kim"}"#).unwrap();
        match ApiRequest::from_verb_args("list_sessions", &args).unwrap() {
            ApiRequest::ListSessions { limit, offset, user } => {
                assert_eq!(limit, 2);
                assert_eq!(offset, 4);
                assert_eq!(user.as_deref(), Some("kim"));
            }
            other => panic!("{:?}", other),
        }
        // Mistyped paging params are named errors, not silent defaults.
        let err = ApiRequest::from_verb_args("list_sessions", &parse(r#"{"limit":-1}"#).unwrap())
            .unwrap_err();
        assert!(err.message.contains("limit"), "{}", err);
        let err = ApiRequest::from_verb_args("list_sessions", &parse(r#"{"offset":1.5}"#).unwrap())
            .unwrap_err();
        assert!(err.message.contains("offset"), "{}", err);
    }

    #[test]
    fn promote_parses_and_validates_actions() {
        // Bare promote defaults the action and requires a session.
        let args = parse(r#"{"endpoint":"prod","session":"kim/mnist/1"}"#).unwrap();
        assert_eq!(
            ApiRequest::from_verb_args("promote", &args).unwrap(),
            ApiRequest::Promote {
                endpoint: "prod".into(),
                action: "promote".into(),
                session: Some("kim/mnist/1".into()),
            }
        );
        // Cursor moves need no session.
        for action in ["rollback", "rollforward", "retire"] {
            let args = parse(&format!(r#"{{"endpoint":"prod","action":"{}"}}"#, action)).unwrap();
            match ApiRequest::from_verb_args("promote", &args).unwrap() {
                ApiRequest::Promote { action: a, session, .. } => {
                    assert_eq!(a, action);
                    assert_eq!(session, None);
                }
                other => panic!("{:?}", other),
            }
        }
        // Promoting without a session and unknown actions are named errors.
        let err = ApiRequest::from_verb_args("promote", &parse(r#"{"endpoint":"prod"}"#).unwrap())
            .unwrap_err();
        assert!(err.message.contains("session"), "{}", err);
        let bad = parse(r#"{"endpoint":"prod","action":"sideways"}"#).unwrap();
        let err = ApiRequest::from_verb_args("promote", &bad).unwrap_err();
        assert!(err.message.contains("sideways"), "{}", err);
        // Full request envelope round-trips.
        let req = ApiRequest::Promote {
            endpoint: "prod".into(),
            action: "rollback".into(),
            session: None,
        };
        let back = ApiRequest::from_json(&parse(&req.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back, req);
    }

    #[test]
    fn serving_responses_round_trip() {
        let view = EndpointView {
            name: "mnist-prod".into(),
            active_version: 2,
            model: "mnist_mlp".into(),
            session: "kim/mnist/2".into(),
            step: 150,
            replicas: 3,
            queue_depth: 17,
            p50_ms: 1.25,
            p99_ms: 8.0,
            versions: vec![
                EndpointVersionView {
                    version: 1,
                    session: "kim/mnist/1".into(),
                    model: "mnist_mlp".into(),
                    step: 100,
                    promoted_at_ms: 5_000,
                },
                EndpointVersionView {
                    version: 2,
                    session: "kim/mnist/2".into(),
                    model: "mnist_mlp".into(),
                    step: 150,
                    promoted_at_ms: 9_000,
                },
            ],
        };
        for resp in [
            ApiResponse::Endpoint { endpoint: view.clone() },
            ApiResponse::Endpoints { endpoints: vec![view] },
            ApiResponse::Endpoints { endpoints: vec![] },
            ApiResponse::Served {
                endpoint: "mnist-prod".into(),
                version: 2,
                batch: 8,
                probs: vec![0.25, 0.75],
            },
        ] {
            let back =
                ApiResponse::from_json(&parse(&resp.to_json().to_string()).unwrap()).unwrap();
            assert_eq!(back, resp);
        }
        // serve_infer request envelope round-trips too.
        let req = ApiRequest::ServeInfer {
            endpoint: "mnist-prod".into(),
            user: "kim".into(),
            x: vec![0.0, 0.5, 1.0],
        };
        let back = ApiRequest::from_json(&parse(&req.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back, req);
    }

    #[test]
    fn service_status_view_round_trips() {
        let view = ServiceStatusView {
            running: true,
            rounds: 40,
            last_round_ms: 2.5,
            rounds_per_sec: 110.0,
            progressed_total: 320,
            dispatches: 7,
        };
        let resp = ApiResponse::Service { service: view };
        let back = ApiResponse::from_json(&parse(&resp.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back, resp);
        // The idle (never-served) view is all zeros and still round-trips.
        let resp = ApiResponse::Service { service: ServiceStatusView::default() };
        let back = ApiResponse::from_json(&parse(&resp.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back, resp);
        assert_eq!(
            ApiRequest::ServiceStatus.to_json().get("verb").and_then(Json::as_str),
            Some("service_status")
        );
    }

    #[test]
    fn metrics_report_round_trips() {
        let view = MetricsReportView {
            enabled: true,
            counters: vec![MetricPointView {
                name: "nsml_dispatch_total".into(),
                labels: vec![("verb".into(), "run".into())],
                value: 42.0,
            }],
            gauges: vec![MetricPointView {
                name: "nsml_cluster_utilization".into(),
                labels: vec![],
                value: 0.75,
            }],
            histograms: vec![HistogramView {
                name: "nsml_dispatch_ms".into(),
                labels: vec![("verb".into(), "run".into())],
                count: 42,
                sum_ms: 63.0,
                p50_ms: 1.0,
                p95_ms: 2.0,
                p99_ms: 4.0,
            }],
        };
        let resp = ApiResponse::Metrics { metrics: view };
        let back = ApiResponse::from_json(&parse(&resp.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back, resp);
        // Disabled registry: empty series still round-trip.
        let resp = ApiResponse::Metrics { metrics: MetricsReportView::default() };
        let back = ApiResponse::from_json(&parse(&resp.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back, resp);
        assert_eq!(
            ApiRequest::MetricsReport.to_json().get("verb").and_then(Json::as_str),
            Some("metrics_report")
        );
    }

    #[test]
    fn trace_round_trips() {
        let view = TraceView {
            id: "a1b2c3".into(),
            spans: vec![
                SpanView {
                    seq: 0,
                    at_ms: 10,
                    dur_ms: 0.4,
                    name: "dispatch.run".into(),
                    source: "service".into(),
                    detail: "".into(),
                },
                SpanView {
                    seq: 1,
                    at_ms: 20,
                    dur_ms: 1.5,
                    name: "state.running".into(),
                    source: "session".into(),
                    detail: "from=queued".into(),
                },
            ],
        };
        let resp = ApiResponse::Trace { trace: view };
        let back = ApiResponse::from_json(&parse(&resp.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back, resp);
        // The trace request carries its id.
        let req = ApiRequest::Trace { id: "a1b2c3".into() };
        let back = ApiRequest::from_json(&parse(&req.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back, req);
    }

    #[test]
    fn events_overflow_is_lenient() {
        let resp = ApiResponse::Events { events: vec![], next: 7, dropped: 2, overflow: 9 };
        let back = ApiResponse::from_json(&parse(&resp.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back, resp);
        // Older peers omit `overflow`; it defaults to 0 instead of erroring.
        let legacy = r#"{"v":1,"kind":"events","data":{"events":[],"next":7,"dropped":0}}"#;
        match ApiResponse::from_json(&parse(legacy).unwrap()).unwrap() {
            ApiResponse::Events { overflow, next, .. } => {
                assert_eq!(overflow, 0);
                assert_eq!(next, 7);
            }
            other => panic!("{:?}", other),
        }
    }
}
