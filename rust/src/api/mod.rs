//! The platform API, in three layers (paper Figure 1 + §3.2's "the web
//! UI wraps NSML-CLI"):
//!
//! * **Facade** ([`NsmlPlatform`], this module) — owns and wires every
//!   subsystem: scheduler (with leader election), simulated cluster,
//!   containerized substrate, storage containers, session management,
//!   leaderboard and the PJRT runtime. Typed, in-process, the only place
//!   subsystems are composed.
//! * **Service** ([`PlatformService`], [`service`]) — the single command/
//!   query entry point: `dispatch(ApiRequest) -> ApiResponse`. All
//!   researcher-facing actions (run, pause, resume-with-new-lr, stop,
//!   infer, board queries, trial batches, …) flow through it; mutations
//!   are audited into the event log. [`ServiceHandle`] +
//!   [`service_channel`] carry dispatches across threads for clients
//!   (like the web server) that cannot own the platform.
//! * **Wire** ([`wire`]) — the serializable vocabulary: exhaustive
//!   [`ApiRequest`]/[`ApiResponse`] enums with JSON round-trips via
//!   `util::json`, versioned envelopes ([`API_VERSION`]) and the uniform
//!   [`ApiError`] `{code, message, session?}` envelope.
//!
//! Consumers: the CLI builds requests and renders responses; the web UI
//! exposes the same verbs as `POST /api/v1/<verb>`; examples and benches
//! drive control-plane actions through `dispatch` too. Only queries that
//! need rich in-process data (metric series, rendering) read the facade
//! directly.
//!
//! Observability flows through the typed event bus
//! ([`crate::events::EventBus`]): subsystems publish structured events,
//! and the facade's derived-consumer subscription (pumped each `drive`
//! round) turns `done` transitions into leaderboard submissions and
//! util/worker samples into [`UtilizationMonitor`](crate::cluster::UtilizationMonitor)
//! records — those views are projections of the event stream, not
//! independently mutated state. `events_since` pages the same stream
//! over the wire.
//!
//! Multi-tenancy ([`crate::tenancy`], `[tenancy]` config): submissions
//! wait in a per-user weighted fair-share admission queue in front of
//! the scheduler; `run` enqueues, and every capacity change
//! (completion, stop, failure, preemption) pumps the queue through
//! [`Master::can_place`](crate::scheduler::Master::can_place). The
//! per-user GPU-second accountant is another derived bus consumer,
//! and each drive round enforces quotas preemptively: an over-quota
//! user's youngest running session is checkpointed, paused and parked
//! for re-admission when someone else is waiting. Decisions publish as
//! `admission` events; `tenant_report` / `set_quota` (wire),
//! `GET /api/v1/tenants` (web) and `nsml tenants` / `nsml quota`
//! (CLI) expose and edit the state.
//!
//! Durability ([`crate::durability`], `[durability]` config): a
//! dedicated bus subscription feeds an append-only fsync-batched WAL,
//! so every state transition, metric, checkpoint and admission
//! decision survives a crash without the old per-mutation
//! `state.json` rewrite. Every `snapshot_every` records the facade
//! takes a compacted snapshot (`persist::save` + usage-ledger
//! metadata) and rotates the WAL; startup recovery replays the WAL
//! tail through the same consumer paths ([`durability::replay`]),
//! re-indexes post-snapshot checkpoints from the object store, and
//! requeues sessions that were in flight. [`NsmlPlatform::gc`] runs
//! mark-and-sweep over the object store after each snapshot (and via
//! `nsml gc`), attributing per-tenant storage bytes. Status surfaces:
//! `durability_status` (wire), `GET /api/v1/durability` (web).
//!
//! Service mode (`nsml serve`, `[service]` config): the platform can
//! run as an always-on daemon. [`PlatformService::run_daemon`]
//! alternates [`NsmlPlatform::drive_round`] with draining queued
//! [`ServiceCall`]s — training advances continuously with no client
//! `drive`s, and every dispatch is answered between rounds
//! (pause-the-loop: a mutation never races a round). The web front end
//! is a bounded worker pool speaking HTTP/1.1 keep-alive, with
//! `GET /api/v1/events/stream` streaming the bus as Server-Sent
//! Events. Loop telemetry (rounds, last-round duration, rounds/sec,
//! dispatches) publishes as `loop` events and reads back through the
//! `service_status` verb / `GET /api/v1/service`.
//!
//! Concurrency model: platform control state (cluster, scheduler,
//! sessions, leaderboard) is thread-safe, and model *execution* runs on
//! the [`crate::executor`] worker pool — each worker thread owns its
//! live runs and a thread-local PJRT engine, mirroring how each NSML ML
//! container owns its GPUs while the master merely coordinates. The
//! facade stays the single coordinator: `drive` fans a step budget out
//! to every worker and joins on the outcomes, idle workers steal
//! pending sessions from loaded peers before stepping (configurable via
//! `[executor] work_steal`), and session-control verbs are routed to
//! the owning worker's mailbox — which re-homes when a session is
//! stolen. Each drive round also records per-worker telemetry
//! (busy-time, live sessions, queue depth, steals) into the
//! [`UtilizationMonitor`](crate::cluster::UtilizationMonitor), surfaced
//! by the `executor_status` verb, `nsml cluster` and
//! `GET /api/v1/executor`. The channel-based [`ServiceHandle`] still
//! carries dispatches from clients (like the web server) that cannot
//! own the platform.

mod config;
pub mod persist;
pub mod service;
mod trial;
pub mod wire;

pub use config::PlatformConfig;
pub use service::{service_channel, DaemonOpts, PlatformService, ServiceCall, ServiceHandle};
pub use trial::PlatformTrialRunner;
pub use wire::{
    ApiError, ApiRequest, ApiResponse, BoardRow, ClusterView, DurabilityView, EndpointVersionView,
    EndpointView, ErrorCode, ExecutorStats, HistogramView, MetricPointView, MetricsReportView,
    NodeStatusView, RunParams, ServiceStatusView, SessionView, SpanView, TenantView, TraceView,
    TrialSpec, WorkerStatView, ALL_KINDS, ALL_VERBS, API_VERSION,
};

use crate::cluster::Cluster;
use crate::container::{ContainerManager, ImageSpec};
use crate::data::{dataset_for, model_for_dataset, register_all};
use crate::durability::{self, Durability, SnapshotMeta, WalScan};
use crate::events::{EventFilter, EventKind, EventLog, Level, Subscription};
use crate::executor::{ExecutorPool, SessionCommand, SessionOutcome, WorkerCtx};
use crate::leaderboard::{Leaderboard, Submission};
use crate::obs::Obs;
use crate::runtime::{Engine, TensorData, TrainableModel};
use crate::scheduler::{ElectionGroup, JobSpec, Master, SubmitOutcome};
use crate::serving::{
    AutoscalePolicy, EndpointRegistry, PendingInfer, ReplicaManager, ScaleDecision, ServeReply,
    ServeWork, ServedModel, ServingQueue,
};
use crate::session::{SessionRecord, SessionSpec, SessionState, SessionStore};
use crate::storage::{CheckpointStore, DatasetRegistry, ObjectStore};
use crate::tenancy::{PendingAdmission, Tenancy};
use crate::util::clock::{sim_clock, SharedClock, SimClock};
use crate::util::idgen;
use anyhow::{anyhow, Context, Result};
use std::sync::Arc;

/// Options for `nsml run` (subset of the paper's CLI flags).
#[derive(Debug, Clone)]
pub struct RunOpts {
    pub gpus: usize,
    pub total_steps: u64,
    pub lr: Option<f64>,
    pub seed: u64,
    pub use_scan: bool,
    pub priority: crate::scheduler::Priority,
    pub checkpoint_every: u64,
    pub eval_every: u64,
}

impl Default for RunOpts {
    fn default() -> RunOpts {
        RunOpts {
            gpus: 1,
            total_steps: 200,
            lr: None,
            seed: 0,
            use_scan: false,
            priority: crate::scheduler::Priority::Normal,
            checkpoint_every: 50,
            eval_every: 25,
        }
    }
}

/// The assembled platform.
pub struct NsmlPlatform {
    pub config: PlatformConfig,
    pub clock: SharedClock,
    pub sim: SimClock,
    pub events: EventLog,
    pub cluster: Cluster,
    pub master: Master,
    pub election: ElectionGroup,
    pub containers: ContainerManager,
    pub objects: ObjectStore,
    pub datasets: DatasetRegistry,
    pub checkpoints: CheckpointStore,
    pub sessions: SessionStore,
    pub leaderboard: Leaderboard,
    /// Multi-tenant fair share: per-user quotas, the weighted
    /// admission queue in front of the scheduler, and the event-bus
    /// derived GPU-second accountant (`[tenancy]` config).
    pub tenancy: Tenancy,
    /// Utilization/queue time series sampled by the drive loop (§3.1).
    pub monitor: crate::cluster::UtilizationMonitor,
    /// Named serving endpoints: promoted checkpoints with a version
    /// history (`nsml promote`, roll forward/back). Persisted in both
    /// the snapshot and the WAL (`EndpointChanged` events).
    pub endpoints: EndpointRegistry,
    /// Per-endpoint micro-batching queue for `serve_infer`. Filled by
    /// dispatch, flushed by the drive loop (`[serving]` config).
    serving: ServingQueue,
    /// Replica placement for the executor serve lane: which workers
    /// host each endpoint, the shared params cache, and the in-flight
    /// gate that registry mutations drain before moving the cursor.
    replicas: ReplicaManager,
    /// Scale-up/down thresholds from `[serving]`. `max_replicas = 0`
    /// disables the serve lane entirely and batches execute inline on
    /// the platform thread (the pre-replica baseline).
    autoscale: AutoscalePolicy,
    /// Loaded serving models keyed by `(endpoint, version)` — only the
    /// inline fallback path (`max_replicas = 0`) reads this; with the
    /// serve lane on, workers keep their own per-thread replicas.
    served_models: std::cell::RefCell<std::collections::HashMap<(String, u64), ServedModel>>,
    /// Facade-local engine for inference/manifest queries. Training
    /// engines live inside the executor workers.
    engine: Arc<Engine>,
    /// The parallel session execution pool; live runs are owned by its
    /// worker threads, keyed here only through the routing table.
    executor: Arc<ExecutorPool>,
    /// Cursor for the derived-view consumers. Pumped after every drive
    /// round, it is the *only* write path into the leaderboard and the
    /// utilization monitor: `done` state events become board
    /// submissions, `util`/`worker` sample events become monitor
    /// records. Everything those views show was first a bus event.
    consumers: std::sync::Mutex<Subscription>,
    /// The autoscaler's private bus cursor, filtered to `InferServed`:
    /// batches answered from worker threads since the last drive round
    /// mark their endpoint busy, so the idle clock only starts once
    /// traffic has truly stopped.
    autoscale_sub: std::sync::Mutex<Subscription>,
    /// Event-sourced durability: WAL + snapshots + GC. `None` when no
    /// state dir is configured or `[durability] enabled = false`.
    durability: Option<Durability>,
    /// Observability: the metrics registry and the request-trace ring
    /// (`[obs]` config). Populated by [`pump_obs`](Self::pump_obs) (a
    /// derived bus consumer rolled forward each drive round) plus
    /// direct instrumentation on the dispatch/HTTP/WAL paths.
    pub obs: Obs,
    /// The obs pump's private bus cursor (unfiltered: it rolls every
    /// event kind into the registry).
    obs_sub: std::sync::Mutex<Subscription>,
    /// Daemon drive-loop telemetry (rounds, durations, dispatches),
    /// read back through the `service_status` verb. Rounds tick only
    /// under [`PlatformService::run_daemon`]; the dispatch counter
    /// also ticks for calls answered by [`PlatformService::serve`].
    loop_stats: std::sync::Mutex<LoopStats>,
}

/// Mutable daemon-loop counters behind [`NsmlPlatform::service_status`].
#[derive(Debug, Default)]
struct LoopStats {
    running: bool,
    rounds: u64,
    last_round_ms: f64,
    progressed_total: u64,
    dispatches: u64,
    /// Wall-clock loop start; rounds/sec is measured against real time
    /// (the drive loop's throughput), not virtual time.
    started: Option<std::time::Instant>,
}

impl NsmlPlatform {
    /// Assemble a platform from config. Loads persisted state if a state
    /// dir is configured.
    pub fn new(config: PlatformConfig) -> Result<NsmlPlatform> {
        // Virtual time: container/scheduler latencies advance a SimClock,
        // so tests/benches are deterministic and instant while relative
        // costs (cold vs warm start, failover) stay measurable.
        let (clock, sim) = sim_clock();
        let events = EventLog::new(clock.clone())
            .with_echo(config.event_echo)
            .with_capacity(config.event_capacity);
        // Subscribe the derived-view consumers before any subsystem can
        // publish, so no completion or sample event is ever missed.
        let consumers = std::sync::Mutex::new(events.bus().subscribe());
        let autoscale_sub = std::sync::Mutex::new(
            events.bus().subscribe().with_filter(EventFilter::default().with_kind("infer")),
        );
        let obs = Obs::new(clock.clone(), config.obs, config.obs_trace_capacity);
        let obs_sub = std::sync::Mutex::new(events.bus().subscribe());
        // The WAL subscription has the same requirement — and opening
        // the log now also hands us last run's tail for recovery.
        let mut recovery = None;
        let durability = match &config.state_dir {
            Some(dir) if config.durability => {
                let (d, scan, meta) = Durability::open(
                    dir,
                    events.bus().subscribe(),
                    config.wal_fsync_every,
                    config.snapshot_every,
                    config.gc,
                )?;
                recovery = Some((scan, meta));
                Some(d)
            }
            _ => None,
        };
        if let Some(d) = &durability {
            d.set_metrics(
                obs.metrics.histogram("nsml_wal_append_ms", &[]),
                obs.metrics.histogram("nsml_wal_fsync_ms", &[]),
            );
        }
        let cluster = Cluster::homogeneous(
            clock.clone(),
            events.clone(),
            config.nodes,
            config.gpus_per_node,
            config.gpu_mem_gb,
        );
        let policy = crate::scheduler::policy_by_name(&config.policy, config.seed);
        let mut master = Master::new(cluster.clone(), policy, events.clone());
        master.fast_path = config.fast_path;
        let master = master.with_skip_window(config.skip_window);
        let tenancy = Tenancy::new(config.tenant_quota, &config.tenant_users);
        let election = ElectionGroup::new(clock.clone(), events.clone(), config.sched_replicas);
        let containers = ContainerManager::new(clock.clone(), events.clone(), config.latency.clone());
        let objects = match &config.state_dir {
            Some(dir) => ObjectStore::filesystem(dir.join("objects"))?,
            None => ObjectStore::memory(),
        };
        let datasets = DatasetRegistry::new(objects.clone());
        let checkpoints = CheckpointStore::new(objects.clone());
        let engine = Arc::new(Engine::new(&config.artifacts_dir).with_context(|| {
            format!("loading artifacts from {} (run `make artifacts`)", config.artifacts_dir.display())
        })?);
        let sessions = SessionStore::new();
        let executor = Arc::new(ExecutorPool::with_stealing(
            config.workers,
            WorkerCtx {
                artifacts_dir: config.artifacts_dir.clone(),
                checkpoints: checkpoints.clone(),
                sessions: sessions.clone(),
                events: events.clone(),
                clock: clock.clone(),
            },
            config.work_steal,
        ));
        let platform = NsmlPlatform {
            clock,
            sim,
            events,
            cluster,
            master,
            election,
            containers,
            objects,
            datasets,
            checkpoints,
            sessions,
            leaderboard: Leaderboard::new(),
            tenancy,
            monitor: crate::cluster::UtilizationMonitor::new(),
            endpoints: EndpointRegistry::new(),
            serving: ServingQueue::new(config.serving_max_batch, config.serving_max_wait_ms),
            replicas: ReplicaManager::new(config.workers),
            autoscale: AutoscalePolicy::new(
                config.serving_min_replicas,
                config.serving_max_replicas,
                config.serving_scale_up_queue_depth,
                config.serving_scale_down_idle_ms,
            ),
            served_models: std::cell::RefCell::new(std::collections::HashMap::new()),
            engine,
            executor,
            consumers,
            autoscale_sub,
            durability,
            obs,
            obs_sub,
            loop_stats: std::sync::Mutex::new(LoopStats::default()),
            config,
        };
        platform.bootstrap()?;
        if platform.config.state_dir.is_some() {
            platform.load_state(recovery)?;
        }
        Ok(platform)
    }

    /// Register the built-in datasets + their leaderboards.
    fn bootstrap(&self) -> Result<()> {
        register_all(&self.datasets, &self.config.system_user)?;
        for name in self.engine.manifest().model_names() {
            let m = self.engine.manifest().model(&name)?;
            self.leaderboard.ensure_board(dataset_for(&name), &m.metric_name, m.lower_is_better);
        }
        Ok(())
    }

    pub fn engine(&self) -> &Arc<Engine> {
        &self.engine
    }

    /// The parallel session execution pool.
    pub fn executor(&self) -> &Arc<ExecutorPool> {
        &self.executor
    }

    /// A fresh worker pool sharing this platform's stores — automl
    /// searches run their trial sessions here so the main pool's step
    /// rounds never touch them.
    pub fn new_trial_pool(&self) -> Arc<ExecutorPool> {
        Arc::new(ExecutorPool::with_stealing(
            self.config.workers,
            self.worker_ctx(),
            self.config.work_steal,
        ))
    }

    fn worker_ctx(&self) -> WorkerCtx {
        WorkerCtx {
            artifacts_dir: self.config.artifacts_dir.clone(),
            checkpoints: self.checkpoints.clone(),
            sessions: self.sessions.clone(),
            events: self.events.clone(),
            clock: self.clock.clone(),
        }
    }

    // ------------------------------------------------------------------
    // nsml run
    // ------------------------------------------------------------------

    /// Submit a training session (the `nsml run main.py -d DATASET` flow):
    /// packs nothing here (code packing is exercised via storage::codepack
    /// by the CLI), submits a job, and starts training when placed.
    pub fn run(&self, user: &str, dataset: &str, opts: RunOpts) -> Result<String> {
        let model = model_for_dataset(dataset)
            .ok_or_else(|| anyhow!("no model registered for dataset '{}'", dataset))?;
        self.datasets.get(dataset, user)?; // visibility check
        // A job no node could ever fit would wedge its user's FIFO
        // admission lane forever (the lane has no skip window by
        // design — a user's own submissions stay ordered). Fail fast
        // instead, like an unknown model does. Alive nodes set the
        // bar; if the whole cluster is down, fall back to the full
        // shape (nodes revive, a total outage should queue, not 400).
        let snapshot = self.cluster.snapshot();
        let largest = snapshot
            .iter()
            .filter(|n| n.alive)
            .map(|n| n.total_gpus)
            .max()
            .or_else(|| snapshot.iter().map(|n| n.total_gpus).max())
            .unwrap_or(0);
        if opts.gpus > largest {
            return Err(anyhow!(
                "session requests {} GPUs but the largest node has {}",
                opts.gpus,
                largest
            ));
        }
        let manifest = self.engine.manifest().model(model)?;
        let id = idgen::session_id(user, dataset);
        let mut spec = SessionSpec::new(&id, user, dataset, model);
        spec.gpus = opts.gpus;
        spec.priority = opts.priority;
        spec.total_steps = opts.total_steps;
        spec.lr = opts.lr.unwrap_or(manifest.default_lr);
        spec.seed = opts.seed;
        spec.checkpoint_every = opts.checkpoint_every;
        spec.eval_every = opts.eval_every;
        spec.use_scan = opts.use_scan;

        self.sessions.insert(SessionRecord::new(spec.clone(), self.clock.now_ms()));
        self.events.bus().publish(
            Level::Debug,
            "platform",
            &id,
            EventKind::StateChanged { from: "new".into(), to: "queued".into(), step: 0 },
        );
        self.tenancy.registry.note_user(user);
        self.tenancy.accountant.register(&id, user, opts.gpus);
        let job = JobSpec {
            id: id.clone(),
            user: user.to_string(),
            dataset: dataset.to_string(),
            req: crate::cluster::ResourceReq::gpus(opts.gpus),
            priority: opts.priority,
        };
        if self.config.tenancy {
            // Fair share: the submission waits in its user's admission
            // lane until quota and capacity both say yes.
            self.tenancy.admission.enqueue(PendingAdmission { job, resume: false });
            self.pump_admission()?;
        } else {
            match self.master.submit(job) {
                SubmitOutcome::PlacedImmediately(node) => {
                    self.prepare_and_start(&id, node)?;
                }
                SubmitOutcome::Queued { position } => {
                    self.events.info("platform", &id, format!("queued at position {}", position));
                }
            }
        }
        Ok(id)
    }

    /// Jobs waiting anywhere: the fair-share admission queue plus the
    /// scheduler's own queue (allocation races, orphan requeues).
    pub fn queued_total(&self) -> usize {
        self.master.queue_len() + self.tenancy.admission.len()
    }

    /// Offer admissible pending submissions to the scheduler in
    /// weighted fair-share order. Runs after every submission and
    /// whenever capacity frees (completion, stop, failure, preemption)
    /// — with tenancy disabled it is a no-op.
    pub fn pump_admission(&self) -> Result<()> {
        if !self.config.tenancy {
            return Ok(());
        }
        loop {
            let now = self.clock.now_ms();
            let waiting = self.tenancy.admission.users_waiting();
            if waiting.is_empty() {
                return Ok(());
            }
            let pop = self.tenancy.admission.pop_next(
                |user| {
                    let q = self.tenancy.registry.quota_of(user);
                    (q.class, q.weight)
                },
                |user, p| self.admissible(user, p, &waiting, now),
            );
            for (user, session) in &pop.deferred {
                self.events.bus().publish(
                    Level::Debug,
                    "tenancy",
                    session,
                    EventKind::AdmissionDecided { decision: "defer".into(), user: user.clone() },
                );
            }
            // Work-conserving fallback: two over-budget users make
            // each other "contended", so the strict gate refuses both
            // and the cluster would idle with work waiting. When no
            // quota-clear waiter is being held out (the capacity is
            // not morally anyone else's), admit the fair-share pick
            // with the budget gate relaxed — hard limits
            // (max_concurrent/max_gpus) still hold, and the strict
            // pass already examined every head, so no new defer
            // events surface here.
            let admitted = match pop.admitted {
                Some(p) => Some(p),
                None => {
                    let clear = self.quota_clear_waiters(&waiting, now);
                    self.tenancy
                        .admission
                        .pop_next(
                            |user| {
                                let q = self.tenancy.registry.quota_of(user);
                                (q.class, q.weight)
                            },
                            |user, p| {
                                !clear.iter().any(|v| v != user)
                                    && self.quota_admissible(user, p, false, now)
                                    && self.master.can_place(&p.job.req)
                            },
                        )
                        .admitted
                }
            };
            let Some(p) = admitted else {
                return Ok(());
            };
            let id = p.job.id.clone();
            self.tenancy.registry.charge(&id, &p.job.user, p.job.req.gpus);
            self.events.bus().publish(
                Level::Info,
                "tenancy",
                &id,
                EventKind::AdmissionDecided {
                    decision: if p.resume { "readmit" } else { "admit" }.into(),
                    user: p.job.user.clone(),
                },
            );
            match self.master.submit(p.job) {
                SubmitOutcome::PlacedImmediately(node) => self.prepare_and_start(&id, node)?,
                // The master queued instead of placing (fast path off,
                // or its queue is non-empty from an orphan requeue /
                // allocation race). Capacity is spoken for but not yet
                // allocated, so can_place would keep saying yes — stop
                // admitting now or the whole burst would drain into the
                // master FIFO and bypass fair-share ordering. The next
                // pump (every drive round and capacity release) admits
                // the next head.
                SubmitOutcome::Queued { .. } => return Ok(()),
            }
        }
    }

    /// Quota + capacity gate for one pending submission.
    fn admissible(&self, user: &str, p: &PendingAdmission, waiting: &[String], now: u64) -> bool {
        let contended = waiting.iter().any(|u| u != user);
        self.quota_admissible(user, p, contended, now) && self.master.can_place(&p.job.req)
    }

    /// The quota half of the admission gate (capacity aside): would
    /// `user`'s submission be allowed under their limits right now?
    /// An exhausted GPU-second budget only blocks while `contended` —
    /// the admission queue stays work-conserving.
    fn quota_admissible(&self, user: &str, p: &PendingAdmission, contended: bool, now: u64) -> bool {
        let q = self.tenancy.registry.quota_of(user);
        let (sessions, gpus) = self.tenancy.registry.occupancy(user);
        if q.max_concurrent > 0 && sessions >= q.max_concurrent {
            return false;
        }
        if q.max_gpus > 0 && gpus + p.job.req.gpus > q.max_gpus {
            return false;
        }
        if contended
            && q.gpu_second_budget > 0.0
            && self.tenancy.accountant.usage_at(user, now) >= q.gpu_second_budget
        {
            return false;
        }
        true
    }

    /// The waiting users whose lane head passes the full (contended)
    /// quota gate right now — the users idle or freed capacity is
    /// being held for. Shared by the admission fallback and the
    /// preemption-eligibility check. (Computed outside any admission
    /// lock: `head_of` takes it.)
    fn quota_clear_waiters(&self, waiting: &[String], now: u64) -> Vec<String> {
        waiting
            .iter()
            .filter(|u| {
                self.tenancy
                    .admission
                    .head_of(u)
                    .map(|head| self.quota_admissible(u, &head, true, now))
                    .unwrap_or(false)
            })
            .cloned()
            .collect()
    }

    /// Container bring-up + session start (or auto-resume) on a node.
    fn prepare_and_start(&self, id: &str, node: crate::cluster::NodeId) -> Result<()> {
        let rec = self.sessions.get(id).ok_or_else(|| anyhow!("unknown session {}", id))?;
        self.publish_transition(id, Some((rec.state, rec.steps_done)), "preparing", Level::Debug);
        self.sessions.update(id, |r| {
            r.state = SessionState::Preparing;
            r.node = Some(node);
        });
        let dataset_info = self.datasets.get(&rec.spec.dataset, &rec.spec.user)?;
        let image = match rec.spec.model.as_str() {
            "mnist_mlp" | "emotion_cnn" => ImageSpec::tensorflow(),
            _ => ImageSpec::pytorch(),
        };
        let container =
            self.containers.launch(id, node, &image, &rec.spec.dataset, dataset_info.nominal_size_gb);
        self.sessions.update(id, |r| r.container = Some(container.id.clone()));

        let has_ckpt = self.checkpoints.latest(id).is_some();
        if has_ckpt {
            if rec.preempted {
                // Preemption resume: quota enforcement, not a failure —
                // clear the flag and leave `recoveries` untouched.
                self.sessions.update(id, |r| r.preempted = false);
            } else {
                // Auto-recovery (§4.2): resume from the last backup.
                self.sessions.update(id, |r| r.recoveries += 1);
            }
        }
        // Hand the run to the executor: the scheduler's node choice maps
        // onto a worker, which constructs the (fresh or resumed) run on
        // its own thread and acks before we return.
        self.executor.submit(rec.spec.clone(), has_ckpt, Some(node))?;
        Ok(())
    }

    // ------------------------------------------------------------------
    // The platform event loop
    // ------------------------------------------------------------------

    /// Drive every active session forward by up to `chunk` steps, handle
    /// completions/failures and start newly placed jobs. Returns the
    /// number of sessions that made progress.
    pub fn drive(&self, chunk: u64) -> Result<usize> {
        // 0. Alive slaves heartbeat continuously in the real system; model
        //    that before staleness checks (virtual time may have jumped a
        //    lot during container bring-up). Nodes killed by failure
        //    injection stay dead — heartbeat_all skips them.
        self.cluster.heartbeat_all();
        for r in self.election.replica_ids() {
            self.election.heartbeat(r); // no-op for killed replicas
        }
        // 1. Cluster maintenance: dead nodes orphan their jobs.
        let orphans = self.cluster.reap_dead();
        if !orphans.is_empty() {
            self.on_orphans(&orphans);
        }
        // 2. Leader lease check (a healthy leader is a no-op).
        self.election.tick();

        // 3. Step active runs — one parallel round across the worker
        //    pool. Workers step their sessions concurrently; the round
        //    has joined by the time step_round returns, so drive keeps
        //    its synchronous contract (all progress done on return).
        let mut progressed = 0;
        for (id, outcome) in self.executor.step_round(chunk) {
            match outcome {
                SessionOutcome::Skipped => {} // externally paused/stopped
                SessionOutcome::Progressed => progressed += 1,
                SessionOutcome::Completed => {
                    progressed += 1;
                    self.finalize(&id)?;
                }
                SessionOutcome::Failed(e) => {
                    progressed += 1;
                    self.events.error("platform", &id, format!("session failed: {}", e));
                    // Training failures flip the record inside the run
                    // (which publishes the failed transition itself);
                    // materialization failures (bad resume checkpoint,
                    // engine init) reach here with it still
                    // non-terminal, so the transition is published here.
                    let prev = self.sessions.get(&id).map(|r| (r.state, r.steps_done));
                    self.sessions.mark_failed(&id, &e);
                    self.publish_transition(&id, prev, "failed", Level::Error);
                    self.release_and_backfill(&id)?;
                }
            }
        }

        // 4. Fair-share quota enforcement (may preempt an over-quota
        //    user's youngest session for a waiting one), then place
        //    queued work: admission lanes first, then the master's own
        //    queue (orphan requeues, allocation races).
        self.enforce_quotas()?;
        self.pump_admission()?;
        for (job, node) in self.master.pump() {
            self.prepare_and_start(&job.id, node)?;
        }

        // 5. Ops telemetry rides the bus: publish one cluster-level
        //    sample and one per-worker snapshot for this round, then…
        let (_, free) = self.cluster.gpu_totals();
        self.events.bus().publish(
            Level::Debug,
            "platform",
            "",
            EventKind::UtilizationSampled {
                utilization: self.cluster.utilization(),
                free_gpus: free,
                alive_nodes: self.cluster.alive_count(),
                queue_depth: self.queued_total(),
            },
        );
        for s in self.executor.stats() {
            self.events.bus().publish(
                Level::Debug,
                "executor",
                "",
                EventKind::WorkerSampled {
                    worker: s.worker,
                    busy_ms: s.busy_ms,
                    live_sessions: s.live_sessions,
                    queue_depth: s.queue_depth,
                    steals: s.steals,
                },
            );
        }
        // 6. Serving: let the autoscaler react to this round's queue
        //    depth and last round's `InferServed` telemetry (one step
        //    per endpoint per round), then flush due micro-batches onto
        //    the executor serve lane — full batches immediately,
        //    partial ones once the oldest request has waited
        //    `[serving] max_wait_ms` of virtual time.
        self.autoscale_tick();
        self.pump_serving(false);
        // 7. …pump the derived consumers: completions reach the
        //    leaderboard, samples reach the monitor — via the bus, not
        //    direct calls.
        self.pump_consumers();
        self.pump_obs();
        // 8. …and the durability consumer: durable events reach the
        //    WAL, and every `snapshot_every` records the world dump is
        //    compacted and the log rotates.
        self.pump_durability()?;
        Ok(progressed)
    }

    /// Drain the WAL subscription into the log; take a snapshot when
    /// the cadence says so — or immediately when the subscription
    /// lagged (ring overflow), because a full snapshot is the only way
    /// to close the resulting WAL gap losslessly.
    fn pump_durability(&self) -> Result<()> {
        let Some(d) = &self.durability else { return Ok(()) };
        let out = d.pump()?;
        if out.overflowed {
            self.events.warn(
                "durability",
                "",
                format!(
                    "WAL subscription lag: {} events aged out unlogged; snapshotting to close the gap",
                    d.stats().wal_dropped
                ),
            );
        }
        if out.overflowed || out.snapshot_due {
            self.snapshot_now()?;
        }
        Ok(())
    }

    /// Drain the consumer subscription into the derived views. This is
    /// the single write path for the leaderboard and the utilization
    /// monitor (acceptance: no direct submit/record calls from session
    /// or executor paths).
    fn pump_consumers(&self) {
        // Poll under the lock, process outside it: submissions take the
        // leaderboard/session locks and must not nest inside ours.
        let (drained, newly_dropped) = {
            let mut sub = self.consumers.lock().unwrap();
            let before = sub.dropped();
            let events = sub.poll();
            (events, sub.dropped() - before)
        };
        for e in drained {
            // The GPU-second accountant is a derived consumer too:
            // running-interval open/close rides the same state events.
            self.tenancy.accountant.observe(&e);
            match &e.kind {
                EventKind::StateChanged { to, .. } if to == "done" => {
                    self.submit_completed(&e.subject, e.at_ms);
                }
                EventKind::UtilizationSampled {
                    utilization,
                    free_gpus,
                    alive_nodes,
                    queue_depth,
                } => {
                    self.monitor.record_sample(crate::cluster::monitor::Sample {
                        at_ms: e.at_ms,
                        utilization: *utilization,
                        free_gpus: *free_gpus,
                        alive_nodes: *alive_nodes,
                        queue_depth: *queue_depth,
                    });
                }
                EventKind::WorkerSampled {
                    worker,
                    busy_ms,
                    live_sessions,
                    queue_depth,
                    steals,
                } => {
                    self.monitor.record_worker(crate::cluster::monitor::WorkerSample {
                        at_ms: e.at_ms,
                        worker: *worker,
                        busy_ms: *busy_ms,
                        live_sessions: *live_sessions,
                        queue_depth: *queue_depth,
                        steals: *steals,
                    });
                }
                _ => {}
            }
        }
        // Ring overflow between pumps could have aged out a `done`
        // event before we read it — a completion must never miss the
        // leaderboard, so reconcile every Done record (submit keeps the
        // better score, so resubmitting already-ranked sessions is a
        // no-op). Lost util/worker samples are accepted: telemetry is a
        // lossy series by design.
        if newly_dropped > 0 {
            self.events.warn(
                "platform",
                "",
                format!("consumer lag: {} events aged out unread; reconciling", newly_dropped),
            );
            for rec in self.sessions.by_state(SessionState::Done) {
                // Stamp with the real completion time, not the
                // reconcile time — tie-breaks rank earlier finishers
                // first even when their done event was dropped.
                let at_ms = rec.finished_at_ms.unwrap_or_else(|| self.clock.now_ms());
                self.submit_completed(&rec.spec.id, at_ms);
            }
            // The accountant needs the same care: a lost exit event
            // would leave a GPU-second interval accruing forever
            // (reading the owner as permanently over budget). Any
            // session whose record stopped running gets its interval
            // settled — at the recorded finish time when known.
            let now = self.clock.now_ms();
            for rec in self.sessions.list() {
                if rec.state != SessionState::Running {
                    self.tenancy
                        .accountant
                        .close_if_open(&rec.spec.id, rec.finished_at_ms.unwrap_or(now));
                }
            }
        }
    }

    /// Roll the event stream into the metrics registry and the trace
    /// ring: the obs pump is another derived bus consumer, pumped once
    /// per drive round. Steals, admission decisions, replica scaling,
    /// serving latencies, loop telemetry and state transitions all
    /// become counters/gauges/histograms here; events whose subject was
    /// tagged with a trace id (a traced `run` dispatch) also land as
    /// spans. Afterwards it samples gauges the bus does not carry —
    /// sessions by state, per-tenant GPU-seconds, per-subscriber bus
    /// lag — and rotates the histogram windows so `windowed_quantile`
    /// tracks the last `[obs] window` rounds.
    fn pump_obs(&self) {
        if !self.obs.enabled() {
            return;
        }
        let m = &self.obs.metrics;
        let drained = self.obs_sub.lock().unwrap().poll();
        for e in &drained {
            // Async run-path spans: a dispatch tagged this subject, so
            // its later bus events join the trace (at event time —
            // `Tracer::get` orders by timestamp, not arrival).
            let traced = |name: String, detail: String| {
                if let Some(t) = self.obs.traces.tag_of(&e.subject) {
                    self.obs.traces.record(&t, e.at_ms, 0.0, &name, &e.source, &detail);
                }
            };
            match &e.kind {
                EventKind::WorkerStolen { .. } => m.counter("nsml_steals_total", &[]).inc(),
                EventKind::AdmissionDecided { decision, user } => {
                    m.counter("nsml_admission_total", &[("decision", decision)]).inc();
                    traced(format!("admission.{}", decision), format!("user={}", user));
                }
                EventKind::PlacementDecided { node, from_queue } => {
                    m.counter("nsml_placements_total", &[]).inc();
                    traced(
                        "placement".into(),
                        format!("node={} from_queue={}", node, from_queue),
                    );
                }
                EventKind::StateChanged { from, to, step } => {
                    m.counter("nsml_state_transitions_total", &[("to", to)]).inc();
                    traced(format!("state.{}", to), format!("from={} step={}", from, step));
                }
                EventKind::CheckpointSaved { step, .. } => {
                    m.counter("nsml_checkpoints_total", &[]).inc();
                    traced("checkpoint".into(), format!("step={}", step));
                }
                EventKind::ReplicaScaled { replicas, .. } => {
                    m.gauge("nsml_replicas", &[("endpoint", &e.subject)]).set(*replicas as f64);
                }
                EventKind::InferServed { batch, latency_ms } => {
                    m.histogram("nsml_serving_latency_ms", &[("endpoint", &e.subject)])
                        .record(*latency_ms);
                    m.histogram("nsml_serving_batch_size", &[("endpoint", &e.subject)])
                        .record(*batch as f64);
                }
                EventKind::UtilizationSampled { utilization, free_gpus, queue_depth, .. } => {
                    m.gauge("nsml_cluster_utilization", &[]).set(*utilization);
                    m.gauge("nsml_free_gpus", &[]).set(*free_gpus as f64);
                    m.gauge("nsml_queue_depth", &[]).set(*queue_depth as f64);
                }
                EventKind::LoopSampled { round_ms, rounds_per_sec, .. } => {
                    m.histogram("nsml_loop_round_ms", &[]).record(*round_ms);
                    m.gauge("nsml_loop_rounds_per_sec", &[]).set(*rounds_per_sec);
                }
                EventKind::EndpointChanged { action, .. } => {
                    m.counter("nsml_endpoint_changes_total", &[("action", action)]).inc();
                }
                _ => {}
            }
        }
        // Gauges the bus does not carry, sampled fresh each round.
        let mut by_state = std::collections::HashMap::new();
        for rec in self.sessions.list() {
            *by_state.entry(rec.state.as_str()).or_insert(0u64) += 1;
        }
        for state in ["queued", "preparing", "running", "paused", "done", "failed", "stopped"] {
            m.gauge("nsml_sessions", &[("state", state)])
                .set(*by_state.get(state).unwrap_or(&0) as f64);
        }
        let now = self.clock.now_ms();
        for user in self.tenancy.registry.users() {
            m.gauge("nsml_tenant_gpu_seconds", &[("user", &user)])
                .set(self.tenancy.accountant.usage_at(&user, now));
        }
        // Per-subscriber bus lag + lifetime ring evictions (satellite:
        // the same numbers ride `events_since` responses).
        m.gauge("nsml_bus_subscriber_dropped", &[("consumer", "views")])
            .set(self.consumers.lock().unwrap().dropped() as f64);
        m.gauge("nsml_bus_subscriber_dropped", &[("consumer", "autoscale")])
            .set(self.autoscale_sub.lock().unwrap().dropped() as f64);
        m.gauge("nsml_bus_subscriber_dropped", &[("consumer", "obs")])
            .set(self.obs_sub.lock().unwrap().dropped() as f64);
        if let Some(d) = &self.durability {
            m.gauge("nsml_bus_subscriber_dropped", &[("consumer", "wal")])
                .set(d.stats().wal_dropped as f64);
        }
        m.gauge("nsml_bus_overflow_total", &[]).set(self.events.bus().overflow() as f64);
        // Advance the quantile windows, then refresh the windowed-p99
        // serving gauges (the autoscaling roadmap's feedback signal).
        m.rotate_windows(self.config.obs_window);
        let mut worst = 0.0f64;
        for ep in self.endpoints.list() {
            let (_, p99) = self.endpoint_latency(&ep.name);
            m.gauge("nsml_serving_latency_p99_ms", &[("endpoint", &ep.name)]).set(p99);
            worst = worst.max(p99);
        }
        m.gauge("nsml_serving_latency_p99_ms", &[]).set(worst);
    }

    /// Windowed serving-latency quantiles `(p50_ms, p99_ms)` for one
    /// endpoint, over the last `[obs] window` drive rounds. Zeros
    /// before any request is served or with observability off.
    pub fn endpoint_latency(&self, name: &str) -> (f64, f64) {
        if !self.obs.enabled() {
            return (0.0, 0.0);
        }
        let h = self.obs.metrics.histogram("nsml_serving_latency_ms", &[("endpoint", name)]);
        (h.windowed_quantile(0.50), h.windowed_quantile(0.99))
    }

    /// Publish a `StateChanged` transition for `id` at `level`, given
    /// the `(state, steps)` captured *before* the store update — a
    /// record that was already terminal publishes nothing.
    fn publish_transition(
        &self,
        id: &str,
        prev: Option<(SessionState, u64)>,
        to: &str,
        level: Level,
    ) {
        if let Some((state, steps)) = prev.filter(|(s, _)| !s.is_terminal()) {
            self.events.bus().publish(
                level,
                "platform",
                id,
                EventKind::StateChanged {
                    from: state.as_str().into(),
                    to: to.to_string(),
                    step: steps,
                },
            );
        }
    }

    /// Leaderboard submission for a session whose `done` transition
    /// arrived on the bus; `at_ms` is the completion event's timestamp.
    fn submit_completed(&self, id: &str, at_ms: u64) {
        let Some(rec) = self.sessions.get(id) else { return };
        let Some(best) = rec.best_metric else { return };
        let manifest = match self.engine.manifest().model(&rec.spec.model) {
            Ok(m) => m,
            Err(e) => {
                self.events.error("platform", id, format!("board submit: {:#}", e));
                return;
            }
        };
        self.leaderboard.submit(
            &rec.spec.dataset,
            Submission {
                session: id.to_string(),
                user: rec.spec.user.clone(),
                model: rec.spec.model.clone(),
                metric_name: manifest.metric_name.clone(),
                value: best,
                step: rec.steps_done,
                at_ms,
            },
        );
    }

    /// One pump-loop round: `drive`, then advance virtual time so
    /// heartbeat/lease logic stays live between rounds. The shared body
    /// of [`run_to_completion`](Self::run_to_completion) and the CLI's
    /// `nsml logs -f` follow loop.
    pub fn drive_round(&self, chunk: u64) -> Result<usize> {
        let progressed = self.drive(chunk)?;
        self.cluster.heartbeat_all();
        if let Some((leader, _)) = self.election.leader() {
            self.election.heartbeat(leader);
        }
        self.sim.advance(10);
        Ok(progressed)
    }

    /// Run until every session is terminal (or `max_rounds` safety cap).
    pub fn run_to_completion(&self, chunk: u64, max_rounds: usize) -> Result<()> {
        for _ in 0..max_rounds {
            let pending = self
                .sessions
                .list()
                .into_iter()
                .filter(|r| !r.state.is_terminal() && r.state != SessionState::Paused)
                .count();
            if pending == 0 {
                return Ok(());
            }
            self.drive_round(chunk)?;
        }
        let stuck: Vec<String> = self
            .sessions
            .list()
            .into_iter()
            .filter(|r| !r.state.is_terminal() && r.state != SessionState::Paused)
            .map(|r| format!("{} ({}, step {}/{})", r.spec.id, r.state.as_str(), r.steps_done, r.spec.total_steps))
            .collect();
        Err(anyhow!(
            "run_to_completion: {} session(s) still pending after {} rounds of {} steps: [{}]",
            stuck.len(),
            max_rounds,
            chunk,
            stuck.join(", ")
        ))
    }

    /// Sessions the drive loop still has work for: non-terminal and
    /// not user-paused (a paused session waits for `resume`, not
    /// driving). The daemon idles on the request channel when this
    /// reaches zero.
    pub fn active_sessions(&self) -> usize {
        self.sessions
            .list()
            .into_iter()
            .filter(|r| !r.state.is_terminal() && r.state != SessionState::Paused)
            .count()
    }

    // ------------------------------------------------------------------
    // Daemon-loop telemetry (`service_status`, `GET /api/v1/service`)
    // ------------------------------------------------------------------

    /// A daemon loop is starting: reset the counters and begin the
    /// rounds/sec wall-clock.
    pub(crate) fn loop_started(&self) {
        let mut s = self.loop_stats.lock().unwrap();
        *s = LoopStats { running: true, started: Some(std::time::Instant::now()), ..LoopStats::default() };
    }

    /// The daemon loop exited; the accumulated counters stay readable.
    pub(crate) fn loop_stopped(&self) {
        self.loop_stats.lock().unwrap().running = false;
    }

    /// Record one completed daemon round and publish it on the bus.
    pub(crate) fn loop_round_done(&self, round_ms: f64, progressed: usize) {
        let (round, rounds_per_sec) = {
            let mut s = self.loop_stats.lock().unwrap();
            s.rounds += 1;
            s.last_round_ms = round_ms;
            s.progressed_total += progressed as u64;
            (s.rounds, rate_of(&s))
        };
        self.events.bus().publish(
            Level::Debug,
            "service",
            "",
            EventKind::LoopSampled {
                round,
                round_ms,
                progressed: progressed as u64,
                rounds_per_sec,
            },
        );
    }

    /// Count one request the daemon answered between rounds.
    pub(crate) fn loop_dispatched(&self) {
        self.loop_stats.lock().unwrap().dispatches += 1;
    }

    /// The daemon loop's counters for the `service_status` verb.
    pub fn service_status(&self) -> ServiceStatusView {
        let s = self.loop_stats.lock().unwrap();
        ServiceStatusView {
            running: s.running,
            rounds: s.rounds,
            last_round_ms: s.last_round_ms,
            rounds_per_sec: rate_of(&s),
            progressed_total: s.progressed_total,
            dispatches: s.dispatches,
        }
    }

    /// Session completed: release its resources. The leaderboard
    /// submission is *not* made here — the run's `done` StateChanged
    /// event drives it when the consumer subscription is pumped at the
    /// end of this drive round.
    fn finalize(&self, id: &str) -> Result<()> {
        self.release_and_backfill(id)
    }

    /// The shared tail of every completion/failure path: tear down the
    /// session's container, credit the user's fair-share charge, free
    /// the cluster allocation, and hand the capacity to queued jobs —
    /// admission lanes first, then the master's own queue.
    fn release_and_backfill(&self, id: &str) -> Result<()> {
        self.containers.stop_job(id);
        self.tenancy.registry.release(id);
        for (job, node) in self.master.complete(id) {
            self.prepare_and_start(&job.id, node)?;
        }
        self.pump_admission()
    }

    // ------------------------------------------------------------------
    // Fair-share quota enforcement (tenancy preemption)
    // ------------------------------------------------------------------

    /// Is `user` currently beyond any of their limits?
    fn over_quota(&self, user: &str, now: u64) -> bool {
        let q = self.tenancy.registry.quota_of(user);
        let (sessions, gpus) = self.tenancy.registry.occupancy(user);
        (q.max_concurrent > 0 && sessions > q.max_concurrent)
            || (q.max_gpus > 0 && gpus > q.max_gpus)
            || (q.gpu_second_budget > 0.0
                && self.tenancy.accountant.usage_at(user, now) >= q.gpu_second_budget)
    }

    /// Preemptive admission control: every drive round, an over-quota
    /// user with running work yields their *youngest* session when
    /// another user is waiting for admission. The victim is
    /// checkpointed, paused, evicted and parked at the front of its
    /// owner's admission lane ([`preempt`](Self::preempt)); it resumes
    /// from the checkpoint once the contention clears.
    fn enforce_quotas(&self) -> Result<()> {
        if !self.config.tenancy {
            return Ok(());
        }
        let waiting = self.tenancy.admission.users_waiting();
        if waiting.is_empty() {
            return Ok(());
        }
        let now = self.clock.now_ms();
        let clear = self.quota_clear_waiters(&waiting, now);
        for user in self.tenancy.registry.users() {
            // Preempting only helps if some *other* waiting user could
            // actually be admitted into the freed capacity — a waiter
            // blocked by their own quota (e.g. their max_concurrent)
            // must not trigger eviction thrash for idle GPUs.
            if !clear.iter().any(|u| *u != user) {
                continue;
            }
            if !self.over_quota(&user, now) {
                continue;
            }
            let victim = self
                .sessions
                .list()
                .into_iter()
                .filter(|r| r.spec.user == user && r.state == SessionState::Running)
                .max_by(|a, b| {
                    a.submitted_at_ms.cmp(&b.submitted_at_ms).then(a.spec.id.cmp(&b.spec.id))
                });
            if let Some(rec) = victim {
                self.preempt(&rec.spec.id)?;
            }
        }
        Ok(())
    }

    /// Checkpoint, pause and evict one running session, freeing its
    /// GPUs for waiting users. The session re-enters admission at the
    /// front of its owner's lane and auto-resumes from the checkpoint
    /// when re-admitted (the executor's pause/checkpoint machinery does
    /// the heavy lifting). Best-effort: a session that cannot be
    /// paused (already terminal, mid-materialization) is skipped with a
    /// warning, never a drive-loop failure.
    fn preempt(&self, id: &str) -> Result<()> {
        let Some(rec) = self.sessions.get(id) else { return Ok(()) };
        if let Err(e) = self.control_session(id, SessionCommand::Pause) {
            self.events.warn("tenancy", id, format!("preempt skipped: {:#}", e));
            return Ok(());
        }
        self.executor.detach(id);
        self.containers.stop_job(id);
        self.tenancy.registry.release(id);
        let prev = self.sessions.get(id).map(|r| (r.state, r.steps_done));
        self.sessions.update(id, |r| {
            if !r.state.is_terminal() {
                r.state = SessionState::Queued;
                r.node = None;
                r.preempted = true;
                r.preemptions += 1;
            }
        });
        self.publish_transition(id, prev, "queued", Level::Warn);
        self.events.bus().publish(
            Level::Warn,
            "tenancy",
            id,
            EventKind::AdmissionDecided { decision: "preempt".into(), user: rec.spec.user.clone() },
        );
        let job = JobSpec {
            id: id.to_string(),
            user: rec.spec.user.clone(),
            dataset: rec.spec.dataset.clone(),
            req: crate::cluster::ResourceReq::gpus(rec.spec.gpus),
            priority: rec.spec.priority,
        };
        self.tenancy.admission.enqueue_front(PendingAdmission { job, resume: true });
        for (job, node) in self.master.complete(id) {
            self.prepare_and_start(&job.id, node)?;
        }
        self.pump_admission()
    }

    /// Node-failure fallout: requeue affected sessions; they auto-resume
    /// from checkpoints when re-placed.
    fn on_orphans(&self, orphans: &[String]) {
        for id in orphans {
            self.executor.detach(id);
            self.containers.stop_job(id);
            let prev = self.sessions.get(id).map(|r| (r.state, r.steps_done));
            self.sessions.update(id, |r| {
                if !r.state.is_terminal() {
                    r.state = SessionState::Queued;
                    r.node = None;
                }
            });
            self.publish_transition(id, prev, "queued", Level::Warn);
        }
        let (_requeued, placed) = self.master.handle_orphans(orphans);
        for (job, node) in placed {
            let _ = self.prepare_and_start(&job.id, node);
        }
    }

    /// Inject a node failure (drills + tests). Affected sessions recover.
    pub fn kill_node(&self, node: crate::cluster::NodeId) {
        let orphans = self.cluster.kill_node(node);
        self.on_orphans(&orphans);
    }

    // ------------------------------------------------------------------
    // Session control (pause / edit / resume / stop — §3.3)
    // ------------------------------------------------------------------

    /// Pause a running session (checkpoints first). The command is
    /// routed to the owning worker's mailbox and acked synchronously.
    pub fn pause(&self, id: &str) -> Result<()> {
        self.control_session(id, SessionCommand::Pause)
    }

    /// Resume a paused session, optionally with a new learning rate —
    /// the paper's in-training hyperparameter tuning.
    pub fn resume(&self, id: &str, new_lr: Option<f64>) -> Result<()> {
        self.control_session(id, SessionCommand::Resume { lr: new_lr })?;
        let prev = self
            .sessions
            .get(id)
            .filter(|r| r.state != SessionState::Running)
            .map(|r| (r.state, r.steps_done));
        self.publish_transition(id, prev, "running", Level::Info);
        self.sessions.update(id, |r| {
            if !r.state.is_terminal() {
                r.state = SessionState::Running;
            }
        });
        Ok(())
    }

    /// Route a control command to the executor. A command addressed to
    /// a still-pending session materializes it first; if that fails
    /// terminally (record flipped to Failed), release the session's
    /// cluster fallout exactly like a drive-round failure would —
    /// otherwise its node allocation would leak.
    fn control_session(&self, id: &str, cmd: SessionCommand) -> Result<()> {
        let res = self.executor.control(id, cmd);
        if res.is_err() && self.sessions.get(id).map(|r| r.state) == Some(SessionState::Failed) {
            // Keep the caller's error primary, but a backfill placement
            // that fails must not vanish silently.
            if let Err(e) = self.release_and_backfill(id) {
                self.events.error("platform", id, format!("backfill after failed control: {:#}", e));
            }
        }
        res
    }

    /// Stop a session outright. Freed resources immediately go to queued
    /// work.
    pub fn stop(&self, id: &str) -> Result<()> {
        self.executor.detach(id);
        self.containers.stop_job(id);
        self.tenancy.admission.remove(id);
        self.tenancy.registry.release(id);
        self.master.cancel_queued(id);
        let placed = self.master.complete(id);
        let prev = self.sessions.get(id).map(|r| (r.state, r.steps_done));
        self.sessions.update(id, |r| {
            if !r.state.is_terminal() {
                r.state = SessionState::Stopped;
            }
        });
        self.publish_transition(id, prev, "stopped", Level::Info);
        self.events.info("platform", id, "stopped by user");
        for (job, node) in placed {
            self.prepare_and_start(&job.id, node)?;
        }
        self.pump_admission()
    }

    // ------------------------------------------------------------------
    // nsml infer (the Fig. 4 demo path)
    // ------------------------------------------------------------------

    /// Run inference against a session's best checkpoint (works for
    /// finished sessions; "nsml infer" spins up a fresh REPL container).
    pub fn infer(&self, id: &str, x: &TensorData) -> Result<Vec<f32>> {
        let rec = self.sessions.get(id).ok_or_else(|| anyhow!("unknown session {}", id))?;
        let manifest = self.engine.manifest().model(&rec.spec.model)?;
        let ckpt = self
            .checkpoints
            .best(id, manifest.lower_is_better)
            .or_else(|| self.checkpoints.latest(id))
            .ok_or_else(|| anyhow!("session {} has no checkpoint", id))?;
        let bytes = self.checkpoints.load_params(&ckpt)?;
        let model = TrainableModel::from_checkpoint(self.engine.clone(), &rec.spec.model, &bytes)?;
        model.infer(x)
    }

    // ------------------------------------------------------------------
    // Serving: named endpoints + micro-batched inference
    // ------------------------------------------------------------------

    /// Promote `session`'s best checkpoint (latest when no metric was
    /// ever reported) to endpoint `name`: append + activate a new
    /// version. Published as a durable `EndpointChanged` event, so the
    /// promote survives a crash through WAL replay even before the next
    /// snapshot.
    pub fn promote_endpoint(
        &self,
        name: &str,
        session: &str,
    ) -> Result<crate::serving::EndpointVersion> {
        if name.is_empty() {
            return Err(anyhow!("endpoint name must be non-empty"));
        }
        let rec = self.sessions.get(session).ok_or_else(|| anyhow!("unknown session {}", session))?;
        let manifest = self.engine.manifest().model(&rec.spec.model)?;
        let ckpt = self
            .checkpoints
            .best(session, manifest.lower_is_better)
            .or_else(|| self.checkpoints.latest(session))
            .ok_or_else(|| anyhow!("session {} has no checkpoint to promote", session))?;
        // Queued + in-flight work finishes under the old version before
        // the cursor moves (no-op for a brand-new endpoint).
        self.quiesce_endpoint(name);
        let v = self.endpoints.promote(
            name,
            session,
            &rec.spec.model,
            ckpt.step,
            ckpt.params.clone(),
            self.clock.now_ms(),
        );
        self.publish_endpoint_changed(name, "promote", &v);
        Ok(v)
    }

    /// Move `name` one version back (serve the previous promote).
    /// Queued and in-flight batches drain at the outgoing version
    /// first, so no batch mixes versions across the rollback.
    pub fn rollback_endpoint(&self, name: &str) -> Result<crate::serving::EndpointVersion> {
        self.quiesce_endpoint(name);
        let v = self.endpoints.rollback(name).map_err(|e| anyhow!(e))?;
        self.publish_endpoint_changed(name, "rollback", &v);
        Ok(v)
    }

    /// Undo a rollback: move `name` one version forward (drains the
    /// outgoing version first, like rollback).
    pub fn rollforward_endpoint(&self, name: &str) -> Result<crate::serving::EndpointVersion> {
        self.quiesce_endpoint(name);
        let v = self.endpoints.rollforward(name).map_err(|e| anyhow!(e))?;
        self.publish_endpoint_changed(name, "rollforward", &v);
        Ok(v)
    }

    /// Remove `name` entirely; requests still queued for it fail
    /// immediately (each reply fires exactly once). The replica set
    /// drains, then drops, and every worker evicts its cached copy.
    pub fn retire_endpoint(&self, name: &str) -> Result<crate::serving::EndpointVersion> {
        self.quiesce_endpoint(name);
        let v = self.endpoints.retire(name).map_err(|e| anyhow!(e))?;
        self.serving.fail_endpoint(name, &format!("endpoint '{}' was retired", name));
        self.replicas.remove(name);
        self.executor.drop_served(name);
        self.replicas.prune_params(&self.endpoints.pinned_objects());
        self.publish_endpoint_changed(name, "retire", &v);
        Ok(v)
    }

    fn publish_endpoint_changed(
        &self,
        name: &str,
        action: &str,
        v: &crate::serving::EndpointVersion,
    ) {
        self.events.bus().publish(
            Level::Info,
            "serving",
            name,
            EventKind::EndpointChanged {
                action: action.to_string(),
                version: v.version,
                session: v.session.clone(),
                model: v.model.clone(),
                step: v.step,
                object: v.object.0.clone(),
            },
        );
    }

    /// Validate + queue one serving request. Errors here are client
    /// errors — unknown endpoint, wrong row size, over QPS quota — and
    /// never reach the engine; `reply` fires (exactly once, later) only
    /// for requests that were actually queued.
    pub fn serve_enqueue(
        &self,
        endpoint: &str,
        user: &str,
        x: Vec<f32>,
        reply: ServeReply,
    ) -> std::result::Result<(), ApiError> {
        let Some(ep) = self.endpoints.get(endpoint) else {
            return Err(ApiError::not_found(format!("unknown endpoint '{}'", endpoint)));
        };
        if user.is_empty() {
            return Err(ApiError::invalid("serve_infer: 'user' must be non-empty"));
        }
        let v = ep.active_version();
        let shape = &self
            .engine
            .manifest()
            .model(&v.model)
            .map_err(|e| ApiError::internal(format!("endpoint '{}': {:#}", endpoint, e)))?
            .infer_x_shape;
        let row_len =
            shape.get(1..).map(|d| d.iter().product::<i64>()).unwrap_or(1).max(1) as usize;
        if x.len() != row_len {
            return Err(ApiError::invalid(format!(
                "serve_infer: request has {} values but one '{}' row is {} values",
                x.len(),
                v.model,
                row_len
            )));
        }
        let now = self.clock.now_ms();
        if let Err(max_qps) = self.tenancy.registry.try_request(user, now) {
            return Err(ApiError::failed(format!(
                "user '{}' is over its serving quota of {} requests/sec",
                user, max_qps
            )));
        }
        // Carry the caller's trace context into the queue: the flush
        // (and the batch execution) happen rounds later on whatever
        // thread the batch lands on, so the id must ride the request.
        let trace = crate::obs::trace::current();
        if let Some(t) = &trace {
            self.obs.span(t, 0.0, "serving.enqueue", "serving", &format!("endpoint={}", endpoint));
        }
        self.serving.enqueue(
            endpoint,
            PendingInfer { user: user.to_string(), x, enqueued_at_ms: now, reply, trace },
        );
        Ok(())
    }

    /// Flush due serving micro-batches: full batches always, partial
    /// ones once their oldest request has waited `[serving]
    /// max_wait_ms` of virtual time — and everything when `flush_all`
    /// is set (the daemon forces a flush after each dispatch burst, so
    /// requests that arrived together leave together). With the serve
    /// lane on each batch is handed to a replica's worker thread and
    /// replies fire asynchronously; with `max_replicas = 0` it executes
    /// inline before this returns.
    pub fn pump_serving(&self, flush_all: bool) {
        for (endpoint, batch) in self.serving.take_due(self.clock.now_ms(), flush_all) {
            self.dispatch_serving_batch(&endpoint, batch);
        }
    }

    /// Micro-batcher counters (depth, requests, batches executed).
    pub fn serving_stats(&self) -> crate::serving::ServingQueueStats {
        self.serving.stats()
    }

    /// Live serving stats for one endpoint: (replica count, queued
    /// requests). The inline fallback reports one replica — the
    /// platform thread itself.
    pub fn endpoint_stats(&self, name: &str) -> (usize, usize) {
        let depth = self.serving.depth_of(name);
        if !self.autoscale.enabled() {
            return (1, depth);
        }
        (self.replicas.replicas(name).max(1), depth)
    }

    /// Route one due batch: onto a replica's worker thread when the
    /// serve lane is enabled, inline on the platform thread otherwise.
    /// The batch binds the endpoint version *here*, and the dispatch
    /// holds an in-flight guard until every reply fires — the two
    /// halves of the no-mixed-version invariant.
    fn dispatch_serving_batch(&self, endpoint: &str, batch: Vec<PendingInfer>) {
        // One flush span per distinct trace in the batch; the duration
        // is that request's queue wait (enqueue → flush).
        if self.obs.enabled() {
            let now = self.clock.now_ms();
            let n = batch.len();
            let mut seen: Vec<&str> = Vec::new();
            for req in &batch {
                if let Some(t) = req.trace.as_deref() {
                    if !seen.contains(&t) {
                        seen.push(t);
                        let wait = now.saturating_sub(req.enqueued_at_ms) as f64;
                        self.obs.span(
                            t,
                            wait,
                            "serving.flush",
                            "serving",
                            &format!("endpoint={} batch={}", endpoint, n),
                        );
                    }
                }
            }
        }
        if !self.autoscale.enabled() {
            self.run_serving_batch(endpoint, batch);
            return;
        }
        let Some(ep) = self.endpoints.get(endpoint) else {
            for req in batch {
                (req.reply)(Err(format!("endpoint '{}' was retired", endpoint)));
            }
            return;
        };
        let v = ep.active_version().clone();
        let params = match self.replicas.params_for(&v.object, || {
            self.objects.get(&v.object).map_err(|e| format!("loading params: {:#}", e))
        }) {
            Ok(p) => p,
            Err(e) => {
                let msg = format!("serving '{}' v{}: {}", endpoint, v.version, e);
                self.events.error("serving", endpoint, msg.clone());
                for req in batch {
                    (req.reply)(Err(msg.clone()));
                }
                return;
            }
        };
        self.replicas.ensure(
            endpoint,
            self.autoscale.initial_replicas(),
            &self.worker_loads(),
            self.clock.now_ms(),
        );
        let Some((worker, guard)) = self.replicas.checkout(endpoint) else {
            // Unreachable after ensure; serve inline rather than drop.
            self.run_serving_batch(endpoint, batch);
            return;
        };
        let work = ServeWork {
            endpoint: endpoint.to_string(),
            version: v.version,
            model: v.model.clone(),
            params,
            batch,
            guard,
        };
        if let Err(work) = self.executor.serve_batch_on(worker, work) {
            // The worker hung up (pool shutdown mid-flight): answer on
            // the platform thread instead of dropping the replies.
            let ServeWork { batch, guard, .. } = work;
            drop(guard);
            self.run_serving_batch(endpoint, batch);
        }
    }

    /// Per-worker live-session counts, indexed by worker id — the
    /// training load signal replica placement steers around.
    fn worker_loads(&self) -> Vec<usize> {
        let mut loads = vec![0usize; self.executor.worker_count()];
        for s in self.executor.stats() {
            if let Some(l) = loads.get_mut(s.worker) {
                *l = s.live_sessions;
            }
        }
        loads
    }

    /// One autoscaler round: drain the `InferServed` telemetry cursor,
    /// observe each endpoint's queue depth and idle time, and apply at
    /// most one scale step per endpoint. Every applied step publishes
    /// `EventKind::ReplicaScaled`.
    fn autoscale_tick(&self) {
        if !self.autoscale.enabled() {
            return;
        }
        let served = self.autoscale_sub.lock().unwrap().poll();
        let now = self.clock.now_ms();
        for e in &served {
            if matches!(e.kind, EventKind::InferServed { .. }) {
                self.replicas.touch(&e.subject, now);
            }
        }
        for name in self.replicas.endpoints() {
            let depth = self.serving.depth_of(&name);
            let (count, idle_ms) = self.replicas.observe(&name, depth, now);
            if count == 0 {
                continue;
            }
            let scaled = match self.autoscale.decide(count, depth, idle_ms) {
                ScaleDecision::Up => self.replicas.scale_up(&name, &self.worker_loads()),
                ScaleDecision::Down => self.replicas.scale_down(&name),
                ScaleDecision::Hold => None,
            };
            if let Some(new_count) = scaled {
                let trigger = if new_count > count { depth as u64 } else { 0 };
                self.events.bus().publish(
                    Level::Info,
                    "serving",
                    &name,
                    EventKind::ReplicaScaled {
                        replicas: new_count as u64,
                        queue_depth: trigger,
                    },
                );
            }
        }
    }

    /// Flush everything queued for `endpoint` at the *current* active
    /// version, then wait for all in-flight batches to answer. Called
    /// by the registry mutation paths before the cursor moves, so no
    /// batch ever mixes endpoint versions.
    fn quiesce_endpoint(&self, name: &str) {
        for batch in self.serving.take_endpoint(name) {
            self.dispatch_serving_batch(name, batch);
        }
        if !self.replicas.drain(name) {
            self.events.warn(
                "serving",
                name,
                "drain timed out with batches still in flight (worker thread lost?)",
            );
        }
    }

    fn run_serving_batch(&self, endpoint: &str, batch: Vec<PendingInfer>) {
        // The active version may have moved while these requests
        // queued (rollback in flight): serve whatever is active *now*.
        let Some(ep) = self.endpoints.get(endpoint) else {
            for req in batch {
                (req.reply)(Err(format!("endpoint '{}' was retired", endpoint)));
            }
            return;
        };
        let v = ep.active_version().clone();
        let n = batch.len();
        let t0 = std::time::Instant::now();
        let rows: Vec<Vec<f32>> = batch.iter().map(|r| r.x.clone()).collect();
        match self.with_served_model(endpoint, &v, |m| m.serve_rows(&rows)) {
            Ok(outs) => {
                let latency_ms = t0.elapsed().as_secs_f64() * 1000.0;
                if self.obs.enabled() {
                    let mut seen: Vec<&str> = Vec::new();
                    for req in &batch {
                        if let Some(t) = req.trace.as_deref() {
                            if !seen.contains(&t) {
                                seen.push(t);
                                self.obs.span(
                                    t,
                                    latency_ms,
                                    "serving.batch",
                                    "serving",
                                    &format!("endpoint={} v{} batch={}", endpoint, v.version, n),
                                );
                            }
                        }
                    }
                }
                for (req, probs) in batch.into_iter().zip(outs) {
                    let row = crate::serving::ServedRow { probs, version: v.version, batch: n };
                    (req.reply)(Ok(row));
                }
                self.events.bus().publish(
                    Level::Debug,
                    "serving",
                    endpoint,
                    EventKind::InferServed { batch: n as u64, latency_ms },
                );
            }
            Err(e) => {
                let msg = format!("serving '{}' v{}: {}", endpoint, v.version, e);
                self.events.error("serving", endpoint, msg.clone());
                for req in batch {
                    (req.reply)(Err(msg.clone()));
                }
            }
        }
    }

    /// Run `f` against the cached [`ServedModel`] for
    /// `(endpoint, version)`, loading it from the object store on the
    /// first request after a promote/rollback.
    fn with_served_model<R>(
        &self,
        endpoint: &str,
        v: &crate::serving::EndpointVersion,
        f: impl FnOnce(&ServedModel) -> std::result::Result<R, String>,
    ) -> std::result::Result<R, String> {
        let key = (endpoint.to_string(), v.version);
        let mut cache = self.served_models.borrow_mut();
        if !cache.contains_key(&key) {
            let bytes =
                self.objects.get(&v.object).map_err(|e| format!("loading params: {:#}", e))?;
            let model = TrainableModel::from_checkpoint(self.engine.clone(), &v.model, &bytes)
                .map_err(|e| format!("loading model: {:#}", e))?;
            cache.insert(key.clone(), ServedModel::new(model)?);
        }
        f(&cache[&key])
    }

    // ------------------------------------------------------------------
    // Persistence
    // ------------------------------------------------------------------

    /// Persist the world. With durability on this is snapshot-on-demand
    /// (drain the consumers, log the tail, compact, rotate) — the
    /// per-mutation full rewrite is gone. With it off, the plain
    /// `persist::save` of old.
    pub fn save_state(&self) -> Result<()> {
        let Some(dir) = &self.config.state_dir else { return Ok(()) };
        if self.durability.is_some() {
            self.pump_consumers();
            if let Some(d) = &self.durability {
                d.pump()?;
            }
            self.snapshot_now()
        } else {
            persist::save(
                dir,
                &self.sessions,
                &self.leaderboard,
                &self.checkpoints,
                &self.tenancy.registry,
                &self.endpoints,
            )
        }
    }

    /// Compact: world dump + snapshot metadata (coverage bound + usage
    /// ledger), then rotate the WAL segment the dump subsumes. GC runs
    /// after each snapshot when `[durability] gc` is on.
    fn snapshot_now(&self) -> Result<()> {
        let (Some(dir), Some(d)) = (self.config.state_dir.as_ref(), self.durability.as_ref())
        else {
            return Ok(());
        };
        persist::save(
            dir,
            &self.sessions,
            &self.leaderboard,
            &self.checkpoints,
            &self.tenancy.registry,
            &self.endpoints,
        )?;
        let head = self.events.bus().head();
        if head == 0 {
            // Nothing ever published: no coverage bound to record, and
            // writing `last_seq = 0` now would wrongly subsume the
            // first real event (seq 0) on the next recovery.
            return Ok(());
        }
        let (closed_usage, open_usage) = self.tenancy.accountant.dump();
        let meta = SnapshotMeta {
            last_seq: head - 1,
            at_ms: self.clock.now_ms(),
            closed_usage,
            open_usage,
        };
        d.mark_snapshot(&meta)?;
        if d.gc_enabled() {
            if let Err(e) = self.gc() {
                self.events.warn("durability", "", format!("post-snapshot gc failed: {:#}", e));
            }
        }
        Ok(())
    }

    /// Mark-and-sweep the object store: checkpoint chains, dataset
    /// manifests and code bundles stay, orphans go, and each tenant's
    /// checkpoint bytes are written to the registry. Callable any time
    /// (`nsml gc`); also runs after each snapshot when configured.
    pub fn gc(&self) -> Result<durability::GcReport> {
        let owner = |session: &str| -> Option<String> {
            self.sessions
                .get(session)
                .map(|r| r.spec.user)
                // Session ids are `user/dataset/N`, so even a session
                // whose record predates the store still attributes.
                .or_else(|| session.split('/').next().map(str::to_string))
        };
        // A live endpoint's whole version history is pinned, so a
        // rollback target stays loadable even if its index entry went.
        let pins = self.endpoints.pinned_objects();
        // The serve lane's in-memory params cache follows the same
        // pinning rule: retired objects leave it with the sweep.
        self.replicas.prune_params(&pins);
        let report = durability::gc::sweep(
            &self.objects,
            &self.checkpoints,
            &self.datasets,
            &owner,
            &self.tenancy.registry,
            &pins,
        );
        self.events.info(
            "durability",
            "",
            format!(
                "gc: swept {} objects ({} B), {} live ({} B)",
                report.swept_objects, report.swept_bytes, report.live_objects, report.live_bytes
            ),
        );
        if let Some(d) = &self.durability {
            d.note_gc(report.clone());
        }
        Ok(report)
    }

    /// Durability counters for the status surfaces; `None` when the
    /// subsystem is off.
    pub fn durability_status(&self) -> Option<durability::DurabilityStats> {
        self.durability.as_ref().map(|d| d.stats())
    }

    /// Events the derived-view consumer subscription has lost to ring
    /// overflow (each loss triggered a reconcile pass).
    pub fn consumer_lag(&self) -> u64 {
        self.consumers.lock().unwrap().dropped()
    }

    /// Restore persisted state, then (durability on) recover the WAL
    /// tail: restore the usage ledger from the snapshot metadata,
    /// re-index post-snapshot checkpoints, replay logged events through
    /// the live consumer paths, and requeue sessions that were in
    /// flight when the last process died.
    fn load_state(&self, recovery: Option<(WalScan, Option<SnapshotMeta>)>) -> Result<()> {
        let Some(dir) = &self.config.state_dir else { return Ok(()) };
        persist::load(
            dir,
            &self.sessions,
            &self.leaderboard,
            &self.checkpoints,
            &self.tenancy.registry,
            &self.endpoints,
        )?;
        // Tenancy views must survive the restart too: every restored
        // session's owner is a known tenant, and non-terminal sessions
        // re-register their accounting metadata so a later resume is
        // billed to the right user.
        for rec in self.sessions.list() {
            self.tenancy.registry.note_user(&rec.spec.user);
            if !rec.state.is_terminal() {
                self.tenancy.accountant.register(&rec.spec.id, &rec.spec.user, rec.spec.gpus);
            }
        }
        let Some((scan, meta)) = recovery else { return Ok(()) };
        if scan.truncated_bytes > 0 {
            self.events.warn(
                "durability",
                "",
                format!("WAL torn tail: {} bytes truncated (crash mid-append)", scan.truncated_bytes),
            );
        }
        // The accrued GPU-second ledger lives only in the snapshot
        // metadata once the pre-snapshot WAL rotates away.
        if let Some(m) = &meta {
            self.tenancy.accountant.restore(&m.closed_usage, &m.open_usage);
        }
        // Checkpoints saved after the snapshot are missing from the
        // persisted index; their metadata records are in the object
        // store by design.
        let reindexed = durability::rebuild_checkpoint_index(&self.objects, &self.checkpoints);
        // Replay the tail through the same consumer paths the live
        // platform pumps.
        let resolve = |model: &str| -> Option<(String, bool)> {
            self.engine
                .manifest()
                .model(model)
                .ok()
                .map(|m| (m.metric_name.clone(), m.lower_is_better))
        };
        let stats = durability::replay(
            &scan.events,
            meta.as_ref().map(|m| m.last_seq),
            &self.sessions,
            &self.leaderboard,
            &self.tenancy.accountant,
            &self.endpoints,
            &resolve,
        );
        // Keep virtual time monotonic across the restart: recovered
        // records carry timestamps the new clock must not run behind.
        let recovered_ms = scan
            .events
            .iter()
            .map(|e| e.at_ms)
            .chain(meta.as_ref().map(|m| m.at_ms))
            .max()
            .unwrap_or(0);
        let now = self.clock.now_ms();
        if recovered_ms > now {
            self.sim.advance(recovered_ms - now);
        }
        if stats.applied > 0 || reindexed > 0 {
            self.events.info(
                "durability",
                "",
                format!(
                    "recovered: {} WAL events replayed ({} snapshot-covered), {} completions resubmitted, {} checkpoints re-indexed",
                    stats.applied, stats.skipped, stats.completions, reindexed
                ),
            );
        }
        // Sessions that were in flight when the process died go back
        // through admission; ones with a checkpoint auto-resume.
        // (Paused stays paused — that was a user decision.)
        let now = self.clock.now_ms();
        for rec in self.sessions.list() {
            if rec.state.is_terminal() || rec.state == SessionState::Paused {
                continue;
            }
            // The run itself is gone; settle any interval replay opened.
            self.tenancy.accountant.close_if_open(&rec.spec.id, now);
            let prev = Some((rec.state, rec.steps_done));
            self.sessions.update(&rec.spec.id, |r| {
                r.state = SessionState::Queued;
                r.node = None;
                r.container = None;
            });
            if rec.state != SessionState::Queued {
                self.publish_transition(&rec.spec.id, prev, "queued", Level::Warn);
            }
            let job = JobSpec {
                id: rec.spec.id.clone(),
                user: rec.spec.user.clone(),
                dataset: rec.spec.dataset.clone(),
                req: crate::cluster::ResourceReq::gpus(rec.spec.gpus),
                priority: rec.spec.priority,
            };
            let resume = self.checkpoints.latest(&rec.spec.id).is_some();
            if self.config.tenancy {
                self.tenancy.admission.enqueue(PendingAdmission { job, resume });
            } else if let SubmitOutcome::PlacedImmediately(node) = self.master.submit(job) {
                self.prepare_and_start(&rec.spec.id, node)?;
            }
        }
        self.pump_admission()?;
        // Baseline snapshot: the new process's bus numbers events from
        // seq 0 again, so the replayed metadata and WAL tail (old seq
        // space) must be retired before new records land in the log —
        // mixing the two would confuse the next recovery's seq gate
        // (and an applied-but-unrotated tail would replay twice).
        if scan.events.is_empty() && meta.is_none() {
            return Ok(()); // fresh durability dir — nothing to retire
        }
        if let Some(d) = &self.durability {
            d.pump()?;
        }
        if self.events.bus().head() == 0 {
            // Nothing published this boot yet; the baseline needs at
            // least one event so it can record a coverage bound.
            self.events.info("durability", "", "recovery baseline");
        }
        self.snapshot_now()
    }
}

/// Rounds per wall-clock second since the loop started (0.0 before the
/// first measurable tick — never a division by zero).
fn rate_of(s: &LoopStats) -> f64 {
    match s.started {
        Some(t0) => {
            let secs = t0.elapsed().as_secs_f64();
            if secs > 0.0 {
                s.rounds as f64 / secs
            } else {
                0.0
            }
        }
        None => 0.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn platform() -> Option<NsmlPlatform> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        let mut cfg = PlatformConfig::test_default();
        cfg.artifacts_dir = dir;
        Some(NsmlPlatform::new(cfg).unwrap())
    }

    fn quick_opts(steps: u64) -> RunOpts {
        RunOpts { total_steps: steps, eval_every: steps / 2, checkpoint_every: steps / 2, ..Default::default() }
    }

    #[test]
    fn end_to_end_run_reaches_leaderboard() {
        let Some(p) = platform() else { return };
        let id = p.run("kim", "mnist", quick_opts(40)).unwrap();
        p.run_to_completion(20, 100).unwrap();
        let rec = p.sessions.get(&id).unwrap();
        assert_eq!(rec.state, SessionState::Done);
        assert!(rec.best_metric.unwrap() > 0.2);
        assert_eq!(p.leaderboard.rank_of("mnist", &id), Some(1));
        // Container was brought up and torn down.
        assert!(p.containers.running().is_empty());
        assert_eq!(p.cluster.gpu_totals().1, 12); // all GPUs free again
    }

    #[test]
    fn contention_queues_then_schedules() {
        let Some(p) = platform() else { return };
        // 3 nodes × 4 GPUs; five 4-GPU jobs → two must wait. Capacity-
        // blocked submissions wait in the fair-share admission queue,
        // not the master's own queue.
        let mut ids = Vec::new();
        for i in 0..5 {
            let mut o = quick_opts(20);
            o.gpus = 4;
            o.seed = i;
            ids.push(p.run("kim", "mnist", o).unwrap());
        }
        assert!(p.queued_total() >= 2);
        assert_eq!(p.tenancy.admission.depth_of("kim"), 2);
        p.run_to_completion(20, 200).unwrap();
        for id in &ids {
            assert_eq!(p.sessions.get(id).unwrap().state, SessionState::Done, "{}", id);
        }
        let s = p.master.stats();
        assert_eq!(s.submitted, 5);
        assert_eq!(s.completed, 5);
        assert_eq!(p.queued_total(), 0);
    }

    #[test]
    fn node_failure_recovers_session_from_checkpoint() {
        let Some(p) = platform() else { return };
        let mut o = quick_opts(60);
        o.checkpoint_every = 10;
        let id = p.run("kim", "mnist", o).unwrap();
        // Train partway, then kill the node under it.
        p.drive(20).unwrap();
        let node = p.sessions.get(&id).unwrap().node.unwrap();
        p.kill_node(node);
        let rec = p.sessions.get(&id).unwrap();
        // Requeued, or already re-placed (Preparing until the next
        // round materializes the resumed run, Running after).
        assert!(matches!(
            rec.state,
            SessionState::Queued | SessionState::Preparing | SessionState::Running
        ));
        p.run_to_completion(20, 200).unwrap();
        let rec = p.sessions.get(&id).unwrap();
        assert_eq!(rec.state, SessionState::Done);
        assert_eq!(rec.recoveries, 1);
        // It resumed, not restarted: steps_done == total even though the
        // checkpoint restart replayed from step <= 20.
        assert_eq!(rec.steps_done, 60);
    }

    #[test]
    fn infer_after_completion() {
        let Some(p) = platform() else { return };
        let id = p.run("kim", "mnist", quick_opts(40)).unwrap();
        p.run_to_completion(20, 100).unwrap();
        // Build a digit and classify it.
        let mut img = vec![0.0f32; 144];
        crate::data::digits::draw_digit(3, 0, 0, 1.0, &mut img);
        let batch_x = img.repeat(64);
        let x = TensorData::f32(batch_x, &[64, 144]);
        let probs = p.infer(&id, &x).unwrap();
        assert_eq!(probs.len(), 640);
        let row = &probs[..10];
        let argmax = row.iter().enumerate().max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap().0;
        assert_eq!(argmax, 3, "probs {:?}", row);
    }

    #[test]
    fn stop_cancels_queued_session() {
        let Some(p) = platform() else { return };
        let mut o = quick_opts(20);
        o.gpus = 4;
        let _a = p.run("kim", "mnist", o.clone()).unwrap();
        let _b = p.run("kim", "mnist", o.clone()).unwrap();
        let _c = p.run("kim", "mnist", o.clone()).unwrap();
        // Fourth job waits for admission; stop it before it ever runs.
        let d = p.run("kim", "mnist", o).unwrap();
        assert!(p.queued_total() >= 1);
        p.stop(&d).unwrap();
        assert_eq!(p.tenancy.admission.depth_of("kim"), 0);
        p.run_to_completion(20, 200).unwrap();
        assert_eq!(p.sessions.get(&d).unwrap().state, SessionState::Stopped);
    }

    #[test]
    fn unknown_dataset_rejected() {
        let Some(p) = platform() else { return };
        assert!(p.run("kim", "no-such-dataset", RunOpts::default()).is_err());
    }

    #[test]
    fn impossible_gpu_request_fails_fast() {
        // 4-GPU nodes: a 5-GPU job could never place and would wedge
        // its user's admission lane — rejected at submission instead.
        let Some(p) = platform() else { return };
        let mut o = quick_opts(10);
        o.gpus = 5;
        let err = p.run("kim", "mnist", o).unwrap_err();
        assert!(err.to_string().contains("largest node"), "{}", err);
        assert!(p.sessions.is_empty(), "no orphan record left behind");
        assert_eq!(p.queued_total(), 0);
    }
}
