//! Glue between the AutoML searchers and real platform sessions: each
//! trial is a genuine training session (model, data, checkpoints) driven
//! incrementally — what §3.1's "automatically optimize the
//! hyperparameters" does on the deployed system.

use crate::automl::TrialRunner;
use crate::data::{generator_for, model_for_dataset};
use crate::events::EventLog;
use crate::runtime::Engine;
use crate::session::{SessionRecord, SessionRun, SessionSpec, SessionStore};
use crate::storage::CheckpointStore;
use crate::util::clock::SharedClock;
use anyhow::Result;
use std::rc::Rc;

/// Runs AutoML trials as real sessions on the platform runtime.
pub struct PlatformTrialRunner {
    engine: Rc<Engine>,
    dataset: String,
    model: String,
    user: String,
    ckpts: CheckpointStore,
    sessions: SessionStore,
    events: EventLog,
    clock: SharedClock,
    seed: u64,
    runs: Vec<Option<SessionRun>>,
    pub session_ids: Vec<String>,
}

impl PlatformTrialRunner {
    pub fn new(
        engine: Rc<Engine>,
        dataset: &str,
        user: &str,
        ckpts: CheckpointStore,
        sessions: SessionStore,
        events: EventLog,
        clock: SharedClock,
        candidates: usize,
        seed: u64,
    ) -> Result<PlatformTrialRunner> {
        let model = model_for_dataset(dataset)
            .ok_or_else(|| anyhow::anyhow!("no model for dataset '{}'", dataset))?
            .to_string();
        Ok(PlatformTrialRunner {
            engine,
            dataset: dataset.to_string(),
            model,
            user: user.to_string(),
            ckpts,
            sessions,
            events,
            clock,
            seed,
            runs: (0..candidates).map(|_| None).collect(),
            session_ids: vec![String::new(); candidates],
        })
    }

    fn ensure_run(&mut self, trial: usize, lr: f64) -> Result<()> {
        if self.runs[trial].is_some() {
            return Ok(());
        }
        let id = format!("{}/{}/automl-{}", self.user, self.dataset, trial);
        let mut spec = SessionSpec::new(&id, &self.user, &self.dataset, &self.model);
        spec.lr = lr;
        spec.seed = self.seed + trial as u64;
        spec.total_steps = u64::MAX / 2; // searcher decides how far to go
        spec.eval_every = 0;
        spec.checkpoint_every = 0;
        self.sessions.insert(SessionRecord::new(spec.clone(), self.clock.now_ms()));
        let gen = generator_for(&self.model, spec.seed).unwrap();
        let run = SessionRun::start(
            self.engine.clone(),
            spec,
            gen,
            self.ckpts.clone(),
            self.sessions.clone(),
            self.events.clone(),
            self.clock.clone(),
        )?;
        self.session_ids[trial] = id;
        self.runs[trial] = Some(run);
        Ok(())
    }

    /// Persist the winner's model ("save the model of best score", §3.1).
    pub fn save_best(&mut self, trial: usize) -> Result<crate::storage::Checkpoint> {
        let run = self.runs[trial].as_mut().expect("winner trial must have run");
        run.checkpoint()
    }
}

impl TrialRunner for PlatformTrialRunner {
    fn extend(&mut self, trial: usize, lr: f64, steps: u64) -> Vec<(f64, f64)> {
        self.ensure_run(trial, lr).expect("trial start");
        let run = self.runs[trial].as_mut().unwrap();
        run.set_lr(lr);
        run.step_chunk(steps).expect("trial step");
        self.sessions
            .get(&self.session_ids[trial])
            .map(|r| r.metrics.series("train_loss"))
            .unwrap_or_default()
    }

    fn current_loss(&mut self, trial: usize) -> f64 {
        match self.runs[trial].as_mut() {
            None => f64::INFINITY,
            Some(run) => {
                // Evaluate on the held-out stream; eval loss is the score.
                let id = self.session_ids[trial].clone();
                let before = self.sessions.get(&id).map(|r| r.metrics.len());
                // Trigger an eval via a zero-step finish-free path: call
                // evaluate directly through the model.
                let _ = before;
                let gen = generator_for(&self.model, 9_999).unwrap();
                let mut gen = gen;
                let batch = gen.eval_batch(run.model().manifest().batch);
                run.model().evaluate(&batch).map(|(loss, _)| loss as f64).unwrap_or(f64::INFINITY)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::automl::{GridSearch, SuccessiveHalving};
    use crate::storage::ObjectStore;
    use crate::util::clock::sim_clock;
    use std::path::PathBuf;

    fn runner(candidates: usize) -> Option<PlatformTrialRunner> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        let engine = Rc::new(Engine::new(&dir).unwrap());
        let (clock, _) = sim_clock();
        let events = EventLog::new(clock.clone()).with_echo(false);
        Some(
            PlatformTrialRunner::new(
                engine,
                "mnist",
                "automl",
                CheckpointStore::new(ObjectStore::memory()),
                SessionStore::new(),
                events,
                clock,
                candidates,
                0,
            )
            .unwrap(),
        )
    }

    #[test]
    fn grid_search_over_real_sessions() {
        let Some(mut r) = runner(3) else { return };
        let out = GridSearch { lrs: vec![0.0001, 0.1, 5.0], steps_per_trial: 30 }.run(&mut r);
        // lr=5.0 diverges or stalls, lr=0.0001 barely moves; 0.1 wins.
        assert!((out.best_lr - 0.1).abs() < 1e-9, "best {}", out.best_lr);
        assert_eq!(out.steps_spent, 90);
        // Winner model is saveable.
        let ck = r.save_best(out.best_trial).unwrap();
        assert!(ck.step >= 30);
    }

    #[test]
    fn successive_halving_spends_less() {
        let Some(mut r) = runner(4) else { return };
        let sh = SuccessiveHalving {
            lrs: vec![0.0001, 0.01, 0.1, 5.0],
            total_steps_per_trial: 40,
            eta: 2,
            rungs: 2,
        }
        .run(&mut r);
        assert!(sh.steps_spent < 4 * 40, "spent {}", sh.steps_spent);
        assert!(sh.best_lr == 0.1 || sh.best_lr == 0.01, "best {}", sh.best_lr);
    }
}
