//! Glue between the AutoML searchers and real platform sessions: each
//! trial is a genuine training session (model, data, checkpoints) that
//! lives inside an executor worker and is driven incrementally — what
//! §3.1's "automatically optimize the hyperparameters" does on the
//! deployed system. Batched rungs ([`TrialRunner::extend_many`]) fan
//! out across the pool, so all surviving candidates of a grid/halving
//! rung train concurrently.

use crate::automl::TrialRunner;
use crate::data::model_for_dataset;
use crate::executor::{ExecutorPool, SessionCommand};
use crate::session::{SessionRecord, SessionSpec, SessionStore};
use crate::util::clock::SharedClock;
use anyhow::Result;
use std::sync::Arc;

/// Fixed generator seed for the held-out scoring stream (kept from the
/// pre-pool runner so search outcomes are comparable).
const EVAL_SEED: u64 = 9_999;

/// Runs AutoML trials as real sessions inside an executor pool.
pub struct PlatformTrialRunner {
    pool: Arc<ExecutorPool>,
    dataset: String,
    model: String,
    user: String,
    sessions: SessionStore,
    clock: SharedClock,
    seed: u64,
    started: Vec<bool>,
    pub session_ids: Vec<String>,
}

impl PlatformTrialRunner {
    pub fn new(
        pool: Arc<ExecutorPool>,
        dataset: &str,
        user: &str,
        sessions: SessionStore,
        clock: SharedClock,
        candidates: usize,
        seed: u64,
    ) -> Result<PlatformTrialRunner> {
        let model = model_for_dataset(dataset)
            .ok_or_else(|| anyhow::anyhow!("no model for dataset '{}'", dataset))?
            .to_string();
        Ok(PlatformTrialRunner {
            pool,
            dataset: dataset.to_string(),
            model,
            user: user.to_string(),
            sessions,
            clock,
            seed,
            started: vec![false; candidates],
            session_ids: vec![String::new(); candidates],
        })
    }

    fn ensure_run(&mut self, trial: usize, lr: f64) -> Result<()> {
        if self.started[trial] {
            return Ok(());
        }
        let id = format!("{}/{}/automl-{}", self.user, self.dataset, trial);
        let mut spec = SessionSpec::new(&id, &self.user, &self.dataset, &self.model);
        spec.lr = lr;
        spec.seed = self.seed + trial as u64;
        spec.total_steps = u64::MAX / 2; // searcher decides how far to go
        spec.eval_every = 0;
        spec.checkpoint_every = 0;
        self.sessions.insert(SessionRecord::new(spec.clone(), self.clock.now_ms()));
        self.pool.submit(spec, false, None)?;
        self.session_ids[trial] = id;
        self.started[trial] = true;
        Ok(())
    }

    /// Persist the winner's model ("save the model of best score", §3.1).
    pub fn save_best(&mut self, trial: usize) -> Result<crate::storage::Checkpoint> {
        self.pool.checkpoint(&self.session_ids[trial])
    }
}

impl Drop for PlatformTrialRunner {
    /// Release this search's live runs from the pool. Trial specs use a
    /// near-infinite step budget, so they never complete on their own —
    /// without this, every search would leave its model parameters
    /// resident in the worker threads for the pool's lifetime.
    fn drop(&mut self) {
        for (started, id) in self.started.iter().zip(&self.session_ids) {
            if *started {
                self.pool.detach(id);
            }
        }
    }
}

impl TrialRunner for PlatformTrialRunner {
    fn extend(&mut self, trial: usize, lr: f64, steps: u64) -> Vec<(f64, f64)> {
        self.extend_many(&[(trial, lr, steps)]).pop().unwrap_or_default()
    }

    /// One rung of parallel training: every listed trial gets its own
    /// step budget, dispatched to its owning worker; the workers train
    /// concurrently and this joins on all of them.
    fn extend_many(&mut self, work: &[(usize, f64, u64)]) -> Vec<Vec<(f64, f64)>> {
        for &(trial, lr, _) in work {
            self.ensure_run(trial, lr).expect("trial start");
            // Mid-search lr edits ride the same mailbox as user edits.
            // A trial that already failed is simply left dead.
            let _ = self.pool.control(&self.session_ids[trial], SessionCommand::SetLr(lr));
        }
        let batch: Vec<(String, u64)> =
            work.iter().map(|&(trial, _, steps)| (self.session_ids[trial].clone(), steps)).collect();
        // Failures (e.g. a diverged lr producing non-finite loss) mark
        // the record failed; the trial scores INFINITY from then on.
        let _ = self.pool.step_many(&batch);
        work.iter()
            .map(|&(trial, ..)| {
                self.sessions
                    .get(&self.session_ids[trial])
                    .map(|r| r.metrics.series("train_loss"))
                    .unwrap_or_default()
            })
            .collect()
    }

    fn current_loss(&mut self, trial: usize) -> f64 {
        if !self.started[trial] {
            return f64::INFINITY;
        }
        // Evaluate on the held-out stream; eval loss is the score.
        self.pool
            .evaluate(&self.session_ids[trial], EVAL_SEED)
            .map(|(loss, _)| loss)
            .unwrap_or(f64::INFINITY)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::automl::{GridSearch, SuccessiveHalving};
    use crate::events::EventLog;
    use crate::executor::WorkerCtx;
    use crate::storage::{CheckpointStore, ObjectStore};
    use crate::util::clock::sim_clock;
    use std::path::PathBuf;

    fn runner(candidates: usize, workers: usize) -> Option<PlatformTrialRunner> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        let (clock, _) = sim_clock();
        let events = EventLog::new(clock.clone()).with_echo(false);
        let ctx = WorkerCtx {
            artifacts_dir: dir,
            checkpoints: CheckpointStore::new(ObjectStore::memory()),
            sessions: SessionStore::new(),
            events,
            clock: clock.clone(),
        };
        let pool = Arc::new(ExecutorPool::new(workers, ctx.clone()));
        Some(
            PlatformTrialRunner::new(pool, "mnist", "automl", ctx.sessions, clock, candidates, 0)
                .unwrap(),
        )
    }

    #[test]
    fn grid_search_over_real_sessions() {
        let Some(mut r) = runner(3, 2) else { return };
        let out = GridSearch { lrs: vec![0.0001, 0.1, 5.0], steps_per_trial: 30 }.run(&mut r);
        // lr=5.0 diverges or stalls, lr=0.0001 barely moves; 0.1 wins.
        assert!((out.best_lr - 0.1).abs() < 1e-9, "best {}", out.best_lr);
        assert_eq!(out.steps_spent, 90);
        // Winner model is saveable.
        let ck = r.save_best(out.best_trial).unwrap();
        assert!(ck.step >= 30);
    }

    #[test]
    fn successive_halving_spends_less() {
        let Some(mut r) = runner(4, 2) else { return };
        let sh = SuccessiveHalving {
            lrs: vec![0.0001, 0.01, 0.1, 5.0],
            total_steps_per_trial: 40,
            eta: 2,
            rungs: 2,
        }
        .run(&mut r);
        assert!(sh.steps_spent < 4 * 40, "spent {}", sh.steps_spent);
        assert!(sh.best_lr == 0.1 || sh.best_lr == 0.01, "best {}", sh.best_lr);
    }

    #[test]
    fn trials_spread_across_workers() {
        let Some(mut r) = runner(4, 4) else { return };
        let out = GridSearch { lrs: vec![0.05, 0.08, 0.1, 0.2], steps_per_trial: 8 }.run(&mut r);
        assert!(out.best_loss.is_finite());
        // Round-robin placement: 4 trials land on 4 distinct workers.
        let owners: std::collections::BTreeSet<usize> =
            r.session_ids.iter().filter_map(|id| r.pool.owner_of(id)).collect();
        assert_eq!(owners.len(), 4, "owners {:?}", owners);
        // Dropping the runner releases its (never-completing) runs.
        let pool = r.pool.clone();
        assert_eq!(pool.len(), 4);
        drop(r);
        assert!(pool.is_empty());
    }
}
