//! Platform state persistence: sessions + leaderboard + tenant quotas
//! as JSON under the state directory, so `nsml` CLI invocations compose
//! (run, then `nsml dataset board`, then `nsml quota`, …) like the real
//! multi-process NSML.

use crate::leaderboard::{Leaderboard, Submission};
use crate::session::{SessionRecord, SessionSpec, SessionState, SessionStore};
use crate::tenancy::{PriorityClass, TenantQuota, TenantRegistry};
use crate::util::json::{parse, Json};
use anyhow::{anyhow, Result};
use std::path::Path;

fn state_of(s: &str) -> SessionState {
    match s {
        "queued" => SessionState::Queued,
        "preparing" => SessionState::Preparing,
        "running" => SessionState::Running,
        "paused" => SessionState::Paused,
        "failed" => SessionState::Failed,
        "stopped" => SessionState::Stopped,
        _ => SessionState::Done,
    }
}

fn record_to_json(r: &SessionRecord) -> Json {
    let mut spec = Json::obj();
    spec.set("id", r.spec.id.as_str().into())
        .set("user", r.spec.user.as_str().into())
        .set("dataset", r.spec.dataset.as_str().into())
        .set("model", r.spec.model.as_str().into())
        .set("gpus", r.spec.gpus.into())
        .set("priority", r.spec.priority.as_str().into())
        .set("total_steps", r.spec.total_steps.into())
        .set("lr", r.spec.lr.into())
        .set("seed", r.spec.seed.into())
        .set("checkpoint_every", r.spec.checkpoint_every.into())
        .set("eval_every", r.spec.eval_every.into())
        .set("use_scan", r.spec.use_scan.into());
    let metrics: Vec<Json> = r
        .metrics
        .points()
        .iter()
        .map(|p| {
            let mut m = Json::obj();
            m.set("step", p.step.into()).set("name", p.name.as_str().into()).set("value", p.value.into());
            m
        })
        .collect();
    let mut o = Json::obj();
    o.set("spec", spec)
        .set("state", r.state.as_str().into())
        .set("steps_done", r.steps_done.into())
        .set("best_metric", r.best_metric.map(Json::Num).unwrap_or(Json::Null))
        .set("submitted_at_ms", r.submitted_at_ms.into())
        .set("recoveries", (r.recoveries as u64).into())
        .set("preemptions", (r.preemptions as u64).into())
        .set("preempted", r.preempted.into())
        .set("metrics", Json::Arr(metrics));
    o
}

fn record_from_json(j: &Json) -> Result<SessionRecord> {
    let spec_j = j.get("spec").ok_or_else(|| anyhow!("record missing spec"))?;
    let s = |k: &str| spec_j.get(k).and_then(Json::as_str).unwrap_or("").to_string();
    let n = |k: &str| spec_j.get(k).and_then(Json::as_f64).unwrap_or(0.0);
    let mut spec = SessionSpec::new(&s("id"), &s("user"), &s("dataset"), &s("model"));
    spec.gpus = n("gpus") as usize;
    spec.priority = crate::scheduler::Priority::from_str(&s("priority"));
    spec.total_steps = n("total_steps") as u64;
    spec.lr = n("lr");
    spec.seed = n("seed") as u64;
    spec.checkpoint_every = n("checkpoint_every") as u64;
    spec.eval_every = n("eval_every") as u64;
    spec.use_scan = spec_j.get("use_scan").and_then(Json::as_bool).unwrap_or(false);

    let mut rec = SessionRecord::new(spec, j.get("submitted_at_ms").and_then(Json::as_i64).unwrap_or(0) as u64);
    rec.state = state_of(j.get("state").and_then(Json::as_str).unwrap_or("done"));
    rec.steps_done = j.get("steps_done").and_then(Json::as_i64).unwrap_or(0) as u64;
    rec.best_metric = j.get("best_metric").and_then(Json::as_f64);
    rec.recoveries = j.get("recoveries").and_then(Json::as_i64).unwrap_or(0) as u32;
    rec.preemptions = j.get("preemptions").and_then(Json::as_i64).unwrap_or(0) as u32;
    rec.preempted = j.get("preempted").and_then(Json::as_bool).unwrap_or(false);
    if let Some(points) = j.get("metrics").and_then(Json::as_arr) {
        for p in points {
            rec.metrics.log(
                p.get("step").and_then(Json::as_i64).unwrap_or(0) as u64,
                p.get("name").and_then(Json::as_str).unwrap_or(""),
                p.get("value").and_then(Json::as_f64).unwrap_or(f64::NAN),
            );
        }
    }
    Ok(rec)
}

/// Save sessions + leaderboard + checkpoint index + tenant quota
/// overrides + serving endpoints under `<dir>/state.json`.
pub fn save(
    dir: &Path,
    sessions: &SessionStore,
    leaderboard: &Leaderboard,
    checkpoints: &crate::storage::CheckpointStore,
    tenants: &TenantRegistry,
    endpoints: &crate::serving::EndpointRegistry,
) -> Result<()> {
    std::fs::create_dir_all(dir)?;
    let mut doc = Json::obj();
    doc.set("format", 1u64.into());
    let ckpt_records: Vec<Json> = checkpoints
        .dump()
        .iter()
        .map(|c| {
            let bytes = crate::storage::CheckpointStore::record_bytes(c);
            parse(std::str::from_utf8(&bytes).unwrap()).unwrap()
        })
        .collect();
    doc.set("checkpoints", Json::Arr(ckpt_records));
    doc.set("sessions", Json::Arr(sessions.list().iter().map(record_to_json).collect()));
    let mut boards = Json::obj();
    for ds in leaderboard.datasets() {
        let subs: Vec<Json> = leaderboard
            .top(&ds, usize::MAX)
            .iter()
            .map(|s| {
                let mut o = Json::obj();
                o.set("session", s.session.as_str().into())
                    .set("user", s.user.as_str().into())
                    .set("model", s.model.as_str().into())
                    .set("metric_name", s.metric_name.as_str().into())
                    .set("value", s.value.into())
                    .set("step", s.step.into())
                    .set("at_ms", s.at_ms.into());
                o
            })
            .collect();
        boards.set(&ds, Json::Arr(subs));
    }
    doc.set("leaderboard", boards);
    let quotas: Vec<Json> = tenants
        .overrides()
        .iter()
        .map(|(user, q)| {
            let mut o = Json::obj();
            o.set("user", user.as_str().into())
                .set("max_concurrent", q.max_concurrent.into())
                .set("max_gpus", q.max_gpus.into())
                .set("gpu_second_budget", q.gpu_second_budget.into())
                .set("weight", q.weight.into())
                .set("class", q.class.as_str().into())
                .set("max_qps", q.max_qps.into());
            o
        })
        .collect();
    doc.set("quotas", Json::Arr(quotas));
    doc.set("endpoints", endpoints.to_json());
    // Temp file + atomic rename: a crash mid-save leaves either the
    // old state.json or the new one on disk, never a torn file.
    let tmp = dir.join("state.json.tmp");
    std::fs::write(&tmp, doc.to_pretty())?;
    std::fs::rename(&tmp, dir.join("state.json"))?;
    Ok(())
}

/// Load persisted state into live stores (boards must already exist).
pub fn load(
    dir: &Path,
    sessions: &SessionStore,
    leaderboard: &Leaderboard,
    checkpoints: &crate::storage::CheckpointStore,
    tenants: &TenantRegistry,
    endpoints: &crate::serving::EndpointRegistry,
) -> Result<()> {
    let path = dir.join("state.json");
    if !path.exists() {
        return Ok(()); // fresh state dir
    }
    let text = std::fs::read_to_string(&path)?;
    let doc = parse(&text).map_err(|e| anyhow!("state.json: {}", e))?;
    if let Some(records) = doc.get("sessions").and_then(Json::as_arr) {
        for r in records {
            sessions.insert(record_from_json(r)?);
        }
    }
    if let Some(records) = doc.get("checkpoints").and_then(Json::as_arr) {
        for r in records {
            let ck = crate::storage::CheckpointStore::parse_record(r.to_string().as_bytes())?;
            checkpoints.restore(ck);
        }
    }
    if let Some(boards) = doc.get("leaderboard").and_then(Json::as_obj) {
        for (ds, subs) in boards {
            if let Some(arr) = subs.as_arr() {
                for s in arr {
                    let g = |k: &str| s.get(k).and_then(Json::as_str).unwrap_or("").to_string();
                    leaderboard.submit(
                        ds,
                        Submission {
                            session: g("session"),
                            user: g("user"),
                            model: g("model"),
                            metric_name: g("metric_name"),
                            value: s.get("value").and_then(Json::as_f64).unwrap_or(f64::NAN),
                            step: s.get("step").and_then(Json::as_i64).unwrap_or(0) as u64,
                            at_ms: s.get("at_ms").and_then(Json::as_i64).unwrap_or(0) as u64,
                        },
                    );
                }
            }
        }
    }
    if let Some(quotas) = doc.get("quotas").and_then(Json::as_arr) {
        for q in quotas {
            let Some(user) = q.get("user").and_then(Json::as_str) else { continue };
            tenants.set_quota(
                user,
                TenantQuota {
                    max_concurrent: q.get("max_concurrent").and_then(Json::as_i64).unwrap_or(0)
                        as usize,
                    max_gpus: q.get("max_gpus").and_then(Json::as_i64).unwrap_or(0) as usize,
                    gpu_second_budget: q
                        .get("gpu_second_budget")
                        .and_then(Json::as_f64)
                        .unwrap_or(0.0),
                    weight: (q.get("weight").and_then(Json::as_i64).unwrap_or(1).max(1)) as u32,
                    class: q
                        .get("class")
                        .and_then(Json::as_str)
                        .and_then(PriorityClass::from_str)
                        .unwrap_or(PriorityClass::Normal),
                    max_qps: q.get("max_qps").and_then(Json::as_i64).unwrap_or(0).max(0) as u32,
                },
            );
        }
    }
    if let Some(eps) = doc.get("endpoints") {
        endpoints.restore(eps).map_err(|e| anyhow!("state.json endpoints: {}", e))?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_sessions_and_board() {
        let dir = std::env::temp_dir().join(format!("nsml-persist-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);

        let sessions = SessionStore::new();
        let mut spec = SessionSpec::new("kim/mnist/1", "kim", "mnist", "mnist_mlp");
        spec.lr = 0.05;
        spec.use_scan = true;
        let mut rec = SessionRecord::new(spec, 42);
        rec.state = SessionState::Done;
        rec.steps_done = 100;
        rec.best_metric = Some(0.93);
        rec.recoveries = 2;
        rec.preemptions = 1;
        rec.preempted = true;
        rec.metrics.log(10, "train_loss", 1.5);
        rec.metrics.log(20, "accuracy", 0.8);
        sessions.insert(rec);

        let lb = Leaderboard::new();
        lb.ensure_board("mnist", "accuracy", false);
        lb.submit(
            "mnist",
            Submission {
                session: "kim/mnist/1".into(),
                user: "kim".into(),
                model: "mnist_mlp".into(),
                metric_name: "accuracy".into(),
                value: 0.93,
                step: 100,
                at_ms: 50,
            },
        );

        let ckpts = crate::storage::CheckpointStore::new(crate::storage::ObjectStore::memory());
        let mut hp = std::collections::BTreeMap::new();
        hp.insert("lr".to_string(), 0.05);
        ckpts.save("kim/mnist/1", 100, 0.2, &hp, b"params", 7).unwrap();
        let tenants = TenantRegistry::new(TenantQuota::default());
        tenants.set_quota(
            "kim",
            TenantQuota {
                max_concurrent: 2,
                max_gpus: 4,
                gpu_second_budget: 30.5,
                weight: 3,
                class: PriorityClass::High,
                max_qps: 25,
            },
        );
        let endpoints = crate::serving::EndpointRegistry::new();
        endpoints.promote(
            "mnist-prod",
            "kim/mnist/1",
            "mnist_mlp",
            100,
            crate::storage::ObjectId("sha-params".into()),
            60,
        );
        save(&dir, &sessions, &lb, &ckpts, &tenants, &endpoints).unwrap();

        let sessions2 = SessionStore::new();
        let lb2 = Leaderboard::new();
        lb2.ensure_board("mnist", "accuracy", false);
        let ckpts2 = crate::storage::CheckpointStore::new(crate::storage::ObjectStore::memory());
        let tenants2 = TenantRegistry::new(TenantQuota::default());
        let endpoints2 = crate::serving::EndpointRegistry::new();
        load(&dir, &sessions2, &lb2, &ckpts2, &tenants2, &endpoints2).unwrap();
        // Quota overrides survive the round trip.
        let q = tenants2.quota_of("kim");
        assert_eq!(q.max_concurrent, 2);
        assert_eq!(q.max_gpus, 4);
        assert_eq!(q.gpu_second_budget, 30.5);
        assert_eq!(q.weight, 3);
        assert_eq!(q.class, PriorityClass::High);
        assert_eq!(q.max_qps, 25);
        assert_eq!(tenants2.quota_of("lee"), TenantQuota::default());
        // Serving endpoints survive the round trip.
        assert_eq!(endpoints2.list(), endpoints.list());
        let ep = endpoints2.get("mnist-prod").unwrap();
        assert_eq!(ep.active_version().object.0, "sha-params");
        // Checkpoint index survives the round trip.
        let restored = ckpts2.latest("kim/mnist/1").unwrap();
        assert_eq!(restored.step, 100);
        assert_eq!(restored.hparams["lr"], 0.05);

        let r = sessions2.get("kim/mnist/1").unwrap();
        assert_eq!(r.state, SessionState::Done);
        assert_eq!(r.steps_done, 100);
        assert_eq!(r.best_metric, Some(0.93));
        assert_eq!(r.recoveries, 2);
        assert_eq!(r.preemptions, 1);
        assert!(r.preempted);
        assert_eq!(r.spec.lr, 0.05);
        assert!(r.spec.use_scan);
        assert_eq!(r.metrics.series("train_loss"), vec![(10.0, 1.5)]);
        assert_eq!(lb2.best("mnist").unwrap().value, 0.93);

        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn load_missing_state_is_noop() {
        let dir = std::env::temp_dir().join("nsml-persist-none");
        let sessions = SessionStore::new();
        let lb = Leaderboard::new();
        let ckpts = crate::storage::CheckpointStore::new(crate::storage::ObjectStore::memory());
        let tenants = TenantRegistry::new(TenantQuota::default());
        let endpoints = crate::serving::EndpointRegistry::new();
        load(&dir, &sessions, &lb, &ckpts, &tenants, &endpoints).unwrap();
        assert!(sessions.is_empty());
        assert!(tenants.overrides().is_empty());
        assert!(endpoints.is_empty());
    }
}
