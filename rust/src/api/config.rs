//! Platform configuration (defaults mirror the paper's 80-P40 prototype).

use crate::container::LatencyModel;
use crate::util::tomlcfg::Config;
use std::path::PathBuf;

/// Everything needed to assemble an [`super::NsmlPlatform`].
#[derive(Debug, Clone)]
pub struct PlatformConfig {
    /// Cluster shape (default: 10 nodes × 8 GPUs = the paper's 80 GPUs).
    pub nodes: usize,
    pub gpus_per_node: usize,
    pub gpu_mem_gb: f64,
    /// Placement policy name (first_fit | best_fit | worst_fit | random).
    pub policy: String,
    /// §3.2 empty-queue fast path.
    pub fast_path: bool,
    /// Scheduler replicas for leader election.
    pub sched_replicas: usize,
    /// Container operation latencies (virtual milliseconds).
    pub latency: LatencyModel,
    /// Where the AOT artifacts live.
    pub artifacts_dir: PathBuf,
    /// Optional state directory for persistence across CLI invocations.
    pub state_dir: Option<PathBuf>,
    /// Default owner of the built-in datasets.
    pub system_user: String,
    pub seed: u64,
    /// Executor worker threads driving sessions in parallel.
    pub workers: usize,
    /// Let idle executor workers steal pending sessions from loaded
    /// peers (off = static `node % workers` routing).
    pub work_steal: bool,
    /// Echo bus events to stderr as they publish (`[events] echo`).
    /// Explicit config only — the old `NSML_LOG` env sniffing is gone,
    /// so tests and the CLI control echo deterministically.
    pub event_echo: bool,
    /// Event-bus ring retention in events (`[events] capacity`).
    pub event_capacity: usize,
}

impl Default for PlatformConfig {
    fn default() -> PlatformConfig {
        PlatformConfig {
            nodes: 10,
            gpus_per_node: 8,
            gpu_mem_gb: 24.0,
            policy: "best_fit".to_string(),
            fast_path: true,
            sched_replicas: 3,
            latency: LatencyModel::default(),
            artifacts_dir: PathBuf::from("artifacts"),
            state_dir: None,
            system_user: "nsml".to_string(),
            seed: 0,
            workers: 4,
            work_steal: true,
            event_echo: false,
            event_capacity: crate::events::DEFAULT_CAPACITY,
        }
    }
}

impl PlatformConfig {
    /// Small/fast shape for tests and benches.
    pub fn test_default() -> PlatformConfig {
        PlatformConfig {
            nodes: 3,
            gpus_per_node: 4,
            latency: LatencyModel::fast(),
            ..Default::default()
        }
    }

    /// Parse an `nsml.toml`.
    pub fn from_toml_str(text: &str) -> Result<PlatformConfig, String> {
        let cfg = Config::parse(text)?;
        let dflt = PlatformConfig::default();
        let lat_dflt = LatencyModel::default();
        Ok(PlatformConfig {
            nodes: cfg.int_or("cluster", "nodes", dflt.nodes as i64) as usize,
            gpus_per_node: cfg.int_or("cluster", "gpus_per_node", dflt.gpus_per_node as i64) as usize,
            gpu_mem_gb: cfg.float_or("cluster", "gpu_mem_gb", dflt.gpu_mem_gb),
            policy: cfg.str_or("scheduler", "policy", &dflt.policy),
            fast_path: cfg.bool_or("scheduler", "fast_path", dflt.fast_path),
            sched_replicas: cfg.int_or("scheduler", "replicas", dflt.sched_replicas as i64) as usize,
            latency: LatencyModel {
                image_build_ms: cfg.int_or("latency", "image_build_ms", lat_dflt.image_build_ms as i64) as u64,
                image_reuse_ms: cfg.int_or("latency", "image_reuse_ms", lat_dflt.image_reuse_ms as i64) as u64,
                dataset_copy_ms_per_gb: cfg
                    .int_or("latency", "dataset_copy_ms_per_gb", lat_dflt.dataset_copy_ms_per_gb as i64)
                    as u64,
                dataset_share_ms: cfg.int_or("latency", "dataset_share_ms", lat_dflt.dataset_share_ms as i64) as u64,
                boot_ms: cfg.int_or("latency", "boot_ms", lat_dflt.boot_ms as i64) as u64,
            },
            artifacts_dir: PathBuf::from(cfg.str_or("platform", "artifacts_dir", "artifacts")),
            state_dir: {
                let s = cfg.str_or("platform", "state_dir", "");
                if s.is_empty() {
                    None
                } else {
                    Some(PathBuf::from(s))
                }
            },
            system_user: cfg.str_or("platform", "system_user", &dflt.system_user),
            seed: cfg.int_or("platform", "seed", 0) as u64,
            workers: (cfg.int_or("executor", "workers", dflt.workers as i64).max(1)) as usize,
            work_steal: cfg.bool_or("executor", "work_steal", dflt.work_steal),
            event_echo: cfg.bool_or("events", "echo", dflt.event_echo),
            event_capacity: (cfg.int_or("events", "capacity", dflt.event_capacity as i64).max(1))
                as usize,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper_prototype() {
        let c = PlatformConfig::default();
        assert_eq!(c.nodes * c.gpus_per_node, 80);
        assert_eq!(c.policy, "best_fit");
        assert!(c.fast_path);
    }

    #[test]
    fn toml_overrides() {
        let text = r#"
[cluster]
nodes = 4
gpus_per_node = 2
[scheduler]
policy = "first_fit"
fast_path = false
replicas = 5
[latency]
image_build_ms = 100
[platform]
state_dir = "/tmp/nsml-state"
seed = 9
[executor]
workers = 2
work_steal = false
[events]
echo = true
capacity = 500
"#;
        let c = PlatformConfig::from_toml_str(text).unwrap();
        assert_eq!(c.nodes, 4);
        assert_eq!(c.gpus_per_node, 2);
        assert_eq!(c.policy, "first_fit");
        assert!(!c.fast_path);
        assert_eq!(c.sched_replicas, 5);
        assert_eq!(c.latency.image_build_ms, 100);
        assert_eq!(c.latency.boot_ms, LatencyModel::default().boot_ms);
        assert_eq!(c.state_dir, Some(PathBuf::from("/tmp/nsml-state")));
        assert_eq!(c.seed, 9);
        assert_eq!(c.workers, 2);
        assert!(!c.work_steal);
        assert!(c.event_echo);
        assert_eq!(c.event_capacity, 500);
    }

    #[test]
    fn empty_toml_is_defaults() {
        let c = PlatformConfig::from_toml_str("").unwrap();
        assert_eq!(c.nodes, PlatformConfig::default().nodes);
        // Echo is opt-in config, never sniffed from the environment.
        assert!(!c.event_echo);
        assert_eq!(c.event_capacity, crate::events::DEFAULT_CAPACITY);
    }
}
