//! Platform configuration (defaults mirror the paper's 80-P40 prototype).

use crate::container::LatencyModel;
use crate::tenancy::{PriorityClass, TenantQuota, TenantSpec};
use crate::util::tomlcfg::Config;
use std::path::PathBuf;

/// Everything needed to assemble an [`super::NsmlPlatform`].
#[derive(Debug, Clone)]
pub struct PlatformConfig {
    /// Cluster shape (default: 10 nodes × 8 GPUs = the paper's 80 GPUs).
    pub nodes: usize,
    pub gpus_per_node: usize,
    pub gpu_mem_gb: f64,
    /// Placement policy name (first_fit | best_fit | worst_fit | random).
    pub policy: String,
    /// §3.2 empty-queue fast path.
    pub fast_path: bool,
    /// How many blocked jobs a scheduling pass may skip per priority
    /// lane (`[scheduler] skip_window`; 0 = strict head-of-line).
    pub skip_window: usize,
    /// Scheduler replicas for leader election.
    pub sched_replicas: usize,
    /// Container operation latencies (virtual milliseconds).
    pub latency: LatencyModel,
    /// Where the AOT artifacts live.
    pub artifacts_dir: PathBuf,
    /// Optional state directory for persistence across CLI invocations.
    pub state_dir: Option<PathBuf>,
    /// Default owner of the built-in datasets.
    pub system_user: String,
    pub seed: u64,
    /// Executor worker threads driving sessions in parallel.
    pub workers: usize,
    /// Let idle executor workers steal pending sessions from loaded
    /// peers (off = static `node % workers` routing).
    pub work_steal: bool,
    /// Echo bus events to stderr as they publish (`[events] echo`).
    /// Explicit config only — the old `NSML_LOG` env sniffing is gone,
    /// so tests and the CLI control echo deterministically.
    pub event_echo: bool,
    /// Event-bus ring retention in events (`[events] capacity`).
    pub event_capacity: usize,
    /// Fair-share admission control + quota enforcement (`[tenancy]
    /// enabled`). Off = submissions go straight to the scheduler (the
    /// pre-tenancy behaviour, kept as the bench baseline).
    pub tenancy: bool,
    /// Default per-user quota (`[tenancy] max_concurrent / max_gpus /
    /// gpu_second_budget / weight / class`; zeros mean unlimited).
    pub tenant_quota: TenantQuota,
    /// Per-user weight/class overrides from `[tenancy] users =
    /// "name:weight:class,…"`.
    pub tenant_users: Vec<TenantSpec>,
    /// Event-sourced durability (`[durability] enabled`): a bus-fed
    /// write-ahead log plus periodic compacted snapshots, replacing
    /// the per-mutation full `state.json` rewrite. Only effective when
    /// `state_dir` is set.
    pub durability: bool,
    /// Fsync the WAL once per N appended records
    /// (`[durability] fsync_every`; 1 = every record).
    pub wal_fsync_every: u64,
    /// Take a compacted snapshot and rotate the WAL segment every N
    /// appended records (`[durability] snapshot_every`).
    pub snapshot_every: u64,
    /// Sweep unreferenced checkpoint/codepack objects after each
    /// snapshot (`[durability] gc`); `nsml gc` forces a sweep.
    pub gc: bool,
    /// HTTP worker-pool size for `nsml serve` / `nsml web`
    /// (`[service] http_workers`).
    pub http_workers: usize,
    /// Steps each active session may advance per daemon drive round
    /// (`[service] chunk`).
    pub serve_chunk: u64,
    /// How long the daemon loop blocks waiting for requests when no
    /// session is runnable (`[service] idle_ms`).
    pub serve_idle_ms: u64,
    /// Per-connection keep-alive read timeout before the worker
    /// recycles the socket (`[service] keepalive_ms`).
    pub http_keepalive_ms: u64,
    /// Max serving requests micro-batched into one engine execution
    /// (`[serving] max_batch`).
    pub serving_max_batch: usize,
    /// Max virtual milliseconds a queued serving request may wait for
    /// batchmates before the drive loop flushes it
    /// (`[serving] max_wait_ms`).
    pub serving_max_wait_ms: u64,
    /// Replicas every endpoint keeps even when idle
    /// (`[serving] min_replicas`).
    pub serving_min_replicas: usize,
    /// Autoscaler replica ceiling per endpoint
    /// (`[serving] max_replicas`). 0 disables the executor serve lane
    /// entirely: batches execute inline on the platform thread (the
    /// pre-replica behaviour, kept as the bench baseline).
    pub serving_max_replicas: usize,
    /// Queue depth at which the autoscaler adds a replica
    /// (`[serving] scale_up_queue_depth`).
    pub serving_scale_up_queue_depth: usize,
    /// Virtual milliseconds of an empty queue before the autoscaler
    /// removes a replica (`[serving] scale_down_idle_ms`).
    pub serving_scale_down_idle_ms: u64,
    /// Observability (`[obs] enabled`): metrics registry + trace ring +
    /// `/metrics` exposition. Off = every record path is a no-op branch
    /// (the bench baseline for the instrumentation-overhead gate).
    pub obs: bool,
    /// Spans retained in the bounded trace ring
    /// (`[obs] trace_capacity`).
    pub obs_trace_capacity: usize,
    /// Histogram snapshots (one per drive round) that windowed
    /// p50/p95/p99 estimates look back over (`[obs] window`).
    pub obs_window: usize,
}

impl Default for PlatformConfig {
    fn default() -> PlatformConfig {
        PlatformConfig {
            nodes: 10,
            gpus_per_node: 8,
            gpu_mem_gb: 24.0,
            policy: "best_fit".to_string(),
            fast_path: true,
            skip_window: crate::scheduler::DEFAULT_SKIP_WINDOW,
            sched_replicas: 3,
            latency: LatencyModel::default(),
            artifacts_dir: PathBuf::from("artifacts"),
            state_dir: None,
            system_user: "nsml".to_string(),
            seed: 0,
            workers: 4,
            work_steal: true,
            event_echo: false,
            event_capacity: crate::events::DEFAULT_CAPACITY,
            tenancy: true,
            tenant_quota: TenantQuota::default(),
            tenant_users: Vec::new(),
            durability: true,
            wal_fsync_every: 64,
            snapshot_every: 512,
            gc: true,
            http_workers: 8,
            serve_chunk: 25,
            serve_idle_ms: 50,
            http_keepalive_ms: 500,
            serving_max_batch: 64,
            serving_max_wait_ms: 20,
            serving_min_replicas: 1,
            serving_max_replicas: 4,
            serving_scale_up_queue_depth: 16,
            serving_scale_down_idle_ms: 250,
            obs: true,
            obs_trace_capacity: 4096,
            obs_window: 32,
        }
    }
}

impl PlatformConfig {
    /// Small/fast shape for tests and benches.
    pub fn test_default() -> PlatformConfig {
        PlatformConfig {
            nodes: 3,
            gpus_per_node: 4,
            latency: LatencyModel::fast(),
            ..Default::default()
        }
    }

    /// Parse an `nsml.toml`.
    pub fn from_toml_str(text: &str) -> Result<PlatformConfig, String> {
        let cfg = Config::parse(text)?;
        let dflt = PlatformConfig::default();
        let lat_dflt = LatencyModel::default();
        Ok(PlatformConfig {
            nodes: cfg.int_or("cluster", "nodes", dflt.nodes as i64) as usize,
            gpus_per_node: cfg.int_or("cluster", "gpus_per_node", dflt.gpus_per_node as i64) as usize,
            gpu_mem_gb: cfg.float_or("cluster", "gpu_mem_gb", dflt.gpu_mem_gb),
            policy: cfg.str_or("scheduler", "policy", &dflt.policy),
            fast_path: cfg.bool_or("scheduler", "fast_path", dflt.fast_path),
            skip_window: cfg.int_or("scheduler", "skip_window", dflt.skip_window as i64).max(0)
                as usize,
            sched_replicas: cfg.int_or("scheduler", "replicas", dflt.sched_replicas as i64) as usize,
            latency: LatencyModel {
                image_build_ms: cfg.int_or("latency", "image_build_ms", lat_dflt.image_build_ms as i64) as u64,
                image_reuse_ms: cfg.int_or("latency", "image_reuse_ms", lat_dflt.image_reuse_ms as i64) as u64,
                dataset_copy_ms_per_gb: cfg
                    .int_or("latency", "dataset_copy_ms_per_gb", lat_dflt.dataset_copy_ms_per_gb as i64)
                    as u64,
                dataset_share_ms: cfg.int_or("latency", "dataset_share_ms", lat_dflt.dataset_share_ms as i64) as u64,
                boot_ms: cfg.int_or("latency", "boot_ms", lat_dflt.boot_ms as i64) as u64,
            },
            artifacts_dir: PathBuf::from(cfg.str_or("platform", "artifacts_dir", "artifacts")),
            state_dir: {
                let s = cfg.str_or("platform", "state_dir", "");
                if s.is_empty() {
                    None
                } else {
                    Some(PathBuf::from(s))
                }
            },
            system_user: cfg.str_or("platform", "system_user", &dflt.system_user),
            seed: cfg.int_or("platform", "seed", 0) as u64,
            workers: (cfg.int_or("executor", "workers", dflt.workers as i64).max(1)) as usize,
            work_steal: cfg.bool_or("executor", "work_steal", dflt.work_steal),
            event_echo: cfg.bool_or("events", "echo", dflt.event_echo),
            event_capacity: (cfg.int_or("events", "capacity", dflt.event_capacity as i64).max(1))
                as usize,
            tenancy: cfg.bool_or("tenancy", "enabled", dflt.tenancy),
            tenant_quota: TenantQuota {
                max_concurrent: cfg.int_or("tenancy", "max_concurrent", 0).max(0) as usize,
                max_gpus: cfg.int_or("tenancy", "max_gpus", 0).max(0) as usize,
                gpu_second_budget: cfg.float_or("tenancy", "gpu_second_budget", 0.0).max(0.0),
                weight: cfg.int_or("tenancy", "weight", 1).max(1) as u32,
                class: {
                    let name = cfg.str_or("tenancy", "class", "normal");
                    PriorityClass::from_str(&name).ok_or_else(|| {
                        format!("[tenancy] class: unknown priority class '{}'", name)
                    })?
                },
                max_qps: cfg.int_or("tenancy", "max_qps", 0).max(0) as u32,
            },
            tenant_users: parse_tenant_users(&cfg.str_or("tenancy", "users", ""))?,
            durability: cfg.bool_or("durability", "enabled", dflt.durability),
            wal_fsync_every: cfg
                .int_or("durability", "fsync_every", dflt.wal_fsync_every as i64)
                .max(1) as u64,
            snapshot_every: cfg
                .int_or("durability", "snapshot_every", dflt.snapshot_every as i64)
                .max(1) as u64,
            gc: cfg.bool_or("durability", "gc", dflt.gc),
            http_workers: cfg.int_or("service", "http_workers", dflt.http_workers as i64).max(1)
                as usize,
            serve_chunk: cfg.int_or("service", "chunk", dflt.serve_chunk as i64).max(1) as u64,
            serve_idle_ms: cfg.int_or("service", "idle_ms", dflt.serve_idle_ms as i64).max(1)
                as u64,
            http_keepalive_ms: cfg
                .int_or("service", "keepalive_ms", dflt.http_keepalive_ms as i64)
                .max(1) as u64,
            serving_max_batch: cfg
                .int_or("serving", "max_batch", dflt.serving_max_batch as i64)
                .max(1) as usize,
            serving_max_wait_ms: cfg
                .int_or("serving", "max_wait_ms", dflt.serving_max_wait_ms as i64)
                .max(0) as u64,
            serving_min_replicas: cfg
                .int_or("serving", "min_replicas", dflt.serving_min_replicas as i64)
                .max(1) as usize,
            serving_max_replicas: cfg
                .int_or("serving", "max_replicas", dflt.serving_max_replicas as i64)
                .max(0) as usize,
            serving_scale_up_queue_depth: cfg
                .int_or("serving", "scale_up_queue_depth", dflt.serving_scale_up_queue_depth as i64)
                .max(1) as usize,
            serving_scale_down_idle_ms: cfg
                .int_or("serving", "scale_down_idle_ms", dflt.serving_scale_down_idle_ms as i64)
                .max(1) as u64,
            obs: cfg.bool_or("obs", "enabled", dflt.obs),
            obs_trace_capacity: cfg
                .int_or("obs", "trace_capacity", dflt.obs_trace_capacity as i64)
                .max(16) as usize,
            obs_window: cfg.int_or("obs", "window", dflt.obs_window as i64).max(1) as usize,
        })
    }
}

/// Parse `[tenancy] users = "name:weight:class,…"` — weight and class
/// are optional per entry (`"alice:4:high, bob:2, carol"`).
fn parse_tenant_users(text: &str) -> Result<Vec<TenantSpec>, String> {
    let mut specs = Vec::new();
    for entry in text.split(',').map(str::trim).filter(|e| !e.is_empty()) {
        let mut parts = entry.split(':').map(str::trim);
        let user = parts.next().unwrap_or("").to_string();
        if user.is_empty() {
            return Err(format!("[tenancy] users: empty user name in '{}'", entry));
        }
        let weight = match parts.next() {
            None | Some("") => 1,
            Some(w) => w
                .parse::<u32>()
                .map_err(|_| format!("[tenancy] users: bad weight in '{}'", entry))?
                .max(1),
        };
        let class = match parts.next() {
            None | Some("") => PriorityClass::Normal,
            Some(c) => PriorityClass::from_str(c)
                .ok_or_else(|| format!("[tenancy] users: unknown class in '{}'", entry))?,
        };
        specs.push(TenantSpec { user, weight, class });
    }
    Ok(specs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper_prototype() {
        let c = PlatformConfig::default();
        assert_eq!(c.nodes * c.gpus_per_node, 80);
        assert_eq!(c.policy, "best_fit");
        assert!(c.fast_path);
    }

    #[test]
    fn toml_overrides() {
        let text = r#"
[cluster]
nodes = 4
gpus_per_node = 2
[scheduler]
policy = "first_fit"
fast_path = false
skip_window = 4
replicas = 5
[latency]
image_build_ms = 100
[platform]
state_dir = "/tmp/nsml-state"
seed = 9
[executor]
workers = 2
work_steal = false
[events]
echo = true
capacity = 500
[tenancy]
enabled = false
max_concurrent = 3
max_gpus = 8
gpu_second_budget = 120.5
weight = 2
class = "low"
max_qps = 40
users = "alice:4:high, bob:2, carol"
[durability]
enabled = false
fsync_every = 8
snapshot_every = 100
gc = false
[service]
http_workers = 3
chunk = 10
idle_ms = 5
keepalive_ms = 250
[serving]
max_batch = 16
max_wait_ms = 5
min_replicas = 2
max_replicas = 6
scale_up_queue_depth = 8
scale_down_idle_ms = 90
[obs]
enabled = false
trace_capacity = 128
window = 8
"#;
        let c = PlatformConfig::from_toml_str(text).unwrap();
        assert_eq!(c.nodes, 4);
        assert_eq!(c.gpus_per_node, 2);
        assert_eq!(c.policy, "first_fit");
        assert!(!c.fast_path);
        assert_eq!(c.skip_window, 4);
        assert_eq!(c.sched_replicas, 5);
        assert_eq!(c.latency.image_build_ms, 100);
        assert_eq!(c.latency.boot_ms, LatencyModel::default().boot_ms);
        assert_eq!(c.state_dir, Some(PathBuf::from("/tmp/nsml-state")));
        assert_eq!(c.seed, 9);
        assert_eq!(c.workers, 2);
        assert!(!c.work_steal);
        assert!(c.event_echo);
        assert_eq!(c.event_capacity, 500);
        assert!(!c.tenancy);
        assert_eq!(c.tenant_quota.max_concurrent, 3);
        assert_eq!(c.tenant_quota.max_gpus, 8);
        assert_eq!(c.tenant_quota.gpu_second_budget, 120.5);
        assert_eq!(c.tenant_quota.weight, 2);
        assert_eq!(c.tenant_quota.class, PriorityClass::Low);
        assert_eq!(c.tenant_quota.max_qps, 40);
        assert_eq!(
            c.tenant_users,
            vec![
                TenantSpec { user: "alice".into(), weight: 4, class: PriorityClass::High },
                TenantSpec { user: "bob".into(), weight: 2, class: PriorityClass::Normal },
                TenantSpec { user: "carol".into(), weight: 1, class: PriorityClass::Normal },
            ]
        );
        assert!(!c.durability);
        assert_eq!(c.wal_fsync_every, 8);
        assert_eq!(c.snapshot_every, 100);
        assert!(!c.gc);
        assert_eq!(c.http_workers, 3);
        assert_eq!(c.serve_chunk, 10);
        assert_eq!(c.serve_idle_ms, 5);
        assert_eq!(c.http_keepalive_ms, 250);
        assert_eq!(c.serving_max_batch, 16);
        assert_eq!(c.serving_max_wait_ms, 5);
        assert_eq!(c.serving_min_replicas, 2);
        assert_eq!(c.serving_max_replicas, 6);
        assert_eq!(c.serving_scale_up_queue_depth, 8);
        assert_eq!(c.serving_scale_down_idle_ms, 90);
        assert!(!c.obs);
        assert_eq!(c.obs_trace_capacity, 128);
        assert_eq!(c.obs_window, 8);
    }

    #[test]
    fn bad_tenancy_entries_are_rejected() {
        for bad in [
            "[tenancy]\nusers = \"alice:nope\"",
            "[tenancy]\nusers = \"alice:2:frobnicate\"",
            "[tenancy]\nusers = \":2:high\"",
            "[tenancy]\nclass = \"frobnicate\"",
        ] {
            assert!(PlatformConfig::from_toml_str(bad).is_err(), "{}", bad);
        }
        // Stray separators are tolerated; entries stay parsed.
        let c = PlatformConfig::from_toml_str("[tenancy]\nusers = \"alice, ,bob:3\"").unwrap();
        assert_eq!(c.tenant_users.len(), 2);
        assert_eq!(c.tenant_users[1].weight, 3);
    }

    #[test]
    fn empty_toml_is_defaults() {
        let c = PlatformConfig::from_toml_str("").unwrap();
        assert_eq!(c.nodes, PlatformConfig::default().nodes);
        // Echo is opt-in config, never sniffed from the environment.
        assert!(!c.event_echo);
        assert_eq!(c.event_capacity, crate::events::DEFAULT_CAPACITY);
        // Tenancy defaults: enabled, but every limit unlimited.
        assert!(c.tenancy);
        assert_eq!(c.tenant_quota, TenantQuota::default());
        assert!(c.tenant_users.is_empty());
        assert_eq!(c.skip_window, crate::scheduler::DEFAULT_SKIP_WINDOW);
        // Durability defaults: on, batched fsync, periodic snapshots.
        assert!(c.durability);
        assert_eq!(c.wal_fsync_every, 64);
        assert_eq!(c.snapshot_every, 512);
        assert!(c.gc);
        // Service defaults: pooled HTTP front end, 25ms drive chunks.
        assert_eq!(c.http_workers, 8);
        assert_eq!(c.serve_chunk, 25);
        assert_eq!(c.serve_idle_ms, 50);
        assert_eq!(c.http_keepalive_ms, 500);
        // Serving defaults: 64-row batches, 20 virtual ms of patience,
        // autoscaling between 1 and 4 replicas per endpoint.
        assert_eq!(c.serving_max_batch, 64);
        assert_eq!(c.serving_max_wait_ms, 20);
        assert_eq!(c.serving_min_replicas, 1);
        assert_eq!(c.serving_max_replicas, 4);
        assert_eq!(c.serving_scale_up_queue_depth, 16);
        assert_eq!(c.serving_scale_down_idle_ms, 250);
        // Observability defaults: on, 4096-span trace ring, 32-round window.
        assert!(c.obs);
        assert_eq!(c.obs_trace_capacity, 4096);
        assert_eq!(c.obs_window, 32);
    }
}
