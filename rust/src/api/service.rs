//! The command/query service over the platform facade.
//!
//! [`PlatformService`] owns an [`NsmlPlatform`] and exposes exactly one
//! entry point — [`PlatformService::dispatch`] — which executes any
//! [`ApiRequest`] and always returns an [`ApiResponse`] (errors included;
//! dispatch never panics on bad input). Every mutation is audited into
//! the platform event log under source `"api"`, so `nsml logs` shows who
//! asked for what.
//!
//! Two calling conventions:
//!
//! * **In-process** — construct the service and call `dispatch`
//!   synchronously (the CLI and examples do this).
//! * **Cross-thread** — the platform facade is not `Send` (it holds a
//!   thread-local PJRT engine for inference; training runs on the
//!   [`crate::executor`] worker pool), so remote callers like the web
//!   server's connection threads talk over a channel: [`service_channel`]
//!   yields a cloneable [`ServiceHandle`] whose [`ServiceHandle::call`]
//!   blocks until the owning thread pumps the request through
//!   [`PlatformService::serve`] (or [`PlatformService::serve_one`]).
//!   Dispatches that advance training (`drive`, `run_to_completion`)
//!   fan the work out across the executor pool before replying.
//!
//! **Daemon mode** (`nsml serve`) combines both:
//! [`PlatformService::run_daemon`] runs on the platform-owning thread
//! and alternates continuous [`NsmlPlatform::drive_round`] calls with
//! draining queued [`ServiceCall`]s, so training advances with no
//! client `drive`s while HTTP threads keep dispatching. Requests are
//! only answered *between* rounds — pause-the-loop semantics: a
//! mutation never races a round that might touch the same session.
//! The loop idles on the channel when no session is active, exits
//! cleanly when every handle drops (or `stop` is raised, or the
//! bounded-round budget runs out), and persists state on the way out.
//! Loop telemetry (rounds, last-round duration, rounds/sec) lands on
//! the bus as `loop` events and in the `service_status` counters.

use super::wire::{
    ApiError, ApiRequest, ApiResponse, BoardRow, ClusterView, DurabilityView, EndpointView,
    ExecutorStats, MetricsReportView, NodeStatusView, SessionView, SpanView, TenantView,
    TraceView, WorkerStatView,
};
use super::{NsmlPlatform, RunOpts};
use crate::cluster::NodeId;
use crate::runtime::TensorData;
use crate::tenancy::PriorityClass;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

/// One queued request plus its reply slot (see [`service_channel`]).
pub struct ServiceCall {
    req: ApiRequest,
    reply: mpsc::Sender<ApiResponse>,
    /// The caller's trace id. [`ServiceHandle::call`] captures the
    /// calling thread's trace context (minting a fresh id when there is
    /// none), so request-scoped traces survive the channel hop onto the
    /// platform thread.
    trace: Option<String>,
}

impl ServiceCall {
    /// The request awaiting dispatch.
    pub fn request(&self) -> &ApiRequest {
        &self.req
    }

    /// Send the reply (consumes the call; a dropped caller is ignored).
    pub fn respond(self, resp: ApiResponse) {
        let _ = self.reply.send(resp);
    }
}

/// Cloneable, `Send` handle that forwards requests to the thread that
/// owns the platform.
#[derive(Clone)]
pub struct ServiceHandle {
    tx: mpsc::Sender<ServiceCall>,
}

impl ServiceHandle {
    /// Dispatch a request and block for the reply. If the service side
    /// is gone, returns an `internal` error envelope instead of hanging.
    pub fn call(&self, req: ApiRequest) -> ApiResponse {
        let trace =
            crate::obs::trace::current().or_else(|| Some(crate::obs::trace::mint()));
        let (reply, rx) = mpsc::channel();
        if self.tx.send(ServiceCall { req, reply, trace }).is_err() {
            return ApiResponse::Error { error: ApiError::internal("platform service is not running") };
        }
        rx.recv().unwrap_or_else(|_| ApiResponse::Error {
            error: ApiError::internal("platform service dropped the request"),
        })
    }
}

/// Create a handle/receiver pair. The receiver side is pumped by the
/// thread that owns the [`PlatformService`].
pub fn service_channel() -> (ServiceHandle, mpsc::Receiver<ServiceCall>) {
    let (tx, rx) = mpsc::channel();
    (ServiceHandle { tx }, rx)
}

/// Classify an endpoint-registry failure: unknown names are 404s,
/// history edges and checkpoint-less sessions are precondition
/// failures, anything else is a bad request.
fn endpoint_error(message: String) -> ApiError {
    if message.contains("unknown endpoint") {
        ApiError::not_found(message)
    } else if message.contains("already at") || message.contains("no checkpoint") {
        ApiError::failed(message)
    } else {
        ApiError::invalid(message)
    }
}

/// Classify a serving-batch failure: a retire that raced the queue is
/// a precondition failure; an engine/object-store fault is internal.
fn serve_error(message: String) -> ApiError {
    if message.contains("retired") {
        ApiError::failed(message)
    } else {
        ApiError::internal(message)
    }
}

/// Knobs for [`PlatformService::run_daemon`] (`[service]` config).
#[derive(Debug, Clone)]
pub struct DaemonOpts {
    /// Steps each active session may advance per round.
    pub chunk: u64,
    /// Stop after this many rounds, or as soon as no session is active
    /// (0 = run until every handle drops or `stop` is raised).
    pub max_rounds: u64,
    /// How long one idle tick blocks on the request channel.
    pub idle_wait: Duration,
    /// Cooperative shutdown flag, typically shared with the HTTP
    /// front end.
    pub stop: Arc<AtomicBool>,
}

impl Default for DaemonOpts {
    fn default() -> DaemonOpts {
        DaemonOpts {
            chunk: 25,
            max_rounds: 0,
            idle_wait: Duration::from_millis(50),
            stop: Arc::new(AtomicBool::new(false)),
        }
    }
}

/// The versioned service layer over the facade.
pub struct PlatformService {
    platform: NsmlPlatform,
}

impl PlatformService {
    pub fn new(platform: NsmlPlatform) -> PlatformService {
        PlatformService { platform }
    }

    /// Read access to the owned facade (queries, persistence, rendering).
    pub fn platform(&self) -> &NsmlPlatform {
        &self.platform
    }

    pub fn into_platform(self) -> NsmlPlatform {
        self.platform
    }

    /// Execute one request. Total: every outcome is an `ApiResponse`.
    ///
    /// Joins the calling thread's trace context (minting a fresh id when
    /// there is none) and times the dispatch into the obs registry —
    /// see [`dispatch_traced`](Self::dispatch_traced).
    pub fn dispatch(&self, req: ApiRequest) -> ApiResponse {
        let trace = crate::obs::trace::current().unwrap_or_else(crate::obs::trace::mint);
        self.dispatch_traced(req, &trace)
    }

    /// Execute one request under an explicit trace id: sets the trace
    /// context for the duration (so paths below — serving enqueue,
    /// nested dispatches — inherit it), records per-verb latency
    /// (`nsml_dispatch_ms{verb}` / `nsml_dispatch_total{verb}`) and a
    /// `dispatch.<verb>` span, and tags submitted sessions so their
    /// later bus events (placement, state transitions, checkpoints)
    /// join the trace asynchronously.
    pub fn dispatch_traced(&self, req: ApiRequest, trace: &str) -> ApiResponse {
        let obs = self.platform.obs.clone();
        let verb = req.verb();
        // Span timestamp is platform (virtual) time at dispatch START:
        // the dispatch may advance the sim clock, and spans recorded
        // later for this trace must not appear to predate it.
        let at_ms = obs.now_ms();
        let t0 = Instant::now();
        let prev = crate::obs::trace::current();
        crate::obs::trace::set_current(Some(trace.to_string()));
        let resp = self.dispatch_inner(req);
        crate::obs::trace::set_current(prev);
        if obs.enabled() {
            let dur_ms = t0.elapsed().as_secs_f64() * 1000.0;
            obs.metrics.counter("nsml_dispatch_total", &[("verb", verb)]).inc();
            obs.metrics.histogram("nsml_dispatch_ms", &[("verb", verb)]).record(dur_ms);
            obs.traces.record(trace, at_ms, dur_ms, &format!("dispatch.{}", verb), "service", "");
            if let ApiResponse::Submitted { session } = &resp {
                obs.traces.tag(session, trace);
            }
        }
        resp
    }

    fn dispatch_inner(&self, req: ApiRequest) -> ApiResponse {
        self.audit(&req);
        match req {
            ApiRequest::Run(params) => match self.platform.run(&params.user, &params.dataset, params.run_opts()) {
                Ok(id) => ApiResponse::Submitted { session: id },
                Err(e) => ApiResponse::Error { error: ApiError::invalid(format!("run: {:#}", e)) },
            },
            ApiRequest::Pause { session } => self.session_ctl(&session, "pause", |p| p.pause(&session)),
            ApiRequest::Resume { session, lr } => {
                self.session_ctl(&session, "resume", |p| p.resume(&session, lr))
            }
            ApiRequest::Stop { session } => self.session_ctl(&session, "stop", |p| p.stop(&session)),
            ApiRequest::Infer { session, x, shape } => {
                let Some(rec) = self.platform.sessions.get(&session) else {
                    return self.not_found(&session);
                };
                // Overflow-safe element count; dims must be positive.
                let elems = shape
                    .iter()
                    .try_fold(1i64, |acc, &d| if d > 0 { acc.checked_mul(d) } else { None });
                if shape.is_empty() || elems != Some(x.len() as i64) {
                    let described = if shape.is_empty() { None } else { elems };
                    return ApiResponse::Error {
                        error: ApiError::invalid(format!(
                            "infer: shape {:?} describes {} values but the request carries {}",
                            shape,
                            described.map(|n| n.to_string()).unwrap_or_else(|| "no".into()),
                            x.len()
                        ))
                        .with_session(&session),
                    };
                }
                // The compiled executable's input shape is fixed; a
                // self-consistent request of the wrong shape is still a
                // client error and must never reach the engine.
                if let Ok(m) = self.platform.engine().manifest().model(&rec.spec.model) {
                    if shape != m.infer_x_shape {
                        return ApiResponse::Error {
                            error: ApiError::invalid(format!(
                                "infer: shape {:?} ({} values) does not match model '{}' input {:?} ({} values)",
                                shape,
                                x.len(),
                                rec.spec.model,
                                m.infer_x_shape,
                                m.infer_x_shape.iter().product::<i64>(),
                            ))
                            .with_session(&session),
                        };
                    }
                }
                match self.platform.infer(&session, &TensorData::f32(x, &shape)) {
                    Ok(probs) => ApiResponse::Probs { probs },
                    Err(e) => ApiResponse::Error {
                        error: ApiError::failed(format!("infer: {:#}", e)).with_session(&session),
                    },
                }
            }
            ApiRequest::Drive { chunk } => match self.platform.drive(chunk) {
                Ok(n) => ApiResponse::Progressed { sessions: n },
                Err(e) => ApiResponse::Error { error: ApiError::internal(format!("drive: {:#}", e)) },
            },
            ApiRequest::RunToCompletion { chunk, max_rounds } => {
                match self.platform.run_to_completion(chunk, max_rounds) {
                    Ok(()) => ApiResponse::Ack { verb: "run_to_completion".into(), session: None },
                    Err(e) => ApiResponse::Error { error: ApiError::internal(format!("{:#}", e)) },
                }
            }
            ApiRequest::KillNode { node } => {
                if (node as usize) >= self.platform.cluster.node_count() {
                    return ApiResponse::Error {
                        error: ApiError::not_found(format!("no node {}", node)),
                    };
                }
                self.platform.kill_node(NodeId(node));
                ApiResponse::Ack { verb: "kill_node".into(), session: None }
            }
            ApiRequest::ListSessions { limit, offset, user } => ApiResponse::Sessions {
                sessions: self
                    .platform
                    .sessions
                    .list()
                    .iter()
                    .filter(|rec| user.as_deref().map_or(true, |u| rec.spec.user == u))
                    .skip(offset)
                    .take(limit.max(1))
                    .map(SessionView::from_record)
                    .collect(),
            },
            ApiRequest::GetSession { session } => match self.platform.sessions.get(&session) {
                Some(rec) => ApiResponse::Session { session: SessionView::from_record(&rec) },
                None => self.not_found(&session),
            },
            ApiRequest::Board { dataset, limit, user } => {
                if !self.platform.leaderboard.datasets().contains(&dataset) {
                    return ApiResponse::Error {
                        error: ApiError::not_found(format!("no leaderboard for dataset '{}'", dataset)),
                    };
                }
                // Rank over the full board first, then slice: a
                // filtered row keeps its global rank. Unfiltered
                // queries only materialize the requested page.
                let depth = if user.is_none() { limit.max(1) } else { usize::MAX };
                let rows = self
                    .platform
                    .leaderboard
                    .top(&dataset, depth)
                    .into_iter()
                    .enumerate()
                    .filter(|(_, s)| user.as_deref().map_or(true, |u| s.user == u))
                    .take(limit.max(1))
                    .map(|(i, s)| BoardRow {
                        rank: i + 1,
                        session: s.session,
                        user: s.user,
                        model: s.model,
                        metric: s.metric_name,
                        value: s.value,
                        step: s.step,
                    })
                    .collect();
                ApiResponse::Board { dataset, rows }
            }
            ApiRequest::ClusterStatus => ApiResponse::Cluster { cluster: self.cluster_view() },
            ApiRequest::ExecutorStatus => ApiResponse::Executor { executor: self.executor_view() },
            ApiRequest::DurabilityStatus => {
                ApiResponse::Durability { durability: self.durability_view() }
            }
            ApiRequest::ServiceStatus => {
                ApiResponse::Service { service: self.platform.service_status() }
            }
            ApiRequest::TenantReport => ApiResponse::Tenants { tenants: self.tenant_views() },
            ApiRequest::SetQuota {
                user,
                max_concurrent,
                max_gpus,
                gpu_second_budget,
                weight,
                class,
                max_qps,
            } => {
                if user.is_empty() {
                    return ApiResponse::Error {
                        error: ApiError::invalid("set_quota: 'user' must be non-empty"),
                    };
                }
                let class = match class.as_deref() {
                    None => None,
                    Some(name) => match PriorityClass::from_str(name) {
                        Some(c) => Some(c),
                        None => {
                            return ApiResponse::Error {
                                error: ApiError::invalid(format!(
                                    "set_quota: unknown class '{}' (expected low | normal | high)",
                                    name
                                )),
                            }
                        }
                    },
                };
                self.platform.tenancy.registry.update_quota(&user, |q| {
                    if let Some(v) = max_concurrent {
                        q.max_concurrent = v as usize;
                    }
                    if let Some(v) = max_gpus {
                        q.max_gpus = v as usize;
                    }
                    if let Some(v) = gpu_second_budget {
                        q.gpu_second_budget = v.max(0.0);
                    }
                    if let Some(v) = weight {
                        q.weight = (v as u32).max(1);
                    }
                    if let Some(c) = class {
                        q.class = c;
                    }
                    if let Some(v) = max_qps {
                        q.max_qps = v as u32;
                    }
                });
                // A raised quota may unblock deferred work right away.
                if let Err(e) = self.platform.pump_admission() {
                    return ApiResponse::Error {
                        error: ApiError::internal(format!("set_quota: admission pump: {:#}", e)),
                    };
                }
                ApiResponse::Ack { verb: "set_quota".into(), session: None }
            }
            ApiRequest::EventsSince { since, kind, subject, limit } => {
                if let Some(k) = &kind {
                    if !crate::events::ALL_EVENT_KINDS.contains(&k.as_str()) {
                        return ApiResponse::Error {
                            error: ApiError::invalid(format!(
                                "unknown event kind '{}' (expected one of: {})",
                                k,
                                crate::events::ALL_EVENT_KINDS.join(", ")
                            )),
                        };
                    }
                }
                let filter = crate::events::EventFilter { kind, subject, ..Default::default() };
                let batch = self.platform.events.bus().read_since(since, limit, &filter);
                ApiResponse::Events {
                    events: batch.events,
                    next: batch.next,
                    dropped: batch.dropped,
                    overflow: self.platform.events.bus().overflow(),
                }
            }
            ApiRequest::SubmitTrialBatch { user, dataset, trials } => {
                if trials.is_empty() {
                    return ApiResponse::Error {
                        error: ApiError::invalid("submit_trial_batch: empty trial list"),
                    };
                }
                let mut sessions = Vec::with_capacity(trials.len());
                for (i, t) in trials.iter().enumerate() {
                    let opts = RunOpts {
                        gpus: t.gpus.max(1),
                        total_steps: t.total_steps,
                        lr: Some(t.lr),
                        seed: t.seed,
                        checkpoint_every: (t.total_steps / 4).max(1),
                        eval_every: (t.total_steps / 8).max(1),
                        ..RunOpts::default()
                    };
                    match self.platform.run(&user, &dataset, opts) {
                        Ok(id) => sessions.push(id),
                        Err(e) => {
                            // Stop the partial batch so no orphan trials linger.
                            for id in &sessions {
                                let _ = self.platform.stop(id);
                            }
                            return ApiResponse::Error {
                                error: ApiError::invalid(format!(
                                    "submit_trial_batch: trial {} of {} failed: {:#}",
                                    i,
                                    trials.len(),
                                    e
                                )),
                            };
                        }
                    }
                }
                self.platform.events.info(
                    "api",
                    "",
                    format!("trial batch placed: {} sessions on '{}'", sessions.len(), dataset),
                );
                ApiResponse::BatchSubmitted { sessions }
            }
            ApiRequest::Promote { endpoint, action, session } => {
                self.promote_ctl(&endpoint, &action, session.as_deref())
            }
            ApiRequest::Endpoints => ApiResponse::Endpoints {
                endpoints: self
                    .platform
                    .endpoints
                    .list()
                    .iter()
                    .map(|ep| {
                        let (replicas, depth) = self.platform.endpoint_stats(&ep.name);
                        let (p50, p99) = self.platform.endpoint_latency(&ep.name);
                        EndpointView::from_endpoint(ep)
                            .with_stats(replicas as u64, depth as u64)
                            .with_latency(p50, p99)
                    })
                    .collect(),
            },
            ApiRequest::ServeInfer { endpoint, user, x } => {
                self.serve_infer_sync(&endpoint, &user, x)
            }
            ApiRequest::MetricsReport => ApiResponse::Metrics {
                metrics: MetricsReportView::from_snapshot(self.platform.obs.metrics.snapshot()),
            },
            ApiRequest::Trace { id } => {
                let spans = self.platform.obs.traces.get(&id);
                if spans.is_empty() {
                    return ApiResponse::Error {
                        error: ApiError::not_found(format!("no spans recorded for trace '{}'", id)),
                    };
                }
                ApiResponse::Trace {
                    trace: TraceView { id, spans: spans.iter().map(SpanView::from_span).collect() },
                }
            }
        }
    }

    /// The `promote` verb's four actions over the endpoint registry.
    fn promote_ctl(&self, endpoint: &str, action: &str, session: Option<&str>) -> ApiResponse {
        let result = match action {
            "promote" => {
                let Some(session) = session else {
                    return ApiResponse::Error {
                        error: ApiError::invalid(
                            "promote: 'session' is required when action is 'promote'",
                        ),
                    };
                };
                if self.platform.sessions.get(session).is_none() {
                    return self.not_found(session);
                }
                self.platform.promote_endpoint(endpoint, session)
            }
            "rollback" => self.platform.rollback_endpoint(endpoint),
            "rollforward" => self.platform.rollforward_endpoint(endpoint),
            "retire" => {
                return match self.platform.retire_endpoint(endpoint) {
                    Ok(_) => ApiResponse::Ack { verb: "retire".into(), session: None },
                    Err(e) => {
                        ApiResponse::Error { error: endpoint_error(format!("retire: {:#}", e)) }
                    }
                }
            }
            other => {
                return ApiResponse::Error {
                    error: ApiError::invalid(format!("promote: unknown action '{}'", other)),
                }
            }
        };
        match result {
            Ok(_) => match self.platform.endpoints.get(endpoint) {
                Some(ep) => {
                    let (replicas, depth) = self.platform.endpoint_stats(endpoint);
                    let (p50, p99) = self.platform.endpoint_latency(endpoint);
                    ApiResponse::Endpoint {
                        endpoint: EndpointView::from_endpoint(&ep)
                            .with_stats(replicas as u64, depth as u64)
                            .with_latency(p50, p99),
                    }
                }
                None => ApiResponse::Error {
                    error: ApiError::internal(format!(
                        "endpoint '{}' vanished mid-dispatch",
                        endpoint
                    )),
                },
            },
            Err(e) => ApiResponse::Error { error: endpoint_error(format!("{}: {:#}", action, e)) },
        }
    }

    /// Synchronous serving path for plain `dispatch` callers (no drive
    /// loop to flush for them): queue the request, force a flush, and
    /// collect the reply. Under the daemon, `serve_daemon_call` queues
    /// instead and the burst is flushed as one micro-batch.
    fn serve_infer_sync(&self, endpoint: &str, user: &str, x: Vec<f32>) -> ApiResponse {
        let (tx, rx) = mpsc::channel();
        let reply: crate::serving::ServeReply = Box::new(move |r| {
            let _ = tx.send(r);
        });
        if let Err(error) = self.platform.serve_enqueue(endpoint, user, x, reply) {
            return ApiResponse::Error { error };
        }
        self.platform.pump_serving(true);
        match rx.recv() {
            Ok(Ok(row)) => ApiResponse::Served {
                endpoint: endpoint.to_string(),
                version: row.version,
                batch: row.batch as u64,
                probs: row.probs,
            },
            Ok(Err(e)) => ApiResponse::Error { error: serve_error(e) },
            Err(_) => ApiResponse::Error { error: ApiError::internal("serving reply dropped") },
        }
    }

    /// Parse a JSON request envelope, dispatch it, serialize the reply.
    /// Parse errors and unknown verbs become error envelopes, never
    /// panics.
    pub fn dispatch_json(&self, text: &str) -> String {
        let resp = match crate::util::json::parse(text) {
            Err(e) => ApiResponse::Error { error: ApiError::invalid(format!("request parse: {}", e)) },
            Ok(j) => match ApiRequest::from_json(&j) {
                Err(error) => ApiResponse::Error { error },
                Ok(req) => self.dispatch(req),
            },
        };
        resp.to_json().to_string()
    }

    /// Pump queued [`ServiceCall`]s until every [`ServiceHandle`] is
    /// dropped. Run this on the thread that owns the platform.
    ///
    /// Serving requests coalesce: when a `serve_infer` arrives, every
    /// further call already waiting in the channel is queued before
    /// the micro-batcher flushes once — so a burst from N concurrent
    /// clients shares batches instead of each paying batch = 1 (the
    /// same policy as the daemon's between-round drain).
    pub fn serve(&self, rx: &mpsc::Receiver<ServiceCall>) {
        while let Ok(call) = rx.recv() {
            let mut queued_serving = self.serve_daemon_call(call);
            while let Ok(call) = rx.try_recv() {
                queued_serving |= self.serve_daemon_call(call);
            }
            if queued_serving {
                self.platform.pump_serving(true);
            }
        }
    }

    /// Pump exactly one queued call; returns false once the channel is
    /// closed. Useful for tests that serve a known number of requests.
    pub fn serve_one(&self, rx: &mpsc::Receiver<ServiceCall>) -> bool {
        match rx.recv() {
            Ok(call) => {
                let ServiceCall { req, reply, trace } = call;
                let resp = match &trace {
                    Some(t) => self.dispatch_traced(req, t),
                    None => self.dispatch(req),
                };
                let _ = reply.send(resp);
                true
            }
            Err(_) => false,
        }
    }

    /// The always-on drive loop behind `nsml serve`.
    ///
    /// Alternates [`NsmlPlatform::drive_round`] with draining every
    /// queued [`ServiceCall`], so training advances continuously while
    /// clients dispatch — and every request is answered *between*
    /// rounds (a mutation never interleaves with a round). While no
    /// session is active the loop blocks on the channel instead of
    /// spinning. Returns after a clean shutdown — channel disconnected
    /// (every [`ServiceHandle`] dropped), `opts.stop` raised, or the
    /// bounded-round budget spent — and saves platform state on exit.
    pub fn run_daemon(
        &self,
        rx: &mpsc::Receiver<ServiceCall>,
        opts: &DaemonOpts,
    ) -> anyhow::Result<()> {
        self.platform.loop_started();
        let result = self.daemon_loop(rx, opts);
        self.platform.loop_stopped();
        self.platform.save_state()?;
        result
    }

    fn daemon_loop(&self, rx: &mpsc::Receiver<ServiceCall>, opts: &DaemonOpts) -> anyhow::Result<()> {
        let mut rounds: u64 = 0;
        loop {
            if opts.stop.load(Ordering::SeqCst) {
                return Ok(());
            }
            if opts.max_rounds > 0 && rounds >= opts.max_rounds {
                return Ok(());
            }
            if self.platform.active_sessions() > 0 {
                let t0 = Instant::now();
                let progressed = self.platform.drive_round(opts.chunk)?;
                self.platform.loop_round_done(t0.elapsed().as_secs_f64() * 1000.0, progressed);
                rounds += 1;
                // Pause-the-loop point: answer everything that queued
                // up during the round before starting the next one.
                // Serving requests only *queue* here; the flush below
                // packs the whole burst into shared micro-batches.
                let mut queued_serving = false;
                let disconnected = loop {
                    match rx.try_recv() {
                        Ok(call) => queued_serving |= self.serve_daemon_call(call),
                        Err(mpsc::TryRecvError::Empty) => break false,
                        Err(mpsc::TryRecvError::Disconnected) => break true,
                    }
                };
                if queued_serving {
                    self.platform.pump_serving(true);
                }
                if disconnected {
                    return Ok(());
                }
            } else {
                // Idle: nothing to drive, so block (briefly) for work.
                // A bounded run exits here instead of waiting out the
                // budget one idle tick at a time.
                if opts.max_rounds > 0 {
                    return Ok(());
                }
                match rx.recv_timeout(opts.idle_wait) {
                    Ok(call) => {
                        if self.serve_daemon_call(call) {
                            // Gather the rest of the burst, then flush:
                            // with no active session there is no drive
                            // round to expire a waiting batch.
                            while let Ok(c) = rx.try_recv() {
                                self.serve_daemon_call(c);
                            }
                            self.platform.pump_serving(true);
                        }
                    }
                    Err(mpsc::RecvTimeoutError::Timeout) => {}
                    Err(mpsc::RecvTimeoutError::Disconnected) => return Ok(()),
                }
            }
        }
    }

    /// Answer one queued call. Serving requests are *queued*, not
    /// answered — their replies fire when the caller flushes the
    /// micro-batcher — and signal that via the `true` return.
    fn serve_daemon_call(&self, call: ServiceCall) -> bool {
        self.platform.loop_dispatched();
        let ServiceCall { req, reply, trace } = call;
        match req {
            ApiRequest::ServeInfer { endpoint, user, x } => {
                let reply_on_error = reply.clone();
                let ep = endpoint.clone();
                let cb: crate::serving::ServeReply = Box::new(move |r| {
                    let resp = match r {
                        Ok(row) => ApiResponse::Served {
                            endpoint: ep,
                            version: row.version,
                            batch: row.batch as u64,
                            probs: row.probs,
                        },
                        Err(e) => ApiResponse::Error { error: serve_error(e) },
                    };
                    let _ = reply.send(resp);
                });
                // The enqueue span attaches to the caller's trace; the
                // flush/batch spans pick it up from PendingInfer.trace
                // once the micro-batcher fires rounds later.
                let prev = crate::obs::trace::current();
                crate::obs::trace::set_current(trace);
                let queued = self.platform.serve_enqueue(&endpoint, &user, x, cb);
                crate::obs::trace::set_current(prev);
                if let Err(error) = queued {
                    let _ = reply_on_error.send(ApiResponse::Error { error });
                    return false;
                }
                true
            }
            req => {
                let resp = match &trace {
                    Some(t) => self.dispatch_traced(req, t),
                    None => self.dispatch(req),
                };
                let _ = reply.send(resp);
                false
            }
        }
    }

    fn not_found(&self, session: &str) -> ApiResponse {
        ApiResponse::Error {
            error: ApiError::not_found(format!("unknown session '{}'", session)).with_session(session),
        }
    }

    /// Serving requests queued and still unanswered (tests/telemetry).
    pub fn serving_depth(&self) -> usize {
        self.platform.serving_stats().depth
    }

    /// Shared pattern for pause/resume/stop: not-found vs wrong-state.
    fn session_ctl(
        &self,
        session: &str,
        verb: &str,
        f: impl FnOnce(&NsmlPlatform) -> anyhow::Result<()>,
    ) -> ApiResponse {
        if self.platform.sessions.get(session).is_none() {
            return self.not_found(session);
        }
        match f(&self.platform) {
            Ok(()) => ApiResponse::Ack { verb: verb.to_string(), session: Some(session.to_string()) },
            Err(e) => ApiResponse::Error {
                error: ApiError::failed(format!("{}: {:#}", verb, e)).with_session(session),
            },
        }
    }

    fn cluster_view(&self) -> ClusterView {
        let (total, free) = self.platform.cluster.gpu_totals();
        ClusterView {
            nodes: self
                .platform
                .cluster
                .snapshot()
                .iter()
                .map(|n| NodeStatusView {
                    hostname: n.hostname.clone(),
                    alive: n.alive,
                    total_gpus: n.total_gpus,
                    free_gpus: n.free_gpus,
                    jobs: n.jobs.clone(),
                })
                .collect(),
            total_gpus: total,
            free_gpus: free,
            utilization: self.platform.cluster.utilization(),
            queue_len: self.platform.queued_total(),
            policy: self.platform.master.policy_name().to_string(),
            fast_path: self.platform.master.fast_path,
            leader: self.platform.election.leader().map(|(l, _)| l.to_string()),
            epoch: self.platform.election.epoch(),
        }
    }

    /// Executor-pool snapshot: per-worker load + steal telemetry (the
    /// `nsml cluster` table and `GET /api/v1/executor`).
    fn executor_view(&self) -> ExecutorStats {
        let stats = self.platform.executor().stats();
        ExecutorStats {
            live_sessions: stats.iter().map(|s| s.live_sessions).sum(),
            queue_depth: stats.iter().map(|s| s.queue_depth).sum(),
            total_steals: stats.iter().map(|s| s.steals).sum(),
            work_steal: self.platform.executor().stealing(),
            workers: stats
                .iter()
                .map(|s| WorkerStatView {
                    worker: s.worker,
                    live_sessions: s.live_sessions,
                    queue_depth: s.queue_depth,
                    steals: s.steals,
                    busy_ms: s.busy_ms,
                })
                .collect(),
        }
    }

    /// WAL/snapshot/GC counters (the `durability_status` verb and
    /// `GET /api/v1/durability`). When the subsystem is off (no
    /// `state_dir` or `[durability] enabled = false`) every counter
    /// reads zero and `enabled` is false.
    fn durability_view(&self) -> DurabilityView {
        let Some(stats) = self.platform.durability_status() else {
            return DurabilityView {
                consumer_dropped: self.platform.consumer_lag(),
                ..DurabilityView::default()
            };
        };
        let gc = stats.last_gc.as_ref();
        DurabilityView {
            enabled: true,
            wal_records: stats.wal_records,
            wal_bytes: stats.wal_bytes,
            wal_last_seq: stats.wal_last_seq,
            records_since_snapshot: stats.records_since_snapshot,
            snapshot_every: self.platform.config.snapshot_every,
            snapshots: stats.snapshots,
            last_snapshot_seq: stats.last_snapshot_seq,
            wal_dropped: stats.wal_dropped,
            consumer_dropped: self.platform.consumer_lag(),
            gc_enabled: self.platform.config.gc,
            gc_live_objects: gc.map(|g| g.live_objects).unwrap_or(0),
            gc_live_bytes: gc.map(|g| g.live_bytes).unwrap_or(0),
            gc_swept_objects: gc.map(|g| g.swept_objects).unwrap_or(0),
            gc_swept_bytes: gc.map(|g| g.swept_bytes).unwrap_or(0),
        }
    }

    /// One fair-share row per known user (the `tenant_report` verb).
    fn tenant_views(&self) -> Vec<TenantView> {
        let p = &self.platform;
        let now = p.clock.now_ms();
        let mut preempts: BTreeMap<String, u64> = BTreeMap::new();
        for rec in p.sessions.list() {
            *preempts.entry(rec.spec.user.clone()).or_insert(0) += rec.preemptions as u64;
        }
        p.tenancy
            .registry
            .users()
            .into_iter()
            .map(|user| {
                let q = p.tenancy.registry.quota_of(&user);
                let (sessions, gpus) = p.tenancy.registry.occupancy(&user);
                TenantView {
                    weight: q.weight,
                    class: q.class.as_str().to_string(),
                    max_concurrent: q.max_concurrent,
                    max_gpus: q.max_gpus,
                    gpu_second_budget: q.gpu_second_budget,
                    gpu_seconds_used: p.tenancy.accountant.usage_at(&user, now),
                    active_sessions: sessions,
                    gpus_in_use: gpus,
                    waiting: p.tenancy.admission.depth_of(&user),
                    preemptions: preempts.get(&user).copied().unwrap_or(0),
                    user,
                }
            })
            .collect()
    }

    /// Audit mutations into the event log (queries stay silent; `drive`
    /// is logged at debug so pump loops don't flood the log).
    fn audit(&self, req: &ApiRequest) {
        if !req.is_mutation() {
            return;
        }
        let (subject, detail) = match req {
            ApiRequest::Run(p) => (String::new(), format!("user={} dataset={}", p.user, p.dataset)),
            ApiRequest::Pause { session } | ApiRequest::Stop { session } => (session.clone(), String::new()),
            ApiRequest::Resume { session, lr } => (
                session.clone(),
                lr.map(|lr| format!("lr={}", lr)).unwrap_or_default(),
            ),
            ApiRequest::KillNode { node } => (String::new(), format!("node={}", node)),
            ApiRequest::RunToCompletion { chunk, max_rounds } => {
                (String::new(), format!("chunk={} max_rounds={}", chunk, max_rounds))
            }
            ApiRequest::SubmitTrialBatch { user, dataset, trials } => {
                (String::new(), format!("user={} dataset={} trials={}", user, dataset, trials.len()))
            }
            ApiRequest::SetQuota { user, .. } => (String::new(), format!("user={}", user)),
            ApiRequest::Promote { endpoint, action, session } => (
                endpoint.clone(),
                match session {
                    Some(s) => format!("action={} session={}", action, s),
                    None => format!("action={}", action),
                },
            ),
            _ => (String::new(), String::new()),
        };
        let line = if detail.is_empty() {
            format!("dispatch {}", req.verb())
        } else {
            format!("dispatch {} {}", req.verb(), detail)
        };
        if matches!(req, ApiRequest::Drive { .. }) {
            self.platform.events.debug("api", &subject, "dispatch drive");
        } else {
            self.platform.events.info("api", &subject, line);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::PlatformConfig;
    use std::path::PathBuf;

    fn service() -> Option<PlatformService> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        let mut cfg = PlatformConfig::test_default();
        cfg.artifacts_dir = dir;
        Some(PlatformService::new(NsmlPlatform::new(cfg).unwrap()))
    }

    #[test]
    fn unknown_session_is_not_found() {
        let Some(s) = service() else { return };
        for req in [
            ApiRequest::Pause { session: "nope".into() },
            ApiRequest::Resume { session: "nope".into(), lr: None },
            ApiRequest::Stop { session: "nope".into() },
            ApiRequest::GetSession { session: "nope".into() },
            ApiRequest::Infer { session: "nope".into(), x: vec![0.0], shape: vec![1] },
        ] {
            match s.dispatch(req.clone()) {
                ApiResponse::Error { error } => {
                    assert_eq!(error.code, crate::api::ErrorCode::NotFound, "{:?}", req);
                    assert_eq!(error.session.as_deref(), Some("nope"));
                }
                other => panic!("{:?} -> {:?}", req, other),
            }
        }
    }

    #[test]
    fn bad_dataset_and_bad_node_reported() {
        let Some(s) = service() else { return };
        let resp = s.dispatch(ApiRequest::Run(crate::api::RunParams::new("kim", "no-such-dataset")));
        match resp {
            ApiResponse::Error { error } => assert_eq!(error.code, crate::api::ErrorCode::InvalidArgument),
            other => panic!("{:?}", other),
        }
        match s.dispatch(ApiRequest::KillNode { node: 99 }) {
            ApiResponse::Error { error } => assert_eq!(error.code, crate::api::ErrorCode::NotFound),
            other => panic!("{:?}", other),
        }
        match s.dispatch(ApiRequest::Board { dataset: "no-such".into(), limit: 5, user: None }) {
            ApiResponse::Error { error } => assert_eq!(error.code, crate::api::ErrorCode::NotFound),
            other => panic!("{:?}", other),
        }
    }

    #[test]
    fn dispatch_json_never_panics() {
        let Some(s) = service() else { return };
        for garbage in ["", "{", "[1,2]", r#"{"v":1}"#, r#"{"v":1,"verb":"nope","args":{}}"#] {
            let out = s.dispatch_json(garbage);
            let j = crate::util::json::parse(&out).unwrap();
            assert_eq!(j.get("kind").unwrap().as_str(), Some("error"), "input {:?}", garbage);
        }
        let ok = s.dispatch_json(r#"{"v":1,"verb":"cluster_status","args":{}}"#);
        let j = crate::util::json::parse(&ok).unwrap();
        assert_eq!(j.get("kind").unwrap().as_str(), Some("cluster"));
        assert_eq!(j.at(&["data", "cluster", "total_gpus"]).unwrap().as_i64(), Some(12));
    }

    #[test]
    fn executor_status_reports_pool_shape() {
        let Some(s) = service() else { return };
        match s.dispatch(ApiRequest::ExecutorStatus) {
            ApiResponse::Executor { executor } => {
                assert_eq!(executor.workers.len(), s.platform().executor().worker_count());
                assert!(executor.work_steal);
                assert_eq!(executor.live_sessions, 0);
                assert_eq!(executor.queue_depth, 0);
                assert_eq!(executor.total_steals, 0);
            }
            other => panic!("{:?}", other),
        }
    }

    #[test]
    fn durability_status_reads_disabled_without_state_dir() {
        let Some(s) = service() else { return };
        match s.dispatch(ApiRequest::DurabilityStatus) {
            ApiResponse::Durability { durability } => {
                assert!(!durability.enabled, "test_default has no state_dir");
                assert_eq!(durability.wal_records, 0);
                assert_eq!(durability.snapshots, 0);
                assert!(!durability.gc_enabled);
            }
            other => panic!("{:?}", other),
        }
    }

    #[test]
    fn mutations_are_audited() {
        let Some(s) = service() else { return };
        let resp = s.dispatch(ApiRequest::Run(crate::api::RunParams::new("audit", "mnist")));
        assert!(!resp.is_error(), "{:?}", resp);
        let api_events = s.platform().events.query(Some("api"), crate::events::Level::Info);
        assert!(
            api_events.iter().any(|e| {
                let m = e.message();
                m.contains("dispatch run") && m.contains("user=audit")
            }),
            "{:?}",
            api_events.iter().map(|e| e.message()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn events_since_pages_the_bus() {
        let Some(s) = service() else { return };
        // Unknown kinds are rejected before touching the bus.
        match s.dispatch(ApiRequest::EventsSince {
            since: 0,
            kind: Some("frobnicate".into()),
            subject: None,
            limit: 10,
        }) {
            ApiResponse::Error { error } => {
                assert_eq!(error.code, crate::api::ErrorCode::InvalidArgument)
            }
            other => panic!("{:?}", other),
        }
        // Submit a run; its typed placement decision lands on the bus.
        let resp = s.dispatch(ApiRequest::Run(crate::api::RunParams::new("ev", "mnist")));
        assert!(!resp.is_error(), "{:?}", resp);
        let next = match s.dispatch(ApiRequest::EventsSince {
            since: 0,
            kind: Some("placement".into()),
            subject: None,
            limit: 100,
        }) {
            ApiResponse::Events { events, next, dropped, .. } => {
                assert_eq!(dropped, 0);
                assert_eq!(events.len(), 1);
                assert!(matches!(
                    events[0].kind,
                    crate::events::EventKind::PlacementDecided { from_queue: false, .. }
                ));
                next
            }
            other => panic!("{:?}", other),
        };
        // The returned cursor resumes past everything already read.
        let req = ApiRequest::EventsSince { since: next, kind: None, subject: None, limit: 100 };
        match s.dispatch(req) {
            ApiResponse::Events { events, .. } => assert!(events.is_empty(), "{:?}", events),
            other => panic!("{:?}", other),
        }
    }

    #[test]
    fn service_handle_round_trips_across_threads() {
        let Some(s) = service() else { return };
        let (handle, rx) = service_channel();
        let client = std::thread::spawn(move || {
            let resp = handle.call(ApiRequest::ClusterStatus);
            let listed = handle.call(ApiRequest::list_sessions());
            (resp, listed)
        });
        // Serve exactly the two calls, then let the handle drop.
        assert!(s.serve_one(&rx));
        assert!(s.serve_one(&rx));
        let (resp, listed) = client.join().unwrap();
        match resp {
            ApiResponse::Cluster { cluster } => assert_eq!(cluster.total_gpus, 12),
            other => panic!("{:?}", other),
        }
        assert!(matches!(listed, ApiResponse::Sessions { .. }));
        // Channel closed -> serve returns false.
        assert!(!s.serve_one(&rx));
    }

    #[test]
    fn list_sessions_pages_and_filters() {
        let Some(s) = service() else { return };
        for user in ["ann", "ann", "bob"] {
            let resp = s.dispatch(ApiRequest::Run(crate::api::RunParams::new(user, "mnist")));
            assert!(!resp.is_error(), "{:?}", resp);
        }
        let listed = |req: ApiRequest| match s.dispatch(req) {
            ApiResponse::Sessions { sessions } => sessions,
            other => panic!("{:?}", other),
        };
        assert_eq!(listed(ApiRequest::list_sessions()).len(), 3);
        let page = listed(ApiRequest::ListSessions { limit: 2, offset: 0, user: None });
        assert_eq!(page.len(), 2);
        let rest = listed(ApiRequest::ListSessions { limit: 2, offset: 2, user: None });
        assert_eq!(rest.len(), 1);
        // Pages tile the full list without overlap.
        assert!(page.iter().all(|s| s.id != rest[0].id));
        // The user filter applies before paging: offset 1 of ann's
        // sessions is her second, not a global slice.
        let ann = listed(ApiRequest::ListSessions { limit: 10, offset: 1, user: Some("ann".into()) });
        assert_eq!(ann.len(), 1);
        assert_eq!(ann[0].user, "ann");
        assert!(listed(ApiRequest::ListSessions {
            limit: 10,
            offset: 0,
            user: Some("nobody".into())
        })
        .is_empty());
    }

    #[test]
    fn daemon_drives_sessions_to_done_without_client_drives() {
        let Some(s) = service() else { return };
        // Idle platform: all-zero status, not running.
        match s.dispatch(ApiRequest::ServiceStatus) {
            ApiResponse::Service { service } => {
                assert_eq!(service, crate::api::ServiceStatusView::default())
            }
            other => panic!("{:?}", other),
        }
        let (handle, rx) = service_channel();
        let client = std::thread::spawn(move || {
            let mut params = crate::api::RunParams::new("kim", "mnist");
            params.total_steps = 40;
            params.checkpoint_every = 20;
            params.eval_every = 10;
            match handle.call(ApiRequest::Run(params)) {
                ApiResponse::Submitted { session } => session,
                other => panic!("{:?}", other),
            }
            // Handle drops here; the daemon keeps driving to Done and
            // then exits on the disconnected channel — no `drive` call
            // ever crossed the wire.
        });
        let opts = DaemonOpts { idle_wait: Duration::from_millis(2), ..DaemonOpts::default() };
        s.run_daemon(&rx, &opts).unwrap();
        let id = client.join().unwrap();
        let rec = s.platform().sessions.get(&id).unwrap();
        assert_eq!(rec.state, crate::session::SessionState::Done, "{:?}", rec);
        // Telemetry: rounds ticked, dispatches counted, loop stopped.
        match s.dispatch(ApiRequest::ServiceStatus) {
            ApiResponse::Service { service } => {
                assert!(!service.running);
                assert!(service.rounds > 0, "{:?}", service);
                assert!(service.progressed_total > 0, "{:?}", service);
                assert_eq!(service.dispatches, 1);
                assert!(service.rounds_per_sec > 0.0);
            }
            other => panic!("{:?}", other),
        }
        // The loop also narrated itself on the bus.
        let batch = s.platform().events.bus().read_since(
            0,
            0,
            &crate::events::EventFilter { kind: Some("loop".into()), ..Default::default() },
        );
        assert!(!batch.events.is_empty());
    }

    #[test]
    fn daemon_bounded_rounds_and_stop_flag_exit() {
        let Some(s) = service() else { return };
        // No active sessions + bounded budget: returns immediately.
        let (_handle, rx) = service_channel();
        let opts = DaemonOpts { max_rounds: 3, ..DaemonOpts::default() };
        s.run_daemon(&rx, &opts).unwrap();
        // A pre-raised stop flag wins over everything else.
        let opts = DaemonOpts::default();
        opts.stop.store(true, Ordering::SeqCst);
        s.run_daemon(&rx, &opts).unwrap();
        assert!(!s.platform.service_status().running);
    }

    #[test]
    fn dead_service_yields_error_envelope() {
        let (handle, rx) = service_channel();
        drop(rx);
        match handle.call(ApiRequest::list_sessions()) {
            ApiResponse::Error { error } => assert_eq!(error.code, crate::api::ErrorCode::Internal),
            other => panic!("{:?}", other),
        }
    }

    #[test]
    fn metrics_and_trace_verbs_observe_dispatches() {
        let Some(s) = service() else { return };
        // An unknown trace is a 404, not an empty success.
        match s.dispatch(ApiRequest::Trace { id: "never-minted".into() }) {
            ApiResponse::Error { error } => assert_eq!(error.code, crate::api::ErrorCode::NotFound),
            other => panic!("{:?}", other),
        }
        // Dispatch under an explicit trace id; the span lands under it.
        let resp = s.dispatch_traced(ApiRequest::ClusterStatus, "trace-1");
        assert!(!resp.is_error(), "{:?}", resp);
        match s.dispatch(ApiRequest::Trace { id: "trace-1".into() }) {
            ApiResponse::Trace { trace } => {
                assert_eq!(trace.id, "trace-1");
                assert_eq!(trace.spans.len(), 1);
                assert_eq!(trace.spans[0].name, "dispatch.cluster_status");
                assert_eq!(trace.spans[0].source, "service");
            }
            other => panic!("{:?}", other),
        }
        // The registry counted and timed both dispatches above.
        match s.dispatch(ApiRequest::MetricsReport) {
            ApiResponse::Metrics { metrics } => {
                assert!(metrics.enabled);
                let count: f64 = metrics
                    .counters
                    .iter()
                    .filter(|c| c.name == "nsml_dispatch_total")
                    .map(|c| c.value)
                    .sum();
                assert!(count >= 3.0, "{:?}", metrics.counters);
                assert!(metrics.histograms.iter().any(|h| h.name == "nsml_dispatch_ms"));
            }
            other => panic!("{:?}", other),
        }
    }
}
