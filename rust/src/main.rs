//! `nsml` — the NSML platform CLI (leader entrypoint).
//!
//! See `nsml --help` for commands; `rust/src/cli/` implements them.

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    std::process::exit(nsml::cli::main(&args));
}
