//! 12×12 face sketches for the GAN task ("real" samples the generator
//! must learn to imitate). Reuses the emotion-face geometry at the GAN's
//! image resolution; labels are dummies (unsupervised task).

use super::DataGen;
use crate::runtime::{Batch, TensorData};
use crate::util::rng::Rng;

pub const SIDE: usize = 12;
pub const DIM: usize = SIDE * SIDE;
pub const LATENT: usize = 32;

fn put(img: &mut [f32], x: i32, y: i32, v: f32) {
    if (0..SIDE as i32).contains(&x) && (0..SIDE as i32).contains(&y) {
        let i = y as usize * SIDE + x as usize;
        img[i] = (img[i] + v).min(1.0);
    }
}

/// Draw a small face: outline + eyes + smile, with jitter.
pub fn draw_small_face(dx: i32, dy: i32, intensity: f32, out: &mut [f32]) {
    out.fill(0.0);
    let (cx, cy) = (6 + dx, 6 + dy);
    for deg in 0..48 {
        let a = deg as f32 * std::f32::consts::TAU / 48.0;
        put(out, cx + (4.5 * a.cos()).round() as i32, cy + (4.5 * a.sin()).round() as i32, intensity * 0.7);
    }
    put(out, cx - 2, cy - 1, intensity);
    put(out, cx + 2, cy - 1, intensity);
    put(out, cx - 1, cy + 2, intensity);
    put(out, cx, cy + 2, intensity);
    put(out, cx + 1, cy + 2, intensity);
}

/// Generator of "real" faces (and latent batches for `infer`).
pub struct FaceGen {
    rng: Rng,
    eval_rng: Rng,
}

impl FaceGen {
    pub fn new(seed: u64) -> FaceGen {
        let mut root = Rng::new(seed ^ 0xfa7e);
        let eval_rng = root.fork(1);
        FaceGen { rng: root, eval_rng }
    }

    fn draw_batch(rng: &mut Rng, n: usize) -> Batch {
        let mut xs = vec![0.0f32; n * DIM];
        let ys = vec![0.0f32; n]; // unsupervised: dummy targets
        let mut img = vec![0.0f32; DIM];
        for i in 0..n {
            let dx = rng.range(0, 3) as i32 - 1;
            let dy = rng.range(0, 3) as i32 - 1;
            draw_small_face(dx, dy, 0.85 + 0.15 * rng.f64() as f32, &mut img);
            for (j, v) in img.iter().enumerate() {
                let noise = (rng.f64() as f32 - 0.5) * 0.1;
                xs[i * DIM + j] = (v + noise).clamp(0.0, 1.0);
            }
        }
        Batch {
            x: TensorData::f32(xs, &[n as i64, DIM as i64]),
            y: TensorData::f32(ys, &[n as i64]),
        }
    }

    /// A batch of latent vectors for generator sampling (`infer`).
    pub fn latents(&mut self, n: usize) -> TensorData {
        let data: Vec<f32> = (0..n * LATENT).map(|_| self.rng.gauss(0.0, 1.0) as f32).collect();
        TensorData::f32(data, &[n as i64, LATENT as i64])
    }
}

impl DataGen for FaceGen {
    fn name(&self) -> &'static str {
        "faces"
    }

    fn batch(&mut self, n: usize) -> Batch {
        Self::draw_batch(&mut self.rng, n)
    }

    fn eval_batch(&mut self, n: usize) -> Batch {
        Self::draw_batch(&mut self.eval_rng, n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn faces_have_mass_and_structure() {
        let mut g = FaceGen::new(0);
        let b = g.batch(4);
        let xs = b.x.as_f32().unwrap();
        let mass: f32 = xs[..DIM].iter().sum();
        assert!(mass > 3.0 && mass < 80.0, "mass {}", mass);
    }

    #[test]
    fn latents_standard_normal_ish() {
        let mut g = FaceGen::new(1);
        let z = g.latents(64);
        assert_eq!(z.shape(), &[64, LATENT as i64]);
        let data = z.as_f32().unwrap();
        let mean: f32 = data.iter().sum::<f32>() / data.len() as f32;
        assert!(mean.abs() < 0.1, "mean {}", mean);
    }

    #[test]
    fn dummy_labels_are_f32_zeros() {
        let mut g = FaceGen::new(2);
        let b = g.batch(3);
        assert_eq!(b.y.as_f32().unwrap(), &[0.0, 0.0, 0.0]);
    }
}
