//! Procedural 16×16 face sketches with 4 emotion classes
//! (happy / sad / angry / neutral) — the facial-emotion corpus stand-in.

use super::DataGen;
use crate::runtime::{Batch, TensorData};
use crate::util::rng::Rng;

pub const SIDE: usize = 16;
pub const DIM: usize = SIDE * SIDE;
pub const CLASSES: usize = 4;
pub const NAMES: [&str; 4] = ["happy", "sad", "angry", "neutral"];

fn put(img: &mut [f32], x: i32, y: i32, v: f32) {
    if (0..SIDE as i32).contains(&x) && (0..SIDE as i32).contains(&y) {
        let i = y as usize * SIDE + x as usize;
        img[i] = (img[i] + v).min(1.0);
    }
}

/// Draw a face with the given emotion onto a DIM buffer.
pub fn draw_face(emotion: usize, dx: i32, dy: i32, intensity: f32, out: &mut [f32]) {
    out.fill(0.0);
    // Face outline: circle of radius 6 centered (8,8).
    let (cx, cy) = (8 + dx, 8 + dy);
    for deg in 0..72 {
        let a = deg as f32 * std::f32::consts::TAU / 72.0;
        put(out, cx + (6.0 * a.cos()).round() as i32, cy + (6.0 * a.sin()).round() as i32, intensity * 0.8);
    }
    // Eyes.
    let eye_y = cy - 2;
    match emotion {
        2 => {
            // Angry: slanted brows + eyes.
            for i in 0..2 {
                put(out, cx - 3 + i, eye_y - 1 + i, intensity);
                put(out, cx + 3 - i, eye_y - 1 + i, intensity);
            }
            put(out, cx - 2, eye_y + 1, intensity);
            put(out, cx + 2, eye_y + 1, intensity);
        }
        _ => {
            put(out, cx - 2, eye_y, intensity);
            put(out, cx + 2, eye_y, intensity);
        }
    }
    // Mouth: curvature encodes the emotion.
    let mouth_y = cy + 3;
    match emotion {
        0 => {
            // Happy: smile (ends up).
            put(out, cx - 2, mouth_y - 1, intensity);
            put(out, cx - 1, mouth_y, intensity);
            put(out, cx, mouth_y, intensity);
            put(out, cx + 1, mouth_y, intensity);
            put(out, cx + 2, mouth_y - 1, intensity);
        }
        1 => {
            // Sad: frown (ends down).
            put(out, cx - 2, mouth_y + 1, intensity);
            put(out, cx - 1, mouth_y, intensity);
            put(out, cx, mouth_y, intensity);
            put(out, cx + 1, mouth_y, intensity);
            put(out, cx + 2, mouth_y + 1, intensity);
        }
        2 => {
            // Angry: tight straight mouth + bared line.
            for x in -2..=2 {
                put(out, cx + x, mouth_y, intensity);
                put(out, cx + x, mouth_y + 1, intensity * 0.6);
            }
        }
        _ => {
            // Neutral: straight line.
            for x in -2..=2 {
                put(out, cx + x, mouth_y, intensity);
            }
        }
    }
}

/// The emotion-face generator.
pub struct EmotionGen {
    rng: Rng,
    eval_rng: Rng,
}

impl EmotionGen {
    pub fn new(seed: u64) -> EmotionGen {
        let mut root = Rng::new(seed ^ 0xe307);
        let eval_rng = root.fork(1);
        EmotionGen { rng: root, eval_rng }
    }

    fn draw_batch(rng: &mut Rng, n: usize) -> Batch {
        let mut xs = vec![0.0f32; n * DIM];
        let mut ys = Vec::with_capacity(n);
        let mut img = vec![0.0f32; DIM];
        for i in 0..n {
            let emotion = rng.below(CLASSES as u64) as usize;
            let dx = rng.range(0, 3) as i32 - 1;
            let dy = rng.range(0, 3) as i32 - 1;
            let intensity = 0.8 + 0.2 * rng.f64() as f32;
            draw_face(emotion, dx, dy, intensity, &mut img);
            for (j, v) in img.iter().enumerate() {
                let noise = (rng.f64() as f32 - 0.5) * 0.12;
                xs[i * DIM + j] = (v + noise).clamp(0.0, 1.0);
            }
            ys.push(emotion as i32);
        }
        Batch {
            x: TensorData::f32(xs, &[n as i64, DIM as i64]),
            y: TensorData::i32(ys, &[n as i64]),
        }
    }
}

impl DataGen for EmotionGen {
    fn name(&self) -> &'static str {
        "emotions"
    }

    fn batch(&mut self, n: usize) -> Batch {
        Self::draw_batch(&mut self.rng, n)
    }

    fn eval_batch(&mut self, n: usize) -> Batch {
        Self::draw_batch(&mut self.eval_rng, n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_shapes() {
        let mut g = EmotionGen::new(0);
        let b = g.batch(8);
        assert_eq!(b.x.shape(), &[8, DIM as i64]);
        assert!(b.y.as_i32().unwrap().iter().all(|&y| (0..4).contains(&y)));
    }

    #[test]
    fn emotions_differ_in_mouth_region() {
        let mut happy = vec![0.0f32; DIM];
        let mut sad = vec![0.0f32; DIM];
        draw_face(0, 0, 0, 1.0, &mut happy);
        draw_face(1, 0, 0, 1.0, &mut sad);
        let dist: f32 = happy.iter().zip(&sad).map(|(a, b)| (a - b).abs()).sum();
        assert!(dist > 2.0, "happy vs sad distance {}", dist);
    }

    #[test]
    fn all_emotions_draw_something() {
        let mut img = vec![0.0f32; DIM];
        for e in 0..CLASSES {
            draw_face(e, 0, 0, 1.0, &mut img);
            let mass: f32 = img.iter().sum();
            assert!(mass > 5.0, "emotion {} mass {}", e, mass);
        }
    }
}
