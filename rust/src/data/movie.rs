//! Movie-review token sequences with a sentiment lexicon — the
//! movie-rating corpus stand-in (BiLSTM task).
//!
//! Vocabulary: 64 tokens. Tokens 1..=12 are "positive", 13..=24 are
//! "negative", the rest neutral filler. The rating is a noisy affine
//! function of (positives − negatives), clipped to [0, 10] — learnable by
//! the BiLSTM to ~1.0 RMSE, far better than the ~2.9 RMSE of guessing
//! the mean.

use super::DataGen;
use crate::runtime::{Batch, TensorData};
use crate::util::rng::Rng;

pub const SEQ_LEN: usize = 24;
pub const VOCAB: usize = 64;
const POS_RANGE: std::ops::RangeInclusive<i32> = 1..=12;
const NEG_RANGE: std::ops::RangeInclusive<i32> = 13..=24;

/// Ground-truth rating for a token sequence (no noise).
pub fn true_rating(tokens: &[i32]) -> f32 {
    let pos = tokens.iter().filter(|t| POS_RANGE.contains(t)).count() as f32;
    let neg = tokens.iter().filter(|t| NEG_RANGE.contains(t)).count() as f32;
    (5.0 + 1.1 * (pos - neg)).clamp(0.0, 10.0)
}

/// The movie-review generator.
pub struct MovieGen {
    rng: Rng,
    eval_rng: Rng,
}

impl MovieGen {
    pub fn new(seed: u64) -> MovieGen {
        let mut root = Rng::new(seed ^ 0x30b1);
        let eval_rng = root.fork(1);
        MovieGen { rng: root, eval_rng }
    }

    fn draw_batch(rng: &mut Rng, n: usize) -> Batch {
        let mut xs = Vec::with_capacity(n * SEQ_LEN);
        let mut ys = Vec::with_capacity(n);
        for _ in 0..n {
            // Choose a sentiment slant, then fill the review.
            let slant = rng.f64(); // 0 = negative ... 1 = positive
            let mut tokens = Vec::with_capacity(SEQ_LEN);
            for _ in 0..SEQ_LEN {
                let r = rng.f64();
                let tok = if r < 0.18 * slant {
                    1 + rng.below(12) as i32 // positive word
                } else if r < 0.18 * slant + 0.18 * (1.0 - slant) {
                    13 + rng.below(12) as i32 // negative word
                } else {
                    25 + rng.below((VOCAB - 25) as u64) as i32 // filler
                };
                tokens.push(tok);
            }
            let noise = (rng.f64() as f32 - 0.5) * 0.6;
            let rating = (true_rating(&tokens) + noise).clamp(0.0, 10.0);
            xs.extend_from_slice(&tokens);
            ys.push(rating);
        }
        Batch {
            x: TensorData::i32(xs, &[n as i64, SEQ_LEN as i64]),
            y: TensorData::f32(ys, &[n as i64]),
        }
    }
}

impl DataGen for MovieGen {
    fn name(&self) -> &'static str {
        "movie-reviews"
    }

    fn batch(&mut self, n: usize) -> Batch {
        Self::draw_batch(&mut self.rng, n)
    }

    fn eval_batch(&mut self, n: usize) -> Batch {
        Self::draw_batch(&mut self.eval_rng, n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_token_ranges() {
        let mut g = MovieGen::new(0);
        let b = g.batch(10);
        assert_eq!(b.x.shape(), &[10, SEQ_LEN as i64]);
        assert!(b.x.as_i32().unwrap().iter().all(|&t| (0..VOCAB as i32).contains(&t)));
        assert!(b.y.as_f32().unwrap().iter().all(|&r| (0.0..=10.0).contains(&r)));
    }

    #[test]
    fn ratings_track_sentiment() {
        let pos_heavy: Vec<i32> = (0..SEQ_LEN).map(|i| 1 + (i % 12) as i32).collect();
        let neg_heavy: Vec<i32> = (0..SEQ_LEN).map(|i| 13 + (i % 12) as i32).collect();
        let neutral: Vec<i32> = (0..SEQ_LEN).map(|i| 25 + (i % 30) as i32).collect();
        assert_eq!(true_rating(&pos_heavy), 10.0);
        assert_eq!(true_rating(&neg_heavy), 0.0);
        assert_eq!(true_rating(&neutral), 5.0);
    }

    #[test]
    fn rating_variance_exists() {
        // The dataset must not collapse to one rating (else RMSE of the
        // mean would be trivially optimal).
        let mut g = MovieGen::new(1);
        let b = g.batch(128);
        let ys = b.y.as_f32().unwrap();
        let mean: f32 = ys.iter().sum::<f32>() / ys.len() as f32;
        let var: f32 = ys.iter().map(|y| (y - mean).powi(2)).sum::<f32>() / ys.len() as f32;
        assert!(var > 1.0, "variance {}", var);
    }
}
