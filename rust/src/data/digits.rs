//! Procedural 12×12 digit raster images (the MNIST stand-in).
//!
//! Digits are drawn seven-segment style on a 12×12 grid, then jittered
//! (shift, per-pixel noise, stroke intensity). Class structure is strong
//! enough that the MLP reaches >90% accuracy in a few hundred steps, and
//! the Fig.-4 demo ("add lines to a 1 and it becomes a 2") works because
//! digit geometry is explicit.

use super::DataGen;
use crate::runtime::{Batch, TensorData};
use crate::util::rng::Rng;

pub const SIDE: usize = 12;
pub const DIM: usize = SIDE * SIDE;
pub const CLASSES: usize = 10;

/// Segment layout (seven-segment on a 12x12 canvas):
///  A: top bar, B: top-right col, C: bottom-right col, D: bottom bar,
///  E: bottom-left col, F: top-left col, G: middle bar.
const SEGMENTS: [[bool; 7]; 10] = [
    // A      B      C      D      E      F      G
    [true, true, true, true, true, true, false],    // 0
    [false, true, true, false, false, false, false], // 1
    [true, true, false, true, true, false, true],   // 2
    [true, true, true, true, false, false, true],   // 3
    [false, true, true, false, false, true, true],   // 4
    [true, false, true, true, false, true, true],    // 5
    [true, false, true, true, true, true, true],     // 6
    [true, true, true, false, false, false, false],  // 7
    [true, true, true, true, true, true, true],      // 8
    [true, true, true, true, false, true, true],     // 9
];

/// Rasterize one digit with given offsets into a DIM-length buffer.
pub fn draw_digit(digit: usize, dx: i32, dy: i32, intensity: f32, out: &mut [f32]) {
    debug_assert_eq!(out.len(), DIM);
    out.fill(0.0);
    let seg = &SEGMENTS[digit % 10];
    // Canvas box: columns 2..=9, rows 1..=10 (before jitter).
    let mut set = |x: i32, y: i32, v: f32| {
        let (x, y) = (x + dx, y + dy);
        if (0..SIDE as i32).contains(&x) && (0..SIDE as i32).contains(&y) {
            let idx = y as usize * SIDE + x as usize;
            out[idx] = (out[idx] + v).min(1.0);
        }
    };
    let (x0, x1, ytop, ymid, ybot) = (3, 8, 1, 5, 10);
    if seg[0] {
        for x in x0..=x1 {
            set(x, ytop, intensity);
        }
    }
    if seg[6] {
        for x in x0..=x1 {
            set(x, ymid, intensity);
        }
    }
    if seg[3] {
        for x in x0..=x1 {
            set(x, ybot, intensity);
        }
    }
    if seg[5] {
        for y in ytop..=ymid {
            set(x0, y, intensity);
        }
    }
    if seg[4] {
        for y in ymid..=ybot {
            set(x0, y, intensity);
        }
    }
    if seg[1] {
        for y in ytop..=ymid {
            set(x1, y, intensity);
        }
    }
    if seg[2] {
        for y in ymid..=ybot {
            set(x1, y, intensity);
        }
    }
}

/// The MNIST-style generator.
pub struct DigitGen {
    rng: Rng,
    eval_rng: Rng,
}

impl DigitGen {
    pub fn new(seed: u64) -> DigitGen {
        let mut root = Rng::new(seed ^ 0xd161);
        let eval_rng = root.fork(1);
        DigitGen { rng: root, eval_rng }
    }

    fn draw_batch(rng: &mut Rng, n: usize) -> Batch {
        let mut xs = vec![0.0f32; n * DIM];
        let mut ys = Vec::with_capacity(n);
        let mut img = vec![0.0f32; DIM];
        for i in 0..n {
            let digit = rng.below(CLASSES as u64) as usize;
            let dx = rng.range(0, 3) as i32 - 1;
            let dy = rng.range(0, 3) as i32 - 1;
            let intensity = 0.75 + 0.25 * rng.f64() as f32;
            draw_digit(digit, dx, dy, intensity, &mut img);
            for (j, v) in img.iter().enumerate() {
                let noise = (rng.f64() as f32 - 0.5) * 0.15;
                xs[i * DIM + j] = (v + noise).clamp(0.0, 1.0);
            }
            ys.push(digit as i32);
        }
        Batch {
            x: TensorData::f32(xs, &[n as i64, DIM as i64]),
            y: TensorData::i32(ys, &[n as i64]),
        }
    }
}

impl DataGen for DigitGen {
    fn name(&self) -> &'static str {
        "mnist"
    }

    fn batch(&mut self, n: usize) -> Batch {
        Self::draw_batch(&mut self.rng, n)
    }

    fn eval_batch(&mut self, n: usize) -> Batch {
        Self::draw_batch(&mut self.eval_rng, n)
    }
}

/// Render a digit image as ASCII art (the CLI demo, Fig. 4).
pub fn ascii_digit(pixels: &[f32]) -> String {
    let mut s = String::new();
    for y in 0..SIDE {
        for x in 0..SIDE {
            let v = pixels[y * SIDE + x];
            s.push(if v > 0.6 {
                '#'
            } else if v > 0.3 {
                '+'
            } else {
                ' '
            });
        }
        s.push('\n');
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_shapes_and_ranges() {
        let mut g = DigitGen::new(0);
        let b = g.batch(16);
        assert_eq!(b.x.shape(), &[16, DIM as i64]);
        assert_eq!(b.y.shape(), &[16]);
        let xs = b.x.as_f32().unwrap();
        assert!(xs.iter().all(|&v| (0.0..=1.0).contains(&v)));
        let ys = b.y.as_i32().unwrap();
        assert!(ys.iter().all(|&y| (0..10).contains(&y)));
    }

    #[test]
    fn digits_are_distinguishable() {
        // Mean pixel distance between digit classes must be material.
        let mut a = vec![0.0f32; DIM];
        let mut b = vec![0.0f32; DIM];
        draw_digit(1, 0, 0, 1.0, &mut a);
        draw_digit(8, 0, 0, 1.0, &mut b);
        let dist: f32 = a.iter().zip(&b).map(|(p, q)| (p - q).abs()).sum();
        assert!(dist > 10.0, "distance {}", dist);
    }

    #[test]
    fn one_plus_lines_is_two_shaped() {
        // The Fig.4 interaction: a '1' plus the 2's extra segments equals
        // the 2 raster (segments are additive geometry).
        let mut one = vec![0.0f32; DIM];
        let mut two = vec![0.0f32; DIM];
        draw_digit(1, 0, 0, 1.0, &mut one);
        draw_digit(2, 0, 0, 1.0, &mut two);
        // Count of pixels active in 2 but not in 1 — the "lines to add".
        let added = two.iter().zip(&one).filter(|(t, o)| **t > 0.5 && **o < 0.5).count();
        assert!(added >= 10);
    }

    #[test]
    fn eval_stream_differs_from_train() {
        let mut g = DigitGen::new(3);
        let train = g.batch(8);
        let eval = g.eval_batch(8);
        assert_ne!(train.x, eval.x);
    }

    #[test]
    fn ascii_render_contains_strokes() {
        let mut img = vec![0.0f32; DIM];
        draw_digit(0, 0, 0, 1.0, &mut img);
        let art = ascii_digit(&img);
        assert_eq!(art.lines().count(), SIDE);
        assert!(art.contains('#'));
    }
}
