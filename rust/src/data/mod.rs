//! Synthetic dataset generators — the stand-in for the real corpora the
//! paper's alpha tests used (MNIST, face photos, movie reviews).
//!
//! No network access exists in this environment, so each generator
//! produces *learnable structure* procedurally and deterministically from
//! a seed: the models in `python/compile/models.py` reach high accuracy /
//! low loss on them, which is what the platform experiments need
//! (leaderboards, AutoML, learning curves — Fig. 3).
//!
//! Generators also register themselves as platform datasets
//! ([`register_all`]) so sessions mount them through the same
//! storage-container path real uploads would use.

pub mod digits;
pub mod emotion;
pub mod movie;
pub mod faces;

pub use digits::DigitGen;
pub use emotion::EmotionGen;
pub use faces::FaceGen;
pub use movie::MovieGen;

use crate::runtime::Batch;
use crate::storage::DatasetRegistry;
use anyhow::Result;

/// A batched synthetic data source.
pub trait DataGen {
    /// Dataset name (matches the model's expected dataset).
    fn name(&self) -> &'static str;
    /// Draw the next training batch of `n` examples.
    fn batch(&mut self, n: usize) -> Batch;
    /// A held-out evaluation batch (fixed per seed).
    fn eval_batch(&mut self, n: usize) -> Batch;
}

/// Construct the generator a given model trains on.
pub fn generator_for(model: &str, seed: u64) -> Option<Box<dyn DataGen>> {
    match model {
        "mnist_mlp" => Some(Box::new(DigitGen::new(seed))),
        "emotion_cnn" => Some(Box::new(EmotionGen::new(seed))),
        "movie_rnn" => Some(Box::new(MovieGen::new(seed))),
        "face_gan" => Some(Box::new(FaceGen::new(seed))),
        _ => None,
    }
}

/// Dataset name each model consumes (paper: `nsml run -d <dataset>`).
pub fn dataset_for(model: &str) -> &'static str {
    match model {
        "mnist_mlp" => "mnist",
        "emotion_cnn" => "emotions",
        "movie_rnn" => "movie-reviews",
        "face_gan" => "faces",
        _ => "default",
    }
}

/// Model that trains on a dataset (inverse of [`dataset_for`]).
pub fn model_for_dataset(dataset: &str) -> Option<&'static str> {
    match dataset {
        "mnist" => Some("mnist_mlp"),
        "emotions" => Some("emotion_cnn"),
        "movie-reviews" => Some("movie_rnn"),
        "faces" => Some("face_gan"),
        _ => None,
    }
}

/// Register the four alpha-test datasets in the platform registry
/// (a small serialized sample + metadata, like a real `nsml dataset push`).
pub fn register_all(registry: &DatasetRegistry, owner: &str) -> Result<()> {
    let specs: &[(&str, &str, f64)] = &[
        ("mnist", "Procedural 12x12 digit raster images, 10 classes", 0.7),
        ("emotions", "Procedural 16x16 face sketches, 4 emotions", 1.2),
        ("movie-reviews", "Token sequences with sentiment lexicon, rating 0-10", 0.4),
        ("faces", "Procedural 12x12 face sketches for GAN training", 0.9),
    ];
    for (name, desc, size_gb) in specs {
        let model = model_for_dataset(name).unwrap();
        let mut gen = generator_for(model, 0).unwrap();
        let sample = gen.batch(8);
        let bytes = sample_bytes(&sample);
        registry.push(name, owner, true, &[("sample.bin", &bytes)], *size_gb, desc)?;
    }
    Ok(())
}

fn sample_bytes(b: &Batch) -> Vec<u8> {
    let mut out = Vec::new();
    match &b.x {
        crate::runtime::TensorData::F32 { data, .. } => {
            for v in data {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
        crate::runtime::TensorData::I32 { data, .. } => {
            for v in data {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::ObjectStore;

    #[test]
    fn generator_registry_complete() {
        for model in ["mnist_mlp", "emotion_cnn", "movie_rnn", "face_gan"] {
            let mut g = generator_for(model, 1).unwrap();
            let b = g.batch(4);
            assert!(!b.x.is_empty(), "{}", model);
            assert_eq!(dataset_for(model), g.name());
            assert_eq!(model_for_dataset(g.name()), Some(model));
        }
        assert!(generator_for("unknown", 1).is_none());
    }

    #[test]
    fn register_all_populates_registry() {
        let reg = DatasetRegistry::new(ObjectStore::memory());
        register_all(&reg, "nsml").unwrap();
        let names: Vec<String> = reg.list("anyone").into_iter().map(|d| d.name).collect();
        assert_eq!(names, vec!["emotions", "faces", "mnist", "movie-reviews"]);
        let d = reg.get("mnist", "anyone").unwrap();
        assert!(d.files.contains_key("sample.bin"));
        assert!(d.nominal_size_gb > 0.0);
    }

    #[test]
    fn generators_deterministic_per_seed() {
        for model in ["mnist_mlp", "emotion_cnn", "movie_rnn", "face_gan"] {
            let mut a = generator_for(model, 9).unwrap();
            let mut b = generator_for(model, 9).unwrap();
            assert_eq!(a.batch(4).x, b.batch(4).x, "{}", model);
            let mut c = generator_for(model, 10).unwrap();
            assert_ne!(a.batch(4).x, c.batch(4).x, "{}", model);
        }
    }
}
