//! Work-distribution state shared between the pool and its workers:
//! the injector queue for placement-less submissions, one pending deque
//! per worker, the session routing table (the mailbox address book) and
//! the per-worker telemetry counters surfaced by `nsml cluster` and
//! `GET /api/v1/executor`.
//!
//! Only *pending* sessions — plain `Send` data ([`PendingSession`]) —
//! ever move between workers. A materialized
//! [`SessionRun`](crate::session::SessionRun) holds non-`Send` PJRT
//! state and stays on the thread that built it; load balancing therefore
//! happens at adoption time: an idle worker first drains its own deque,
//! then the injector, then steals the oldest pending session from the
//! most-loaded peer (see `Worker::adopt_pending` in `worker.rs`).

use crate::session::SessionSpec;
use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;

/// A submitted session that no worker has materialized yet. Unlike a
/// live run this is plain `Send` data, so it may hop between workers —
/// whichever worker claims it builds the `SessionRun` (fresh start or
/// checkpoint resume) on its own thread.
pub(super) struct PendingSession {
    pub spec: SessionSpec,
    pub resume: bool,
}

/// Where a session currently lives. The routing table *is* the command
/// mailbox address: control verbs are delivered to `worker()`. Stealing
/// a session re-homes its route, so pause/resume/lr-edit keep finding
/// the run after an ownership transfer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(super) enum Route {
    /// In the shared injector queue; no owner yet.
    Injected,
    /// In worker `i`'s pending deque (submitted, not yet materialized).
    Pending(usize),
    /// Materialized: worker `i` owns the live run and its mailbox.
    Live(usize),
    /// Detached while a steal was in flight: a tombstone that makes
    /// the thief's [`Shared::register_live`] abort instead of
    /// resurrecting a session the caller already detached.
    Detached,
}

impl Route {
    pub fn worker(&self) -> Option<usize> {
        match self {
            Route::Injected | Route::Detached => None,
            Route::Pending(w) | Route::Live(w) => Some(*w),
        }
    }
}

/// One worker's telemetry snapshot (see
/// [`ExecutorPool::stats`](super::ExecutorPool::stats)).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WorkerStats {
    /// Worker index (0-based, stable for the pool's lifetime).
    pub worker: usize,
    /// Live (materialized) sessions the worker owns right now.
    pub live_sessions: usize,
    /// Depth of the worker's pending deque.
    pub queue_depth: usize,
    /// Pending sessions this worker has stolen from peers since start.
    pub steals: u64,
    /// Cumulative wall-clock time spent executing mailbox messages.
    pub busy_ms: f64,
}

/// The state every pool handle and worker thread shares.
pub(super) struct Shared {
    /// Placement-less submissions; any worker may claim one.
    injector: Mutex<VecDeque<PendingSession>>,
    /// One pending deque per worker (the preferred owner's inbox).
    deques: Vec<Mutex<VecDeque<PendingSession>>>,
    routes: Mutex<BTreeMap<String, Route>>,
    live: Vec<AtomicUsize>,
    steals: Vec<AtomicU64>,
    busy_nanos: Vec<AtomicU64>,
    /// Work-steal enabled? Off reproduces the static `node % workers`
    /// routing of the pre-steal executor (kept as the bench baseline).
    stealing: bool,
}

impl Shared {
    pub fn new(workers: usize, stealing: bool) -> Shared {
        Shared {
            injector: Mutex::new(VecDeque::new()),
            deques: (0..workers).map(|_| Mutex::new(VecDeque::new())).collect(),
            routes: Mutex::new(BTreeMap::new()),
            live: (0..workers).map(|_| AtomicUsize::new(0)).collect(),
            steals: (0..workers).map(|_| AtomicU64::new(0)).collect(),
            busy_nanos: (0..workers).map(|_| AtomicU64::new(0)).collect(),
            stealing,
        }
    }

    pub fn stealing(&self) -> bool {
        self.stealing
    }

    // -- routing ------------------------------------------------------

    pub fn route_of(&self, id: &str) -> Option<Route> {
        self.routes.lock().unwrap().get(id).copied()
    }

    pub fn set_route(&self, id: &str, route: Route) {
        self.routes.lock().unwrap().insert(id.to_string(), route);
    }

    pub fn remove_route(&self, id: &str) -> Option<Route> {
        self.routes.lock().unwrap().remove(id)
    }

    pub fn routed_ids(&self) -> Vec<String> {
        self.routes.lock().unwrap().keys().cloned().collect()
    }

    pub fn route_count(&self) -> usize {
        self.routes.lock().unwrap().len()
    }

    // -- queues -------------------------------------------------------

    /// Enqueue a pending session on worker `w`'s deque.
    pub fn push_pending(&self, w: usize, p: PendingSession) {
        self.set_route(&p.spec.id, Route::Pending(w));
        self.deques[w].lock().unwrap().push_back(p);
    }

    /// Enqueue a placement-less session into the shared injector.
    pub fn inject(&self, p: PendingSession) {
        self.set_route(&p.spec.id, Route::Injected);
        self.injector.lock().unwrap().push_back(p);
    }

    /// Pop the oldest pending session from worker `w`'s own deque,
    /// counting the claim into `w`'s live tally before the deque lock
    /// is released — a mid-materialization session must stay visible
    /// to peers' load math (fair share, least-loaded, steal targets).
    pub fn pop_own(&self, w: usize) -> Option<PendingSession> {
        let mut dq = self.deques[w].lock().unwrap();
        let p = dq.pop_front();
        if p.is_some() {
            self.live[w].fetch_add(1, Ordering::Relaxed);
        }
        p
    }

    /// Pop the oldest injected session, counting the claim for worker
    /// `w` (see [`Shared::pop_own`]).
    pub fn pop_injected(&self, w: usize) -> Option<PendingSession> {
        let mut inj = self.injector.lock().unwrap();
        let p = inj.pop_front();
        if p.is_some() {
            self.live[w].fetch_add(1, Ordering::Relaxed);
        }
        p
    }

    /// Steal the oldest pending session from the most-loaded peer of
    /// `thief` (load = pending depth + live runs). Counts the claim
    /// for the thief. Returns the session plus the victim worker (for
    /// the thief's `WorkerStolen` event); `None` when no peer has
    /// pending work.
    pub fn steal_for(&self, thief: usize) -> Option<(PendingSession, usize)> {
        let mut best: Option<(usize, usize)> = None;
        for (w, dq) in self.deques.iter().enumerate() {
            if w == thief {
                continue;
            }
            // Depth under the lock first, live second: a pop counts
            // its claim before releasing the deque lock, so this order
            // never observes a session in neither tally.
            let depth = dq.lock().unwrap().len();
            if depth == 0 {
                continue;
            }
            let load = depth + self.live_count(w);
            if best.map_or(0, |(_, l)| l) < load {
                best = Some((w, load));
            }
        }
        let (victim, _) = best?;
        let mut dq = self.deques[victim].lock().unwrap();
        let stolen = dq.pop_front()?;
        self.live[thief].fetch_add(1, Ordering::Relaxed);
        drop(dq);
        self.steals[thief].fetch_add(1, Ordering::Relaxed);
        Some((stolen, victim))
    }

    /// Remove a specific pending session from worker `w`'s deque (the
    /// target of an id-addressed message that has not materialized
    /// yet). Counts the claim for `w`.
    pub fn take_pending(&self, w: usize, id: &str) -> Option<PendingSession> {
        let mut dq = self.deques[w].lock().unwrap();
        let pos = dq.iter().position(|p| p.spec.id == id)?;
        let p = dq.remove(pos);
        if p.is_some() {
            self.live[w].fetch_add(1, Ordering::Relaxed);
        }
        p
    }

    /// Move an injected session onto the least-loaded worker's deque so
    /// an id-addressed message has a concrete owner. Returns the worker
    /// (`None` if the session is gone — or was detached mid-move).
    pub fn adopt_injected(&self, id: &str) -> Option<usize> {
        let p = {
            let mut inj = self.injector.lock().unwrap();
            let pos = inj.iter().position(|p| p.spec.id == id)?;
            inj.remove(pos)?
        };
        let w = self.least_loaded();
        // Re-route under the lock: a detach that raced the move left a
        // tombstone — consume it and drop the session instead of
        // resurrecting it on a deque.
        {
            let mut routes = self.routes.lock().unwrap();
            if matches!(routes.get(id), Some(Route::Detached)) {
                routes.remove(id);
                return None;
            }
            routes.insert(id.to_string(), Route::Pending(w));
        }
        self.deques[w].lock().unwrap().push_back(p);
        Some(w)
    }

    /// Atomically detach a session: remove its route and purge it from
    /// the queues. When the route says `Pending(w)` but the deque
    /// misses (a steal is in flight), a [`Route::Detached`] tombstone
    /// is left so the thief's [`Shared::register_live`] aborts instead
    /// of resurrecting the session. Returns `Some(worker)` when a
    /// (possibly) materialized run must also be dropped through that
    /// worker's mailbox.
    pub fn detach(&self, id: &str) -> Option<usize> {
        let mut routes = self.routes.lock().unwrap();
        match routes.remove(id) {
            None | Some(Route::Detached) => None,
            Some(Route::Injected) => {
                // Nested routes → injector lock (same direction as the
                // deque nesting below; never nested in reverse).
                let purged = {
                    let mut inj = self.injector.lock().unwrap();
                    inj.iter().position(|p| p.spec.id == id).map(|pos| inj.remove(pos))
                };
                if purged.is_none() {
                    // Claimed mid-move/materialization: tombstone so
                    // the claimer's registration aborts.
                    routes.insert(id.to_string(), Route::Detached);
                }
                None
            }
            Some(Route::Pending(w)) => {
                // Nested routes → deque lock; no code path nests the
                // reverse order, so this cannot deadlock.
                let purged = {
                    let mut dq = self.deques[w].lock().unwrap();
                    dq.iter().position(|p| p.spec.id == id).map(|pos| dq.remove(pos))
                };
                if purged.is_some() {
                    return None;
                }
                routes.insert(id.to_string(), Route::Detached);
                Some(w)
            }
            Some(Route::Live(w)) => Some(w),
        }
    }

    /// Register a materialized run's route (re-homing the mailbox to
    /// worker `w`) — unless a detach raced the materialization: then
    /// the tombstone is consumed, `false` is returned, and the caller
    /// must drop the run it just built.
    pub fn register_live(&self, id: &str, w: usize) -> bool {
        let mut routes = self.routes.lock().unwrap();
        if matches!(routes.get(id), Some(Route::Detached)) {
            routes.remove(id);
            return false;
        }
        routes.insert(id.to_string(), Route::Live(w));
        true
    }

    // -- load accounting ----------------------------------------------

    pub fn live_count(&self, w: usize) -> usize {
        self.live[w].load(Ordering::Relaxed)
    }

    /// Release one claim from worker `w`'s live tally (run dropped,
    /// spawn failed, or a detach raced the materialization). The
    /// matching increment happens inside the pop/steal/take claims.
    pub fn live_dec(&self, w: usize) {
        self.live[w].fetch_sub(1, Ordering::Relaxed);
    }

    fn pending_total(&self) -> usize {
        self.injector.lock().unwrap().len()
            + self.deques.iter().map(|d| d.lock().unwrap().len()).sum::<usize>()
    }

    /// Ceiling of (pending + live) / workers: the per-worker adoption
    /// cap that makes concurrent stealing converge to a balanced split.
    pub fn fair_share(&self) -> usize {
        // Pending first, live second (see steal_for): a claim leaves a
        // queue only after its live increment is in place, so this
        // order never observes a session in neither tally and the cap
        // never undercounts.
        let total = self.pending_total()
            + self.live.iter().map(|a| a.load(Ordering::Relaxed)).sum::<usize>();
        total.div_ceil(self.deques.len()).max(1)
    }

    fn least_loaded(&self) -> usize {
        let mut best = 0;
        let mut best_load = usize::MAX;
        for (w, dq) in self.deques.iter().enumerate() {
            // Depth under the lock first, live second (see steal_for).
            let load = dq.lock().unwrap().len() + self.live_count(w);
            if load < best_load {
                best = w;
                best_load = load;
            }
        }
        best
    }

    // -- telemetry ----------------------------------------------------

    pub fn add_busy(&self, w: usize, elapsed: std::time::Duration) {
        self.busy_nanos[w].fetch_add(elapsed.as_nanos() as u64, Ordering::Relaxed);
    }

    pub fn stats(&self) -> Vec<WorkerStats> {
        (0..self.deques.len())
            .map(|w| WorkerStats {
                worker: w,
                live_sessions: self.live_count(w),
                queue_depth: self.deques[w].lock().unwrap().len(),
                steals: self.steals[w].load(Ordering::Relaxed),
                busy_ms: self.busy_nanos[w].load(Ordering::Relaxed) as f64 / 1e6,
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pending(id: &str) -> PendingSession {
        PendingSession {
            spec: SessionSpec::new(id, "u", "mnist", "mnist_mlp"),
            resume: false,
        }
    }

    #[test]
    fn steal_targets_most_loaded_peer() {
        let s = Shared::new(3, true);
        s.push_pending(0, pending("a"));
        s.push_pending(0, pending("b"));
        s.push_pending(1, pending("c"));
        // Worker 2 steals from worker 0 (load 2 beats load 1), oldest first.
        let (got, victim) = s.steal_for(2).unwrap();
        assert_eq!(got.spec.id, "a");
        assert_eq!(victim, 0);
        assert_eq!(s.stats()[2].steals, 1);
        assert_eq!(s.stats()[0].queue_depth, 1);
        // A worker never steals from itself.
        assert_eq!(s.steal_for(1).unwrap().0.spec.id, "b");
        assert_eq!(s.steal_for(0).unwrap().0.spec.id, "c");
        assert!(s.steal_for(0).is_none());
    }

    #[test]
    fn fair_share_and_injector() {
        let s = Shared::new(4, true);
        assert_eq!(s.fair_share(), 1); // empty pool still caps at >= 1
        for i in 0..8 {
            s.inject(pending(&format!("t{}", i)));
        }
        assert_eq!(s.fair_share(), 2);
        assert_eq!(s.route_of("t0"), Some(Route::Injected));
        // Adopting an injected session gives it a concrete owner.
        let w = s.adopt_injected("t3").unwrap();
        assert_eq!(s.route_of("t3"), Some(Route::Pending(w)));
        assert!(s.take_pending(w, "t3").is_some());
        // Oldest-first injector order; claims keep the total invariant
        // (live + pending stays 8, so the fair share does too).
        assert_eq!(s.pop_injected(0).unwrap().spec.id, "t0");
        assert_eq!(s.fair_share(), 2);
    }

    #[test]
    fn claims_count_toward_least_loaded() {
        let s = Shared::new(2, true);
        s.push_pending(0, pending("a"));
        s.push_pending(0, pending("b"));
        // Worker 0 claims both: they leave the deque but stay visible
        // in its live tally while they materialize.
        assert!(s.pop_own(0).is_some());
        assert!(s.pop_own(0).is_some());
        assert_eq!(s.live_count(0), 2);
        s.inject(pending("x"));
        assert_eq!(s.adopt_injected("x"), Some(1));
        s.live_dec(0);
        assert_eq!(s.live_count(0), 1);
    }

    #[test]
    fn detach_mid_steal_tombstones_the_route() {
        let s = Shared::new(2, true);
        s.push_pending(0, pending("a"));
        // Worker 1 steals "a" but has not registered it yet.
        let (stolen, _) = s.steal_for(1).unwrap();
        assert_eq!(stolen.spec.id, "a");
        // A detach arriving in that window cannot find the pending
        // item; it plants a tombstone instead of succeeding silently.
        assert_eq!(s.detach("a"), Some(0));
        // The thief's registration aborts and consumes the tombstone.
        assert!(!s.register_live("a", 1));
        assert!(s.route_of("a").is_none());
        // A normal (unraced) registration still re-homes the route.
        s.push_pending(0, pending("b"));
        let (b, _) = s.steal_for(1).unwrap();
        assert!(s.register_live(&b.spec.id, 1));
        assert_eq!(s.route_of("b"), Some(Route::Live(1)));
        // Detach of a live run reports the owning worker.
        assert_eq!(s.detach("b"), Some(1));
        assert!(s.route_of("b").is_none());
    }
}
